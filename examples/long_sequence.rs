//! Long-sequence training with sparse attention (paper §4.3, Fig. 5b).
//!
//! Two parts:
//!
//! 1. REAL COMPUTE — runs the Linformer + sequence-parallelism attention
//!    path on the native backend (no artifacts needed): each device
//!    projects its local K/V chunk with its slice of the projection
//!    matrix, the partial projections are all-reduced (Table 3's
//!    communication), and attention runs against the fixed-K projected
//!    keys.  Verifies the distributed projection identity
//!    Σₙ Eⁿ Xⁿ = E X  numerically.
//!
//! 2. THREADS (optional, `--threads N`) — runs one dense RSA training
//!    step both ways on a ring of N: sequentially simulated
//!    (`SeqParEngine`) and genuinely parallel with one OS thread per rank
//!    (`exec::DistRunner`), printing the wall-clock for each and checking
//!    the losses agree.
//!
//! 3. SCALE — prints the Fig. 5b sequence-length upper-bound table from
//!    the cluster simulator (the 114K-tokens-on-32-P100s headline).
//!
//!     cargo run --release --example long_sequence [-- --threads 4]

use anyhow::Result;

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::exec::DistRunner;
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_BASE;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::{registry, Runtime};
use seqpar::simulator::{search, sparse, Cluster, Strategy};
use seqpar::tensor::{ops, Tensor};
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::cli::Args;
use seqpar::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::native(NativeConfig { linformer_k: 8, ..NativeConfig::tiny() })?;
    let m = rt.manifest().clone();
    anyhow::ensure!(m.linformer_k > 0, "native config must set linformer_k");
    let (b, n, z, a) = (m.batch, m.ring, m.heads, m.head_dim);
    let lc = m.seq_len / n;
    let kp = m.linformer_k;
    println!(
        "Linformer + sequence parallelism: ring of {n}, chunk {lc} tokens, projection K={kp}"
    );

    // ---- part 1: real compute through the native kernels -----------------
    let mut rng = Rng::new(11);
    let chunk = |rng: &mut Rng| Tensor::randn(&[b, z, lc, a], 1.0, rng);
    let q: Vec<Tensor> = (0..n).map(|_| chunk(&mut rng)).collect();
    let k: Vec<Tensor> = (0..n).map(|_| chunk(&mut rng)).collect();
    let v: Vec<Tensor> = (0..n).map(|_| chunk(&mut rng)).collect();
    // per-device slices of the shared projection matrix E [K, L]
    let e_slices: Vec<Tensor> = (0..n).map(|_| Tensor::randn(&[kp, lc], 0.1, &mut rng)).collect();

    let call1 = |step: &str, inputs: &[&Tensor]| -> Result<Tensor> {
        rt.call1(&registry::art_name_for(step, inputs), inputs)
    };

    let meter = Meter::new();
    let fabric = Fabric::new(n, meter.clone());

    // each device projects its local chunk; all-reduce sums the partials
    let mut k_proj: Vec<Tensor> = (0..n)
        .map(|d| call1("linformer_proj", &[&e_slices[d], &k[d]]))
        .collect::<Result<_>>()?;
    fabric.all_reduce_sum(&mut k_proj)?;
    let mut v_proj: Vec<Tensor> = (0..n)
        .map(|d| call1("linformer_proj", &[&e_slices[d], &v[d]]))
        .collect::<Result<_>>()?;
    fabric.all_reduce_sum(&mut v_proj)?;

    // distributed-projection identity: Σₙ Eⁿ Kⁿ == E K (dense, host-side)
    {
        let full_e = ops::concat_last(&e_slices.iter().collect::<Vec<_>>())?;
        let full_k = ops::concat_dim(&k.iter().collect::<Vec<_>>(), 2)?;
        let dense = host_project(&full_e, &full_k)?;
        let diff = ops::max_abs_diff(&k_proj[0], &dense)?;
        println!("distributed projection identity: max|Δ| = {diff:.2e}");
        anyhow::ensure!(diff < 1e-3, "projection identity violated");
    }

    // attention against the projected K/V — O(L·K) per device, not O(L²)
    for d in 0..n {
        let s = call1("scores_step", &[&q[d], &k_proj[d]])?;
        let p = call1("softmax_fwd", &[&s])?;
        let acc = Tensor::zeros(&q[d].shape);
        let out = call1("av_step", &[&p, &v_proj[d], &acc])?;
        anyhow::ensure!(out.shape == q[d].shape);
        if d == 0 {
            println!(
                "device 0: sparse attention {:?} -> {:?} (score rows {} wide, not {})",
                q[d].shape, out.shape, kp, m.seq_len
            );
        }
    }
    println!(
        "comm: all_reduce={}B ring_p2p={}B — every L-term divided by N (Table 3)",
        meter.get(seqpar::comm::CommKind::AllReduce),
        meter.get(seqpar::comm::CommKind::RingP2p),
    );

    // ---- part 2 (optional): threaded execution ---------------------------
    let threads = Args::parse_env().usize_or("threads", 0)?;
    if threads > 0 {
        let sl = 64usize;
        anyhow::ensure!(sl % threads == 0, "--threads {threads} must divide seq_len {sl}");
        println!("\n=== threaded execution: ring of {threads}, one OS thread per rank ===");
        let rt2 = Runtime::native(NativeConfig { seq_len: sl, ring: threads, ..NativeConfig::tiny() })?;
        let m2 = rt2.manifest().clone();
        let params = ParamStore::synthetic(&m2);
        let batch =
            Corpus::new(CorpusConfig::new(m2.vocab, m2.seq_len, m2.batch), 7).next_batch()?;

        let seq_engine = SeqParEngine::new(&rt2, Fabric::new(threads, Meter::new()))?;
        let t0 = std::time::Instant::now();
        let a = seq_engine.forward_backward(&params, &batch)?;
        let seq_dt = t0.elapsed();

        let dist = DistRunner::new(&rt2, Meter::new())?;
        let t0 = std::time::Instant::now();
        let b = dist.forward_backward(&params, &batch)?;
        let thr_dt = t0.elapsed();

        println!(
            "sequential sim {seq_dt:?}   threaded {thr_dt:?}   Δloss {:.2e}",
            (a.loss - b.loss).abs()
        );
        anyhow::ensure!((a.loss - b.loss).abs() < 1e-4, "threaded loss diverged");
    }

    // ---- part 3: the Fig. 5b upper bound at cluster scale -----------------
    let cluster = Cluster::default();
    println!("\n=== Fig. 5b — BERT-Base length upper bound (batch 4, K=256, 16GB P100) ===");
    println!("{:>8} {:>12} {:>14}", "devices", "dense maxL", "sparse maxL");
    for nn in [1usize, 2, 4, 8, 16, 32] {
        let dense = search::max_seq_len(&cluster, BERT_BASE, 4, 1, 1, Strategy::Sequence { n: nn }, 64);
        let sp = sparse::max_seq_len_linformer(&cluster, BERT_BASE, 4, nn, 256, 64);
        println!("{nn:>8} {dense:>12} {sp:>14}");
    }
    println!("(paper: >114K tokens at 32 devices — 27x beyond single-device sparse attention)");
    Ok(())
}

/// Host-side dense reference for the projection identity check:
/// E [K, L] × X [B, Z, L, A] -> [B, Z, K, A].
fn host_project(e: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (kp, l) = (e.shape[0], e.shape[1]);
    let (b, z, lx, a) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    anyhow::ensure!(l == lx);
    let ed = e.f32s()?;
    let xd = x.f32s()?;
    let mut out = vec![0.0f32; b * z * kp * a];
    for bi in 0..b * z {
        for ki in 0..kp {
            for li in 0..l {
                let w = ed[ki * l + li];
                let xbase = (bi * l + li) * a;
                let obase = (bi * kp + ki) * a;
                for ai in 0..a {
                    out[obase + ai] += w * xd[xbase + ai];
                }
            }
        }
    }
    Tensor::from_f32(&[b, z, kp, a], out)
}
