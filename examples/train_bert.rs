//! End-to-end driver (Fig. 6 / Appendix B): train BERT with sequence
//! parallelism and with the Megatron tensor-parallel baseline FROM THE
//! SAME INITIALIZATION on the same synthetic corpus, and show the loss
//! curves coincide — the paper's convergence-correctness experiment.
//!
//! Runs on the native backend: no artifacts, no python.
//!
//!     cargo run --release --example train_bert -- --steps 200
//!
//! Flags: --steps N (default 200), --seed S, --lr F,
//!        --model NAME (default bert-tiny), --batch N, --seq-len N,
//!        --ring N, --tp N, --engines seq,serial,tensor (default seq,serial)
//!
//! The run is recorded in EXPERIMENTS.md §Fig6.

use anyhow::Result;

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::train::trainer::{LogPoint, TrainConfig, Trainer};
use seqpar::util::cli::Args;

fn run_engine(rt: &Runtime, which: &str, cfg: TrainConfig, seed: u64) -> Result<Vec<LogPoint>> {
    // fresh params + fresh corpus per engine: identical starting point
    // (synthetic init is deterministic in the manifest seed)
    let mut params = ParamStore::synthetic(rt.manifest());
    let m = rt.manifest().clone();
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let meter = Meter::new();
    let curve = match which {
        "seq" => {
            let e = SeqParEngine::new(rt, Fabric::new(m.ring, meter.clone()))?;
            println!("--- engine: {} (ring of {}) ---", e.name(), m.ring);
            Trainer::new(&e, &params, cfg).run(&mut params, || corpus.next_batch(), false)?
        }
        "tensor" => {
            let e = TensorParEngine::new(rt, Fabric::new(m.tp, meter.clone()))?;
            println!("--- engine: {} (group of {}) ---", e.name(), m.tp);
            Trainer::new(&e, &params, cfg).run(&mut params, || corpus.next_batch(), false)?
        }
        "serial" => {
            let e = TensorParEngine::new(rt, Fabric::new(1, meter.clone()))?;
            println!("--- engine: {} ---", e.name());
            Trainer::new(&e, &params, cfg).run(&mut params, || corpus.next_batch(), false)?
        }
        other => anyhow::bail!("unknown engine {other:?}"),
    };
    let s = meter.snapshot();
    println!(
        "    comm: ring_p2p={}MB all_reduce={}MB",
        s.ring_p2p / (1 << 20),
        s.all_reduce / (1 << 20)
    );
    Ok(curve)
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let steps = args.usize_or("steps", 200)? as u64;
    let seed = args.usize_or("seed", 7)? as u64;
    let engines: Vec<String> = args
        .str_or("engines", "seq,serial")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let ncfg = NativeConfig {
        model: seqpar::model::by_name(args.str_or("model", "bert-tiny"))?,
        batch: args.usize_or("batch", 2)?,
        seq_len: args.usize_or("seq-len", 64)?,
        ring: args.usize_or("ring", 4)?,
        tp: args.usize_or("tp", 2)?,
        linformer_k: 0,
        block_w: 0,
        seed: args.usize_or("init-seed", 0)? as u64,
    };
    let rt = Runtime::native(ncfg)?;
    println!(
        "training {} (L={}, B={}) for {} steps on the synthetic Zipf corpus [{} backend]",
        rt.manifest().model,
        rt.manifest().seq_len,
        rt.manifest().batch,
        steps,
        rt.backend_name()
    );
    let cfg = TrainConfig {
        steps,
        warmup: (steps / 10).max(1),
        peak_lr: args.f64_or("lr", 3e-4)? as f32,
        log_every: (steps / 20).max(1),
    };

    let mut curves: Vec<(String, Vec<LogPoint>)> = Vec::new();
    for e in &engines {
        curves.push((e.clone(), run_engine(&rt, e, cfg, seed)?));
    }

    // Fig. 6 claim: the engines' curves coincide (same math, same data).
    println!("\n=== Fig. 6 — convergence comparison (MLM / SOP loss) ===");
    println!("{:>6} {}", "step", curves.iter().map(|(n, _)| format!("{n:>22}")).collect::<String>());
    let rows = curves[0].1.len();
    for i in 0..rows {
        let step = curves[0].1[i].step;
        let mut line = format!("{step:>6}");
        for (_, c) in &curves {
            line += &format!("   mlm {:>6.4} sop {:>5.3}", c[i].mlm, c[i].sop);
        }
        println!("{line}");
    }
    if curves.len() >= 2 {
        let last: Vec<f32> = curves.iter().map(|(_, c)| c.last().unwrap().loss).collect();
        let spread = last
            .iter()
            .fold(0.0f32, |acc, &x| acc.max((x - last[0]).abs()));
        println!("\nfinal-loss spread across engines: {spread:.2e}");
        anyhow::ensure!(
            spread < 0.05,
            "engines diverged: final losses {last:?}"
        );
        // the corpus is learnable: the (smoothed) total loss must go DOWN.
        // At this batch size the per-step MLM is noisy (~13 masked tokens),
        // so compare window means; the SOP head converges sharply.
        let c = &curves[0].1;
        let w = (c.len() / 4).max(1);
        let head: f32 = c[..w].iter().map(|p| p.loss).sum::<f32>() / w as f32;
        let tail: f32 = c[c.len() - w..].iter().map(|p| p.loss).sum::<f32>() / w as f32;
        anyhow::ensure!(
            tail < head,
            "smoothed loss did not improve: {head:.4} -> {tail:.4}"
        );
        println!(
            "convergence OK — engines agree and the smoothed loss decreases \
             ({head:.4} -> {tail:.4}; paper Fig. 6)"
        );
    }
    Ok(())
}
