//! Quickstart: Ring Self-Attention across 4 simulated devices.
//!
//! Runs entirely on the native backend — no artifacts, no python.  A
//! random batch of queries/keys/values is chunked along the sequence
//! dimension, the paper's RSA (ring-QK^T → softmax → ring-AV) computes
//! per-device attention, and the result is checked against monolithic
//! full-sequence attention computed through the same backend's serial
//! step kernels.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::runtime::{registry, Runtime};
use seqpar::tensor::{ops, Tensor};
use seqpar::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::native(NativeConfig::tiny())?;
    let m = rt.manifest().clone();
    let n = m.ring;
    let (b, z, a, l) = (m.batch, m.heads, m.head_dim, m.seq_len);
    let lc = l / n;
    println!(
        "model {}  ring size {n}  (chunk = {lc} of {l} tokens, backend {})",
        m.model,
        rt.backend_name()
    );

    // random full-length q/k/v, then chunked along the sequence dim
    let mut rng = Rng::new(1);
    let q_full = Tensor::randn(&[b, z, l, a], 1.0, &mut rng);
    let k_full = Tensor::randn(&[b, z, l, a], 1.0, &mut rng);
    let v_full = Tensor::randn(&[b, z, l, a], 1.0, &mut rng);
    let chunk = |t: &Tensor| -> Result<Vec<Tensor>> {
        let flat = t.clone().reshaped(&[b * z, l, a])?;
        ops::chunk_dim1(&flat, n)?
            .into_iter()
            .map(|c| c.reshaped(&[b, z, lc, a]))
            .collect()
    };
    let q = chunk(&q_full)?;
    let k = chunk(&k_full)?;
    let v = chunk(&v_full)?;

    // monolithic reference through the serial-shape kernels of the SAME
    // backend: scores -> softmax -> AV over the full sequence
    let call1 = |step: &str, inputs: &[&Tensor]| -> Result<Tensor> {
        rt.call1(&registry::art_name_for(step, inputs), inputs)
    };
    let s = call1("scores_step", &[&q_full, &k_full])?;
    let p = call1("softmax_fwd", &[&s])?;
    let acc = Tensor::zeros(&[b, z, l, a]);
    let mono = call1("av_step", &[&p, &v_full, &acc])?;
    let want = chunk(&mono)?;

    // the distributed version through the metered ring
    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(n, meter.clone()))?;
    let out = engine.rsa_attention(&q, &k, &v)?;

    let mut worst = 0.0f32;
    for d in 0..n {
        let diff = ops::max_abs_diff(&out[d], &want[d])?;
        println!(
            "device {d}: attention chunk {:?}, max|Δ| vs monolithic = {diff:.2e}",
            out[d].shape
        );
        worst = worst.max(diff);
    }
    println!(
        "ring traffic: {} bytes over {} P2P ops (2 x (N-1) rotations — paper §3.2.2)",
        meter.get(CommKind::RingP2p),
        meter.snapshot().ops
    );
    anyhow::ensure!(worst < 1e-4, "RSA output diverged from monolithic: {worst}");
    println!("quickstart OK — distributed RSA == monolithic attention");
    Ok(())
}
