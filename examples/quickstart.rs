//! Quickstart: Ring Self-Attention across 4 simulated devices.
//!
//! Loads the AOT artifacts, chunks a batch of queries/keys/values along
//! the sequence dimension, runs the paper's RSA (ring-QK^T → softmax →
//! ring-AV) through the PJRT runtime, and checks the result against the
//! monolithic-attention golden exported by the python compile path.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::runtime::Runtime;
use seqpar::tensor::{io, ops};

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let rt = Runtime::open(&dir)?;
    let n = rt.manifest.ring;
    println!(
        "model {}  ring size {}  (chunk = {} of {} tokens)",
        rt.manifest.model,
        n,
        rt.manifest.seq_len / n,
        rt.manifest.seq_len
    );

    // golden q/k/v chunks + expected outputs, exported by aot.py from the
    // pure-jnp reference (ref.ring_attention == monolithic attention).
    let load = |name: &str| io::load(&dir.join(&rt.manifest.goldens[name]));
    let mut q = Vec::new();
    let mut k = Vec::new();
    let mut v = Vec::new();
    let mut want = Vec::new();
    for d in 0..n {
        q.push(load(&format!("qs_dev{d}"))?);
        k.push(load(&format!("ks_dev{d}"))?);
        v.push(load(&format!("vs_dev{d}"))?);
        want.push(load(&format!("attn_out_dev{d}"))?);
    }

    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(n, meter.clone()))?;
    let out = engine.rsa_attention(&q, &k, &v)?;

    let mut worst = 0.0f32;
    for d in 0..n {
        let diff = ops::max_abs_diff(&out[d], &want[d])?;
        println!("device {d}: attention chunk {:?}, max|Δ| vs golden = {diff:.2e}", out[d].shape);
        worst = worst.max(diff);
    }
    println!(
        "ring traffic: {} bytes over {} P2P ops (2 x (N-1) rotations — paper §3.2.2)",
        meter.get(CommKind::RingP2p),
        meter.snapshot().ops
    );
    anyhow::ensure!(worst < 1e-4, "RSA output diverged from golden: {worst}");
    println!("quickstart OK — distributed RSA == monolithic attention");
    Ok(())
}
