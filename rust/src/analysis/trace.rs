//! The trace instrument: a [`Collective`] that records WHAT a rank's
//! schedule would send instead of sending it.
//!
//! [`TraceCollective`] is a single-rank view (like the threaded
//! `RingComm`: one slot, one global rank) whose collectives append a
//! [`TraceEvent`] — kind, routing parameters, exact payload bytes — and
//! rewrite the slot's SHAPE exactly as the real fabric would (all-gather
//! concatenates, all-to-all re-shards, a skipped sparse hop leaves the
//! empty placeholder).  No payload ever moves; the values are whatever
//! zeros the [`super::ShapeExecutor`] produced.
//!
//! Metering mirrors the per-rank convention of `comm::threaded::RingComm`
//! byte-for-byte: ring P2P is metered at each sender, the formula
//! collectives once per group call (at rank 0 / the root) on the
//! canonical group totals.  Abstract-interpreting every rank of a group
//! therefore lands the SAME per-kind byte totals as either real
//! execution — that is what makes the derived closed forms comparable to
//! measured meters exactly (`rust/tests/analysis_props.rs`).

use std::cell::RefCell;
use std::fmt;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::comm::{Collective, CommKind, Meter};
use crate::tensor::{ops, Tensor};

/// One collective call as one rank's schedule would issue it: the kind,
/// every routing parameter that must agree across the group, and the
/// exact payload size.  Two ranks deadlock-match iff their event
/// sequences are equal element-wise.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// One ring hop: this rank's chunk moves to rank+1.
    RingShift { bytes: u64 },
    /// Group all-reduce of a `bytes`-sized tensor.
    AllReduce { bytes: u64 },
    /// Group all-gather along `dim` of a `bytes`-sized local chunk.
    AllGather { dim: usize, bytes: u64 },
    /// Replication from `root` of a `bytes`-sized tensor.
    Broadcast { root: usize, bytes: u64 },
    /// Head-shard transpose of a `bytes`-sized local tensor.
    AllToAll { split_dim: usize, concat_dim: usize, bytes: u64 },
    /// Skip-aware ring hop under the shared liveness plan.
    RingShiftSparse { live: Vec<bool>, bytes: u64 },
    /// Sparse gradient homing under the shared consumer plan
    /// (`chunk_bytes` = one contribution's payload).
    ReduceChunksHome { consumers: Vec<Vec<usize>>, chunk_bytes: u64 },
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceEvent::RingShift { bytes } => write!(f, "ring_shift[{bytes}B]"),
            TraceEvent::AllReduce { bytes } => write!(f, "all_reduce[{bytes}B]"),
            TraceEvent::AllGather { dim, bytes } => write!(f, "all_gather(dim={dim})[{bytes}B]"),
            TraceEvent::Broadcast { root, bytes } => write!(f, "broadcast(root={root})[{bytes}B]"),
            TraceEvent::AllToAll { split_dim, concat_dim, bytes } => {
                write!(f, "all_to_all({split_dim}->{concat_dim})[{bytes}B]")
            }
            TraceEvent::RingShiftSparse { live, bytes } => {
                let mask: String =
                    live.iter().map(|&l| if l { '1' } else { '0' }).collect();
                write!(f, "ring_shift_sparse(live={mask})[{bytes}B]")
            }
            TraceEvent::ReduceChunksHome { consumers, chunk_bytes } => {
                write!(f, "reduce_chunks_home({consumers:?})[{chunk_bytes}B/chunk]")
            }
        }
    }
}

/// One rank's recorded collective schedule.
#[derive(Clone, Debug)]
pub struct Trace {
    pub rank: usize,
    pub events: Vec<TraceEvent>,
}

/// First point where the traces of one communicator group disagree —
/// the static image of the classic mismatched-collective hang.
#[derive(Debug)]
pub struct Divergence {
    /// Which carved group diverged (e.g. "ring", "mp group (dp=0, pp=1)").
    pub group: String,
    /// Index of the first non-matching event.
    pub index: usize,
    /// What every rank issues at `index` (`None` = its schedule ended).
    pub per_rank: Vec<(usize, Option<TraceEvent>)>,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "collective schedules diverge in {} at event #{} \
             (ranks agree on the first {} events):",
            self.group, self.index, self.index
        )?;
        for (rank, ev) in &self.per_rank {
            match ev {
                Some(ev) => writeln!(f, "  rank {rank}: {ev}")?,
                None => writeln!(f, "  rank {rank}: (end of schedule)")?,
            }
        }
        write!(
            f,
            "a real run would deadlock here: some ranks enter a collective \
             the others never issue"
        )
    }
}

impl std::error::Error for Divergence {}

/// Match-soundness check for one communicator group: every rank must
/// issue the identical collective sequence.  Returns the rank-by-rank
/// first-divergence diff otherwise.
pub fn check_uniform(group: &str, traces: &[Trace]) -> Result<(), Box<Divergence>> {
    let Some(first) = traces.first() else { return Ok(()) };
    let longest = traces.iter().map(|t| t.events.len()).max().unwrap_or(0);
    for i in 0..longest {
        let agree = traces.iter().all(|t| t.events.get(i) == first.events.get(i));
        if !agree {
            return Err(Box::new(Divergence {
                group: group.to_string(),
                index: i,
                per_rank: traces
                    .iter()
                    .map(|t| (t.rank, t.events.get(i).cloned()))
                    .collect(),
            }));
        }
    }
    Ok(())
}

/// The trace view: executes exactly one global rank of an `n`-rank group,
/// records every collective, moves no data.
pub struct TraceCollective {
    n: usize,
    rank: usize,
    meter: Arc<Meter>,
    events: RefCell<Vec<TraceEvent>>,
}

impl TraceCollective {
    pub fn new(n: usize, rank: usize, meter: Arc<Meter>) -> TraceCollective {
        assert!(rank < n, "trace rank {rank} out of group size {n}");
        TraceCollective { n, rank, meter, events: RefCell::new(Vec::new()) }
    }

    /// Consume the view, yielding the recorded schedule.
    pub fn into_trace(self) -> Trace {
        Trace { rank: self.rank, events: self.events.into_inner() }
    }

    /// Append an event directly (tests use this to seed a deliberately
    /// skewed schedule; the analyzer itself only records through the
    /// collective calls).
    pub fn push_event(&self, ev: TraceEvent) {
        self.events.borrow_mut().push(ev);
    }

    fn one_slot<'s>(&self, slots: &'s mut [Tensor], op: &str) -> Result<&'s mut Tensor> {
        if slots.len() != 1 {
            bail!(
                "rank {}: {op} on a per-rank trace view needs exactly 1 slot, got {}",
                self.rank,
                slots.len()
            );
        }
        Ok(&mut slots[0])
    }
}

impl Collective for TraceCollective {
    fn world(&self) -> usize {
        self.n
    }

    fn local_ranks(&self) -> Vec<usize> {
        vec![self.rank]
    }

    fn ring_shift(&self, slots: &mut [Tensor]) -> Result<()> {
        let t = self.one_slot(slots, "ring_shift")?;
        let bytes = t.bytes() as u64;
        self.push_event(TraceEvent::RingShift { bytes });
        if self.n > 1 {
            // per-send convention: each rank meters its own outgoing chunk
            self.meter.add(CommKind::RingP2p, bytes);
        }
        // the incoming chunk has the sender's shape == ours (SPMD); the
        // slot already holds a correctly-shaped placeholder
        Ok(())
    }

    fn all_reduce_sum(&self, slots: &mut [Tensor]) -> Result<()> {
        let t = self.one_slot(slots, "all_reduce_sum")?;
        let c = t.bytes() as u64;
        self.push_event(TraceEvent::AllReduce { bytes: c });
        if self.n > 1 && self.rank == 0 {
            self.meter.add(CommKind::AllReduce, 2 * (self.n as u64 - 1) * c);
        }
        Ok(())
    }

    fn all_gather(&self, slots: &mut [Tensor], dim: usize) -> Result<()> {
        let t = self.one_slot(slots, "all_gather")?;
        let c = t.bytes() as u64;
        self.push_event(TraceEvent::AllGather { dim, bytes: c });
        if self.n == 1 {
            return Ok(());
        }
        if dim >= t.shape.len() {
            bail!("rank {}: all_gather dim {dim} out of rank-{} tensor", self.rank, t.shape.len());
        }
        // result shape: n same-shaped chunks concatenated along `dim`
        // (match soundness separately proves the group is symmetric)
        let gathered: Vec<&Tensor> = (0..self.n).map(|_| &*t).collect();
        let out = ops::concat_dim(&gathered, dim)?;
        if self.rank == 0 {
            self.meter.add(CommKind::AllGather, (self.n as u64 - 1) * self.n as u64 * c);
        }
        slots[0] = out;
        Ok(())
    }

    fn broadcast(&self, slots: &mut [Tensor], root: usize) -> Result<()> {
        let t = self.one_slot(slots, "broadcast")?;
        if root >= self.n {
            bail!("rank {}: broadcast root {root} out of {}", self.rank, self.n);
        }
        let c = t.bytes() as u64;
        self.push_event(TraceEvent::Broadcast { root, bytes: c });
        if self.n > 1 && self.rank == root {
            self.meter.add(CommKind::Broadcast, (self.n as u64 - 1) * c);
        }
        Ok(())
    }

    fn all_to_all(&self, slots: &mut [Tensor], split_dim: usize, concat_dim: usize) -> Result<()> {
        let t = self.one_slot(slots, "all_to_all")?;
        let c = t.bytes() as u64;
        self.push_event(TraceEvent::AllToAll { split_dim, concat_dim, bytes: c });
        if self.n == 1 {
            return Ok(());
        }
        // re-shard the SHAPE: 1/n along split_dim, ×n along concat_dim
        // (chunk_dim validates divisibility exactly like the fabrics)
        let pieces = ops::chunk_dim(t, split_dim, self.n)?;
        let piece = &pieces[self.rank];
        let received: Vec<&Tensor> = (0..self.n).map(|_| piece).collect();
        let out = ops::concat_dim(&received, concat_dim)?;
        if self.rank == 0 {
            self.meter.add(CommKind::AllToAll, (self.n as u64 - 1) * c);
        }
        slots[0] = out;
        Ok(())
    }

    fn ring_shift_sparse(&self, slots: &mut [Tensor], live: &[bool]) -> Result<()> {
        let t = self.one_slot(slots, "ring_shift_sparse")?;
        if live.len() != self.n {
            bail!("rank {}: {} live flags for {} ranks", self.rank, live.len(), self.n);
        }
        let bytes = t.bytes() as u64;
        self.push_event(TraceEvent::RingShiftSparse { live: live.to_vec(), bytes });
        if self.n == 1 {
            return Ok(());
        }
        if live[self.rank] {
            self.meter.add(CommKind::RingP2p, bytes);
        }
        let prev = (self.rank + self.n - 1) % self.n;
        if !live[prev] {
            // dead hop: the fabrics leave an empty placeholder the plan
            // guarantees is never read — reproduce it so shape flow agrees
            slots[0] = Tensor::zeros(&[]);
        }
        Ok(())
    }

    fn reduce_chunks_home(
        &self,
        mut parts: Vec<Vec<Option<Tensor>>>,
        consumers: &[Vec<usize>],
    ) -> Result<Vec<Tensor>> {
        if parts.len() != 1 {
            bail!("rank {}: per-rank trace view holds 1 part row, got {}", self.rank, parts.len());
        }
        if consumers.len() != self.n {
            bail!("rank {}: {} consumer lists for {} ranks", self.rank, consumers.len(), self.n);
        }
        let mine = parts.pop().unwrap_or_default();
        if mine.len() != self.n {
            bail!("rank {}: {} chunk parts for {} ranks", self.rank, mine.len(), self.n);
        }
        // the same plan-agreement validation the fabrics run
        for (src, part) in mine.iter().enumerate() {
            if part.is_some() != consumers[src].contains(&self.rank) {
                bail!(
                    "rank {}: contribution set disagrees with the consumer plan for chunk {src}",
                    self.rank
                );
            }
        }
        let chunk_bytes = mine
            .iter()
            .flatten()
            .map(|t| t.bytes() as u64)
            .max()
            .unwrap_or(0);
        self.push_event(TraceEvent::ReduceChunksHome {
            consumers: consumers.to_vec(),
            chunk_bytes,
        });
        // per-send convention: every off-home contribution is one metered
        // chunk-send at its producer (= this rank)
        let mut home_shape: Option<Vec<usize>> = mine
            .iter()
            .flatten()
            .next()
            .map(|t| t.shape.clone());
        for (src, part) in mine.into_iter().enumerate() {
            if let Some(t) = part {
                if src == self.rank {
                    home_shape = Some(t.shape.clone());
                } else {
                    self.meter.add(CommKind::RingP2p, t.bytes() as u64);
                }
            }
        }
        if consumers[self.rank].is_empty() {
            bail!("rank {}: chunk {} has no consumers", self.rank, self.rank);
        }
        // every contribution to our home chunk has our chunk's shape
        let shape = home_shape.ok_or_else(|| {
            anyhow::anyhow!("rank {}: no contributions to derive the home-chunk shape", self.rank)
        })?;
        Ok(vec![Tensor::zeros(&shape)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_traces_pass_and_skew_is_located() {
        let meter = Meter::new();
        let mk = |rank: usize| {
            let v = TraceCollective::new(2, rank, meter.clone());
            v.push_event(TraceEvent::RingShift { bytes: 64 });
            v.push_event(TraceEvent::AllReduce { bytes: 128 });
            v
        };
        let a = mk(0);
        let b = mk(1);
        assert!(check_uniform("ring", &[a.into_trace(), b.into_trace()]).is_ok());

        let a = mk(0);
        let b = mk(1);
        b.push_event(TraceEvent::AllReduce { bytes: 4 }); // the skew
        let d = check_uniform("ring", &[a.into_trace(), b.into_trace()]).unwrap_err();
        assert_eq!(d.index, 2);
        assert!(d.per_rank[0].1.is_none(), "rank 0 ended");
        assert_eq!(d.per_rank[1].1, Some(TraceEvent::AllReduce { bytes: 4 }));
        let text = d.to_string();
        assert!(text.contains("rank 0: (end of schedule)"), "{text}");
        assert!(text.contains("rank 1: all_reduce[4B]"), "{text}");
    }

    #[test]
    fn all_to_all_reshapes_without_moving_bytes() {
        let meter = Meter::new();
        let v = TraceCollective::new(4, 1, meter.clone());
        let mut slots = vec![Tensor::zeros(&[2, 4, 8, 16])];
        v.all_to_all(&mut slots, 1, 2).unwrap();
        assert_eq!(slots[0].shape, vec![2, 1, 32, 16]);
        // metered at rank 0 only
        assert_eq!(meter.get(CommKind::AllToAll), 0);
        let v0 = TraceCollective::new(4, 0, meter.clone());
        let mut slots = vec![Tensor::zeros(&[2, 4, 8, 16])];
        v0.all_to_all(&mut slots, 1, 2).unwrap();
        assert_eq!(meter.get(CommKind::AllToAll), 3 * 2 * 4 * 8 * 16 * 4);
    }
}
