//! `analysis` — the static collective-schedule verifier.
//!
//! Every step program in this repo (the `seqpar_step` ring/Ulysses ×
//! dense/Linformer/block schedules, the `tp_step` Megatron baseline, and
//! the full DP×PP×MP mesh step) is ordinary Rust driven through two
//! traits: [`Executor`](crate::runtime::Executor) for kernels and
//! [`Collective`](crate::comm::Collective) for communication.  This
//! module abstract-interprets those SAME programs over two instruments
//! that move no data:
//!
//! * [`ShapeExecutor`] — validates every kernel call against its
//!   manifest registration and returns zero tensors in the registered
//!   output shapes (shape/dtype soundness);
//! * [`TraceCollective`] — a per-rank view that records each collective
//!   as a [`TraceEvent`] (kind, routing parameters, exact bytes) and
//!   rewrites only the slot SHAPE (match soundness).
//!
//! Three things are then proved statically, before any thread spawns:
//!
//! 1. **Match soundness / deadlock freedom** — all ranks of every carved
//!    sub-communicator issue the identical collective sequence; a
//!    mismatch yields a rank-by-rank first-divergence diff
//!    ([`Divergence`]) instead of the runtime hang it would cause.
//! 2. **Shape/dtype soundness** — a missing or mis-shaped kernel
//!    registration is an `Err` naming the kernel, not a mid-step panic.
//! 3. **Derived closed forms** — per-kind byte totals accumulate on a
//!    meter under the exact runtime metering convention, and must equal
//!    the hand formulas of [`closed_form`]; callers (the `analyze` CLI,
//!    `rust/tests/analysis_props.rs`) close the triangle against
//!    measured runtime meters.

pub mod closed_form;
pub mod shape_exec;
pub mod trace;

use std::cell::RefCell;
use std::collections::VecDeque;

use anyhow::{anyhow, bail, Result};

use crate::attn::AttnPattern;
use crate::comm::{CommKind, Meter, MeterSnapshot};
use crate::exec::mesh::{Link, MeshSpec, Stage};
use crate::parallel::pipeline::{Cell, Schedule};
use crate::parallel::sequence::{seqpar_step, SpStrategy, StepShape};
use crate::parallel::tensorp::{tp_step, TpShape};
use crate::parallel::topology::{Coord, Mesh};
use crate::parallel::allreduce_named;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub use shape_exec::{shape_batch, shape_params, ShapeExecutor};
pub use trace::{check_uniform, Divergence, Trace, TraceCollective, TraceEvent};

/// A human-readable name for the attention pattern (report labels).
pub fn pattern_label(p: AttnPattern) -> String {
    match p {
        AttnPattern::Dense => "dense".to_string(),
        AttnPattern::Linformer { k } => format!("linformer:{k}"),
        AttnPattern::Block { w } => format!("block:{w}"),
    }
}

/// The traces of one carved communicator group, ready for the
/// uniformity check.
pub struct TraceGroup {
    pub name: String,
    pub traces: Vec<Trace>,
}

/// Result of one static analysis run: per-group traces, trace-derived
/// byte totals, and the independent closed-form prediction.
pub struct Analysis {
    pub label: String,
    pub groups: Vec<TraceGroup>,
    /// Byte totals accumulated by the trace views under the runtime
    /// metering convention.
    pub derived: MeterSnapshot,
    /// The hand formulas of [`closed_form`] for the same config.
    pub closed: MeterSnapshot,
    /// Kernel calls validated by the [`ShapeExecutor`].
    pub kernel_calls: u64,
}

impl Analysis {
    /// Match soundness: every group's ranks issue identical schedules.
    pub fn check_matched(&self) -> Result<(), Box<Divergence>> {
        for g in &self.groups {
            check_uniform(&g.name, &g.traces)?;
        }
        Ok(())
    }

    /// Derived-vs-closed-form byte check, per collective kind.
    pub fn check_closed_forms(&self) -> Result<()> {
        if !self.derived.same_bytes(&self.closed) {
            bail!(
                "{}: trace-derived bytes diverge from the closed forms\n{}",
                self.label,
                render_bytes(&self.derived, &self.closed, None)
            );
        }
        Ok(())
    }

    /// All three static verdicts (shape soundness already held, or this
    /// `Analysis` would not exist).
    pub fn verify(&self) -> Result<()> {
        self.check_matched().map_err(|d| anyhow!("{}: {d}", self.label))?;
        self.check_closed_forms()
    }

    /// The full report: per-group trace summary, per-kind byte table
    /// (with an optional measured column), verdicts.
    pub fn report(&self, measured: Option<&MeterSnapshot>) -> String {
        let mut out = format!("static schedule analysis: {}\n", self.label);
        out.push_str(&format!(
            "  kernel calls validated against the manifest: {}\n",
            self.kernel_calls
        ));
        for g in &self.groups {
            let events = g.traces.first().map(|t| t.events.len()).unwrap_or(0);
            match check_uniform(&g.name, &g.traces) {
                Ok(()) => out.push_str(&format!(
                    "  {}: {} rank(s) x {} collective(s) — schedules match\n",
                    g.name,
                    g.traces.len(),
                    events
                )),
                Err(d) => {
                    out.push_str(&format!("  {}: MISMATCH\n", g.name));
                    for line in d.to_string().lines() {
                        out.push_str(&format!("    {line}\n"));
                    }
                }
            }
        }
        out.push_str(&render_bytes(&self.derived, &self.closed, measured));
        let verdict = match (self.check_matched().is_ok(), self.check_closed_forms().is_ok()) {
            (true, true) => "PASS (deadlock-free, shape-sound, closed forms agree)",
            (false, _) => "FAIL (collective schedules diverge — a real run would deadlock)",
            (_, false) => "FAIL (trace bytes diverge from the closed forms)",
        };
        out.push_str(&format!("  verdict: {verdict}\n"));
        out
    }
}

fn kind_name(k: CommKind) -> &'static str {
    match k {
        CommKind::RingP2p => "ring_p2p",
        CommKind::AllReduce => "all_reduce",
        CommKind::AllGather => "all_gather",
        CommKind::AllToAll => "all_to_all",
        CommKind::Broadcast => "broadcast",
        CommKind::Scatter => "scatter",
        CommKind::Pipeline => "pipeline",
    }
}

fn render_bytes(
    derived: &MeterSnapshot,
    closed: &MeterSnapshot,
    measured: Option<&MeterSnapshot>,
) -> String {
    let mut out = String::from(match measured {
        Some(_) => "  bytes by kind (derived | closed form | measured):\n",
        None => "  bytes by kind (derived | closed form):\n",
    });
    for ((kind, d), (_, c)) in derived.kind_bytes().into_iter().zip(closed.kind_bytes()) {
        let mut line = format!("    {:<10} {:>12} | {:>12}", kind_name(kind), d, c);
        let mut ok = d == c;
        if let Some(ms) = measured {
            let m = ms.kind_bytes()[kind_bytes_index(kind)].1;
            line.push_str(&format!(" | {m:>12}"));
            ok &= d == m;
        }
        line.push_str(if ok { "  ok\n" } else { "  MISMATCH\n" });
        out.push_str(&line);
    }
    out
}

fn kind_bytes_index(kind: CommKind) -> usize {
    MeterSnapshot::default()
        .kind_bytes()
        .iter()
        .position(|(k, _)| *k == kind)
        .unwrap_or(0)
}

/// Statically analyze one `seqpar_step` (the pure SP engines and the
/// `DistRunner` run exactly this) at the manifest's ring size.
pub fn analyze_sp_step(rt: &Runtime, pattern: AttnPattern, sp: SpStrategy) -> Result<Analysis> {
    let m = rt.manifest();
    let sh = StepShape::from_manifest_sp(m, pattern, sp)?;
    let ex = ShapeExecutor::new(m.clone());
    let params = shape_params(m);
    let batch = shape_batch(m)?;
    let meter = Meter::new();
    let mut traces = Vec::with_capacity(sh.n);
    for rank in 0..sh.n {
        let view = TraceCollective::new(sh.n, rank, meter.clone());
        seqpar_step(&ex, &view, &sh, &params, &batch)
            .map_err(|e| anyhow!("sp step, rank {rank}: {e}"))?;
        traces.push(view.into_trace());
    }
    Ok(Analysis {
        label: format!("sp step n={} sp={} attn={}", sh.n, sp.label(), pattern_label(pattern)),
        groups: vec![TraceGroup { name: "ring group".to_string(), traces }],
        derived: meter.snapshot(),
        closed: closed_form::sp_step(m, pattern, sp),
        kernel_calls: ex.calls(),
    })
}

/// Statically analyze one `tp_step` (the tensor-parallel / serial
/// engine) at TP degree `t`.
pub fn analyze_tp_step(rt: &Runtime, t: usize) -> Result<Analysis> {
    let m = rt.manifest();
    let tsh = TpShape::from_manifest(m, t)?;
    let ex = ShapeExecutor::new(m.clone());
    let params = shape_params(m);
    let batch = shape_batch(m)?;
    let meter = Meter::new();
    let mut traces = Vec::with_capacity(t);
    for rank in 0..t {
        let view = TraceCollective::new(t, rank, meter.clone());
        tp_step(&ex, &view, &tsh, &params, &batch)
            .map_err(|e| anyhow!("tp step, rank {rank}: {e}"))?;
        traces.push(view.into_trace());
    }
    Ok(Analysis {
        label: format!("tp step t={t}"),
        groups: vec![TraceGroup { name: "tp group".to_string(), traces }],
        derived: meter.snapshot(),
        closed: closed_form::tp_step(m, t),
        kernel_calls: ex.calls(),
    })
}

/// Statically analyze one full mesh step: every coordinate's stage runs
/// over per-rank trace views, pipeline boundaries over metered local
/// queues, GPipe cells in global causal order — the union of what
/// `MeshEngine` and `MeshRunner` execute, with per-group traces.
pub fn analyze_mesh(rt: &Runtime, mesh: Mesh, micros: usize, sp: SpStrategy) -> Result<Analysis> {
    let spec = MeshSpec::new(rt, mesh, micros, sp)?;
    let m = rt.manifest();
    let ex = ShapeExecutor::new(m.clone());
    let params = shape_params(m);
    let batch = shape_batch(m)?;
    let meter = Meter::new();
    let (dp, pp, mp) = (mesh.dp, mesh.pp, mesh.mp);
    let world = mesh.world_size();

    // per-coordinate trace views for the two collective axes, indexed by
    // global rank (the pp axis communicates through Link queues below)
    let mut mp_views = Vec::with_capacity(world);
    let mut dp_views = Vec::with_capacity(world);
    for rank in 0..world {
        let c = mesh.coord(rank)?;
        mp_views.push(TraceCollective::new(mp, c.mp, meter.clone()));
        dp_views.push(TraceCollective::new(dp, c.dp, meter.clone()));
    }

    // one boundary-queue pair per (replica, mp rank, stage boundary)
    let nb = pp.saturating_sub(1);
    let q_at = |d: usize, i: usize, b: usize| (d * mp + i) * nb + b;
    let fwd_q: Vec<RefCell<VecDeque<Vec<Tensor>>>> =
        (0..dp * mp * nb).map(|_| RefCell::new(VecDeque::new())).collect();
    let bwd_q: Vec<RefCell<VecDeque<Vec<Tensor>>>> =
        (0..dp * mp * nb).map(|_| RefCell::new(VecDeque::new())).collect();

    let mut stages: Vec<Stage> = Vec::with_capacity(world);
    for rank in 0..world {
        let c = mesh.coord(rank)?;
        stages.push(Stage::new(&spec, &ex, &params, &mp_views[rank], &meter, c.pp)?);
    }

    // causal execution order across ALL coordinates: cells sorted by
    // start tick (exactly the MeshEngine order), each cell executed for
    // every (dp, mp) coordinate of its stage
    let mut cells: Vec<Cell> = Schedule::gpipe(pp, micros).cells;
    cells.sort_by_key(|c| (c.start, c.stage));
    for c in &cells {
        let s = c.stage;
        for d in 0..dp {
            for i in 0..mp {
                let rank = mesh.rank(Coord { dp: d, pp: s, mp: i });
                let run = |q: &[RefCell<VecDeque<Vec<Tensor>>>],
                           st: &mut Stage|
                 -> Result<()> {
                    let prev =
                        (s > 0).then(|| Link::Queue { q: &q[q_at(d, i, s - 1)], meter: &meter });
                    let next =
                        (s + 1 < pp).then(|| Link::Queue { q: &q[q_at(d, i, s)], meter: &meter });
                    if c.forward {
                        st.forward_micro(c.micro, &batch, prev.as_ref(), next.as_ref())
                    } else {
                        st.backward_micro(c.micro, &batch, prev.as_ref(), next.as_ref())
                    }
                };
                run(if c.forward { &fwd_q } else { &bwd_q }, &mut stages[rank]).map_err(|e| {
                    anyhow!(
                        "mesh {} coordinate (dp={d}, pp={s}, mp={i}), micro {} {}: {e}",
                        mesh.label(),
                        c.micro,
                        if c.forward { "forward" } else { "backward" }
                    )
                })?;
            }
        }
    }
    // static liveness: every boundary payload must have been consumed
    for (name, qs) in [("forward", &fwd_q), ("backward", &bwd_q)] {
        if let Some(idx) = qs.iter().position(|q| !q.borrow().is_empty()) {
            bail!(
                "mesh {}: {name} boundary queue {idx} not drained — the schedule \
                 produced more sends than receives",
                mesh.label()
            );
        }
    }

    // close out the stages (SP: mp-group grad all-reduce), then the dp
    // gradient reduction per (stage, mp rank) — mirroring run_coord
    let mut finished: Vec<Vec<crate::model::params::ParamStore>> = Vec::with_capacity(world);
    for (rank, st) in stages.into_iter().enumerate() {
        let c = mesh.coord(rank)?;
        let (_, _, g) = st
            .finish(&spec.owned[c.pp])
            .map_err(|e| anyhow!("mesh {} rank {rank} finish: {e}", mesh.label()))?;
        finished.push(g);
    }
    if dp > 1 {
        for (rank, g) in finished.iter_mut().enumerate() {
            let c = mesh.coord(rank)?;
            allreduce_named(&dp_views[rank], g, &spec.owned[c.pp])
                .map_err(|e| anyhow!("mesh {} rank {rank} dp reduce: {e}", mesh.label()))?;
        }
    }

    // carve the per-group traces: mp groups by (dp, pp), dp groups by
    // (pp, mp) — the same sub-communicators the threaded runner builds
    let mp_traces: Vec<Trace> = mp_views.into_iter().map(TraceCollective::into_trace).collect();
    let dp_traces: Vec<Trace> = dp_views.into_iter().map(TraceCollective::into_trace).collect();
    let mut groups = Vec::new();
    let mut mp_by_rank: Vec<Option<Trace>> = mp_traces.into_iter().map(Some).collect();
    for d in 0..dp {
        for p in 0..pp {
            let traces = (0..mp)
                .map(|i| {
                    mp_by_rank[mesh.rank(Coord { dp: d, pp: p, mp: i })]
                        .take()
                        .ok_or_else(|| anyhow!("mp trace taken twice"))
                })
                .collect::<Result<Vec<_>>>()?;
            groups.push(TraceGroup { name: format!("mp group (dp={d}, pp={p})"), traces });
        }
    }
    let mut dp_by_rank: Vec<Option<Trace>> = dp_traces.into_iter().map(Some).collect();
    for p in 0..pp {
        for i in 0..mp {
            let traces = (0..dp)
                .map(|d| {
                    dp_by_rank[mesh.rank(Coord { dp: d, pp: p, mp: i })]
                        .take()
                        .ok_or_else(|| anyhow!("dp trace taken twice"))
                })
                .collect::<Result<Vec<_>>>()?;
            groups.push(TraceGroup { name: format!("dp group (pp={p}, mp={i})"), traces });
        }
    }

    Ok(Analysis {
        label: format!("mesh {} micros={micros} sp={}", mesh.label(), sp.label()),
        groups,
        derived: meter.snapshot(),
        closed: closed_form::mesh_step(m, &mesh, micros, sp),
        kernel_calls: ex.calls(),
    })
}

/// Cheap pre-flight for `train`: run the static analysis and verify.
/// Returns a one-line summary on success; on any failure the error
/// carries the COMPLETE static report.
pub fn preflight(built: Result<Analysis>) -> Result<String> {
    let a = built.map_err(|e| anyhow!("static schedule analysis rejected this config: {e}"))?;
    match a.verify() {
        Ok(()) => Ok(format!(
            "static analysis ok: {} — {} group(s) matched, {} kernel call(s) shape-checked, \
             {} comm bytes derived",
            a.label,
            a.groups.len(),
            a.kernel_calls,
            a.derived.total()
        )),
        Err(e) => Err(anyhow!("{}static schedule analysis FAILED: {e}", a.report(None))),
    }
}
