//! The shape instrument: an [`Executor`] that validates and propagates
//! shapes without computing.
//!
//! Every `call` resolves the kernel in the [`Manifest`], runs the SAME
//! arity/shape/dtype validation as the real backends, and returns
//! zero-filled outputs in the registered output shapes.  A missing or
//! mis-shaped registration therefore surfaces as a clean `Err` naming
//! the kernel — before any thread is spawned or any f32 touched.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::model::params::ParamStore;
use crate::parallel::Batch;
use crate::runtime::{validate_inputs, Executor, Manifest, RuntimeStats};
use crate::tensor::{DType, Tensor};

/// Shape-only symbolic executor over a manifest snapshot.
pub struct ShapeExecutor {
    manifest: Manifest,
    calls: AtomicU64,
}

impl ShapeExecutor {
    pub fn new(manifest: Manifest) -> ShapeExecutor {
        ShapeExecutor { manifest, calls: AtomicU64::new(0) }
    }

    /// Kernel calls validated so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

impl Executor for ShapeExecutor {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("{name}: not in manifest (shape analysis)"))?;
        validate_inputs(name, spec, inputs)?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        spec.outputs
            .iter()
            .map(|io| match io.dtype {
                DType::F32 => Ok(Tensor::zeros(&io.dims)),
                DType::I32 => Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]),
            })
            .collect()
    }

    fn stats(&self) -> RuntimeStats {
        RuntimeStats { compiles: 0, calls: self.calls(), compile_nanos: 0, exec_nanos: 0 }
    }
}

/// Zero parameters in the manifest-registered shapes — enough for shape
/// flow; no seeding, no RNG.
pub fn shape_params(m: &Manifest) -> ParamStore {
    let mut store = ParamStore { values: Default::default() };
    for p in &m.params {
        store.values.insert(p.name.clone(), Tensor::zeros(&p.dims));
    }
    store
}

/// An all-zeros batch in the run shape `[B, L]` — token values never
/// matter to shape flow (embedding lookups are never executed).
pub fn shape_batch(m: &Manifest) -> Result<Batch> {
    let (b, l) = (m.batch, m.seq_len);
    Ok(Batch {
        ids: Tensor::from_i32(&[b, l], vec![0; b * l])?,
        labels: Tensor::from_i32(&[b, l], vec![0; b * l])?,
        mask: Tensor::zeros(&[b, l]),
        sop_labels: Tensor::from_i32(&[b], vec![0; b])?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeConfig;
    use crate::runtime::Runtime;

    #[test]
    fn shape_executor_validates_like_the_backend() {
        let rt = Runtime::native(NativeConfig::tiny()).unwrap();
        let ex = ShapeExecutor::new(rt.manifest().clone());
        let err = ex.call("nope__2x2", &[]).unwrap_err().to_string();
        assert!(err.contains("not in manifest"), "{err}");

        let name = rt.manifest().artifacts.keys().next().unwrap().clone();
        let err = ex.call(&name, &[]).unwrap_err().to_string();
        assert!(err.contains("inputs"), "{err}");
        assert_eq!(ex.calls(), 0, "failed calls are not counted");
    }

    #[test]
    fn outputs_take_registered_shapes() {
        let rt = Runtime::native(NativeConfig::tiny()).unwrap();
        let ex = ShapeExecutor::new(rt.manifest().clone());
        let name = rt.manifest().artifacts.keys().next().unwrap().clone();
        let spec = rt.manifest().artifacts[&name].clone();
        let inputs: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|io| match io.dtype {
                DType::F32 => Tensor::zeros(&io.dims),
                DType::I32 => {
                    Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]).unwrap()
                }
            })
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = ex.call(&name, &refs).unwrap();
        assert_eq!(out.len(), spec.outputs.len());
        for (t, io) in out.iter().zip(&spec.outputs) {
            assert_eq!(t.shape, io.dims);
            assert_eq!(t.dtype(), io.dtype);
        }
        assert_eq!(ex.calls(), 1);
    }
}
