//! Closed-form communication volumes, derived from the schedule
//! definitions alone (no execution, no trace).
//!
//! These are the paper-§3.2.2-style hand formulas already pinned against
//! measured meters in `rust/tests/comm_volume.rs` and
//! `rust/tests/mesh_props.rs`, lifted into one callable place.  The
//! analyzer's three-way check is: trace-derived bytes == these formulas
//! == measured runtime meters, per [`CommKind`](crate::comm::CommKind),
//! exactly.

use crate::attn::block::BlockPlan;
use crate::attn::AttnPattern;
use crate::comm::MeterSnapshot;
use crate::parallel::pipeline::boundary_totals;
use crate::parallel::sequence::SpStrategy;
use crate::parallel::topology::{Mesh, MpKind};
use crate::runtime::Manifest;

/// Total parameter-gradient payload: every manifest parameter, f32.
pub fn param_bytes(m: &Manifest) -> u64 {
    m.params.iter().map(|p| p.dims.iter().product::<usize>() as u64 * 4).sum()
}

/// One rank's K/V (or head-sharded) chunk: `[B, Z, L/n, A]` f32.
fn chunk_bytes(m: &Manifest, n: usize) -> u64 {
    (m.batch * m.heads * (m.seq_len / n) * m.head_dim * 4) as u64
}

/// Attention-schedule bytes for one full `seqpar_step` over `n` ranks
/// and `layers` layers, EXCLUDING the parameter-gradient all-reduce —
/// so the mesh form can scale it by micro-batches independently.
fn sp_attention(m: &Manifest, pattern: AttnPattern, sp: SpStrategy, n: usize, layers: u64) -> MeterSnapshot {
    let mut s = MeterSnapshot::default();
    if n <= 1 {
        return s; // every collective is a no-op at group size 1
    }
    let nn = n as u64;
    let chunk = chunk_bytes(m, n);
    match (sp, pattern) {
        (SpStrategy::Ulysses, AttnPattern::Dense) => {
            // 8 all-to-alls of the local chunk per layer (q/k/v/ctx
            // forward + their grads backward), each (n-1)·chunk
            s.all_to_all = 8 * (nn - 1) * chunk * layers;
        }
        (_, AttnPattern::Dense) => {
            // forward: 2(n-1) k/v rotations; backward: (n-1)+n v/dv and
            // (n-1)+n k/dk rotations — n·chunk group bytes per rotation
            s.ring_p2p = (2 * (nn - 1) + (4 * nn - 2)) * nn * chunk * layers;
        }
        (_, AttnPattern::Block { w }) => {
            let plan = BlockPlan::new(n, m.seq_len / n, w);
            s.ring_p2p = plan.chunk_sends_per_layer() * chunk * layers;
        }
        (_, AttnPattern::Linformer { k }) => {
            // 4 all-reduces of the projected [B, Z, k, A] per layer
            // (K̃/Ṽ forward, their grads backward); no ring traffic
            let proj = (m.batch * m.heads * k * m.head_dim * 4) as u64;
            s.all_reduce = 2 * (nn - 1) * 4 * proj * layers;
        }
    }
    s
}

/// Full `seqpar_step` closed form at group size `m.ring`: attention
/// schedule + the parameter-gradient all-reduce.
pub fn sp_step(m: &Manifest, pattern: AttnPattern, sp: SpStrategy) -> MeterSnapshot {
    let n = m.ring;
    let mut s = sp_attention(m, pattern, sp, n, m.layers as u64);
    if n > 1 {
        s.all_reduce += 2 * (n as u64 - 1) * param_bytes(m);
    }
    s
}

/// Full `tp_step` closed form at group size `t`: 4 all-reduces of the
/// full `[B·L, H]` activation per layer (attention + FFN partials,
/// forward and backward); gradients merge host-side — no collective.
pub fn tp_step(m: &Manifest, t: usize) -> MeterSnapshot {
    let mut s = MeterSnapshot::default();
    if t > 1 {
        let act = (m.batch * m.seq_len * m.hidden * 4) as u64;
        s.all_reduce = 2 * (t as u64 - 1) * 4 * act * m.layers as u64;
    }
    s
}

/// Full DP×PP×MP mesh step closed form: stage-boundary traffic
/// (`pipeline::boundary_totals`, per replica) + the model-parallel
/// schedule per micro-batch per replica + the two gradient reductions
/// (stage-owned params over the mp group, then every (stage, mp-rank)
/// slot over the dp group).
pub fn mesh_step(m: &Manifest, mesh: &Mesh, micros: usize, sp: SpStrategy) -> MeterSnapshot {
    let (dp, pp, mp) = (mesh.dp as u64, mesh.pp, mesh.mp);
    let per = boundary_totals(mesh.kind, m.batch, m.seq_len, m.hidden, mp, pp, micros);
    let mut s = MeterSnapshot {
        pipeline: per.send * dp,
        all_gather: per.gather * dp,
        ..MeterSnapshot::default()
    };
    if mesh.kind == MpKind::Tensor && mp > 1 {
        s.scatter = per.send * dp;
    }
    let per_micro = match mesh.kind {
        MpKind::Sequence => sp_attention(m, AttnPattern::Dense, sp, mp, m.layers as u64),
        MpKind::Tensor => tp_step(m, mp),
    };
    s.ring_p2p += per_micro.ring_p2p * micros as u64 * dp;
    s.all_to_all += per_micro.all_to_all * micros as u64 * dp;
    s.all_reduce += per_micro.all_reduce * micros as u64 * dp;
    // gradient reductions: each pipeline stage owns a disjoint slice of
    // the parameters, so summing the per-stage reductions over all
    // stages covers param_bytes exactly once per group
    let pb = param_bytes(m);
    if mesh.kind == MpKind::Sequence && mp > 1 {
        s.all_reduce += 2 * (mp as u64 - 1) * pb * dp;
    }
    if dp > 1 {
        s.all_reduce += 2 * (dp - 1) * pb * mp as u64;
    }
    s
}
