//! seqpar — the coordinator CLI (leader entrypoint).
//!
//! Subcommands (each maps to a DESIGN.md experiment or utility):
//!
//! ```text
//! seqpar info                         # manifest + runtime summary
//! seqpar verify                       # rust engines vs python goldens
//! seqpar train [--engine seq|tensor|serial] [--steps N] ...
//! seqpar analyze [--grid]             # static collective-schedule verifier
//! seqpar sweep --experiment fig3a ... # simulator-backed paper figures
//! seqpar trace [--out BENCH_obs.json] # measured metrics + Chrome trace
//! ```
//!
//! Run `seqpar help` for the full flag reference.

use anyhow::Result;

use seqpar::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => seqpar::eval::cmd::info(&args),
        "verify" => seqpar::eval::cmd::verify(&args),
        "train" => seqpar::eval::cmd::train(&args),
        "analyze" => seqpar::eval::cmd::analyze(&args),
        "sweep" => seqpar::eval::cmd::sweep(&args),
        "trace" => seqpar::eval::cmd::trace(&args),
        "help" | _ => {
            print!("{}", seqpar::eval::cmd::HELP);
            Ok(())
        }
    }
}
