//! Synthetic pre-training corpus: Zipf-distributed token stream with
//! local structure, MLM masking, and SOP pair construction.
//!
//! Substitutes the paper's Wikipedia corpus (DESIGN.md §2): the Fig. 6
//! convergence experiment only needs a learnable distribution on which the
//! engines' loss curves can be compared — learnability comes from (a) the
//! Zipf unigram skew and (b) a first-order Markov "topic chain" that makes
//! context informative, so MLM loss genuinely decreases.
//!
//! Special ids match python/compile/configs.py: PAD=0, CLS=1, SEP=2, MASK=3.

use anyhow::Result;

use crate::parallel::Batch;
use crate::tensor::Tensor;
use crate::util::rng::{harmonic, Rng};

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const MASK: i32 = 3;
pub const N_SPECIAL: i32 = 4;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub mask_prob: f64,
    pub zipf_s: f64,
    /// Probability of continuing the current "topic" (token neighborhood);
    /// gives the corpus learnable bigram structure.
    pub topic_stickiness: f64,
}

impl CorpusConfig {
    pub fn new(vocab: usize, seq_len: usize, batch: usize) -> CorpusConfig {
        CorpusConfig {
            vocab,
            seq_len,
            batch,
            mask_prob: 0.15,
            zipf_s: 1.1,
            topic_stickiness: 0.8,
        }
    }
}

pub struct Corpus {
    cfg: CorpusConfig,
    rng: Rng,
    harm: f64,
    /// Batches drawn so far — the data-loader cursor.  The stream is a pure
    /// function of (cfg, seed), so (seed, drawn) fully addresses a position:
    /// a checkpoint stores `drawn` and resume replays that many draws.
    drawn: u64,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Corpus {
        let harm = harmonic(cfg.vocab - N_SPECIAL as usize, cfg.zipf_s);
        Corpus { cfg, rng: Rng::new(seed), harm, drawn: 0 }
    }

    /// Rebuild a corpus positioned `cursor` batches into the stream by
    /// replaying the draws from a fresh seed.  O(cursor) but exact: the
    /// resumed stream continues with the same remaining batches the
    /// original would have produced (no epoch restart).
    pub fn at_cursor(cfg: CorpusConfig, seed: u64, cursor: u64) -> Result<Corpus> {
        let mut c = Corpus::new(cfg, seed);
        for _ in 0..cursor {
            c.next_batch()?;
        }
        Ok(c)
    }

    /// The data-loader cursor: how many batches this corpus has produced.
    pub fn drawn(&self) -> u64 {
        self.drawn
    }

    fn sample_token(&mut self, prev: i32) -> i32 {
        let n_norm = self.cfg.vocab - N_SPECIAL as usize;
        if prev >= N_SPECIAL && self.rng.uniform() < self.cfg.topic_stickiness {
            // stay in the neighborhood of the previous token (topic chain)
            let base = prev - N_SPECIAL;
            let jitter = self.rng.below(16) as i32 - 8;
            let tok = (base + jitter).rem_euclid(n_norm as i32);
            tok + N_SPECIAL
        } else {
            self.rng.zipf(n_norm, self.cfg.zipf_s, self.harm) as i32 + N_SPECIAL
        }
    }

    /// One "sentence" of `len` content tokens.
    fn sentence(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = -1;
        for _ in 0..len {
            let t = self.sample_token(prev);
            out.push(t);
            prev = t;
        }
        out
    }

    /// Build a batch: `[CLS] sent_a [SEP] sent_b [SEP]`, with sent_b either
    /// the true continuation (label 0) or swapped with sent_a (label 1 —
    /// the Sentence Order Prediction objective), then 15% MLM masking.
    pub fn next_batch(&mut self) -> Result<Batch> {
        let (b, l, v) = (self.cfg.batch, self.cfg.seq_len, self.cfg.vocab as i32);
        let content = l - 3; // CLS + 2 SEP
        let half = content / 2;
        let rest = content - half;
        let mut ids = Vec::with_capacity(b * l);
        let mut labels = Vec::with_capacity(b * l);
        let mut mask = Vec::with_capacity(b * l);
        let mut sop = Vec::with_capacity(b);
        for _ in 0..b {
            let a = self.sentence(half);
            // continuation: reuse the topic chain from a's last token
            let mut bb = Vec::with_capacity(rest);
            let mut prev = *a.last().unwrap();
            for _ in 0..rest {
                let t = self.sample_token(prev);
                bb.push(t);
                prev = t;
            }
            let swapped = self.rng.uniform() < 0.5;
            sop.push(if swapped { 1 } else { 0 });
            let (first, second): (&[i32], &[i32]) =
                if swapped { (&bb, &a) } else { (&a, &bb) };
            let mut seq = Vec::with_capacity(l);
            seq.push(CLS);
            seq.extend_from_slice(first);
            seq.push(SEP);
            seq.extend_from_slice(second);
            seq.push(SEP);
            debug_assert_eq!(seq.len(), l);
            // MLM masking (BERT recipe: 80% MASK / 10% random / 10% keep)
            for (pos, tok) in seq.iter_mut().enumerate() {
                let maskable = *tok >= N_SPECIAL;
                if maskable && self.rng.uniform() < self.cfg.mask_prob {
                    labels.push(*tok);
                    mask.push(1.0f32);
                    let r = self.rng.uniform();
                    if r < 0.8 {
                        *tok = MASK;
                    } else if r < 0.9 {
                        *tok = self.rng.below((v - N_SPECIAL) as u64) as i32 + N_SPECIAL;
                    } // else keep
                } else {
                    labels.push(N_SPECIAL); // ignored (mask = 0)
                    mask.push(0.0);
                }
                let _ = pos;
            }
            ids.extend_from_slice(&seq);
        }
        self.drawn += 1;
        Ok(Batch {
            ids: Tensor::from_i32(&[b, l], ids)?,
            labels: Tensor::from_i32(&[b, l], labels)?,
            mask: Tensor::from_f32(&[b, l], mask)?,
            sop_labels: Tensor::from_i32(&[b], sop)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::new(1024, 64, 4), 42)
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let mut c = corpus();
        let b = c.next_batch().unwrap();
        assert_eq!(b.ids.shape, vec![4, 64]);
        assert_eq!(b.labels.shape, vec![4, 64]);
        assert_eq!(b.mask.shape, vec![4, 64]);
        assert_eq!(b.sop_labels.shape, vec![4]);
        for &t in b.ids.i32s().unwrap() {
            assert!((0..1024).contains(&t), "token {t} out of vocab");
        }
        for &s in b.sop_labels.i32s().unwrap() {
            assert!(s == 0 || s == 1);
        }
    }

    #[test]
    fn mask_rate_near_15_percent() {
        let mut c = Corpus::new(CorpusConfig::new(1024, 256, 16), 7);
        let b = c.next_batch().unwrap();
        let m = b.mask.f32s().unwrap();
        let rate = m.iter().sum::<f32>() / m.len() as f32;
        assert!((0.08..0.22).contains(&rate), "mask rate {rate}");
    }

    #[test]
    fn masked_positions_have_real_labels() {
        let mut c = corpus();
        let b = c.next_batch().unwrap();
        let ids = b.ids.i32s().unwrap();
        let labels = b.labels.i32s().unwrap();
        let mask = b.mask.f32s().unwrap();
        for i in 0..ids.len() {
            if mask[i] > 0.0 {
                assert!(labels[i] >= N_SPECIAL, "masked label {}", labels[i]);
            }
        }
    }

    #[test]
    fn sequences_start_with_cls() {
        let mut c = corpus();
        let b = c.next_batch().unwrap();
        let ids = b.ids.i32s().unwrap();
        for s in 0..4 {
            // CLS is never maskable, so position 0 survives masking
            assert_eq!(ids[s * 64], CLS);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = corpus();
        let mut b = corpus();
        assert_eq!(a.next_batch().unwrap().ids, b.next_batch().unwrap().ids);
    }

    #[test]
    fn at_cursor_resumes_the_stream_exactly() {
        let mut full = corpus();
        for _ in 0..5 {
            full.next_batch().unwrap();
        }
        assert_eq!(full.drawn(), 5);
        let mut resumed =
            Corpus::at_cursor(CorpusConfig::new(1024, 64, 4), 42, 5).unwrap();
        assert_eq!(resumed.drawn(), 5);
        for _ in 0..3 {
            let a = full.next_batch().unwrap();
            let b = resumed.next_batch().unwrap();
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.sop_labels, b.sop_labels);
        }
    }

    #[test]
    fn sop_labels_balanced() {
        let mut c = Corpus::new(CorpusConfig::new(1024, 64, 64), 3);
        let b = c.next_batch().unwrap();
        let ones: i32 = b.sop_labels.i32s().unwrap().iter().sum();
        assert!((10..=54).contains(&ones), "sop balance {ones}/64");
    }
}
