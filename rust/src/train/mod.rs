//! Training: optimizer, LR schedule, synthetic corpus, and the loop.

pub mod checkpoint;
pub mod data;
pub mod optim;
pub mod trainer;
