//! The training loop: engine-agnostic, logs the Fig. 6 loss curves.

use anyhow::Result;

use crate::model::params::ParamStore;
use crate::parallel::{Batch, Engine};

use super::optim::{lr_schedule, Adam, AdamConfig};

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub warmup: u64,
    pub peak_lr: f32,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 100, warmup: 10, peak_lr: 1e-3, log_every: 10 }
    }
}

/// One logged point of the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct LogPoint {
    pub step: u64,
    pub loss: f32,
    pub mlm: f32,
    pub sop: f32,
    pub lr: f32,
    pub tokens_per_sec: f64,
}

pub struct Trainer<'e, E: Engine> {
    pub engine: &'e E,
    pub cfg: TrainConfig,
    pub adam: Adam,
}

impl<'e, E: Engine> Trainer<'e, E> {
    pub fn new(engine: &'e E, params: &ParamStore, cfg: TrainConfig) -> Trainer<'e, E> {
        Trainer { engine, cfg, adam: Adam::new(params, AdamConfig::default()) }
    }

    /// Train over batches produced by `next_batch`; returns the loss curve.
    pub fn run<F>(
        &mut self,
        params: &mut ParamStore,
        mut next_batch: F,
        quiet: bool,
    ) -> Result<Vec<LogPoint>>
    where
        F: FnMut() -> Result<Batch>,
    {
        let mut curve = Vec::new();
        for step in 0..self.cfg.steps {
            let batch = next_batch()?;
            let tokens = (batch.ids.numel()) as f64;
            let t0 = std::time::Instant::now();
            let out = self.engine.forward_backward(params, &batch)?;
            let lr = lr_schedule(step, self.cfg.warmup, self.cfg.steps, self.cfg.peak_lr);
            self.adam.step(params, &out.grads, lr)?;
            let dt = t0.elapsed().as_secs_f64();
            let point = LogPoint {
                step,
                loss: out.loss,
                mlm: out.mlm,
                sop: out.sop,
                lr,
                tokens_per_sec: tokens / dt.max(1e-9),
            };
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                if !quiet {
                    println!(
                        "[{}] step {:>5}  loss {:.4}  mlm {:.4}  sop {:.4}  lr {:.2e}  {:>8.0} tok/s",
                        self.engine.name(), step, point.loss, point.mlm, point.sop,
                        lr, point.tokens_per_sec
                    );
                }
                curve.push(point);
            }
        }
        Ok(curve)
    }
}
