//! The training loop: engine-agnostic, logs the Fig. 6 loss curves.
//! [`Trainer`] drives a flat [`Engine`]; [`MeshTrainer`] drives a 4D
//! mesh backend (`exec::MeshStep`), feeding it `dp × micros`
//! manifest-shaped microbatches per optimizer step.

use anyhow::Result;

use crate::exec::MeshStep;
use crate::model::params::ParamStore;
use crate::parallel::{Batch, Engine};

use super::optim::{lr_schedule, Adam, AdamConfig};

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub warmup: u64,
    pub peak_lr: f32,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 100, warmup: 10, peak_lr: 1e-3, log_every: 10 }
    }
}

/// One logged point of the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct LogPoint {
    pub step: u64,
    pub loss: f32,
    pub mlm: f32,
    pub sop: f32,
    pub lr: f32,
    pub tokens_per_sec: f64,
}

/// Shared per-step epilogue for both loops: build the [`LogPoint`], log
/// on the configured cadence, and record it on the curve.  Also used by
/// `exec::recovery`'s elastic loop so recovered runs log identically.
#[allow(clippy::too_many_arguments)]
pub(crate) fn record_step(
    name: &str,
    cfg: &TrainConfig,
    curve: &mut Vec<LogPoint>,
    step: u64,
    (loss, mlm, sop): (f32, f32, f32),
    lr: f32,
    tokens: f64,
    dt: f64,
    quiet: bool,
) {
    let point = LogPoint { step, loss, mlm, sop, lr, tokens_per_sec: tokens / dt.max(1e-9) };
    if step % cfg.log_every == 0 || step + 1 == cfg.steps {
        if !quiet {
            println!(
                "[{name}] step {step:>5}  loss {:.4}  mlm {:.4}  sop {:.4}  lr {lr:.2e}  {:>8.0} tok/s",
                point.loss, point.mlm, point.sop, point.tokens_per_sec
            );
        }
        curve.push(point);
    }
}

pub struct Trainer<'e, E: Engine> {
    pub engine: &'e E,
    pub cfg: TrainConfig,
    pub adam: Adam,
}

impl<'e, E: Engine> Trainer<'e, E> {
    pub fn new(engine: &'e E, params: &ParamStore, cfg: TrainConfig) -> Trainer<'e, E> {
        Trainer { engine, cfg, adam: Adam::new(params, AdamConfig::default()) }
    }

    /// Train over batches produced by `next_batch`; returns the loss curve.
    pub fn run<F>(
        &mut self,
        params: &mut ParamStore,
        mut next_batch: F,
        quiet: bool,
    ) -> Result<Vec<LogPoint>>
    where
        F: FnMut() -> Result<Batch>,
    {
        // Adam's moment stores are replicated like the params: every
        // simulated device holds a copy for the whole run (the `2×params`
        // Optimizer row of `simulator::memory`).
        let _opt_charges: Vec<crate::obs::mem::Charge> = (0..self.engine.group_size())
            .map(|d| {
                crate::obs::mem::Charge::new(
                    d,
                    crate::obs::mem::Category::Optimizer,
                    self.adam.state_bytes() as u64,
                )
            })
            .collect();
        let mut curve = Vec::new();
        for step in 0..self.cfg.steps {
            let batch = next_batch()?;
            let tokens = (batch.ids.numel()) as f64;
            let sw = crate::obs::Stopwatch::start();
            let step_sp = crate::obs::begin();
            let out = self.engine.forward_backward(params, &batch)?;
            let lr = lr_schedule(step, self.cfg.warmup, self.cfg.steps, self.cfg.peak_lr);
            let opt_sp = crate::obs::begin();
            self.adam.step(params, &out.grads, lr)?;
            opt_sp.end_phase("optimizer");
            step_sp.end_phase_idx("step", step as usize);
            let dt = sw.elapsed_secs();
            record_step(
                self.engine.name(),
                &self.cfg,
                &mut curve,
                step,
                (out.loss, out.mlm, out.sop),
                lr,
                tokens,
                dt,
                quiet,
            );
        }
        Ok(curve)
    }
}

/// The mesh training loop: one optimizer step consumes `dp * micros`
/// manifest-shaped microbatches (replicas × GPipe microbatches), pulled
/// from `next_batch` in (replica-major, micro-minor) order so a run is
/// fully determined by the corpus seed regardless of mesh factorization.
pub struct MeshTrainer<'e> {
    pub engine: &'e dyn MeshStep,
    pub cfg: TrainConfig,
    pub adam: Adam,
}

impl<'e> MeshTrainer<'e> {
    pub fn new(engine: &'e dyn MeshStep, params: &ParamStore, cfg: TrainConfig) -> MeshTrainer<'e> {
        MeshTrainer { engine, cfg, adam: Adam::new(params, AdamConfig::default()) }
    }

    pub fn run<F>(
        &mut self,
        params: &mut ParamStore,
        mut next_batch: F,
        quiet: bool,
    ) -> Result<Vec<LogPoint>>
    where
        F: FnMut() -> Result<Batch>,
    {
        let mesh = self.engine.mesh();
        let micros = self.engine.micros();
        let label = format!("mesh-{}", mesh.label());
        // replicated Adam state, one copy per mesh coordinate
        let _opt_charges: Vec<crate::obs::mem::Charge> = (0..mesh.world_size())
            .map(|d| {
                crate::obs::mem::Charge::new(
                    d,
                    crate::obs::mem::Category::Optimizer,
                    self.adam.state_bytes() as u64,
                )
            })
            .collect();
        let mut curve = Vec::new();
        for step in 0..self.cfg.steps {
            let batches: Vec<Vec<Batch>> = (0..mesh.dp)
                .map(|_| (0..micros).map(|_| next_batch()).collect::<Result<Vec<_>>>())
                .collect::<Result<_>>()?;
            // a mesh step consumes dp*micros microbatches of tokens
            let tokens: f64 = batches
                .iter()
                .flatten()
                .map(|b| b.ids.numel() as f64)
                .sum();
            let sw = crate::obs::Stopwatch::start();
            let step_sp = crate::obs::begin();
            let out = self.engine.step(params, &batches)?;
            let lr = lr_schedule(step, self.cfg.warmup, self.cfg.steps, self.cfg.peak_lr);
            let opt_sp = crate::obs::begin();
            self.adam.step(params, &out.grads, lr)?;
            opt_sp.end_phase("optimizer");
            step_sp.end_phase_idx("step", step as usize);
            let dt = sw.elapsed_secs();
            record_step(
                &label,
                &self.cfg,
                &mut curve,
                step,
                (out.loss, out.mlm, out.sop),
                lr,
                tokens,
                dt,
                quiet,
            );
        }
        Ok(curve)
    }
}
