//! Checkpointing: save/restore parameters + Adam state + step counter +
//! data-loader cursor.
//!
//! Layout (SPT1 tensors + a small JSON index):
//!
//! ```text
//! <dir>/checkpoint.json        {"step": N, "data_cursor": D, "params": [names...]}
//! <dir>/params/<name>.tensor
//! <dir>/adam_m/<name>.tensor
//! <dir>/adam_v/<name>.tensor
//! ```
//!
//! Engines are stateless, so a checkpoint fully determines the run; the
//! resume test asserts bit-identical continuation.  `data_cursor` is the
//! number of batches the data loader had already produced — without it a
//! mid-epoch resume would restart the batch stream from the epoch head and
//! silently retrain on consumed data.
//!
//! [`Checkpoint::capture`] / [`Checkpoint::unpack`] form the in-memory
//! save/load path: elastic recovery (exec::recovery) snapshots and restores
//! training state through the same struct without a disk round-trip.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::params::ParamStore;
use crate::tensor::io;
use crate::train::optim::Adam;
use crate::util::json::{self, Value};

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub step: u64,
    pub params: ParamStore,
    pub adam_m: ParamStore,
    pub adam_v: ParamStore,
    /// Batches the data loader had produced when this checkpoint was taken.
    pub data_cursor: u64,
}

impl Checkpoint {
    /// In-memory save: snapshot the full training state (params, Adam
    /// moments, step, data cursor) without touching disk.  `save()` on the
    /// result produces exactly the on-disk layout; recovery skips that.
    pub fn capture(
        step: u64,
        params: &ParamStore,
        adam: &Adam,
        data_cursor: u64,
    ) -> Checkpoint {
        let (m, v, _t) = adam.state();
        Checkpoint {
            step,
            params: params.clone(),
            adam_m: m.clone(),
            adam_v: v.clone(),
            data_cursor,
        }
    }

    /// In-memory load: split the checkpoint back into live training state.
    /// The Adam step count is restored from `step` (the trainer advances
    /// them in lockstep, which `capture` relies on too).
    pub fn unpack(self) -> (ParamStore, ParamStore, ParamStore, u64, u64) {
        (self.params, self.adam_m, self.adam_v, self.step, self.data_cursor)
    }
}

fn save_store(dir: &Path, sub: &str, store: &ParamStore) -> Result<()> {
    let d = dir.join(sub);
    std::fs::create_dir_all(&d)?;
    for (name, t) in &store.values {
        io::save(&d.join(format!("{}.tensor", name.replace('.', "_"))), t)?;
    }
    Ok(())
}

fn load_store(dir: &Path, sub: &str, names: &[String]) -> Result<ParamStore> {
    let d = dir.join(sub);
    let mut values = BTreeMap::new();
    for name in names {
        let t = io::load(&d.join(format!("{}.tensor", name.replace('.', "_"))))
            .with_context(|| format!("loading {sub}/{name}"))?;
        values.insert(name.clone(), t);
    }
    Ok(ParamStore { values })
}

pub fn save(dir: &Path, ckpt: &Checkpoint) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    save_store(dir, "params", &ckpt.params)?;
    save_store(dir, "adam_m", &ckpt.adam_m)?;
    save_store(dir, "adam_v", &ckpt.adam_v)?;
    let mut obj = BTreeMap::new();
    obj.insert("step".to_string(), Value::Num(ckpt.step as f64));
    obj.insert("data_cursor".to_string(), Value::Num(ckpt.data_cursor as f64));
    obj.insert(
        "params".to_string(),
        Value::Arr(
            ckpt.params
                .values
                .keys()
                .map(|k| Value::Str(k.clone()))
                .collect(),
        ),
    );
    std::fs::write(dir.join("checkpoint.json"), json::encode(&Value::Obj(obj)))?;
    Ok(())
}

pub fn load(dir: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(dir.join("checkpoint.json"))
        .with_context(|| format!("no checkpoint at {}", dir.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let step = v
        .req("step")?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("bad step"))? as u64;
    // absent in pre-cursor checkpoints: those were only ever taken at epoch
    // boundaries in spirit, so resume-from-stream-head is the best reading
    let data_cursor = v.get("data_cursor").and_then(Value::as_f64).unwrap_or(0.0) as u64;
    let names: Vec<String> = v
        .req("params")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad params list"))?
        .iter()
        .map(|s| {
            s.as_str()
                .map(String::from)
                .ok_or_else(|| anyhow::anyhow!("bad param name"))
        })
        .collect::<Result<_>>()?;
    if names.is_empty() {
        bail!("checkpoint lists no parameters");
    }
    Ok(Checkpoint {
        step,
        params: load_store(dir, "params", &names)?,
        adam_m: load_store(dir, "adam_m", &names)?,
        adam_v: load_store(dir, "adam_v", &names)?,
        data_cursor,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn store(seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::default();
        s.values.insert("layer0.wq".into(), Tensor::randn(&[8, 8], 0.1, &mut rng));
        s.values.insert("bias".into(), Tensor::randn(&[8], 0.1, &mut rng));
        s
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = std::env::temp_dir().join("seqpar_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = Checkpoint {
            step: 42,
            params: store(1),
            adam_m: store(2),
            adam_v: store(3),
            data_cursor: 17,
        };
        save(&dir, &ckpt).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.data_cursor, 17);
        assert_eq!(back.params.values, ckpt.params.values);
        assert_eq!(back.adam_m.values, ckpt.adam_m.values);
        assert_eq!(back.adam_v.values, ckpt.adam_v.values);
    }

    #[test]
    fn pre_cursor_checkpoints_default_to_zero() {
        // a checkpoint written before data_cursor existed must still load
        let dir = std::env::temp_dir().join("seqpar_ckpt_legacy");
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = Checkpoint {
            step: 3,
            params: store(1),
            adam_m: store(2),
            adam_v: store(3),
            data_cursor: 99,
        };
        save(&dir, &ckpt).unwrap();
        let text = std::fs::read_to_string(dir.join("checkpoint.json")).unwrap();
        let stripped = text.replace("\"data_cursor\":99,", "");
        assert_ne!(stripped, text, "fixture must actually drop the field");
        std::fs::write(dir.join("checkpoint.json"), stripped).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.data_cursor, 0);
        assert_eq!(back.step, 3);
    }

    #[test]
    fn missing_checkpoint_errors_with_path() {
        let err = load(Path::new("/nonexistent/ckpt")).unwrap_err().to_string();
        assert!(err.contains("/nonexistent/ckpt"), "{err}");
    }

    #[test]
    fn dotted_names_are_file_safe() {
        let dir = std::env::temp_dir().join("seqpar_ckpt_dots");
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = Checkpoint {
            step: 0,
            params: store(5),
            adam_m: store(6),
            adam_v: store(7),
            data_cursor: 0,
        };
        save(&dir, &ckpt).unwrap();
        assert!(dir.join("params/layer0_wq.tensor").exists());
        assert!(load(&dir).is_ok());
    }
}
