//! Adam optimizer + linear warmup/decay schedule (Megatron defaults).
//!
//! Runs host-side over the replicated [`ParamStore`]: the update is
//! identical on every simulated device (gradients are already reduced), so
//! one update serves the group — exactly the semantics of replicated-state
//! training the paper assumes (it uses Megatron's Adam, §3.2.1).

use anyhow::Result;

use crate::model::params::ParamStore;

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

pub struct Adam {
    pub cfg: AdamConfig,
    m: ParamStore,
    v: ParamStore,
    t: u64,
}

impl Adam {
    pub fn new(params: &ParamStore, cfg: AdamConfig) -> Adam {
        Adam { cfg, m: params.zeros_like(), v: params.zeros_like(), t: 0 }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Expose moment estimates + step for checkpointing.
    pub fn state(&self) -> (&ParamStore, &ParamStore, u64) {
        (&self.m, &self.v, self.t)
    }

    /// Bytes held by the two moment stores — the `obs::mem` Optimizer
    /// category (exactly `2×` the parameter bytes, the closed form
    /// `simulator::memory` uses).
    pub fn state_bytes(&self) -> usize {
        self.m.total_bytes() + self.v.total_bytes()
    }

    /// Rebuild from a checkpoint (see `train::checkpoint`).
    pub fn from_state(cfg: AdamConfig, m: ParamStore, v: ParamStore, t: u64) -> Adam {
        Adam { cfg, m, v, t }
    }

    /// One update: `p -= lr * m_hat / (sqrt(v_hat) + eps)`.
    pub fn step(&mut self, params: &mut ParamStore, grads: &ParamStore, lr: f32) -> Result<()> {
        self.t += 1;
        let t = self.t as f32;
        let (b1, b2, eps, wd) = (self.cfg.beta1, self.cfg.beta2, self.cfg.eps, self.cfg.weight_decay);
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        for (name, p) in params.values.iter_mut() {
            let g = grads.values[name].f32s()?;
            let m = self.m.values.get_mut(name).unwrap().f32s_mut()?;
            let v = self.v.values.get_mut(name).unwrap().f32s_mut()?;
            let pd = p.f32s_mut()?;
            for i in 0..pd.len() {
                let gi = g[i] + wd * pd[i];
                m[i] = b1 * m[i] + (1.0 - b1) * gi;
                v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                pd[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        Ok(())
    }
}

/// Linear warmup to `peak`, then linear decay to zero at `total` steps.
pub fn lr_schedule(step: u64, warmup: u64, total: u64, peak: f32) -> f32 {
    if total == 0 {
        return peak;
    }
    if step < warmup {
        return peak * (step + 1) as f32 / warmup.max(1) as f32;
    }
    let rest = (total.saturating_sub(step)) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    peak * rest.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn store(vals: &[f32]) -> ParamStore {
        let mut s = ParamStore::default();
        s.values.insert(
            "w".into(),
            Tensor::from_f32(&[vals.len()], vals.to_vec()).unwrap(),
        );
        s
    }

    #[test]
    fn first_step_matches_closed_form() {
        let mut p = store(&[1.0, -2.0]);
        let g = store(&[0.5, -0.25]);
        let mut adam = Adam::new(&p, AdamConfig::default());
        adam.step(&mut p, &g, 1e-3).unwrap();
        // t=1: mhat = g, vhat = g^2  =>  p -= lr * g/|g| = lr * sign(g)
        let w = p.values["w"].f32s().unwrap();
        assert!((w[0] - (1.0 - 1e-3)).abs() < 1e-5, "{w:?}");
        assert!((w[1] - (-2.0 + 1e-3)).abs() < 1e-5, "{w:?}");
    }

    #[test]
    fn descends_a_quadratic() {
        // minimize f(w) = (w - 3)^2 / 2; grad = w - 3
        let mut p = store(&[0.0]);
        let mut adam = Adam::new(&p, AdamConfig::default());
        for _ in 0..2000 {
            let w = p.values["w"].f32s().unwrap()[0];
            let g = store(&[w - 3.0]);
            adam.step(&mut p, &g, 0.01).unwrap();
        }
        let w = p.values["w"].f32s().unwrap()[0];
        assert!((w - 3.0).abs() < 0.05, "converged to {w}");
    }

    #[test]
    fn schedule_warms_up_and_decays() {
        let peak = 1e-4;
        assert!(lr_schedule(0, 10, 100, peak) < peak * 0.2);
        assert!((lr_schedule(9, 10, 100, peak) - peak).abs() < 1e-9); // 1 ulp slack
        assert!(lr_schedule(50, 10, 100, peak) < peak);
        assert!(lr_schedule(99, 10, 100, peak) < peak * 0.05);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = store(&[10.0]);
        let g = store(&[0.0]);
        let mut adam = Adam::new(
            &p,
            AdamConfig { weight_decay: 0.1, ..AdamConfig::default() },
        );
        for _ in 0..50 {
            adam.step(&mut p, &g, 0.01).unwrap();
        }
        assert!(p.values["w"].f32s().unwrap()[0] < 10.0);
    }
}
