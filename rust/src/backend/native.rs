//! Pure-rust executor: every manifest step as an in-process f32 kernel.
//!
//! This backend makes the whole system self-contained — engines, tests and
//! benches run with **zero external artifacts**.  It mirrors the AOT
//! pipeline exactly:
//!
//! * the artifact *names* are enumerated the same way `aot.py` enumerates
//!   them (`enumerate_seqpar` + `enumerate_tensorpar(tp)` +
//!   `enumerate_tensorpar(1)` + optional Linformer), so a config mismatch
//!   between an engine and the backend is still caught by name lookup;
//! * the kernel *semantics* are the `python/compile/kernels/ref.py`
//!   oracles: scaled `QK^T/sqrt(A)` scores, max-subtracted softmax,
//!   `EPS = 1e-5` LayerNorm, tanh-approximate GeLU, and the hand-scheduled
//!   ring-attention backward GEMMs of `steps.py`;
//! * static parameters that `aot.py` bakes into an artifact at lowering
//!   time (the `to_heads`/`qkv_proj` head layout, the loss normalizers)
//!   are baked into the per-artifact `Kernel` descriptor here.
//!
//! Everything is plain row-major f32 on the host — no BLAS, no hidden
//! kernel-level threading — which keeps the backend dependency-free and
//! deterministic.  The backend itself is `Send + Sync` (stats are atomic),
//! so `exec::DistRunner` can drive one kernel stream per rank thread.
//!
//! Memory accounting: every kernel output materializes through the
//! `Tensor` constructors, which report allocation CHURN to
//! [`crate::obs::mem::note_alloc`]; live/peak RESIDENCY is charged at the
//! stash/param choke points in the engines, not per kernel call.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::model::{self, ModelConfig};
use crate::runtime::{
    registry, validate_inputs, ArtifactSpec, IoSpec, Manifest, ParamSpec, RuntimeStats,
};
use crate::tensor::{DType, Tensor};

const LN_EPS: f32 = 1e-5;
const GELU_C0: f32 = 0.797_884_56;
const GELU_C1: f32 = 0.044715;

/// Run-shape configuration for a synthetic (artifact-free) manifest.
#[derive(Clone, Copy, Debug)]
pub struct NativeConfig {
    pub model: ModelConfig,
    pub batch: usize,
    pub seq_len: usize,
    pub ring: usize,
    pub tp: usize,
    /// Linformer projection dim K (0 = skip those artifacts).
    pub linformer_k: usize,
    /// Blockwise-causal band width in TOKENS (0 = skip the masked-softmax
    /// artifacts; `--attn block:W`).
    pub block_w: usize,
    /// Register the Ulysses head-shard attention kernels (`--sp ulysses`):
    /// full-sequence dense attention at `[B, Z/ring, L, A]` chunk shapes.
    /// Requires `ring` to divide the head count.
    pub ulysses: bool,
    pub seed: u64,
}

impl NativeConfig {
    /// The CI / test default: bert-tiny at a short sequence, ring of 4.
    pub fn tiny() -> NativeConfig {
        NativeConfig {
            model: model::BERT_TINY,
            batch: 2,
            seq_len: 32,
            ring: 4,
            tp: 2,
            linformer_k: 0,
            block_w: 0,
            ulysses: false,
            seed: 0,
        }
    }

    /// Lower this config for a mesh's model axis (`exec::mesh`): the
    /// manifest must carry ring=mp kernels under the sequence kind, or
    /// tp=mp shard kernels under the tensor kind.  A TP axis that
    /// violates Megatron's head-count cap keeps the base lowering — the
    /// backend stays constructible and the mesh constructor reports the
    /// real §4.2 error.
    pub fn for_mesh(mut self, mesh: &crate::parallel::topology::Mesh) -> NativeConfig {
        use crate::parallel::topology::MpKind;
        match mesh.kind {
            MpKind::Sequence => self.ring = mesh.mp,
            MpKind::Tensor => {
                self.ring = 1;
                if mesh.mp > 1 && self.model.heads % mesh.mp == 0 {
                    self.tp = mesh.mp;
                }
            }
        }
        self
    }
}

/// One registered artifact's kernel identity + lowering-time constants.
#[derive(Clone, Copy, Debug)]
enum Kernel {
    EmbedFwd,
    EmbedBwd,
    LnFwd,
    LnBwd,
    LinearFwd,
    LinearBwd,
    GeluLinearFwd,
    GeluLinearBwd,
    Add,
    BiasAdd,
    ToHeads { b: usize, z: usize, a: usize },
    FromHeads,
    QkvProj { b: usize, z: usize, a: usize },
    QkvProjBwd,
    AddLnFwd,
    MlpFwd,
    MlpBwd,
    ScoresStep,
    SoftmaxFwd,
    SoftmaxBwd,
    MaskedSoftmaxFwd,
    AvStep,
    AttnDpStep,
    AttnDqStep,
    AttnDkStep,
    AttnDvStep,
    LinformerProj,
    LinformerProjBwd,
    MlmLoss { norm: f32 },
    SopLoss { batch: usize, norm: f32 },
}

pub struct NativeBackend {
    manifest: Manifest,
    kernels: HashMap<String, Kernel>,
    // Counters use atomics/locks (not RefCell) so the backend is Sync and
    // one instance can serve every rank thread of exec::DistRunner.
    calls: AtomicU64,
    exec_nanos: AtomicU64,
    // name -> (calls, total dispatch nanos); keys double as the distinct-
    // kernel set behind cached_executables().
    kernel_log: Mutex<BTreeMap<String, (u64, u64)>>,
}

// ---------------------------------------------------------------- registry

struct Reg {
    artifacts: BTreeMap<String, ArtifactSpec>,
    kernels: HashMap<String, Kernel>,
}

fn fio(dims: &[usize]) -> IoSpec {
    IoSpec { dims: dims.to_vec(), dtype: DType::F32 }
}

fn iio(dims: &[usize]) -> IoSpec {
    IoSpec { dims: dims.to_vec(), dtype: DType::I32 }
}

impl Reg {
    fn new() -> Reg {
        Reg { artifacts: BTreeMap::new(), kernels: HashMap::new() }
    }

    /// Register one artifact (skip if an identical name already exists —
    /// the same dedup rule `aot.py::lower_all` applies).
    fn add(&mut self, step: &str, kernel: Kernel, inputs: Vec<IoSpec>) -> Result<()> {
        let sig: Vec<(&[usize], bool)> = inputs
            .iter()
            .map(|s| (s.dims.as_slice(), s.dtype == DType::I32))
            .collect();
        let name = registry::art_name(step, &sig);
        if self.artifacts.contains_key(&name) {
            return Ok(());
        }
        let outputs = infer_outputs(kernel, &inputs)
            .map_err(|e| anyhow!("registering {name}: {e}"))?;
        self.artifacts
            .insert(name.clone(), ArtifactSpec { file: String::new(), inputs, outputs });
        self.kernels.insert(name, kernel);
        Ok(())
    }
}

/// Output shapes of a kernel given its input specs — the native mirror of
/// `jax.eval_shape` in `aot.py`.
fn infer_outputs(kernel: Kernel, ins: &[IoSpec]) -> Result<Vec<IoSpec>> {
    let d = |i: usize| -> Result<&Vec<usize>> {
        ins.get(i)
            .map(|s| &s.dims)
            .ok_or_else(|| anyhow!("kernel needs input {i}, got {}", ins.len()))
    };
    Ok(match kernel {
        Kernel::EmbedFwd => {
            let (ids, tok) = (d(0)?, d(1)?);
            vec![fio(&[ids[0] * ids[1], tok[1]])]
        }
        Kernel::EmbedBwd => vec![fio(d(1)?), fio(d(2)?)],
        Kernel::LnFwd => vec![fio(d(0)?)],
        Kernel::LnBwd => vec![fio(d(0)?), fio(d(1)?), fio(d(2)?)],
        Kernel::LinearFwd | Kernel::GeluLinearFwd => {
            let (x, w) = (d(0)?, d(1)?);
            vec![fio(&[x[0], w[1]])]
        }
        Kernel::LinearBwd | Kernel::GeluLinearBwd => {
            vec![fio(d(0)?), fio(d(1)?), fio(d(2)?)]
        }
        Kernel::Add
        | Kernel::BiasAdd
        | Kernel::SoftmaxFwd
        | Kernel::SoftmaxBwd
        | Kernel::MaskedSoftmaxFwd => {
            vec![fio(d(0)?)]
        }
        Kernel::ToHeads { b, z, a } => {
            let x = d(0)?;
            vec![fio(&[b, z, x[0] / b, a])]
        }
        Kernel::FromHeads => {
            let x = d(0)?;
            vec![fio(&[x[0] * x[2], x[1] * x[3]])]
        }
        Kernel::QkvProj { b, z, a } => {
            let x = d(0)?;
            let hs = fio(&[b, z, x[0] / b, a]);
            vec![hs.clone(), hs.clone(), hs]
        }
        Kernel::QkvProjBwd => {
            let (x, w) = (d(0)?, d(1)?);
            let za = w[1];
            vec![
                fio(x),
                fio(&[w[0], za]),
                fio(&[za]),
                fio(&[w[0], za]),
                fio(&[za]),
                fio(&[w[0], za]),
                fio(&[za]),
            ]
        }
        Kernel::AddLnFwd => vec![fio(d(0)?), fio(d(0)?)],
        Kernel::MlpFwd => vec![fio(d(0)?)],
        Kernel::MlpBwd => {
            vec![fio(d(0)?), fio(d(1)?), fio(d(2)?), fio(d(3)?), fio(d(4)?)]
        }
        Kernel::ScoresStep | Kernel::AttnDpStep => {
            let (q, k) = (d(0)?, d(1)?);
            vec![fio(&[q[0], q[1], q[2], k[2]])]
        }
        Kernel::AvStep | Kernel::AttnDqStep | Kernel::AttnDkStep | Kernel::AttnDvStep => {
            vec![fio(d(2)?)]
        }
        Kernel::LinformerProj => {
            let (e, x) = (d(0)?, d(1)?);
            vec![fio(&[x[0], x[1], e[0], x[3]])]
        }
        Kernel::LinformerProjBwd => vec![fio(d(1)?), fio(d(0)?)],
        Kernel::MlmLoss { .. } => {
            let (x, w) = (d(0)?, d(1)?);
            vec![fio(&[]), fio(x), fio(w), fio(&[w[0]])]
        }
        Kernel::SopLoss { .. } => {
            let (x, w) = (d(0)?, d(1)?);
            vec![fio(&[]), fio(x), fio(w), fio(&[w[0]])]
        }
    })
}

// ------------------------------------------------- aot.py step enumeration

fn attention_steps(reg: &mut Reg, b: usize, z: usize, lc: usize, l_total: usize, a: usize) -> Result<()> {
    let qs = [b, z, lc, a];
    let ss = [b, z, lc, lc];
    let fl = [b, z, lc, l_total];
    reg.add("scores_step", Kernel::ScoresStep, vec![fio(&qs), fio(&qs)])?;
    reg.add("softmax_fwd", Kernel::SoftmaxFwd, vec![fio(&fl)])?;
    reg.add("av_step", Kernel::AvStep, vec![fio(&ss), fio(&qs), fio(&qs)])?;
    reg.add("attn_dp_step", Kernel::AttnDpStep, vec![fio(&qs), fio(&qs)])?;
    reg.add("softmax_bwd", Kernel::SoftmaxBwd, vec![fio(&fl), fio(&fl)])?;
    reg.add("attn_dq_step", Kernel::AttnDqStep, vec![fio(&ss), fio(&qs), fio(&qs)])?;
    reg.add("attn_dk_step", Kernel::AttnDkStep, vec![fio(&ss), fio(&qs), fio(&qs)])?;
    reg.add("attn_dv_step", Kernel::AttnDvStep, vec![fio(&ss), fio(&qs), fio(&qs)])?;
    Ok(())
}

fn fused_steps(reg: &mut Reg, h: usize, b: usize, lc: usize, z: usize, a: usize, fp: usize) -> Result<()> {
    let m = b * lc;
    let za = z * a;
    let qs = [b, z, lc, a];
    reg.add(
        &format!("qkv_proj_b{b}"),
        Kernel::QkvProj { b, z, a },
        vec![fio(&[m, h]), fio(&[h, za]), fio(&[za]), fio(&[h, za]), fio(&[za]), fio(&[h, za]), fio(&[za])],
    )?;
    reg.add(
        "qkv_proj_bwd",
        Kernel::QkvProjBwd,
        vec![fio(&[m, h]), fio(&[h, za]), fio(&[h, za]), fio(&[h, za]), fio(&qs), fio(&qs), fio(&qs)],
    )?;
    reg.add(
        "add_ln_fwd",
        Kernel::AddLnFwd,
        vec![fio(&[m, h]), fio(&[m, h]), fio(&[h]), fio(&[h])],
    )?;
    reg.add(
        "mlp_fwd",
        Kernel::MlpFwd,
        vec![fio(&[m, h]), fio(&[h, fp]), fio(&[fp]), fio(&[fp, h]), fio(&[h])],
    )?;
    reg.add(
        "mlp_bwd",
        Kernel::MlpBwd,
        vec![fio(&[m, h]), fio(&[h, fp]), fio(&[fp]), fio(&[fp, h]), fio(&[h]), fio(&[m, h])],
    )?;
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn local_steps(
    reg: &mut Reg,
    h: usize,
    v: usize,
    b: usize,
    lc: usize,
    l_global: usize,
    z: usize,
    a: usize,
) -> Result<()> {
    let m = b * lc;
    let za = z * a;
    reg.add("embed_fwd", Kernel::EmbedFwd, vec![iio(&[b, lc]), fio(&[v, h]), fio(&[lc, h])])?;
    reg.add(
        "embed_bwd",
        Kernel::EmbedBwd,
        vec![iio(&[b, lc]), fio(&[v, h]), fio(&[lc, h]), fio(&[m, h])],
    )?;
    reg.add("ln_fwd", Kernel::LnFwd, vec![fio(&[m, h]), fio(&[h]), fio(&[h])])?;
    reg.add("ln_bwd", Kernel::LnBwd, vec![fio(&[m, h]), fio(&[h]), fio(&[h]), fio(&[m, h])])?;
    reg.add("linear_fwd", Kernel::LinearFwd, vec![fio(&[m, h]), fio(&[h, za]), fio(&[za])])?;
    reg.add(
        "linear_bwd",
        Kernel::LinearBwd,
        vec![fio(&[m, h]), fio(&[h, za]), fio(&[za]), fio(&[m, za])],
    )?;
    reg.add("linear_fwd", Kernel::LinearFwd, vec![fio(&[m, za]), fio(&[za, h]), fio(&[h])])?;
    reg.add(
        "linear_bwd",
        Kernel::LinearBwd,
        vec![fio(&[m, za]), fio(&[za, h]), fio(&[h]), fio(&[m, h])],
    )?;
    reg.add(&format!("to_heads_b{b}"), Kernel::ToHeads { b, z, a }, vec![fio(&[m, za])])?;
    reg.add("from_heads", Kernel::FromHeads, vec![fio(&[b, z, lc, a])])?;
    reg.add("add", Kernel::Add, vec![fio(&[m, h]), fio(&[m, h])])?;
    reg.add("bias_add", Kernel::BiasAdd, vec![fio(&[m, h]), fio(&[h])])?;
    reg.add(
        "mlm_loss",
        Kernel::MlmLoss { norm: (b * l_global) as f32 },
        vec![fio(&[m, h]), fio(&[v, h]), fio(&[v]), iio(&[m]), fio(&[m])],
    )?;
    reg.add(
        "sop_loss",
        Kernel::SopLoss { batch: b, norm: b as f32 },
        vec![fio(&[m, h]), fio(&[2, h]), fio(&[2]), iio(&[b])],
    )?;
    Ok(())
}

fn mlp_steps(reg: &mut Reg, h: usize, b: usize, lc: usize, fp: usize) -> Result<()> {
    let m = b * lc;
    reg.add("gelu_linear_fwd", Kernel::GeluLinearFwd, vec![fio(&[m, h]), fio(&[h, fp]), fio(&[fp])])?;
    reg.add(
        "gelu_linear_bwd",
        Kernel::GeluLinearBwd,
        vec![fio(&[m, h]), fio(&[h, fp]), fio(&[fp]), fio(&[m, fp])],
    )?;
    reg.add("linear_fwd", Kernel::LinearFwd, vec![fio(&[m, fp]), fio(&[fp, h]), fio(&[h])])?;
    reg.add(
        "linear_bwd",
        Kernel::LinearBwd,
        vec![fio(&[m, fp]), fio(&[fp, h]), fio(&[h]), fio(&[m, h])],
    )?;
    Ok(())
}

fn enumerate_seqpar(reg: &mut Reg, cfg: &NativeConfig) -> Result<()> {
    let m = &cfg.model;
    let (h, v) = (m.hidden, m.vocab);
    let lc = cfg.seq_len / cfg.ring;
    let (z, a) = (m.heads, m.head_dim);
    local_steps(reg, h, v, cfg.batch, lc, cfg.seq_len, z, a)?;
    mlp_steps(reg, h, cfg.batch, lc, m.ffn())?;
    attention_steps(reg, cfg.batch, z, lc, cfg.seq_len, a)?;
    fused_steps(reg, h, cfg.batch, lc, z, a, m.ffn())?;
    Ok(())
}

fn enumerate_tensorpar(reg: &mut Reg, cfg: &NativeConfig, t: usize) -> Result<()> {
    let m = &cfg.model;
    let (h, v, l) = (m.hidden, m.vocab, cfg.seq_len);
    let zp = m.heads / t;
    let fp = m.ffn() / t;
    let a = m.head_dim;
    local_steps(reg, h, v, cfg.batch, l, l, zp, a)?;
    mlp_steps(reg, h, cfg.batch, l, fp)?;
    attention_steps(reg, cfg.batch, zp, l, l, a)?;
    fused_steps(reg, h, cfg.batch, l, zp, a, fp)?;
    Ok(())
}

fn enumerate_linformer(reg: &mut Reg, cfg: &NativeConfig) -> Result<()> {
    let m = &cfg.model;
    let lc = cfg.seq_len / cfg.ring;
    let (z, a, kp) = (m.heads, m.head_dim, cfg.linformer_k);
    let qs = [cfg.batch, z, lc, a];
    let ks = [cfg.batch, z, kp, a];
    let sk = [cfg.batch, z, lc, kp];
    reg.add("linformer_proj", Kernel::LinformerProj, vec![fio(&[kp, lc]), fio(&qs)])?;
    reg.add("scores_step", Kernel::ScoresStep, vec![fio(&qs), fio(&ks)])?;
    reg.add("softmax_fwd", Kernel::SoftmaxFwd, vec![fio(&sk)])?;
    reg.add("av_step", Kernel::AvStep, vec![fio(&sk), fio(&ks), fio(&qs)])?;
    // backward of the executable Linformer path (attn::linformer)
    reg.add(
        "linformer_proj_bwd",
        Kernel::LinformerProjBwd,
        vec![fio(&[kp, lc]), fio(&qs), fio(&ks)],
    )?;
    reg.add("softmax_bwd", Kernel::SoftmaxBwd, vec![fio(&sk), fio(&sk)])?;
    reg.add("attn_dp_step", Kernel::AttnDpStep, vec![fio(&qs), fio(&ks)])?;
    reg.add("attn_dq_step", Kernel::AttnDqStep, vec![fio(&sk), fio(&ks), fio(&qs)])?;
    reg.add("attn_dk_step", Kernel::AttnDkStep, vec![fio(&sk), fio(&qs), fio(&ks)])?;
    reg.add("attn_dv_step", Kernel::AttnDvStep, vec![fio(&sk), fio(&qs), fio(&ks)])?;
    Ok(())
}

/// Ulysses head-shard artifacts (`--sp ulysses`): after the q/k/v
/// all-to-all each rank holds `Z/n` heads over the FULL sequence, so the
/// dense attention step kernels are registered at `[B, Z/n, L, A]` chunk
/// shapes (score rows `[L, L]`) — no new kernel semantics, just the
/// head-sharded signatures (`attn::ulysses` reuses the dense steps).
fn enumerate_ulysses(reg: &mut Reg, cfg: &NativeConfig) -> Result<()> {
    let m = &cfg.model;
    attention_steps(
        reg,
        cfg.batch,
        m.heads / cfg.ring,
        cfg.seq_len,
        cfg.seq_len,
        m.head_dim,
    )
}

/// Blockwise-sparse artifacts: per-rank masked softmax over the reachable
/// concatenation (widths depend on the plan, deduped by signature).  The
/// score/context/backward step kernels reuse the dense chunk shapes.
fn enumerate_block(reg: &mut Reg, cfg: &NativeConfig) -> Result<()> {
    let m = &cfg.model;
    let lc = cfg.seq_len / cfg.ring;
    let z = m.heads;
    // widths only — the full plan (with its mask tensors) is built once,
    // at engine construction (StepShape::from_manifest_sp)
    for w in crate::attn::block::BlockPlan::distinct_widths_for(cfg.ring, lc, cfg.block_w) {
        let rows = [cfg.batch, z, lc, w];
        reg.add(
            "masked_softmax_fwd",
            Kernel::MaskedSoftmaxFwd,
            vec![fio(&rows), fio(&[lc, w])],
        )?;
        reg.add("softmax_bwd", Kernel::SoftmaxBwd, vec![fio(&rows), fio(&rows)])?;
    }
    Ok(())
}

// ----------------------------------------------------------------- backend

impl NativeBackend {
    pub fn new(cfg: NativeConfig) -> Result<NativeBackend> {
        let m = &cfg.model;
        if cfg.ring == 0 || cfg.tp == 0 || cfg.batch == 0 {
            bail!("ring/tp/batch must be >= 1");
        }
        if cfg.seq_len % cfg.ring != 0 {
            bail!("seq_len {} not divisible by ring size {}", cfg.seq_len, cfg.ring);
        }
        if m.heads % cfg.tp != 0 {
            bail!("tp {} must divide head count {}", cfg.tp, m.heads);
        }
        if m.ffn() % cfg.tp != 0 {
            bail!("tp {} must divide FFN width {}", cfg.tp, m.ffn());
        }
        if m.heads * m.head_dim != m.hidden {
            bail!("model {}: heads*head_dim != hidden", m.name);
        }
        if cfg.ulysses && m.heads % cfg.ring != 0 {
            // same cap as Megatron's §4.2 tp-over-heads bound: the
            // all-to-all shards whole heads across the ring
            bail!(
                "ulysses sequence parallelism size {} must divide the head count {} \
                 (the all-to-all shards whole attention heads)",
                cfg.ring,
                m.heads
            );
        }
        let mut reg = Reg::new();
        enumerate_seqpar(&mut reg, &cfg)?;
        enumerate_tensorpar(&mut reg, &cfg, cfg.tp)?;
        enumerate_tensorpar(&mut reg, &cfg, 1)?;
        if cfg.linformer_k > 0 {
            enumerate_linformer(&mut reg, &cfg)?;
        }
        if cfg.block_w > 0 {
            enumerate_block(&mut reg, &cfg)?;
        }
        if cfg.ulysses {
            enumerate_ulysses(&mut reg, &cfg)?;
        }
        let mut params: Vec<ParamSpec> = model::param_spec(m, cfg.seq_len)
            .into_iter()
            .map(|(name, dims)| ParamSpec { name, dims, file: String::new() })
            .collect();
        if cfg.linformer_k > 0 {
            // shared Linformer projections [K, L], sliced [K, Lc] per
            // device like pos_emb (attn::linformer)
            for name in [crate::attn::LINFORMER_EK, crate::attn::LINFORMER_EV] {
                params.push(ParamSpec {
                    name: name.to_string(),
                    dims: vec![cfg.linformer_k, cfg.seq_len],
                    file: String::new(),
                });
            }
        }
        let manifest = Manifest {
            model: m.name.to_string(),
            batch: cfg.batch,
            seq_len: cfg.seq_len,
            ring: cfg.ring,
            tp: cfg.tp,
            linformer_k: cfg.linformer_k,
            block_w: cfg.block_w,
            ulysses: cfg.ulysses,
            hidden: m.hidden,
            heads: m.heads,
            head_dim: m.head_dim,
            ffn: m.ffn(),
            layers: m.layers,
            vocab: m.vocab,
            seed: cfg.seed as usize,
            artifacts: reg.artifacts,
            params,
            goldens: BTreeMap::new(),
        };
        Ok(NativeBackend {
            manifest,
            kernels: reg.kernels,
            calls: AtomicU64::new(0),
            exec_nanos: AtomicU64::new(0),
            kernel_log: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            compiles: 0,
            calls: self.calls.load(Ordering::Relaxed),
            compile_nanos: 0,
            exec_nanos: self.exec_nanos.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct kernels dispatched so far (the native analogue
    /// of the XLA backend's compiled-executable cache).
    pub fn cached_executables(&self) -> usize {
        self.kernel_log.lock().unwrap().len()
    }

    /// Per-kernel (calls, total dispatch time) breakdown, unsorted.
    pub fn kernel_stats(&self) -> Vec<crate::runtime::KernelStat> {
        self.kernel_log
            .lock()
            .unwrap()
            .iter()
            .map(|(name, &(calls, total_ns))| crate::runtime::KernelStat {
                name: name.clone(),
                calls,
                total_ns,
            })
            .collect()
    }

    pub fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest — engine and backend disagree on shapes"))?;
        validate_inputs(name, spec, inputs)?;
        let kernel = *self
            .kernels
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} has no native kernel"))?;
        let sp = crate::obs::begin();
        let sw = crate::obs::Stopwatch::start();
        let out = dispatch(kernel, inputs).map_err(|e| anyhow!("{name}: {e}"))?;
        let dur = sw.elapsed_ns();
        if out.len() != spec.outputs.len() {
            bail!("{name}: kernel returned {} outputs, manifest says {}", out.len(), spec.outputs.len());
        }
        for (i, (t, io)) in out.iter().zip(&spec.outputs).enumerate() {
            if t.shape != io.dims || t.dtype() != io.dtype {
                bail!(
                    "{name}: output {i} is {:?}/{:?}, manifest wants {:?}/{:?}",
                    t.shape, t.dtype(), io.dims, io.dtype
                );
            }
        }
        let bytes: u64 = inputs.iter().map(|t| t.bytes() as u64).sum::<u64>()
            + out.iter().map(|t| t.bytes() as u64).sum::<u64>();
        sp.end_kernel(name, bytes);
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.exec_nanos.fetch_add(dur, Ordering::Relaxed);
        {
            let mut log = self.kernel_log.lock().unwrap();
            let slot = log.entry(name.to_string()).or_insert((0, 0));
            slot.0 += 1;
            slot.1 += dur;
        }
        Ok(out)
    }
}

// ------------------------------------------------------------ math helpers

/// C[m,n] = A[m,k] @ B[k,n].
fn mm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    out
}

/// C[m,n] = A[m,k] @ B[n,k]^T.
fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                s += x * y;
            }
            out[i * n + j] = s;
        }
    }
    out
}

/// C[m,n] = A[r,m]^T @ B[r,n] (sum over the shared leading dim `r`).
fn mm_tn(a: &[f32], b: &[f32], r: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for row in 0..r {
        let arow = &a[row * m..(row + 1) * m];
        let brow = &b[row * n..(row + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    out
}

fn colsum(x: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for r in 0..m {
        for c in 0..n {
            out[c] += x[r * n + c];
        }
    }
    out
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C0 * (x + GELU_C1 * x * x * x)).tanh())
}

fn dgelu(x: f32) -> f32 {
    let u = GELU_C0 * (x + GELU_C1 * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C0 * (1.0 + 3.0 * GELU_C1 * x * x)
}

/// Leading batch (B*Z) and trailing (rows, cols) of a rank-4 tensor.
fn bz_split(shape: &[usize]) -> (usize, usize, usize) {
    (shape[0] * shape[1], shape[2], shape[3])
}

// ---------------------------------------------------------------- kernels

fn k_linear_fwd(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = (x.shape[0], x.shape[1]);
    let n = w.shape[1];
    let mut y = mm_nn(x.f32s()?, w.f32s()?, m, k, n);
    let bd = b.f32s()?;
    for r in 0..m {
        for c in 0..n {
            y[r * n + c] += bd[c];
        }
    }
    Tensor::from_f32(&[m, n], y)
}

fn k_linear_bwd(x: &Tensor, w: &Tensor, dy: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
    let (m, k) = (x.shape[0], x.shape[1]);
    let n = w.shape[1];
    let dyd = dy.f32s()?;
    let dx = mm_nt(dyd, w.f32s()?, m, n, k);
    let dw = mm_tn(x.f32s()?, dyd, m, k, n);
    let db = colsum(dyd, m, n);
    Ok((
        Tensor::from_f32(&[m, k], dx)?,
        Tensor::from_f32(&[k, n], dw)?,
        Tensor::from_f32(&[n], db)?,
    ))
}

fn k_gelu_linear_fwd(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    let mut y = k_linear_fwd(x, w, b)?;
    for v in y.f32s_mut()? {
        *v = gelu(*v);
    }
    Ok(y)
}

fn k_gelu_linear_bwd(x: &Tensor, w: &Tensor, b: &Tensor, dy: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
    let u = k_linear_fwd(x, w, b)?; // pre-activation, rematerialized
    let (m, n) = (u.shape[0], u.shape[1]);
    let ud = u.f32s()?;
    let dyd = dy.f32s()?;
    let mut dz = vec![0.0f32; m * n];
    for i in 0..m * n {
        dz[i] = dyd[i] * dgelu(ud[i]);
    }
    let k = x.shape[1];
    let dx = mm_nt(&dz, w.f32s()?, m, n, k);
    let dw = mm_tn(x.f32s()?, &dz, m, k, n);
    let db = colsum(&dz, m, n);
    Ok((
        Tensor::from_f32(&[m, k], dx)?,
        Tensor::from_f32(&[k, n], dw)?,
        Tensor::from_f32(&[n], db)?,
    ))
}

fn layernorm_rows(x: &[f32], gamma: &[f32], beta: &[f32], m: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * h];
    for r in 0..m {
        let row = &x[r * h..(r + 1) * h];
        let mean = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let orow = &mut out[r * h..(r + 1) * h];
        for c in 0..h {
            orow[c] = (row[c] - mean) * inv * gamma[c] + beta[c];
        }
    }
    out
}

fn k_ln_fwd(x: &Tensor, g: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, h) = (x.shape[0], x.shape[1]);
    let y = layernorm_rows(x.f32s()?, g.f32s()?, b.f32s()?, m, h);
    Tensor::from_f32(&[m, h], y)
}

fn k_ln_bwd(x: &Tensor, g: &Tensor, dy: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
    let (m, h) = (x.shape[0], x.shape[1]);
    let xd = x.f32s()?;
    let gd = g.f32s()?;
    let dyd = dy.f32s()?;
    let mut dx = vec![0.0f32; m * h];
    let mut dgamma = vec![0.0f32; h];
    let mut dbeta = vec![0.0f32; h];
    for r in 0..m {
        let row = &xd[r * h..(r + 1) * h];
        let dyr = &dyd[r * h..(r + 1) * h];
        let mean = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        // xhat = (x - mean) * inv;  grad through gamma: hvec = dy * gamma
        let mut mh = 0.0f32; // mean of hvec
        let mut mhx = 0.0f32; // mean of hvec * xhat
        for c in 0..h {
            let xhat = (row[c] - mean) * inv;
            let hv = dyr[c] * gd[c];
            mh += hv;
            mhx += hv * xhat;
            dgamma[c] += dyr[c] * xhat;
            dbeta[c] += dyr[c];
        }
        mh /= h as f32;
        mhx /= h as f32;
        let dxr = &mut dx[r * h..(r + 1) * h];
        for c in 0..h {
            let xhat = (row[c] - mean) * inv;
            let hv = dyr[c] * gd[c];
            dxr[c] = (hv - mh - xhat * mhx) * inv;
        }
    }
    Ok((
        Tensor::from_f32(&[m, h], dx)?,
        Tensor::from_f32(&[h], dgamma)?,
        Tensor::from_f32(&[h], dbeta)?,
    ))
}

fn k_to_heads(x: &Tensor, b: usize, z: usize, a: usize) -> Result<Tensor> {
    let m = x.shape[0];
    let za = x.shape[1];
    let lc = m / b;
    let xd = x.f32s()?;
    let mut out = vec![0.0f32; m * za];
    for bi in 0..b {
        for li in 0..lc {
            for zi in 0..z {
                let src = (bi * lc + li) * za + zi * a;
                let dst = ((bi * z + zi) * lc + li) * a;
                out[dst..dst + a].copy_from_slice(&xd[src..src + a]);
            }
        }
    }
    Tensor::from_f32(&[b, z, lc, a], out)
}

fn k_from_heads(x: &Tensor) -> Result<Tensor> {
    let (b, z, lc, a) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let za = z * a;
    let xd = x.f32s()?;
    let mut out = vec![0.0f32; b * lc * za];
    for bi in 0..b {
        for zi in 0..z {
            for li in 0..lc {
                let src = ((bi * z + zi) * lc + li) * a;
                let dst = (bi * lc + li) * za + zi * a;
                out[dst..dst + a].copy_from_slice(&xd[src..src + a]);
            }
        }
    }
    Tensor::from_f32(&[b * lc, za], out)
}

fn k_scores(q: &Tensor, k: &Tensor) -> Result<Tensor> {
    let (bz, lq, a) = bz_split(&q.shape);
    let lk = k.shape[2];
    let scale = 1.0 / (a as f32).sqrt();
    let qd = q.f32s()?;
    let kd = k.f32s()?;
    let mut out = vec![0.0f32; bz * lq * lk];
    for g in 0..bz {
        let s = mm_nt(&qd[g * lq * a..(g + 1) * lq * a], &kd[g * lk * a..(g + 1) * lk * a], lq, a, lk);
        let orow = &mut out[g * lq * lk..(g + 1) * lq * lk];
        for (o, v) in orow.iter_mut().zip(s) {
            *o = v * scale;
        }
    }
    Tensor::from_f32(&[q.shape[0], q.shape[1], lq, lk], out)
}

fn k_softmax_fwd(s: &Tensor) -> Result<Tensor> {
    let w = *s.shape.last().unwrap();
    let rows = s.numel() / w;
    let sd = s.f32s()?;
    let mut out = vec![0.0f32; rows * w];
    for r in 0..rows {
        let row = &sd[r * w..(r + 1) * w];
        let mx = row.iter().fold(f32::NEG_INFINITY, |acc, &v| acc.max(v));
        let orow = &mut out[r * w..(r + 1) * w];
        let mut sum = 0.0f32;
        for c in 0..w {
            let e = (row[c] - mx).exp();
            orow[c] = e;
            sum += e;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    Tensor::from_f32(&s.shape, out)
}

/// Softmax over `s + mask`, the mask broadcast over the leading B*Z
/// groups (mask is `[Lc, W]`, rows are `[B, Z, Lc, W]`).  Forbidden
/// entries carry a large-negative additive term, so their probabilities
/// underflow to exactly 0 and the backward is plain `softmax_bwd` on the
/// returned probs (the mask takes no gradient).
fn k_masked_softmax(s: &Tensor, mask: &Tensor) -> Result<Tensor> {
    let w = *s.shape.last().unwrap();
    let lc = mask.shape[0];
    if mask.shape[1] != w {
        bail!("mask width {} vs score width {w}", mask.shape[1]);
    }
    let rows = s.numel() / w;
    let sd = s.f32s()?;
    let md = mask.f32s()?;
    let mut out = vec![0.0f32; rows * w];
    for r in 0..rows {
        let row = &sd[r * w..(r + 1) * w];
        let mrow = &md[(r % lc) * w..(r % lc + 1) * w];
        let mx = row
            .iter()
            .zip(mrow)
            .fold(f32::NEG_INFINITY, |acc, (&v, &m)| acc.max(v + m));
        let orow = &mut out[r * w..(r + 1) * w];
        let mut sum = 0.0f32;
        for c in 0..w {
            let e = (row[c] + mrow[c] - mx).exp();
            orow[c] = e;
            sum += e;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    Tensor::from_f32(&s.shape, out)
}

fn k_softmax_bwd(p: &Tensor, dp: &Tensor) -> Result<Tensor> {
    let w = *p.shape.last().unwrap();
    let rows = p.numel() / w;
    let pd = p.f32s()?;
    let dpd = dp.f32s()?;
    let mut out = vec![0.0f32; rows * w];
    for r in 0..rows {
        let prow = &pd[r * w..(r + 1) * w];
        let dprow = &dpd[r * w..(r + 1) * w];
        let inner: f32 = prow.iter().zip(dprow).map(|(&a, &b)| a * b).sum();
        let orow = &mut out[r * w..(r + 1) * w];
        for c in 0..w {
            orow[c] = prow[c] * (dprow[c] - inner);
        }
    }
    Tensor::from_f32(&p.shape, out)
}

/// acc + P @ V over the leading B*Z groups.
fn k_av(p: &Tensor, v: &Tensor, acc: &Tensor) -> Result<Tensor> {
    let (bz, lq, lk) = bz_split(&p.shape);
    let a = v.shape[3];
    let pd = p.f32s()?;
    let vd = v.f32s()?;
    let mut out = acc.f32s()?.to_vec();
    for g in 0..bz {
        let c = mm_nn(&pd[g * lq * lk..(g + 1) * lq * lk], &vd[g * lk * a..(g + 1) * lk * a], lq, lk, a);
        let orow = &mut out[g * lq * a..(g + 1) * lq * a];
        for (o, x) in orow.iter_mut().zip(c) {
            *o += x;
        }
    }
    Tensor::from_f32(&acc.shape, out)
}

/// dP = dO @ V^T over the leading B*Z groups (unscaled).
fn k_attn_dp(d_out: &Tensor, v: &Tensor) -> Result<Tensor> {
    let (bz, lq, a) = bz_split(&d_out.shape);
    let lk = v.shape[2];
    let dd = d_out.f32s()?;
    let vd = v.f32s()?;
    let mut out = vec![0.0f32; bz * lq * lk];
    for g in 0..bz {
        let c = mm_nt(&dd[g * lq * a..(g + 1) * lq * a], &vd[g * lk * a..(g + 1) * lk * a], lq, a, lk);
        out[g * lq * lk..(g + 1) * lq * lk].copy_from_slice(&c);
    }
    Tensor::from_f32(&[d_out.shape[0], d_out.shape[1], lq, lk], out)
}

/// dQ_acc + scale * dS @ K.
fn k_attn_dq(ds: &Tensor, k: &Tensor, acc: &Tensor) -> Result<Tensor> {
    let (bz, lq, lk) = bz_split(&ds.shape);
    let a = k.shape[3];
    let scale = 1.0 / (a as f32).sqrt();
    let dsd = ds.f32s()?;
    let kd = k.f32s()?;
    let mut out = acc.f32s()?.to_vec();
    for g in 0..bz {
        let c = mm_nn(&dsd[g * lq * lk..(g + 1) * lq * lk], &kd[g * lk * a..(g + 1) * lk * a], lq, lk, a);
        let orow = &mut out[g * lq * a..(g + 1) * lq * a];
        for (o, x) in orow.iter_mut().zip(c) {
            *o += scale * x;
        }
    }
    Tensor::from_f32(&acc.shape, out)
}

/// dK_acc + scale * dS^T @ Q.
fn k_attn_dk(ds: &Tensor, q: &Tensor, acc: &Tensor) -> Result<Tensor> {
    let (bz, lq, lk) = bz_split(&ds.shape);
    let a = q.shape[3];
    let scale = 1.0 / (a as f32).sqrt();
    let dsd = ds.f32s()?;
    let qd = q.f32s()?;
    let mut out = acc.f32s()?.to_vec();
    for g in 0..bz {
        let c = mm_tn(&dsd[g * lq * lk..(g + 1) * lq * lk], &qd[g * lq * a..(g + 1) * lq * a], lq, lk, a);
        let orow = &mut out[g * lk * a..(g + 1) * lk * a];
        for (o, x) in orow.iter_mut().zip(c) {
            *o += scale * x;
        }
    }
    Tensor::from_f32(&acc.shape, out)
}

/// dV_acc + P^T @ dO.
fn k_attn_dv(p: &Tensor, d_out: &Tensor, acc: &Tensor) -> Result<Tensor> {
    let (bz, lq, lk) = bz_split(&p.shape);
    let a = d_out.shape[3];
    let pd = p.f32s()?;
    let dd = d_out.f32s()?;
    let mut out = acc.f32s()?.to_vec();
    for g in 0..bz {
        let c = mm_tn(&pd[g * lq * lk..(g + 1) * lq * lk], &dd[g * lq * a..(g + 1) * lq * a], lq, lk, a);
        let orow = &mut out[g * lk * a..(g + 1) * lk * a];
        for (o, x) in orow.iter_mut().zip(c) {
            *o += x;
        }
    }
    Tensor::from_f32(&acc.shape, out)
}

fn k_embed_fwd(ids: &Tensor, tok: &Tensor, pos: &Tensor) -> Result<Tensor> {
    let (b, lc) = (ids.shape[0], ids.shape[1]);
    let (v, h) = (tok.shape[0], tok.shape[1]);
    let idd = ids.i32s()?;
    let td = tok.f32s()?;
    let pd = pos.f32s()?;
    let mut out = vec![0.0f32; b * lc * h];
    for bi in 0..b {
        for li in 0..lc {
            let id = idd[bi * lc + li];
            if id < 0 || id as usize >= v {
                bail!("token id {id} out of vocab {v}");
            }
            let trow = &td[id as usize * h..(id as usize + 1) * h];
            let prow = &pd[li * h..(li + 1) * h];
            let orow = &mut out[(bi * lc + li) * h..(bi * lc + li + 1) * h];
            for c in 0..h {
                orow[c] = trow[c] + prow[c];
            }
        }
    }
    Tensor::from_f32(&[b * lc, h], out)
}

fn k_embed_bwd(ids: &Tensor, tok: &Tensor, pos: &Tensor, dx: &Tensor) -> Result<(Tensor, Tensor)> {
    let (b, lc) = (ids.shape[0], ids.shape[1]);
    let (v, h) = (tok.shape[0], tok.shape[1]);
    let idd = ids.i32s()?;
    let dxd = dx.f32s()?;
    let mut dtok = vec![0.0f32; v * h];
    let mut dpos = vec![0.0f32; lc * h];
    for bi in 0..b {
        for li in 0..lc {
            let id = idd[bi * lc + li];
            if id < 0 || id as usize >= v {
                bail!("token id {id} out of vocab {v}");
            }
            let drow = &dxd[(bi * lc + li) * h..(bi * lc + li + 1) * h];
            let trow = &mut dtok[id as usize * h..(id as usize + 1) * h];
            for c in 0..h {
                trow[c] += drow[c];
            }
            let prow = &mut dpos[li * h..(li + 1) * h];
            for c in 0..h {
                prow[c] += drow[c];
            }
        }
    }
    let _ = pos;
    Ok((Tensor::from_f32(&[v, h], dtok)?, Tensor::from_f32(&[lc, h], dpos)?))
}

#[allow(clippy::too_many_arguments)]
fn k_mlm_loss(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    labels: &Tensor,
    mask: &Tensor,
    norm: f32,
) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
    let (m, h) = (x.shape[0], x.shape[1]);
    let v = w.shape[0];
    let xd = x.f32s()?;
    let wd = w.f32s()?;
    let bd = b.f32s()?;
    let ld = labels.i32s()?;
    let md = mask.f32s()?;
    let mut loss = 0.0f32;
    let mut dx = vec![0.0f32; m * h];
    let mut dw = vec![0.0f32; v * h];
    let mut db = vec![0.0f32; v];
    let mut logits = vec![0.0f32; v];
    for r in 0..m {
        let mk = md[r];
        if mk == 0.0 {
            continue; // per_tok is masked out: zero loss AND zero grads
        }
        let lab = ld[r];
        if lab < 0 || lab as usize >= v {
            bail!("label {lab} out of vocab {v}");
        }
        let lab = lab as usize;
        let xr = &xd[r * h..(r + 1) * h];
        for j in 0..v {
            let wr = &wd[j * h..(j + 1) * h];
            let mut s = bd[j];
            for (&a, &b) in xr.iter().zip(wr) {
                s += a * b;
            }
            logits[j] = s;
        }
        let mx = logits.iter().fold(f32::NEG_INFINITY, |acc, &v| acc.max(v));
        let sum: f32 = logits.iter().map(|&l| (l - mx).exp()).sum();
        let lse = mx + sum.ln();
        loss += (lse - logits[lab]) * mk;
        let coef = mk / norm;
        let dxr = &mut dx[r * h..(r + 1) * h];
        for j in 0..v {
            let mut g = (logits[j] - lse).exp() * coef; // softmax * coef
            if j == lab {
                g -= coef;
            }
            db[j] += g;
            let wr = &wd[j * h..(j + 1) * h];
            let dwr = &mut dw[j * h..(j + 1) * h];
            for c in 0..h {
                dxr[c] += g * wr[c];
                dwr[c] += g * xr[c];
            }
        }
    }
    loss /= norm;
    Ok((
        Tensor::scalar(loss),
        Tensor::from_f32(&[m, h], dx)?,
        Tensor::from_f32(&[v, h], dw)?,
        Tensor::from_f32(&[v], db)?,
    ))
}

fn k_sop_loss(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    labels: &Tensor,
    batch: usize,
    norm: f32,
) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
    let (m, h) = (x.shape[0], x.shape[1]);
    let lc = m / batch;
    let xd = x.f32s()?;
    let wd = w.f32s()?;
    let bd = b.f32s()?;
    let ld = labels.i32s()?;
    let mut loss = 0.0f32;
    let mut dx = vec![0.0f32; m * h];
    let mut dw = vec![0.0f32; 2 * h];
    let mut db = vec![0.0f32; 2];
    for bi in 0..batch {
        let lab = ld[bi];
        if !(0..2).contains(&lab) {
            bail!("SOP label {lab} not in {{0, 1}}");
        }
        let lab = lab as usize;
        let cls = &xd[bi * lc * h..(bi * lc + 1) * h];
        let mut logits = [bd[0], bd[1]];
        for j in 0..2 {
            let wr = &wd[j * h..(j + 1) * h];
            for (&a, &b) in cls.iter().zip(wr) {
                logits[j] += a * b;
            }
        }
        let mx = logits[0].max(logits[1]);
        let sum = (logits[0] - mx).exp() + (logits[1] - mx).exp();
        let lse = mx + sum.ln();
        loss += lse - logits[lab];
        let dxr = &mut dx[bi * lc * h..(bi * lc + 1) * h];
        for j in 0..2 {
            let mut g = (logits[j] - lse).exp() / norm;
            if j == lab {
                g -= 1.0 / norm;
            }
            db[j] += g;
            let wr = &wd[j * h..(j + 1) * h];
            let dwr = &mut dw[j * h..(j + 1) * h];
            for c in 0..h {
                dxr[c] += g * wr[c];
                dwr[c] += g * cls[c];
            }
        }
    }
    loss /= norm;
    Ok((
        Tensor::scalar(loss),
        Tensor::from_f32(&[m, h], dx)?,
        Tensor::from_f32(&[2, h], dw)?,
        Tensor::from_f32(&[2], db)?,
    ))
}

fn k_linformer_proj(e: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (kp, lc) = (e.shape[0], e.shape[1]);
    let (b, z, _lx, a) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ed = e.f32s()?;
    let xd = x.f32s()?;
    let mut out = vec![0.0f32; b * z * kp * a];
    for g in 0..b * z {
        let c = mm_nn(ed, &xd[g * lc * a..(g + 1) * lc * a], kp, lc, a);
        out[g * kp * a..(g + 1) * kp * a].copy_from_slice(&c);
    }
    Tensor::from_f32(&[b, z, kp, a], out)
}

/// Backward of [`k_linformer_proj`] (`y_g = E @ x_g` per B*Z group):
/// `dx_g = E^T @ dy_g`, `dE = Σ_g dy_g @ x_g^T` (the projection is shared
/// across batch and heads, so its gradient sums over the groups).
fn k_linformer_proj_bwd(e: &Tensor, x: &Tensor, dy: &Tensor) -> Result<(Tensor, Tensor)> {
    let (kp, lc) = (e.shape[0], e.shape[1]);
    let (b, z, _lx, a) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ed = e.f32s()?;
    let xd = x.f32s()?;
    let dyd = dy.f32s()?;
    let mut dx = vec![0.0f32; b * z * lc * a];
    let mut de = vec![0.0f32; kp * lc];
    for g in 0..b * z {
        let dy_g = &dyd[g * kp * a..(g + 1) * kp * a];
        let c = mm_tn(ed, dy_g, kp, lc, a);
        dx[g * lc * a..(g + 1) * lc * a].copy_from_slice(&c);
        let x_g = &xd[g * lc * a..(g + 1) * lc * a];
        let d = mm_nt(dy_g, x_g, kp, a, lc);
        for (o, v) in de.iter_mut().zip(d) {
            *o += v;
        }
    }
    Ok((
        Tensor::from_f32(&x.shape, dx)?,
        Tensor::from_f32(&[kp, lc], de)?,
    ))
}

// --------------------------------------------------------------- dispatch

fn dispatch(kernel: Kernel, ins: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(match kernel {
        Kernel::EmbedFwd => vec![k_embed_fwd(ins[0], ins[1], ins[2])?],
        Kernel::EmbedBwd => {
            let (dtok, dpos) = k_embed_bwd(ins[0], ins[1], ins[2], ins[3])?;
            vec![dtok, dpos]
        }
        Kernel::LnFwd => vec![k_ln_fwd(ins[0], ins[1], ins[2])?],
        Kernel::LnBwd => {
            let (dx, dg, db) = k_ln_bwd(ins[0], ins[1], ins[3])?;
            vec![dx, dg, db]
        }
        Kernel::LinearFwd => vec![k_linear_fwd(ins[0], ins[1], ins[2])?],
        Kernel::LinearBwd => {
            let (dx, dw, db) = k_linear_bwd(ins[0], ins[1], ins[3])?;
            vec![dx, dw, db]
        }
        Kernel::GeluLinearFwd => vec![k_gelu_linear_fwd(ins[0], ins[1], ins[2])?],
        Kernel::GeluLinearBwd => {
            let (dx, dw, db) = k_gelu_linear_bwd(ins[0], ins[1], ins[2], ins[3])?;
            vec![dx, dw, db]
        }
        Kernel::Add => {
            let a = ins[0].f32s()?;
            let b = ins[1].f32s()?;
            let out: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| x + y).collect();
            vec![Tensor::from_f32(&ins[0].shape, out)?]
        }
        Kernel::BiasAdd => {
            let (m, n) = (ins[0].shape[0], ins[0].shape[1]);
            let y = ins[0].f32s()?;
            let b = ins[1].f32s()?;
            let mut out = y.to_vec();
            for r in 0..m {
                for c in 0..n {
                    out[r * n + c] += b[c];
                }
            }
            vec![Tensor::from_f32(&[m, n], out)?]
        }
        Kernel::ToHeads { b, z, a } => vec![k_to_heads(ins[0], b, z, a)?],
        Kernel::FromHeads => vec![k_from_heads(ins[0])?],
        Kernel::QkvProj { b, z, a } => {
            let q = k_to_heads(&k_linear_fwd(ins[0], ins[1], ins[2])?, b, z, a)?;
            let k = k_to_heads(&k_linear_fwd(ins[0], ins[3], ins[4])?, b, z, a)?;
            let v = k_to_heads(&k_linear_fwd(ins[0], ins[5], ins[6])?, b, z, a)?;
            vec![q, k, v]
        }
        Kernel::QkvProjBwd => {
            let (x, wq, wk, wv) = (ins[0], ins[1], ins[2], ins[3]);
            let (m, h) = (x.shape[0], x.shape[1]);
            let za = wq.shape[1];
            let mut dx = vec![0.0f32; m * h];
            let mut outs: Vec<Tensor> = Vec::with_capacity(7);
            for (w, dhead) in [(wq, ins[4]), (wk, ins[5]), (wv, ins[6])] {
                let flat = k_from_heads(dhead)?;
                let fd = flat.f32s()?;
                let dxp = mm_nt(fd, w.f32s()?, m, za, h);
                for (o, v) in dx.iter_mut().zip(dxp) {
                    *o += v;
                }
                let dw = mm_tn(x.f32s()?, fd, m, h, za);
                let db = colsum(fd, m, za);
                outs.push(Tensor::from_f32(&[h, za], dw)?);
                outs.push(Tensor::from_f32(&[za], db)?);
            }
            let mut res = vec![Tensor::from_f32(&[m, h], dx)?];
            res.extend(outs);
            res
        }
        Kernel::AddLnFwd => {
            let a = ins[0].f32s()?;
            let r = ins[1].f32s()?;
            let pre: Vec<f32> = a.iter().zip(r).map(|(&x, &y)| x + y).collect();
            let pre = Tensor::from_f32(&ins[0].shape, pre)?;
            let y = k_ln_fwd(&pre, ins[2], ins[3])?;
            vec![y, pre]
        }
        Kernel::MlpFwd => {
            let hmid = k_gelu_linear_fwd(ins[0], ins[1], ins[2])?;
            vec![k_linear_fwd(&hmid, ins[3], ins[4])?]
        }
        Kernel::MlpBwd => {
            let (x, w1, b1, w2, dy) = (ins[0], ins[1], ins[2], ins[3], ins[5]);
            let hmid = k_gelu_linear_fwd(x, w1, b1)?; // rematerialized
            let (dh, dw2, db2) = k_linear_bwd(&hmid, w2, dy)?;
            let (dx, dw1, db1) = k_gelu_linear_bwd(x, w1, b1, &dh)?;
            vec![dx, dw1, db1, dw2, db2]
        }
        Kernel::ScoresStep => vec![k_scores(ins[0], ins[1])?],
        Kernel::SoftmaxFwd => vec![k_softmax_fwd(ins[0])?],
        Kernel::SoftmaxBwd => vec![k_softmax_bwd(ins[0], ins[1])?],
        Kernel::MaskedSoftmaxFwd => vec![k_masked_softmax(ins[0], ins[1])?],
        Kernel::AvStep => vec![k_av(ins[0], ins[1], ins[2])?],
        Kernel::AttnDpStep => vec![k_attn_dp(ins[0], ins[1])?],
        Kernel::AttnDqStep => vec![k_attn_dq(ins[0], ins[1], ins[2])?],
        Kernel::AttnDkStep => vec![k_attn_dk(ins[0], ins[1], ins[2])?],
        Kernel::AttnDvStep => vec![k_attn_dv(ins[0], ins[1], ins[2])?],
        Kernel::LinformerProj => vec![k_linformer_proj(ins[0], ins[1])?],
        Kernel::LinformerProjBwd => {
            let (dx, de) = k_linformer_proj_bwd(ins[0], ins[1], ins[2])?;
            vec![dx, de]
        }
        Kernel::MlmLoss { norm } => {
            let (lo, dx, dw, db) = k_mlm_loss(ins[0], ins[1], ins[2], ins[3], ins[4], norm)?;
            vec![lo, dx, dw, db]
        }
        Kernel::SopLoss { batch, norm } => {
            let (lo, dx, dw, db) = k_sop_loss(ins[0], ins[1], ins[2], ins[3], batch, norm)?;
            vec![lo, dx, dw, db]
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(shape: &[usize], rng: &mut Rng) -> Tensor {
        Tensor::randn(shape, 1.0, rng)
    }

    /// Central finite difference of a scalar-valued function of one input
    /// tensor, compared against an analytic gradient.
    fn check_grad<F>(x: &Tensor, analytic: &Tensor, f: F, tol: f32)
    where
        F: Fn(&Tensor) -> f32,
    {
        let eps = 1e-2f32;
        let n = x.numel();
        // probe a handful of coordinates, not all (speed)
        let stride = (n / 17).max(1);
        for i in (0..n).step_by(stride) {
            let mut xp = x.clone();
            xp.f32s_mut().unwrap()[i] += eps;
            let mut xm = x.clone();
            xm.f32s_mut().unwrap()[i] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            let an = analytic.f32s().unwrap()[i];
            assert!(
                (fd - an).abs() < tol * (1.0 + an.abs()),
                "coord {i}: finite-diff {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn linear_bwd_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let x = randn(&[3, 4], &mut rng);
        let w = randn(&[4, 5], &mut rng);
        let b = randn(&[5], &mut rng);
        let dy = randn(&[3, 5], &mut rng);
        let (dx, dw, db) = k_linear_bwd(&x, &w, &dy).unwrap();
        // scalar objective: sum(linear(x, w, b) * dy)
        let obj_x = |t: &Tensor| {
            let y = k_linear_fwd(t, &w, &b).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&x, &dx, obj_x, 1e-2);
        let obj_w = |t: &Tensor| {
            let y = k_linear_fwd(&x, t, &b).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&w, &dw, obj_w, 1e-2);
        let obj_b = |t: &Tensor| {
            let y = k_linear_fwd(&x, &w, t).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&b, &db, obj_b, 1e-2);
    }

    #[test]
    fn ln_bwd_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let x = randn(&[4, 8], &mut rng);
        let g = randn(&[8], &mut rng);
        let be = randn(&[8], &mut rng);
        let dy = randn(&[4, 8], &mut rng);
        let (dx, dgamma, dbeta) = k_ln_bwd(&x, &g, &dy).unwrap();
        let obj = |t: &Tensor| -> f32 {
            let y = k_ln_fwd(t, &g, &be).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &gg)| a * gg).sum()
        };
        check_grad(&x, &dx, obj, 2e-2);
        let obj_g = |t: &Tensor| -> f32 {
            let y = k_ln_fwd(&x, t, &be).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &gg)| a * gg).sum()
        };
        check_grad(&g, &dgamma, obj_g, 2e-2);
        let obj_b = |t: &Tensor| -> f32 {
            let y = k_ln_fwd(&x, &g, t).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &gg)| a * gg).sum()
        };
        check_grad(&be, &dbeta, obj_b, 2e-2);
    }

    #[test]
    fn mlp_bwd_matches_finite_difference() {
        let mut rng = Rng::new(13);
        let x = randn(&[3, 4], &mut rng);
        let w1 = randn(&[4, 6], &mut rng);
        let b1 = randn(&[6], &mut rng);
        let w2 = randn(&[6, 4], &mut rng);
        let b2 = randn(&[4], &mut rng);
        let dy = randn(&[3, 4], &mut rng);
        let outs = dispatch(Kernel::MlpBwd, &[&x, &w1, &b1, &w2, &b2, &dy]).unwrap();
        let fwd = |x: &Tensor, w1: &Tensor, b1: &Tensor, w2: &Tensor, b2: &Tensor| -> f32 {
            let h = k_gelu_linear_fwd(x, w1, b1).unwrap();
            let y = k_linear_fwd(&h, w2, b2).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&x, &outs[0], |t| fwd(t, &w1, &b1, &w2, &b2), 2e-2);
        check_grad(&w1, &outs[1], |t| fwd(&x, t, &b1, &w2, &b2), 2e-2);
        check_grad(&b1, &outs[2], |t| fwd(&x, &w1, t, &w2, &b2), 2e-2);
        check_grad(&w2, &outs[3], |t| fwd(&x, &w1, &b1, t, &b2), 2e-2);
        check_grad(&b2, &outs[4], |t| fwd(&x, &w1, &b1, &w2, t), 2e-2);
    }

    #[test]
    fn softmax_bwd_matches_finite_difference() {
        let mut rng = Rng::new(17);
        let s = randn(&[1, 1, 3, 5], &mut rng);
        let dp = randn(&[1, 1, 3, 5], &mut rng);
        let p = k_softmax_fwd(&s).unwrap();
        let ds = k_softmax_bwd(&p, &dp).unwrap();
        let obj = |t: &Tensor| -> f32 {
            let p = k_softmax_fwd(t).unwrap();
            p.f32s().unwrap().iter().zip(dp.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&s, &ds, obj, 2e-2);
    }

    #[test]
    fn masked_softmax_bwd_matches_finite_difference() {
        // backward of masked softmax IS softmax_bwd on the masked probs
        // (the mask is additive and takes no gradient) — check it against
        // finite differences of the masked forward, including at masked
        // coordinates where both sides must be exactly insensitive.
        let mut rng = Rng::new(31);
        let s = randn(&[1, 2, 3, 6], &mut rng);
        let dp = randn(&[1, 2, 3, 6], &mut rng);
        // block-causal-ish mask rows with a mix of open and closed slots
        let mut m = vec![crate::attn::block::NEG; 3 * 6];
        for (i, row_open) in [(0usize, 2usize), (1, 4), (2, 6)] {
            for j in 0..row_open {
                m[i * 6 + j] = 0.0;
            }
        }
        let mask = Tensor::from_f32(&[3, 6], m).unwrap();
        let p = k_masked_softmax(&s, &mask).unwrap();
        // masked entries produce exactly zero probability
        for r in 0..2 * 3 {
            let row = &p.f32s().unwrap()[r * 6..(r + 1) * 6];
            let open = [2, 4, 6][r % 3];
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v == 0.0, j >= open, "row {r} col {j}");
            }
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        let ds = k_softmax_bwd(&p, &dp).unwrap();
        let obj = |t: &Tensor| -> f32 {
            let p = k_masked_softmax(t, &mask).unwrap();
            p.f32s().unwrap().iter().zip(dp.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&s, &ds, obj, 2e-2);
    }

    #[test]
    fn linformer_proj_bwd_matches_finite_difference() {
        let mut rng = Rng::new(37);
        let (kp, lc, a) = (3usize, 5usize, 4usize);
        let e = randn(&[kp, lc], &mut rng);
        let x = randn(&[2, 1, lc, a], &mut rng);
        let dy = randn(&[2, 1, kp, a], &mut rng);
        let (dx, de) = k_linformer_proj_bwd(&e, &x, &dy).unwrap();
        let obj_x = |t: &Tensor| -> f32 {
            let y = k_linformer_proj(&e, t).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&x, &dx, obj_x, 2e-2);
        let obj_e = |t: &Tensor| -> f32 {
            let y = k_linformer_proj(t, &x).unwrap();
            y.f32s().unwrap().iter().zip(dy.f32s().unwrap()).map(|(&a, &g)| a * g).sum()
        };
        check_grad(&e, &de, obj_e, 2e-2);
    }

    #[test]
    fn mlm_loss_grads_match_finite_difference() {
        let mut rng = Rng::new(19);
        let (m, h, v) = (4usize, 6usize, 9usize);
        let x = randn(&[m, h], &mut rng);
        let w = randn(&[v, h], &mut rng);
        let b = randn(&[v], &mut rng);
        let labels = Tensor::from_i32(&[m], vec![1, 4, 0, 7]).unwrap();
        let mask = Tensor::from_f32(&[m], vec![1.0, 0.0, 1.0, 1.0]).unwrap();
        let norm = 8.0f32;
        let (_, dx, dw, db) = k_mlm_loss(&x, &w, &b, &labels, &mask, norm).unwrap();
        let loss_of = |x: &Tensor, w: &Tensor, b: &Tensor| -> f32 {
            k_mlm_loss(x, w, b, &labels, &mask, norm).unwrap().0.scalar_f32().unwrap()
        };
        check_grad(&x, &dx, |t| loss_of(t, &w, &b), 2e-2);
        check_grad(&w, &dw, |t| loss_of(&x, t, &b), 2e-2);
        check_grad(&b, &db, |t| loss_of(&x, &w, t), 2e-2);
    }

    #[test]
    fn sop_loss_grads_match_finite_difference() {
        let mut rng = Rng::new(23);
        let (batch, lc, h) = (2usize, 3usize, 5usize);
        let x = randn(&[batch * lc, h], &mut rng);
        let w = randn(&[2, h], &mut rng);
        let b = randn(&[2], &mut rng);
        let labels = Tensor::from_i32(&[batch], vec![1, 0]).unwrap();
        let (_, dx, dw, db) = k_sop_loss(&x, &w, &b, &labels, batch, batch as f32).unwrap();
        let loss_of = |x: &Tensor, w: &Tensor, b: &Tensor| -> f32 {
            k_sop_loss(x, w, b, &labels, batch, batch as f32).unwrap().0.scalar_f32().unwrap()
        };
        check_grad(&x, &dx, |t| loss_of(t, &w, &b), 2e-2);
        check_grad(&w, &dw, |t| loss_of(&x, t, &b), 2e-2);
        check_grad(&b, &db, |t| loss_of(&x, &w, t), 2e-2);
    }

    #[test]
    fn to_heads_from_heads_roundtrip() {
        let mut rng = Rng::new(29);
        let (b, z, lc, a) = (2usize, 3usize, 4usize, 5usize);
        let x = randn(&[b * lc, z * a], &mut rng);
        let heads = k_to_heads(&x, b, z, a).unwrap();
        assert_eq!(heads.shape, vec![b, z, lc, a]);
        let back = k_from_heads(&heads).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn backend_registers_and_validates() {
        let be = NativeBackend::new(NativeConfig::tiny()).unwrap();
        assert!(!be.manifest().artifacts.is_empty());
        assert!(be.call("nonexistent__1x1", &[]).unwrap_err().to_string().contains("not in manifest"));
        // wrong arity on a real artifact
        let (name, _) = be.manifest().artifacts.iter().next().unwrap();
        let name = name.clone();
        let err = be.call(&name, &[]).unwrap_err().to_string();
        assert!(err.contains("inputs"), "{err}");
    }

    #[test]
    fn embed_roundtrip_grads() {
        // dtok scatters exactly the rows of dx; dpos sums over batch
        let ids = Tensor::from_i32(&[2, 2], vec![1, 0, 1, 2]).unwrap();
        let tok = Tensor::zeros(&[3, 2]);
        let pos = Tensor::zeros(&[2, 2]);
        let dx = Tensor::from_f32(&[4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let (dtok, dpos) = k_embed_bwd(&ids, &tok, &pos, &dx).unwrap();
        // id 1 appears at rows 0 and 2 of dx
        assert_eq!(dtok.f32s().unwrap(), &[2.0, 3.0, 4.0 + 0.0, 5.0 + 1.0, 6.0, 7.0]);
        // position 0 rows: 0 and 2; position 1 rows: 1 and 3
        assert_eq!(dpos.f32s().unwrap(), &[0.0 + 4.0, 1.0 + 5.0, 2.0 + 6.0, 3.0 + 7.0]);
    }
}
