//! Execution backends implementing [`crate::runtime::Executor`].
//!
//! * [`native`] — pure-rust f32 kernels + a synthetic in-memory manifest;
//!   the default: engines, tests and benches run with zero artifacts.
//! * [`xla_pjrt`] (feature `backend-xla`) — the original PJRT path: loads
//!   `artifacts/*.hlo.txt` lowered by `python/compile/aot.py` and executes
//!   them on the PJRT CPU client.
//!
//! Both backends validate every call against the same [`Manifest`]
//! shape contract, so an engine that runs on one runs on the other.
//!
//! [`Manifest`]: crate::runtime::Manifest

pub mod native;

#[cfg(feature = "backend-xla")]
pub mod xla_pjrt;
