//! PJRT executor: load the AOT artifacts and execute them (feature
//! `backend-xla`).
//!
//! `aot.py` lowers every L2 step function to HLO **text** (xla_extension
//! 0.5.1 rejects jax>=0.5 serialized protos — 64-bit instruction ids; the
//! text parser reassigns ids) and writes `manifest.json` describing each
//! artifact's input/output shapes.  This module:
//!
//! * parses the manifest ([`Manifest`]),
//! * compiles artifacts on the PJRT CPU client **lazily** and caches the
//!   loaded executables (one compile per artifact per process, ever),
//! * converts between host [`Tensor`]s and `xla::Literal`s,
//! * validates every call against the manifest shapes — a shape mismatch
//!   is an orchestration bug and fails loudly with the artifact name.
//!
//! The default build links an offline stub of the `xla` crate (see
//! `rust/xla-stub/`); point the `xla` dependency at a real xla-rs checkout
//! to execute HLO for real.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{validate_inputs, IoSpec, Manifest, RuntimeStats};
use crate::tensor::{DType, TData, Tensor};

pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl XlaRuntime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(XlaRuntime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    /// Number of distinct executables compiled so far.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest — re-run `make artifacts` with matching config"))?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_nanos += t0.elapsed().as_nanos() as u64;
        }
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` on `inputs`; returns the output tuple.
    pub fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        validate_inputs(name, &spec, inputs)?;
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| to_literal(t))
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.calls += 1;
            st.exec_nanos += t0.elapsed().as_nanos() as u64;
        }
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: artifact returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| from_literal(&lit, io))
            .collect()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    // Single-copy path: build the literal directly at its final shape
    // (§Perf iteration 1 — the vec1+reshape route copied twice and cost
    // ~8% of step time at bert-tiny; see EXPERIMENTS.md §Perf).
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        TData::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
        TData::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        .map_err(|e| anyhow!("literal for shape {:?}: {e}", t.shape))
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    // safe: f32 has no padding/invalid bit patterns as bytes
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn from_literal(lit: &xla::Literal, io: &IoSpec) -> Result<Tensor> {
    match io.dtype {
        DType::F32 => {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("literal -> f32 vec: {e}"))?;
            Tensor::from_f32(&io.dims, v)
        }
        DType::I32 => {
            let v = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("literal -> i32 vec: {e}"))?;
            Tensor::from_i32(&io.dims, v)
        }
    }
}
