//! Blockwise-sparse Ring Self-Attention with comm-skipping.
//!
//! The mask is defined at TOKEN level — position `i` attends `j` iff
//! `j <= i && i - j < w` (block-causal band of `w` tokens) — so the same
//! `--attn block:W` run computes identical attention at every ring size
//! (the serial ring-of-1 reference applies the full `[L, L]` mask; a ring
//! of n applies the same mask chunk by chunk).  What IS ring-size
//! dependent is the execution plan derived from the mask:
//!
//! * **reachability** — query chunk `dst` needs key chunk `src` iff some
//!   token pair inside the pair of chunks is unmasked; unreachable pairs
//!   skip their score/context/backward kernels entirely;
//! * **hop counts** — chunk `src` only travels `h(src) = max reachable
//!   dst − src` ring hops; the skip-aware
//!   [`Collective::ring_shift_sparse`] sends nothing for dead chunks
//!   (that is the §4.3 "sparse attention removes communication" claim
//!   made executable);
//! * **gradient homing** — each consumer's dK/dV partial is delivered
//!   straight to the owner with [`Collective::reduce_chunks_home`]
//!   instead of riding an accumulator around the whole ring.
//!
//! Per layer the ring traffic is exactly
//! `4·Σ h(src) + 2·Σ (consumers(src) − 1)` chunk-sends
//! ([`BlockPlan::chunk_sends_per_layer`]) versus dense RSA's
//! `(2(n−1) + (4n−2))·n` — `rust/tests/comm_volume.rs` pins both.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::comm::Collective;
use crate::obs::mem;
use crate::parallel::call1_on;
use crate::parallel::sequence::StepShape;
use crate::runtime::Executor;
use crate::tensor::{ops, Tensor};

use super::AttnStash;

/// Additive mask value for forbidden positions: finite (no NaN if a whole
/// row were masked) but large enough that `exp(s + NEG - max)` underflows
/// to exactly 0.0 for any realistic score.
pub const NEG: f32 = -1.0e30;

/// Static execution plan for one (n, Lc, w) blockwise run — reachability,
/// hop counts, per-rank masks.  Shared by every rank (the schedule is
/// global knowledge, which is what lets the threaded ranks agree on which
/// hops carry no message).
#[derive(Debug)]
pub struct BlockPlan {
    pub n: usize,
    pub lc: usize,
    pub w: usize,
    /// `reach[dst][src]`: does query chunk dst need key chunk src?
    reach: Vec<Vec<bool>>,
    /// `hops[src]` = max reachable dst − src (how far the chunk travels).
    pub hops: Vec<usize>,
    /// `consumers[src]`: ranks with `reach[dst][src]`, ascending.
    pub consumers: Vec<Vec<usize>>,
    /// `srcs[dst]`: reachable key chunks, ascending (the concat layout).
    srcs: Vec<Vec<usize>>,
    /// `masks[dst]`: additive token mask `[Lc, width(dst)]` over the
    /// reachable concatenation.
    masks: Vec<Tensor>,
}

/// Chunk pair (dst, src) reachable iff the closest token pair is in the
/// band: min(i - j) = (dst - src - 1)·lc + 1 for src < dst.
fn chunk_reachable(dst: usize, src: usize, lc: usize, w: usize) -> bool {
    src == dst || (src < dst && (dst - src - 1) * lc + 1 <= w - 1)
}

impl BlockPlan {
    pub fn new(n: usize, lc: usize, w: usize) -> BlockPlan {
        assert!(n >= 1 && lc >= 1 && w >= 1, "BlockPlan needs n, lc, w >= 1");
        let reachable = |dst: usize, src: usize| chunk_reachable(dst, src, lc, w);
        let reach: Vec<Vec<bool>> =
            (0..n).map(|dst| (0..n).map(|src| reachable(dst, src)).collect()).collect();
        let hops: Vec<usize> = (0..n)
            .map(|src| (src..n).filter(|&dst| reach[dst][src]).map(|dst| dst - src).max().unwrap_or(0))
            .collect();
        let consumers: Vec<Vec<usize>> = (0..n)
            .map(|src| (0..n).filter(|&dst| reach[dst][src]).collect())
            .collect();
        let srcs: Vec<Vec<usize>> = (0..n)
            .map(|dst| (0..n).filter(|&src| reach[dst][src]).collect())
            .collect();
        let masks = (0..n)
            .map(|dst| {
                let width = srcs[dst].len() * lc;
                let mut m = vec![NEG; lc * width];
                for il in 0..lc {
                    let i = dst * lc + il;
                    for (idx, &src) in srcs[dst].iter().enumerate() {
                        for jl in 0..lc {
                            let j = src * lc + jl;
                            if j <= i && i - j < w {
                                m[il * width + idx * lc + jl] = 0.0;
                            }
                        }
                    }
                }
                Tensor::from_f32(&[lc, width], m).expect("mask shape")
            })
            .collect();
        BlockPlan { n, lc, w, reach, hops, consumers, srcs, masks }
    }

    pub fn reach(&self, dst: usize, src: usize) -> bool {
        self.reach[dst][src]
    }

    /// Reachable concat width for rank `dst` (columns of its score rows).
    pub fn width(&self, dst: usize) -> usize {
        self.srcs[dst].len() * self.lc
    }

    /// All distinct score widths across ranks (kernel registration).
    pub fn distinct_widths(&self) -> BTreeSet<usize> {
        (0..self.n).map(|d| self.width(d)).collect()
    }

    /// [`BlockPlan::distinct_widths`] from the reachability rule alone —
    /// for kernel registration, which only needs the widths and should
    /// not materialize the O(L·width) mask tensors a full plan carries.
    pub fn distinct_widths_for(n: usize, lc: usize, w: usize) -> BTreeSet<usize> {
        assert!(n >= 1 && lc >= 1 && w >= 1, "distinct_widths_for needs n, lc, w >= 1");
        (0..n)
            .map(|dst| (0..n).filter(|&src| chunk_reachable(dst, src, lc, w)).count() * lc)
            .collect()
    }

    pub fn mask(&self, dst: usize) -> &Tensor {
        &self.masks[dst]
    }

    /// Column offset of key chunk `src` inside rank `dst`'s reachable
    /// concatenation (None when unreachable).
    pub fn col_offset(&self, dst: usize, src: usize) -> Option<usize> {
        self.srcs[dst].iter().position(|&s| s == src).map(|idx| idx * self.lc)
    }

    /// Liveness vector for the shift after ring step `t`, indexed by the
    /// HOLDING rank: rank d currently holds chunk (d − t) mod n, which is
    /// transmitted onward iff it has a consumer more than t hops from
    /// home.
    pub fn live_at(&self, t: usize) -> Vec<bool> {
        (0..self.n).map(|d| t < self.hops[(d + self.n - t) % self.n]).collect()
    }

    /// Ring steps the schedule actually needs: every reachable (dst, src)
    /// pair sits at ring distance `dst − src ≤ h(src) ≤ max hops`, so no
    /// compute happens past step `max hops` and no chunk is live past the
    /// shift before it — the loops stop there instead of sweeping all `n`
    /// dead iterations (bit-identical results, same sends).
    pub fn steps(&self) -> usize {
        self.n.min(self.hops.iter().copied().max().unwrap_or(0) + 1)
    }

    /// Ring chunk-sends per layer under the skip-aware schedule:
    /// `4·Σ h(src)` data hops (K and V travel their reachable span in
    /// forward AND backward) plus `2·Σ (|consumers(src)| − 1)` direct
    /// dK/dV gradient deliveries.  Dense RSA's counterpart is
    /// `(2(n−1) + (4n−2))·n` (rust/tests/comm_volume.rs checks both).
    pub fn chunk_sends_per_layer(&self) -> u64 {
        let h: u64 = self.hops.iter().map(|&x| x as u64).sum();
        let deliveries: u64 =
            self.consumers.iter().map(|c| (c.len() as u64).saturating_sub(1)).sum();
        4 * h + 2 * deliveries
    }
}

fn plan_of(sh: &StepShape) -> Result<&BlockPlan> {
    sh.plan
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("block attention needs a BlockPlan in the step shape"))
}

/// Blockwise forward: ring-QK^T and ring-AV over live hops only, masked
/// softmax over the reachable concatenation.
#[allow(clippy::needless_range_loop)]
pub(crate) fn forward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, AttnStash)> {
    let plan = plan_of(sh)?;
    let n = sh.n;
    let ranks = view.local_ranks();
    let ln = ranks.len();
    if q.len() != ln || k.len() != ln || v.len() != ln {
        bail!("block forward: need {ln} local chunks, got {}/{}/{}", q.len(), k.len(), v.len());
    }
    // ---- stage 1: ring-QK^T over reachable pairs --------------------
    let steps = plan.steps();
    let mut parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    let mut k_slots: Vec<Tensor> = k.to_vec();
    // Ring-buffer residency is reported only (no closed-form contract —
    // occupancy depends on which hops are live, so `sp_expect` leaves
    // `ring_buf` unvalidated for the block pattern).
    let k_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, k_slots[li].bytes() as u64))
        .collect();
    for t in 0..steps {
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            if plan.reach(d, src) {
                parts[li][src] = Some(call1_on(ex, "scores_step", &[&q[li], &k_slots[li]])?);
            }
        }
        if t + 1 < steps {
            view.ring_shift_sparse(&mut k_slots, &plan.live_at(t))?;
        }
    }
    // masked softmax over the reachable concatenation (ascending src)
    let mut p = Vec::with_capacity(ln);
    for li in 0..ln {
        let owned: Vec<Tensor> = parts[li].iter_mut().filter_map(|o| o.take()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        let s = ops::concat_last(&refs)?;
        p.push(call1_on(ex, "masked_softmax_fwd", &[&s, plan.mask(ranks[li])])?);
    }
    drop(k_charges); // K slots retire before the V rotation begins
    // ---- stage 2: ring-AV over the same live hops -------------------
    let mut v_slots: Vec<Tensor> = v.to_vec();
    let _v_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, v_slots[li].bytes() as u64))
        .collect();
    let mut acc: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    for t in 0..steps {
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            if let Some(off) = plan.col_offset(d, src) {
                let p_i = ops::slice_last(&p[li], off, off + sh.lc)?;
                acc[li] = call1_on(ex, "av_step", &[&p_i, &v_slots[li], &acc[li]])?;
            }
        }
        if t + 1 < steps {
            view.ring_shift_sparse(&mut v_slots, &plan.live_at(t))?;
        }
    }
    Ok((acc, AttnStash::Block { p }))
}

/// Blockwise backward: the V and K data re-circulate over live hops only;
/// each consumer's dV/dK partial is delivered straight home instead of
/// riding an accumulator the full ring.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
pub(crate) fn backward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    d_ctx: &[Tensor],
    q: &[Tensor],
    p: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
    let plan = plan_of(sh)?;
    let n = sh.n;
    let ranks = view.local_ranks();
    let ln = ranks.len();
    // ---- ring pass of V: dP parts + per-consumer dV partials --------
    let steps = plan.steps();
    let mut v_slots: Vec<Tensor> = v.to_vec();
    // reported-only residency: one visiting V chunk per rank (the dV
    // partials go straight home rather than riding an accumulator)
    let vpass_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, v_slots[li].bytes() as u64))
        .collect();
    let mut dp_parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    let mut dv_parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    for t in 0..steps {
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            if let Some(off) = plan.col_offset(d, src) {
                dp_parts[li][src] =
                    Some(call1_on(ex, "attn_dp_step", &[&d_ctx[li], &v_slots[li]])?);
                let p_i = ops::slice_last(&p[li], off, off + sh.lc)?;
                let zero = Tensor::zeros(&v[li].shape);
                dv_parts[li][src] =
                    Some(call1_on(ex, "attn_dv_step", &[&p_i, &d_ctx[li], &zero])?);
            }
        }
        if t + 1 < steps {
            view.ring_shift_sparse(&mut v_slots, &plan.live_at(t))?;
        }
    }
    let dv = view.reduce_chunks_home(dv_parts, &plan.consumers)?;
    drop(vpass_charges);
    // ---- local softmax backward over the reachable columns ----------
    let mut ds = Vec::with_capacity(ln);
    for li in 0..ln {
        let owned: Vec<Tensor> = dp_parts[li].iter_mut().filter_map(|o| o.take()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        let dp = ops::concat_last(&refs)?;
        ds.push(call1_on(ex, "softmax_bwd", &[&p[li], &dp])?);
    }
    // ---- ring pass of K: dQ accumulation + per-consumer dK partials -
    let mut k_slots: Vec<Tensor> = k.to_vec();
    let _kpass_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, k_slots[li].bytes() as u64))
        .collect();
    let mut dk_parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    let mut dq: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    for t in 0..steps {
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            if let Some(off) = plan.col_offset(d, src) {
                let ds_i = ops::slice_last(&ds[li], off, off + sh.lc)?;
                dq[li] = call1_on(ex, "attn_dq_step", &[&ds_i, &k_slots[li], &dq[li]])?;
                let zero = Tensor::zeros(&k[li].shape);
                dk_parts[li][src] =
                    Some(call1_on(ex, "attn_dk_step", &[&ds_i, &q[li], &zero])?);
            }
        }
        if t + 1 < steps {
            view.ring_shift_sparse(&mut k_slots, &plan.live_at(t))?;
        }
    }
    let dk = view.reduce_chunks_home(dk_parts, &plan.consumers)?;
    Ok((dq, dk, dv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reachability_is_causal_banded() {
        // n=4, lc=8, w=8: diagonal + first subdiagonal only
        let p = BlockPlan::new(4, 8, 8);
        for dst in 0..4 {
            for src in 0..4 {
                let want = src == dst || (src + 1 == dst);
                assert_eq!(p.reach(dst, src), want, "reach({dst},{src})");
            }
        }
        assert_eq!(p.hops, vec![1, 1, 1, 0]);
        assert_eq!(p.consumers[0], vec![0, 1]);
        assert_eq!(p.consumers[3], vec![3]);
        // 4·H + 2·Σ(consumers−1) = 4·3 + 2·3
        assert_eq!(p.chunk_sends_per_layer(), 18);
    }

    #[test]
    fn wide_window_reaches_full_causal() {
        let p = BlockPlan::new(4, 8, 32);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(p.reach(dst, src), src <= dst);
            }
        }
        // full causal: H = Σ (n−1−src) = 6, deliveries = Σ dst = 6
        assert_eq!(p.chunk_sends_per_layer(), 4 * 6 + 2 * 6);
    }

    #[test]
    fn masks_allow_exactly_the_band() {
        let p = BlockPlan::new(2, 4, 3);
        // rank 1 reaches chunks {0, 1}: width 8
        let m = p.mask(1);
        assert_eq!(m.shape, vec![4, 8]);
        let md = m.f32s().unwrap();
        for il in 0..4 {
            let i = 4 + il;
            for j in 0..8 {
                let want = j <= i && i - j < 3;
                assert_eq!(md[il * 8 + j] == 0.0, want, "mask[{il},{j}]");
            }
        }
        // every row keeps its diagonal
        for il in 0..4 {
            assert_eq!(md[il * 8 + 4 + il], 0.0);
        }
    }

    #[test]
    fn liveness_follows_hop_counts() {
        let p = BlockPlan::new(4, 8, 8); // hops = [1,1,1,0]
        // before shift t=0 every chunk with hops>0 is at home and live
        assert_eq!(p.live_at(0), vec![true, true, true, false]);
        // after one hop nothing needs to travel further
        assert_eq!(p.live_at(1), vec![false, false, false, false]);
    }

    #[test]
    fn registration_widths_match_the_full_plan() {
        // the mask-free width enumeration (kernel registration) must agree
        // with the materialized plan for every shape
        for (n, lc, w) in [(4, 8, 8), (2, 4, 3), (4, 4, 16), (3, 5, 6), (1, 8, 4)] {
            assert_eq!(
                BlockPlan::distinct_widths_for(n, lc, w),
                BlockPlan::new(n, lc, w).distinct_widths(),
                "widths diverged at n={n} lc={lc} w={w}"
            );
        }
    }

    #[test]
    fn steps_stop_at_the_longest_hop() {
        // band of one subdiagonal: 2 steps (compute at t ∈ {0, 1}) no
        // matter the ring size; full causal needs all n
        assert_eq!(BlockPlan::new(4, 8, 8).steps(), 2);
        assert_eq!(BlockPlan::new(6, 4, 5).steps(), 2);
        assert_eq!(BlockPlan::new(4, 8, 32).steps(), 4);
        assert_eq!(BlockPlan::new(4, 8, 1).steps(), 1); // diagonal only
    }

    #[test]
    fn single_rank_plan_is_local_only() {
        let p = BlockPlan::new(1, 16, 5);
        assert!(p.reach(0, 0));
        assert_eq!(p.hops, vec![0]);
        assert_eq!(p.chunk_sends_per_layer(), 0);
        assert_eq!(p.width(0), 16);
    }
}
