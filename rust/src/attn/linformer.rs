//! Linformer attention under sequence parallelism (paper §4.3, Table 3).
//!
//! The shared projections `E_k`/`E_v ∈ R^{k×L}` collapse the sequence
//! axis of K and V to a fixed `k` rows.  Under sequence parallelism the
//! projection is a partial sum over devices:
//!
//! `K̃ = Σ_d  E_k[:, d·Lc:(d+1)·Lc] @ K_d`
//!
//! so each rank projects its OWN chunk with its slice of E and the
//! `[B, Z, k, A]` partials are combined **once** per layer with an
//! all-reduce (reduce-scatter + all-gather) — no ring rotation of K/V at
//! all, and the communicated volume is independent of L.  That is exactly
//! the Table 3 regime: every L-carrying term is divided by N while the
//! attention communication stops growing with L (`simulator::sparse`
//! models the same accounting analytically; `benches/sparse_seqlen.rs`
//! cross-checks the two).
//!
//! Backward mirrors it: dK̃/dṼ partials are all-reduced (each rank's
//! loss depends on the shared K̃/Ṽ), then pushed through the projection
//! locally — dK_d = E_d^T @ dK̃ and dE_d = dK̃ @ K_d^T, the E-slice
//! gradient landing in the rank's grad store like the pos_emb slice.

use anyhow::{bail, Result};

use crate::comm::Collective;
use crate::model::params::ParamStore;
use crate::parallel::{call1_on, call_on};
use crate::parallel::sequence::StepShape;
use crate::runtime::Executor;
use crate::tensor::{ops, Tensor};

use super::{AttnStash, LINFORMER_EK, LINFORMER_EV};

/// Project the view's local K-or-V chunks with the matching E slices and
/// all-reduce the partials: every executed rank ends with the full
/// projected `[B, Z, k, A]` tensor.
fn project_all(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    e_full: &Tensor,
    x: &[Tensor],
) -> Result<Vec<Tensor>> {
    let ranks = view.local_ranks();
    let mut parts = Vec::with_capacity(ranks.len());
    for (li, &d) in ranks.iter().enumerate() {
        let e_d = ops::slice_last(e_full, d * sh.lc, (d + 1) * sh.lc)?;
        parts.push(call1_on(ex, "linformer_proj", &[&e_d, &x[li]])?);
    }
    view.all_reduce_sum(&mut parts)?;
    Ok(parts)
}

/// Linformer forward for the view's ranks: project-and-reduce K̃/Ṽ, then
/// attention is purely local (`[Lc, k]` score rows, no ring).
pub(crate) fn forward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, AttnStash)> {
    let ranks = view.local_ranks();
    let ln = ranks.len();
    if q.len() != ln || k.len() != ln || v.len() != ln {
        bail!("linformer forward: need {ln} local chunks, got {}/{}/{}", q.len(), k.len(), v.len());
    }
    let kt = project_all(ex, view, sh, params.get(LINFORMER_EK)?, k)?;
    let vt = project_all(ex, view, sh, params.get(LINFORMER_EV)?, v)?;
    let mut p = Vec::with_capacity(ln);
    let mut ctx = Vec::with_capacity(ln);
    for li in 0..ln {
        let s = call1_on(ex, "scores_step", &[&q[li], &kt[li]])?;
        let pl = call1_on(ex, "softmax_fwd", &[&s])?;
        let zero = Tensor::zeros(&q[li].shape);
        ctx.push(call1_on(ex, "av_step", &[&pl, &vt[li], &zero])?);
        p.push(pl);
    }
    Ok((ctx, AttnStash::Linformer { p, kt, vt }))
}

/// Linformer backward: local attention grads, all-reduce of the shared
/// dK̃/dṼ, then the projection backward producing dK/dV for the local
/// chunk plus the E-slice gradients (accumulated into `grads`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    p: &[Tensor],
    kt: &[Tensor],
    vt: &[Tensor],
    d_ctx: &[Tensor],
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
    grads: &mut [ParamStore],
) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
    let ranks = view.local_ranks();
    let ln = ranks.len();
    if grads.len() != ln {
        bail!("linformer backward: {ln} ranks but {} grad stores", grads.len());
    }
    let mut dq = Vec::with_capacity(ln);
    let mut dkt = Vec::with_capacity(ln);
    let mut dvt = Vec::with_capacity(ln);
    for li in 0..ln {
        let dp = call1_on(ex, "attn_dp_step", &[&d_ctx[li], &vt[li]])?;
        let zero_kv = Tensor::zeros(&kt[li].shape);
        dvt.push(call1_on(ex, "attn_dv_step", &[&p[li], &d_ctx[li], &zero_kv])?);
        let ds = call1_on(ex, "softmax_bwd", &[&p[li], &dp])?;
        let zero_q = Tensor::zeros(&q[li].shape);
        dq.push(call1_on(ex, "attn_dq_step", &[&ds, &kt[li], &zero_q])?);
        let zero_kv = Tensor::zeros(&kt[li].shape);
        dkt.push(call1_on(ex, "attn_dk_step", &[&ds, &q[li], &zero_kv])?);
    }
    // the projected K̃/Ṽ are shared: total gradient is the sum of every
    // rank's contribution
    view.all_reduce_sum(&mut dkt)?;
    view.all_reduce_sum(&mut dvt)?;
    // projection backward, per rank: dX_d = E_d^T @ dX̃, dE_d = dX̃ @ X_d^T
    let ek = params.get(LINFORMER_EK)?;
    let ev = params.get(LINFORMER_EV)?;
    let mut dk = Vec::with_capacity(ln);
    let mut dv = Vec::with_capacity(ln);
    for (li, &d) in ranks.iter().enumerate() {
        let (lo, hi) = (d * sh.lc, (d + 1) * sh.lc);
        let e_d = ops::slice_last(ek, lo, hi)?;
        let out = call_on(ex, "linformer_proj_bwd", &[&e_d, &k[li], &dkt[li]])?;
        let [dkd, dek]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow::anyhow!("linformer_proj_bwd arity"))?;
        dk.push(dkd);
        ops::add_into_last(grads[li].get_mut(LINFORMER_EK)?, &dek, lo)?;
        let e_d = ops::slice_last(ev, lo, hi)?;
        let out = call_on(ex, "linformer_proj_bwd", &[&e_d, &v[li], &dvt[li]])?;
        let [dvd, dev]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow::anyhow!("linformer_proj_bwd arity"))?;
        dv.push(dvd);
        ops::add_into_last(grads[li].get_mut(LINFORMER_EV)?, &dev, lo)?;
    }
    Ok((dq, dk, dv))
}
