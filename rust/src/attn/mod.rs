//! `attn` — executable attention patterns for sequence parallelism.
//!
//! The paper's headline claim (§4.3, Table 3, Fig. 5b) is that sequence
//! parallelism composed with *sparse* attention removes the single-device
//! sequence-length ceiling.  `simulator::sparse` models that analytically;
//! this subsystem makes it executable: every pattern has a forward and a
//! hand-scheduled backward that run identically under the sequential
//! [`crate::comm::Fabric`] slot view and the threaded per-rank
//! [`crate::comm::threaded::RingComm`] (`exec::DistRunner`).
//!
//! Patterns ([`AttnPattern`], selected with `--attn` on the CLI):
//!
//! * [`dense`] — full Ring Self-Attention, the paper's §3 schedule
//!   (K and V chunks rotate the whole ring every layer);
//! * [`linformer`] — the §4.3 Linformer composition: shared `E_k`/`E_v`
//!   projections collapse the L-long K/V axis to a fixed `k`, so the ring
//!   disappears entirely — each rank projects its own chunk and the
//!   `[B, Z, k, A]` partial sums are combined **once** per layer with an
//!   all-reduce (reduce-scatter + all-gather) whose size is independent
//!   of L, exactly the Table 3 communication profile;
//! * [`block`] — token-level block-causal banded masks: per-(dst, src)
//!   chunk reachability is precomputed ([`block::BlockPlan`]), fully
//!   masked ring hops send nothing and skip their score/context kernels
//!   (the skip-aware [`crate::comm::Collective::ring_shift_sparse`]), and
//!   the dK/dV partials are delivered straight home
//!   ([`crate::comm::Collective::reduce_chunks_home`]) instead of riding
//!   the full ring.  The `Meter` records the reduced volume; the
//!   skip-aware closed form is pinned by `rust/tests/comm_volume.rs`.
//!
//! Orthogonal to the pattern, the SEQUENCE-PARALLEL STRATEGY
//! ([`crate::parallel::sequence::SpStrategy`], `--sp ring|ulysses`)
//! decides how cross-chunk attention data moves: the ring schedules
//! above, or [`ulysses`] — DeepSpeed-Ulysses-style all-to-alls that
//! re-shard q/k/v into whole-head shards so each rank runs full-sequence
//! dense attention locally (dense pattern only; `8(n−1)` chunk-sends per
//! layer vs the dense ring's `(2(n−1)+(4n−2))·n`).
//!
//! The per-rank step logic in `parallel::sequence::seqpar_step` dispatches
//! through `forward_on`/`backward_on`; `rust/tests/dist_equivalence.rs`
//! proves threaded == sequential == serial (ring of 1) for every pattern
//! and strategy.

pub mod block;
pub mod dense;
pub mod linformer;
pub mod ulysses;

use anyhow::{bail, Result};

use crate::comm::Collective;
use crate::model::params::ParamStore;
use crate::parallel::sequence::StepShape;
use crate::runtime::Executor;
use crate::tensor::Tensor;

/// Which attention pattern the sequence-parallel step executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnPattern {
    /// Full Ring Self-Attention (the paper's §3 schedule).
    Dense,
    /// Linformer: K/V projected to `k` rows by the shared E_k/E_v
    /// parameters; communication is one all-reduce per tensor per layer,
    /// independent of sequence length (§4.3, Table 3).
    Linformer { k: usize },
    /// Token-level block-causal band: position i attends j iff
    /// `j <= i && i - j < w` (window of `w` tokens).  Fully masked ring
    /// hops skip both compute and communication.
    Block { w: usize },
}

impl AttnPattern {
    /// Parse the CLI surface: `dense | linformer:K | block:W`.
    pub fn parse(s: &str) -> Result<AttnPattern> {
        if s == "dense" {
            return Ok(AttnPattern::Dense);
        }
        if let Some(k) = s.strip_prefix("linformer:") {
            let k: usize = k.parse().map_err(|_| anyhow::anyhow!("bad --attn {s:?}"))?;
            if k == 0 {
                bail!("--attn linformer:K needs K >= 1");
            }
            return Ok(AttnPattern::Linformer { k });
        }
        if let Some(w) = s.strip_prefix("block:") {
            let w: usize = w.parse().map_err(|_| anyhow::anyhow!("bad --attn {s:?}"))?;
            if w == 0 {
                bail!("--attn block:W needs W >= 1 (every token attends at least itself)");
            }
            return Ok(AttnPattern::Block { w });
        }
        bail!("unknown --attn {s:?} (dense | linformer:K | block:W)")
    }

    /// The CLI spelling of this pattern.
    pub fn label(&self) -> String {
        match self {
            AttnPattern::Dense => "dense".to_string(),
            AttnPattern::Linformer { k } => format!("linformer:{k}"),
            AttnPattern::Block { w } => format!("block:{w}"),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, AttnPattern::Dense)
    }

    /// The backend knobs this pattern needs at manifest-lowering time:
    /// `(linformer_k, block_w)` for `NativeConfig` — the single place the
    /// pattern→config mapping lives (CLI, benches and tests all route
    /// through it, so a new pattern cannot silently miss one of them).
    pub fn native_knobs(&self) -> (usize, usize) {
        match *self {
            AttnPattern::Dense => (0, 0),
            AttnPattern::Linformer { k } => (k, 0),
            AttnPattern::Block { w } => (0, w),
        }
    }
}

/// Forward activations the backward pass needs, per pattern.  One entry
/// per executed rank in every vector.
pub(crate) enum AttnStash {
    /// Softmax probs over the full rows `[B, Z, Lc, L]`.
    Dense { p: Vec<Tensor> },
    /// Probs `[B, Z, Lc, k]` plus the (replicated) projected K̃/Ṽ
    /// `[B, Z, k, A]` — kept instead of remote K/V chunks.
    Linformer { p: Vec<Tensor>, kt: Vec<Tensor>, vt: Vec<Tensor> },
    /// Probs over the reachable concatenation `[B, Z, Lc, r(d)·Lc]`.
    Block { p: Vec<Tensor> },
    /// Ulysses head shards: probs `[B, Z/n, L, L]` plus the transposed
    /// q/k/v `[B, Z/n, L, A]` — stashed so backward needs no re-exchange
    /// (the memory-for-bandwidth trade the all-to-all schedule makes).
    Ulysses { p: Vec<Tensor>, qg: Vec<Tensor>, kg: Vec<Tensor>, vg: Vec<Tensor> },
}

impl AttnStash {
    /// Total stash bytes held for the `li`-th executed rank — the
    /// pattern-dependent part of the `obs::mem` AttnStash category.
    pub(crate) fn bytes_at(&self, li: usize) -> usize {
        match self {
            AttnStash::Dense { p } | AttnStash::Block { p } => p[li].bytes(),
            AttnStash::Linformer { p, kt, vt } => {
                p[li].bytes() + kt[li].bytes() + vt[li].bytes()
            }
            AttnStash::Ulysses { p, qg, kg, vg } => {
                p[li].bytes() + qg[li].bytes() + kg[li].bytes() + vg[li].bytes()
            }
        }
    }
}

/// Attention forward for the view's ranks, dispatched on the shape's
/// pattern.  `q/k/v[li]` is the local chunk of the li-th executed rank;
/// returns the per-rank context plus the pattern's backward stash.
pub(crate) fn forward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, AttnStash)> {
    if !sh.sp.is_ring() {
        // Ulysses re-shards heads with all-to-alls; StepShape guarantees
        // the pattern is dense when this branch is taken
        return ulysses::forward_on(ex, view, sh, q, k, v);
    }
    match sh.pattern {
        AttnPattern::Dense => {
            let (ctx, p) = dense::rsa_forward_on(ex, view, sh, q, k, v)?;
            Ok((ctx, AttnStash::Dense { p }))
        }
        AttnPattern::Linformer { .. } => linformer::forward_on(ex, view, sh, params, q, k, v),
        AttnPattern::Block { .. } => block::forward_on(ex, view, sh, q, k, v),
    }
}

/// Attention backward for the view's ranks.  Returns (dq, dk, dv) per
/// executed rank with dk/dv already delivered to their home ranks;
/// pattern-owned parameter gradients (the Linformer projections) are
/// accumulated into `grads` directly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    stash: &AttnStash,
    d_ctx: &[Tensor],
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
    grads: &mut [ParamStore],
) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
    if let AttnStash::Ulysses { p, qg, kg, vg } = stash {
        if !sh.pattern.is_dense() || sh.sp.is_ring() {
            bail!("attention stash does not match pattern {:?}", sh.pattern);
        }
        return ulysses::backward_on(ex, view, sh, p, qg, kg, vg, d_ctx);
    }
    match (sh.pattern, stash) {
        (AttnPattern::Dense, AttnStash::Dense { p }) => {
            dense::rsa_backward_on(ex, view, sh, d_ctx, q, p, k, v)
        }
        (AttnPattern::Linformer { .. }, AttnStash::Linformer { p, kt, vt }) => {
            linformer::backward_on(ex, view, sh, params, p, kt, vt, d_ctx, q, k, v, grads)
        }
        (AttnPattern::Block { .. }, AttnStash::Block { p }) => {
            block::backward_on(ex, view, sh, d_ctx, q, p, k, v)
        }
        _ => bail!("attention stash does not match pattern {:?}", sh.pattern),
    }
}

/// Names of the shared Linformer projection parameters (shape `[k, L]`,
/// sliced `[k, Lc]` per device like `pos_emb`).
pub const LINFORMER_EK: &str = "linformer_ek";
pub const LINFORMER_EV: &str = "linformer_ev";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        for s in ["dense", "linformer:64", "block:128"] {
            assert_eq!(AttnPattern::parse(s).unwrap().label(), s);
        }
    }

    #[test]
    fn parse_rejects_bad_inputs() {
        for s in ["", "linformer", "linformer:", "linformer:0", "block:0", "block:x", "sparse"] {
            assert!(AttnPattern::parse(s).is_err(), "{s:?} should not parse");
        }
    }
}
