//! Ulysses-style sequence parallelism: head-shard all-to-alls instead of
//! ring rotation (DeepSpeed-Ulysses, Jacobs et al., 2023).
//!
//! Under the ring schedule every layer streams K/V chunks around the
//! whole ring.  Ulysses replaces that with a tensor transpose: one
//! [`Collective::all_to_all`] re-shards the already-projected q/k/v from
//! sequence-split `[B, Z, Lc, A]` to head-split `[B, Z/n, L, A]`, each
//! rank computes FULL-sequence dense attention for the `Z/n` heads it now
//! owns (the same `scores_step`/`softmax`/`av_step` kernels the dense
//! path uses, at head-sharded signatures), and a second all-to-all
//! restores the sequence layout for the out-projection.
//!
//! Backward is the mirror image: the incoming `d_ctx` takes the forward
//! transpose, the attention backward runs locally against the stashed
//! head-shard q/k/v (no re-communication — the gathered tensors are the
//! activation stash, which is the memory-for-bandwidth trade Ulysses
//! makes), and dq/dk/dv take the reverse transpose home.  That is 8
//! all-to-alls per layer — `8(n−1)` chunk-send equivalents in total,
//! independent of the per-hop ring length, vs the dense ring's
//! `(2(n−1) + (4n−2))·n` (closed forms pinned by
//! `rust/tests/comm_volume.rs`).
//!
//! Layout invariants:
//! * the forward exchange splits heads (dim 1) and concatenates sequence
//!   chunks in rank order (dim 2); the reverse swaps the two dims, and
//!   `all_to_all ∘ all_to_all` with swapped dims is the identity;
//! * `n` must divide the head count — whole heads move, mirroring
//!   Megatron's §4.2 tensor-parallel cap (validated at engine build).

use anyhow::{bail, Result};

use crate::comm::Collective;
use crate::parallel::call1_on;
use crate::parallel::sequence::StepShape;
use crate::runtime::Executor;
use crate::tensor::Tensor;

use super::AttnStash;

/// Head dim (1) ⇄ sequence dim (2) of the `[B, Z, Lc, A]` chunks.
const HEAD_DIM: usize = 1;
const SEQ_DIM: usize = 2;

/// All-to-all the view's local chunks into head shards:
/// `[B, Z, Lc, A]` → `[B, Z/n, L, A]`.
fn to_head_shards(view: &dyn Collective, x: &[Tensor]) -> Result<Vec<Tensor>> {
    let mut slots = x.to_vec();
    view.all_to_all(&mut slots, HEAD_DIM, SEQ_DIM)?;
    Ok(slots)
}

/// The reverse transpose: `[B, Z/n, L, A]` → `[B, Z, Lc, A]`.
fn to_seq_chunks(view: &dyn Collective, mut x: Vec<Tensor>) -> Result<Vec<Tensor>> {
    view.all_to_all(&mut x, SEQ_DIM, HEAD_DIM)?;
    Ok(x)
}

/// Ulysses forward for the view's ranks: transpose q/k/v to head shards,
/// full-sequence dense attention per shard, transpose the context back.
pub(crate) fn forward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    _sh: &StepShape,
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, AttnStash)> {
    let ln = view.local_ranks().len();
    if q.len() != ln || k.len() != ln || v.len() != ln {
        bail!("ulysses forward: need {ln} local chunks, got {}/{}/{}", q.len(), k.len(), v.len());
    }
    let qg = to_head_shards(view, q)?;
    let kg = to_head_shards(view, k)?;
    let vg = to_head_shards(view, v)?;
    let mut p = Vec::with_capacity(ln);
    let mut ctx_g = Vec::with_capacity(ln);
    for li in 0..ln {
        let s = call1_on(ex, "scores_step", &[&qg[li], &kg[li]])?;
        let pl = call1_on(ex, "softmax_fwd", &[&s])?;
        let zero = Tensor::zeros(&qg[li].shape);
        ctx_g.push(call1_on(ex, "av_step", &[&pl, &vg[li], &zero])?);
        p.push(pl);
    }
    let ctx = to_seq_chunks(view, ctx_g)?;
    Ok((ctx, AttnStash::Ulysses { p, qg, kg, vg }))
}

/// Ulysses backward: forward-transpose `d_ctx`, run the dense attention
/// backward locally against the stashed head shards, reverse-transpose
/// dq/dk/dv back to sequence chunks.  No parameter gradients — Ulysses
/// owns no parameters of its own.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    _sh: &StepShape,
    p: &[Tensor],
    qg: &[Tensor],
    kg: &[Tensor],
    vg: &[Tensor],
    d_ctx: &[Tensor],
) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
    let ln = view.local_ranks().len();
    if d_ctx.len() != ln {
        bail!("ulysses backward: need {ln} d_ctx chunks, got {}", d_ctx.len());
    }
    let dg = to_head_shards(view, d_ctx)?;
    let mut dqg = Vec::with_capacity(ln);
    let mut dkg = Vec::with_capacity(ln);
    let mut dvg = Vec::with_capacity(ln);
    for li in 0..ln {
        let dp = call1_on(ex, "attn_dp_step", &[&dg[li], &vg[li]])?;
        let zero_v = Tensor::zeros(&vg[li].shape);
        dvg.push(call1_on(ex, "attn_dv_step", &[&p[li], &dg[li], &zero_v])?);
        let ds = call1_on(ex, "softmax_bwd", &[&p[li], &dp])?;
        let zero_q = Tensor::zeros(&qg[li].shape);
        dqg.push(call1_on(ex, "attn_dq_step", &[&ds, &kg[li], &zero_q])?);
        let zero_k = Tensor::zeros(&kg[li].shape);
        dkg.push(call1_on(ex, "attn_dk_step", &[&ds, &qg[li], &zero_k])?);
    }
    let dq = to_seq_chunks(view, dqg)?;
    let dk = to_seq_chunks(view, dkg)?;
    let dv = to_seq_chunks(view, dvg)?;
    Ok((dq, dk, dv))
}
