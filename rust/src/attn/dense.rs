//! Dense Ring Self-Attention — the paper's §3 schedule.
//!
//! * forward stage 1 — key chunks rotate around the ring N-1 times; each
//!   device accumulates its score rows `S^n ∈ R^{Lc×L}`;
//! * forward stage 2 — value chunks rotate; `O^n = Σᵢ SᵢⁿVᵢ` (Eq. 4);
//! * backward — value chunks rotate again (computing `dPᵢ` and carrying
//!   the `dVᵢ` accumulators home), then key chunks rotate (computing `dQ`
//!   and carrying `dKᵢ` home).  This is the "2 ring-P2P + gradient
//!   accumulation" schedule of §3.2.2.
//!
//! Ring convention: after `t` shifts device `d` holds the chunk originally
//! owned by `(d - t) mod n`.
//!
//! With `StepShape::overlap` on, every DATA ring (K and V, forward and
//! backward) double-buffers: the shift of chunk t+1 is posted before the
//! compute on chunk t and awaited after, so the hop hides behind the
//! kernels on the threaded runner.  The dV/dK accumulator rings stay
//! blocking — their payload is produced by the very compute the data
//! shift hides behind.  Bytes, trace events and results are identical to
//! the blocking schedule (rust/tests/dist_equivalence.rs pins all three).

use anyhow::{bail, Result};

use crate::comm::{Collective, ShiftHandle};
use crate::obs::mem;
use crate::parallel::call1_on;
use crate::parallel::sequence::StepShape;
use crate::runtime::Executor;
use crate::tensor::{ops, Tensor};

/// A data-ring shift in flight: the completion handle plus the ring-buffer
/// residency of the chunk being received while the owner computes (the
/// double buffer's second slot — `simulator::memory::sp_expect` grows its
/// ring_buf closed form by exactly this chunk when overlap is on).
struct PendingShift {
    handle: ShiftHandle,
    _inflight: Vec<mem::Charge>,
}

/// Post the send/recv of the currently-held `slots` BEFORE the caller
/// computes on them (`Collective::ring_shift_post`).  Eager on the
/// sequential [`Fabric`] view, a real nonblocking isend on the threaded
/// per-rank view — identical bytes and trace either way.
fn post_shift(
    view: &dyn Collective,
    ranks: &[usize],
    slots: &[Tensor],
) -> Result<PendingShift> {
    let handle = view.ring_shift_post(slots)?;
    let _inflight = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, slots[li].bytes() as u64))
        .collect();
    Ok(PendingShift { handle, _inflight })
}

/// RSA stages 1+2 for the view's ranks.  `q/k/v[li]` is the local chunk of
/// the li-th executed rank.  Returns (ctx, p) per executed rank.
#[allow(clippy::needless_range_loop)] // loops index several rank-parallel vecs
pub(crate) fn rsa_forward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let n = sh.n;
    let ranks = view.local_ranks();
    let ln = ranks.len();
    if q.len() != ln || k.len() != ln || v.len() != ln {
        bail!("rsa_forward: need {ln} local chunks, got {}/{}/{}", q.len(), k.len(), v.len());
    }
    // ---- stage 1: Ring-QK^T --------------------------------------
    // score parts indexed by ORIGIN chunk so concat restores global order
    let mut parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    let mut k_slots: Vec<Tensor> = k.to_vec();
    // each rank keeps exactly one visiting K chunk in its ring buffer
    let k_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, k_slots[li].bytes() as u64))
        .collect();
    for t in 0..n {
        let sp = crate::obs::begin();
        // double buffer: chunk t+1 is already on the wire while the
        // scores for chunk t run (Ring Attention's overlap schedule)
        let posted = (sh.overlap && t + 1 < n)
            .then(|| post_shift(view, &ranks, &k_slots))
            .transpose()?;
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            parts[li][src] = Some(call1_on(ex, "scores_step", &[&q[li], &k_slots[li]])?);
        }
        if let Some(p) = posted {
            k_slots = view.ring_shift_wait(p.handle)?;
        } else if t + 1 < n {
            view.ring_shift(&mut k_slots)?;
        }
        sp.end_phase_idx("rsa_qk_hop", t);
    }
    let mut p = Vec::with_capacity(ln);
    for li in 0..ln {
        let owned: Vec<Tensor> = parts[li].iter_mut().map(|o| o.take().unwrap()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        let s = ops::concat_last(&refs)?;
        p.push(call1_on(ex, "softmax_fwd", &[&s])?);
    }
    drop(k_charges); // K slots retire before the V rotation begins
    // ---- stage 2: Ring-AV (Eq. 4) --------------------------------
    let mut v_slots: Vec<Tensor> = v.to_vec();
    let _v_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::RingBuf, v_slots[li].bytes() as u64))
        .collect();
    let mut acc: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    for t in 0..n {
        let sp = crate::obs::begin();
        let posted = (sh.overlap && t + 1 < n)
            .then(|| post_shift(view, &ranks, &v_slots))
            .transpose()?;
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            let p_i = ops::slice_last(&p[li], src * sh.lc, (src + 1) * sh.lc)?;
            acc[li] = call1_on(ex, "av_step", &[&p_i, &v_slots[li], &acc[li]])?;
        }
        if let Some(pd) = posted {
            v_slots = view.ring_shift_wait(pd.handle)?;
        } else if t + 1 < n {
            view.ring_shift(&mut v_slots)?;
        }
        sp.end_phase_idx("rsa_av_hop", t);
    }
    Ok((acc, p))
}

/// RSA backward for the view's ranks.  Returns (dq, dk, dv) per executed
/// rank with dk/dv already delivered back to their home ranks (the
/// accumulators ride the ring).
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
pub(crate) fn rsa_backward_on(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    d_ctx: &[Tensor],
    q: &[Tensor],
    p: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
    let n = sh.n;
    let ranks = view.local_ranks();
    let ln = ranks.len();
    // ---- ring pass of V: dP parts + dV accumulators ride along ----
    let mut v_slots: Vec<Tensor> = v.to_vec();
    let mut dv_slots: Vec<Tensor> = v.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    // the backward ring-buffer peak: one data chunk + one gradient
    // accumulator chunk in flight per rank (2·B·Z·Lc·A floats — the
    // value mem_validation pins)
    let vpass_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .flat_map(|(li, &d)| {
            [
                mem::Charge::new(d, mem::Category::RingBuf, v_slots[li].bytes() as u64),
                mem::Charge::new(d, mem::Category::RingBuf, dv_slots[li].bytes() as u64),
            ]
        })
        .collect();
    let mut dp_parts: Vec<Vec<Option<Tensor>>> = (0..ln).map(|_| vec![None; n]).collect();
    for t in 0..n {
        let sp = crate::obs::begin();
        // Only the DATA ring double-buffers; the dV accumulators must
        // absorb this step's contribution before they can move, so their
        // shift stays blocking AFTER the wait (per-edge FIFO then keeps
        // the v-before-dv message order every peer expects).
        let posted = (sh.overlap && t + 1 < n)
            .then(|| post_shift(view, &ranks, &v_slots))
            .transpose()?;
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            dp_parts[li][src] =
                Some(call1_on(ex, "attn_dp_step", &[&d_ctx[li], &v_slots[li]])?);
            let p_i = ops::slice_last(&p[li], src * sh.lc, (src + 1) * sh.lc)?;
            dv_slots[li] =
                call1_on(ex, "attn_dv_step", &[&p_i, &d_ctx[li], &dv_slots[li]])?;
        }
        // The V chunks only need n-1 shifts (a final rotation would
        // just return them home, pure wasted traffic); the dV
        // accumulators take all n — the last shift delivers each dV_i
        // to its home rank (§3.2.2).
        if let Some(pd) = posted {
            v_slots = view.ring_shift_wait(pd.handle)?;
        } else if t + 1 < n {
            view.ring_shift(&mut v_slots)?;
        }
        view.ring_shift(&mut dv_slots)?;
        sp.end_phase_idx("rsa_bwd_v_hop", t);
    }
    drop(vpass_charges); // delivered dVs are flow now, not ring residency
    // ---- local softmax backward over full rows ---------------------
    let mut ds = Vec::with_capacity(ln);
    for li in 0..ln {
        let owned: Vec<Tensor> = dp_parts[li].iter_mut().map(|o| o.take().unwrap()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        let dp = ops::concat_last(&refs)?;
        ds.push(call1_on(ex, "softmax_bwd", &[&p[li], &dp])?);
    }
    // ---- ring pass of K: dQ accumulation + dK accumulators ---------
    let mut k_slots: Vec<Tensor> = k.to_vec();
    let mut dk_slots: Vec<Tensor> = k.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    let _kpass_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .flat_map(|(li, &d)| {
            [
                mem::Charge::new(d, mem::Category::RingBuf, k_slots[li].bytes() as u64),
                mem::Charge::new(d, mem::Category::RingBuf, dk_slots[li].bytes() as u64),
            ]
        })
        .collect();
    let mut dq: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    for t in 0..n {
        let sp = crate::obs::begin();
        let posted = (sh.overlap && t + 1 < n)
            .then(|| post_shift(view, &ranks, &k_slots))
            .transpose()?;
        for (li, &d) in ranks.iter().enumerate() {
            let src = (d + n - t) % n;
            let ds_i = ops::slice_last(&ds[li], src * sh.lc, (src + 1) * sh.lc)?;
            dq[li] = call1_on(ex, "attn_dq_step", &[&ds_i, &k_slots[li], &dq[li]])?;
            dk_slots[li] = call1_on(ex, "attn_dk_step", &[&ds_i, &q[li], &dk_slots[li]])?;
        }
        // Same asymmetry as the V pass: K data shifts n-1 times, the
        // dK accumulators ride all n shifts home.
        if let Some(pd) = posted {
            k_slots = view.ring_shift_wait(pd.handle)?;
        } else if t + 1 < n {
            view.ring_shift(&mut k_slots)?;
        }
        view.ring_shift(&mut dk_slots)?;
        sp.end_phase_idx("rsa_bwd_k_hop", t);
    }
    Ok((dq, dk_slots, dv_slots))
}
