//! `exec::recovery` — elastic recovery from rank failure.
//!
//! PR 9 made a dying rank *detectable*: a panicked thread drops its
//! channel endpoints, its peers' blocked recvs error with the dead rank
//! named, and the runner joins everything and reports a [`RankFailure`].
//! The checkpoint layer (PR 4) proved training state resumes bitwise
//! across mesh factorizations.  This module is the bridge: when a step
//! fails, [`Elastic`] snapshots the (untouched) training state through an
//! in-memory [`Checkpoint`], re-carves the largest valid topology from
//! the surviving world size, re-runs the same static-analysis preflight
//! `train` startup uses, and resumes the step loop on the new topology.
//!
//! ## Failure model
//!
//! A rank dies by panicking mid-step (in production: a device falling off
//! the fabric; in tests: `inject_fault_at`).  The optimizer never applies
//! a partial step — the runner joins all survivors and returns an error
//! before any update — so the host-side state (params, Adam moments,
//! data-loader cursor) at the failed step IS the recovery point.  Params
//! and moments are host-resident in global layout (every rank's view is
//! carved at use time), so "resharding" is re-lowering the runtime for
//! the new topology; no tensor surgery is needed.
//!
//! ## Re-carve rules
//!
//! The new world is `old world - 1` (the dead rank is gone; survivors
//! are re-used).  [`carve_topo`] searches world sizes downward and keeps
//! the same caps the constructors enforce:
//!
//! * flat ring: `n | seq_len`, plus `n | heads` under Ulysses;
//! * mesh: `pp | layers`; a sequence model axis needs `mp | seq_len`
//!   (plus `mp | heads` under Ulysses); a tensor model axis needs
//!   `mp | heads` (Megatron's §4.2 cap) and `mp | B·L` when `pp > 1`.
//!
//! Within one world size the model-parallel axis is kept as large as the
//! caps allow (the paper's axis), then data parallel, pipeline last.
//!
//! ## The recovered == clean contract
//!
//! A recovered run must be bit-equivalent to checkpointing at the failed
//! step and cleanly resuming on the re-carved topology: same losses, same
//! grads, same optimizer state, and byte-for-byte meter parity on the
//! post-recovery steps (the meter is restarted at recovery so the two
//! are comparable).  `rust/tests/chaos_props.rs` fuzzes (failure step ×
//! factorization × SP strategy × pattern × overlap) against this
//! contract via `util::state_hash`.

use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::analysis;
use crate::attn::AttnPattern;
use crate::backend::native::NativeConfig;
use crate::comm::{Meter, MeterSnapshot};
use crate::exec::{DistRunner, MeshRunner, MeshStep};
use crate::model::params::ParamStore;
use crate::model::ModelConfig;
use crate::parallel::sequence::SpStrategy;
use crate::parallel::topology::{Mesh, MpKind};
use crate::parallel::Batch;
use crate::runtime::Runtime;
use crate::train::checkpoint::Checkpoint;
use crate::train::data::{Corpus, CorpusConfig};
use crate::train::optim::{lr_schedule, Adam, AdamConfig};
use crate::train::trainer::{record_step, LogPoint, TrainConfig};
use crate::util::prop::divisors;

// ---------------------------------------------------------------------
// The structured failure
// ---------------------------------------------------------------------

/// A rank died mid-step.  Both runners return this (through `anyhow`) so
/// the elastic driver can `downcast_ref` instead of string-matching; the
/// `Display` text is exactly the PR-9 message the failure-path tests pin.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankFailure {
    /// The dead rank: flat ring rank, or linearized mesh rank.
    pub rank: usize,
    /// World size of the group the rank died in.
    pub world: usize,
    /// Whether the failure surfaced from the 4D mesh runner.
    pub on_mesh: bool,
}

impl RankFailure {
    pub(crate) fn ring(rank: usize, world: usize) -> RankFailure {
        RankFailure { rank, world, on_mesh: false }
    }

    pub(crate) fn mesh(rank: usize, world: usize) -> RankFailure {
        RankFailure { rank, world, on_mesh: true }
    }
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.on_mesh {
            write!(
                f,
                "mesh rank {}: thread panicked mid-step; its peers saw the \
                 disconnect and unwound (panic payload on stderr)",
                self.rank
            )
        } else {
            write!(
                f,
                "rank {}: thread panicked mid-step; its ring peers saw the \
                 disconnect and unwound (panic payload on stderr)",
                self.rank
            )
        }
    }
}

impl std::error::Error for RankFailure {}

// ---------------------------------------------------------------------
// Policy + topology
// ---------------------------------------------------------------------

/// What to do when a rank dies (`--recover`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoverPolicy {
    /// Propagate the contextful failure (the PR-9 behavior).
    None,
    /// Re-carve the surviving world and resume from in-memory state.
    Reshard,
}

impl RecoverPolicy {
    /// Parse the CLI surface: `none | reshard`.
    pub fn parse(s: &str) -> Result<RecoverPolicy> {
        match s {
            "none" => Ok(RecoverPolicy::None),
            "reshard" => Ok(RecoverPolicy::Reshard),
            other => bail!("unknown --recover {other:?} (none | reshard)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RecoverPolicy::None => "none",
            RecoverPolicy::Reshard => "reshard",
        }
    }
}

/// The topology one elastic incarnation runs on.
#[derive(Clone, Copy, Debug)]
pub enum Topo {
    /// A flat SP ring driven by [`DistRunner`] (`--threads N`).
    Flat { n: usize },
    /// A 4D mesh driven by [`MeshRunner`] (`--mesh DxPxM`).
    Mesh { mesh: Mesh, micros: usize },
}

impl Topo {
    pub fn world(&self) -> usize {
        match self {
            Topo::Flat { n } => *n,
            Topo::Mesh { mesh, .. } => mesh.world_size(),
        }
    }

    /// Batches one optimizer step consumes on this topology.
    pub fn batches_per_step(&self) -> u64 {
        match self {
            Topo::Flat { .. } => 1,
            Topo::Mesh { mesh, micros } => (mesh.dp * micros) as u64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Topo::Flat { n } => format!("ring-{n}"),
            Topo::Mesh { mesh, micros } => format!("mesh-{}@{micros}", mesh.label()),
        }
    }
}

// ---------------------------------------------------------------------
// Re-carving
// ---------------------------------------------------------------------

/// The divisibility caps a carved topology must satisfy — the same ones
/// the runner constructors enforce (Megatron head cap, SP chunking,
/// GPipe stage split).
#[derive(Clone, Copy, Debug)]
pub struct Caps {
    pub layers: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    /// Ulysses SP also shards heads: adds `n | heads` (flat ring) or
    /// `mp | heads` (mesh sequence axis).
    pub ulysses: bool,
}

impl Caps {
    pub fn of(cfg: &ElasticConfig) -> Caps {
        Caps {
            layers: cfg.model.layers,
            heads: cfg.model.heads,
            seq_len: cfg.seq_len,
            batch: cfg.batch,
            ulysses: !cfg.sp.is_ring(),
        }
    }

    fn ring_ok(&self, n: usize) -> bool {
        n >= 1 && self.seq_len % n == 0 && (!self.ulysses || self.heads % n == 0)
    }
}

/// Largest valid flat ring size `<= survivors`.
pub fn carve_flat(survivors: usize, caps: &Caps) -> Option<usize> {
    (1..=survivors).rev().find(|&n| caps.ring_ok(n))
}

/// Best valid mesh factorization with world size `<= survivors`
/// (`factor3`-style search over (dp, pp, mp) triples, made exhaustive and
/// deterministic): world sizes are tried largest-first; within one world
/// size the model-parallel axis is kept as large as the caps allow, then
/// dp, with pp soaking the remainder.
pub fn carve_mesh(survivors: usize, kind: MpKind, caps: &Caps) -> Option<Mesh> {
    for w in (1..=survivors).rev() {
        for mp in divisors(w).into_iter().rev() {
            let mp_ok = match kind {
                MpKind::Sequence => caps.ring_ok(mp),
                MpKind::Tensor => mp == 1 || caps.heads % mp == 0,
            };
            if !mp_ok {
                continue;
            }
            for dp in divisors(w / mp).into_iter().rev() {
                let pp = w / mp / dp;
                if caps.layers % pp != 0 {
                    continue;
                }
                if matches!(kind, MpKind::Tensor)
                    && pp > 1
                    && (caps.batch * caps.seq_len) % mp != 0
                {
                    continue;
                }
                if let Ok(m) = Mesh::new(dp, pp, mp, kind) {
                    return Some(m);
                }
            }
        }
    }
    None
}

/// Re-carve `old`'s topology family for `survivors` ranks, or `None` when
/// no valid shape exists (e.g. zero survivors).
pub fn carve_topo(survivors: usize, caps: &Caps, old: &Topo) -> Option<Topo> {
    if survivors == 0 {
        return None;
    }
    match old {
        Topo::Flat { .. } => carve_flat(survivors, caps).map(|n| Topo::Flat { n }),
        Topo::Mesh { mesh, micros } => carve_mesh(survivors, mesh.kind, caps)
            .map(|m| Topo::Mesh { mesh: m, micros: *micros }),
    }
}

// ---------------------------------------------------------------------
// The elastic driver
// ---------------------------------------------------------------------

/// Everything an elastic run needs to (re)build runtimes and data streams
/// from scratch — the run is a pure function of this config plus the
/// fault schedule.
#[derive(Clone, Copy, Debug)]
pub struct ElasticConfig {
    pub model: ModelConfig,
    pub batch: usize,
    pub seq_len: usize,
    pub pattern: AttnPattern,
    pub sp: SpStrategy,
    pub overlap: bool,
    pub policy: RecoverPolicy,
    /// Corpus seed: identifies the batch stream.
    pub data_seed: u64,
    /// Manifest / parameter-init seed.
    pub init_seed: u64,
    pub train: TrainConfig,
    pub topo: Topo,
    pub quiet: bool,
}

/// One recovery, as reported on the outcome and printed by the CLI.
#[derive(Clone, Debug)]
pub struct RecoveryEvent {
    /// Global step the failure hit (the step that was re-run).
    pub step: u64,
    pub failed_rank: usize,
    pub old_world: usize,
    pub new_world: usize,
    pub old_label: String,
    pub new_label: String,
}

impl fmt::Display for RecoveryEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "step {}: rank {} of {} died; re-carved {} -> {} ({} survivor(s))",
            self.step,
            self.failed_rank,
            self.old_world,
            self.old_label,
            self.new_label,
            self.new_world
        )
    }
}

/// What an elastic run hands back: the curve, the final training state
/// (for state-hash comparison), the recovery record, and the meter
/// snapshot covering the steps since the last (re)carve.
pub struct ElasticOutcome {
    pub curve: Vec<LogPoint>,
    pub recoveries: Vec<RecoveryEvent>,
    /// The in-memory checkpoint captured at each failure, in order — the
    /// chaos suite resumes its clean comparison leg from these.
    pub checkpoints: Vec<Checkpoint>,
    pub params: ParamStore,
    pub adam: Adam,
    /// Data-loader cursor after the last step.
    pub cursor: u64,
    /// Gradients of the final completed step.
    pub last_grads: Option<ParamStore>,
    pub final_topo: Topo,
    /// Byte accounting since the last (re)carve — the meter restarts at
    /// every recovery so post-recovery traffic is comparable
    /// byte-for-byte with a clean run resumed from the same checkpoint.
    pub post_meter: MeterSnapshot,
}

/// The elastic step loop.  Build with [`Elastic::new`], optionally add a
/// deterministic fault schedule ([`Elastic::fault_at`]) or a resume point
/// ([`Elastic::resume_from`]), then [`Elastic::run`].
pub struct Elastic {
    cfg: ElasticConfig,
    /// (global step, rank): the rank dies at the start of that step.
    faults: Vec<(u64, usize)>,
    start: Option<Checkpoint>,
}

impl Elastic {
    pub fn new(cfg: ElasticConfig) -> Elastic {
        Elastic { cfg, faults: Vec::new(), start: None }
    }

    /// Schedule rank `rank` to die at the start of global step `step`
    /// (on whatever topology is live then; ranks >= the live world are
    /// ignored, mirroring a failure of a machine not in the job).
    pub fn fault_at(mut self, step: u64, rank: usize) -> Elastic {
        self.faults.push((step, rank));
        self
    }

    /// Resume from an in-memory checkpoint instead of fresh synthetic
    /// state — the clean leg of the recovered==clean contract, and the
    /// CLI resume path after `checkpoint::load`.
    pub fn resume_from(mut self, ckpt: Checkpoint) -> Elastic {
        self.start = Some(ckpt);
        self
    }

    /// Drive the step loop to `cfg.train.steps`, recovering per policy.
    pub fn run(mut self) -> Result<ElasticOutcome> {
        let cfg = self.cfg;
        if matches!(cfg.topo, Topo::Mesh { .. }) && !cfg.pattern.is_dense() {
            bail!(
                "mesh elastic runs support the dense pattern only (got --attn {})",
                cfg.pattern.label()
            );
        }
        let caps = Caps::of(&cfg);
        let corpus_cfg = CorpusConfig::new(cfg.model.vocab, cfg.seq_len, cfg.batch);
        let total = cfg.train.steps;

        let mut topo = cfg.topo;
        let first_rt = runtime_for(&cfg, &topo)?;
        let (mut params, mut adam, mut step, cursor0) = match self.start {
            Some(ck) => {
                let (p, m, v, s, c) = ck.unpack();
                (p, Adam::from_state(AdamConfig::default(), m, v, s), s, c)
            }
            None => {
                let p = ParamStore::synthetic(first_rt.manifest());
                let a = Adam::new(&p, AdamConfig::default());
                (p, a, 0u64, 0u64)
            }
        };
        drop(first_rt);
        let mut corpus = Corpus::at_cursor(corpus_cfg.clone(), cfg.data_seed, cursor0)?;

        let mut curve: Vec<LogPoint> = Vec::new();
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();
        let mut checkpoints: Vec<Checkpoint> = Vec::new();
        let mut last_grads: Option<ParamStore> = None;
        let mut meter = Meter::new();

        'incarnation: loop {
            let rt = runtime_for(&cfg, &topo)?;
            // the same static-analysis gate `train` startup runs: the
            // re-carved schedule must verify before the loop (re)enters
            let report = preflight_topo(&rt, &cfg, &topo)?;
            if !cfg.quiet {
                println!("[elastic] {report}");
            }
            let mut runner = StepRunner::build(&rt, &cfg, &topo, meter.clone())?;
            let start_step = step;
            // arm the earliest pending fault that can hit this
            // incarnation; a machine dies once, so the fault is consumed
            // when its failure is recovered from
            let armed: Option<(u64, usize)> = self
                .faults
                .iter()
                .filter(|(fs, fr)| *fs >= start_step && *fr < topo.world())
                .min_by_key(|(fs, _)| *fs)
                .copied();
            if let Some((fstep, frank)) = armed {
                runner.inject(frank, fstep - start_step);
            }
            let label = format!("elastic-{}", topo.label());

            while step < total {
                let cursor_before = corpus.drawn();
                let batches = draw(&mut corpus, &topo)?;
                let tokens = batches.tokens();
                let sw = crate::obs::Stopwatch::start();
                let step_sp = crate::obs::begin();
                match runner.step(&params, &batches) {
                    Ok((loss, mlm, sop, grads)) => {
                        let lr = lr_schedule(step, cfg.train.warmup, total, cfg.train.peak_lr);
                        let opt_sp = crate::obs::begin();
                        adam.step(&mut params, &grads, lr)?;
                        opt_sp.end_phase("optimizer");
                        step_sp.end_phase_idx("step", step as usize);
                        let dt = sw.elapsed_secs();
                        record_step(
                            &label,
                            &cfg.train,
                            &mut curve,
                            step,
                            (loss, mlm, sop),
                            lr,
                            tokens,
                            dt,
                            cfg.quiet,
                        );
                        last_grads = Some(grads);
                        step += 1;
                    }
                    Err(e) => {
                        let failure = match e.downcast_ref::<RankFailure>() {
                            Some(f) if cfg.policy == RecoverPolicy::Reshard => *f,
                            // --recover none (or a non-failure error):
                            // propagate the PR-9 contextful report
                            _ => return Err(e),
                        };
                        let rec_sp = crate::obs::begin();
                        // consume the fault that fired — the dead machine
                        // stays dead; it must not re-kill the next topology
                        if let Some(ch) = armed {
                            if let Some(pos) = self.faults.iter().position(|f| *f == ch) {
                                self.faults.remove(pos);
                            }
                        }
                        // the failed step applied no update: state at this
                        // step's entry IS the recovery point
                        let ck = Checkpoint::capture(step, &params, &adam, cursor_before);
                        let survivors = topo.world() - 1;
                        let new_topo =
                            carve_topo(survivors, &caps, &topo).ok_or_else(|| {
                                anyhow!(
                                    "recovery failed at step {step}: no valid topology for \
                                     {survivors} survivor(s) (seq_len {}, heads {}, layers {}) \
                                     after: {failure}",
                                    caps.seq_len,
                                    caps.heads,
                                    caps.layers
                                )
                            })?;
                        let event = RecoveryEvent {
                            step,
                            failed_rank: failure.rank,
                            old_world: topo.world(),
                            new_world: new_topo.world(),
                            old_label: topo.label(),
                            new_label: new_topo.label(),
                        };
                        if !cfg.quiet {
                            println!("[elastic] {event}");
                        }
                        recoveries.push(event);
                        checkpoints.push(ck);
                        // rewind the data stream to the failed step's
                        // entry; the new topology re-draws from there
                        // (its batches-per-step may differ)
                        corpus =
                            Corpus::at_cursor(corpus_cfg.clone(), cfg.data_seed, cursor_before)?;
                        // fresh meter: post-recovery byte accounting must
                        // equal a clean run resumed from `ck`
                        meter = Meter::new();
                        topo = new_topo;
                        rec_sp.end_phase("recovery");
                        continue 'incarnation;
                    }
                }
            }
            break;
        }

        Ok(ElasticOutcome {
            curve,
            recoveries,
            checkpoints,
            cursor: corpus.drawn(),
            last_grads,
            final_topo: topo,
            post_meter: meter.snapshot(),
            params,
            adam,
        })
    }
}

// ---------------------------------------------------------------------
// Incarnation plumbing
// ---------------------------------------------------------------------

/// Build the runtime for a topology: the flat ring lowers ring-`n`
/// kernels; a mesh lowers its model axis via [`NativeConfig::for_mesh`].
fn runtime_for(cfg: &ElasticConfig, topo: &Topo) -> Result<Runtime> {
    let (linformer_k, block_w) = match cfg.pattern {
        AttnPattern::Dense => (0, 0),
        AttnPattern::Linformer { k } => (k, 0),
        AttnPattern::Block { w } => (0, w),
    };
    let base = NativeConfig {
        model: cfg.model,
        batch: cfg.batch,
        seq_len: cfg.seq_len,
        ring: match topo {
            Topo::Flat { n } => *n,
            Topo::Mesh { .. } => 1,
        },
        tp: 1,
        linformer_k,
        block_w,
        ulysses: !cfg.sp.is_ring(),
        seed: cfg.init_seed,
    };
    let nc = match topo {
        Topo::Flat { .. } => base,
        Topo::Mesh { mesh, .. } => base.for_mesh(mesh),
    };
    Runtime::native(nc)
}

/// The `train`-startup preflight, applied to whatever topology is live.
fn preflight_topo(rt: &Runtime, cfg: &ElasticConfig, topo: &Topo) -> Result<String> {
    match topo {
        Topo::Flat { .. } => {
            analysis::preflight(analysis::analyze_sp_step(rt, cfg.pattern, cfg.sp))
        }
        Topo::Mesh { mesh, micros } => {
            analysis::preflight(analysis::analyze_mesh(rt, *mesh, *micros, cfg.sp))
        }
    }
}

/// One incarnation's runner, unified over the two threaded backends.
enum StepRunner<'rt> {
    Flat(DistRunner<'rt>),
    Mesh(MeshRunner<'rt>),
}

impl<'rt> StepRunner<'rt> {
    fn build(
        rt: &'rt Runtime,
        cfg: &ElasticConfig,
        topo: &Topo,
        meter: Arc<Meter>,
    ) -> Result<StepRunner<'rt>> {
        match topo {
            Topo::Flat { .. } => {
                let r = DistRunner::with_strategy(rt, meter, cfg.pattern, cfg.sp)?
                    .overlap(cfg.overlap);
                Ok(StepRunner::Flat(r))
            }
            Topo::Mesh { mesh, micros } => {
                let r = MeshRunner::with_strategy(rt, *mesh, *micros, meter, cfg.sp)?
                    .overlap(cfg.overlap);
                Ok(StepRunner::Mesh(r))
            }
        }
    }

    fn inject(&mut self, rank: usize, step: u64) {
        match self {
            StepRunner::Flat(r) => r.inject_fault_at(rank, step),
            StepRunner::Mesh(r) => r.inject_fault_at(rank, step),
        }
    }

    fn step(
        &self,
        params: &ParamStore,
        batches: &StepBatches,
    ) -> Result<(f32, f32, f32, ParamStore)> {
        match (self, batches) {
            (StepRunner::Flat(r), StepBatches::Flat(b)) => {
                let out = r.forward_backward(params, b)?;
                Ok((out.loss, out.mlm, out.sop, out.grads))
            }
            (StepRunner::Mesh(r), StepBatches::Mesh(bs)) => {
                let out = MeshStep::step(r, params, bs)?;
                Ok((out.loss, out.mlm, out.sop, out.grads))
            }
            _ => bail!("elastic runner/batch topology mismatch"),
        }
    }
}

/// One step's batches, shaped for the live topology.
enum StepBatches {
    Flat(Batch),
    Mesh(Vec<Vec<Batch>>),
}

impl StepBatches {
    fn tokens(&self) -> f64 {
        match self {
            StepBatches::Flat(b) => b.ids.numel() as f64,
            StepBatches::Mesh(bs) => {
                bs.iter().flatten().map(|b| b.ids.numel() as f64).sum()
            }
        }
    }
}

/// Draw one optimizer step's batches (mesh: replica-major, micro-minor —
/// the `MeshTrainer` order, so a run is determined by the corpus seed).
fn draw(corpus: &mut Corpus, topo: &Topo) -> Result<StepBatches> {
    match topo {
        Topo::Flat { .. } => Ok(StepBatches::Flat(corpus.next_batch()?)),
        Topo::Mesh { mesh, micros } => {
            let b: Vec<Vec<Batch>> = (0..mesh.dp)
                .map(|_| {
                    (0..*micros)
                        .map(|_| corpus.next_batch())
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<_>>()?;
            Ok(StepBatches::Mesh(b))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn caps(seq_len: usize, heads: usize, layers: usize, ulysses: bool) -> Caps {
        Caps { layers, heads, seq_len, batch: 2, ulysses }
    }

    #[test]
    fn flat_carve_prefers_largest_valid_ring() {
        // survivors 3, seq_len 32: 3 does not divide 32, 2 does
        assert_eq!(carve_flat(3, &caps(32, 2, 2, false)), Some(2));
        // survivors 4 is directly valid
        assert_eq!(carve_flat(4, &caps(32, 2, 2, false)), Some(4));
        assert_eq!(carve_flat(0, &caps(32, 2, 2, false)), None);
    }

    #[test]
    fn flat_carve_respects_the_ulysses_head_cap() {
        // ulysses on a 2-head model: n must divide 2, so survivors 3 -> 2
        assert_eq!(carve_flat(3, &caps(32, 2, 2, true)), Some(2));
        // 4-head model: survivors 3 -> 2 (3 divides neither 32 nor 4)
        assert_eq!(carve_flat(3, &caps(32, 4, 2, true)), Some(2));
    }

    #[test]
    fn mesh_carve_keeps_the_model_axis_large() {
        // 3 survivors of a sequence mesh on seq_len 32: w=3 only factors
        // as mp=1 (3 ∤ 32), pp ∈ {1, 3} but layers=2 rejects pp=3 -> 3x1x1
        let m = carve_mesh(3, MpKind::Sequence, &caps(32, 2, 2, false)).unwrap();
        assert_eq!((m.dp, m.pp, m.mp), (3, 1, 1));
        // 4 survivors: mp=4 divides 32 and is preferred over dp
        let m = carve_mesh(4, MpKind::Sequence, &caps(32, 2, 2, false)).unwrap();
        assert_eq!((m.dp, m.pp, m.mp), (1, 1, 4));
    }

    #[test]
    fn mesh_carve_respects_the_megatron_head_cap() {
        // tensor axis on a 2-head model: mp ∈ {1, 2}; survivors 4 -> mp=2
        let m = carve_mesh(4, MpKind::Tensor, &caps(32, 2, 2, false)).unwrap();
        assert_eq!(m.mp, 2);
        assert_eq!(m.dp * m.pp * m.mp, 4);
        // heads=3 rejects mp ∈ {2, 4}; the largest world still wins via
        // data parallelism (world beats model-axis width in the search)
        let m = carve_mesh(4, MpKind::Tensor, &caps(32, 3, 2, false)).unwrap();
        assert_eq!((m.dp, m.pp, m.mp), (4, 1, 1));
    }

    #[test]
    fn carve_topo_zero_survivors_is_none() {
        let c = caps(32, 2, 2, false);
        assert!(carve_topo(0, &c, &Topo::Flat { n: 1 }).is_none());
    }

    #[test]
    fn rank_failure_display_matches_the_pinned_messages() {
        let flat = RankFailure::ring(2, 4).to_string();
        assert!(flat.starts_with("rank 2: thread panicked mid-step"), "{flat}");
        let mesh = RankFailure::mesh(1, 4).to_string();
        assert!(mesh.starts_with("mesh rank 1: thread panicked mid-step"), "{mesh}");
    }

    #[test]
    fn recover_policy_parses_both_spellings() {
        assert_eq!(RecoverPolicy::parse("none").unwrap(), RecoverPolicy::None);
        assert_eq!(RecoverPolicy::parse("reshard").unwrap(), RecoverPolicy::Reshard);
        assert!(RecoverPolicy::parse("magic").is_err());
    }
}
