//! The multi-threaded distributed runner: one OS thread per rank.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::comm::threaded::mesh;
use crate::comm::Meter;
use crate::exec::recovery::RankFailure;
use crate::model::params::ParamStore;
use crate::parallel::sequence::{seqpar_step, RankOutput, SpStrategy, StepShape};
use crate::parallel::{Batch, Engine, StepOutput};
use crate::runtime::Runtime;

/// Runs the sequence-parallel training step with genuinely concurrent
/// ranks: `n` OS threads (n = the manifest's ring size), each owning its
/// sequence chunk and a per-rank `RingComm`, all sharing one `Sync`
/// executor backend.
///
/// Semantics are the sequential `SeqParEngine`'s — same schedule, same
/// metered bytes — but stages that the slot view serializes (all ranks'
/// QK^T at ring step t, the backward GEMMs, the MLPs) run in parallel on
/// real cores, and every ring exchange is a live P2P message.
pub struct DistRunner<'rt> {
    rt: &'rt Runtime,
    /// Ranks = OS threads = ring size the manifest was built for.
    pub n: usize,
    pub meter: Arc<Meter>,
    shape: StepShape,
    /// Fault injection for the failure-path tests: `(rank, from_step)` —
    /// the rank's thread panics at the start of every step whose 0-based
    /// index on this runner is >= `from_step`.
    inject_fault: Option<(usize, u64)>,
    /// Steps started on this runner; drives step-targeted injection.
    steps_run: AtomicU64,
}

impl<'rt> DistRunner<'rt> {
    /// Build a runner over the runtime's manifest (rank count = manifest
    /// ring size — the chunk shapes every artifact was lowered for).
    /// Fails up front when the backend cannot cross threads (xla-pjrt).
    pub fn new(rt: &'rt Runtime, meter: Arc<Meter>) -> Result<DistRunner<'rt>> {
        DistRunner::with_pattern(rt, meter, crate::attn::AttnPattern::Dense)
    }

    /// Build the runner with a specific attention pattern (`--attn`); the
    /// manifest must carry the matching kernels (linformer_k / block_w).
    pub fn with_pattern(
        rt: &'rt Runtime,
        meter: Arc<Meter>,
        pattern: crate::attn::AttnPattern,
    ) -> Result<DistRunner<'rt>> {
        DistRunner::with_strategy(rt, meter, pattern, SpStrategy::Ring)
    }

    /// Build the runner with an explicit attention pattern AND
    /// sequence-parallel strategy (`--attn` / `--sp`): under
    /// [`SpStrategy::Ulysses`] every ring exchange is replaced by the
    /// all-to-all head-shard transposes, executed as real channel
    /// messages between the rank threads with the same byte accounting
    /// as the sequential engine.
    pub fn with_strategy(
        rt: &'rt Runtime,
        meter: Arc<Meter>,
        pattern: crate::attn::AttnPattern,
        sp: SpStrategy,
    ) -> Result<DistRunner<'rt>> {
        rt.sync_backend()?; // threaded execution needs a Send + Sync backend
        let shape = StepShape::from_manifest_sp(rt.manifest(), pattern, sp)?;
        let n = shape.n;
        Ok(DistRunner {
            rt,
            n,
            meter,
            shape,
            inject_fault: None,
            steps_run: AtomicU64::new(0),
        })
    }

    /// Enable comm/compute overlap in the dense ring loops (`--overlap`):
    /// each rank thread posts the shift of chunk t+1 before computing on
    /// chunk t and waits after.  Results, metered bytes and trace events
    /// are identical to the blocking schedule — only wait time moves
    /// (rust/tests/dist_equivalence.rs pins the equivalence).
    pub fn overlap(mut self, on: bool) -> Self {
        self.shape.overlap = on;
        self
    }

    /// TESTING the failure path: make rank `rank`'s thread panic at the
    /// start of every subsequent step.  Its ring peers must surface the broken
    /// channels as contextful "peer disconnected" errors and the join
    /// must report the dead rank by number instead of hanging.
    pub fn inject_fault(&mut self, rank: usize) {
        self.inject_fault_at(rank, 0);
    }

    /// Step-targeted fault injection: rank `rank` panics at the start of
    /// the step with 0-based index `step` (counted per runner) and every
    /// step after it.  `exec::recovery`'s chaos suite uses this to kill a
    /// rank at a fuzzed point in the run.
    pub fn inject_fault_at(&mut self, rank: usize, step: u64) {
        self.inject_fault = Some((rank, step));
    }

    /// One forward+backward step, wall-clock parallel across ranks.
    ///
    /// Spawns a scoped thread per rank over a fresh channel mesh (fresh
    /// channels keep every step's message schedule identical, so results
    /// are bit-deterministic regardless of OS scheduling), joins the
    /// per-rank outputs, and reassembles the global [`StepOutput`]:
    /// losses are summed over ranks, hidden chunks ordered by rank, and
    /// the gradients — already globally all-reduced on every rank — are
    /// taken from rank 0.
    pub fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        let ex = self.rt.sync_backend()?;
        let shape = &self.shape;
        let comms = mesh(self.n, self.meter.clone());

        let fh = crate::obs::fork();
        let mfh = crate::obs::mem::fork();
        let step_idx = self.steps_run.fetch_add(1, Ordering::Relaxed);
        let inject = match self.inject_fault {
            Some((rank, from)) if step_idx >= from => Some(rank),
            _ => None,
        };
        let results: Vec<(usize, bool, Result<RankOutput>)> = thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    s.spawn(move || {
                        let rank = comm.rank;
                        crate::obs::adopt(fh, rank);
                        // charges name the global rank, so lane base 0
                        crate::obs::mem::adopt(mfh, 0);
                        if inject == Some(rank) {
                            panic!("injected fault on rank {rank} (DistRunner::inject_fault)");
                        }
                        // &(dyn Executor + Sync) coerces to &dyn Executor
                        let out = seqpar_step(ex, &comm, shape, params, batch);
                        crate::obs::flush();
                        (rank, out)
                    })
                })
                .collect();
            // Handles are in rank order; joining EVERY one — panicked or
            // not — is what turns a dead rank into a reportable error
            // instead of a hung runner (a panicking rank drops its
            // channel endpoints, so its peers' blocked recvs return
            // "peer disconnected" errors and those threads unwind too).
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| match h.join() {
                    Ok((r, out)) => (r, false, out),
                    Err(_) => {
                        (rank, true, Err(anyhow!("rank {rank}: thread panicked mid-step")))
                    }
                })
                .collect()
        });

        // A panicked rank is the root cause; its ring peers' "peer
        // disconnected" errors are downstream symptoms of the same death.
        // Returned as the structured [`RankFailure`] so `exec::recovery`
        // can downcast and reshard instead of string-matching.
        if let Some((rank, ..)) = results.iter().find(|(_, panicked, _)| *panicked) {
            return Err(RankFailure::ring(*rank, self.n).into());
        }

        let mut by_rank: Vec<Option<RankOutput>> = (0..self.n).map(|_| None).collect();
        for (rank, _, res) in results {
            let out = res.map_err(|e| anyhow!("rank {rank}: {e}"))?;
            if rank >= self.n || by_rank[rank].is_some() {
                bail!("runner joined an unexpected rank {rank}");
            }
            by_rank[rank] = Some(out);
        }

        let mut mlm = 0.0f32;
        let mut sop = 0.0f32;
        let mut hidden = Vec::with_capacity(self.n);
        let mut grads: Option<ParamStore> = None;
        for (rank, slot) in by_rank.into_iter().enumerate() {
            let out = slot.ok_or_else(|| anyhow!("rank {rank} produced no output"))?;
            mlm += out.mlm;
            sop += out.sop;
            let mut h = out.hidden;
            if h.len() != 1 {
                bail!("rank {rank}: expected 1 hidden chunk, got {}", h.len());
            }
            hidden.push(
                h.pop()
                    .ok_or_else(|| anyhow!("rank {rank}: hidden chunk vanished after join"))?,
            );
            if rank == 0 {
                // ranks agree up to f32 reduction-order rounding; rank 0's
                // copy has a fixed accumulation order (deterministic bits),
                // so the runner always returns that one
                grads = Some(out.grads);
            }
        }

        Ok(StepOutput {
            loss: mlm + sop,
            mlm,
            sop,
            grads: grads.ok_or_else(|| anyhow!("rank 0 produced no gradients"))?,
            hidden,
        })
    }
}

impl<'rt> Engine for DistRunner<'rt> {
    fn name(&self) -> &'static str {
        "seq-par-threaded"
    }

    fn group_size(&self) -> usize {
        self.n
    }

    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        DistRunner::forward_backward(self, params, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeConfig;
    use crate::comm::{Fabric, Meter};
    use crate::parallel::sequence::SeqParEngine;
    use crate::train::data::{Corpus, CorpusConfig};

    /// Smoke: the threaded runner produces the sequential engine's loss on
    /// the tiny manifest (the full n-sweep lives in
    /// rust/tests/dist_equivalence.rs).
    #[test]
    fn threaded_step_matches_sequential_loss() {
        let rt = Runtime::native(NativeConfig::tiny()).unwrap();
        let m = rt.manifest().clone();
        let params = ParamStore::synthetic(&m);
        let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 9)
            .next_batch()
            .unwrap();

        let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new())).unwrap();
        let a = Engine::forward_backward(&seq, &params, &batch).unwrap();

        let dist = DistRunner::new(&rt, Meter::new()).unwrap();
        let b = dist.forward_backward(&params, &batch).unwrap();

        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "sequential {} vs threaded {}",
            a.loss,
            b.loss
        );
        assert_eq!(a.hidden.len(), b.hidden.len());
    }
}
