//! The executable 4D mesh: DP×PP×SP (and the DP×PP×TP baseline).
//!
//! `parallel::topology::Mesh` describes the rank layout analytically;
//! this module makes the composed mesh *run*.  Every mesh coordinate
//! `(dp, pp, mp)` executes the pipeline-stage slice of the model that
//! `pp` owns, over the model-parallel group that `mp` indexes, on the
//! data-parallel replica `dp`:
//!
//! * **mp axis** — the paper's contribution slot: a sequence-parallel
//!   ring (`MpKind::Sequence`, chunks of `L/mp` tokens per rank) or the
//!   Megatron tensor-parallel baseline (`MpKind::Tensor`, head/FFN
//!   shards).  Both reuse the per-stage segments of
//!   `parallel::{sequence, tensorp}` — the same code the pure engines
//!   run.
//! * **pp axis** — a real GPipe schedule ([`Schedule::gpipe`]): stage
//!   boundaries carry activations forward and gradients backward once
//!   per microbatch, with activations stashed per in-flight microbatch.
//!   The paper's §3.2.2 observation is executable here: a sequence-
//!   parallel stage sends its already-split `[B, L/mp, H]` chunk
//!   directly, while the tensor-parallel baseline pays scatter + send +
//!   all-gather (every TP rank holds the full sequence).
//! * **dp axis** — gradient all-reduce across replicas (summed over
//!   microbatches, averaged over replicas), through the same
//!   `parallel::allreduce_named` the `DataParallel` wrapper uses.
//!
//! Two executions, one step logic, byte-identical meters:
//!
//! * [`MeshEngine`] — sequential simulation: every coordinate on the
//!   calling thread, model-parallel groups as `Fabric` slot views,
//!   boundaries as buffered local queues, schedule cells executed in
//!   start-tick order.
//! * [`MeshRunner`] — one OS thread per mesh coordinate over per-group
//!   channel meshes (`comm::threaded`): ring exchanges, boundary sends
//!   and the dp/mp all-reduces are real concurrent messages, each thread
//!   executing its stage's projection of the same GPipe schedule.
//!
//! `rust/tests/mesh_equivalence.rs` pins threaded == sequential == the
//! serial engine (loss, every gradient, meter parity);
//! `rust/tests/mesh_props.rs` fuzzes factorizations and pins measured
//! boundary bytes to `pipeline::boundary_totals` exactly.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::attn::AttnPattern;
use crate::comm::threaded::{mesh as comm_mesh, RingComm};
use crate::comm::{Collective, CommKind, Fabric, Meter};
use crate::exec::recovery::RankFailure;
use crate::model::params::ParamStore;
use crate::obs::mem;
use crate::parallel::pipeline::{Cell, Schedule};
use crate::parallel::sequence::{self, LayerStash, SpStrategy, StepShape};
use crate::parallel::tensorp::{self, TpLayerStash, TpShape};
use crate::parallel::topology::{Coord, Mesh, MpKind};
use crate::parallel::{allreduce_named, Batch};
use crate::runtime::{Executor, Runtime};
use crate::tensor::{ops, Tensor};

/// Result of one mesh training step over `dp * micros` microbatches.
#[derive(Debug)]
pub struct MeshOutput {
    /// Mean over replicas of the per-replica loss (each the sum over its
    /// microbatches) — equals the pure-SP loss at dp=pp=1, micros=1.
    pub loss: f32,
    pub mlm: f32,
    pub sop: f32,
    /// Per-replica total loss, in dp order.
    pub replica_loss: Vec<f32>,
    /// Gradients in GLOBAL layout: summed over microbatches, all-reduced
    /// over the mesh, averaged over dp — ready for the optimizer.
    pub grads: ParamStore,
}

/// One mesh execution backend (sequential simulation or threaded).
pub trait MeshStep {
    fn mesh(&self) -> Mesh;
    fn micros(&self) -> usize;
    /// `batches[dp][micro]` — one manifest-shaped batch per microbatch
    /// per replica (the artifact shapes fix the per-microbatch batch
    /// size, exactly as in `parallel::data::DataParallel`).
    fn step(&self, params: &ParamStore, batches: &[Vec<Batch>]) -> Result<MeshOutput>;
}

/// Which pipeline stage owns parameter `name` (stage 0: embeddings,
/// last: the loss heads, layers by contiguous blocks).
fn stage_of(name: &str, layers_per_stage: usize, stages: usize) -> Option<usize> {
    if name == "tok_emb" || name == "pos_emb" {
        return Some(0);
    }
    if name.starts_with("mlm_") || name.starts_with("sop_") {
        return Some(stages - 1);
    }
    let rest = name.strip_prefix("layer")?;
    let idx: usize = rest.split('.').next()?.parse().ok()?;
    let s = idx / layers_per_stage;
    (s < stages).then_some(s)
}

/// Validated run-shape for a mesh execution, shared by both backends
/// (and by the static analyzer, which abstract-interprets the same
/// stages over trace views — `crate::analysis`).
pub(crate) struct MeshSpec {
    pub(crate) mesh: Mesh,
    pub(crate) micros: usize,
    layers_per_stage: usize,
    sp: Option<StepShape>,
    tp: Option<TpShape>,
    /// Sorted parameter names owned by each pipeline stage — a disjoint
    /// cover of the manifest inventory (validated at construction).
    pub(crate) owned: Vec<Vec<String>>,
}

impl MeshSpec {
    pub(crate) fn new(rt: &Runtime, mesh: Mesh, micros: usize, sp: SpStrategy) -> Result<MeshSpec> {
        let m = rt.manifest();
        if micros == 0 {
            bail!("a mesh step needs micros >= 1");
        }
        if m.linformer_k != 0 {
            // either kind: the Linformer projections add parameters that
            // have no pipeline-stage owner
            bail!(
                "mesh execution supports dense attention only \
                 (manifest was lowered with linformer_k={})",
                m.linformer_k
            );
        }
        let layers_per_stage = mesh.stage_layers(m.layers)?;
        let (sp, tp) = match mesh.kind {
            MpKind::Sequence => {
                if m.ring != mesh.mp {
                    bail!(
                        "manifest was lowered for ring={}, the mesh's sequence axis \
                         wants mp={} — rebuild the backend with --ring {}",
                        m.ring,
                        mesh.mp,
                        mesh.mp
                    );
                }
                (Some(StepShape::from_manifest_sp(m, AttnPattern::Dense, sp)?), None)
            }
            MpKind::Tensor => {
                if !sp.is_ring() {
                    bail!(
                        "--sp {} applies to the sequence model axis (this mesh's \
                         model axis is tensor-parallel)",
                        sp.label()
                    );
                }
                let tsh = TpShape::from_manifest(m, mesh.mp)?;
                if mesh.pp > 1 && (m.batch * m.seq_len) % mesh.mp != 0 {
                    bail!(
                        "the stage-boundary scatter needs mp={} to divide B*L={}",
                        mesh.mp,
                        m.batch * m.seq_len
                    );
                }
                (None, Some(tsh))
            }
        };
        let mut owned: Vec<Vec<String>> = vec![Vec::new(); mesh.pp];
        for p in &m.params {
            let s = stage_of(&p.name, layers_per_stage, mesh.pp).ok_or_else(|| {
                anyhow!(
                    "parameter {:?} has no pipeline-stage owner (mesh execution \
                     covers the dense transformer inventory)",
                    p.name
                )
            })?;
            owned[s].push(p.name.clone());
        }
        for o in &mut owned {
            o.sort();
        }
        Ok(MeshSpec { mesh, micros, layers_per_stage, sp, tp, owned })
    }

    /// Zero gradient buffers for stage `s` only — a rank holds grads for
    /// its own stage's parameters, not the whole model (the GPipe memory
    /// story; at pp=1 this is the full inventory).
    fn stage_zeros(&self, params: &ParamStore, s: usize) -> ParamStore {
        ParamStore {
            values: self.owned[s]
                .iter()
                .map(|n| (n.clone(), Tensor::zeros(&params.values[n].shape)))
                .collect(),
        }
    }

    fn check_batches(&self, batches: &[Vec<Batch>]) -> Result<()> {
        if batches.len() != self.mesh.dp {
            bail!(
                "mesh with dp={} needs {} replica batch lists, got {}",
                self.mesh.dp,
                self.mesh.dp,
                batches.len()
            );
        }
        for (r, b) in batches.iter().enumerate() {
            if b.len() != self.micros {
                bail!(
                    "replica {r}: mesh with micros={} needs {} microbatches, got {}",
                    self.micros,
                    self.micros,
                    b.len()
                );
            }
        }
        Ok(())
    }
}

/// One direction of one stage boundary, executed two ways: a buffered
/// local queue (sequential simulation) or the direct channel edges of the
/// pp-column communicator (threaded).  Every part sent is metered as
/// [`CommKind::Pipeline`], so the two executions agree byte-for-byte.
///
/// The threaded edge is `RingComm::send_to` — a nonblocking isend — so a
/// stage's boundary send returns immediately and its next schedule cell
/// computes while the adjacent stage drains the channel: GPipe boundary
/// traffic overlaps micro-batch compute by construction, the same
/// primitive the dense ring loops double-buffer with under `--overlap`.
pub(crate) enum Link<'a> {
    Queue { q: &'a RefCell<VecDeque<Vec<Tensor>>>, meter: &'a Meter },
    Comm { comm: &'a RingComm, peer: usize },
}

impl<'a> Link<'a> {
    fn send(&self, parts: Vec<Tensor>) -> Result<()> {
        match self {
            Link::Queue { q, meter } => {
                for t in &parts {
                    let sp = crate::obs::begin();
                    meter.add_traced(CommKind::Pipeline, t.bytes() as u64, sp);
                }
                q.borrow_mut().push_back(parts);
                Ok(())
            }
            Link::Comm { comm, peer } => {
                let [t]: [Tensor; 1] = parts
                    .try_into()
                    .map_err(|_| anyhow!("a per-rank link sends exactly one part"))?;
                comm.send_to(*peer, t)
            }
        }
    }

    fn recv(&self) -> Result<Vec<Tensor>> {
        match self {
            Link::Queue { q, .. } => q
                .borrow_mut()
                .pop_front()
                .ok_or_else(|| anyhow!("stage boundary queue empty — schedule violated causality")),
            Link::Comm { comm, peer } => Ok(vec![comm.recv_from(*peer)?]),
        }
    }
}

fn need<'l, 'a>(link: Option<&'l Link<'a>>, what: &str) -> Result<&'l Link<'a>> {
    link.ok_or_else(|| anyhow!("stage has no {what} link"))
}

/// A sequence-parallel pipeline stage: layers `[lo, hi)` over the mp-ring
/// view, with per-microbatch activation stashes.
pub(crate) struct SpStage<'a> {
    ex: &'a dyn Executor,
    sh: &'a StepShape,
    params: &'a ParamStore,
    view: &'a dyn Collective,
    lo: usize,
    hi: usize,
    first: bool,
    last: bool,
    stash: Vec<Vec<LayerStash>>,
    /// Last stage's held forward output per in-flight microbatch, with
    /// its per-rank PipeStash charges (the GPipe activation residency).
    held: Vec<Option<(Vec<Tensor>, Vec<mem::Charge>)>>,
    grads: Vec<ParamStore>,
    /// Residency charges for the per-rank stage gradient stores.
    _grad_charges: Vec<mem::Charge>,
    mlm: f32,
    sop: f32,
}

impl<'a> SpStage<'a> {
    fn forward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        let ranks = self.view.local_ranks();
        let mut x = if self.first {
            sequence::sp_embed_fwd(self.ex, self.sh, self.params, batch, &ranks)?
        } else {
            // SP boundary: the already-split [B, Lc, H] chunks arrive
            // directly — no scatter, no gather (paper §3.2.2)
            need(prev, "inbound")?.recv()?
        };
        let mut sts = Vec::with_capacity(self.hi - self.lo);
        for layer in self.lo..self.hi {
            let (x_next, st) =
                sequence::sp_layer_fwd(self.ex, self.view, self.sh, self.params, layer, x)?;
            x = x_next;
            sts.push(st);
        }
        if self.stash.len() != u {
            bail!("stage ran forward microbatch {u} out of schedule order");
        }
        self.stash.push(sts);
        if self.last {
            let charges = ranks
                .iter()
                .enumerate()
                .map(|(li, &d)| {
                    mem::Charge::new(d, mem::Category::PipeStash, x[li].bytes() as u64)
                })
                .collect();
            self.held[u] = Some((x, charges));
        } else {
            need(next, "outbound")?.send(x)?;
        }
        Ok(())
    }

    fn backward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        let ranks = self.view.local_ranks();
        let mut dx = if self.last {
            let (x, _held_charges) = self.held[u]
                .take()
                .ok_or_else(|| anyhow!("microbatch {u} has no held activation"))?;
            let (mlm, sop, dx) = sequence::sp_heads_fwd_bwd(
                self.ex, self.sh, self.params, batch, &x, &ranks, &mut self.grads,
            )?;
            self.mlm += mlm;
            self.sop += sop;
            dx
        } else {
            need(next, "inbound gradient")?.recv()?
        };
        let sts = std::mem::take(&mut self.stash[u]); // GPipe frees the stash here
        for (i, layer) in (self.lo..self.hi).enumerate().rev() {
            dx = sequence::sp_layer_bwd(
                self.ex, self.view, self.sh, self.params, layer, &sts[i], &dx, &mut self.grads,
            )?;
        }
        if self.first {
            sequence::sp_embed_bwd(
                self.ex, self.sh, self.params, batch, &dx, &ranks, &mut self.grads,
            )?;
        } else {
            need(prev, "outbound gradient")?.send(dx)?;
        }
        Ok(())
    }
}

/// A tensor-parallel pipeline stage (the Megatron baseline): every rank
/// holds the full sequence (one replicated activation per view);
/// boundaries pay scatter + send + all-gather.
pub(crate) struct TpStage<'a> {
    ex: &'a dyn Executor,
    tsh: &'a TpShape,
    params: &'a ParamStore,
    view: &'a dyn Collective,
    meter: &'a Meter,
    lo: usize,
    hi: usize,
    first: bool,
    last: bool,
    stash: Vec<Vec<TpLayerStash>>,
    /// Replicated held output per in-flight microbatch: every executed
    /// rank keeps a full-sequence copy, so one charge per rank.
    held: Vec<Option<(Tensor, Vec<mem::Charge>)>>,
    grads: Vec<ParamStore>,
    /// Residency charges for the per-rank stage gradient stores.
    _grad_charges: Vec<mem::Charge>,
    mlm: f32,
    sop: f32,
}

impl<'a> TpStage<'a> {
    /// Megatron's boundary send: scatter the replicated [B*L, H]
    /// activation to 1/mp row slices (metered [`CommKind::Scatter`]),
    /// send each executed rank's slice to its peer in the adjacent stage.
    fn send_boundary(&self, x: Tensor, link: &Link) -> Result<()> {
        let t = self.view.world();
        if t == 1 {
            return link.send(vec![x]); // degenerate group: a plain send
        }
        let rows = self.tsh.b * self.tsh.l / t;
        let parts = self
            .view
            .local_ranks()
            .iter()
            .map(|&d| {
                let sp = crate::obs::begin();
                let sl = ops::slice_dim0(&x, d * rows, (d + 1) * rows)?;
                self.meter.add_traced(CommKind::Scatter, sl.bytes() as u64, sp);
                Ok(sl)
            })
            .collect::<Result<Vec<_>>>()?;
        link.send(parts)
    }

    /// The receiving side's all-gather back to the full activation.
    fn recv_boundary(&self, link: &Link) -> Result<Tensor> {
        let mut parts = link.recv()?;
        self.view.all_gather(&mut parts, 0)?; // no-op (and free) at mp=1
        Ok(parts.swap_remove(0))
    }

    fn forward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        let mut x = if self.first {
            tensorp::tp_embed_fwd(self.ex, self.tsh, self.params, batch)?
        } else {
            self.recv_boundary(need(prev, "inbound")?)?
        };
        let mut sts = Vec::with_capacity(self.hi - self.lo);
        for layer in self.lo..self.hi {
            let (x_next, st) =
                tensorp::tp_layer_fwd(self.ex, self.view, self.tsh, self.params, layer, x)?;
            x = x_next;
            sts.push(st);
        }
        if self.stash.len() != u {
            bail!("stage ran forward microbatch {u} out of schedule order");
        }
        self.stash.push(sts);
        if self.last {
            let charges = self
                .view
                .local_ranks()
                .iter()
                .map(|&d| mem::Charge::new(d, mem::Category::PipeStash, x.bytes() as u64))
                .collect();
            self.held[u] = Some((x, charges));
        } else {
            self.send_boundary(x, need(next, "outbound")?)?;
        }
        Ok(())
    }

    fn backward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        let ranks = self.view.local_ranks();
        let mut dx = if self.last {
            let (x, _held_charges) = self.held[u]
                .take()
                .ok_or_else(|| anyhow!("microbatch {u} has no held activation"))?;
            let (mlm, sop, dx) = tensorp::tp_heads_fwd_bwd(
                self.ex, self.tsh, self.params, batch, &x, &ranks, &mut self.grads,
            )?;
            self.mlm += mlm;
            self.sop += sop;
            dx
        } else {
            self.recv_boundary(need(next, "inbound gradient")?)?
        };
        let sts = std::mem::take(&mut self.stash[u]);
        for (i, layer) in (self.lo..self.hi).enumerate().rev() {
            dx = tensorp::tp_layer_bwd(
                self.ex, self.view, self.tsh, self.params, layer, &sts[i], &dx, &mut self.grads,
            )?;
        }
        if self.first {
            tensorp::tp_embed_bwd(
                self.ex, self.tsh, self.params, batch, &dx, &ranks, &mut self.grads,
            )?;
        } else {
            self.send_boundary(dx, need(prev, "outbound gradient")?)?;
        }
        Ok(())
    }
}

/// One pipeline stage of one replica, either kind.
pub(crate) enum Stage<'a> {
    Sp(SpStage<'a>),
    Tp(TpStage<'a>),
}

impl<'a> Stage<'a> {
    pub(crate) fn new(
        spec: &'a MeshSpec,
        ex: &'a dyn Executor,
        params: &'a ParamStore,
        view: &'a dyn Collective,
        meter: &'a Meter,
        s: usize,
    ) -> Result<Stage<'a>> {
        let lo = s * spec.layers_per_stage;
        let hi = lo + spec.layers_per_stage;
        let first = s == 0;
        let last = s + 1 == spec.mesh.pp;
        let ln = view.local_ranks().len();
        let grads: Vec<ParamStore> = (0..ln).map(|_| spec.stage_zeros(params, s)).collect();
        // each rank's gradient store covers this stage's owned params only
        let grad_charges: Vec<mem::Charge> = view
            .local_ranks()
            .iter()
            .enumerate()
            .map(|(li, &d)| {
                mem::Charge::new(d, mem::Category::Grads, grads[li].total_bytes() as u64)
            })
            .collect();
        Ok(match spec.mesh.kind {
            MpKind::Sequence => Stage::Sp(SpStage {
                ex,
                sh: spec.sp.as_ref().ok_or_else(|| {
                    anyhow!("stage {s}: sequence-kind mesh spec lost its StepShape")
                })?,
                params,
                view,
                lo,
                hi,
                first,
                last,
                stash: Vec::new(),
                held: (0..spec.micros).map(|_| None).collect(),
                grads,
                _grad_charges: grad_charges,
                mlm: 0.0,
                sop: 0.0,
            }),
            MpKind::Tensor => Stage::Tp(TpStage {
                ex,
                tsh: spec.tp.as_ref().ok_or_else(|| {
                    anyhow!("stage {s}: tensor-kind mesh spec lost its TpShape")
                })?,
                params,
                view,
                meter,
                lo,
                hi,
                first,
                last,
                stash: Vec::new(),
                held: (0..spec.micros).map(|_| None).collect(),
                grads,
                _grad_charges: grad_charges,
                mlm: 0.0,
                sop: 0.0,
            }),
        })
    }

    pub(crate) fn forward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        match self {
            Stage::Sp(s) => s.forward_micro(u, batch, prev, next),
            Stage::Tp(s) => s.forward_micro(u, batch, prev, next),
        }
    }

    pub(crate) fn backward_micro(
        &mut self,
        u: usize,
        batch: &Batch,
        prev: Option<&Link>,
        next: Option<&Link>,
    ) -> Result<()> {
        match self {
            Stage::Sp(s) => s.backward_micro(u, batch, prev, next),
            Stage::Tp(s) => s.backward_micro(u, batch, prev, next),
        }
    }

    /// Close out the stage after all cells ran: SP all-reduces its owned
    /// gradients across the mp ring (the seqpar convention — every ring
    /// rank ends with the group sums); TP keeps per-rank shards, merged
    /// host-side at assembly exactly like the pure engine.
    pub(crate) fn finish(self, owned: &[String]) -> Result<(f32, f32, Vec<ParamStore>)> {
        match self {
            Stage::Sp(mut s) => {
                if s.view.world() > 1 {
                    allreduce_named(s.view, &mut s.grads, owned)?;
                }
                Ok((s.mlm, s.sop, s.grads))
            }
            Stage::Tp(s) => Ok((s.mlm, s.sop, s.grads)),
        }
    }
}

/// Merge replica 0's per-stage, per-rank stores (already dp-reduced) into
/// one global-layout store, then average over dp.
fn assemble(
    spec: &MeshSpec,
    params: &ParamStore,
    stage_stores: Vec<Vec<ParamStore>>,
) -> Result<ParamStore> {
    let mut out = params.zeros_like();
    for (s, stores) in stage_stores.iter().enumerate() {
        match spec.mesh.kind {
            MpKind::Sequence => {
                // all ring ranks hold the same sums post all-reduce
                for name in &spec.owned[s] {
                    *out.get_mut(name)? = stores[0].values[name].clone();
                }
            }
            MpKind::Tensor => {
                // disjoint shards + rank-0-only replicated entries: exact
                for name in &spec.owned[s] {
                    for st in stores {
                        ops::add_assign(out.get_mut(name)?, &st.values[name])?;
                    }
                }
            }
        }
    }
    if spec.mesh.dp > 1 {
        for t in out.values.values_mut() {
            ops::scale_assign(t, 1.0 / spec.mesh.dp as f32)?;
        }
    }
    Ok(out)
}

fn output_from(
    spec: &MeshSpec,
    params: &ParamStore,
    replica_mlm: Vec<f32>,
    replica_sop: Vec<f32>,
    stage_stores: Vec<Vec<ParamStore>>,
) -> Result<MeshOutput> {
    let dp = spec.mesh.dp as f32;
    let mlm = replica_mlm.iter().sum::<f32>() / dp;
    let sop = replica_sop.iter().sum::<f32>() / dp;
    let replica_loss: Vec<f32> = replica_mlm
        .iter()
        .zip(&replica_sop)
        .map(|(a, b)| a + b)
        .collect();
    Ok(MeshOutput {
        loss: mlm + sop,
        mlm,
        sop,
        replica_loss,
        grads: assemble(spec, params, stage_stores)?,
    })
}

/// Sequential mesh simulation: every coordinate on the calling thread,
/// model-parallel groups as [`Fabric`] slot views, stage boundaries as
/// buffered queues, GPipe cells executed in start-tick order.
pub struct MeshEngine<'rt> {
    rt: &'rt Runtime,
    spec: MeshSpec,
    pub meter: Arc<Meter>,
}

impl<'rt> MeshEngine<'rt> {
    pub fn new(rt: &'rt Runtime, mesh: Mesh, micros: usize, meter: Arc<Meter>) -> Result<Self> {
        MeshEngine::with_strategy(rt, mesh, micros, meter, SpStrategy::Ring)
    }

    /// Build the simulation with an explicit SP strategy for the
    /// sequence model axis (`--sp`; [`SpStrategy::Ulysses`] runs the
    /// head-shard all-to-alls inside each mp group).
    pub fn with_strategy(
        rt: &'rt Runtime,
        mesh: Mesh,
        micros: usize,
        meter: Arc<Meter>,
        sp: SpStrategy,
    ) -> Result<Self> {
        Ok(MeshEngine { rt, spec: MeshSpec::new(rt, mesh, micros, sp)?, meter })
    }

    /// Enable comm/compute overlap in the sequence axis' dense ring loops
    /// (`--overlap`; no-op for a tensor model axis).  Eager under the
    /// sequential simulation — the knob exists so both backends run the
    /// SAME `StepShape` and stay meter-identical.
    pub fn overlap(mut self, on: bool) -> Self {
        if let Some(sh) = self.spec.sp.as_mut() {
            sh.overlap = on;
        }
        self
    }
}

impl<'rt> MeshStep for MeshEngine<'rt> {
    fn mesh(&self) -> Mesh {
        self.spec.mesh
    }

    fn micros(&self) -> usize {
        self.spec.micros
    }

    fn step(&self, params: &ParamStore, batches: &[Vec<Batch>]) -> Result<MeshOutput> {
        self.spec.check_batches(batches)?;
        let ex = self.rt.backend();
        let mesh = self.spec.mesh;
        let (dp, pp, mp) = (mesh.dp, mesh.pp, mesh.mp);
        let meter: &Meter = &self.meter;
        let mp_view = Fabric::new(mp, self.meter.clone());
        let dp_view = Fabric::new(dp, self.meter.clone());
        // causal execution order: cells sorted by start tick (ties are
        // dataflow-independent; stage order keeps it deterministic)
        let mut cells: Vec<Cell> = Schedule::gpipe(pp, self.spec.micros).cells;
        cells.sort_by_key(|c| (c.start, c.stage));

        let mut replica_mlm = vec![0.0f32; dp];
        let mut replica_sop = vec![0.0f32; dp];
        let mut grads_by: Vec<Vec<Vec<ParamStore>>> = Vec::with_capacity(dp);
        for r in 0..dp {
            let fwd_q: Vec<RefCell<VecDeque<Vec<Tensor>>>> =
                (0..pp.saturating_sub(1)).map(|_| RefCell::new(VecDeque::new())).collect();
            let bwd_q: Vec<RefCell<VecDeque<Vec<Tensor>>>> =
                (0..pp.saturating_sub(1)).map(|_| RefCell::new(VecDeque::new())).collect();
            let mut stages: Vec<Stage> = (0..pp)
                .map(|s| {
                    // aim the stage's charges at its coordinates' global
                    // lanes: rank(Coord{r, s, i}) = ((r*pp)+s)*mp + i
                    mem::set_lane_base(((r * pp) + s) * mp);
                    Stage::new(&self.spec, ex, params, &mp_view, meter, s)
                })
                .collect::<Result<_>>()?;
            for c in &cells {
                let s = c.stage;
                let batch = &batches[r][c.micro];
                mem::set_lane_base(((r * pp) + s) * mp);
                let sp = crate::obs::begin();
                if c.forward {
                    let prev = (s > 0).then(|| Link::Queue { q: &fwd_q[s - 1], meter });
                    let next = (s + 1 < pp).then(|| Link::Queue { q: &fwd_q[s], meter });
                    stages[s].forward_micro(c.micro, batch, prev.as_ref(), next.as_ref())?;
                } else {
                    let prev = (s > 0).then(|| Link::Queue { q: &bwd_q[s - 1], meter });
                    let next = (s + 1 < pp).then(|| Link::Queue { q: &bwd_q[s], meter });
                    stages[s].backward_micro(c.micro, batch, prev.as_ref(), next.as_ref())?;
                }
                sp.end_cell(s, c.micro, c.forward);
            }
            let mut per_stage = Vec::with_capacity(pp);
            for (s, st) in stages.into_iter().enumerate() {
                let (mlm, sop, g) = st.finish(&self.spec.owned[s])?;
                replica_mlm[r] += mlm;
                replica_sop[r] += sop;
                per_stage.push(g);
            }
            grads_by.push(per_stage);
        }
        mem::set_lane_base(0); // back to the session thread's default lanes

        // dp gradient all-reduce: one reduce per (stage, mp-rank) group —
        // the same per-rank traffic the threaded mesh meters
        if dp > 1 {
            for s in 0..pp {
                for i in 0..mp {
                    let mut slots: Vec<ParamStore> = (0..dp)
                        .map(|r| std::mem::take(&mut grads_by[r][s][i]))
                        .collect();
                    allreduce_named(&dp_view, &mut slots, &self.spec.owned[s])?;
                    for (r, g) in slots.into_iter().enumerate() {
                        grads_by[r][s][i] = g;
                    }
                }
            }
        }

        let stage_stores = grads_by.swap_remove(0);
        output_from(&self.spec, params, replica_mlm, replica_sop, stage_stores)
    }
}

/// The threaded 4D mesh runner: one OS thread per mesh coordinate, ring /
/// all-reduce / boundary traffic as real channel messages, each thread
/// executing its stage's projection of the GPipe schedule.
pub struct MeshRunner<'rt> {
    rt: &'rt Runtime,
    spec: MeshSpec,
    pub meter: Arc<Meter>,
    /// Fault injection for the failure-path tests: `(rank, from_step)` —
    /// the mesh rank's thread panics at the start of every step whose
    /// 0-based index on this runner is >= `from_step`.
    inject_fault: Option<(usize, u64)>,
    /// Steps started on this runner; drives step-targeted injection.
    steps_run: AtomicU64,
}

impl<'rt> MeshRunner<'rt> {
    /// Fails up front when the backend cannot cross threads (xla-pjrt).
    pub fn new(rt: &'rt Runtime, mesh: Mesh, micros: usize, meter: Arc<Meter>) -> Result<Self> {
        MeshRunner::with_strategy(rt, mesh, micros, meter, SpStrategy::Ring)
    }

    /// Build the runner with an explicit SP strategy for the sequence
    /// model axis (`--sp`; [`SpStrategy::Ulysses`] runs the head-shard
    /// all-to-alls as real channel messages inside each mp group).
    pub fn with_strategy(
        rt: &'rt Runtime,
        mesh: Mesh,
        micros: usize,
        meter: Arc<Meter>,
        sp: SpStrategy,
    ) -> Result<Self> {
        rt.sync_backend()?;
        Ok(MeshRunner {
            rt,
            spec: MeshSpec::new(rt, mesh, micros, sp)?,
            meter,
            inject_fault: None,
            steps_run: AtomicU64::new(0),
        })
    }

    /// Enable comm/compute overlap in the sequence axis' dense ring loops
    /// (`--overlap`; no-op for a tensor model axis): each mp-ring thread
    /// posts the shift of chunk t+1 before computing on chunk t.  Same
    /// results, bytes and trace events as the blocking schedule.
    pub fn overlap(mut self, on: bool) -> Self {
        if let Some(sh) = self.spec.sp.as_mut() {
            sh.overlap = on;
        }
        self
    }

    /// TESTING the failure path: make mesh rank `rank`'s thread panic at
    /// the start of every subsequent step — peers must error out with the
    /// disconnect named and the join must report this rank, not hang.
    pub fn inject_fault(&mut self, rank: usize) {
        self.inject_fault_at(rank, 0);
    }

    /// Step-targeted fault injection: mesh rank `rank` panics at the
    /// start of the step with 0-based index `step` (counted per runner)
    /// and every step after it — the chaos suite's deterministic trigger.
    pub fn inject_fault_at(&mut self, rank: usize, step: u64) {
        self.inject_fault = Some((rank, step));
    }
}

/// The per-coordinate body: run this stage's schedule cells over the
/// coordinate's mp view, then reduce gradients across dp.
#[allow(clippy::too_many_arguments)]
fn run_coord(
    ex: &dyn Executor,
    spec: &MeshSpec,
    params: &ParamStore,
    replica: &[Batch],
    coord: Coord,
    mpc: &RingComm,
    dpc: &RingComm,
    ppc: &RingComm,
    meter: &Meter,
) -> Result<(f32, f32, ParamStore)> {
    let stage_idx = coord.pp;
    let stages = spec.mesh.pp;
    let mut st = Stage::new(spec, ex, params, mpc, meter, stage_idx)?;
    let prev = (stage_idx > 0).then(|| Link::Comm { comm: ppc, peer: stage_idx - 1 });
    let next = (stage_idx + 1 < stages).then(|| Link::Comm { comm: ppc, peer: stage_idx + 1 });
    // this stage's projection of the GPipe schedule, in start-tick order
    let mut cells: Vec<Cell> = Schedule::gpipe(stages, spec.micros)
        .cells
        .into_iter()
        .filter(|c| c.stage == stage_idx)
        .collect();
    cells.sort_by_key(|c| c.start);
    for c in &cells {
        let sp = crate::obs::begin();
        if c.forward {
            st.forward_micro(c.micro, &replica[c.micro], prev.as_ref(), next.as_ref())?;
        } else {
            st.backward_micro(c.micro, &replica[c.micro], prev.as_ref(), next.as_ref())?;
        }
        sp.end_cell(stage_idx, c.micro, c.forward);
    }
    let (mlm, sop, mut g) = st.finish(&spec.owned[stage_idx])?;
    if spec.mesh.dp > 1 {
        let sp = crate::obs::begin();
        allreduce_named(dpc, &mut g, &spec.owned[stage_idx])?;
        sp.end_phase("grad_allreduce");
    }
    Ok((mlm, sop, g.swap_remove(0)))
}

impl<'rt> MeshStep for MeshRunner<'rt> {
    fn mesh(&self) -> Mesh {
        self.spec.mesh
    }

    fn micros(&self) -> usize {
        self.spec.micros
    }

    fn step(&self, params: &ParamStore, batches: &[Vec<Batch>]) -> Result<MeshOutput> {
        self.spec.check_batches(batches)?;
        let ex = self.rt.sync_backend()?;
        let mesh = self.spec.mesh;
        let (dp, pp, mp) = (mesh.dp, mesh.pp, mesh.mp);
        let world = mesh.world_size();
        let spec = &self.spec;
        let meter: &Meter = &self.meter;

        // carve the sub-communicators from the mesh: one channel group
        // per (dp, pp) mp-ring, per (pp, mp) dp replica set, per (dp, mp)
        // pp column.  Fresh channels every step keep the message schedule
        // identical across steps, so results are bit-deterministic.
        let mut mp_slot: Vec<Option<RingComm>> = (0..world).map(|_| None).collect();
        let mut dp_slot: Vec<Option<RingComm>> = (0..world).map(|_| None).collect();
        let mut pp_slot: Vec<Option<RingComm>> = (0..world).map(|_| None).collect();
        for d in 0..dp {
            for p in 0..pp {
                for (i, c) in comm_mesh(mp, self.meter.clone()).into_iter().enumerate() {
                    mp_slot[mesh.rank(Coord { dp: d, pp: p, mp: i })] = Some(c);
                }
            }
        }
        for p in 0..pp {
            for m in 0..mp {
                for (i, c) in comm_mesh(dp, self.meter.clone()).into_iter().enumerate() {
                    dp_slot[mesh.rank(Coord { dp: i, pp: p, mp: m })] = Some(c);
                }
            }
        }
        for d in 0..dp {
            for m in 0..mp {
                for (i, c) in comm_mesh(pp, self.meter.clone()).into_iter().enumerate() {
                    pp_slot[mesh.rank(Coord { dp: d, pp: i, mp: m })] = Some(c);
                }
            }
        }

        // resolve every coordinate's communicators BEFORE spawning, so a
        // carving bug is a clean Err naming the rank, not a thread panic
        let mut slots: Vec<(Coord, RingComm, RingComm, RingComm)> = Vec::with_capacity(world);
        for rank in 0..world {
            let coord = mesh.coord(rank)?;
            let take = |slot: &mut Vec<Option<RingComm>>, axis: &str| {
                slot[rank]
                    .take()
                    .ok_or_else(|| anyhow!("mesh rank {rank}: no {axis} communicator was carved"))
            };
            let mpc = take(&mut mp_slot, "mp")?;
            let dpc = take(&mut dp_slot, "dp")?;
            let ppc = take(&mut pp_slot, "pp")?;
            slots.push((coord, mpc, dpc, ppc));
        }

        let fh = crate::obs::fork();
        let mfh = mem::fork();
        let step_idx = self.steps_run.fetch_add(1, Ordering::Relaxed);
        let inject = match self.inject_fault {
            Some((rank, from)) if step_idx >= from => Some(rank),
            _ => None,
        };
        let results: Vec<(usize, bool, Result<(f32, f32, ParamStore)>)> = thread::scope(|sc| {
            let mut handles = Vec::with_capacity(world);
            for (rank, (coord, mpc, dpc, ppc)) in slots.into_iter().enumerate() {
                let replica = &batches[coord.dp];
                handles.push(sc.spawn(move || {
                    crate::obs::adopt(fh, rank);
                    // this thread's charges name ranks within its mp view
                    // ([coord.mp]), so base + coord.mp = the global rank
                    mem::adopt(mfh, rank - coord.mp);
                    if inject == Some(rank) {
                        panic!("injected fault on mesh rank {rank} (MeshRunner::inject_fault)");
                    }
                    let out =
                        run_coord(ex, spec, params, replica, coord, &mpc, &dpc, &ppc, meter);
                    crate::obs::flush();
                    (rank, out)
                }));
            }
            // Handles are in rank order; join EVERY one so a dead rank
            // becomes a named error, never a hang (its dropped channel
            // endpoints error out the peers' blocked recvs).
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| match h.join() {
                    Ok((r, out)) => (r, false, out),
                    Err(_) => {
                        (rank, true, Err(anyhow!("mesh rank {rank}: thread panicked mid-step")))
                    }
                })
                .collect()
        });

        // A panicked rank is the root cause; its peers' "peer
        // disconnected" errors are downstream symptoms of the same death.
        // Returned as the structured [`RankFailure`] so `exec::recovery`
        // can downcast and reshard instead of string-matching.
        if let Some((rank, ..)) = results.iter().find(|(_, panicked, _)| *panicked) {
            return Err(RankFailure::mesh(*rank, world).into());
        }

        let mut replica_mlm = vec![0.0f32; dp];
        let mut replica_sop = vec![0.0f32; dp];
        let mut stage_stores: Vec<Vec<Option<ParamStore>>> =
            (0..pp).map(|_| (0..mp).map(|_| None).collect()).collect();
        let mut seen = vec![false; world];
        for (rank, _, res) in results {
            let out = res.map_err(|e| anyhow!("mesh coordinate {rank}: {e}"))?;
            if rank >= world || seen[rank] {
                bail!("mesh runner joined an unexpected rank {rank}");
            }
            seen[rank] = true;
            let c = mesh.coord(rank)?;
            replica_mlm[c.dp] += out.0;
            replica_sop[c.dp] += out.1;
            if c.dp == 0 {
                stage_stores[c.pp][c.mp] = Some(out.2);
            }
        }
        let stage_stores: Vec<Vec<ParamStore>> = stage_stores
            .into_iter()
            .enumerate()
            .map(|(s, row)| {
                row.into_iter()
                    .enumerate()
                    .map(|(i, g)| {
                        g.ok_or_else(|| anyhow!("stage {s} mp-rank {i} produced no gradients"))
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<_>>()?;
        output_from(spec, params, replica_mlm, replica_sop, stage_stores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeConfig;
    use crate::exec::DistRunner;
    use crate::train::data::{Corpus, CorpusConfig};

    fn batches(rt: &Runtime, dp: usize, micros: usize, seed: u64) -> Vec<Vec<Batch>> {
        let m = rt.manifest();
        let mut c = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
        (0..dp)
            .map(|_| (0..micros).map(|_| c.next_batch().unwrap()).collect())
            .collect()
    }

    /// Smoke: at dp=pp=1 the threaded mesh IS the pure-SP threaded
    /// runner (the full matrix lives in rust/tests/mesh_equivalence.rs).
    #[test]
    fn unit_mesh_matches_dist_runner_loss() {
        let rt = Runtime::native(NativeConfig { ring: 2, ..NativeConfig::tiny() }).unwrap();
        let params = ParamStore::synthetic(rt.manifest());
        let b = batches(&rt, 1, 1, 11);

        let mesh = Mesh::new(1, 1, 2, MpKind::Sequence).unwrap();
        let runner = MeshRunner::new(&rt, mesh, 1, Meter::new()).unwrap();
        let out = runner.step(&params, &b).unwrap();

        let dist = DistRunner::new(&rt, Meter::new()).unwrap();
        let want = dist.forward_backward(&params, &b[0][0]).unwrap();
        assert!(
            (out.loss - want.loss).abs() < 1e-5,
            "mesh {} vs dist {}",
            out.loss,
            want.loss
        );
    }

    /// The Ulysses strategy runs under both mesh backends: the unit mesh
    /// matches the pure threaded runner, and the sequential simulation
    /// meters the identical all-to-all traffic.
    #[test]
    fn unit_mesh_runs_ulysses_strategy() {
        let rt = Runtime::native(NativeConfig { ring: 2, ulysses: true, ..NativeConfig::tiny() })
            .unwrap();
        let params = ParamStore::synthetic(rt.manifest());
        let b = batches(&rt, 1, 1, 11);
        let mesh = Mesh::new(1, 1, 2, MpKind::Sequence).unwrap();

        let thr_meter = Meter::new();
        let runner =
            MeshRunner::with_strategy(&rt, mesh, 1, thr_meter.clone(), SpStrategy::Ulysses)
                .unwrap();
        let out = runner.step(&params, &b).unwrap();

        let dist =
            DistRunner::with_strategy(&rt, Meter::new(), AttnPattern::Dense, SpStrategy::Ulysses)
                .unwrap();
        let want = dist.forward_backward(&params, &b[0][0]).unwrap();
        assert!(
            (out.loss - want.loss).abs() < 1e-5,
            "mesh {} vs dist {}",
            out.loss,
            want.loss
        );
        assert!(thr_meter.get(CommKind::AllToAll) > 0, "mesh step moved no all-to-all bytes");
        assert_eq!(thr_meter.get(CommKind::RingP2p), 0, "ulysses mesh rang the ring");

        let sim_meter = Meter::new();
        let engine =
            MeshEngine::with_strategy(&rt, mesh, 1, sim_meter.clone(), SpStrategy::Ulysses)
                .unwrap();
        let sim = engine.step(&params, &b).unwrap();
        assert!((sim.loss - out.loss).abs() < 1e-5, "sim {} vs threaded {}", sim.loss, out.loss);
        assert_eq!(sim_meter.snapshot(), thr_meter.snapshot(), "mesh meters diverged");

        // a tensor-parallel model axis refuses the flag
        assert!(MeshRunner::with_strategy(
            &rt,
            Mesh::new(1, 1, 2, MpKind::Tensor).unwrap(),
            1,
            Meter::new(),
            SpStrategy::Ulysses
        )
        .is_err());
    }

    #[test]
    fn spec_rejects_bad_shapes() {
        let rt = Runtime::native(NativeConfig { ring: 2, ..NativeConfig::tiny() }).unwrap();
        // micros = 0
        assert!(MeshRunner::new(&rt, Mesh::new(1, 1, 2, MpKind::Sequence).unwrap(), 0, Meter::new()).is_err());
        // pp does not divide the layer count (bert-tiny has 2 layers)
        assert!(MeshRunner::new(&rt, Mesh::new(1, 3, 2, MpKind::Sequence).unwrap(), 1, Meter::new()).is_err());
        // SP mp must match the manifest ring
        assert!(MeshRunner::new(&rt, Mesh::new(1, 1, 4, MpKind::Sequence).unwrap(), 1, Meter::new()).is_err());
        // TP mp above the head count hits Megatron's cap (bert-tiny: 2)
        assert!(MeshRunner::new(&rt, Mesh::new(1, 1, 4, MpKind::Tensor).unwrap(), 1, Meter::new()).is_err());
        // batch-shape validation
        let runner =
            MeshRunner::new(&rt, Mesh::new(2, 1, 2, MpKind::Sequence).unwrap(), 2, Meter::new())
                .unwrap();
        let params = ParamStore::synthetic(rt.manifest());
        let b = batches(&rt, 1, 2, 3); // one replica short
        assert!(runner.step(&params, &b).is_err());
    }
}
