//! `exec` — the threaded distributed execution layer.
//!
//! Everything below `parallel` *simulates* a device group on one thread:
//! correct schedules, correct metered traffic, zero wall-clock overlap.
//! This module is the step from simulator to system — the paper's actual
//! premise of N devices each working on its own sequence chunk while K/V
//! chunks stream around the ring:
//!
//! * [`DistRunner`] spawns **one OS thread per rank**; each thread owns
//!   its shard of the batch and drives the full per-rank step
//!   (`qkv → ring score accumulation → ring context → MLP →
//!   hand-scheduled ring backward`, or the Ulysses all-to-all schedule
//!   under `--sp ulysses`) against its own
//!   [`crate::comm::threaded::RingComm`];
//! * ring exchanges are real P2P messages between concurrently running
//!   threads, so RSA stages 1–2 (and the backward rings) overlap compute
//!   with communication exactly the way Ring Attention-style systems do;
//! * parameter gradients are combined with a threaded ring
//!   `all_reduce_sum`, after which every rank holds the global sums.
//!
//! The per-rank step logic is the SAME function the sequential
//! [`crate::parallel::sequence::SeqParEngine`] drives over the `Fabric`
//! slot view — `rust/tests/dist_equivalence.rs` pins loss/grad agreement
//! (and byte-for-byte meter agreement) between the two executions, and
//! `benches/dist_speedup.rs` measures the wall-clock win.
//!
//! [`MeshRunner`] generalizes the same idea to the full 4D mesh
//! (DP×PP×SP, plus the DP×PP×TP baseline): one OS thread per mesh
//! coordinate, sub-communicators carved per mesh axis, a real GPipe
//! microbatch pipeline across stages — see [`mesh`](self::MeshRunner).
//!
//! [`recovery`] closes the loop on rank death: when either runner
//! surfaces a [`RankFailure`], the [`Elastic`] driver snapshots training
//! state through an in-memory checkpoint, re-carves a valid topology
//! from the survivors, and resumes — bit-equivalent to a clean resume
//! from the same checkpoint (`rust/tests/chaos_props.rs`).
//!
//! Requires a `Send + Sync` backend: the default native backend qualifies;
//! the `backend-xla` PJRT backend (Rc-based, thread-local handles) is
//! rejected at construction with a pointer at `--backend native`.

pub(crate) mod mesh;
pub mod recovery;
mod runner;

pub use mesh::{MeshEngine, MeshOutput, MeshRunner, MeshStep};
pub use recovery::{
    Elastic, ElasticConfig, ElasticOutcome, RankFailure, RecoverPolicy, RecoveryEvent, Topo,
};
pub use runner::DistRunner;
