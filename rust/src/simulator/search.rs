//! Max-batch / max-sequence-length searches under the device memory budget
//! (the paper's Figs. 3a, 4a, 5a, 7a, 8a, 9 are all searches of this kind:
//! "increase until CUDA OOM").

use super::{memory, Cluster, RunShape, Strategy};
use crate::model::ModelConfig;

/// Does this run shape fit in device memory under the strategy?
pub fn fits(cluster: &Cluster, shape: &RunShape, strategy: Strategy) -> bool {
    strategy.feasible(&shape.model, shape.seq_len)
        && memory::peak_bytes(shape, strategy) <= cluster.gpu_mem
}

/// Largest batch size that fits (exponential probe + binary search).
/// Returns 0 if even batch 1 OOMs.
pub fn max_batch(
    cluster: &Cluster,
    model: ModelConfig,
    seq_len: usize,
    pipeline: usize,
    micros: usize,
    strategy: Strategy,
) -> usize {
    let shape = |b: usize| {
        RunShape::new(model, b, seq_len).with_pipeline(pipeline, micros)
    };
    if !fits(cluster, &shape(1), strategy) {
        return 0;
    }
    let mut hi = 1usize;
    while fits(cluster, &shape(hi * 2), strategy) {
        hi *= 2;
        if hi > 1 << 22 {
            break; // guard absurd growth
        }
    }
    let mut lo = hi; // lo fits
    let mut top = hi * 2; // top does not
    while top - lo > 1 {
        let mid = (lo + top) / 2;
        if fits(cluster, &shape(mid), strategy) {
            lo = mid;
        } else {
            top = mid;
        }
    }
    lo
}

/// Largest sequence length that fits, searched over multiples of `step`
/// (sequence parallelism additionally requires L % N == 0, which holds
/// when step is a multiple of N).
pub fn max_seq_len(
    cluster: &Cluster,
    model: ModelConfig,
    batch: usize,
    pipeline: usize,
    micros: usize,
    strategy: Strategy,
    step: usize,
) -> usize {
    let step = match strategy {
        Strategy::Sequence { n } | Strategy::Ulysses { n } => step.max(1).next_multiple_of(n),
        _ => step.max(1),
    };
    let shape = |l: usize| {
        RunShape::new(model, batch, l).with_pipeline(pipeline, micros)
    };
    if !fits(cluster, &shape(step), strategy) {
        return 0;
    }
    let mut hi = 1usize;
    while fits(cluster, &shape(hi * 2 * step), strategy) {
        hi *= 2;
        if hi > 1 << 22 {
            break;
        }
    }
    let mut lo = hi;
    let mut top = hi * 2;
    while top - lo > 1 {
        let mid = (lo + top) / 2;
        if fits(cluster, &shape(mid * step), strategy) {
            lo = mid;
        } else {
            top = mid;
        }
    }
    lo * step
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BERT_BASE;
    use crate::util::prop::Prop;

    fn c() -> Cluster {
        Cluster::default()
    }

    #[test]
    fn seqpar_max_batch_grows_with_devices() {
        // Fig. 3a: SP max batch rises with ring size.
        let b4 = max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Sequence { n: 4 });
        let b16 = max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Sequence { n: 16 });
        let b64 = max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Sequence { n: 64 });
        assert!(b4 > 0 && b16 > b4 && b64 > b16, "{b4} {b16} {b64}");
    }

    #[test]
    fn tensor_parallelism_capped_by_heads() {
        // BERT-Base has 12 heads: TP 16 infeasible, TP 12 fine (§4.2).
        assert_eq!(
            max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Tensor { n: 16 }),
            0
        );
        assert!(max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Tensor { n: 12 }) > 0);
    }

    #[test]
    fn headline_13_7x_direction() {
        // Fig. 3a headline: SP@64 vs TP@12 max batch should be a large
        // multiple (paper: 13.7x on hardware).
        let sp64 = max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Sequence { n: 64 });
        let tp12 = max_batch(&c(), BERT_BASE, 512, 1, 1, Strategy::Tensor { n: 12 });
        let ratio = sp64 as f64 / tp12 as f64;
        assert!(ratio > 4.0, "SP@64 / TP@12 batch ratio only {ratio}");
    }

    #[test]
    fn max_seq_len_respects_ring_divisibility() {
        Prop::new(24, 5).check("seqlen divisible by ring", |rng| {
            let n = 1usize << rng.below(5);
            let l = max_seq_len(&c(), BERT_BASE, 4, 1, 1, Strategy::Sequence { n }, 32);
            if l == 0 || l % n == 0 {
                Ok(())
            } else {
                Err(format!("L={l} not divisible by ring {n}"))
            }
        });
    }

    #[test]
    fn search_result_is_tight() {
        Prop::new(16, 9).check("max_batch is maximal", |rng| {
            let n = 1usize << rng.below(4);
            let strat = Strategy::Sequence { n };
            let b = max_batch(&c(), BERT_BASE, 512, 1, 1, strat);
            let fits_b = fits(&c(), &RunShape::new(BERT_BASE, b, 512), strat);
            let fits_b1 = fits(&c(), &RunShape::new(BERT_BASE, b + 1, 512), strat);
            if fits_b && !fits_b1 {
                Ok(())
            } else {
                Err(format!("n={n}: b={b} fits={fits_b}, b+1 fits={fits_b1}"))
            }
        });
    }
}
