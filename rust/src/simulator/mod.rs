//! The P100-cluster simulator.
//!
//! The paper's scaling experiments ran on up to 64 NVIDIA P100s (16 GB) on
//! Piz Daint.  We have one CPU host, so the *scale* dimension is
//! reproduced by this simulator (DESIGN.md §2 documents the substitution):
//!
//! * [`memory`] — a per-device byte LEDGER enumerating every tensor the
//!   real engines allocate (parameters + grads + Adam states, per-layer
//!   activation stashes — the same `LayerStash` fields the rust engines
//!   keep — and transients).  "OOM" = ledger exceeds 16 GiB.  The paper's
//!   Tables 1–2 closed forms are implemented alongside and tested to agree
//!   with the ledger's corresponding terms.
//! * [`timing`] — an analytic step-time model (GEMM flops at calibrated
//!   P100 efficiency + collective bytes over the interconnect + pipeline
//!   bubble), giving the tokens/sec curves of Figs. 3b/4b/7b/8b.
//! * [`search`] — max-batch / max-seq-len searches under the memory budget
//!   (Figs. 3a/4a/5a/7a/8a/9).
//! * [`sparse`] — the Linformer + sequence-parallelism memory model
//!   (Table 3) and the Fig. 5b length upper bound.

pub mod memory;
pub mod search;
pub mod sparse;
pub mod timing;

use crate::model::ModelConfig;

/// Hardware constants of the simulated testbed.
#[derive(Clone, Copy, Debug)]
pub struct Cluster {
    /// Device memory budget (bytes).  P100: 16 GB.
    pub gpu_mem: u64,
    /// Peak FLOP/s.  P100: 18.7e12 fp16 / 9.3e12 fp32 (paper-era Megatron
    /// trains BERT in fp16 via apex; we model the fp16 peak).
    pub peak_flops: f64,
    /// Achieved-fraction for transformer GEMMs at these sizes (calibrated
    /// so serial BERT-Base tokens/s lands near Table 4 row 1: ~9.9k tok/s).
    pub efficiency: f64,
    /// Interconnect bandwidth per link, bytes/s (Piz Daint Aries ~ 8 GB/s).
    pub link_bw: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl Default for Cluster {
    fn default() -> Self {
        Cluster {
            gpu_mem: 16 * (1 << 30),
            peak_flops: 18.7e12,
            efficiency: 0.35,
            link_bw: 8.0e9,
            latency: 5.0e-6,
        }
    }
}

/// Which model-parallel strategy occupies the devices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Megatron tensor parallelism of size n (must divide heads & FFN).
    Tensor { n: usize },
    /// Ring sequence parallelism of size n (must divide the sequence
    /// length).
    Sequence { n: usize },
    /// Ulysses all-to-all sequence parallelism of size n.  Memory is
    /// identical to ring SP (the head-sharded stash holds the same
    /// element count as the sequence-sharded one — pinned by a unit
    /// test in [`memory`]); only the collective schedule differs, so
    /// [`timing`] gives it its own comm arm while [`memory`] shares the
    /// `Sequence` forms.  Feasibility additionally needs the head count
    /// divisible (heads are resharded across ranks mid-attention).
    Ulysses { n: usize },
}

impl Strategy {
    pub fn n(&self) -> usize {
        match self {
            Strategy::Tensor { n } | Strategy::Sequence { n } | Strategy::Ulysses { n } => *n,
        }
    }

    /// Is this strategy feasible for the model/run shape at all?
    /// Encodes Megatron's head-count cap the paper exploits (§4.2).
    pub fn feasible(&self, cfg: &ModelConfig, seq_len: usize) -> bool {
        match self {
            Strategy::Tensor { n } => cfg.heads % n == 0 && cfg.ffn() % n == 0 && *n <= cfg.heads,
            Strategy::Sequence { n } => seq_len % n == 0,
            Strategy::Ulysses { n } => {
                seq_len % n == 0 && cfg.heads % n == 0 && *n <= cfg.heads
            }
        }
    }
}

/// One simulated run shape.
#[derive(Clone, Copy, Debug)]
pub struct RunShape {
    pub model: ModelConfig,
    pub batch: usize,
    pub seq_len: usize,
    /// Pipeline stages (1 = no pipeline).  Layers are split evenly.
    pub pipeline: usize,
    /// Micro-batches per pipeline flush (GPipe).
    pub micros: usize,
}

impl RunShape {
    pub fn new(model: ModelConfig, batch: usize, seq_len: usize) -> RunShape {
        RunShape { model, batch, seq_len, pipeline: 1, micros: 1 }
    }

    pub fn with_pipeline(mut self, stages: usize, micros: usize) -> RunShape {
        self.pipeline = stages;
        self.micros = micros;
        self
    }

    /// Layers resident on one pipeline stage (ceil division — the paper
    /// balances stages evenly, BERT layer counts divide cleanly).
    pub fn layers_per_stage(&self) -> usize {
        self.model.layers.div_ceil(self.pipeline)
    }
}
