//! Analytic step-time model: GEMM flops at calibrated efficiency +
//! collective traffic over the interconnect + pipeline bubble.
//!
//! Not a cycle simulator — a roofline-style schedule model.  Its job is
//! the SHAPE of the paper's throughput curves (who wins, where curves
//! bend), not absolute numbers; the calibration constant is chosen once so
//! the serial BERT-Base point lands near Table 4's measured ~9.9k tok/s,
//! then never touched per-experiment.

use anyhow::{bail, Result};

use super::{Cluster, RunShape, Strategy};
use crate::parallel::pipeline::{boundary_bytes_megatron, boundary_bytes_seqpar, Schedule};

/// Forward GEMM flops for one transformer layer on ONE device.
fn layer_flops(shape: &RunShape, strategy: Strategy) -> f64 {
    let m = &shape.model;
    let (h, f) = (m.hidden as f64, m.ffn() as f64);
    let (z, a) = (m.heads as f64, m.head_dim as f64);
    let b = shape.batch as f64;
    let l = shape.seq_len as f64;
    match strategy {
        Strategy::Sequence { n } | Strategy::Ulysses { n } => {
            let n = n as f64;
            let tok = b * l / n;
            // qkv + out proj on the chunk; attention spans the FULL row
            // (the ring brings every key/value chunk through the device;
            // Ulysses computes Z/N whole heads over L×L — same flops)
            2.0 * tok * h * h * 4.0
                + 2.0 * b * z * (l / n) * l * a * 2.0  // scores + AV
                + 2.0 * tok * h * f * 2.0 // mlp
        }
        Strategy::Tensor { n } => {
            let n = n as f64;
            let tok = b * l;
            2.0 * tok * h * (h / n) * 4.0
                + 2.0 * b * (z / n) * l * l * a * 2.0
                + 2.0 * tok * h * (f / n) * 2.0
        }
    }
}

/// Bytes each device sends per layer, forward+backward.
fn layer_comm_bytes(shape: &RunShape, strategy: Strategy) -> f64 {
    let m = &shape.model;
    let h = m.hidden as f64;
    let (z, a) = (m.heads as f64, m.head_dim as f64);
    let b = shape.batch as f64;
    let l = shape.seq_len as f64;
    match strategy {
        Strategy::Sequence { n } => {
            let n_ = n as f64;
            if n == 1 {
                return 0.0;
            }
            // §3.2.2: 2(N-1) chunk sends fwd + 6(N-1) bwd, chunk = BZ(L/N)A
            // — exactly equal to Megatron's total below (the paper's point).
            let chunk = b * z * (l / n_) * a * 4.0;
            8.0 * (n_ - 1.0) * chunk
        }
        Strategy::Ulysses { n } => {
            let n_ = n as f64;
            if n == 1 {
                return 0.0;
            }
            // 8 all-to-alls of the local chunk per layer (q/k/v/ctx fwd +
            // grads bwd): group total 8(N-1)·chunk (analysis::closed_form),
            // so each device ships 8(N-1)/N·chunk — strictly below the
            // ring's 8(N-1)·chunk per device.
            let chunk = b * z * (l / n_) * a * 4.0;
            8.0 * (n_ - 1.0) / n_ * chunk
        }
        Strategy::Tensor { n } => {
            let n_ = n as f64;
            if n == 1 {
                return 0.0;
            }
            // 4 ring all-reduces (2 fwd + 2 bwd) of the [B, L, H] activation:
            // 2(N-1)/N * C each
            let c = b * l * h * 4.0;
            4.0 * 2.0 * (n_ - 1.0) / n_ * c
        }
    }
}

/// Per-layer collective COUNT (latency term).
fn layer_comm_msgs(_shape: &RunShape, strategy: Strategy) -> f64 {
    match strategy {
        Strategy::Sequence { n } | Strategy::Ulysses { n } => {
            if n == 1 { 0.0 } else { 8.0 * (n - 1) as f64 }
        }
        Strategy::Tensor { n } => {
            if n == 1 { 0.0 } else { 4.0 * 2.0 * (n - 1) as f64 }
        }
    }
}

/// Seconds for one optimizer step (fwd + bwd over all layers + pipeline).
///
/// Degenerate shapes (`pipeline == 0`, `micros == 0`, a strategy with
/// `n() == 0`) are rejected with an error rather than silently producing
/// NaN/∞ curves that would leak into the BENCH JSON artifacts.
pub fn step_time(cluster: &Cluster, shape: &RunShape, strategy: Strategy) -> Result<f64> {
    let mp = strategy.n();
    if mp == 0 {
        bail!("degenerate strategy {strategy:?}: model-parallel size 0 (need n >= 1)");
    }
    if shape.pipeline == 0 {
        bail!("degenerate run shape: pipeline=0 (a run has at least 1 stage)");
    }
    if shape.micros == 0 {
        bail!("degenerate run shape: micros=0 (a step has at least 1 microbatch)");
    }
    let layers = shape.model.layers as f64;
    let achieved = cluster.peak_flops * cluster.efficiency;
    // backward ~ 2x forward flops
    let compute_per_layer = 3.0 * layer_flops(shape, strategy) / achieved;
    let comm_per_layer = layer_comm_bytes(shape, strategy) / cluster.link_bw
        + layer_comm_msgs(shape, strategy) * cluster.latency;
    let per_layer = compute_per_layer + comm_per_layer;

    if shape.pipeline == 1 {
        return Ok(layers * per_layer);
    }
    // GPipe: per-microbatch stage time, bubble from the schedule, plus the
    // stage-boundary traffic (where SP saves Megatron's split+gather).
    let stages = shape.pipeline;
    let micros = shape.micros;
    let stage_layers = layers / stages as f64;
    let micro_stage_time = stage_layers * per_layer / micros as f64;
    let sched = Schedule::gpipe(stages, micros);
    let ticks = sched.makespan(2) as f64 / 3.0; // fwd=1 bwd=2 normalized
    let pipe_time = ticks * micro_stage_time;
    let bnd = match strategy {
        Strategy::Tensor { .. } => {
            boundary_bytes_megatron(shape.batch, shape.seq_len, shape.model.hidden, mp)
        }
        Strategy::Sequence { .. } | Strategy::Ulysses { .. } => {
            boundary_bytes_seqpar(shape.batch, shape.seq_len, shape.model.hidden, mp)
        }
    };
    // Per-rank WIRE bytes per crossing: send/mp (each rank ships its 1/mp
    // slice) plus this rank's share of the ring all-gather, gather/mp —
    // with the group-total closed forms this is C for Megatron and C/mp
    // for sequence parallelism.  The scatter is a local slice: the comm
    // Meter charges it as §3.2.2 traffic volume, but it costs no link
    // time, so it does not appear here.
    let bnd_bytes = (bnd.send + bnd.gather) as f64 / mp as f64;
    let boundary_time =
        (stages - 1) as f64 * (bnd_bytes / cluster.link_bw + cluster.latency) * 2.0; // fwd+bwd
    Ok(pipe_time + boundary_time)
}

/// Tokens processed per second for the GLOBAL batch.  Errors on the same
/// degenerate shapes as [`step_time`].
pub fn tokens_per_sec(cluster: &Cluster, shape: &RunShape, strategy: Strategy) -> Result<f64> {
    let tokens = (shape.batch * shape.seq_len) as f64;
    Ok(tokens / step_time(cluster, shape, strategy)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BERT_BASE;

    fn cluster() -> Cluster {
        Cluster::default()
    }

    #[test]
    fn serial_baseline_near_table4() {
        // Table 4 row 1: parallel size 1, batch 64, L=512 → ~9.9k tokens/s.
        let shape = RunShape::new(BERT_BASE, 64, 512);
        let tps = tokens_per_sec(&cluster(), &shape, Strategy::Sequence { n: 1 }).unwrap();
        assert!(
            (5_000.0..20_000.0).contains(&tps),
            "serial BERT-Base {tps} tok/s should be near the paper's ~9.9k"
        );
    }

    #[test]
    fn throughput_scales_with_devices() {
        // Table 4: 2 devices ~1.5x, 4 devices ~2.1x (sub-linear but rising)
        let c = cluster();
        let shape = |b| RunShape::new(BERT_BASE, b, 512);
        let t1 = tokens_per_sec(&c, &shape(64), Strategy::Sequence { n: 1 }).unwrap();
        let t2 = tokens_per_sec(&c, &shape(128), Strategy::Sequence { n: 2 }).unwrap();
        let t4 = tokens_per_sec(&c, &shape(256), Strategy::Sequence { n: 4 }).unwrap();
        assert!(t2 > 1.2 * t1, "2-device weak scaling {t2} vs {t1}");
        assert!(t4 > t2, "4-device {t4} vs {t2}");
        assert!(t2 < 2.0 * t1, "comm must cost something");
    }

    #[test]
    fn comparable_throughput_same_parallel_size() {
        // Fig. 3b: SP ≈ TP at the same parallel size (within ~25%).
        let c = cluster();
        let shape = RunShape::new(BERT_BASE, 16, 512);
        for n in [2usize, 4] {
            let sp = tokens_per_sec(&c, &shape, Strategy::Sequence { n }).unwrap();
            let tp = tokens_per_sec(&c, &shape, Strategy::Tensor { n }).unwrap();
            let ratio = sp / tp;
            assert!((0.6..1.6).contains(&ratio), "n={n}: SP/TP ratio {ratio}");
        }
    }

    #[test]
    fn ulysses_no_slower_than_ring() {
        // Same flops and message count, strictly fewer per-device bytes
        // (8(N-1)/N vs 8(N-1) chunks), so the analytic step time can
        // only improve.
        let c = cluster();
        let shape = RunShape::new(BERT_BASE, 16, 512);
        for n in [2usize, 4] {
            let uly = step_time(&c, &shape, Strategy::Ulysses { n }).unwrap();
            let ring = step_time(&c, &shape, Strategy::Sequence { n }).unwrap();
            assert!(uly <= ring, "n={n}: ulysses {uly}s vs ring {ring}s");
        }
        assert_eq!(
            step_time(&c, &shape, Strategy::Ulysses { n: 1 }).unwrap(),
            step_time(&c, &shape, Strategy::Sequence { n: 1 }).unwrap(),
            "serial: identical model"
        );
    }

    #[test]
    fn seqpar_pipeline_beats_megatron_pipeline() {
        // Fig. 4b: with MP size 4 fixed, SP throughput >= TP as stages grow
        // (Megatron pays split+gather at each boundary).
        let c = cluster();
        for stages in [2usize, 4, 8] {
            let shape = RunShape::new(BERT_BASE, 32, 512).with_pipeline(stages, 8);
            let sp = step_time(&c, &shape, Strategy::Sequence { n: 4 }).unwrap();
            let tp = step_time(&c, &shape, Strategy::Tensor { n: 4 }).unwrap();
            assert!(
                sp <= tp,
                "stages={stages}: SP {sp}s should not exceed TP {tp}s"
            );
        }
    }

    #[test]
    fn more_microbatches_less_bubble_time() {
        let c = cluster();
        let few = RunShape::new(BERT_BASE, 32, 512).with_pipeline(4, 2);
        let many = RunShape::new(BERT_BASE, 32, 512).with_pipeline(4, 16);
        assert!(
            step_time(&c, &many, Strategy::Sequence { n: 4 }).unwrap()
                < step_time(&c, &few, Strategy::Sequence { n: 4 }).unwrap()
        );
    }

    #[test]
    fn degenerate_shapes_error_not_nan() {
        // stages=0, micros=0 and mp=0 used to divide straight through and
        // emit NaN curves; they must be clean errors now.
        let c = cluster();
        let mut stages0 = RunShape::new(BERT_BASE, 8, 512);
        stages0.pipeline = 0;
        let err = step_time(&c, &stages0, Strategy::Sequence { n: 2 }).unwrap_err();
        assert!(err.to_string().contains("pipeline=0"), "got: {err}");

        let mut micros0 = RunShape::new(BERT_BASE, 8, 512).with_pipeline(2, 4);
        micros0.micros = 0;
        let err = step_time(&c, &micros0, Strategy::Sequence { n: 2 }).unwrap_err();
        assert!(err.to_string().contains("micros=0"), "got: {err}");

        let shape = RunShape::new(BERT_BASE, 8, 512);
        for strat in [
            Strategy::Sequence { n: 0 },
            Strategy::Ulysses { n: 0 },
            Strategy::Tensor { n: 0 },
        ] {
            let err = step_time(&c, &shape, strat).unwrap_err();
            assert!(err.to_string().contains("model-parallel size 0"), "got: {err}");
            assert!(tokens_per_sec(&c, &shape, strat).is_err());
        }
        // the guards must not reject healthy shapes
        assert!(step_time(&c, &shape, Strategy::Sequence { n: 2 }).unwrap().is_finite());
    }
}
