//! Linformer + sequence parallelism (paper §4.3, Table 3, Fig. 5b).
//!
//! With the K-dim projection, EVERY L-carrying memory term is divided by
//! the device count N (Table 3) — so the reachable sequence length scales
//! ~linearly with devices ("train with infinite long sequence").  This
//! module implements Table 3's accounting plus the full-attention
//! comparison for the Fig. 5b upper-bound curve.

use super::{memory, Cluster, RunShape, Strategy};

/// Table 3 element count for the sparse attention block per device:
/// 2AZH + 2BZLA/N + BZLK/N + BLH/N + 2BZKA/N.
pub fn paper_sparse_attn(b: u64, l: u64, h: u64, a: u64, z: u64, k: u64, n: u64) -> u64 {
    2 * a * z * h + 2 * b * z * l * a / n + b * z * l * k / n + b * l * h / n
        + 2 * b * z * k * a / n
}

/// Per-device peak bytes with Linformer attention under sequence
/// parallelism: like the dense ledger but the score rows are [Lc, K]
/// instead of [Lc, L] and K/V are projected to K rows.
pub fn peak_bytes_linformer(shape: &RunShape, n: usize, k_proj: usize) -> u64 {
    let m = &shape.model;
    let (h, f) = (m.hidden as u64, m.ffn() as u64);
    let (z, a) = (m.heads as u64, m.head_dim as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    let nn = n as u64;
    let k = k_proj as u64;
    let lc = l / nn;
    let tok = b * lc;
    let layers = shape.layers_per_stage() as u64;
    // dense ledger with the quadratic term replaced by the projected one
    let stash = tok * h
        + 3 * b * z * lc * a          // q, k, v (pre-projection)
        + 2 * b * z * k * a           // projected K, V
        + b * z * lc * k              // score rows [Lc, K]  <- was [Lc, L]
        + b * z * lc * a              // ctx
        + 3 * tok * h
        + tok * f;
    let dense = memory::breakdown(shape, Strategy::Sequence { n });
    // params gain the projection matrices E_k/E_v: 2 * K * L elements
    // (shared across heads, split over devices: K * Lc each)
    let proj_params = 2 * k * lc * 4 * 4;
    let transients = 2 * tok * m.vocab as u64 + b * z * lc * k + tok * h;
    dense.param_state + proj_params + layers * stash * 4 + transients * 4
}

/// Largest sequence length under Linformer + SP, searched over multiples
/// of `step`.
///
/// Sequence parallelism needs `L % n == 0`, so `step` is first rounded UP
/// to a multiple of `n` (a `step` the caller picked without thinking
/// about the ring still yields a valid ring-divisible answer — the
/// returned length is a multiple of BOTH the rounded step and `n`).
/// Returns 0 when even one rounded step does not fit.
pub fn max_seq_len_linformer(
    cluster: &Cluster,
    model: crate::model::ModelConfig,
    batch: usize,
    n: usize,
    k_proj: usize,
    step: usize,
) -> usize {
    let n = n.max(1);
    let step = step.max(1).next_multiple_of(n);
    let fits = |l: usize| {
        let shape = RunShape::new(model, batch, l);
        peak_bytes_linformer(&shape, n, k_proj) <= cluster.gpu_mem
    };
    if !fits(step) {
        return 0;
    }
    // exponential probe (guard before the multiply so the probe cannot
    // overflow on absurd budgets), then binary search on step multiples
    let mut hi = 1usize;
    while hi <= 1 << 24 && fits(hi * 2 * step) {
        hi *= 2;
    }
    let (mut lo, mut top) = (hi, hi * 2);
    while top - lo > 1 {
        let mid = (lo + top) / 2;
        if fits(mid * step) {
            lo = mid;
        } else {
            top = mid;
        }
    }
    lo * step
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BERT_BASE;

    #[test]
    fn table3_every_l_term_divided_by_n() {
        // Doubling N must (asymptotically) halve the L-dependent part.
        let f = |n| paper_sparse_attn(4, 65536, 768, 64, 12, 256, n);
        let fixed = 2 * 64 * 12 * 768; // the only N-free term: 2AZH
        let l8 = f(8) - fixed;
        let l16 = f(16) - fixed;
        assert_eq!(l8 / 2, l16, "L-terms must scale 1/N");
    }

    #[test]
    fn fig5b_near_ideal_scaling() {
        // Fig. 5b: sparse + SP length upper bound scales ~linearly with
        // devices (ideal scaling), unlike dense attention.
        let c = Cluster::default();
        let l8 = max_seq_len_linformer(&c, BERT_BASE, 4, 8, 256, 256);
        let l16 = max_seq_len_linformer(&c, BERT_BASE, 4, 16, 256, 256);
        let l32 = max_seq_len_linformer(&c, BERT_BASE, 4, 32, 256, 256);
        let r = l32 as f64 / l8 as f64;
        assert!(
            (2.8..4.5).contains(&r),
            "sparse scaling {l8} -> {l16} -> {l32} (x{r}) should be near-linear"
        );
    }

    #[test]
    fn headline_114k_tokens_at_32_gpus() {
        // Paper: >114K tokens on 32 P100s with sparse attention, batch 4.
        let c = Cluster::default();
        let l32 = max_seq_len_linformer(&c, BERT_BASE, 4, 32, 256, 256);
        assert!(
            l32 >= 64_000,
            "sparse+SP @32 devices reaches only {l32} tokens (paper: 114K)"
        );
    }

    #[test]
    fn step_rounds_up_when_n_does_not_divide_it() {
        // step=100 with n=48 rounds to 144: the answer must be a multiple
        // of the ROUNDED step (and therefore of n — the SP divisibility
        // requirement) even though the caller's step was ring-oblivious.
        let c = Cluster::default();
        let l = max_seq_len_linformer(&c, BERT_BASE, 4, 48, 256, 100);
        assert!(l > 0);
        assert_eq!(l % 48, 0, "result {l} must be ring-divisible");
        assert_eq!(l % 144, 0, "result {l} must be a multiple of the rounded step");
        // maximality at the rounded-step granularity
        let shape_fits = |len: usize| {
            peak_bytes_linformer(&RunShape::new(BERT_BASE, 4, len), 48, 256) <= c.gpu_mem
        };
        assert!(shape_fits(l));
        assert!(!shape_fits(l + 144), "{l} + one step should OOM");
    }

    #[test]
    fn l_not_multiple_of_n_is_never_probed() {
        // step already a multiple of n: identical answer to an equivalent
        // unrounded call (regression for the step-rounding path)
        let c = Cluster::default();
        let a = max_seq_len_linformer(&c, BERT_BASE, 4, 8, 256, 256);
        let b = max_seq_len_linformer(&c, BERT_BASE, 4, 8, 256, 255); // rounds to 256
        assert_eq!(a, b);
    }

    #[test]
    fn too_small_budget_returns_zero() {
        // fits(step) == false early return: a 1-byte device holds nothing
        let c = Cluster { gpu_mem: 1, ..Cluster::default() };
        assert_eq!(max_seq_len_linformer(&c, BERT_BASE, 4, 8, 256, 256), 0);
        // and a degenerate step=0 / n=0 call neither panics nor divides by 0
        assert_eq!(max_seq_len_linformer(&c, BERT_BASE, 4, 0, 256, 0), 0);
    }

    #[test]
    fn sparse_beats_dense_at_same_device_count() {
        let c = Cluster::default();
        let dense = crate::simulator::search::max_seq_len(
            &c, BERT_BASE, 4, 1, 1, Strategy::Sequence { n: 32 }, 256,
        );
        let sparse = max_seq_len_linformer(&c, BERT_BASE, 4, 32, 256, 256);
        assert!(sparse > 2 * dense, "sparse {sparse} vs dense {dense}");
    }
}
