//! Per-device memory ledger + the paper's closed forms (Tables 1 & 2).
//!
//! The ledger enumerates the SAME tensors the real rust engines allocate:
//!
//! * parameter state: weight + gradient + Adam m + Adam v (4 × 4 bytes per
//!   element; the paper assumes Megatron's Adam, §3.2.1);
//! * per-layer activation stash (the engines' `LayerStash` fields),
//!   including the score/probability matrix — the quadratic term;
//! * transients: the MLM logits and their gradient (the largest
//!   short-lived pair), and the assembled dP rows in backward.
//!
//! The paper's Table 1/2 entries count ELEMENTS of the block's operand /
//! output / weight tensors; `paper_*` below implement those formulas
//! verbatim, and unit tests check the ledger's matching terms reduce to
//! them, so the headline break-evens (`BL > 32H`, `BL > 16AZ`) hold in the
//! ledger too.

use super::{RunShape, Strategy};

const F32: u64 = 4;
/// weight + grad + Adam m + Adam v
const OPT_STATE_MULT: u64 = 4;

// ---------------------------------------------------------------------------
// Paper closed forms (element counts, as printed)
// ---------------------------------------------------------------------------

/// Table 1, tensor parallelism row: 32H²/N + 4BLH/N + BLH.
pub fn paper_mlp_tensor(b: u64, l: u64, h: u64, n: u64) -> u64 {
    32 * h * h / n + 4 * b * l * h / n + b * l * h
}

/// Table 1, sequence parallelism row: 32H² + 5BLH/N.
pub fn paper_mlp_sequence(b: u64, l: u64, h: u64, n: u64) -> u64 {
    32 * h * h + 5 * b * l * h / n
}

/// Table 2, tensor parallelism row: 16AZH/N + 4BLZA/N + BZL²/N + BLH.
pub fn paper_attn_tensor(b: u64, l: u64, h: u64, a: u64, z: u64, n: u64) -> u64 {
    16 * a * z * h / n + 4 * b * l * z * a / n + b * z * l * l / n + b * l * h
}

/// Table 2, sequence parallelism row: 16AZH + 4BZLA/N + BZL²/N + BLH/N.
pub fn paper_attn_sequence(b: u64, l: u64, h: u64, a: u64, z: u64, n: u64) -> u64 {
    16 * a * z * h + 4 * b * z * l * a / n + b * z * l * l / n + b * l * h / n
}

/// Eq. 5: sequence parallelism wins the MLP block iff BL > 32H
/// (asymptotically in N; the paper states the N-free comparison).
pub fn mlp_breakeven_bl(h: u64) -> u64 {
    32 * h
}

/// §3.2.1: sequence parallelism wins the attention block iff BL > 16AZ.
pub fn attn_breakeven_bl(a: u64, z: u64) -> u64 {
    16 * a * z
}

// ---------------------------------------------------------------------------
// The ledger
// ---------------------------------------------------------------------------

/// Byte breakdown for one device.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoryBreakdown {
    pub param_state: u64,
    pub activations: u64,
    pub transients: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.param_state + self.activations + self.transients
    }
}

/// Parameters resident on one device (elements).
fn params_per_device(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let (h, f, v) = (m.hidden as u64, m.ffn() as u64, m.vocab as u64);
    let l = shape.seq_len as u64;
    let layers = shape.layers_per_stage() as u64;
    let per_layer_full = 4 * h * h + 4 * h + h * f + f + f * h + h + 4 * h;
    // embeddings + heads live on the first/last stage; charge the worst
    // stage (first: tok+pos; last: heads) — take the max.
    let emb = v * h + l * h;
    let heads = v * h + v + 2 * h + 2;
    let boundary = emb.max(heads);
    match strategy {
        Strategy::Sequence { .. } => {
            // all parameters replicated
            boundary + layers * per_layer_full
        }
        Strategy::Tensor { n } => {
            let n = n as u64;
            // qkv cols + wo rows + mlp both GEMMs split; LN + biases of the
            // all-reduced outputs replicated
            let per_layer = 4 * h * h / n      // wq,wk,wv,wo
                + 3 * h / n + h                // qkv biases split, bo replicated
                + h * f / n + f / n            // w1, b1
                + f * h / n + h                // w2, b2 (replicated bias)
                + 4 * h; // layernorms
            boundary + layers * per_layer
        }
    }
}

/// Activation stash elements for ONE transformer layer on one device —
/// field-for-field the engines' `LayerStash`.
pub fn layer_stash_elems(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let (h, f) = (m.hidden as u64, m.ffn() as u64);
    let (z, a) = (m.heads as u64, m.head_dim as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    match strategy {
        Strategy::Sequence { n } => {
            let n = n as u64;
            let lc = l / n;
            let tok = b * lc; // tokens on this device
            // x_in + q + k + v + p + ctx + pre1 + xm + h + pre2
            tok * h                 // x_in
                + 3 * b * z * lc * a // q, k, v
                + b * z * lc * l     // p (rows Lc, FULL width L)
                + b * z * lc * a     // ctx
                + 3 * tok * h        // pre1, xm, pre2
                + tok * f // h
        }
        Strategy::Tensor { n } => {
            let n = n as u64;
            let zp = z / n;
            let fp = f / n;
            let tok = b * l; // full sequence on every device
            tok * h
                + 3 * b * zp * l * a
                + b * zp * l * l
                + b * zp * l * a
                + 3 * tok * h
                + tok * fp
        }
    }
}

/// Largest transient pair: MLM logits + their gradient, plus the backward
/// dP/dS rows (same size as p).  The loss runs PER MICROBATCH (only one
/// microbatch's logits are ever live), and under tensor parallelism
/// Megatron's head is vocab-parallel so logits carry V/N columns.
fn transient_elems(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let v = m.vocab as u64;
    let (z, h) = (m.heads as u64, m.hidden as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    let micros = shape.micros.max(1) as u64;
    let (tok, logit_cols, score_rows) = match strategy {
        Strategy::Sequence { n } => {
            let lc = l / n as u64;
            (b * lc, v, b * z * lc * l)
        }
        Strategy::Tensor { n } => (b * l, v / n as u64, b * z / n as u64 * l * l),
    };
    // logits + dlogits (one microbatch) + dP + dx
    2 * (tok / micros) * logit_cols + score_rows + tok * h
}

/// Full per-device breakdown for a run shape under a strategy.
pub fn breakdown(shape: &RunShape, strategy: Strategy) -> MemoryBreakdown {
    let layers = shape.layers_per_stage() as u64;
    MemoryBreakdown {
        param_state: params_per_device(shape, strategy) * F32 * OPT_STATE_MULT,
        activations: layers * layer_stash_elems(shape, strategy) * F32
            // embedding output held alongside the stashes
            + match strategy {
                Strategy::Sequence { n } => {
                    (shape.batch * shape.seq_len / n * shape.model.hidden) as u64 * F32
                }
                Strategy::Tensor { .. } => {
                    (shape.batch * shape.seq_len * shape.model.hidden) as u64 * F32
                }
            },
        transients: transient_elems(shape, strategy) * F32,
    }
}

/// Peak bytes on the worst device.
pub fn peak_bytes(shape: &RunShape, strategy: Strategy) -> u64 {
    breakdown(shape, strategy).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BERT_BASE, BERT_LARGE};
    use crate::simulator::RunShape;
    use crate::util::prop::Prop;

    #[test]
    fn paper_formula_breakeven_mlp() {
        // Eq. 5: with BL > 32H sequence parallelism uses less MLP memory.
        let (h, n) = (768u64, 8u64);
        let bl_win = 32 * h + 1000;
        let bl_lose = 32 * h / 4;
        // pick b, l splitting bl
        assert!(
            paper_mlp_sequence(1, bl_win, h, n) < paper_mlp_tensor(1, bl_win, h, n),
            "SP should win above the break-even"
        );
        assert!(
            paper_mlp_sequence(1, bl_lose, h, n) > paper_mlp_tensor(1, bl_lose, h, n),
            "TP should win below the break-even"
        );
    }

    #[test]
    fn paper_formula_breakeven_attention() {
        let (h, a, z, n) = (768u64, 64u64, 12u64, 8u64);
        let bl = 16 * a * z;
        assert!(
            paper_attn_sequence(1, 4 * bl, h, a, z, n) < paper_attn_tensor(1, 4 * bl, h, a, z, n)
        );
        assert!(paper_attn_sequence(1, bl / 8, h, a, z, n) > paper_attn_tensor(1, bl / 8, h, a, z, n));
    }

    #[test]
    fn ledger_quadratic_term_matches_paper() {
        // The score matrix term in the ledger equals the paper's BZL²/N
        // for both strategies (the only quadratic-in-L term).
        let shape = RunShape::new(BERT_BASE, 8, 512);
        let shape2 = RunShape::new(BERT_BASE, 8, 1024);
        // SP sizes must divide L; TP sizes must divide the 12 heads.
        for n in [2usize, 4, 8] {
            let sp = layer_stash_elems(&shape, Strategy::Sequence { n });
            let quad = 8u64 * 12 * 512 * 512 / n as u64; // BZL²/N
            assert!(sp >= quad);
            let sp_linear = sp - quad;
            let sp2 = layer_stash_elems(&shape2, Strategy::Sequence { n });
            assert_eq!(sp2 - 4 * quad, 2 * sp_linear, "SP ledger not L-linear+L²");
        }
        for n in [2usize, 4, 6] {
            let tp = layer_stash_elems(&shape, Strategy::Tensor { n });
            let quad = 8u64 * 12 * 512 * 512 / n as u64;
            assert!(tp >= quad);
            let tp_linear = tp - quad;
            let tp2 = layer_stash_elems(&shape2, Strategy::Tensor { n });
            assert_eq!(tp2 - 4 * quad, 2 * tp_linear, "TP ledger not L-linear+L²");
        }
    }

    #[test]
    fn sp_memory_is_constant_in_batch_scaling() {
        // Table 4 weak scaling: doubling batch AND devices keeps SP
        // per-device memory ~constant, while TP grows.
        let base = RunShape::new(BERT_BASE, 64, 512);
        let m1 = peak_bytes(&base, Strategy::Sequence { n: 1 });
        let big = RunShape::new(BERT_BASE, 512, 512);
        let m8 = peak_bytes(&big, Strategy::Sequence { n: 8 });
        let ratio = m8 as f64 / m1 as f64;
        assert!((0.8..1.3).contains(&ratio), "SP weak-scaling ratio {ratio}");
        // TP at its feasible size 4 with batch 256 (Table 4 row 3):
        // per-device memory must GROW with the global batch (paper: 1.44x
        // from 8477 MB to 12232 MB), unlike SP's flat line.
        let mid = RunShape::new(BERT_BASE, 256, 512);
        let t1 = peak_bytes(&base, Strategy::Tensor { n: 1 });
        let t4 = peak_bytes(&mid, Strategy::Tensor { n: 4 });
        assert!(t4 as f64 / t1 as f64 > 1.25, "TP should grow with batch");
    }

    #[test]
    fn sp_param_state_replicated_tp_sharded() {
        let shape = RunShape::new(BERT_LARGE, 16, 512);
        let sp = breakdown(&shape, Strategy::Sequence { n: 8 });
        let sp1 = breakdown(&shape, Strategy::Sequence { n: 1 });
        assert_eq!(sp.param_state, sp1.param_state, "SP params must not shrink");
        let tp = breakdown(&shape, Strategy::Tensor { n: 8 });
        assert!(tp.param_state < sp.param_state, "TP shards weights");
    }

    #[test]
    fn ledger_is_monotone_in_everything() {
        Prop::new(48, 21).check("ledger monotone", |rng| {
            let b = 1 + rng.below(32) as usize;
            let l = 64 * (1 + rng.below(16)) as usize;
            let n = 1usize << rng.below(4);
            let shape = RunShape::new(BERT_BASE, b, l);
            let bigger_b = RunShape::new(BERT_BASE, b + 1, l);
            let bigger_l = RunShape::new(BERT_BASE, b, l + 64);
            for strat in [Strategy::Sequence { n }, Strategy::Tensor { n: 4 }] {
                if peak_bytes(&bigger_b, strat) < peak_bytes(&shape, strat) {
                    return Err(format!("batch monotonicity broken at {shape:?} {strat:?}"));
                }
                if peak_bytes(&bigger_l, strat) < peak_bytes(&shape, strat) {
                    return Err(format!("length monotonicity broken at {shape:?} {strat:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pipeline_divides_activation_memory() {
        let flat = RunShape::new(BERT_BASE, 32, 512);
        let piped = flat.with_pipeline(4, 4);
        let f = breakdown(&flat, Strategy::Sequence { n: 4 });
        let p = breakdown(&piped, Strategy::Sequence { n: 4 });
        assert!(p.activations < f.activations / 2);
    }
}
