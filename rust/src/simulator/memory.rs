//! Per-device memory ledger + the paper's closed forms (Tables 1 & 2).
//!
//! The ledger enumerates the SAME tensors the real rust engines allocate:
//!
//! * parameter state: weight + gradient + Adam m + Adam v (4 × 4 bytes per
//!   element; the paper assumes Megatron's Adam, §3.2.1);
//! * per-layer activation stash (the engines' `LayerStash` fields),
//!   including the score/probability matrix — the quadratic term;
//! * transients: the MLM logits and their gradient (the largest
//!   short-lived pair), and the assembled dP rows in backward.
//!
//! The paper's Table 1/2 entries count ELEMENTS of the block's operand /
//! output / weight tensors; `paper_*` below implement those formulas
//! verbatim, and unit tests check the ledger's matching terms reduce to
//! them, so the headline break-evens (`BL > 32H`, `BL > 16AZ`) hold in the
//! ledger too.

use super::{RunShape, Strategy};
use crate::attn::AttnPattern;
use crate::model::ModelConfig;

const F32: u64 = 4;
/// weight + grad + Adam m + Adam v
const OPT_STATE_MULT: u64 = 4;

// ---------------------------------------------------------------------------
// Paper closed forms (element counts, as printed)
// ---------------------------------------------------------------------------

/// Table 1, tensor parallelism row: 32H²/N + 4BLH/N + BLH.
pub fn paper_mlp_tensor(b: u64, l: u64, h: u64, n: u64) -> u64 {
    32 * h * h / n + 4 * b * l * h / n + b * l * h
}

/// Table 1, sequence parallelism row: 32H² + 5BLH/N.
pub fn paper_mlp_sequence(b: u64, l: u64, h: u64, n: u64) -> u64 {
    32 * h * h + 5 * b * l * h / n
}

/// Table 2, tensor parallelism row: 16AZH/N + 4BLZA/N + BZL²/N + BLH.
pub fn paper_attn_tensor(b: u64, l: u64, h: u64, a: u64, z: u64, n: u64) -> u64 {
    16 * a * z * h / n + 4 * b * l * z * a / n + b * z * l * l / n + b * l * h
}

/// Table 2, sequence parallelism row: 16AZH + 4BZLA/N + BZL²/N + BLH/N.
pub fn paper_attn_sequence(b: u64, l: u64, h: u64, a: u64, z: u64, n: u64) -> u64 {
    16 * a * z * h + 4 * b * z * l * a / n + b * z * l * l / n + b * l * h / n
}

/// Eq. 5: sequence parallelism wins the MLP block iff BL > 32H
/// (asymptotically in N; the paper states the N-free comparison).
pub fn mlp_breakeven_bl(h: u64) -> u64 {
    32 * h
}

/// §3.2.1: sequence parallelism wins the attention block iff BL > 16AZ.
pub fn attn_breakeven_bl(a: u64, z: u64) -> u64 {
    16 * a * z
}

// ---------------------------------------------------------------------------
// The ledger
// ---------------------------------------------------------------------------

/// Byte breakdown for one device.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoryBreakdown {
    pub param_state: u64,
    pub activations: u64,
    pub transients: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.param_state + self.activations + self.transients
    }
}

/// Parameters resident on one device (elements).
fn params_per_device(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let (h, f, v) = (m.hidden as u64, m.ffn() as u64, m.vocab as u64);
    let l = shape.seq_len as u64;
    let layers = shape.layers_per_stage() as u64;
    let per_layer_full = 4 * h * h + 4 * h + h * f + f + f * h + h + 4 * h;
    // embeddings + heads live on the first/last stage; charge the worst
    // stage (first: tok+pos; last: heads) — take the max.
    let emb = v * h + l * h;
    let heads = v * h + v + 2 * h + 2;
    let boundary = emb.max(heads);
    match strategy {
        Strategy::Sequence { .. } | Strategy::Ulysses { .. } => {
            // all parameters replicated (both SP strategies)
            boundary + layers * per_layer_full
        }
        Strategy::Tensor { n } => {
            let n = n as u64;
            // qkv cols + wo rows + mlp both GEMMs split; LN + biases of the
            // all-reduced outputs replicated
            let per_layer = 4 * h * h / n      // wq,wk,wv,wo
                + 3 * h / n + h                // qkv biases split, bo replicated
                + h * f / n + f / n            // w1, b1
                + f * h / n + h                // w2, b2 (replicated bias)
                + 4 * h; // layernorms
            boundary + layers * per_layer
        }
    }
}

/// Activation stash elements for ONE transformer layer on one device —
/// field-for-field the engines' `LayerStash`.
pub fn layer_stash_elems(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let (h, f) = (m.hidden as u64, m.ffn() as u64);
    let (z, a) = (m.heads as u64, m.head_dim as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    match strategy {
        Strategy::Sequence { n } | Strategy::Ulysses { n } => {
            // Ulysses holds the SAME element count head-sharded: q/k/v/p
            // carry Z/N heads over the FULL length L instead of Z heads
            // over the chunk Lc, and Z/N·L == Z·Lc.  Pinned by
            // `ulysses_stash_matches_ring` below.
            let n = n as u64;
            let lc = l / n;
            let tok = b * lc; // tokens on this device
            // x_in + q + k + v + p + ctx + pre1 + xm + pre2.  The MLP
            // hidden is NOT stashed — the engines rematerialize it in
            // backward (`mlp_bwd`) — so it is a transient, not a stash
            // field (see `transient_elems`).
            tok * h                 // x_in
                + 3 * b * z * lc * a // q, k, v
                + b * z * lc * l     // p (rows Lc, FULL width L)
                + b * z * lc * a     // ctx
                + 3 * tok * h // pre1, xm, pre2
        }
        Strategy::Tensor { n } => {
            let n = n as u64;
            let zp = z / n;
            let fp = f / n;
            let tok = b * l; // full sequence on every device
            tok * h
                + 3 * b * zp * l * a
                + b * zp * l * l
                + b * zp * l * a
                + 3 * tok * h
                + tok * fp
        }
    }
}

/// Largest transient pair: MLM logits + their gradient, plus the backward
/// dP/dS rows (same size as p).  The loss runs PER MICROBATCH (only one
/// microbatch's logits are ever live), and under tensor parallelism
/// Megatron's head is vocab-parallel so logits carry V/N columns.
fn transient_elems(shape: &RunShape, strategy: Strategy) -> u64 {
    let m = &shape.model;
    let v = m.vocab as u64;
    let (z, h, f) = (m.heads as u64, m.hidden as u64, m.ffn() as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    let micros = shape.micros.max(1) as u64;
    // Under SP the MLP hidden is rematerialized in backward (it is not a
    // `LayerStash` field), so it appears here as a short-lived tensor;
    // under TP it IS stashed (`TpLayerStash::h`) and is counted in
    // `layer_stash_elems` instead.
    let (tok, logit_cols, score_rows, mlp_hidden) = match strategy {
        Strategy::Sequence { n } | Strategy::Ulysses { n } => {
            let lc = l / n as u64;
            (b * lc, v, b * z * lc * l, b * lc * f)
        }
        Strategy::Tensor { n } => (b * l, v / n as u64, b * z / n as u64 * l * l, 0),
    };
    // logits + dlogits (one microbatch) + dP + dx + rematerialized hidden
    2 * (tok / micros) * logit_cols + score_rows + tok * h + mlp_hidden
}

/// Full per-device breakdown for a run shape under a strategy.
pub fn breakdown(shape: &RunShape, strategy: Strategy) -> MemoryBreakdown {
    let layers = shape.layers_per_stage() as u64;
    MemoryBreakdown {
        param_state: params_per_device(shape, strategy) * F32 * OPT_STATE_MULT,
        activations: layers * layer_stash_elems(shape, strategy) * F32
            // embedding output held alongside the stashes
            + match strategy {
                Strategy::Sequence { n } | Strategy::Ulysses { n } => {
                    (shape.batch * shape.seq_len / n * shape.model.hidden) as u64 * F32
                }
                Strategy::Tensor { .. } => {
                    (shape.batch * shape.seq_len * shape.model.hidden) as u64 * F32
                }
            },
        transients: transient_elems(shape, strategy) * F32,
    }
}

/// Peak bytes on the worst device.
pub fn peak_bytes(shape: &RunShape, strategy: Strategy) -> u64 {
    breakdown(shape, strategy).total()
}

// ---------------------------------------------------------------------------
// Measured-vs-closed-form contract (obs::mem validation)
// ---------------------------------------------------------------------------

/// Total parameter ELEMENTS the native backend registers for a model at
/// `seq_len`: the `crate::model::param_spec` sum plus, when the run uses
/// `linformer:K`, the shared E_k/E_v projections (`[K, L]` each) that
/// `backend::native` appends to the manifest.  Unlike the internal
/// `params_per_device` (which charges the worst PIPELINE stage), this
/// is the exact replicated total a single-stage SP rank holds — what
/// `obs::mem` measures for the params/grads categories.
pub fn params_total_elems(m: &ModelConfig, seq_len: usize, linformer_k: usize) -> u64 {
    let (h, f, v) = (m.hidden as u64, m.ffn() as u64, m.vocab as u64);
    let l = seq_len as u64;
    let layers = m.layers as u64;
    let per_layer = 4 * h * h + 4 * h + h * f + f + f * h + h + 4 * h;
    let mut total = (v * h + l * h) + layers * per_layer + (v * h + v + 2 * h + 2);
    if linformer_k > 0 {
        total += 2 * linformer_k as u64 * l;
    }
    total
}

/// Width (in tokens) of rank `dst`'s stashed probability rows under
/// `block:W` with `n` chunks of `lc` tokens — `reach(dst) · lc`, where
/// the chunk-level reachability mirrors `attn::block`'s plan: chunk
/// `src` is reachable from `dst` iff some token pair falls inside the
/// causal window.
pub fn block_stash_width(dst: usize, n: usize, lc: usize, w: usize) -> u64 {
    let reach = (0..n)
        .filter(|&src| src == dst || (src < dst && (dst - src - 1) * lc + 1 <= w.saturating_sub(1)))
        .count() as u64;
    reach * lc as u64
}

/// Expected per-rank PEAK bytes per `obs::mem` accounting category for
/// the real SP engines.  `tests/mem_validation.rs` and
/// `benches/mem_profile.rs` assert measured peaks EQUAL these —
/// element-count exactness, the memory analogue of PR 6's comm-byte
/// closed forms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemExpect {
    /// Replicated parameter bytes (`ParamStore::total_bytes`).
    pub params: u64,
    /// Gradient-accumulator bytes (`zeros_like`: same spec as params).
    pub grads: u64,
    /// Adam state bytes (m + v = 2 × params).
    pub optimizer: u64,
    /// Residual-stream stash: x_in + pre1 + xm + pre2, per layer.
    pub activation: u64,
    /// Attention stash: q/k/v/ctx plus the pattern's score stash (and
    /// Linformer's K̃/Ṽ), per layer.
    pub attn_stash: u64,
    /// Ring-buffer peak (in-flight k/v + gradient slots).  `None` means
    /// the category is reported but not validated (block-sparse keeps a
    /// schedule-dependent number of slots in flight).
    pub ring_buf: Option<u64>,
}

impl MemExpect {
    /// Sum of every validated category (`ring_buf` included when pinned).
    pub fn validated_total(&self) -> u64 {
        self.params
            + self.grads
            + self.optimizer
            + self.activation
            + self.attn_stash
            + self.ring_buf.unwrap_or(0)
    }
}

/// Closed-form per-category peak for rank `rank` of an n-way SP run.
/// Covers the SP strategies only (TP enters the contract only through
/// the SP-peak < TP-peak inequality); `rank` matters only for `block:W`,
/// whose stash width varies per chunk.  Blocking ring schedule; see
/// [`sp_expect_overlap`] for the double-buffered variant.
pub fn sp_expect(
    shape: &RunShape,
    strategy: Strategy,
    pattern: AttnPattern,
    rank: usize,
) -> MemExpect {
    sp_expect_overlap(shape, strategy, pattern, rank, false)
}

/// [`sp_expect`] with the comm/compute-overlap knob: double-buffering
/// keeps ONE extra chunk-sized slot in flight per rank while a posted
/// data shift is outstanding, so the dense ring's peak grows from 2
/// chunks (backward: v + dv resident) to 3 (v + dv + the incoming v).
/// The all-to-all and Linformer schedules never touch the ring buffers,
/// so their forms are overlap-invariant.
pub fn sp_expect_overlap(
    shape: &RunShape,
    strategy: Strategy,
    pattern: AttnPattern,
    rank: usize,
    overlap: bool,
) -> MemExpect {
    assert!(
        !matches!(strategy, Strategy::Tensor { .. }),
        "sp_expect covers SP strategies only"
    );
    let m = &shape.model;
    let (h, z, a) = (m.hidden as u64, m.heads as u64, m.head_dim as u64);
    let b = shape.batch as u64;
    let l = shape.seq_len as u64;
    let n = strategy.n() as u64;
    let lc = l / n;
    let tok = b * lc;
    let layers = m.layers as u64;
    let linformer_k = match pattern {
        AttnPattern::Linformer { k } => k,
        _ => 0,
    };
    let params = params_total_elems(m, shape.seq_len, linformer_k) * F32;
    // q + k + v + ctx — identical element counts for ring (Z heads × Lc
    // rows) and Ulysses (Z/N heads × L rows).
    let qkv_ctx = 4 * b * z * lc * a;
    let pattern_elems = match pattern {
        AttnPattern::Dense => b * z * lc * l,
        AttnPattern::Linformer { k } => {
            let k = k as u64;
            b * z * lc * k + 2 * b * z * k * a
        }
        AttnPattern::Block { w } => b * z * lc * block_stash_width(rank, n as usize, lc as usize, w),
    };
    let ring_buf = match pattern {
        // the dense ring's backward holds exactly two chunk-sized slot
        // sets in flight per rank (v+dv, then k+dk) — three when a
        // double-buffered data shift is also outstanding; the all-to-all
        // schedule never touches the ring buffers
        AttnPattern::Dense => {
            if matches!(strategy, Strategy::Ulysses { .. }) {
                Some(0)
            } else {
                // a ring of 1 has no hop to post, so overlap adds nothing
                let slots = if overlap && n > 1 { 3 } else { 2 };
                Some(slots * b * z * lc * a * F32)
            }
        }
        AttnPattern::Linformer { .. } => Some(0),
        AttnPattern::Block { .. } => None,
    };
    MemExpect {
        params,
        grads: params,
        optimizer: 2 * params,
        activation: layers * 4 * tok * h * F32,
        attn_stash: layers * (qkv_ctx + pattern_elems) * F32,
        ring_buf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BERT_BASE, BERT_LARGE};
    use crate::simulator::RunShape;
    use crate::util::prop::Prop;

    #[test]
    fn paper_formula_breakeven_mlp() {
        // Eq. 5: with BL > 32H sequence parallelism uses less MLP memory.
        let (h, n) = (768u64, 8u64);
        let bl_win = 32 * h + 1000;
        let bl_lose = 32 * h / 4;
        // pick b, l splitting bl
        assert!(
            paper_mlp_sequence(1, bl_win, h, n) < paper_mlp_tensor(1, bl_win, h, n),
            "SP should win above the break-even"
        );
        assert!(
            paper_mlp_sequence(1, bl_lose, h, n) > paper_mlp_tensor(1, bl_lose, h, n),
            "TP should win below the break-even"
        );
    }

    #[test]
    fn paper_formula_breakeven_attention() {
        let (h, a, z, n) = (768u64, 64u64, 12u64, 8u64);
        let bl = 16 * a * z;
        assert!(
            paper_attn_sequence(1, 4 * bl, h, a, z, n) < paper_attn_tensor(1, 4 * bl, h, a, z, n)
        );
        assert!(paper_attn_sequence(1, bl / 8, h, a, z, n) > paper_attn_tensor(1, bl / 8, h, a, z, n));
    }

    #[test]
    fn ledger_quadratic_term_matches_paper() {
        // The score matrix term in the ledger equals the paper's BZL²/N
        // for both strategies (the only quadratic-in-L term).
        let shape = RunShape::new(BERT_BASE, 8, 512);
        let shape2 = RunShape::new(BERT_BASE, 8, 1024);
        // SP sizes must divide L; TP sizes must divide the 12 heads.
        for n in [2usize, 4, 8] {
            let sp = layer_stash_elems(&shape, Strategy::Sequence { n });
            let quad = 8u64 * 12 * 512 * 512 / n as u64; // BZL²/N
            assert!(sp >= quad);
            let sp_linear = sp - quad;
            let sp2 = layer_stash_elems(&shape2, Strategy::Sequence { n });
            assert_eq!(sp2 - 4 * quad, 2 * sp_linear, "SP ledger not L-linear+L²");
        }
        for n in [2usize, 4, 6] {
            let tp = layer_stash_elems(&shape, Strategy::Tensor { n });
            let quad = 8u64 * 12 * 512 * 512 / n as u64;
            assert!(tp >= quad);
            let tp_linear = tp - quad;
            let tp2 = layer_stash_elems(&shape2, Strategy::Tensor { n });
            assert_eq!(tp2 - 4 * quad, 2 * tp_linear, "TP ledger not L-linear+L²");
        }
    }

    #[test]
    fn sp_memory_is_constant_in_batch_scaling() {
        // Table 4 weak scaling: doubling batch AND devices keeps SP
        // per-device memory ~constant, while TP grows.
        let base = RunShape::new(BERT_BASE, 64, 512);
        let m1 = peak_bytes(&base, Strategy::Sequence { n: 1 });
        let big = RunShape::new(BERT_BASE, 512, 512);
        let m8 = peak_bytes(&big, Strategy::Sequence { n: 8 });
        let ratio = m8 as f64 / m1 as f64;
        assert!((0.8..1.3).contains(&ratio), "SP weak-scaling ratio {ratio}");
        // TP at its feasible size 4 with batch 256 (Table 4 row 3):
        // per-device memory must GROW with the global batch (paper: 1.44x
        // from 8477 MB to 12232 MB), unlike SP's flat line.
        let mid = RunShape::new(BERT_BASE, 256, 512);
        let t1 = peak_bytes(&base, Strategy::Tensor { n: 1 });
        let t4 = peak_bytes(&mid, Strategy::Tensor { n: 4 });
        assert!(t4 as f64 / t1 as f64 > 1.25, "TP should grow with batch");
    }

    #[test]
    fn sp_param_state_replicated_tp_sharded() {
        let shape = RunShape::new(BERT_LARGE, 16, 512);
        let sp = breakdown(&shape, Strategy::Sequence { n: 8 });
        let sp1 = breakdown(&shape, Strategy::Sequence { n: 1 });
        assert_eq!(sp.param_state, sp1.param_state, "SP params must not shrink");
        let tp = breakdown(&shape, Strategy::Tensor { n: 8 });
        assert!(tp.param_state < sp.param_state, "TP shards weights");
    }

    #[test]
    fn ledger_is_monotone_in_everything() {
        Prop::new(48, 21).check("ledger monotone", |rng| {
            let b = 1 + rng.below(32) as usize;
            let l = 64 * (1 + rng.below(16)) as usize;
            let n = 1usize << rng.below(4);
            let shape = RunShape::new(BERT_BASE, b, l);
            let bigger_b = RunShape::new(BERT_BASE, b + 1, l);
            let bigger_l = RunShape::new(BERT_BASE, b, l + 64);
            for strat in [Strategy::Sequence { n }, Strategy::Tensor { n: 4 }] {
                if peak_bytes(&bigger_b, strat) < peak_bytes(&shape, strat) {
                    return Err(format!("batch monotonicity broken at {shape:?} {strat:?}"));
                }
                if peak_bytes(&bigger_l, strat) < peak_bytes(&shape, strat) {
                    return Err(format!("length monotonicity broken at {shape:?} {strat:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pipeline_divides_activation_memory() {
        let flat = RunShape::new(BERT_BASE, 32, 512);
        let piped = flat.with_pipeline(4, 4);
        let f = breakdown(&flat, Strategy::Sequence { n: 4 });
        let p = breakdown(&piped, Strategy::Sequence { n: 4 });
        assert!(p.activations < f.activations / 2);
    }

    #[test]
    fn ulysses_stash_matches_ring() {
        // The head-sharded Ulysses stash (Z/N heads × full L) holds the
        // same element count as the ring stash (Z heads × chunk Lc), so
        // the whole breakdown is shared between the two SP strategies.
        let shape = RunShape::new(BERT_BASE, 8, 512);
        for n in [1usize, 2, 4] {
            assert_eq!(
                layer_stash_elems(&shape, Strategy::Sequence { n }),
                layer_stash_elems(&shape, Strategy::Ulysses { n }),
                "stash elems diverge at n={n}"
            );
            assert_eq!(
                breakdown(&shape, Strategy::Sequence { n }),
                breakdown(&shape, Strategy::Ulysses { n }),
                "breakdown diverges at n={n}"
            );
        }
        // Ulysses additionally needs the head count divisible.
        assert!(Strategy::Ulysses { n: 4 }.feasible(&BERT_BASE, 512));
        assert!(!Strategy::Ulysses { n: 8 }.feasible(&BERT_BASE, 512), "12 heads % 8 != 0");
        assert!(Strategy::Sequence { n: 8 }.feasible(&BERT_BASE, 512), "ring has no head cap");
    }

    #[test]
    fn params_formula_matches_spec() {
        // params_total_elems must equal the element sum of the manifest
        // the native backend actually registers.
        for l in [128usize, 512] {
            let spec_sum: u64 = crate::model::param_spec(&BERT_BASE, l)
                .iter()
                .map(|(_, dims)| dims.iter().product::<usize>() as u64)
                .sum();
            assert_eq!(params_total_elems(&BERT_BASE, l, 0), spec_sum);
            // linformer adds the two [K, L] projections
            assert_eq!(
                params_total_elems(&BERT_BASE, l, 32),
                spec_sum + 2 * 32 * l as u64
            );
        }
    }

    #[test]
    fn sp_expect_pins_category_forms() {
        use crate::attn::AttnPattern;
        let shape = RunShape::new(BERT_BASE, 2, 512);
        let (b, z, a, h) = (2u64, 12u64, 64u64, 768u64);
        let (l, n) = (512u64, 4usize);
        let lc = l / n as u64;
        let strat = Strategy::Sequence { n };
        let dense = sp_expect(&shape, strat, AttnPattern::Dense, 0);
        // params/grads/optimizer tie to the manifest sum
        assert_eq!(dense.params, params_total_elems(&BERT_BASE, 512, 0) * F32);
        assert_eq!(dense.grads, dense.params);
        assert_eq!(dense.optimizer, 2 * dense.params);
        // activation: 4 residual-stream tensors per layer
        assert_eq!(dense.activation, 12 * 4 * b * lc * h * F32);
        // dense attn stash: q/k/v/ctx + full-width probs
        assert_eq!(
            dense.attn_stash,
            12 * (4 * b * z * lc * a + b * z * lc * l) * F32
        );
        assert_eq!(dense.ring_buf, Some(2 * b * z * lc * a * F32));
        // double-buffered ring: +1 chunk in flight, everything else fixed
        let dense_ov = sp_expect_overlap(&shape, strat, AttnPattern::Dense, 0, true);
        assert_eq!(dense_ov.ring_buf, Some(3 * b * z * lc * a * F32));
        assert_eq!(dense_ov.attn_stash, dense.attn_stash);
        assert_eq!(dense_ov.activation, dense.activation);
        assert_eq!(dense_ov.params, dense.params);
        // ulysses: same stash, no ring buffers (overlap-invariant)
        let uly = sp_expect(&shape, Strategy::Ulysses { n }, AttnPattern::Dense, 0);
        assert_eq!(uly.attn_stash, dense.attn_stash);
        assert_eq!(uly.activation, dense.activation);
        assert_eq!(uly.ring_buf, Some(0));
        assert_eq!(
            sp_expect_overlap(&shape, Strategy::Ulysses { n }, AttnPattern::Dense, 0, true)
                .ring_buf,
            Some(0)
        );
        // linformer: K-width probs + projected K̃/Ṽ, no ring buffers,
        // and the E_k/E_v parameters join the replicated params
        let k = 64u64;
        let lin = sp_expect(&shape, strat, AttnPattern::Linformer { k: 64 }, 0);
        assert_eq!(
            lin.attn_stash,
            12 * (4 * b * z * lc * a + b * z * lc * k + 2 * b * z * k * a) * F32
        );
        assert_eq!(lin.params, dense.params + 2 * k * l * F32);
        assert_eq!(lin.ring_buf, Some(0));
        assert!(lin.attn_stash < dense.attn_stash, "K < L must shrink the stash");
        // block: causal reach — width grows with rank, hits full L on the
        // last rank when the window spans the sequence
        let w = l as usize;
        for d in 0..n {
            assert_eq!(block_stash_width(d, n, lc as usize, w), (d as u64 + 1) * lc);
        }
        let blk_last = sp_expect(&shape, strat, AttnPattern::Block { w }, n - 1);
        assert_eq!(
            blk_last.attn_stash,
            12 * (4 * b * z * lc * a + b * z * lc * l) * F32,
            "full-reach last rank matches the dense width"
        );
        assert_eq!(blk_last.ring_buf, None, "block ring-buf is reported, not validated");
        let blk_first = sp_expect(&shape, strat, AttnPattern::Block { w }, 0);
        assert!(blk_first.attn_stash < blk_last.attn_stash, "reach grows with rank");
    }
}
