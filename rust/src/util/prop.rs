//! Miniature property-testing harness — proptest is unavailable offline.
//!
//! Runs a property over many randomized cases from a deterministic seed;
//! on failure it reports the case index and seed so the exact case can be
//! replayed (`Prop::replay`).  No shrinking — cases are kept small enough
//! to be readable directly.
//!
//! Used by the coordinator invariants tests (routing, chunking, collective
//! correctness, ledger-vs-formula) — see rust/tests/.

use crate::util::rng::Rng;

/// Uniform pick from a non-empty slice (generator helper for properties).
pub fn pick<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
    assert!(!items.is_empty(), "pick from empty slice");
    &items[rng.below(items.len() as u64) as usize]
}

/// The divisors of `n`, ascending (n >= 1).
pub fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// A uniform-ish random 3-way factorization `(a, b, c)` with
/// `a * b * c == world`: draw `a` from the divisors of `world`, `b` from
/// the divisors of the remainder.  The mesh fuzz uses this to sample
/// valid (dp, pp, mp) splits of a world size; invalid model shapes are
/// rejected downstream via the constructors.
pub fn factor3(rng: &mut Rng, world: usize) -> (usize, usize, usize) {
    assert!(world >= 1);
    let a = *pick(rng, &divisors(world));
    let rest = world / a;
    let b = *pick(rng, &divisors(rest));
    (a, b, rest / b)
}

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0x5e9_9a11e1 }
    }
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// Run `property` over `cases` randomized cases.  The property gets a
    /// per-case RNG; `Err` fails the run with replay info.
    pub fn check<F>(&self, name: &str, mut property: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add(case as u64));
            if let Err(msg) = property(&mut rng) {
                panic!(
                    "property {name:?} failed at case {case}/{} (seed {}): {msg}\n\
                     replay with Prop::replay({name:?}, {}, {case}, ...)",
                    self.cases, self.seed, self.seed
                );
            }
        }
    }

    /// Re-run a single failing case by index.
    pub fn replay<F>(name: &str, seed: u64, case: usize, mut property: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        let mut rng = Rng::new(seed.wrapping_add(case as u64));
        if let Err(msg) = property(&mut rng) {
            panic!("replayed property {name:?} case {case}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        Prop::new(16, 1).check("u64 plus zero", |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(0) == x {
                Ok(())
            } else {
                Err(format!("{x} + 0 != {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failing_case() {
        Prop::new(4, 2).check("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn factor3_products_match_the_world() {
        Prop::new(64, 3).check("factor3 multiplies back", |rng| {
            for world in [1usize, 2, 4, 6, 8, 12] {
                let (a, b, c) = factor3(rng, world);
                if a * b * c != world {
                    return Err(format!("{a}*{b}*{c} != {world}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn divisors_are_exact() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn factor3_covers_nontrivial_splits() {
        // over many draws of world=8 we must see a split with every axis > 1
        let mut rng = Rng::new(9);
        let mut saw_3d = false;
        for _ in 0..200 {
            let (a, b, c) = factor3(&mut rng, 8);
            if a > 1 && b > 1 && c > 1 {
                saw_3d = true;
            }
        }
        assert!(saw_3d, "factor3 never produced a genuinely 3D split of 8");
    }
}
