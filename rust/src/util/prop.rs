//! Miniature property-testing harness — proptest is unavailable offline.
//!
//! Runs a property over many randomized cases from a deterministic seed;
//! on failure it reports the case index and seed so the exact case can be
//! replayed (`Prop::replay`).  No shrinking — cases are kept small enough
//! to be readable directly.
//!
//! Used by the coordinator invariants tests (routing, chunking, collective
//! correctness, ledger-vs-formula) — see rust/tests/.

use crate::util::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0x5e9_9a11e1 }
    }
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// Run `property` over `cases` randomized cases.  The property gets a
    /// per-case RNG; `Err` fails the run with replay info.
    pub fn check<F>(&self, name: &str, mut property: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add(case as u64));
            if let Err(msg) = property(&mut rng) {
                panic!(
                    "property {name:?} failed at case {case}/{} (seed {}): {msg}\n\
                     replay with Prop::replay({name:?}, {}, {case}, ...)",
                    self.cases, self.seed, self.seed
                );
            }
        }
    }

    /// Re-run a single failing case by index.
    pub fn replay<F>(name: &str, seed: u64, case: usize, mut property: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        let mut rng = Rng::new(seed.wrapping_add(case as u64));
        if let Err(msg) = property(&mut rng) {
            panic!("replayed property {name:?} case {case}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        Prop::new(16, 1).check("u64 plus zero", |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(0) == x {
                Ok(())
            } else {
                Err(format!("{x} + 0 != {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failing_case() {
        Prop::new(4, 2).check("always fails", |_| Err("nope".into()));
    }
}
