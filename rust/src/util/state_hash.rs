//! Deterministic training-state hash.
//!
//! One `u64` fingerprint over (params, grads, optimizer moments, data
//! cursor): the chaos suite's "recovered == clean" contract and the
//! checkpoint-resume tests compare a single pinned hash per scenario
//! instead of ad-hoc per-tensor loops.  The hash is FNV-1a over a
//! canonical byte stream — sorted parameter names, shapes, and raw
//! little-endian element bits — so equal hashes mean bit-identical state,
//! not merely approximately-equal state.
//!
//! Not a cryptographic hash and not portable across dtype layout changes;
//! it only needs to be deterministic within one build, which is all the
//! equivalence tests require.

use crate::model::params::ParamStore;
use crate::tensor::{TData, Tensor};
use crate::train::optim::Adam;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a hasher over a canonical byte stream.
#[derive(Clone, Copy, Debug)]
pub struct StateHash(u64);

impl Default for StateHash {
    fn default() -> Self {
        StateHash::new()
    }
}

impl StateHash {
    pub fn new() -> StateHash {
        StateHash(FNV_OFFSET)
    }

    pub fn bytes(&mut self, data: &[u8]) -> &mut Self {
        for &b in data {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        // length prefix keeps ("ab","c") distinct from ("a","bc")
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    pub fn tensor(&mut self, t: &Tensor) -> &mut Self {
        self.u64(t.shape.len() as u64);
        for &d in &t.shape {
            self.u64(d as u64);
        }
        match &t.data {
            TData::F32(v) => {
                self.u64(0);
                for x in v {
                    self.bytes(&x.to_bits().to_le_bytes());
                }
            }
            TData::I32(v) => {
                self.u64(1);
                for x in v {
                    self.bytes(&x.to_le_bytes());
                }
            }
        }
        self
    }

    /// Hash a whole store under a label.  BTreeMap iteration is already
    /// name-sorted, so the stream is canonical.
    pub fn store(&mut self, label: &str, s: &ParamStore) -> &mut Self {
        self.str(label);
        self.u64(s.values.len() as u64);
        for (name, t) in &s.values {
            self.str(name);
            self.tensor(t);
        }
        self
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// The canonical training-state fingerprint: params + Adam moments + Adam
/// step + data-loader cursor.  Two runs with equal hashes here will produce
/// bit-identical futures (engines are stateless; this is the whole state).
pub fn train_state_hash(params: &ParamStore, adam: &Adam, data_cursor: u64) -> u64 {
    let (m, v, t) = adam.state();
    let mut h = StateHash::new();
    h.store("params", params)
        .store("adam_m", m)
        .store("adam_v", v)
        .u64(t)
        .u64(data_cursor);
    h.finish()
}

/// Fingerprint of raw stores (params / moments already split out of an
/// optimizer, e.g. from a loaded checkpoint) plus scalar cursors.
pub fn stores_hash(stores: &[(&str, &ParamStore)], scalars: &[u64]) -> u64 {
    let mut h = StateHash::new();
    for (label, s) in stores {
        h.store(label, s);
    }
    for &v in scalars {
        h.u64(v);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn store(seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::default();
        s.values
            .insert("a.w".into(), Tensor::randn(&[4, 4], 0.1, &mut rng));
        s.values
            .insert("b".into(), Tensor::randn(&[4], 0.1, &mut rng));
        s
    }

    #[test]
    fn equal_state_equal_hash() {
        let a = stores_hash(&[("p", &store(7))], &[3]);
        let b = stores_hash(&[("p", &store(7))], &[3]);
        assert_eq!(a, b);
    }

    #[test]
    fn any_perturbation_changes_the_hash() {
        let base = stores_hash(&[("p", &store(7))], &[3]);
        // different values
        assert_ne!(base, stores_hash(&[("p", &store(8))], &[3]));
        // different scalar cursor
        assert_ne!(base, stores_hash(&[("p", &store(7))], &[4]));
        // different label
        assert_ne!(base, stores_hash(&[("q", &store(7))], &[3]));
        // single-element bit flip
        let mut s = store(7);
        if let TData::F32(v) = &mut s.values.get_mut("a.w").unwrap().data {
            v[5] += 1e-7;
        }
        assert_ne!(base, stores_hash(&[("p", &s)], &[3]));
    }

    #[test]
    fn shape_is_part_of_the_identity() {
        let mut flat = ParamStore::default();
        flat.values
            .insert("w".into(), Tensor::from_f32(&[4], vec![1.0; 4]).unwrap());
        let mut sq = ParamStore::default();
        sq.values
            .insert("w".into(), Tensor::from_f32(&[2, 2], vec![1.0; 4]).unwrap());
        assert_ne!(
            stores_hash(&[("p", &flat)], &[]),
            stores_hash(&[("p", &sq)], &[])
        );
    }
}
