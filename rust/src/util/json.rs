//! Minimal JSON parser — serde is unavailable in the offline build.
//!
//! Supports the full JSON grammar we emit from `aot.py` (objects, arrays,
//! strings with escapes, f64 numbers, booleans, null).  Errors carry byte
//! offsets for debuggability.  Writing is handled by a tiny encoder at the
//! bottom (used for run reports / bench output).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access that errors with the full path.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Strict integral access: `Some` only for non-negative whole numbers
    /// that fit in `usize` — `-1`, `2.5` or `1e300` return `None` instead
    /// of silently truncating (manifest dims must be exact).
    pub fn as_usize(&self) -> Option<usize> {
        match self.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f <= usize::MAX as f64 => Some(f as usize),
            _ => None,
        }
    }

    /// The JSON type of this value — for "expected X, got Y" errors.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "a bool",
            Value::Num(_) => "a number",
            Value::Str(_) => "a string",
            Value::Arr(_) => "an array",
            Value::Obj(_) => "an object",
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not produced by aot.py;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch = std::str::from_utf8(s)
                        .map_err(|_| self.err("invalid utf-8"))?
                        .chars()
                        .next()
                        .unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Encode a [`Value`] as compact JSON (reports, bench output).
pub fn encode(v: &Value) -> String {
    let mut s = String::new();
    enc(v, &mut s);
    s
}

fn enc(v: &Value, s: &mut String) {
    match v {
        Value::Null => s.push_str("null"),
        Value::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                s.push_str(&format!("{}", *n as i64));
            } else {
                s.push_str(&format!("{n}"));
            }
        }
        Value::Str(v) => {
            s.push('"');
            for c in v.chars() {
                match c {
                    '"' => s.push_str("\\\""),
                    '\\' => s.push_str("\\\\"),
                    '\n' => s.push_str("\\n"),
                    '\t' => s.push_str("\\t"),
                    '\r' => s.push_str("\\r"),
                    c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
                    c => s.push(c),
                }
            }
            s.push('"');
        }
        Value::Arr(a) => {
            s.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                enc(v, s);
            }
            s.push(']');
        }
        Value::Obj(m) => {
            s.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                enc(&Value::Str(k.clone()), s);
                s.push(':');
                enc(v, s);
            }
            s.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn usize_access_is_strict() {
        assert_eq!(Value::Num(42.0).as_usize(), Some(42));
        assert_eq!(Value::Num(0.0).as_usize(), Some(0));
        // truncation hazards all refuse instead of rounding
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Num(2.5).as_usize(), None);
        assert_eq!(Value::Num(1e300).as_usize(), None);
        assert_eq!(Value::Str("3".into()).as_usize(), None);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_via_encode() {
        let src = r#"{"a":[1,2.5,"x"],"b":true,"c":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&encode(&v)).unwrap(), v);
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"artifacts": {"linear_fwd__2x3_3x4_4":
            {"file": "f.hlo.txt",
             "inputs": [{"dims": [2,3], "dtype": "f32"}],
             "outputs": [{"dims": [2,4], "dtype": "f32"}]}}}"#;
        let v = parse(src).unwrap();
        let art = v.get("artifacts").unwrap().get("linear_fwd__2x3_3x4_4").unwrap();
        let dims: Vec<usize> = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("dims").unwrap().as_arr().unwrap()
            .iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![2, 3]);
    }
}
