//! Tiny CLI argument parser — clap is unavailable in the offline build.
//!
//! Supports `--flag`, `--key value`, and `--key=value`; everything else is
//! a positional.  Typed getters parse on access and report the offending
//! flag on error.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                    out.present.push(stripped.to_string());
                } else {
                    out.flags.insert(stripped.to_string(), String::new());
                    out.present.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Present-or-absent usize flag (`--skew 2`): `None` when the flag was
    /// not given, an error when it was given but does not parse.
    pub fn usize_opt(&self, key: &str) -> anyhow::Result<Option<usize>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// 'x'-separated usize triple, e.g. `--mesh 2x2x4` (DP×PP×MP).
    pub fn triple_opt(&self, key: &str) -> anyhow::Result<Option<(usize, usize, usize)>> {
        let Some(v) = self.flags.get(key) else {
            return Ok(None);
        };
        let parts: Vec<&str> = v.split('x').collect();
        if parts.len() != 3 {
            anyhow::bail!("--{key} expects AxBxC (e.g. 2x2x4), got {v:?}");
        }
        let p = |s: &str| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{key}: bad axis {s:?} in {v:?}"))
        };
        Ok(Some((p(parts[0])?, p(parts[1])?, p(parts[2])?)))
    }

    /// Comma-separated usize list, e.g. `--sizes 1,2,4,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer {p:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_and_flags() {
        // convention: positionals first — a bare flag greedily takes the
        // next non-flag token as its value, so `--verbose out.json` would
        // bind them together.
        let a = args("train out.json --steps 10 --model=bert-tiny --verbose");
        assert_eq!(a.positional, vec!["train", "out.json"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
        assert_eq!(a.str_or("model", ""), "bert-tiny");
        assert!(a.has("verbose"));
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
    }

    #[test]
    fn type_errors_name_the_flag() {
        let a = args("--steps ten");
        let err = a.usize_or("steps", 0).unwrap_err().to_string();
        assert!(err.contains("--steps"), "{err}");
    }

    #[test]
    fn parses_mesh_triples() {
        let a = args("--mesh 2x2x4");
        assert_eq!(a.triple_opt("mesh").unwrap(), Some((2, 2, 4)));
        assert_eq!(a.triple_opt("absent").unwrap(), None);
        for bad in ["2x2", "2x2x4x8", "axbxc", "2xx4"] {
            let b = args(&format!("--mesh {bad}"));
            assert!(b.triple_opt("mesh").is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn parses_lists() {
        let a = args("--sizes 1,2, 4");
        // note: "4" after the space is positional; list parsing is on the value
        assert_eq!(a.usize_list_or("sizes", &[]).unwrap(), vec![1, 2]);
        let b = args("--sizes 1,2,4");
        assert_eq!(b.usize_list_or("sizes", &[]).unwrap(), vec![1, 2, 4]);
    }
}
