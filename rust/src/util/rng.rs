//! Deterministic PRNG (xoshiro256++) — data generation, init, proptest.
//!
//! Determinism matters more than statistical perfection here: the engines
//! must be byte-reproducible across runs so that the Fig. 6 convergence
//! comparison is an apples-to-apples curve, and the property harness must
//! replay failures from a printed seed.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's unbiased bounded sampling would be overkill; modulo bias
        // is negligible for n << 2^64 and determinism is what we need.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (synthetic corpus).
    /// Rejection-inversion would be fancier; CDF inversion over a cached
    /// normalizer is exact and fast enough for corpus generation.
    pub fn zipf(&mut self, n: usize, s: f64, harmonic: f64) -> usize {
        let target = self.uniform() * harmonic;
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            if acc >= target {
                return k;
            }
        }
        n - 1
    }
}

/// Precompute the generalized harmonic number H_{n,s} for [`Rng::zipf`].
pub fn harmonic(n: usize, s: f64) -> f64 {
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_rank_zero_most_frequent() {
        let mut r = Rng::new(3);
        let h = harmonic(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..5_000 {
            counts[r.zipf(100, 1.1, h)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[99]);
    }
}
