//! Offline-build substrates.
//!
//! The build environment vendors only the `xla` crate and its transitive
//! deps, so the conveniences a networked project would pull from crates.io
//! are implemented here: a JSON parser ([`json`]), a CLI argument parser
//! ([`cli`]), a deterministic PRNG ([`rng`]), and a miniature
//! property-testing harness ([`prop`]) standing in for proptest, plus a
//! deterministic training-state fingerprint ([`state_hash`]) used by the
//! checkpoint-resume and chaos-recovery equivalence tests.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod state_hash;
