//! Minimal benchmark harness — criterion is unavailable offline.
//!
//! Warmup + N timed iterations, reporting mean / p50 / p95 / min.  The
//! `cargo bench` targets (Cargo.toml `[[bench]]`, `harness = false`) use
//! this to time the real hot paths and to regenerate the paper's
//! figures/tables (benches print the same rows the paper reports).
//! Samples come off [`crate::obs::Stopwatch`] so bench numbers, trainer
//! tok/s and backend kernel stats all share one clock discipline.

use crate::obs::Stopwatch;

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn report(&self, name: &str) {
        println!(
            "{name:<44} {:>6} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_ns() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let idx = |q: f64| ((samples.len() as f64 - 1.0) * q) as usize;
    BenchStats {
        iters,
        mean_ns: mean,
        p50_ns: samples[idx(0.5)],
        p95_ns: samples[idx(0.95)],
        min_ns: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench(2, 32, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p95_ns);
        assert_eq!(s.iters, 32);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("µs"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with('s'));
    }
}
