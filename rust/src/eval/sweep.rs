//! `seqpar sweep --experiment <id>` — print a paper figure/table.

use anyhow::{bail, Result};

use crate::model::by_name;
use crate::simulator::Cluster;
use crate::util::cli::Args;

use super::figures;

fn fmt_opt_usize(v: Option<usize>) -> String {
    v.map(|x| x.to_string()).unwrap_or_else(|| "—".into())
}

fn fmt_opt_f64(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.0}")).unwrap_or_else(|| "—".into())
}

pub fn run(args: &Args) -> Result<()> {
    let exp = args.str_or("experiment", "all").to_string();
    let cluster = Cluster::default();
    match exp.as_str() {
        "fig3a" | "fig3b" | "fig3" | "fig7" => fig3(&cluster, args),
        "fig4a" | "fig4b" | "fig4" | "fig8" => fig4(&cluster, args),
        "fig5a" | "fig9" => fig5a(&cluster, args),
        "fig5b" => fig5b(&cluster, args),
        "table4" => table4(&cluster, args),
        "tables" => tables12(args),
        "all" => {
            fig3(&cluster, args)?;
            println!();
            fig4(&cluster, args)?;
            println!();
            fig5a(&cluster, args)?;
            println!();
            fig5b(&cluster, args)?;
            println!();
            table4(&cluster, args)?;
            println!();
            tables12(args)
        }
        other => bail!("unknown --experiment {other:?}"),
    }
}

fn model_of(args: &Args) -> Result<crate::model::ModelConfig> {
    by_name(args.str_or("model", "bert-base"))
}

fn fig3(cluster: &Cluster, args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let fig = if model.name == "bert-large" { "Fig. 7" } else { "Fig. 3" };
    println!("=== {fig}a/b — {} max batch & throughput vs parallel size (L=512) ===", model.name);
    println!("{:>4} | {:>12} {:>12} | {:>12} {:>12}", "n", "TP maxB", "SP maxB", "TP tok/s", "SP tok/s");
    let rows = figures::fig3(cluster, model);
    for r in &rows {
        // SP is infeasible when n does not divide L=512 (the paper's own
        // divisibility requirement) — shown as "—" like TP past its cap.
        let (sp_b, sp_t) = if r.sp_max_batch == 0 {
            ("—".to_string(), "—".to_string())
        } else {
            (r.sp_max_batch.to_string(), format!("{:.0}", r.sp_tokens_per_sec))
        };
        println!(
            "{:>4} | {:>12} {:>12} | {:>12} {:>12}",
            r.n,
            fmt_opt_usize(r.tp_max_batch),
            sp_b,
            fmt_opt_f64(r.tp_tokens_per_sec),
            sp_t,
        );
    }
    // headline ratio (paper: 13.7x for Base SP@64 vs TP@12)
    let tp_best = rows
        .iter()
        .filter_map(|r| r.tp_max_batch)
        .max()
        .unwrap_or(1)
        .max(1);
    let sp64 = rows.iter().find(|r| r.n == 64).map(|r| r.sp_max_batch).unwrap_or(0);
    println!(
        "SP@64 / best-TP max batch = {:.1}x   (paper: 13.7x Base, 10.2x Large)",
        sp64 as f64 / tp_best as f64
    );
    Ok(())
}

fn fig4(cluster: &Cluster, args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let fig = if model.name == "bert-large" { "Fig. 8" } else { "Fig. 4" };
    println!("=== {fig}a/b — {} scaling along pipeline size (MP=4, L=512, micros=8) ===", model.name);
    println!("{:>6} | {:>12} {:>12} | {:>12} {:>12}", "stages", "TP maxB", "SP maxB", "TP tok/s", "SP tok/s");
    for r in figures::fig4(cluster, model) {
        println!(
            "{:>6} | {:>12} {:>12} | {:>12} {:>12}",
            r.n,
            fmt_opt_usize(r.tp_max_batch),
            r.sp_max_batch,
            fmt_opt_f64(r.tp_tokens_per_sec),
            format!("{:.0}", r.sp_tokens_per_sec),
        );
    }
    println!("(SP's pipeline boundary skips Megatron's split+all-gather — §3.2.2)");
    Ok(())
}

fn fig5a(cluster: &Cluster, args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let (fig, batch) = if model.name == "bert-large" { ("Fig. 9", 16) } else { ("Fig. 5a", 64) };
    println!("=== {fig} — {} max sequence length vs devices (batch={batch}) ===", model.name);
    println!("{:>4} | {:>12} {:>12}", "n", "TP maxL", "SP maxL");
    let rows = figures::fig5a(cluster, model, batch);
    for r in &rows {
        println!("{:>4} | {:>12} {:>12}", r.n, fmt_opt_usize(r.tp_max_len), r.sp_max_len);
    }
    let tp_best = rows.iter().filter_map(|r| r.tp_max_len).max().unwrap_or(1).max(1);
    let sp64 = rows.iter().find(|r| r.n == 64).map(|r| r.sp_max_len).unwrap_or(0);
    println!(
        "SP@64 / best-TP max length = {:.1}x   (paper: ~3x Base, ~2x Large)",
        sp64 as f64 / tp_best as f64
    );
    Ok(())
}

fn fig5b(cluster: &Cluster, args: &Args) -> Result<()> {
    let model = model_of(args)?;
    println!("=== Fig. 5b — {} sequence length upper bound, batch=4 (Linformer K=256) ===", model.name);
    println!("{:>4} | {:>12} {:>12} {:>10}", "n", "dense maxL", "sparse maxL", "ideal");
    let rows = figures::fig5b(cluster, model);
    let base = rows.first().map(|r| r.sparse_max_len).unwrap_or(0);
    for r in &rows {
        println!(
            "{:>4} | {:>12} {:>12} {:>10}",
            r.n, r.dense_max_len, r.sparse_max_len, base * r.n
        );
    }
    if let Some(last) = rows.last() {
        println!(
            "sparse @{} devices: {} tokens  (paper: >114K on 32 P100s)",
            last.n, last.sparse_max_len
        );
    }
    Ok(())
}

fn table4(cluster: &Cluster, args: &Args) -> Result<()> {
    let model = model_of(args)?;
    println!("=== Table 4 — weak scaling (pipeline=8) — {} ===", model.name);
    println!(
        "{:>4} {:>6} {:>6} | {:>10} {:>10} | {:>10} {:>10}",
        "n", "batch", "L", "TP MB", "TP tok/s", "SP MB", "SP tok/s"
    );
    for r in figures::table4(cluster, model) {
        println!(
            "{:>4} {:>6} {:>6} | {:>10} {:>10} | {:>10.1} {:>10.0}",
            r.n,
            r.batch,
            r.seq_len,
            r.tp_mem_mb.map(|m| format!("{m:.1}")).unwrap_or_else(|| "OOM".into()),
            fmt_opt_f64(r.tp_tokens_per_sec),
            r.sp_mem_mb,
            r.sp_tokens_per_sec,
        );
    }
    Ok(())
}

fn tables12(args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let (b, l, n) = (
        args.usize_or("batch", 64)? as u64,
        args.usize_or("seq-len", 512)? as u64,
        args.usize_or("mp", 8)? as u64,
    );
    println!("=== Tables 1 & 2 — closed-form memory (elements), {} B={b} L={l} N={n} ===", model.name);
    for row in figures::tables12(model, b, l, n) {
        println!(
            "{:<22} TP {:>14}  SP {:>14}   winner: {}",
            row.block,
            row.tp_elems,
            row.sp_elems,
            if row.sp_wins { "sequence" } else { "tensor" }
        );
    }
    let h = model.hidden as u64;
    let (a, z) = (model.head_dim as u64, model.heads as u64);
    println!(
        "break-evens: MLP BL > 32H = {}  (BL = {});  Attn BL > 16AZ = {}  (BL = {})",
        crate::simulator::memory::mlp_breakeven_bl(h),
        b * l,
        crate::simulator::memory::attn_breakeven_bl(a, z),
        b * l
    );
    Ok(())
}
