//! CLI subcommand implementations.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::comm::{Fabric, Meter};
use crate::model::params::ParamStore;
use crate::parallel::sequence::SeqParEngine;
use crate::parallel::tensorp::TensorParEngine;
use crate::parallel::{Batch, Engine};
use crate::runtime::Runtime;
use crate::tensor::{io, ops};
use crate::train::data::{Corpus, CorpusConfig};
use crate::train::trainer::{TrainConfig, Trainer};
use crate::util::cli::Args;

pub const HELP: &str = "\
seqpar — Sequence Parallelism (Li et al., ACL 2023) reproduction

USAGE:
  seqpar <command> [flags]

COMMANDS:
  info      print manifest + runtime summary
  verify    run the rust engines against the python-exported goldens
  train     train with --engine seq|tensor|serial (Fig. 6 convergence)
  sweep     regenerate a paper figure/table via the cluster simulator
  help      this text

COMMON FLAGS:
  --artifacts DIR     artifact directory (default: artifacts)
  --steps N           training steps (train; default 50)
  --engine NAME       seq | tensor | serial (train; default seq)
  --seed N            corpus seed (train; default 7)
  --experiment ID     fig3a|fig3b|fig4a|fig4b|fig5a|fig5b|fig7|fig8|fig9|
                      table4|tables (sweep)
  --model NAME        bert-base | bert-large (sweep; default bert-base)
";

pub fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

pub fn info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::open(&dir)?;
    let m = &rt.manifest;
    println!("manifest: {}", dir.join("manifest.json").display());
    println!(
        "model {}  layers={} H={} Z={} A={} FFN={} V={}",
        m.model, m.layers, m.hidden, m.heads, m.head_dim, m.ffn, m.vocab
    );
    println!(
        "run shapes: batch={} seq_len={} ring={} tp={} linformer_k={}",
        m.batch, m.seq_len, m.ring, m.tp, m.linformer_k
    );
    println!("artifacts: {}", m.artifacts.len());
    println!("params: {} tensors", m.params.len());
    println!("goldens: {} tensors", m.goldens.len());
    Ok(())
}

/// Load the golden batch exported by aot.py.
pub fn golden_batch(rt: &Runtime, dir: &PathBuf) -> Result<Batch> {
    let g = |name: &str| -> Result<_> {
        let rel = rt
            .manifest
            .goldens
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("golden {name:?} missing"))?;
        io::load(&dir.join(rel))
    };
    Ok(Batch {
        ids: g("ids")?,
        labels: g("labels")?,
        mask: g("mask")?,
        sop_labels: g("sop_labels")?,
    })
}

pub fn verify(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::open(&dir)?;
    let params = ParamStore::load(&dir, &rt.manifest)?;
    let batch = golden_batch(&rt, &dir)?;
    let n = rt.manifest.ring;
    let tol = 2e-3f32;

    // ---- sequence-parallel engine vs python chain goldens ---------------
    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(n, meter.clone()))?;
    let out = engine.forward_backward(&params, &batch)?;
    let want_loss = io::load(&dir.join(&rt.manifest.goldens["loss"]))?;
    let wl = want_loss.f32s()?;
    println!(
        "seq-par  loss {:.6} (golden {:.6})  mlm {:.6}/{:.6}  sop {:.6}/{:.6}",
        out.loss, wl[0], out.mlm, wl[1], out.sop, wl[2]
    );
    if (out.loss - wl[0]).abs() > tol {
        bail!("loss mismatch: {} vs golden {}", out.loss, wl[0]);
    }
    let mut worst = 0.0f32;
    for d in 0..n {
        let want = io::load(&dir.join(&rt.manifest.goldens[&format!("hidden_dev{d}")]))?;
        let diff = ops::max_abs_diff(&out.hidden[d], &want)?;
        worst = worst.max(diff);
    }
    println!("seq-par  hidden max|Δ| = {worst:.2e} over {n} devices");
    if worst > tol {
        bail!("hidden mismatch {worst}");
    }
    for gname in ["layer0.wq", "mlm_b", "tok_emb"] {
        let file = &rt.manifest.goldens[&format!("grad_{}", gname.replace('.', "_"))];
        let want = io::load(&dir.join(file))?;
        let diff = ops::max_abs_diff(&out.grads.values[gname], &want)?;
        println!("seq-par  grad[{gname}] max|Δ| = {diff:.2e}");
        if diff > tol {
            bail!("grad {gname} mismatch {diff}");
        }
    }
    println!(
        "seq-par  comm: ring_p2p={}B all_reduce={}B ({} ops)",
        meter.get(crate::comm::CommKind::RingP2p),
        meter.get(crate::comm::CommKind::AllReduce),
        meter.snapshot().ops,
    );

    // ---- serial engine must agree with seq-par ---------------------------
    let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new()))?;
    let sout = serial.forward_backward(&params, &batch)?;
    println!("serial   loss {:.6}  (Δ vs seq-par {:.2e})", sout.loss, (sout.loss - out.loss).abs());
    if (sout.loss - out.loss).abs() > tol {
        bail!("serial/seq-par disagree: {} vs {}", sout.loss, out.loss);
    }

    // ---- tensor-parallel engine must agree too ---------------------------
    let tp = rt.manifest.tp;
    if tp > 1 {
        let tpe = TensorParEngine::new(&rt, Fabric::new(tp, Meter::new()))?;
        let tout = tpe.forward_backward(&params, &batch)?;
        println!("tensor{tp}  loss {:.6}  (Δ vs serial {:.2e})", tout.loss, (tout.loss - sout.loss).abs());
        if (tout.loss - sout.loss).abs() > tol {
            bail!("tensor-par/serial disagree: {} vs {}", tout.loss, sout.loss);
        }
    }
    let stats = rt.stats();
    println!(
        "runtime: {} executables compiled, {} calls, compile {:.2}s, exec {:.2}s",
        rt.cached_executables(),
        stats.calls,
        stats.compile_nanos as f64 / 1e9,
        stats.exec_nanos as f64 / 1e9,
    );
    println!("VERIFY OK");
    Ok(())
}

pub fn train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::open(&dir)?;
    let mut params = ParamStore::load(&dir, &rt.manifest)?;
    let steps = args.usize_or("steps", 50)? as u64;
    let seed = args.usize_or("seed", 7)? as u64;
    let engine_name = args.str_or("engine", "seq").to_string();
    let m = &rt.manifest;
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let cfg = TrainConfig {
        steps,
        warmup: (steps / 10).max(1),
        peak_lr: args.f64_or("lr", 1e-3)? as f32,
        log_every: args.usize_or("log-every", 10)? as u64,
    };
    let meter = Meter::new();
    match engine_name.as_str() {
        "seq" => {
            let e = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone()))?;
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        "tensor" => {
            let e = TensorParEngine::new(&rt, Fabric::new(m.tp, meter.clone()))?;
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        "serial" => {
            let e = TensorParEngine::new(&rt, Fabric::new(1, meter.clone()))?;
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        other => bail!("unknown --engine {other:?} (seq|tensor|serial)"),
    }
    let s = meter.snapshot();
    println!(
        "comm totals: ring_p2p={} all_reduce={} all_gather={} pipeline={} ({} ops)",
        s.ring_p2p, s.all_reduce, s.all_gather, s.pipeline, s.ops
    );
    Ok(())
}

pub fn sweep(args: &Args) -> Result<()> {
    crate::eval::sweep::run(args)
}
