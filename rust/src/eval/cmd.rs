//! CLI subcommand implementations.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::analysis::{self, Analysis, TraceEvent};
use crate::attn::AttnPattern;
use crate::backend::native::NativeConfig;
use crate::comm::{Fabric, Meter, MeterSnapshot};
use crate::exec::{
    DistRunner, Elastic, ElasticConfig, MeshEngine, MeshRunner, MeshStep, RecoverPolicy, Topo,
};
use crate::parallel::pipeline::Schedule;
use crate::parallel::sequence::{SeqParEngine, SpStrategy};
use crate::parallel::tensorp::TensorParEngine;
use crate::parallel::topology::{Mesh, MpKind};
use crate::model::params::ParamStore;
use crate::parallel::{Batch, Engine};
use crate::runtime::Runtime;
use crate::tensor::{io, ops};
use crate::train::data::{Corpus, CorpusConfig};
use crate::train::trainer::{MeshTrainer, TrainConfig, Trainer};
use crate::util::cli::Args;

pub const HELP: &str = "\
seqpar — Sequence Parallelism (Li et al., ACL 2023) reproduction

USAGE:
  seqpar <command> [flags]

COMMANDS:
  info      print manifest + runtime summary
  verify    check RSA == serial == tensor-parallel (and goldens, if any)
  train     train with --engine seq|tensor|serial (Fig. 6 convergence)
  analyze   statically verify the collective schedule: abstract-interpret
            the step program over symbolic comm traces + a shape-only
            executor, prove deadlock-freedom (all ranks issue identical
            collective sequences), lint every kernel call against the
            manifest, and cross-check trace-derived byte totals against
            the closed forms AND a measured one-step runtime meter.
            Takes the train flags (--engine/--attn/--sp/--mesh/--micros).
            --grid sweeps the whole equivalence-grid config matrix;
            --skew R injects a divergent collective on rank R to
            demonstrate the rank-by-rank divergence report
  sweep     regenerate a paper figure/table via the cluster simulator
  trace     run a short traced training (default --steps 1) and print the
            measured metrics report: step wall time, per-kind comm
            wait/transfer attribution, top-k kernels by total time,
            tokens/sec, (on a mesh) the measured pipeline bubble, and
            the per-rank memory table — measured peak bytes by category
            (params/grads/optimizer/activation/attn_stash/ring_buf/
            pipe_stash; see README \"Memory profiling\").
            Takes the train flags.  --out FILE writes the report JSON
            (the BENCH_obs.json payload, with a \"mem\" key), --trace
            FILE also dumps the Chrome trace with its ph:\"C\" memory
            counter track.  --validate FILE instead schema-checks an
            existing Chrome-trace file OR a BENCH_mem.json memory
            profile (dispatched on its mem_rows key) and summarizes it
  help      this text

BACKEND FLAGS:
  --backend MODE      native | xla | auto (default auto: xla when
                      artifacts/manifest.json exists and the build has the
                      backend-xla feature, otherwise native)
  --artifacts DIR     artifact directory for the xla backend (default:
                      artifacts)
  --model NAME        native run shape (default bert-tiny)
  --batch N --seq-len N --ring N --tp N --linformer K --init-seed N
                      native run shape (defaults 2/32/4/2/0/0).
                      --linformer K registers the projection kernels AND
                      adds the trainable E_k/E_v params; prefer
                      --attn linformer:K, which implies it

COMMON FLAGS:
  --steps N           training steps (train; default 50)
  --engine NAME       seq | tensor | serial (train; default seq)
  --attn PATTERN      dense | linformer:K | block:W — attention pattern
                      for --engine seq (default dense).  linformer:K
                      projects K/V to K rows (one L-independent all-reduce
                      per layer instead of the ring); block:W applies a
                      token-level causal band of W tokens and skips both
                      the kernels and the ring hops of fully masked
                      chunk pairs (see README \"Sparse attention\")
  --sp STRATEGY       ring | ulysses — how --engine seq moves cross-chunk
                      attention data (default ring).  ring rotates K/V
                      chunks around the ring every layer (the paper's
                      RSA); ulysses re-shards q/k/v into whole-head
                      shards with all-to-alls and runs full-sequence
                      attention locally (8 all-to-alls per layer, flat in
                      the ring size; needs ring | head count and --attn
                      dense; see README \"Choosing an SP strategy\")
  --overlap           (train/trace, --engine seq) double-buffer the
                      attention ring: post each K/V chunk shift
                      nonblocking and compute on the held chunk while it
                      is in flight.  Numerically identical to the
                      blocking schedule and meters exactly the same
                      bytes; on the threaded runners the recv wait moves
                      off the critical path (see the overlap_efficiency
                      field in `trace --out` reports).  Costs one extra
                      in-flight K/V chunk of ring-buffer memory per rank
  --threads N         run `train --engine seq` on N OS threads — one per
                      ring rank via exec::DistRunner (native backend
                      only; implies --ring N, since rank count must equal
                      the ring size the manifest was built for)
  --mesh DPxPPxMP     execute a full 4D mesh training step (one OS thread
                      per mesh coordinate via exec::MeshRunner): data x
                      pipeline x model parallelism, where the model axis
                      is a sequence ring (--engine seq, implies --ring MP)
                      or the Megatron tensor baseline (--engine tensor,
                      implies --tp MP).  E.g. --mesh 2x2x2 (8 threads)
  --micros M          GPipe microbatches per mesh step (default 1); each
                      microbatch is one manifest-shaped batch
  --mesh-sim          run the mesh sequentially simulated (exec::MeshEngine)
                      instead of threaded — byte-identical meters
  --trace FILE        (train/trace) record every runtime span — kernels,
                      collectives with bytes + channel-wait time, ring
                      hops, GPipe cells, optimizer — and write Chrome
                      trace-format JSON, one pid per rank (open in
                      Perfetto or chrome://tracing).  Per-comm-kind event
                      counts and bytes are checked against the run's
                      meter at exit and must match exactly.  A memory-
                      accounting session rides along: the trace gains a
                      ph:\"C\" \"memory\" counter track (live bytes by
                      category under each rank's timeline) and the run
                      prints the per-rank peak table at exit
  --recover MODE      none | reshard (train; default none) — what to do
                      when a rank dies mid-step.  none surfaces the
                      contextful failure (dead rank named, peers unwound,
                      no hang).  reshard snapshots training state through
                      an in-memory checkpoint, re-carves the largest
                      valid topology from the survivors (same
                      divisibility caps as startup), re-runs the static
                      preflight on the new schedule, and resumes — see
                      README \"Elastic recovery\".  Needs a threaded run:
                      --threads N or --mesh DxPxM
  --inject-rank R     (train, threaded runs) kill rank R's thread at the
                      start of step --inject-step to exercise the failure
                      path: --recover none reports the dead rank and
                      exits; --recover reshard re-carves and runs to
                      completion
  --inject-step N     the 0-based step --inject-rank dies at (default 0)
  --top-k N           (trace) kernel table size (default 10)
  --out FILE          (trace) write the metrics report JSON
  --seed N            corpus seed (train/verify; default 7)
  --experiment ID     fig3a|fig3b|fig4a|fig4b|fig5a|fig5b|fig7|fig8|fig9|
                      table4|tables (sweep)
  --model NAME        sweep simulates bert-base | bert-large
                      (default bert-base; distinct from the native
                      backend's run-shape --model above)
";

pub fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn native_config(args: &Args) -> Result<NativeConfig> {
    // --threads N runs the ranks on N OS threads; the rank count must
    // equal the ring size the manifest is built for, so the flag also
    // sets the ring (and conflicts with a disagreeing --ring).
    let threads = args.usize_or("threads", 0)?;
    let ring = if threads > 0 {
        if args.has("ring") && args.usize_or("ring", threads)? != threads {
            bail!(
                "--threads {threads} conflicts with --ring {} (rank count must equal ring size)",
                args.usize_or("ring", 0)?
            );
        }
        threads
    } else {
        args.usize_or("ring", 4)?
    };
    let tp = args.usize_or("tp", 2)?;
    // --attn decides which sparse kernels the backend registers; the
    // standalone --linformer K flag (predates --attn) is still honoured
    // when no pattern asks for a different K.  NOTE: linformer_k > 0 now
    // also adds the E_k/E_v projection parameters to the manifest (the
    // executable path trains them); under a dense pattern they sit idle
    // with zero gradients — harmless, but they do ride the gradient
    // all-reduce, so don't set --linformer on a dense run you are
    // metering.
    let pattern = attn_pattern(args)?;
    let (mut linformer_k, block_w) = pattern.native_knobs();
    if linformer_k == 0 {
        linformer_k = args.usize_or("linformer", 0)?;
    }
    let mut cfg = NativeConfig {
        model: crate::model::by_name(args.str_or("model", "bert-tiny"))?,
        batch: args.usize_or("batch", 2)?,
        seq_len: args.usize_or("seq-len", 32)?,
        ring,
        tp,
        linformer_k,
        block_w,
        // --sp ulysses lowers the head-shard attention kernels on top of
        // the ring set (the backend enforces ring | head count)
        ulysses: !sp_strategy(args)?.is_ring(),
        seed: args.usize_or("init-seed", 0)? as u64,
    };
    // --mesh DPxPPxMP fixes the model-parallel axis through the one
    // shared lowering rule (`NativeConfig::for_mesh`): ring=MP under
    // --engine seq, tp=MP (ring unused, lowered at 1) under tensor.
    // Explicit --ring/--tp that disagree with the mesh are refused.
    if let Some((dp, pp, mp)) = args.triple_opt("mesh")? {
        let kind = match args.str_or("engine", "seq") {
            "seq" => Some(MpKind::Sequence),
            "tensor" => Some(MpKind::Tensor),
            _ => None, // train() reports the engine/mesh mismatch
        };
        if let Some(kind) = kind {
            let lowered = cfg.for_mesh(&Mesh::new(dp, pp, mp, kind)?);
            if args.has("ring") && cfg.ring != lowered.ring {
                bail!(
                    "--ring {} conflicts with --mesh {dp}x{pp}x{mp} (the mesh lowers ring={})",
                    cfg.ring,
                    lowered.ring
                );
            }
            if args.has("tp") && cfg.tp != lowered.tp {
                bail!(
                    "--tp {} conflicts with --mesh {dp}x{pp}x{mp} (the mesh lowers tp={})",
                    cfg.tp,
                    lowered.tp
                );
            }
            cfg = lowered;
        }
    }
    Ok(cfg)
}

/// The `--attn` pattern (train/bench surface; default dense).
pub fn attn_pattern(args: &Args) -> Result<AttnPattern> {
    AttnPattern::parse(args.str_or("attn", "dense"))
}

/// The `--sp` sequence-parallel strategy (train surface; default ring).
pub fn sp_strategy(args: &Args) -> Result<SpStrategy> {
    SpStrategy::parse(args.str_or("sp", "ring"))
}

/// Pick a backend per `--backend`; returns the artifact dir when the XLA
/// path was chosen (params/goldens are loaded from it).
pub fn open_runtime(args: &Args) -> Result<(Runtime, Option<PathBuf>)> {
    let dir = artifacts_dir(args);
    let use_xla = match args.str_or("backend", "auto") {
        "xla" => true,
        "native" => false,
        "auto" => dir.join("manifest.json").exists() && cfg!(feature = "backend-xla"),
        other => bail!("unknown --backend {other:?} (native|xla|auto)"),
    };
    if use_xla {
        Ok((Runtime::open(&dir)?, Some(dir)))
    } else {
        Ok((Runtime::native(native_config(args)?)?, None))
    }
}

/// Parameters for a runtime: exported `.tensor` files when artifact-backed,
/// seeded synthetic init otherwise.
pub fn load_params(rt: &Runtime, dir: &Option<PathBuf>) -> Result<ParamStore> {
    match dir {
        Some(d) => ParamStore::load(d, rt.manifest()),
        None => Ok(ParamStore::synthetic(rt.manifest())),
    }
}

pub fn info(args: &Args) -> Result<()> {
    let (rt, dir) = open_runtime(args)?;
    let m = rt.manifest();
    match &dir {
        Some(d) => println!("backend {}  manifest {}", rt.backend_name(), d.join("manifest.json").display()),
        None => println!("backend {}  manifest synthesized in-memory", rt.backend_name()),
    }
    println!(
        "model {}  layers={} H={} Z={} A={} FFN={} V={}",
        m.model, m.layers, m.hidden, m.heads, m.head_dim, m.ffn, m.vocab
    );
    println!(
        "run shapes: batch={} seq_len={} ring={} tp={} linformer_k={} block_w={}",
        m.batch, m.seq_len, m.ring, m.tp, m.linformer_k, m.block_w
    );
    println!("artifacts: {}", m.artifacts.len());
    println!("params: {} tensors", m.params.len());
    println!("goldens: {} tensors", m.goldens.len());
    Ok(())
}

/// Load the golden batch exported by aot.py (artifact-backed runs only).
pub fn golden_batch(rt: &Runtime, dir: &Path) -> Result<Batch> {
    let g = |name: &str| -> Result<_> {
        let rel = rt
            .manifest()
            .goldens
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("golden {name:?} missing"))?;
        io::load(&dir.join(rel))
    };
    Ok(Batch {
        ids: g("ids")?,
        labels: g("labels")?,
        mask: g("mask")?,
        sop_labels: g("sop_labels")?,
    })
}

/// The cross-engine half of `verify`: RSA == serial == tensor-parallel on
/// losses, every gradient, and the reassembled hidden states.  Runs on
/// either backend — this is the paper's Fig. 6 / Appendix B claim.
/// `a` is the seq-par step output (computed once by the caller, shared
/// with the golden comparison) and `meter` its ring fabric's meter.
fn verify_cross_engine(
    rt: &Runtime,
    params: &ParamStore,
    batch: &Batch,
    a: &crate::parallel::StepOutput,
    meter: &std::sync::Arc<crate::comm::Meter>,
) -> Result<()> {
    let m = rt.manifest().clone();
    let tol = 2e-3f32;

    let serial = TensorParEngine::new(rt, Fabric::new(1, Meter::new()))?;
    let b = serial.forward_backward(params, batch)?;
    println!(
        "seq-par  loss {:.6}   serial loss {:.6}   Δ {:.2e}",
        a.loss,
        b.loss,
        (a.loss - b.loss).abs()
    );
    if (a.loss - b.loss).abs() > tol {
        bail!("seq-par/serial disagree: {} vs {}", a.loss, b.loss);
    }
    let mut worst = (String::new(), 0.0f32);
    for (name, g) in &b.grads.values {
        let d = ops::max_abs_diff(&a.grads.values[name], g)?;
        if d > worst.1 {
            worst = (name.clone(), d);
        }
    }
    println!("seq-par vs serial: worst grad Δ = {:.2e} ({})", worst.1, worst.0);
    if worst.1 > tol {
        bail!("grad {} diverged: Δ={}", worst.0, worst.1);
    }

    // hidden states: seq chunks reassemble to the serial tensor
    let lc = m.seq_len / m.ring;
    let chunks3d: Vec<_> = a
        .hidden
        .iter()
        .map(|h| h.clone().reshaped(&[m.batch, lc, m.hidden]))
        .collect::<Result<_>>()?;
    let refs: Vec<_> = chunks3d.iter().collect();
    let full = ops::concat_dim(&refs, 1)?
        .reshaped(&[m.batch * m.seq_len, m.hidden])?;
    let dh = ops::max_abs_diff(&full, &b.hidden[0])?;
    println!("hidden chunks reassemble: max|Δ| = {dh:.2e}");
    if dh > tol {
        bail!("hidden mismatch {dh}");
    }

    if m.tp > 1 {
        let tpe = TensorParEngine::new(rt, Fabric::new(m.tp, Meter::new()))?;
        let c = tpe.forward_backward(params, batch)?;
        println!(
            "tensor{}  loss {:.6}   Δ vs serial {:.2e}",
            m.tp,
            c.loss,
            (c.loss - b.loss).abs()
        );
        if (c.loss - b.loss).abs() > tol {
            bail!("tensor-par/serial disagree: {} vs {}", c.loss, b.loss);
        }
        for (name, g) in &b.grads.values {
            let d = ops::max_abs_diff(&c.grads.values[name], g)?;
            if d > tol {
                bail!("tensor-par grad {name} diverged: Δ={d}");
            }
        }
    }

    println!(
        "seq-par comm: ring_p2p={}B all_reduce={}B ({} ops)",
        meter.get(crate::comm::CommKind::RingP2p),
        meter.get(crate::comm::CommKind::AllReduce),
        meter.snapshot().ops,
    );
    Ok(())
}

/// Golden comparison against the python-exported chain outputs (only
/// available when an artifact directory supplied the goldens).  Reuses
/// the seq-par step output the caller already computed.
fn verify_goldens(rt: &Runtime, dir: &Path, out: &crate::parallel::StepOutput) -> Result<()> {
    let m = rt.manifest().clone();
    let tol = 2e-3f32;
    let n = m.ring;
    let want_loss = io::load(&dir.join(&m.goldens["loss"]))?;
    let wl = want_loss.f32s()?;
    println!(
        "goldens: loss {:.6} (want {:.6})  mlm {:.6}/{:.6}  sop {:.6}/{:.6}",
        out.loss, wl[0], out.mlm, wl[1], out.sop, wl[2]
    );
    if (out.loss - wl[0]).abs() > tol {
        bail!("loss mismatch: {} vs golden {}", out.loss, wl[0]);
    }
    let mut worst = 0.0f32;
    for d in 0..n {
        let want = io::load(&dir.join(&m.goldens[&format!("hidden_dev{d}")]))?;
        worst = worst.max(ops::max_abs_diff(&out.hidden[d], &want)?);
    }
    println!("goldens: hidden max|Δ| = {worst:.2e} over {n} devices");
    if worst > tol {
        bail!("hidden mismatch {worst}");
    }
    for gname in ["layer0.wq", "mlm_b", "tok_emb"] {
        let file = &m.goldens[&format!("grad_{}", gname.replace('.', "_"))];
        let want = io::load(&dir.join(file))?;
        let diff = ops::max_abs_diff(&out.grads.values[gname], &want)?;
        println!("goldens: grad[{gname}] max|Δ| = {diff:.2e}");
        if diff > tol {
            bail!("grad {gname} mismatch {diff}");
        }
    }
    Ok(())
}

pub fn verify(args: &Args) -> Result<()> {
    let (rt, dir) = open_runtime(args)?;
    let params = load_params(&rt, &dir)?;
    println!("backend: {}", rt.backend_name());

    // batch: the exported golden batch when available, synthetic otherwise
    let batch = match &dir {
        Some(d) if !rt.manifest().goldens.is_empty() => golden_batch(&rt, d)?,
        _ => {
            let m = rt.manifest();
            let seed = args.usize_or("seed", 7)? as u64;
            Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed).next_batch()?
        }
    };

    // one seq-par step, shared by the golden check and the cross-engine
    // comparison (it is the expensive half of verify)
    let meter = Meter::new();
    let seq = SeqParEngine::new(&rt, Fabric::new(rt.manifest().ring, meter.clone()))?;
    let seq_out = seq.forward_backward(&params, &batch)?;

    if let Some(d) = &dir {
        if !rt.manifest().goldens.is_empty() {
            verify_goldens(&rt, d, &seq_out)?;
        }
    }
    verify_cross_engine(&rt, &params, &batch, &seq_out, &meter)?;

    let stats = rt.stats();
    println!(
        "runtime: {} executables, {} calls, compile {:.2}s, exec {:.2}s",
        rt.cached_executables(),
        stats.calls,
        stats.compile_nanos as f64 / 1e9,
        stats.exec_nanos as f64 / 1e9,
    );
    println!("VERIFY OK");
    Ok(())
}

pub fn train(args: &Args) -> Result<()> {
    // flag/engine compatibility first, so a bad combination is reported
    // as such instead of as a backend-lowering error (e.g. the ulysses
    // head-count cap firing for a --sp that a tensor engine ignores)
    let engine_name = args.str_or("engine", "seq").to_string();
    let threads = args.usize_or("threads", 0)?;
    if threads > 0 && engine_name != "seq" {
        bail!("--threads applies to --engine seq (got --engine {engine_name})");
    }
    let pattern = attn_pattern(args)?;
    if !pattern.is_dense() && engine_name != "seq" {
        bail!(
            "--attn {} applies to --engine seq (got --engine {engine_name})",
            pattern.label()
        );
    }
    let sp = sp_strategy(args)?;
    if !sp.is_ring() && engine_name != "seq" {
        bail!("--sp {} applies to --engine seq (got --engine {engine_name})", sp.label());
    }
    let overlap = args.has("overlap");
    if overlap && engine_name != "seq" {
        bail!("--overlap applies to --engine seq (got --engine {engine_name})");
    }

    // ---- elastic recovery (--recover) --------------------------------
    // reshard routes the whole run through exec::recovery::Elastic (it
    // rebuilds runtimes per re-carve, so it owns the loop); none keeps
    // the normal paths, optionally with a fault injected to demo the
    // contextful failure report.
    if RecoverPolicy::parse(args.str_or("recover", "none"))? == RecoverPolicy::Reshard {
        return train_elastic(args);
    }
    let inject_rank = args.usize_opt("inject-rank")?;
    let inject_step = args.usize_or("inject-step", 0)? as u64;
    if inject_rank.is_some()
        && !(threads > 0 || (args.triple_opt("mesh")?.is_some() && !args.has("mesh-sim")))
    {
        bail!(
            "--inject-rank needs a threaded failure domain: --threads N or \
             --mesh DxPxM without --mesh-sim (rank death is a thread dying)"
        );
    }

    let (rt, dir) = open_runtime(args)?;
    let mut params = load_params(&rt, &dir)?;
    let steps = args.usize_or("steps", 50)? as u64;
    let seed = args.usize_or("seed", 7)? as u64;
    let m = rt.manifest().clone();
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let cfg = TrainConfig {
        steps,
        warmup: (steps / 10).max(1),
        peak_lr: args.f64_or("lr", 1e-3)? as f32,
        log_every: args.usize_or("log-every", 10)? as u64,
    };
    let meter = Meter::new();
    // --trace: record every span of the run; finish_trace() checks the
    // event-for-op invariant against `meter` and writes the Chrome JSON.
    // The recorder must start AFTER the static pre-flight: the analyzer
    // replays the real (instrumented) step programs against its own
    // symbolic meter, and those replayed spans must not leak into the
    // runtime trace or the cross-check against `meter` would fail.
    let trace_path = args.str_opt("trace").map(PathBuf::from);
    let start_recorder = || trace_path.as_ref().map(|_| crate::obs::Recorder::start());
    // --trace also opens a memory-accounting session: every tensor-
    // lifetime charge in the step lands in per-rank live/peak accounts,
    // exported into the same Chrome trace as a ph:"C" "memory" counter
    // track (one pid per rank) and printed as the per-rank peak table.
    let start_mem = || trace_path.as_ref().map(|_| crate::obs::mem::MemSession::start());

    // ---- 4D mesh execution (DP×PP×SP / DP×PP×TP) --------------------
    if let Some((dp, pp, mp)) = args.triple_opt("mesh")? {
        if threads > 0 {
            bail!("--mesh is threaded already (one OS thread per coordinate); use --mesh-sim for the sequential simulation");
        }
        if !pattern.is_dense() {
            bail!("--mesh supports --attn dense only (got --attn {})", pattern.label());
        }
        let kind = match engine_name.as_str() {
            "seq" => MpKind::Sequence,
            "tensor" => MpKind::Tensor,
            other => bail!("--mesh needs --engine seq or tensor (got --engine {other})"),
        };
        let mesh = Mesh::new(dp, pp, mp, kind)?;
        let micros = args.usize_or("micros", 1)?;
        // static pre-flight: a bad combination gets the analyzer's report
        // (schedule + shapes + closed forms) instead of a runtime error
        println!("{}", analysis::preflight(analysis::analyze_mesh(&rt, mesh, micros, sp))?);
        let runner: Box<dyn MeshStep + '_> = if args.has("mesh-sim") {
            Box::new(MeshEngine::with_strategy(&rt, mesh, micros, meter.clone(), sp)?.overlap(overlap))
        } else {
            let mut r =
                MeshRunner::with_strategy(&rt, mesh, micros, meter.clone(), sp)?.overlap(overlap);
            if let Some(rank) = inject_rank {
                println!(
                    "fault injection: mesh rank {rank} dies at step {inject_step} \
                     (--recover none: the failure is reported, not recovered)"
                );
                r.inject_fault_at(rank, inject_step);
            }
            Box::new(r)
        };
        if overlap {
            println!("comm/compute overlap: double-buffered ring shifts");
        }
        println!(
            "mesh execution: {} ({} coordinates{}), micros={}, pipeline bubble {:.3}",
            mesh.label(),
            mesh.world_size(),
            if args.has("mesh-sim") { ", sequential simulation" } else { ", one OS thread each" },
            micros,
            Schedule::gpipe(pp, micros).bubble_fraction(),
        );
        let mut trainer = MeshTrainer::new(runner.as_ref(), &params, cfg);
        let rec = start_recorder();
        let mem_ses = start_mem();
        trainer.run(&mut params, || corpus.next_batch(), false)?;
        let s = meter.snapshot();
        println!(
            "comm totals: ring_p2p={} all_reduce={} all_gather={} all_to_all={} broadcast={} scatter={} pipeline={} ({} ops)",
            s.ring_p2p, s.all_reduce, s.all_gather, s.all_to_all, s.broadcast, s.scatter, s.pipeline, s.ops
        );
        return finish_trace(rec, mem_ses, trace_path.as_deref(), &meter);
    }

    // static pre-flight for the single-axis engines (same verifier the
    // `analyze` subcommand runs; serial has no collectives to check)
    match engine_name.as_str() {
        "seq" => {
            println!("{}", analysis::preflight(analysis::analyze_sp_step(&rt, pattern, sp))?);
        }
        "tensor" => {
            println!("{}", analysis::preflight(analysis::analyze_tp_step(&rt, m.tp))?);
        }
        _ => {}
    }

    let rec = start_recorder();
    let mem_ses = start_mem();
    match engine_name.as_str() {
        "seq" if threads > 0 => {
            let mut e = DistRunner::with_strategy(&rt, meter.clone(), pattern, sp)?.overlap(overlap);
            if let Some(rank) = inject_rank {
                println!(
                    "fault injection: rank {rank} dies at step {inject_step} \
                     (--recover none: the failure is reported, not recovered)"
                );
                e.inject_fault_at(rank, inject_step);
            }
            println!(
                "threaded execution: {} ranks, one OS thread each, attn {}, sp {}{}",
                e.n,
                pattern.label(),
                sp.label(),
                if overlap { ", double-buffered ring" } else { "" }
            );
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        "seq" => {
            if !pattern.is_dense() {
                println!("attention pattern: {}", pattern.label());
            }
            if !sp.is_ring() {
                println!("sequence-parallel strategy: {}", sp.label());
            }
            if overlap {
                println!("comm/compute overlap: double-buffered ring shifts");
            }
            let e = SeqParEngine::with_strategy(
                &rt,
                Fabric::new(m.ring, meter.clone()),
                pattern,
                sp,
            )?
            .overlap(overlap);
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        "tensor" => {
            let e = TensorParEngine::new(&rt, Fabric::new(m.tp, meter.clone()))?;
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        "serial" => {
            let e = TensorParEngine::new(&rt, Fabric::new(1, meter.clone()))?;
            let mut trainer = Trainer::new(&e, &params, cfg);
            trainer.run(&mut params, || corpus.next_batch(), false)?;
        }
        other => bail!("unknown --engine {other:?} (seq|tensor|serial)"),
    }
    let s = meter.snapshot();
    println!(
        "comm totals: ring_p2p={} all_reduce={} all_gather={} all_to_all={} broadcast={} scatter={} pipeline={} ({} ops)",
        s.ring_p2p, s.all_reduce, s.all_gather, s.all_to_all, s.broadcast, s.scatter, s.pipeline, s.ops
    );
    finish_trace(rec, mem_ses, trace_path.as_deref(), &meter)
}

/// `train --recover reshard`: route the run through the elastic driver
/// ([`crate::exec::recovery`]).  The driver owns runtime construction —
/// it re-lowers a fresh runtime for every re-carved topology — so this
/// path builds an [`ElasticConfig`] from the native run-shape flags
/// instead of calling [`open_runtime`].  The driver also re-runs the
/// same static-analysis preflight `train` startup uses before every
/// (re)incarnation of the step loop.
fn train_elastic(args: &Args) -> Result<()> {
    let engine_name = args.str_or("engine", "seq");
    let threads = args.usize_or("threads", 0)?;
    let pattern = attn_pattern(args)?;
    let sp = sp_strategy(args)?;
    let overlap = args.has("overlap");
    if args.str_or("backend", "auto") == "xla" {
        bail!("--recover reshard re-lowers a runtime per re-carve; it needs --backend native");
    }
    if args.has("mesh-sim") {
        bail!(
            "--recover reshard drives the threaded runners (rank death is a \
             thread dying); drop --mesh-sim"
        );
    }
    let topo = if let Some((dp, pp, mp)) = args.triple_opt("mesh")? {
        if threads > 0 {
            bail!("--mesh is threaded already (one OS thread per coordinate); drop --threads");
        }
        let kind = match engine_name {
            "seq" => MpKind::Sequence,
            "tensor" => MpKind::Tensor,
            other => bail!("--mesh needs --engine seq or tensor (got --engine {other})"),
        };
        Topo::Mesh { mesh: Mesh::new(dp, pp, mp, kind)?, micros: args.usize_or("micros", 1)? }
    } else if threads > 0 && engine_name == "seq" {
        Topo::Flat { n: threads }
    } else {
        bail!(
            "--recover reshard needs a threaded failure domain: --engine seq \
             --threads N, or --mesh DxPxM (rank death only surfaces on the \
             threaded runners)"
        );
    };
    if args.str_opt("trace").is_some() {
        bail!(
            "--trace is not supported with --recover reshard: the comm meter \
             restarts at each recovery, so a whole-run trace cannot cross-check \
             against it (trace a clean resume from the recovery point instead)"
        );
    }
    let nc = native_config(args)?;
    let steps = args.usize_or("steps", 50)? as u64;
    let cfg = ElasticConfig {
        model: nc.model,
        batch: nc.batch,
        seq_len: nc.seq_len,
        pattern,
        sp,
        overlap,
        policy: RecoverPolicy::Reshard,
        data_seed: args.usize_or("seed", 7)? as u64,
        init_seed: nc.seed,
        train: TrainConfig {
            steps,
            warmup: (steps / 10).max(1),
            peak_lr: args.f64_or("lr", 1e-3)? as f32,
            log_every: args.usize_or("log-every", 10)? as u64,
        },
        topo,
        quiet: false,
    };
    println!(
        "elastic training: {} with --recover reshard (survivor re-carve on rank death)",
        topo.label()
    );
    let mut run = Elastic::new(cfg);
    if let Some(rank) = args.usize_opt("inject-rank")? {
        let at = args.usize_or("inject-step", 0)? as u64;
        println!("fault injection: rank {rank} dies at step {at}");
        run = run.fault_at(at, rank);
    }
    let out = run.run()?;
    for ev in &out.recoveries {
        println!("recovery: {ev}");
    }
    println!(
        "elastic run complete: {} step(s), {} recover{}, final topology {}",
        steps,
        out.recoveries.len(),
        if out.recoveries.len() == 1 { "y" } else { "ies" },
        out.final_topo.label()
    );
    let s = &out.post_meter;
    println!(
        "comm totals since last re-carve: ring_p2p={} all_reduce={} all_gather={} all_to_all={} broadcast={} scatter={} pipeline={} ({} ops)",
        s.ring_p2p, s.all_reduce, s.all_gather, s.all_to_all, s.broadcast, s.scatter, s.pipeline, s.ops
    );
    Ok(())
}

pub fn sweep(args: &Args) -> Result<()> {
    crate::eval::sweep::run(args)
}

// ------------------------------------------------------------------------
// trace — runtime observability: measured metrics + Chrome-trace export
// ------------------------------------------------------------------------

/// Shared `--trace` epilogue for a recorded run: stop the recorder,
/// enforce the event-for-op invariant against the run's live meter
/// (`crate::obs::cross_check`), and write the Chrome trace — with the
/// ph:"C" memory counter track when a `MemSession` rode along.
fn finish_trace(
    rec: Option<crate::obs::Recorder>,
    mem_ses: Option<crate::obs::mem::MemSession>,
    path: Option<&Path>,
    meter: &Meter,
) -> Result<()> {
    let (Some(rec), Some(path)) = (rec, path) else {
        return Ok(());
    };
    let events = rec.finish();
    let mem = mem_ses.map(|s| s.finish());
    let rows = crate::obs::cross_check(&events, meter)?;
    crate::obs::write_chrome_trace_with_counters(path, &events, mem.as_ref())?;
    let ranks = events.iter().map(|e| e.rank).max().map_or(0, |r| r + 1);
    println!(
        "trace: {} events over {} rank(s) -> {} (meter cross-check OK over {} comm kinds)",
        events.len(),
        ranks,
        path.display(),
        rows.iter().filter(|r| r.trace_events > 0).count(),
    );
    if let Some(report) = &mem {
        println!(
            "memory: {} counter sample(s), max per-rank peak {} B, churn {} tensors / {} B",
            report.samples.len(),
            report.max_peak_total(),
            report.churn_tensors,
            report.churn_bytes,
        );
        print!("{report}");
    }
    Ok(())
}

/// `trace` — run a short traced training (default one step) and print
/// the measured `crate::obs::MetricsReport`: step wall time, per-kind
/// comm wait/transfer attribution, top-k kernels, tokens/sec and the
/// measured pipeline bubble (mesh runs).  `--out` serializes the report
/// (the BENCH_obs.json payload), `--trace FILE` additionally dumps the
/// Chrome trace, `--validate FILE` schema-checks an existing trace
/// instead of running anything.
pub fn trace(args: &Args) -> Result<()> {
    if let Some(file) = args.str_opt("validate") {
        return validate_trace_file(Path::new(file));
    }
    let engine_name = args.str_or("engine", "seq").to_string();
    let threads = args.usize_or("threads", 0)?;
    let pattern = attn_pattern(args)?;
    let sp = sp_strategy(args)?;
    let overlap = args.has("overlap");
    if overlap && engine_name != "seq" {
        bail!("--overlap applies to --engine seq (got --engine {engine_name})");
    }
    let (rt, dir) = open_runtime(args)?;
    let mut params = load_params(&rt, &dir)?;
    let steps = args.usize_or("steps", 1)? as u64;
    let seed = args.usize_or("seed", 7)? as u64;
    let m = rt.manifest().clone();
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let cfg = TrainConfig {
        steps,
        warmup: (steps / 10).max(1),
        peak_lr: args.f64_or("lr", 1e-3)? as f32,
        log_every: u64::MAX,
    };
    let meter = Meter::new();
    let rec = crate::obs::Recorder::start();
    // the memory accountant rides along unconditionally here: `trace`
    // IS the observability report, and the per-rank peak table is part
    // of it (the train surface gates the session on --trace instead)
    let mem_ses = crate::obs::mem::MemSession::start();
    let label;
    let tokens_per_step;
    if let Some((dp, pp, mp)) = args.triple_opt("mesh")? {
        let kind = match engine_name.as_str() {
            "seq" => MpKind::Sequence,
            "tensor" => MpKind::Tensor,
            other => bail!("--mesh needs --engine seq or tensor (got --engine {other})"),
        };
        let mesh = Mesh::new(dp, pp, mp, kind)?;
        let micros = args.usize_or("micros", 1)?;
        let runner: Box<dyn MeshStep + '_> = if args.has("mesh-sim") {
            Box::new(MeshEngine::with_strategy(&rt, mesh, micros, meter.clone(), sp)?.overlap(overlap))
        } else {
            Box::new(MeshRunner::with_strategy(&rt, mesh, micros, meter.clone(), sp)?.overlap(overlap))
        };
        let mut t = MeshTrainer::new(runner.as_ref(), &params, cfg);
        t.run(&mut params, || corpus.next_batch(), true)?;
        label = format!(
            "mesh-{} micros={micros} sp={}{}",
            mesh.label(),
            sp.label(),
            if overlap { " overlap" } else { "" }
        );
        tokens_per_step = (mesh.dp * micros * m.batch * m.seq_len) as u64;
    } else {
        tokens_per_step = (m.batch * m.seq_len) as u64;
        match engine_name.as_str() {
            "seq" if threads > 0 => {
                let e = DistRunner::with_strategy(&rt, meter.clone(), pattern, sp)?.overlap(overlap);
                let mut t = Trainer::new(&e, &params, cfg);
                t.run(&mut params, || corpus.next_batch(), true)?;
                label = format!(
                    "seq threaded n={} attn={} sp={}{}",
                    e.n,
                    pattern.label(),
                    sp.label(),
                    if overlap { " overlap" } else { "" }
                );
            }
            "seq" => {
                let e = SeqParEngine::with_strategy(
                    &rt,
                    Fabric::new(m.ring, meter.clone()),
                    pattern,
                    sp,
                )?
                .overlap(overlap);
                let mut t = Trainer::new(&e, &params, cfg);
                t.run(&mut params, || corpus.next_batch(), true)?;
                label = format!(
                    "seq sequential n={} attn={} sp={}{}",
                    m.ring,
                    pattern.label(),
                    sp.label(),
                    if overlap { " overlap" } else { "" }
                );
            }
            "tensor" => {
                let e = TensorParEngine::new(&rt, Fabric::new(m.tp, meter.clone()))?;
                let mut t = Trainer::new(&e, &params, cfg);
                t.run(&mut params, || corpus.next_batch(), true)?;
                label = format!("tensor tp={}", m.tp);
            }
            "serial" => {
                let e = TensorParEngine::new(&rt, Fabric::new(1, meter.clone()))?;
                let mut t = Trainer::new(&e, &params, cfg);
                t.run(&mut params, || corpus.next_batch(), true)?;
                label = "serial".to_string();
            }
            other => bail!("unknown --engine {other:?} (seq|tensor|serial)"),
        }
    }
    let events = rec.finish();
    let mem_report = mem_ses.finish();
    let rows = crate::obs::cross_check(&events, &meter)?;
    let top_k = args.usize_or("top-k", 10)?;
    let report =
        crate::obs::MetricsReport::build(&events, steps as usize, tokens_per_step * steps, top_k);
    println!("traced run: {label}");
    print!("{report}");
    println!(
        "trace/meter cross-check OK: {} comm kinds, {} comm events",
        rows.iter().filter(|r| r.trace_events > 0).count(),
        rows.iter().map(|r| r.trace_events).sum::<u64>(),
    );
    println!("memory peaks by rank (measured, bytes):");
    print!("{mem_report}");
    println!(
        "memory: max per-rank peak {} B, churn {} tensors / {} B",
        mem_report.max_peak_total(),
        mem_report.churn_tensors,
        mem_report.churn_bytes,
    );
    // the backend's own per-kernel accounting — same clock as the spans
    let mut ks = rt.kernel_stats();
    if !ks.is_empty() {
        ks.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.name.cmp(&b.name)));
        println!(
            "top-{} kernels by total time (backend {}):",
            top_k.min(ks.len()),
            rt.backend_name()
        );
        for k in ks.iter().take(top_k) {
            println!(
                "  {:<26} {:>8} calls  {:>12}",
                k.name,
                k.calls,
                crate::eval::bench::fmt_ns(k.total_ns as f64)
            );
        }
    }
    if let Some(p) = args.str_opt("trace") {
        crate::obs::write_chrome_trace_with_counters(Path::new(p), &events, Some(&mem_report))?;
        println!(
            "trace: wrote {} events + {} memory counter record(s) to {p}",
            events.len(),
            mem_report.samples.len()
        );
    }
    if let Some(out) = args.str_opt("out") {
        let mut doc = report.to_json();
        if let crate::util::json::Value::Obj(map) = &mut doc {
            map.insert("run".to_string(), crate::util::json::Value::Str(label.clone()));
            map.insert("mem".to_string(), mem_report.to_json());
        }
        std::fs::write(out, crate::util::json::encode(&doc))?;
        println!("metrics: wrote {out}");
    }
    Ok(())
}

/// `trace --validate FILE`: parse + schema-check an existing JSON file
/// and summarize it.  Dispatches on shape: a root `mem_rows` key means
/// a `BENCH_mem.json` memory profile (checked by
/// `obs::mem::validate_bench_mem`); anything else is a Chrome trace.
fn validate_trace_file(path: &Path) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let doc = crate::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    if doc.get("mem_rows").is_some() {
        let summary = crate::obs::mem::validate_bench_mem(&doc)?;
        println!("{}: {summary}", path.display());
        println!("MEM VALIDATE OK");
        return Ok(());
    }
    let chk = crate::obs::validate_chrome_trace(&doc)?;
    println!(
        "{}: {} records ({} complete events, {} metadata, {} memory counters) across {} rank(s)",
        path.display(),
        chk.events,
        chk.complete,
        chk.meta,
        chk.counters,
        chk.pids.len()
    );
    for (cat, count) in &chk.cats {
        println!("  {cat:<10} {count}");
    }
    println!("TRACE VALIDATE OK");
    Ok(())
}

// ------------------------------------------------------------------------
// analyze — the static collective-schedule verifier (crate::analysis)
// ------------------------------------------------------------------------

/// Which step program a flag set selects — shared by the single-config
/// report, the measured cross-check leg, and the train pre-flight.
enum AnalyzeMode {
    Sp(AttnPattern, SpStrategy),
    Tp(usize),
    Mesh(Mesh, usize, SpStrategy),
}

fn analyze_mode(args: &Args, rt: &Runtime) -> Result<AnalyzeMode> {
    let engine_name = args.str_or("engine", "seq");
    let pattern = attn_pattern(args)?;
    let sp = sp_strategy(args)?;
    if let Some((dp, pp, mp)) = args.triple_opt("mesh")? {
        let kind = match engine_name {
            "seq" => MpKind::Sequence,
            "tensor" => MpKind::Tensor,
            other => bail!("--mesh needs --engine seq or tensor (got --engine {other})"),
        };
        return Ok(AnalyzeMode::Mesh(
            Mesh::new(dp, pp, mp, kind)?,
            args.usize_or("micros", 1)?,
            sp,
        ));
    }
    Ok(match engine_name {
        "seq" => AnalyzeMode::Sp(pattern, sp),
        "tensor" => AnalyzeMode::Tp(rt.manifest().tp),
        "serial" => AnalyzeMode::Tp(1),
        other => bail!("unknown --engine {other:?} (seq|tensor|serial)"),
    })
}

fn build_analysis(rt: &Runtime, mode: &AnalyzeMode) -> Result<Analysis> {
    match mode {
        AnalyzeMode::Sp(pattern, sp) => analysis::analyze_sp_step(rt, *pattern, *sp),
        AnalyzeMode::Tp(t) => analysis::analyze_tp_step(rt, *t),
        AnalyzeMode::Mesh(mesh, micros, sp) => analysis::analyze_mesh(rt, *mesh, *micros, *sp),
    }
}

/// The measured leg of the three-way check: run the REAL engine for one
/// step on a fresh meter and return its per-kind byte totals.
fn measured_step(rt: &Runtime, mode: &AnalyzeMode, seed: u64) -> Result<MeterSnapshot> {
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let meter = Meter::new();
    match mode {
        AnalyzeMode::Sp(pattern, sp) => {
            let e =
                SeqParEngine::with_strategy(rt, Fabric::new(m.ring, meter.clone()), *pattern, *sp)?;
            e.forward_backward(&params, &corpus.next_batch()?)?;
        }
        AnalyzeMode::Tp(t) => {
            let e = TensorParEngine::new(rt, Fabric::new(*t, meter.clone()))?;
            e.forward_backward(&params, &corpus.next_batch()?)?;
        }
        AnalyzeMode::Mesh(mesh, micros, sp) => {
            let e = MeshEngine::with_strategy(rt, *mesh, *micros, meter.clone(), *sp)?;
            let mut batches: Vec<Vec<Batch>> = Vec::with_capacity(mesh.dp);
            for _ in 0..mesh.dp {
                let mut row = Vec::with_capacity(*micros);
                for _ in 0..*micros {
                    row.push(corpus.next_batch()?);
                }
                batches.push(row);
            }
            e.step(&params, &batches)?;
        }
    }
    Ok(meter.snapshot())
}

pub fn analyze(args: &Args) -> Result<()> {
    if args.has("grid") {
        return analyze_grid();
    }
    let (rt, _dir) = open_runtime(args)?;
    let mode = analyze_mode(args, &rt)?;
    let mut a = match build_analysis(&rt, &mode) {
        Ok(a) => a,
        Err(e) => {
            println!("REJECT (static): {e:#}");
            return Err(e);
        }
    };
    if let Some(r) = args.usize_opt("skew")? {
        // deliberately corrupt rank r's schedule so the divergence diff
        // can be inspected (the negative test is analysis_props.rs)
        let g = a
            .groups
            .first_mut()
            .ok_or_else(|| anyhow::anyhow!("no trace groups to skew"))?;
        let t = g.traces.get_mut(r).ok_or_else(|| {
            anyhow::anyhow!("--skew {r}: group {:?} has only {} ranks", g.name, g.traces.len())
        })?;
        t.events.push(TraceEvent::AllReduce { bytes: 4 });
        print!("{}", a.report(None));
        bail!("--skew {r}: injected divergent collective was statically detected (as intended)");
    }
    let measured = measured_step(&rt, &mode, args.usize_or("seed", 7)? as u64)?;
    print!("{}", a.report(Some(&measured)));
    a.verify()?;
    if !a.derived.same_bytes(&measured) {
        bail!("analyzer-derived bytes diverge from the measured runtime meter");
    }
    println!("ANALYZE OK");
    Ok(())
}

/// One grid row end to end: build, statically verify, cross-check the
/// derived bytes against a measured one-step meter.
fn grid_row_outcome(row: &GridRow) -> Result<()> {
    let rt = row.rt.as_ref().map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let a = build_analysis(rt, &row.mode)?;
    a.verify()?;
    let measured = measured_step(rt, &row.mode, 7)?;
    if !a.derived.same_bytes(&measured) {
        bail!(
            "derived bytes diverge from the measured meter\n{}",
            a.report(Some(&measured))
        );
    }
    Ok(())
}

/// One row of the `analyze --grid` sweep.
struct GridRow {
    name: String,
    /// The static analyzer is EXPECTED to reject this combination — the
    /// grid asserts it does (and fails if it passes instead).
    expect_reject: bool,
    rt: Result<Runtime>,
    mode: AnalyzeMode,
}

/// Sweep the equivalence-grid config matrix — the CI lint step.  Every
/// valid combination must pass all three static checks AND match a
/// measured one-step meter; every invalid combination must be rejected
/// statically (not by a runtime panic).
fn analyze_grid() -> Result<()> {
    // one run shape for the whole grid: bert-tiny-z4 (4 heads) keeps
    // every mp in {1,2,4} compatible with both SP strategies and TP
    let cfg = |ring: usize, tp: usize, pattern: AttnPattern, ulysses: bool| -> Result<Runtime> {
        let (linformer_k, block_w) = pattern.native_knobs();
        Runtime::native(NativeConfig {
            model: crate::model::by_name("bert-tiny-z4")?,
            batch: 2,
            seq_len: 32,
            ring,
            tp,
            linformer_k,
            block_w,
            ulysses,
            seed: 0,
        })
    };
    let strategies = [SpStrategy::Ring, SpStrategy::Ulysses];
    let patterns = [AttnPattern::Dense, AttnPattern::Linformer { k: 8 }, AttnPattern::Block { w: 8 }];
    let mut rows: Vec<GridRow> = Vec::new();

    // pure SP steps at ring 4 (what DistRunner / SeqParEngine execute)
    for sp in strategies {
        for pattern in patterns {
            rows.push(GridRow {
                name: format!("step ring=4 sp={} attn={}", sp.label(), analysis::pattern_label(pattern)),
                // ulysses re-shards whole heads and needs dense attention
                expect_reject: !sp.is_ring() && pattern != AttnPattern::Dense,
                rt: cfg(4, 1, pattern, !sp.is_ring()),
                mode: AnalyzeMode::Sp(pattern, sp),
            });
        }
    }
    // the Megatron TP baseline step
    rows.push(GridRow {
        name: "step tp=2".to_string(),
        expect_reject: false,
        rt: cfg(1, 2, AttnPattern::Dense, false),
        mode: AnalyzeMode::Tp(2),
    });
    // full mesh steps: every factorization of world=4 plus 2x2x2
    let meshes = [(1, 1, 4), (2, 1, 2), (1, 2, 2), (2, 2, 2)];
    for sp in strategies {
        for pattern in patterns {
            for (dp, pp, mp) in meshes {
                for kind in [MpKind::Sequence, MpKind::Tensor] {
                    let mesh = Mesh::new(dp, pp, mp, kind)?;
                    // same lowering rule the train path uses: ring=mp for a
                    // sequence model axis, tp=mp for a tensor one
                    let (linformer_k, block_w) = pattern.native_knobs();
                    let nc = NativeConfig {
                        model: crate::model::by_name("bert-tiny-z4")?,
                        batch: 2,
                        seq_len: 32,
                        ring: 4,
                        tp: 2,
                        linformer_k,
                        block_w,
                        ulysses: !sp.is_ring(),
                        seed: 0,
                    }
                    .for_mesh(&mesh);
                    let kl = if kind == MpKind::Sequence { "sp" } else { "tp" };
                    rows.push(GridRow {
                        name: format!(
                            "mesh {dp}x{pp}x{mp}-{kl} micros=2 sp={} attn={}",
                            sp.label(),
                            analysis::pattern_label(pattern)
                        ),
                        // linformer adds stage-ownerless projection params;
                        // a tensor model axis has no SP strategy to vary
                        expect_reject: linformer_k != 0
                            || (kind == MpKind::Tensor && !sp.is_ring()),
                        rt: Runtime::native(nc),
                        mode: AnalyzeMode::Mesh(mesh, 2, sp),
                    });
                }
            }
        }
    }

    let mut failures = 0usize;
    let (mut passed, mut rejected) = (0usize, 0usize);
    for row in rows {
        match (grid_row_outcome(&row), row.expect_reject) {
            (Ok(()), false) => {
                passed += 1;
                println!("PASS    {}", row.name);
            }
            (Err(e), true) => {
                rejected += 1;
                println!("REJECT  {} (static): {e:#}", row.name);
            }
            (Ok(()), true) => {
                failures += 1;
                println!("FAIL    {} — expected a static rejection, got a pass", row.name);
            }
            (Err(e), false) => {
                failures += 1;
                println!("FAIL    {} — {e:#}", row.name);
            }
        }
    }
    println!("grid: {passed} passed, {rejected} statically rejected (expected), {failures} failed");
    if failures > 0 {
        bail!("{failures} grid config(s) failed static analysis");
    }
    println!("ANALYZE GRID OK");
    Ok(())
}
