//! Figure/table data generators — one function per paper artifact.
//!
//! Each returns plain rows (testable without capturing stdout); the
//! `sweep` command formats them.  The paper's concrete claims are encoded
//! in rust/tests/paper_claims.rs against these generators.

use crate::model::ModelConfig;
use crate::simulator::{memory, search, sparse, timing, Cluster, RunShape, Strategy};

/// Candidate parallel sizes the paper sweeps (1..64 devices).
pub const SIZES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// TP sizes feasible for a model (divisors of the head count, the
/// Megatron cap the paper highlights: max 12 for Base, 16 for Large).
pub fn tp_sizes(cfg: &ModelConfig) -> Vec<usize> {
    (1..=cfg.heads)
        .filter(|n| cfg.heads % n == 0 && cfg.ffn() % n == 0)
        .collect()
}

#[derive(Clone, Copy, Debug)]
pub struct ScalingRow {
    pub n: usize,
    pub tp_max_batch: Option<usize>,
    pub sp_max_batch: usize,
    pub tp_tokens_per_sec: Option<f64>,
    pub sp_tokens_per_sec: f64,
}

/// Fig. 3 (BERT-Base) / Fig. 7 (BERT-Large): max batch + throughput while
/// scaling the tensor/sequence parallel size.  L = 512, no pipeline.
/// Throughput is measured at the per-strategy max batch (how the paper
/// saturates each configuration).
/// Sweep grid: the power-of-two sizes plus TP's feasible sizes (so the
/// paper's comparison points — TP@12 for Base, TP@16 for Large — appear).
fn grid(cfg: &ModelConfig) -> Vec<usize> {
    let mut v: Vec<usize> = SIZES.to_vec();
    v.extend(tp_sizes(cfg));
    v.sort_unstable();
    v.dedup();
    v
}

pub fn fig3(cluster: &Cluster, model: ModelConfig) -> Vec<ScalingRow> {
    let l = 512;
    let tps = tp_sizes(&model);
    grid(&model)
        .iter()
        .map(|&n| {
            let sp = Strategy::Sequence { n };
            let sp_max = search::max_batch(cluster, model, l, 1, 1, sp);
            let sp_tps = timing::tokens_per_sec(
                cluster,
                &RunShape::new(model, sp_max.max(1), l),
                sp,
            )
            .expect("sweep grid sizes are non-degenerate");
            let (tp_max, tp_tps) = if tps.contains(&n) {
                let tp = Strategy::Tensor { n };
                let mb = search::max_batch(cluster, model, l, 1, 1, tp);
                let t = timing::tokens_per_sec(
                    cluster,
                    &RunShape::new(model, mb.max(1), l),
                    tp,
                )
                .expect("sweep grid sizes are non-degenerate");
                (Some(mb), Some(t))
            } else {
                (None, None)
            };
            ScalingRow {
                n,
                tp_max_batch: tp_max,
                sp_max_batch: sp_max,
                tp_tokens_per_sec: tp_tps,
                sp_tokens_per_sec: sp_tps,
            }
        })
        .collect()
}

/// Fig. 4 (Base) / Fig. 8 (Large): MP size fixed at 4, scale pipeline.
pub fn fig4(cluster: &Cluster, model: ModelConfig) -> Vec<ScalingRow> {
    let l = 512;
    let micros = 8;
    [1usize, 2, 4, 8]
        .iter()
        .map(|&stages| {
            let sp = Strategy::Sequence { n: 4 };
            let tp = Strategy::Tensor { n: 4 };
            let sp_max = search::max_batch(cluster, model, l, stages, micros, sp);
            let tp_max = search::max_batch(cluster, model, l, stages, micros, tp);
            let sp_tps = timing::tokens_per_sec(
                cluster,
                &RunShape::new(model, sp_max.max(1), l).with_pipeline(stages, micros),
                sp,
            )
            .expect("fig4 stages/micros are non-degenerate");
            let tp_tps = timing::tokens_per_sec(
                cluster,
                &RunShape::new(model, tp_max.max(1), l).with_pipeline(stages, micros),
                tp,
            )
            .expect("fig4 stages/micros are non-degenerate");
            ScalingRow {
                n: stages,
                tp_max_batch: Some(tp_max),
                sp_max_batch: sp_max,
                tp_tokens_per_sec: Some(tp_tps),
                sp_tokens_per_sec: sp_tps,
            }
        })
        .collect()
}

#[derive(Clone, Copy, Debug)]
pub struct SeqLenRow {
    pub n: usize,
    pub tp_max_len: Option<usize>,
    pub sp_max_len: usize,
}

/// Fig. 5a (Base, batch 64) / Fig. 9 (Large, batch 16): max sequence
/// length while scaling devices.
pub fn fig5a(cluster: &Cluster, model: ModelConfig, batch: usize) -> Vec<SeqLenRow> {
    let tps = tp_sizes(&model);
    grid(&model)
        .iter()
        .map(|&n| {
            let sp_len =
                search::max_seq_len(cluster, model, batch, 1, 1, Strategy::Sequence { n }, 64);
            let tp_len = if tps.contains(&n) {
                Some(search::max_seq_len(
                    cluster, model, batch, 1, 1, Strategy::Tensor { n }, 64,
                ))
            } else {
                None
            };
            SeqLenRow { n, tp_max_len: tp_len, sp_max_len: sp_len }
        })
        .collect()
}

#[derive(Clone, Copy, Debug)]
pub struct SparseRow {
    pub n: usize,
    pub dense_max_len: usize,
    pub sparse_max_len: usize,
}

/// Fig. 5b: sequence length upper bound, dense vs Linformer sparse
/// attention under sequence parallelism (batch 4, K = 256).
pub fn fig5b(cluster: &Cluster, model: ModelConfig) -> Vec<SparseRow> {
    [1usize, 2, 4, 8, 16, 32]
        .iter()
        .map(|&n| SparseRow {
            n,
            dense_max_len: search::max_seq_len(
                cluster, model, 4, 1, 1, Strategy::Sequence { n }, 64,
            ),
            sparse_max_len: sparse::max_seq_len_linformer(cluster, model, 4, n, 256, 64),
        })
        .collect()
}

#[derive(Clone, Copy, Debug)]
pub struct WeakScalingRow {
    pub n: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub tp_mem_mb: Option<f64>,
    pub tp_tokens_per_sec: Option<f64>,
    pub sp_mem_mb: f64,
    pub sp_tokens_per_sec: f64,
}

/// Table 4: weak scaling.  Two sweeps: batch-dim (B = 64·n, L = 512) and
/// sequence-dim (B = 64, L = 256·n).  Pipeline size 8 as in the paper.
pub fn table4(cluster: &Cluster, model: ModelConfig) -> Vec<WeakScalingRow> {
    let tps = tp_sizes(&model);
    let mut rows = Vec::new();
    let mut push = |n: usize, batch: usize, seq_len: usize| {
        let shape = RunShape::new(model, batch, seq_len).with_pipeline(8, 8);
        let sp = Strategy::Sequence { n };
        let sp_bytes = memory::peak_bytes(&shape, sp);
        let sp_fit = sp_bytes <= cluster.gpu_mem;
        let (tp_mem, tp_tps) = if tps.contains(&n) {
            let tp = Strategy::Tensor { n };
            let bytes = memory::peak_bytes(&shape, tp);
            if bytes <= cluster.gpu_mem {
                (
                    Some(bytes as f64 / (1 << 20) as f64),
                    Some(
                        timing::tokens_per_sec(cluster, &shape, tp)
                            .expect("table4 shapes are non-degenerate"),
                    ),
                )
            } else {
                (None, None) // OOM — exactly what Table 4 reports at n=8
            }
        } else {
            (None, None)
        };
        rows.push(WeakScalingRow {
            n,
            batch,
            seq_len,
            tp_mem_mb: tp_mem,
            tp_tokens_per_sec: tp_tps,
            sp_mem_mb: sp_bytes as f64 / (1 << 20) as f64,
            sp_tokens_per_sec: if sp_fit {
                timing::tokens_per_sec(cluster, &shape, sp)
                    .expect("table4 shapes are non-degenerate")
            } else {
                0.0
            },
        });
    };
    for n in [1usize, 2, 4, 8] {
        push(n, 64 * n, 512); // batch-dimension weak scaling
    }
    for n in [1usize, 2, 4, 8] {
        push(n, 64, 256 * n); // sequence-dimension weak scaling
    }
    rows
}

/// Tables 1 & 2: the closed-form memory comparison at a given shape.
#[derive(Clone, Copy, Debug)]
pub struct FormulaRow {
    pub block: &'static str,
    pub tp_elems: u64,
    pub sp_elems: u64,
    pub sp_wins: bool,
}

pub fn tables12(model: ModelConfig, b: u64, l: u64, n: u64) -> [FormulaRow; 2] {
    let (h, a, z) = (model.hidden as u64, model.head_dim as u64, model.heads as u64);
    let mlp_tp = memory::paper_mlp_tensor(b, l, h, n);
    let mlp_sp = memory::paper_mlp_sequence(b, l, h, n);
    let at_tp = memory::paper_attn_tensor(b, l, h, a, z, n);
    let at_sp = memory::paper_attn_sequence(b, l, h, a, z, n);
    [
        FormulaRow { block: "MLP (Table 1)", tp_elems: mlp_tp, sp_elems: mlp_sp, sp_wins: mlp_sp < mlp_tp },
        FormulaRow { block: "Attention (Table 2)", tp_elems: at_tp, sp_elems: at_sp, sp_wins: at_sp < at_tp },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BERT_BASE, BERT_LARGE};

    #[test]
    fn tp_sizes_capped_at_head_count() {
        assert_eq!(tp_sizes(&BERT_BASE), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(tp_sizes(&BERT_LARGE), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn fig3_sp_extends_past_tp_cap() {
        let rows = fig3(&Cluster::default(), BERT_BASE);
        let at64 = rows.iter().find(|r| r.n == 64).unwrap();
        assert!(at64.tp_max_batch.is_none(), "TP cannot reach 64 on 12 heads");
        assert!(at64.sp_max_batch > 0);
    }

    #[test]
    fn fig5b_sparse_dominates_dense() {
        for row in fig5b(&Cluster::default(), BERT_BASE) {
            assert!(row.sparse_max_len >= row.dense_max_len, "{row:?}");
        }
    }

    #[test]
    fn table4_tp_ooms_at_8_sp_does_not() {
        let rows = table4(&Cluster::default(), BERT_BASE);
        let batch8 = rows.iter().find(|r| r.n == 8 && r.seq_len == 512).unwrap();
        assert!(batch8.tp_mem_mb.is_none(), "paper Table 4: TP OOMs at n=8");
        assert!(batch8.sp_mem_mb > 0.0 && batch8.sp_tokens_per_sec > 0.0);
    }

    #[test]
    fn table4_sp_memory_flat_in_batch_sweep() {
        let rows = table4(&Cluster::default(), BERT_BASE);
        let batch_rows: Vec<_> = rows.iter().filter(|r| r.seq_len == 512).collect();
        let first = batch_rows.first().unwrap().sp_mem_mb;
        let last = batch_rows.last().unwrap().sp_mem_mb;
        assert!(
            (last / first) < 1.35,
            "SP memory should stay ~constant: {first} -> {last} MB"
        );
    }
}
