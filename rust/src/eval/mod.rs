//! Experiment harness: regenerates every figure and table of the paper
//! (see DESIGN.md §5 for the index) and hosts the CLI subcommands.

pub mod bench;
pub mod cmd;
pub mod figures;
pub mod sweep;
