//! # seqpar — Sequence Parallelism from a system perspective
//!
//! A rust reproduction of *"Sequence Parallelism: Long Sequence Training
//! from System Perspective"* (Li et al., ACL 2023) with two interchangeable
//! execution backends behind one [`runtime::Executor`] contract:
//!
//! * **native** (default) — ~20 pure-rust f32 kernels matching the manifest
//!   step signatures, plus a synthetic in-memory manifest and seeded
//!   parameter init.  Engines, tests and benches run with **zero external
//!   artifacts**: `cargo test` exercises the full RSA ≡ serial ≡
//!   tensor-parallel equivalence out of the box.
//! * **xla-pjrt** (feature `backend-xla`) — the three-layer AOT stack:
//!   Pallas kernels (`python/compile/kernels/`) and JAX step functions
//!   (`python/compile/steps.py`) are lowered by `make artifacts` to HLO
//!   text, which this crate compiles on the PJRT CPU client and
//!   orchestrates.  Python never runs on the request path.
//!
//! Either way the crate is the **coordinator**: it chains step executables
//! across simulated devices with the paper's Ring Self-Attention schedule,
//! the Megatron tensor-parallel baseline, GPipe-style pipeline parallelism
//! and data parallelism (4D).
//!
//! Module map (docs/ARCHITECTURE.md ties each module to its paper
//! section and tabulates the pinned communication closed forms):
//!
//! * [`tensor`] — host tensors + the SPT1 interchange format
//! * [`analysis`] — the static collective-schedule verifier: abstract
//!   interpretation of every step program over symbolic comm traces and
//!   a shape-only executor (deadlock/shape linting + derived closed
//!   forms, `cargo run -- analyze`)
//! * [`attn`] — executable attention patterns (dense RSA, Linformer,
//!   blockwise masks with comm-skipping) behind [`attn::AttnPattern`],
//!   plus the Ulysses all-to-all SP strategy
//!   ([`parallel::sequence::SpStrategy`], `--sp ring|ulysses`)
//! * [`comm`] — the collective fabric (ring P2P, all-reduce, all-to-all,
//!   …) + meters, sequential ([`comm::Fabric`]) and threaded
//!   ([`comm::threaded`])
//! * [`exec`] — the threaded distributed runners: one OS thread per rank
//!   over real ring P2P ([`exec::DistRunner`]), and the executable 4D
//!   mesh — DP×PP×SP and the DP×PP×TP baseline with a real GPipe
//!   microbatch pipeline ([`exec::MeshRunner`] threaded,
//!   [`exec::MeshEngine`] sequentially simulated, byte-identical meters)
//! * [`runtime`] — the [`runtime::Executor`] trait, manifest contract,
//!   artifact-name registry, and the [`runtime::Runtime`] backend enum
//! * [`backend`] — the executors: `native` (pure rust) and `xla_pjrt`
//!   (PJRT artifact runner, feature-gated)
//! * [`model`] — transformer config, parameter store (+ seeded init)
//! * [`obs`] — runtime observability: per-rank span recorder, Chrome-trace
//!   export (`train --trace`), per-step metrics + measured comm/compute/
//!   bubble attribution (`trace` subcommand), cross-checked event-for-op
//!   against the [`comm`] meters
//! * [`parallel`] — the engines: sequence (RSA), tensor (Megatron),
//!   pipeline (GPipe), data; and the 4D topology
//! * [`train`] — Adam, LR schedule, losses bookkeeping, synthetic corpus
//! * [`simulator`] — P100-cluster memory/time model for the paper's
//!   64-GPU experiments (see DESIGN.md §2 on the substitution)
//! * [`eval`] — experiment harness regenerating every figure and table
//! * [`util`] — offline-build substrates: JSON, CLI, PRNG, mini-proptest

pub mod analysis;
pub mod attn;
pub mod backend;
pub mod comm;
pub mod eval;
pub mod exec;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod train;
pub mod util;
