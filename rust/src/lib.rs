//! # seqpar — Sequence Parallelism from a system perspective
//!
//! A rust + JAX + Pallas reproduction of *"Sequence Parallelism: Long
//! Sequence Training from System Perspective"* (Li et al., ACL 2023).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), lowered at build
//!   time into the HLO artifacts.
//! * **L2** — JAX step functions (`python/compile/steps.py`) defining the
//!   per-device computation; `make artifacts` AOT-lowers them to
//!   `artifacts/*.hlo.txt`.
//! * **L3** — this crate: loads the artifacts via the PJRT C API and
//!   orchestrates them across simulated devices with the paper's
//!   Ring Self-Attention schedule, the Megatron tensor-parallel baseline,
//!   GPipe-style pipeline parallelism and data parallelism (4D).
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`tensor`] — host tensors + the SPT1 interchange format
//! * [`comm`] — the collective fabric (ring P2P, all-reduce, …) + meters
//! * [`runtime`] — PJRT client, artifact registry, executable cache
//! * [`model`] — transformer config, parameter store
//! * [`parallel`] — the engines: sequence (RSA), tensor (Megatron),
//!   pipeline (GPipe), data; and the 4D topology
//! * [`train`] — Adam, LR schedule, losses bookkeeping, synthetic corpus
//! * [`simulator`] — P100-cluster memory/time model for the paper's
//!   64-GPU experiments (see DESIGN.md §2 on the substitution)
//! * [`eval`] — experiment harness regenerating every figure and table
//! * [`util`] — offline-build substrates: JSON, CLI, PRNG, mini-proptest

pub mod comm;
pub mod eval;
pub mod model;
pub mod parallel;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod train;
pub mod util;
