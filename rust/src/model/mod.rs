//! Model configuration and the parameter store.
//!
//! Mirrors `python/compile/configs.py` and `model.py::param_spec`: the
//! engines address parameters by the same names the manifest exports, and
//! all engines of a run share one [`ParamStore`] loaded from the artifact
//! directory so that every comparison starts from identical weights.

pub mod params;

use anyhow::{bail, Result};

/// Transformer hyper-parameters (paper notation: H, Z, A, plus depth/V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,   // H
    pub heads: usize,    // Z
    pub head_dim: usize, // A
    pub vocab: usize,
    pub max_len: usize,
    pub ffn_mult: usize,
}

impl ModelConfig {
    pub const fn ffn(&self) -> usize {
        self.ffn_mult * self.hidden
    }

    /// Approximate parameter count (embeddings + blocks + heads) — must
    /// agree with configs.py::ModelConfig.params.
    pub fn params(&self) -> usize {
        let (h, f, v) = (self.hidden, self.ffn(), self.vocab);
        let per_layer = 4 * h * h + 4 * h + h * f + f + f * h + h + 4 * h;
        let emb = v * h + self.max_len * h;
        let heads = v * h + v + 2 * h + 2;
        emb + self.layers * per_layer + heads
    }
}

/// The paper's models plus the CPU-testbed configs (configs.py mirror).
pub const BERT_BASE: ModelConfig = ModelConfig {
    name: "bert-base", layers: 12, hidden: 768, heads: 12, head_dim: 64,
    vocab: 30522, max_len: 512, ffn_mult: 4,
};

pub const BERT_LARGE: ModelConfig = ModelConfig {
    name: "bert-large", layers: 24, hidden: 1024, heads: 16, head_dim: 64,
    vocab: 30522, max_len: 512, ffn_mult: 4,
};

pub const BERT_SMALL: ModelConfig = ModelConfig {
    name: "bert-small", layers: 4, hidden: 256, heads: 4, head_dim: 64,
    vocab: 8192, max_len: 512, ffn_mult: 4,
};

pub const BERT_TINY: ModelConfig = ModelConfig {
    name: "bert-tiny", layers: 2, hidden: 128, heads: 2, head_dim: 64,
    vocab: 1024, max_len: 256, ffn_mult: 4,
};

/// bert-tiny with its 128 hidden dims split over 4 heads instead of 2 —
/// the Ulysses all-to-all strategy shards whole heads, so testing it at
/// ring sizes up to 4 needs `4 | heads` (`--model bert-tiny-z4`).
pub const BERT_TINY_Z4: ModelConfig = ModelConfig {
    name: "bert-tiny-z4", layers: 2, hidden: 128, heads: 4, head_dim: 32,
    vocab: 1024, max_len: 256, ffn_mult: 4,
};

pub fn by_name(name: &str) -> Result<ModelConfig> {
    Ok(match name {
        "bert-base" => BERT_BASE,
        "bert-large" => BERT_LARGE,
        "bert-small" => BERT_SMALL,
        "bert-tiny" => BERT_TINY,
        "bert-tiny-z4" => BERT_TINY_Z4,
        _ => bail!("unknown model {name:?} (have bert-base/large/small/tiny/tiny-z4)"),
    })
}

/// Ordered parameter inventory for a run at sequence length `seq_len` —
/// the exact mirror of model.py::param_spec.
pub fn param_spec(cfg: &ModelConfig, seq_len: usize) -> Vec<(String, Vec<usize>)> {
    let (h, f, v) = (cfg.hidden, cfg.ffn(), cfg.vocab);
    let mut spec: Vec<(String, Vec<usize>)> = vec![
        ("tok_emb".into(), vec![v, h]),
        ("pos_emb".into(), vec![seq_len, h]),
    ];
    for i in 0..cfg.layers {
        let p = format!("layer{i}.");
        for (n, s) in [
            ("wq", vec![h, h]), ("bq", vec![h]),
            ("wk", vec![h, h]), ("bk", vec![h]),
            ("wv", vec![h, h]), ("bv", vec![h]),
            ("wo", vec![h, h]), ("bo", vec![h]),
            ("ln1_g", vec![h]), ("ln1_b", vec![h]),
            ("w1", vec![h, f]), ("b1", vec![f]),
            ("w2", vec![f, h]), ("b2", vec![h]),
            ("ln2_g", vec![h]), ("ln2_b", vec![h]),
        ] {
            spec.push((format!("{p}{n}"), s));
        }
    }
    spec.push(("mlm_w".into(), vec![v, h]));
    spec.push(("mlm_b".into(), vec![v]));
    spec.push(("sop_w".into(), vec![2, h]));
    spec.push(("sop_b".into(), vec![2]));
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_is_about_110m() {
        let p = BERT_BASE.params();
        assert!(
            (100_000_000..135_000_000).contains(&p),
            "BERT-Base params {p}"
        );
    }

    #[test]
    fn bert_large_is_about_340m() {
        let p = BERT_LARGE.params();
        assert!(
            (320_000_000..370_000_000).contains(&p),
            "BERT-Large params {p}"
        );
    }

    #[test]
    fn heads_times_head_dim_is_hidden() {
        for cfg in [BERT_BASE, BERT_LARGE, BERT_SMALL, BERT_TINY] {
            assert_eq!(cfg.heads * cfg.head_dim, cfg.hidden, "{}", cfg.name);
        }
    }

    #[test]
    fn spec_matches_python_inventory_size() {
        // 2 embeddings + 16 per layer + 4 heads
        let spec = param_spec(&BERT_TINY, 64);
        assert_eq!(spec.len(), 2 + 16 * BERT_TINY.layers + 4);
        assert_eq!(spec[0].1, vec![1024, 128]);
        assert_eq!(spec[1].1, vec![64, 128]);
    }
}
