//! Parameter store: named tensors + Adam state, loaded from artifacts or
//! synthesized from a seeded deterministic init.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::Manifest;
use crate::tensor::{io, Tensor};
use crate::util::rng::Rng;

/// Named parameter set.  Under sequence parallelism all parameters are
/// replicated (that is the point of the scheme), so one store serves all
/// simulated devices; per-device *slices* (pos_emb, TP weight shards) are
/// produced by the engines on the fly.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub values: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Load the initial parameters exported by aot.py.
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<ParamStore> {
        let mut values = BTreeMap::new();
        for p in &manifest.params {
            let t = io::load(&dir.join(&p.file))?;
            if t.shape != p.dims {
                anyhow::bail!(
                    "param {}: file has shape {:?}, manifest says {:?}",
                    p.name, t.shape, p.dims
                );
            }
            values.insert(p.name.clone(), t);
        }
        Ok(ParamStore { values })
    }

    /// Seeded deterministic init from a manifest's parameter inventory —
    /// the artifact-free mirror of `model.py::init_params`: N(0, 0.02)
    /// weights, zero biases, unit LayerNorm gains.  Every engine started
    /// from the same manifest sees identical weights (the Fig. 6
    /// precondition), no exported `.tensor` files needed.
    pub fn synthetic(manifest: &Manifest) -> ParamStore {
        // the manifest's parameter inventory IS the spec (native manifests
        // fill it from model::param_spec; aot.py exports the same list)
        let spec: Vec<(String, Vec<usize>)> = manifest
            .params
            .iter()
            .map(|p| (p.name.clone(), p.dims.clone()))
            .collect();
        let mut rng = Rng::new(manifest.seed as u64);
        let mut values = BTreeMap::new();
        for (name, dims) in spec {
            let t = if name.ends_with("_g") {
                let n: usize = dims.iter().product();
                Tensor::from_f32(&dims, vec![1.0; n]).expect("spec shape")
            } else if dims.len() == 1 {
                Tensor::zeros(&dims)
            } else {
                Tensor::randn(&dims, 0.02, &mut rng)
            };
            values.insert(name, t);
        }
        ParamStore { values }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.values
            .get(name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.values
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))
    }

    /// Zero-filled gradient/optimizer-state buffers matching this store.
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            values: self
                .values
                .iter()
                .map(|(k, v)| (k.clone(), Tensor::zeros(&v.shape)))
                .collect(),
        }
    }

    pub fn total_elements(&self) -> usize {
        self.values.values().map(|t| t.numel()).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.values.values().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_like_matches_shapes() {
        let mut s = ParamStore::default();
        s.values.insert("a".into(), Tensor::zeros(&[2, 3]));
        s.values.insert("b".into(), Tensor::zeros(&[4]));
        let z = s.zeros_like();
        assert_eq!(z.values["a"].shape, vec![2, 3]);
        assert_eq!(z.values["b"].shape, vec![4]);
        assert_eq!(s.total_elements(), 10);
        assert_eq!(s.total_bytes(), 40);
    }

    #[test]
    fn get_unknown_errors() {
        let s = ParamStore::default();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn synthetic_init_is_deterministic_and_structured() {
        use crate::backend::native::{NativeBackend, NativeConfig};
        let be = NativeBackend::new(NativeConfig::tiny()).unwrap();
        let a = ParamStore::synthetic(be.manifest());
        let b = ParamStore::synthetic(be.manifest());
        assert_eq!(a.values.len(), b.values.len());
        for (name, t) in &a.values {
            assert_eq!(t, &b.values[name], "param {name} not deterministic");
        }
        // LN gains are ones, biases zero, weights non-trivial
        assert!(a.values["layer0.ln1_g"].f32s().unwrap().iter().all(|&v| v == 1.0));
        assert!(a.values["layer0.bq"].f32s().unwrap().iter().all(|&v| v == 0.0));
        assert!(a.values["layer0.wq"].f32s().unwrap().iter().any(|&v| v != 0.0));
        assert_eq!(a.values["tok_emb"].shape, vec![1024, 128]);
    }
}
