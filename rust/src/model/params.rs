//! Parameter store: named tensors + Adam state, loaded from artifacts.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::Manifest;
use crate::tensor::{io, Tensor};

/// Named parameter set.  Under sequence parallelism all parameters are
/// replicated (that is the point of the scheme), so one store serves all
/// simulated devices; per-device *slices* (pos_emb, TP weight shards) are
/// produced by the engines on the fly.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub values: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Load the initial parameters exported by aot.py.
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<ParamStore> {
        let mut values = BTreeMap::new();
        for p in &manifest.params {
            let t = io::load(&dir.join(&p.file))?;
            if t.shape != p.dims {
                anyhow::bail!(
                    "param {}: file has shape {:?}, manifest says {:?}",
                    p.name, t.shape, p.dims
                );
            }
            values.insert(p.name.clone(), t);
        }
        Ok(ParamStore { values })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.values
            .get(name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.values
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))
    }

    /// Zero-filled gradient/optimizer-state buffers matching this store.
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            values: self
                .values
                .iter()
                .map(|(k, v)| (k.clone(), Tensor::zeros(&v.shape)))
                .collect(),
        }
    }

    pub fn total_elements(&self) -> usize {
        self.values.values().map(|t| t.numel()).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.values.values().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_like_matches_shapes() {
        let mut s = ParamStore::default();
        s.values.insert("a".into(), Tensor::zeros(&[2, 3]));
        s.values.insert("b".into(), Tensor::zeros(&[4]));
        let z = s.zeros_like();
        assert_eq!(z.values["a"].shape, vec![2, 3]);
        assert_eq!(z.values["b"].shape, vec![4]);
        assert_eq!(s.total_elements(), 10);
        assert_eq!(s.total_bytes(), 40);
    }

    #[test]
    fn get_unknown_errors() {
        let s = ParamStore::default();
        assert!(s.get("nope").is_err());
    }
}
