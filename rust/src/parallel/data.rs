//! Data parallelism: replicas process independent (micro)batches and
//! all-reduce averaged gradients.
//!
//! The artifact shapes fix the per-replica batch size, so the wrapper runs
//! the inner engine once per replica on that replica's batch — exactly the
//! semantics of DP ranks — and reduces gradients through the metered
//! fabric.  Composes with either inner engine, which is how the paper's
//! "combine data parallelism and tensor parallelism to scale Megatron up
//! to 64 GPUs" comparison point (Fig. 3a) is built.

use anyhow::{bail, Result};

use crate::comm::Fabric;
use crate::model::params::ParamStore;
use crate::tensor::ops;

use super::{Batch, Engine, StepOutput};

pub struct DataParallel<'e, E: Engine> {
    pub inner: &'e E,
    pub fabric: Fabric, // the DP group (size = number of replicas)
}

impl<'e, E: Engine> DataParallel<'e, E> {
    pub fn new(inner: &'e E, fabric: Fabric) -> Self {
        DataParallel { inner, fabric }
    }

    /// One DP step: `batches[r]` is replica r's batch.  Returns the
    /// all-reduced (averaged) gradients and the mean loss.
    pub fn step(&self, params: &ParamStore, batches: &[Batch]) -> Result<StepOutput> {
        let n = self.fabric.n;
        if batches.len() != n {
            bail!("data parallelism over {n} replicas needs {n} batches, got {}", batches.len());
        }
        let mut outs = Vec::with_capacity(n);
        for b in batches {
            outs.push(self.inner.forward_backward(params, b)?);
        }
        let loss = outs.iter().map(|o| o.loss).sum::<f32>() / n as f32;
        let mlm = outs.iter().map(|o| o.mlm).sum::<f32>() / n as f32;
        let sop = outs.iter().map(|o| o.sop).sum::<f32>() / n as f32;
        // gradient all-reduce per parameter through the metered fabric —
        // the same shared reduce the mesh runner's dp axis uses
        // (`parallel::allreduce_named`), then average over replicas.
        let names: Vec<String> = outs[0].grads.values.keys().cloned().collect();
        let hidden = outs[0].hidden.split_off(0);
        let mut stores: Vec<ParamStore> = outs.into_iter().map(|o| o.grads).collect();
        super::allreduce_named(&self.fabric, &mut stores, &names)?;
        let mut reduced = stores.swap_remove(0);
        for t in reduced.values.values_mut() {
            ops::scale_assign(t, 1.0 / n as f32)?;
        }
        Ok(StepOutput { loss, mlm, sop, grads: reduced, hidden })
    }
}
