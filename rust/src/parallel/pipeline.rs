//! Pipeline parallelism: the GPipe micro-batch schedule (paper §2).
//!
//! Used two ways:
//!
//! 1. [`Schedule`] computes the exact micro-batch timeline (which stage
//!    runs which microbatch when, bubble fraction) — the timing input for
//!    the Fig. 4 throughput comparison.
//! 2. [`boundary_bytes_megatron`] / [`boundary_bytes_seqpar`] account the
//!    stage-boundary activation traffic,
//!    where the paper's observation lives: Megatron must SPLIT the
//!    activation before sending and ALL-GATHER after (its tensor shards
//!    all hold the full sequence), while sequence parallelism sends its
//!    already-split sub-sequence chunk directly — one less all-gather per
//!    boundary (paper §3.2.2, last paragraph).
//!
//! The memory side (why fewer stages = more activation memory per device)
//! is handled by `simulator::memory`, which charges `layers/stages` of
//! activations per device.

/// One cell of the pipeline timeline: stage `s` runs microbatch `m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell {
    pub stage: usize,
    pub micro: usize,
    /// Clock tick at which this cell starts (unit: one stage-time).
    pub start: usize,
    pub forward: bool,
}

/// GPipe schedule: all-forward then all-backward, synchronous flush.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub stages: usize,
    pub micros: usize,
    pub cells: Vec<Cell>,
}

impl Schedule {
    pub fn gpipe(stages: usize, micros: usize) -> Schedule {
        assert!(stages >= 1 && micros >= 1);
        let mut cells = Vec::with_capacity(2 * stages * micros);
        // forward wave: stage s starts microbatch m at tick m + s
        for s in 0..stages {
            for m in 0..micros {
                cells.push(Cell { stage: s, micro: m, start: m + s, forward: true });
            }
        }
        // backward wave: starts after the last forward leaves the pipe;
        // stage order reversed.  Backward of micro m on stage s starts at
        // fwd_makespan + m + (stages - 1 - s).
        let fwd_makespan = micros + stages - 1;
        for s in (0..stages).rev() {
            for m in 0..micros {
                cells.push(Cell {
                    stage: s,
                    micro: m,
                    start: fwd_makespan + m + (stages - 1 - s),
                    forward: false,
                });
            }
        }
        Schedule { stages, micros, cells }
    }

    /// Total ticks until the last backward cell finishes (bwd cells take
    /// `bwd_cost` ticks each; GPipe convention bwd ~ 2x fwd).
    pub fn makespan(&self, bwd_cost: usize) -> usize {
        self.cells
            .iter()
            .map(|c| c.start + if c.forward { 1 } else { bwd_cost })
            .max()
            .unwrap_or(0)
    }

    /// Fraction of stage-time lost to the bubble (fwd+bwd, bwd_cost=1):
    /// (s-1) idle slots at each end per wave.
    pub fn bubble_fraction(&self) -> f64 {
        let useful = 2.0 * self.micros as f64;
        let total = useful + 2.0 * (self.stages as f64 - 1.0);
        1.0 - useful / total
    }

    /// Sanity: no stage runs two cells at the same tick.
    pub fn is_conflict_free(&self, bwd_cost: usize) -> bool {
        for a in &self.cells {
            let a_end = a.start + if a.forward { 1 } else { bwd_cost };
            for b in &self.cells {
                if (a.stage, a.micro, a.forward) == (b.stage, b.micro, b.forward) {
                    continue;
                }
                if a.stage == b.stage {
                    let b_end = b.start + if b.forward { 1 } else { bwd_cost };
                    if a.start < b_end && b.start < a_end {
                        return false;
                    }
                }
            }
        }
        true
    }
}

/// Stage-boundary activation traffic per microbatch per crossing, in
/// bytes, for an activation of `b * l * h` f32 elements split over `mp`
/// tensor/sequence ranks.
///
/// Megatron (tensor parallelism): every rank holds the full `[b, l, h]`
/// activation; to save bandwidth it scatters to `1/mp` slices, sends, and
/// all-gathers on the receiving stage (paper §3.2.2): the send is C/mp
/// per rank (C group total), and the ring all-gather on the receive side
/// moves `(mp-1) * C` group total — each rank forwards mp-1 chunks of
/// C/mp, the same accounting `comm::Fabric::all_gather` meters — so the
/// closed form equals what the executable mesh boundary measures
/// (`exec::mesh`, rust/tests/mesh_props.rs).
///
/// Sequence parallelism: each rank owns `[b, l/mp, h]` already — it just
/// sends its chunk: C/mp per rank, no scatter, no gather.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoundaryBytes {
    pub send: u64,
    pub gather: u64,
}

pub fn boundary_bytes_megatron(b: usize, l: usize, h: usize, mp: usize) -> BoundaryBytes {
    let c = (b * l * h * 4) as u64;
    BoundaryBytes { send: c, gather: (mp as u64 - 1) * c }
}

pub fn boundary_bytes_seqpar(b: usize, l: usize, h: usize, _mp: usize) -> BoundaryBytes {
    let c = (b * l * h * 4) as u64;
    BoundaryBytes { send: c, gather: 0 }
}

/// Boundary traffic of a FULL GPipe step over one pipeline (one
/// data-parallel replica — multiply by dp for a whole mesh step):
/// `(pp-1)` stage boundaries, each crossed once forward (activations)
/// and once backward (gradients) by every one of `micros` microbatches.
/// This is the closed form the mesh property test pins against measured
/// `CommKind::Pipeline` (send) and `CommKind::AllGather` (gather) meters.
pub fn boundary_totals(
    kind: super::topology::MpKind,
    b: usize,
    l: usize,
    h: usize,
    mp: usize,
    pp: usize,
    micros: usize,
) -> BoundaryBytes {
    let per = match kind {
        super::topology::MpKind::Tensor => boundary_bytes_megatron(b, l, h, mp),
        super::topology::MpKind::Sequence => boundary_bytes_seqpar(b, l, h, mp),
    };
    let crossings = (pp.saturating_sub(1) * micros * 2) as u64;
    BoundaryBytes { send: per.send * crossings, gather: per.gather * crossings }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_timeline_shape() {
        let s = Schedule::gpipe(4, 8);
        assert_eq!(s.cells.len(), 2 * 4 * 8);
        // first forward cell of stage 0 at tick 0; of stage 3 at tick 3
        assert!(s.cells.contains(&Cell { stage: 0, micro: 0, start: 0, forward: true }));
        assert!(s.cells.contains(&Cell { stage: 3, micro: 0, start: 3, forward: true }));
        // forward makespan is micros + stages - 1
        let fwd_last = s.cells.iter().filter(|c| c.forward).map(|c| c.start + 1).max();
        assert_eq!(fwd_last, Some(8 + 4 - 1 + 1 - 1 + 0)); // 11 ticks, ends at 11
    }

    #[test]
    fn gpipe_is_conflict_free() {
        for (st, mi) in [(1, 1), (2, 4), (4, 8), (8, 2)] {
            let s = Schedule::gpipe(st, mi);
            assert!(s.is_conflict_free(1), "conflict at stages={st} micros={mi}");
        }
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        let few = Schedule::gpipe(4, 2).bubble_fraction();
        let many = Schedule::gpipe(4, 32).bubble_fraction();
        assert!(many < few);
        assert!(Schedule::gpipe(1, 8).bubble_fraction() < 1e-12);
    }

    #[test]
    fn seqpar_boundary_saves_the_gather() {
        let meg = boundary_bytes_megatron(4, 512, 768, 4);
        let seq = boundary_bytes_seqpar(4, 512, 768, 4);
        assert_eq!(meg.send, seq.send);
        // ring all-gather group total: (mp-1) * C
        assert_eq!(meg.gather, 3 * meg.send);
        assert_eq!(seq.gather, 0);
        // degenerate mp=1: no split, no gather for either scheme
        assert_eq!(boundary_bytes_megatron(4, 512, 768, 1).gather, 0);
    }

    #[test]
    fn bubble_fraction_matches_closed_form() {
        // GPipe bubble: (s-1) / (m + s - 1)
        for s in [1usize, 2, 4, 8] {
            for m in [1usize, 2, 4, 8, 32] {
                let got = Schedule::gpipe(s, m).bubble_fraction();
                let want = (s as f64 - 1.0) / (m as f64 + s as f64 - 1.0);
                assert!(
                    (got - want).abs() < 1e-12,
                    "stages={s} micros={m}: bubble {got} != closed form {want}"
                );
            }
        }
    }

    #[test]
    fn gpipe_backward_cells_are_packed_at_unit_cost() {
        // The schedule packs backward cells one tick apart: disjoint at
        // the unit cost it is built for, but NOT when backward takes 2
        // ticks — the timing model's makespan(bwd_cost) stretches the
        // bound rather than repacking (pinning this keeps the two
        // interpretations from being silently conflated).
        for (st, mi) in [(1, 1), (2, 3), (4, 8), (8, 2)] {
            let s = Schedule::gpipe(st, mi);
            assert!(s.is_conflict_free(1), "overlap at stages={st} micros={mi} bwd_cost=1");
        }
        assert!(!Schedule::gpipe(2, 3).is_conflict_free(2));
        // single-microbatch schedules have no backward packing to violate
        assert!(Schedule::gpipe(4, 1).is_conflict_free(2));
        assert!(Schedule::gpipe(1, 1).is_conflict_free(2));
    }

    #[test]
    fn backward_traverses_stages_in_exact_reverse_per_microbatch() {
        let sched = Schedule::gpipe(4, 3);
        for micro in 0..3 {
            let order = |forward: bool| -> Vec<usize> {
                let mut cells: Vec<&Cell> = sched
                    .cells
                    .iter()
                    .filter(|c| c.micro == micro && c.forward == forward)
                    .collect();
                cells.sort_by_key(|c| c.start);
                cells.iter().map(|c| c.stage).collect()
            };
            assert_eq!(order(true), vec![0, 1, 2, 3], "micro {micro} forward order");
            assert_eq!(order(false), vec![3, 2, 1, 0], "micro {micro} backward order");
        }
    }

    #[test]
    fn boundary_totals_scale_with_crossings() {
        use crate::parallel::topology::MpKind;
        let per = boundary_bytes_megatron(2, 32, 128, 2);
        let tot = boundary_totals(MpKind::Tensor, 2, 32, 128, 2, 3, 4);
        // 2 boundaries x 4 micros x 2 directions = 16 crossings
        assert_eq!(tot.send, per.send * 16);
        assert_eq!(tot.gather, per.gather * 16);
        // no pipeline, no boundary traffic
        let none = boundary_totals(MpKind::Sequence, 2, 32, 128, 2, 1, 4);
        assert_eq!((none.send, none.gather), (0, 0));
    }
}
