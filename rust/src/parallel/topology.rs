//! 4D device mesh: data × pipeline × (tensor | sequence) parallelism.
//!
//! The paper's headline compatibility claim: sequence parallelism slots
//! into the same mesh position Megatron's tensor parallelism occupies, so
//! the familiar DP×PP×MP factorization becomes DP×PP×SP — "4D parallelism"
//! with the batch, depth, and sequence dimensions all sharded.
//!
//! Rank layout (innermost-fastest, Megatron convention):
//!     global = ((dp * PP) + pp) * MP + mp

use anyhow::{bail, Result};

/// Which strategy occupies the innermost (model-parallel) axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpKind {
    Tensor,
    Sequence,
}

#[derive(Clone, Copy, Debug)]
pub struct Mesh {
    pub dp: usize,
    pub pp: usize,
    pub mp: usize,
    pub kind: MpKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coord {
    pub dp: usize,
    pub pp: usize,
    pub mp: usize,
}

impl Mesh {
    pub fn new(dp: usize, pp: usize, mp: usize, kind: MpKind) -> Result<Mesh> {
        if dp == 0 || pp == 0 || mp == 0 {
            bail!("mesh axes must be positive: dp={dp} pp={pp} mp={mp}");
        }
        Ok(Mesh { dp, pp, mp, kind })
    }

    pub fn world_size(&self) -> usize {
        self.dp * self.pp * self.mp
    }

    pub fn coord(&self, rank: usize) -> Result<Coord> {
        if rank >= self.world_size() {
            bail!("rank {rank} out of world {}", self.world_size());
        }
        Ok(Coord {
            mp: rank % self.mp,
            pp: (rank / self.mp) % self.pp,
            dp: rank / (self.mp * self.pp),
        })
    }

    pub fn rank(&self, c: Coord) -> usize {
        (c.dp * self.pp + c.pp) * self.mp + c.mp
    }

    /// Layers per pipeline stage; errors unless the stage count divides
    /// the layer count evenly (GPipe stages must be balanced).
    pub fn stage_layers(&self, layers: usize) -> Result<usize> {
        if layers == 0 || layers % self.pp != 0 {
            bail!(
                "pipeline stages {} must divide the layer count {layers} evenly",
                self.pp
            );
        }
        Ok(layers / self.pp)
    }

    /// Compact "dp×pp×mp-kind" label for logs and bench rows.
    pub fn label(&self) -> String {
        format!(
            "{}x{}x{}-{}",
            self.dp,
            self.pp,
            self.mp,
            match self.kind {
                MpKind::Tensor => "tp",
                MpKind::Sequence => "sp",
            }
        )
    }

    /// All ranks sharing this rank's (dp, pp) — its model-parallel group
    /// (the ring, under sequence parallelism).
    pub fn mp_group(&self, rank: usize) -> Result<Vec<usize>> {
        let c = self.coord(rank)?;
        Ok((0..self.mp)
            .map(|mp| self.rank(Coord { mp, ..c }))
            .collect())
    }

    /// All ranks sharing (dp, mp) — the pipeline this rank belongs to.
    pub fn pp_group(&self, rank: usize) -> Result<Vec<usize>> {
        let c = self.coord(rank)?;
        Ok((0..self.pp)
            .map(|pp| self.rank(Coord { pp, ..c }))
            .collect())
    }

    /// All ranks sharing (pp, mp) — the data-parallel replica group.
    pub fn dp_group(&self, rank: usize) -> Result<Vec<usize>> {
        let c = self.coord(rank)?;
        Ok((0..self.dp)
            .map(|dp| self.rank(Coord { dp, ..c }))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn rank_coord_roundtrip() {
        let m = Mesh::new(2, 4, 8, MpKind::Sequence).unwrap();
        for r in 0..m.world_size() {
            assert_eq!(m.rank(m.coord(r).unwrap()), r);
        }
    }

    #[test]
    fn groups_partition_the_world() {
        Prop::new(32, 7).check("mesh groups partition", |rng| {
            let dp = 1 + rng.below(3) as usize;
            let pp = 1 + rng.below(3) as usize;
            let mp = 1 + rng.below(4) as usize;
            let m = Mesh::new(dp, pp, mp, MpKind::Tensor).map_err(|e| e.to_string())?;
            for axis in 0..3 {
                let mut seen = vec![0usize; m.world_size()];
                for r in 0..m.world_size() {
                    let group = match axis {
                        0 => m.mp_group(r),
                        1 => m.pp_group(r),
                        _ => m.dp_group(r),
                    }
                    .map_err(|e| e.to_string())?;
                    if !group.contains(&r) {
                        return Err(format!("rank {r} missing from its own group"));
                    }
                    for g in group {
                        seen[g] += 1;
                    }
                }
                // each rank appears in exactly group_len groups-membership counts
                let expect = match axis {
                    0 => mp,
                    1 => pp,
                    _ => dp,
                };
                if seen.iter().any(|&c| c != expect) {
                    return Err(format!("axis {axis}: membership counts {seen:?} != {expect}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mp_group_is_contiguous() {
        let m = Mesh::new(2, 2, 4, MpKind::Sequence).unwrap();
        assert_eq!(m.mp_group(0).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(m.mp_group(5).unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn zero_axis_rejected() {
        assert!(Mesh::new(0, 1, 1, MpKind::Tensor).is_err());
    }

    #[test]
    fn stage_layers_requires_even_split() {
        let m = Mesh::new(1, 2, 2, MpKind::Sequence).unwrap();
        assert_eq!(m.stage_layers(4).unwrap(), 2);
        assert!(m.stage_layers(3).is_err());
        assert!(m.stage_layers(0).is_err());
    }

    #[test]
    fn label_names_axes_and_kind() {
        assert_eq!(Mesh::new(2, 2, 4, MpKind::Sequence).unwrap().label(), "2x2x4-sp");
        assert_eq!(Mesh::new(1, 2, 2, MpKind::Tensor).unwrap().label(), "1x2x2-tp");
    }
}
