//! The parallelism engines.
//!
//! * [`sequence`] — the paper's contribution: Ring Self-Attention sequence
//!   parallelism (forward + hand-scheduled backward).
//! * [`tensorp`] — the Megatron-LM tensor-parallel baseline.
//! * [`pipeline`] — GPipe-style micro-batch pipeline scheduler, composable
//!   with both of the above (paper §4.2 "scaling with pipeline parallelism").
//! * [`data`] — data parallelism (gradient all-reduce across replicas).
//! * [`topology`] — the 4D device mesh gluing them together.
//!
//! The engines here simulate their devices sequentially on one thread but
//! drive the REAL collective fabric for every exchange, so communication
//! volume and schedule are the paper's — see `comm::Meter` and
//! rust/tests/comm_volume.rs.  Sequential execution is a *requirement*
//! only for the `backend-xla` feature (PJRT client handles are `Rc`-based
//! and thread-local); on the default native backend the same per-rank
//! step logic also runs genuinely parallel, one OS thread per rank, via
//! [`crate::exec::DistRunner`].

pub mod data;
pub mod pipeline;
pub mod sequence;
pub mod tensorp;
pub mod topology;

use anyhow::Result;

use crate::model::params::ParamStore;
use crate::runtime::{registry, Runtime};
use crate::tensor::Tensor;

/// One training batch (global view; engines shard it themselves).
#[derive(Clone, Debug)]
pub struct Batch {
    pub ids: Tensor,        // [B, L] i32
    pub labels: Tensor,     // [B, L] i32 (MLM targets at masked positions)
    pub mask: Tensor,       // [B, L] f32 (1.0 where masked)
    pub sop_labels: Tensor, // [B] i32
}

/// Result of one forward+backward over a batch.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    pub mlm: f32,
    pub sop: f32,
    /// Parameter gradients in GLOBAL layout (already reduced across the
    /// parallel group — ready for the optimizer).
    pub grads: ParamStore,
    /// Final hidden states, one chunk per device (sequence engines) or a
    /// single full tensor (tensor/serial engines).
    pub hidden: Vec<Tensor>,
}

/// A training engine: one parallelism strategy over one runtime.
pub trait Engine {
    fn name(&self) -> &'static str;
    /// Number of simulated devices in the parallel group.
    fn group_size(&self) -> usize;
    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput>;
}

/// Shared helper: all-reduce (sum) the named tensors of per-rank gradient
/// stores through a metered [`crate::comm::Collective`] view, in the given
/// name order.  One call covers the whole group under the sequential
/// `Fabric` view (`stores` holds every rank) or exactly this rank under a
/// threaded per-rank view (`stores` holds one entry, the peers call the
/// same collective).  Used by the sequence-parallel ring reduce, the
/// data-parallel replica reduce, and the mesh runner's dp axis — one
/// implementation, one accounting (2(n-1)·C group total per tensor).
pub(crate) fn allreduce_named(
    view: &dyn crate::comm::Collective,
    stores: &mut [ParamStore],
    names: &[String],
) -> Result<()> {
    for name in names {
        let mut slots: Vec<Tensor> = stores
            .iter_mut()
            .map(|g| {
                g.values
                    .get_mut(name)
                    .map(|t| std::mem::replace(t, Tensor::zeros(&[])))
                    .ok_or_else(|| anyhow::anyhow!("all-reduce of unknown gradient {name:?}"))
            })
            .collect::<Result<_>>()?;
        view.all_reduce_sum(&mut slots)?;
        for (g, t) in stores.iter_mut().zip(slots) {
            *g.values.get_mut(name).unwrap() = t;
        }
    }
    Ok(())
}

/// Shared helper: execute a step artifact, resolving the name from the
/// actual input tensors (mirror of aot.py naming).  Works against any
/// [`crate::runtime::Executor`] — the name lookup is what catches a
/// config mismatch between an engine and the backend's manifest.  The
/// executor-typed variants exist so per-rank threads (which share one
/// `&dyn Executor + Sync` backend, not a `&Runtime`) use the same path.
pub(crate) fn call_on(
    ex: &dyn crate::runtime::Executor,
    step: &str,
    inputs: &[&Tensor],
) -> Result<Vec<Tensor>> {
    let name = registry::art_name_for(step, inputs);
    ex.call(&name, inputs)
}

pub(crate) fn call1_on(
    ex: &dyn crate::runtime::Executor,
    step: &str,
    inputs: &[&Tensor],
) -> Result<Tensor> {
    let name = registry::art_name_for(step, inputs);
    ex.call1(&name, inputs)
}

pub(crate) fn call(rt: &Runtime, step: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    call_on(rt.backend(), step, inputs)
}

pub(crate) fn call1(rt: &Runtime, step: &str, inputs: &[&Tensor]) -> Result<Tensor> {
    call1_on(rt.backend(), step, inputs)
}
