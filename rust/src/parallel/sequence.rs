//! Sequence parallelism with Ring Self-Attention — the paper's system.
//!
//! The input batch is chunked along the SEQUENCE dimension (`L/N` tokens
//! per device); every device holds the full parameter set and runs the
//! whole transformer on its own chunk.  Cross-chunk attention is computed
//! by Ring Self-Attention (paper §3.1):
//!
//! * forward stage 1 — key chunks rotate around the ring N-1 times; each
//!   device accumulates its score rows `S^n ∈ R^{Lc×L}`;
//! * forward stage 2 — value chunks rotate; `O^n = Σᵢ SᵢⁿVᵢ` (Eq. 4);
//! * backward — value chunks rotate again (computing `dPᵢ` and carrying
//!   the `dVᵢ` accumulators home), then key chunks rotate (computing `dQ`
//!   and carrying `dKᵢ` home).  This is the "2 ring-P2P + gradient
//!   accumulation" schedule of §3.2.2.
//!
//! Every exchange goes through the metered fabric; the schedule is the
//! exact transcription of the validated python chain
//! (`python/compile/chain.py` — tested against `jax.grad`), with the ring
//! made explicit as slot-vector rotations.
//!
//! Ring convention: after `t` shifts device `d` holds the chunk originally
//! owned by `(d - t) mod n`.

use anyhow::{bail, Result};

use crate::comm::{CommKind, Fabric};
use crate::model::params::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::{ops, Tensor};

use super::{call, call1, Batch, Engine, StepOutput};

/// Per-layer forward activations stashed for the backward pass (one entry
/// per device).  This is exactly the paper's activation memory: note there
/// is NO stash of remote K/V chunks — they are re-circulated in backward,
/// which is what makes the scheme memory-efficient.
struct LayerStash {
    x_in: Vec<Tensor>,
    q: Vec<Tensor>,
    k: Vec<Tensor>,
    v: Vec<Tensor>,
    p: Vec<Tensor>,    // softmax probs [B, Z, Lc, L]
    ctx: Vec<Tensor>,  // attention context [B, Z, Lc, A]
    pre1: Vec<Tensor>, // x + attn (LN1 input)
    xm: Vec<Tensor>,   // LN1 output
    pre2: Vec<Tensor>, // xm + mlp (LN2 input)
    // NOTE: the MLP hidden activation is NOT stashed — mlp_bwd
    // rematerializes it (§Perf iteration 2), matching Megatron's recompute.
}

pub struct SeqParEngine<'rt> {
    rt: &'rt Runtime,
    pub fabric: Fabric,
    pub n: usize,
    b: usize,
    l: usize,
    lc: usize,
    layers: usize,
    to_heads_step: String,
    qkv_step: String,
}

impl<'rt> SeqParEngine<'rt> {
    pub fn new(rt: &'rt Runtime, fabric: Fabric) -> Result<SeqParEngine<'rt>> {
        let m = rt.manifest();
        let n = fabric.n;
        if m.seq_len % n != 0 {
            bail!("seq_len {} not divisible by ring size {n}", m.seq_len);
        }
        if m.ring != n {
            bail!(
                "artifacts were lowered for ring={}, engine asked for {n}; re-run `make artifacts`",
                m.ring
            );
        }
        Ok(SeqParEngine {
            rt,
            fabric,
            n,
            b: m.batch,
            l: m.seq_len,
            lc: m.seq_len / n,
            layers: m.layers,
            to_heads_step: format!("to_heads_b{}", m.batch),
            qkv_step: format!("qkv_proj_b{}", m.batch),
        })
    }

    fn to_heads(&self, x: &Tensor) -> Result<Tensor> {
        call1(self.rt, &self.to_heads_step, &[x])
    }

    fn from_heads(&self, x: &Tensor) -> Result<Tensor> {
        call1(self.rt, "from_heads", &[x])
    }

    fn linear(&self, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
        call1(self.rt, "linear_fwd", &[x, w, b])
    }

    /// Public API: Ring Self-Attention over pre-chunked q/k/v.
    ///
    /// `q/k/v[d]` are device d's local `[B, Z, L/N, A]` chunks; returns the
    /// per-device attention outputs.  This is the paper's Eq. 4 surface —
    /// what a downstream user embeds into their own model code.
    pub fn rsa_attention(
        &self,
        q: &[Tensor],
        k: &[Tensor],
        v: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if q.len() != self.n || k.len() != self.n || v.len() != self.n {
            bail!("rsa_attention: need {} chunks, got {}/{}/{}", self.n, q.len(), k.len(), v.len());
        }
        Ok(self.rsa_forward(q, k, v)?.0)
    }

    /// RSA stages 1+2 for all devices.  `q/k/v[d]` are the local chunks.
    /// Returns (ctx, p) per device.
    fn rsa_forward(
        &self,
        q: &[Tensor],
        k: &[Tensor],
        v: &[Tensor],
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let n = self.n;
        // ---- stage 1: Ring-QK^T --------------------------------------
        // score parts indexed by ORIGIN chunk so concat restores global order
        let mut parts: Vec<Vec<Option<Tensor>>> = (0..n).map(|_| vec![None; n]).collect();
        let mut k_slots: Vec<Tensor> = k.to_vec();
        for t in 0..n {
            for d in 0..n {
                let src = (d + n - t) % n;
                parts[d][src] = Some(call1(self.rt, "scores_step", &[&q[d], &k_slots[d]])?);
            }
            if t + 1 < n {
                self.fabric.ring_shift(&mut k_slots)?;
            }
        }
        let mut p = Vec::with_capacity(n);
        for d in 0..n {
            let owned: Vec<Tensor> = parts[d].iter_mut().map(|o| o.take().unwrap()).collect();
            let refs: Vec<&Tensor> = owned.iter().collect();
            let s = ops::concat_last(&refs)?;
            p.push(call1(self.rt, "softmax_fwd", &[&s])?);
        }
        // ---- stage 2: Ring-AV (Eq. 4) --------------------------------
        let mut v_slots: Vec<Tensor> = v.to_vec();
        let mut acc: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        for t in 0..n {
            for d in 0..n {
                let src = (d + n - t) % n;
                let p_i = ops::slice_last(&p[d], src * self.lc, (src + 1) * self.lc)?;
                acc[d] = call1(self.rt, "av_step", &[&p_i, &v_slots[d], &acc[d]])?;
            }
            if t + 1 < n {
                self.fabric.ring_shift(&mut v_slots)?;
            }
        }
        Ok((acc, p))
    }

    /// RSA backward for all devices.  Returns (dq, dk, dv) per device with
    /// dk/dv already delivered back to their home devices (the
    /// accumulators ride the ring).
    fn rsa_backward(
        &self,
        d_ctx: &[Tensor],
        q: &[Tensor],
        p: &[Tensor],
        k: &[Tensor],
        v: &[Tensor],
    ) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
        let n = self.n;
        // ---- ring pass of V: dP parts + dV accumulators ride along ----
        let mut v_slots: Vec<Tensor> = v.to_vec();
        let mut dv_slots: Vec<Tensor> = v.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        let mut dp_parts: Vec<Vec<Option<Tensor>>> = (0..n).map(|_| vec![None; n]).collect();
        for t in 0..n {
            for d in 0..n {
                let src = (d + n - t) % n;
                dp_parts[d][src] =
                    Some(call1(self.rt, "attn_dp_step", &[&d_ctx[d], &v_slots[d]])?);
                let p_i = ops::slice_last(&p[d], src * self.lc, (src + 1) * self.lc)?;
                dv_slots[d] =
                    call1(self.rt, "attn_dv_step", &[&p_i, &d_ctx[d], &dv_slots[d]])?;
            }
            // The V chunks only need n-1 shifts (a final rotation would
            // just return them home, pure wasted traffic); the dV
            // accumulators take all n — the last shift delivers each dV_i
            // to its home device (§3.2.2).
            if t + 1 < n {
                self.fabric.ring_shift(&mut v_slots)?;
            }
            self.fabric.ring_shift(&mut dv_slots)?;
        }
        // ---- local softmax backward over full rows ---------------------
        let mut ds = Vec::with_capacity(n);
        for d in 0..n {
            let owned: Vec<Tensor> = dp_parts[d].iter_mut().map(|o| o.take().unwrap()).collect();
            let refs: Vec<&Tensor> = owned.iter().collect();
            let dp = ops::concat_last(&refs)?;
            ds.push(call1(self.rt, "softmax_bwd", &[&p[d], &dp])?);
        }
        // ---- ring pass of K: dQ accumulation + dK accumulators ---------
        let mut k_slots: Vec<Tensor> = k.to_vec();
        let mut dk_slots: Vec<Tensor> = k.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        let mut dq: Vec<Tensor> = q.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        for t in 0..n {
            for d in 0..n {
                let src = (d + n - t) % n;
                let ds_i = ops::slice_last(&ds[d], src * self.lc, (src + 1) * self.lc)?;
                dq[d] = call1(self.rt, "attn_dq_step", &[&ds_i, &k_slots[d], &dq[d]])?;
                dk_slots[d] = call1(self.rt, "attn_dk_step", &[&ds_i, &q[d], &dk_slots[d]])?;
            }
            // Same asymmetry as the V pass: K data shifts n-1 times, the
            // dK accumulators ride all n shifts home.
            if t + 1 < n {
                self.fabric.ring_shift(&mut k_slots)?;
            }
            self.fabric.ring_shift(&mut dk_slots)?;
        }
        Ok((dq, dk_slots, dv_slots))
    }
}

impl<'rt> Engine for SeqParEngine<'rt> {
    fn name(&self) -> &'static str {
        "sequence-parallel"
    }

    fn group_size(&self) -> usize {
        self.n
    }

    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        let (n, b, l, lc) = (self.n, self.b, self.l, self.lc);
        let rt = self.rt;
        let p_of = |name: &str| params.get(name);

        // ---- shard the batch along the sequence dimension ---------------
        let ids_c = ops::chunk_dim1(&batch.ids, n)?;
        let labels_c: Vec<Tensor> = ops::chunk_dim1(&batch.labels, n)?
            .into_iter()
            .map(|t| t.reshaped(&[b * lc]).unwrap())
            .collect();
        let mask_c: Vec<Tensor> = ops::chunk_dim1(&batch.mask, n)?
            .into_iter()
            .map(|t| t.reshaped(&[b * lc]).unwrap())
            .collect();
        let pos = p_of("pos_emb")?;
        let pos_c: Vec<Tensor> = (0..n)
            .map(|d| ops::slice_dim0(pos, d * lc, (d + 1) * lc))
            .collect::<Result<_>>()?;

        // ---- forward ----------------------------------------------------
        let tok = p_of("tok_emb")?;
        let mut x: Vec<Tensor> = (0..n)
            .map(|d| call1(rt, "embed_fwd", &[&ids_c[d], tok, &pos_c[d]]))
            .collect::<Result<_>>()?;

        let mut stashes: Vec<LayerStash> = Vec::with_capacity(self.layers);
        for li in 0..self.layers {
            let pf = |s: &str| format!("layer{li}.{s}");
            let (wq, bq) = (p_of(&pf("wq"))?, p_of(&pf("bq"))?);
            let (wk, bk) = (p_of(&pf("wk"))?, p_of(&pf("bk"))?);
            let (wv, bv) = (p_of(&pf("wv"))?, p_of(&pf("bv"))?);
            let mut q = Vec::new();
            let mut k = Vec::new();
            let mut v = Vec::new();
            for d in 0..n {
                // fused QKV projection + head split (1 call, was 6)
                let out = call(rt, &self.qkv_step, &[&x[d], wq, bq, wk, bk, wv, bv])?;
                let [qd, kd, vd]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow::anyhow!("qkv_proj arity"))?;
                q.push(qd);
                k.push(kd);
                v.push(vd);
            }
            let (ctx, p) = self.rsa_forward(&q, &k, &v)?;
            let (wo, bo) = (p_of(&pf("wo"))?, p_of(&pf("bo"))?);
            let (g1, be1) = (p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?);
            let mut pre1 = Vec::new();
            let mut xm = Vec::new();
            for d in 0..n {
                let attn = self.linear(&self.from_heads(&ctx[d])?, wo, bo)?;
                // fused residual-add + LayerNorm (also returns the pre-LN
                // sum, the same stash the unfused path kept)
                let out = call(rt, "add_ln_fwd", &[&x[d], &attn, g1, be1])?;
                let [y, pre]: [Tensor; 2] =
                    out.try_into().map_err(|_| anyhow::anyhow!("add_ln arity"))?;
                xm.push(y);
                pre1.push(pre);
            }
            let (w1, b1) = (p_of(&pf("w1"))?, p_of(&pf("b1"))?);
            let (w2, b2) = (p_of(&pf("w2"))?, p_of(&pf("b2"))?);
            let (g2, be2) = (p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?);
            let mut pre2 = Vec::new();
            let mut x_next = Vec::new();
            for d in 0..n {
                // fused MLP block (hidden activation rematerialized in bwd)
                let m2 = call1(rt, "mlp_fwd", &[&xm[d], w1, b1, w2, b2])?;
                let out = call(rt, "add_ln_fwd", &[&xm[d], &m2, g2, be2])?;
                let [y, pre]: [Tensor; 2] =
                    out.try_into().map_err(|_| anyhow::anyhow!("add_ln arity"))?;
                x_next.push(y);
                pre2.push(pre);
            }
            stashes.push(LayerStash {
                x_in: std::mem::replace(&mut x, x_next),
                q, k, v, p, ctx, pre1, xm, pre2,
            });
        }

        // ---- losses -------------------------------------------------------
        let mut grads = params.zeros_like();
        let (mlm_w, mlm_b) = (p_of("mlm_w")?, p_of("mlm_b")?);
        let mut mlm_total = 0.0f32;
        let mut dx: Vec<Tensor> = Vec::with_capacity(n);
        for d in 0..n {
            let out = call(rt, "mlm_loss", &[&x[d], mlm_w, mlm_b, &labels_c[d], &mask_c[d]])?;
            let [lo, dxd, dw, db]: [Tensor; 4] = out
                .try_into()
                .map_err(|_| anyhow::anyhow!("mlm_loss arity"))?;
            mlm_total += lo.scalar_f32()?;
            dx.push(dxd);
            ops::add_assign(grads.get_mut("mlm_w")?, &dw)?;
            ops::add_assign(grads.get_mut("mlm_b")?, &db)?;
        }
        // SOP head lives on device 0 (it owns every sequence's CLS token).
        let (sop_w, sop_b) = (p_of("sop_w")?, p_of("sop_b")?);
        let out = call(rt, "sop_loss", &[&x[0], sop_w, sop_b, &batch.sop_labels])?;
        let [sop_lo, dx0, dsw, dsb]: [Tensor; 4] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("sop_loss arity"))?;
        let sop = sop_lo.scalar_f32()?;
        ops::add_assign(&mut dx[0], &dx0)?;
        ops::add_assign(grads.get_mut("sop_w")?, &dsw)?;
        ops::add_assign(grads.get_mut("sop_b")?, &dsb)?;

        let hidden = x;

        // ---- backward ------------------------------------------------------
        for li in (0..self.layers).rev() {
            let pf = |s: &str| format!("layer{li}.{s}");
            let st = &stashes[li];
            // LN2
            let (g2, be2) = (p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?);
            let mut d_pre2 = Vec::with_capacity(n);
            for d in 0..n {
                let out = call(rt, "ln_bwd", &[&st.pre2[d], g2, be2, &dx[d]])?;
                let [dp, dg, db]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow::anyhow!("ln_bwd arity"))?;
                ops::add_assign(grads.get_mut(&pf("ln2_g"))?, &dg)?;
                ops::add_assign(grads.get_mut(&pf("ln2_b"))?, &db)?;
                d_pre2.push(dp);
            }
            // MLP (fused bwd: rematerializes the hidden activation inside)
            let (w1, b1) = (p_of(&pf("w1"))?, p_of(&pf("b1"))?);
            let (w2, b2) = (p_of(&pf("w2"))?, p_of(&pf("b2"))?);
            let mut dxm = Vec::with_capacity(n);
            for d in 0..n {
                let out = call(rt, "mlp_bwd", &[&st.xm[d], w1, b1, w2, b2, &d_pre2[d]])?;
                let [dxmlp, dw1, db1, dw2, db2]: [Tensor; 5] =
                    out.try_into().map_err(|_| anyhow::anyhow!("mlp_bwd arity"))?;
                ops::add_assign(grads.get_mut(&pf("w1"))?, &dw1)?;
                ops::add_assign(grads.get_mut(&pf("b1"))?, &db1)?;
                ops::add_assign(grads.get_mut(&pf("w2"))?, &dw2)?;
                ops::add_assign(grads.get_mut(&pf("b2"))?, &db2)?;
                dxm.push(call1(rt, "add", &[&d_pre2[d], &dxmlp])?); // residual join
            }
            // LN1
            let (g1, be1) = (p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?);
            let mut d_pre1 = Vec::with_capacity(n);
            for d in 0..n {
                let out = call(rt, "ln_bwd", &[&st.pre1[d], g1, be1, &dxm[d]])?;
                let [dp, dg, db]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow::anyhow!("ln_bwd arity"))?;
                ops::add_assign(grads.get_mut(&pf("ln1_g"))?, &dg)?;
                ops::add_assign(grads.get_mut(&pf("ln1_b"))?, &db)?;
                d_pre1.push(dp);
            }
            // attention out-projection
            let (wo, bo) = (p_of(&pf("wo"))?, p_of(&pf("bo"))?);
            let mut d_ctx = Vec::with_capacity(n);
            for d in 0..n {
                let flat = self.from_heads(&st.ctx[d])?;
                let out = call(rt, "linear_bwd", &[&flat, wo, bo, &d_pre1[d]])?;
                let [dflat, dwo, dbo]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow::anyhow!("linear_bwd arity"))?;
                ops::add_assign(grads.get_mut(&pf("wo"))?, &dwo)?;
                ops::add_assign(grads.get_mut(&pf("bo"))?, &dbo)?;
                d_ctx.push(self.to_heads(&dflat)?);
            }
            // RSA backward (the ring)
            let (dq, dk, dv) = self.rsa_backward(&d_ctx, &st.q, &st.p, &st.k, &st.v)?;
            // fused qkv backward (1 call, was 6) + residual join
            let (wq, wk, wv) = (p_of(&pf("wq"))?, p_of(&pf("wk"))?, p_of(&pf("wv"))?);
            let mut new_dx = Vec::with_capacity(n);
            for d in 0..n {
                let out = call(
                    rt,
                    "qkv_proj_bwd",
                    &[&st.x_in[d], wq, wk, wv, &dq[d], &dk[d], &dv[d]],
                )?;
                let [dxp, dwq, dbq, dwk, dbk, dwv, dbv]: [Tensor; 7] = out
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("qkv_proj_bwd arity"))?;
                for (gname, g) in [
                    ("wq", dwq), ("bq", dbq), ("wk", dwk),
                    ("bk", dbk), ("wv", dwv), ("bv", dbv),
                ] {
                    ops::add_assign(grads.get_mut(&pf(gname))?, &g)?;
                }
                let mut dx_d = d_pre1[d].clone();
                ops::add_assign(&mut dx_d, &dxp)?;
                new_dx.push(dx_d);
            }
            dx = new_dx;
        }

        // embeddings
        for d in 0..n {
            let out = call(rt, "embed_bwd", &[&ids_c[d], tok, &pos_c[d], &dx[d]])?;
            let [dtok, dpos]: [Tensor; 2] =
                out.try_into().map_err(|_| anyhow::anyhow!("embed_bwd arity"))?;
            ops::add_assign(grads.get_mut("tok_emb")?, &dtok)?;
            ops::add_into_dim0(grads.get_mut("pos_emb")?, &dpos, d * lc)?;
        }

        // Parameter-gradient reduction across the ring group: each device
        // computed grads from its own tokens; the sum is the global grad.
        // The sequential simulation already summed — meter the all-reduce
        // the real cluster would perform (ring: 2(n-1)/n * bytes).
        if n > 1 {
            let param_bytes: u64 = grads.values.values().map(|t| t.bytes() as u64).sum();
            self.fabric
                .meter
                .add(CommKind::AllReduce, 2 * (n as u64 - 1) * param_bytes / n as u64);
        }

        let _ = l; // (kept for symmetry with the python chain signature)
        Ok(StepOutput {
            loss: mlm_total + sop,
            mlm: mlm_total,
            sop,
            grads,
            hidden,
        })
    }
}
