//! Sequence parallelism with Ring Self-Attention — the paper's system.
//!
//! The input batch is chunked along the SEQUENCE dimension (`L/N` tokens
//! per device); every device holds the full parameter set and runs the
//! whole transformer on its own chunk.  How cross-chunk attention data
//! moves is the [`SpStrategy`] (`--sp ring|ulysses`): the default is
//! Ring Self-Attention (paper §3.1); the alternative replaces the ring
//! rotation with Ulysses-style all-to-alls ([`crate::attn::ulysses`]).
//! The ring schedule:
//!
//! * forward stage 1 — key chunks rotate around the ring N-1 times; each
//!   device accumulates its score rows `S^n ∈ R^{Lc×L}`;
//! * forward stage 2 — value chunks rotate; `O^n = Σᵢ SᵢⁿVᵢ` (Eq. 4);
//! * backward — value chunks rotate again (computing `dPᵢ` and carrying
//!   the `dVᵢ` accumulators home), then key chunks rotate (computing `dQ`
//!   and carrying `dKᵢ` home).  This is the "2 ring-P2P + gradient
//!   accumulation" schedule of §3.2.2.
//!
//! The per-rank step logic (`seqpar_step`) is written once against the
//! [`Collective`] rank-set view and executed two ways:
//!
//! * [`SeqParEngine`] drives it over the sequential [`Fabric`] slot view —
//!   all ranks simulated deterministically on the calling thread, rings as
//!   slot-vector rotations (the schedule is the exact transcription of the
//!   validated python chain `python/compile/chain.py`, tested against
//!   `jax.grad`);
//! * `exec::DistRunner` runs the SAME function on one OS thread per rank
//!   over `comm::threaded::RingComm`, so the ring exchanges are real
//!   concurrent P2P messages and the step is wall-clock parallel.
//!
//! Every exchange goes through the metered fabric either way, with
//! identical byte accounting.
//!
//! Ring convention: after `t` shifts device `d` holds the chunk originally
//! owned by `(d - t) mod n`.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::attn::{self, block::BlockPlan, AttnPattern, AttnStash};
use crate::comm::{Collective, Fabric};
use crate::model::params::ParamStore;
use crate::obs::mem;
use crate::runtime::{Executor, Manifest, Runtime};
use crate::tensor::{ops, Tensor};

use super::{call1_on, call_on, Batch, Engine, StepOutput};

/// Which sequence-parallel schedule moves the cross-chunk attention data
/// (`train --sp ring|ulysses`).  Both shard the batch along the sequence
/// dimension; they differ in HOW a rank sees the tokens it does not own:
///
/// * [`SpStrategy::Ring`] — the paper's Ring Self-Attention: K and V
///   chunks rotate around the ring every layer (and the hand-scheduled
///   backward rotates them again), so per-layer ring traffic grows with
///   the ring size (`(2(n−1) + (4n−2))·n` chunk-sends — see
///   `rust/tests/comm_volume.rs`);
/// * [`SpStrategy::Ulysses`] — DeepSpeed-Ulysses (Jacobs et al., 2023):
///   one [`Collective::all_to_all`] re-shards q/k/v from sequence-split
///   `[B, Z, L/n, A]` to head-split `[B, Z/n, L, A]`, each rank runs
///   full-sequence dense attention for its own head shard, and a second
///   all-to-all restores the sequence layout.  8 all-to-alls per layer
///   (q/k/v/ctx forward, their gradients backward) move `8(n−1)` chunk
///   equivalents in total — flat in `n` where the ring grows linearly.
///   Requires `n` to divide the head count (whole heads are sharded,
///   mirroring Megatron's §4.2 tensor-parallel cap) and composes with
///   dense attention only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpStrategy {
    /// Ring Self-Attention (the paper's §3 schedule) — the default.
    Ring,
    /// DeepSpeed-Ulysses head-shard all-to-alls.
    Ulysses,
}

impl SpStrategy {
    /// Parse the CLI surface: `ring | ulysses`.
    pub fn parse(s: &str) -> Result<SpStrategy> {
        match s {
            "ring" => Ok(SpStrategy::Ring),
            "ulysses" => Ok(SpStrategy::Ulysses),
            other => bail!("unknown --sp {other:?} (ring | ulysses)"),
        }
    }

    /// The CLI spelling of this strategy.
    pub fn label(&self) -> &'static str {
        match self {
            SpStrategy::Ring => "ring",
            SpStrategy::Ulysses => "ulysses",
        }
    }

    pub fn is_ring(&self) -> bool {
        matches!(self, SpStrategy::Ring)
    }
}

/// Run-shape constants + size-suffixed step names + the attention pattern,
/// derived once from the manifest and shared by every rank (sequential or
/// threaded).
#[derive(Clone, Debug)]
pub(crate) struct StepShape {
    pub n: usize,
    pub b: usize,
    pub lc: usize,
    pub layers: usize,
    pub to_heads_step: String,
    pub qkv_step: String,
    /// Which attention the step executes (see [`crate::attn`]).
    pub pattern: AttnPattern,
    /// How cross-chunk attention data moves (ring rotation vs Ulysses
    /// all-to-alls); validated against the manifest at construction.
    pub sp: SpStrategy,
    /// Precomputed reachability/mask plan (Block pattern only); Arc'd so
    /// every rank thread shares the one set of mask tensors.
    pub plan: Option<Arc<BlockPlan>>,
    /// Double-buffer the dense ring loops: post the shift of chunk t+1
    /// before computing on chunk t, wait after (`Collective::
    /// ring_shift_post` / `ring_shift_wait`).  Byte- and
    /// schedule-identical to the blocking ring — under the sequential
    /// [`Fabric`] the post is eager, under the threaded `RingComm` the
    /// recv is deferred so the hop hides behind the kernels.
    pub overlap: bool,
}

impl StepShape {
    /// Build the shape for a specific attention pattern and SP strategy,
    /// validating that the manifest was lowered with the matching kernels
    /// registered (and, for Ulysses, that the ring divides the heads).
    pub(crate) fn from_manifest_sp(
        m: &Manifest,
        pattern: AttnPattern,
        sp: SpStrategy,
    ) -> Result<StepShape> {
        let n = m.ring;
        if sp == SpStrategy::Ulysses {
            if !pattern.is_dense() {
                bail!(
                    "--sp ulysses composes with --attn dense only (got --attn {}); \
                     the sparse patterns run under the ring strategy",
                    pattern.label()
                );
            }
            if m.heads % n != 0 {
                // mirror of the Megatron §4.2 tp-over-heads cap: the
                // all-to-all shards whole attention heads across the ring
                bail!(
                    "ulysses sequence parallelism size {n} must divide the head count {} \
                     (the all-to-all shards whole attention heads)",
                    m.heads
                );
            }
            if n > 1 && !m.ulysses {
                bail!(
                    "manifest was lowered without the Ulysses head-shard kernels; \
                     rebuild the backend with --sp ulysses"
                );
            }
        }
        if m.seq_len % n != 0 {
            bail!("seq_len {} not divisible by ring size {n}", m.seq_len);
        }
        let lc = m.seq_len / n;
        let plan = match pattern {
            AttnPattern::Dense => None,
            AttnPattern::Linformer { k } => {
                if m.linformer_k != k {
                    bail!(
                        "manifest was lowered with linformer_k={}, engine asked for linformer:{k} \
                         (set --linformer/--attn consistently so the projection kernels exist)",
                        m.linformer_k
                    );
                }
                None
            }
            AttnPattern::Block { w } => {
                if m.block_w != w {
                    bail!(
                        "manifest was lowered with block_w={}, engine asked for block:{w} \
                         (set --attn when building the backend so the masked kernels exist)",
                        m.block_w
                    );
                }
                Some(Arc::new(BlockPlan::new(n, lc, w)))
            }
        };
        Ok(StepShape {
            n,
            b: m.batch,
            lc,
            layers: m.layers,
            to_heads_step: format!("to_heads_b{}", m.batch),
            qkv_step: format!("qkv_proj_b{}", m.batch),
            pattern,
            sp,
            plan,
            overlap: false,
        })
    }
}

/// What one collective view produces for the ranks it executes: the
/// sequential [`Fabric`] view yields the whole group's output; a threaded
/// per-rank view yields that rank's share (loss partials, its hidden
/// chunk) plus the globally all-reduced gradients.
pub(crate) struct RankOutput {
    /// MLM loss contribution of the executed ranks' tokens.
    pub mlm: f32,
    /// SOP loss (non-zero only on the view that executes rank 0).
    pub sop: f32,
    /// Final hidden states, one chunk per executed rank.
    pub hidden: Vec<Tensor>,
    /// Parameter gradients AFTER the cross-ring all-reduce, in global
    /// layout.  Every rank holds the same sums up to f32 reduction-order
    /// rounding (the threaded ring accumulates in per-rank arrival
    /// order); each rank's own copy is bit-deterministic.
    pub grads: ParamStore,
}

/// Per-layer forward activations stashed for the backward pass (one entry
/// per executed rank).  This is exactly the paper's activation memory:
/// note there is NO stash of remote K/V chunks — they are re-circulated in
/// backward, which is what makes the scheme memory-efficient.  Under
/// pipeline parallelism (`exec::mesh`) each stage holds one of these per
/// layer per in-flight microbatch — the GPipe activation profile.
/// Under the Ulysses strategy `q`/`k`/`v` are left EMPTY — the head-shard
/// copies live in the `AttnStash` instead (one copy either way).
pub(crate) struct LayerStash {
    pub(crate) x_in: Vec<Tensor>,
    pub(crate) q: Vec<Tensor>,
    pub(crate) k: Vec<Tensor>,
    pub(crate) v: Vec<Tensor>,
    pub(crate) attn: AttnStash, // pattern-specific stash (probs, projected K̃/Ṽ)
    pub(crate) ctx: Vec<Tensor>, // attention context [B, Z, Lc, A]
    pub(crate) pre1: Vec<Tensor>, // x + attn (LN1 input)
    pub(crate) xm: Vec<Tensor>,  // LN1 output
    pub(crate) pre2: Vec<Tensor>, // xm + mlp (LN2 input)
    // NOTE: the MLP hidden activation is NOT stashed — mlp_bwd
    // rematerializes it (§Perf iteration 2), matching Megatron's recompute.
    /// Per-rank residency charges (`obs::mem`) covering exactly the
    /// tensors above; releasing the stash releases the accounted bytes.
    pub(crate) _charges: Vec<mem::Charge>,
}

/// Embedding forward for the executed `ranks`: token + per-chunk position
/// embeddings over each rank's sequence chunk.  This is pipeline stage 0
/// (or the whole model when there is no pipeline).
pub(crate) fn sp_embed_fwd(
    ex: &dyn Executor,
    sh: &StepShape,
    params: &ParamStore,
    batch: &Batch,
    ranks: &[usize],
) -> Result<Vec<Tensor>> {
    let ids_c = ops::chunk_dim1(&batch.ids, sh.n)?;
    let tok = params.get("tok_emb")?;
    let pos = params.get("pos_emb")?;
    ranks
        .iter()
        .map(|&d| {
            let pos_d = ops::slice_dim0(pos, d * sh.lc, (d + 1) * sh.lc)?;
            call1_on(ex, "embed_fwd", &[&ids_c[d], tok, &pos_d])
        })
        .collect()
}

/// One transformer layer forward for the executed ranks.  Consumes the
/// layer input (it moves into the returned stash) and yields the next
/// activation.
#[allow(clippy::needless_range_loop)] // loops index several rank-parallel vecs
pub(crate) fn sp_layer_fwd(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    layer: usize,
    x: Vec<Tensor>,
) -> Result<(Vec<Tensor>, LayerStash)> {
    let ln = x.len();
    let p_of = |name: &str| params.get(name);
    let pf = |s: &str| format!("layer{layer}.{s}");
    let (wq, bq) = (p_of(&pf("wq"))?, p_of(&pf("bq"))?);
    let (wk, bk) = (p_of(&pf("wk"))?, p_of(&pf("bk"))?);
    let (wv, bv) = (p_of(&pf("wv"))?, p_of(&pf("bv"))?);
    let mut q = Vec::new();
    let mut k = Vec::new();
    let mut v = Vec::new();
    for li in 0..ln {
        // fused QKV projection + head split (1 call, was 6)
        let out = call_on(ex, &sh.qkv_step, &[&x[li], wq, bq, wk, bk, wv, bv])?;
        let [qd, kd, vd]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow::anyhow!("qkv_proj arity"))?;
        q.push(qd);
        k.push(kd);
        v.push(vd);
    }
    let (ctx, astash) = attn::forward_on(ex, view, sh, params, &q, &k, &v)?;
    if !sh.sp.is_ring() {
        // Ulysses already stashed the head-shard q/k/v inside its
        // AttnStash (its backward never touches the sequence layout);
        // keeping both copies would double the dominant activation term.
        q = Vec::new();
        k = Vec::new();
        v = Vec::new();
    }
    let (wo, bo) = (p_of(&pf("wo"))?, p_of(&pf("bo"))?);
    let (g1, be1) = (p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?);
    let mut pre1 = Vec::new();
    let mut xm = Vec::new();
    for li in 0..ln {
        let flat = call1_on(ex, "from_heads", &[&ctx[li]])?;
        let attn = call1_on(ex, "linear_fwd", &[&flat, wo, bo])?;
        // fused residual-add + LayerNorm (also returns the pre-LN
        // sum, the same stash the unfused path kept)
        let out = call_on(ex, "add_ln_fwd", &[&x[li], &attn, g1, be1])?;
        let [y, pre]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow::anyhow!("add_ln arity"))?;
        xm.push(y);
        pre1.push(pre);
    }
    let (w1, b1) = (p_of(&pf("w1"))?, p_of(&pf("b1"))?);
    let (w2, b2) = (p_of(&pf("w2"))?, p_of(&pf("b2"))?);
    let (g2, be2) = (p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?);
    let mut pre2 = Vec::new();
    let mut x_next = Vec::new();
    for li in 0..ln {
        // fused MLP block (hidden activation rematerialized in bwd)
        let m2 = call1_on(ex, "mlp_fwd", &[&xm[li], w1, b1, w2, b2])?;
        let out = call_on(ex, "add_ln_fwd", &[&xm[li], &m2, g2, be2])?;
        let [y, pre]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow::anyhow!("add_ln arity"))?;
        x_next.push(y);
        pre2.push(pre);
    }
    // Residency charges for everything this stash keeps alive, attributed
    // to the executed rank that owns each chunk: the residual-chain
    // activations (x_in/pre1/xm/pre2 — the closed form's `4·tok·h` per
    // layer) and the attention stash (q/k/v/ctx plus the pattern-specific
    // probs; under Ulysses q/k/v are empty and the head-shard copies in
    // the AttnStash carry the same bytes).
    let ranks = view.local_ranks();
    let mut charges = Vec::with_capacity(2 * ln);
    for li in 0..ln {
        let d = ranks[li];
        let act = x[li].bytes() + pre1[li].bytes() + xm[li].bytes() + pre2[li].bytes();
        let qkv: usize =
            [&q, &k, &v].iter().map(|t| t.get(li).map_or(0, |c| c.bytes())).sum();
        let stash_b = qkv + ctx[li].bytes() + astash.bytes_at(li);
        charges.push(mem::Charge::new(d, mem::Category::Activation, act as u64));
        charges.push(mem::Charge::new(d, mem::Category::AttnStash, stash_b as u64));
    }
    Ok((x_next, LayerStash { x_in: x, q, k, v, attn: astash, ctx, pre1, xm, pre2, _charges: charges }))
}

/// MLM + SOP heads: loss forward and the head backward, producing the
/// gradient w.r.t. the final hidden states.  Last pipeline stage only.
/// Returns `(mlm, sop, dx)`: the executed ranks' MLM loss share, the SOP
/// loss (non-zero only on the view that executes ring rank 0, which owns
/// every sequence's CLS token), and dx per executed rank.
#[allow(clippy::needless_range_loop)]
pub(crate) fn sp_heads_fwd_bwd(
    ex: &dyn Executor,
    sh: &StepShape,
    params: &ParamStore,
    batch: &Batch,
    x: &[Tensor],
    ranks: &[usize],
    grads: &mut [ParamStore],
) -> Result<(f32, f32, Vec<Tensor>)> {
    let (n, b, lc) = (sh.n, sh.b, sh.lc);
    let ln = ranks.len();
    let p_of = |name: &str| params.get(name);
    let labels_c: Vec<Tensor> = ops::chunk_dim1(&batch.labels, n)?
        .into_iter()
        .map(|t| t.reshaped(&[b * lc]))
        .collect::<Result<_>>()?;
    let mask_c: Vec<Tensor> = ops::chunk_dim1(&batch.mask, n)?
        .into_iter()
        .map(|t| t.reshaped(&[b * lc]))
        .collect::<Result<_>>()?;
    let (mlm_w, mlm_b) = (p_of("mlm_w")?, p_of("mlm_b")?);
    let mut mlm_total = 0.0f32;
    let mut dx: Vec<Tensor> = Vec::with_capacity(ln);
    for li in 0..ln {
        let d = ranks[li];
        let out = call_on(ex, "mlm_loss", &[&x[li], mlm_w, mlm_b, &labels_c[d], &mask_c[d]])?;
        let [lo, dxd, dw, db]: [Tensor; 4] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("mlm_loss arity"))?;
        mlm_total += lo.scalar_f32()?;
        dx.push(dxd);
        ops::add_assign(grads[li].get_mut("mlm_w")?, &dw)?;
        ops::add_assign(grads[li].get_mut("mlm_b")?, &db)?;
    }
    // SOP head lives on rank 0 (it owns every sequence's CLS token).
    let mut sop = 0.0f32;
    if let Some(li0) = ranks.iter().position(|&d| d == 0) {
        let (sop_w, sop_b) = (p_of("sop_w")?, p_of("sop_b")?);
        let out = call_on(ex, "sop_loss", &[&x[li0], sop_w, sop_b, &batch.sop_labels])?;
        let [sop_lo, dx0, dsw, dsb]: [Tensor; 4] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("sop_loss arity"))?;
        sop = sop_lo.scalar_f32()?;
        ops::add_assign(&mut dx[li0], &dx0)?;
        ops::add_assign(grads[li0].get_mut("sop_w")?, &dsw)?;
        ops::add_assign(grads[li0].get_mut("sop_b")?, &dsb)?;
    }
    Ok((mlm_total, sop, dx))
}

/// One transformer layer backward for the executed ranks; `dx` is the
/// gradient flowing into this layer's OUTPUT, the return value the
/// gradient at its input.
#[allow(clippy::needless_range_loop)]
pub(crate) fn sp_layer_bwd(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    layer: usize,
    st: &LayerStash,
    dx: &[Tensor],
    grads: &mut [ParamStore],
) -> Result<Vec<Tensor>> {
    let ln = dx.len();
    let p_of = |name: &str| params.get(name);
    let pf = |s: &str| format!("layer{layer}.{s}");
    // LN2
    let (g2, be2) = (p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?);
    let mut d_pre2 = Vec::with_capacity(ln);
    for li in 0..ln {
        let out = call_on(ex, "ln_bwd", &[&st.pre2[li], g2, be2, &dx[li]])?;
        let [dp, dg, db]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow::anyhow!("ln_bwd arity"))?;
        ops::add_assign(grads[li].get_mut(&pf("ln2_g"))?, &dg)?;
        ops::add_assign(grads[li].get_mut(&pf("ln2_b"))?, &db)?;
        d_pre2.push(dp);
    }
    // MLP (fused bwd: rematerializes the hidden activation inside)
    let (w1, b1) = (p_of(&pf("w1"))?, p_of(&pf("b1"))?);
    let (w2, b2) = (p_of(&pf("w2"))?, p_of(&pf("b2"))?);
    let mut dxm = Vec::with_capacity(ln);
    for li in 0..ln {
        let out = call_on(ex, "mlp_bwd", &[&st.xm[li], w1, b1, w2, b2, &d_pre2[li]])?;
        let [dxmlp, dw1, db1, dw2, db2]: [Tensor; 5] =
            out.try_into().map_err(|_| anyhow::anyhow!("mlp_bwd arity"))?;
        ops::add_assign(grads[li].get_mut(&pf("w1"))?, &dw1)?;
        ops::add_assign(grads[li].get_mut(&pf("b1"))?, &db1)?;
        ops::add_assign(grads[li].get_mut(&pf("w2"))?, &dw2)?;
        ops::add_assign(grads[li].get_mut(&pf("b2"))?, &db2)?;
        dxm.push(call1_on(ex, "add", &[&d_pre2[li], &dxmlp])?); // residual join
    }
    // LN1
    let (g1, be1) = (p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?);
    let mut d_pre1 = Vec::with_capacity(ln);
    for li in 0..ln {
        let out = call_on(ex, "ln_bwd", &[&st.pre1[li], g1, be1, &dxm[li]])?;
        let [dp, dg, db]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow::anyhow!("ln_bwd arity"))?;
        ops::add_assign(grads[li].get_mut(&pf("ln1_g"))?, &dg)?;
        ops::add_assign(grads[li].get_mut(&pf("ln1_b"))?, &db)?;
        d_pre1.push(dp);
    }
    // attention out-projection
    let (wo, bo) = (p_of(&pf("wo"))?, p_of(&pf("bo"))?);
    let mut d_ctx = Vec::with_capacity(ln);
    for li in 0..ln {
        let flat = call1_on(ex, "from_heads", &[&st.ctx[li]])?;
        let out = call_on(ex, "linear_bwd", &[&flat, wo, bo, &d_pre1[li]])?;
        let [dflat, dwo, dbo]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow::anyhow!("linear_bwd arity"))?;
        ops::add_assign(grads[li].get_mut(&pf("wo"))?, &dwo)?;
        ops::add_assign(grads[li].get_mut(&pf("bo"))?, &dbo)?;
        d_ctx.push(call1_on(ex, &sh.to_heads_step, &[&dflat])?);
    }
    // attention backward (ring / projected / masked, per pattern)
    let (dq, dk, dv) = attn::backward_on(
        ex, view, sh, params, &st.attn, &d_ctx, &st.q, &st.k, &st.v, grads,
    )?;
    // fused qkv backward (1 call, was 6) + residual join
    let (wq, wk, wv) = (p_of(&pf("wq"))?, p_of(&pf("wk"))?, p_of(&pf("wv"))?);
    let mut new_dx = Vec::with_capacity(ln);
    for li in 0..ln {
        let out = call_on(
            ex,
            "qkv_proj_bwd",
            &[&st.x_in[li], wq, wk, wv, &dq[li], &dk[li], &dv[li]],
        )?;
        let [dxp, dwq, dbq, dwk, dbk, dwv, dbv]: [Tensor; 7] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("qkv_proj_bwd arity"))?;
        for (gname, g) in [
            ("wq", dwq), ("bq", dbq), ("wk", dwk),
            ("bk", dbk), ("wv", dwv), ("bv", dbv),
        ] {
            ops::add_assign(grads[li].get_mut(&pf(gname))?, &g)?;
        }
        let mut dx_d = d_pre1[li].clone();
        ops::add_assign(&mut dx_d, &dxp)?;
        new_dx.push(dx_d);
    }
    Ok(new_dx)
}

/// Embedding backward for the executed ranks (pipeline stage 0).
#[allow(clippy::needless_range_loop)]
pub(crate) fn sp_embed_bwd(
    ex: &dyn Executor,
    sh: &StepShape,
    params: &ParamStore,
    batch: &Batch,
    dx: &[Tensor],
    ranks: &[usize],
    grads: &mut [ParamStore],
) -> Result<()> {
    let ids_c = ops::chunk_dim1(&batch.ids, sh.n)?;
    let tok = params.get("tok_emb")?;
    let pos = params.get("pos_emb")?;
    for li in 0..ranks.len() {
        let d = ranks[li];
        let pos_d = ops::slice_dim0(pos, d * sh.lc, (d + 1) * sh.lc)?;
        let out = call_on(ex, "embed_bwd", &[&ids_c[d], tok, &pos_d, &dx[li]])?;
        let [dtok, dpos]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow::anyhow!("embed_bwd arity"))?;
        ops::add_assign(grads[li].get_mut("tok_emb")?, &dtok)?;
        ops::add_into_dim0(grads[li].get_mut("pos_emb")?, &dpos, d * sh.lc)?;
    }
    Ok(())
}

/// One full forward+backward step of the sequence-parallel transformer,
/// executed for the ranks of `view`.  This is the function every rank
/// runs — sequentially simulated under the [`Fabric`] slot view, or on
/// its own OS thread under a `RingComm` per-rank view — and it finishes
/// with the cross-ring gradient all-reduce, so the returned grads are the
/// global sums on every rank.
///
/// The body is the pipeline-free composition of the per-stage segments
/// ([`sp_embed_fwd`] → [`sp_layer_fwd`]* → [`sp_heads_fwd_bwd`] →
/// [`sp_layer_bwd`]* → [`sp_embed_bwd`]); `exec::mesh` runs the SAME
/// segments split across GPipe pipeline stages.
pub(crate) fn seqpar_step(
    ex: &dyn Executor,
    view: &dyn Collective,
    sh: &StepShape,
    params: &ParamStore,
    batch: &Batch,
) -> Result<RankOutput> {
    let ranks = view.local_ranks();
    let ln = ranks.len();

    // Every rank holds the full replicated parameter set for the whole
    // step (the sequence-parallel memory trade the paper's Table 2 makes).
    let _param_charges: Vec<mem::Charge> = ranks
        .iter()
        .map(|&d| mem::Charge::new(d, mem::Category::Params, params.total_bytes() as u64))
        .collect();

    // ---- forward ----------------------------------------------------
    let sp = crate::obs::begin();
    let mut x = sp_embed_fwd(ex, sh, params, batch, &ranks)?;
    sp.end_phase("sp_embed_fwd");
    let mut stashes: Vec<LayerStash> = Vec::with_capacity(sh.layers);
    for layer in 0..sh.layers {
        let sp = crate::obs::begin();
        let (x_next, st) = sp_layer_fwd(ex, view, sh, params, layer, x)?;
        sp.end_phase_idx("sp_layer_fwd", layer);
        x = x_next;
        stashes.push(st);
    }

    // ---- losses -------------------------------------------------------
    // Every executed rank accumulates into its OWN grad store; the
    // cross-ring all-reduce at the bottom combines them.  Under the
    // sequential view this deliberately holds all n stores at once — the
    // same per-rank gradient memory the real device group holds — where
    // the old engine shortcut summed into one store and only metered.
    let mut grads: Vec<ParamStore> = (0..ln).map(|_| params.zeros_like()).collect();
    let _grad_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::Grads, grads[li].total_bytes() as u64))
        .collect();
    let sp = crate::obs::begin();
    let (mlm_total, sop, mut dx) =
        sp_heads_fwd_bwd(ex, sh, params, batch, &x, &ranks, &mut grads)?;
    sp.end_phase("sp_heads_fwd_bwd");

    let hidden = x;

    // ---- backward ------------------------------------------------------
    for layer in (0..sh.layers).rev() {
        let sp = crate::obs::begin();
        dx = sp_layer_bwd(ex, view, sh, params, layer, &stashes[layer], &dx, &mut grads)?;
        sp.end_phase_idx("sp_layer_bwd", layer);
    }
    let sp = crate::obs::begin();
    sp_embed_bwd(ex, sh, params, batch, &dx, &ranks, &mut grads)?;
    sp.end_phase("sp_embed_bwd");

    // Parameter-gradient all-reduce across the ring group: each rank
    // computed grads from its own tokens; after the reduce every rank
    // holds the global sum, ready for the optimizer.  Metered on the
    // canonical ring formula — 2(n-1)·C total per tensor, the same group
    // accounting Fabric and RingComm share (rust/tests/comm_volume.rs).
    if sh.n > 1 {
        let sp = crate::obs::begin();
        let names: Vec<String> = grads[0].values.keys().cloned().collect();
        super::allreduce_named(view, &mut grads, &names)?;
        sp.end_phase("grad_allreduce");
    }

    Ok(RankOutput {
        mlm: mlm_total,
        sop,
        hidden,
        grads: grads.swap_remove(0),
    })
}

/// The sequential sequence-parallel engine: simulates all `n` ring ranks
/// deterministically on the calling thread over the [`Fabric`] slot view.
/// (For genuinely concurrent ranks over the same step logic, see
/// `exec::DistRunner`.)
pub struct SeqParEngine<'rt> {
    rt: &'rt Runtime,
    pub fabric: Fabric,
    pub n: usize,
    shape: StepShape,
}

impl<'rt> SeqParEngine<'rt> {
    pub fn new(rt: &'rt Runtime, fabric: Fabric) -> Result<SeqParEngine<'rt>> {
        SeqParEngine::with_pattern(rt, fabric, AttnPattern::Dense)
    }

    /// Build the engine with a specific attention pattern (`--attn` on
    /// the CLI) under the default ring schedule; the manifest must have
    /// been lowered with the matching kernels (linformer_k / block_w).
    pub fn with_pattern(
        rt: &'rt Runtime,
        fabric: Fabric,
        pattern: AttnPattern,
    ) -> Result<SeqParEngine<'rt>> {
        SeqParEngine::with_strategy(rt, fabric, pattern, SpStrategy::Ring)
    }

    /// Build the engine with an explicit attention pattern AND
    /// sequence-parallel strategy (`--attn` / `--sp` on the CLI).
    /// [`SpStrategy::Ulysses`] requires a dense pattern, a manifest
    /// lowered with the head-shard kernels, and `n | heads`.
    pub fn with_strategy(
        rt: &'rt Runtime,
        fabric: Fabric,
        pattern: AttnPattern,
        sp: SpStrategy,
    ) -> Result<SeqParEngine<'rt>> {
        let m = rt.manifest();
        let n = fabric.n;
        if m.ring != n {
            bail!(
                "artifacts were lowered for ring={}, engine asked for {n}; re-run `make artifacts`",
                m.ring
            );
        }
        let shape = StepShape::from_manifest_sp(m, pattern, sp)?;
        Ok(SeqParEngine { rt, fabric, n, shape })
    }

    /// Enable/disable comm/compute overlap in the dense ring loops
    /// (`--overlap`).  The sequential engine's posts resolve eagerly, so
    /// this is a semantic no-op here — it exists so the flag reaches the
    /// SAME `StepShape` the threaded runner uses and the two executions
    /// stay schedule- and meter-identical.
    pub fn overlap(mut self, on: bool) -> Self {
        self.shape.overlap = on;
        self
    }

    /// The attention pattern this engine executes.
    pub fn pattern(&self) -> AttnPattern {
        self.shape.pattern
    }

    /// The sequence-parallel strategy this engine executes.
    pub fn strategy(&self) -> SpStrategy {
        self.shape.sp
    }

    /// Public API: dense Ring Self-Attention over pre-chunked q/k/v.
    ///
    /// `q/k/v[d]` are device d's local `[B, Z, L/N, A]` chunks; returns the
    /// per-device attention outputs.  This is the paper's Eq. 4 surface —
    /// what a downstream user embeds into their own model code.  (Always
    /// the dense ring regardless of the engine's training pattern; the
    /// sparse patterns are driven through `forward_backward`.)
    pub fn rsa_attention(
        &self,
        q: &[Tensor],
        k: &[Tensor],
        v: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if q.len() != self.n || k.len() != self.n || v.len() != self.n {
            bail!("rsa_attention: need {} chunks, got {}/{}/{}", self.n, q.len(), k.len(), v.len());
        }
        Ok(attn::dense::rsa_forward_on(self.rt.backend(), &self.fabric, &self.shape, q, k, v)?.0)
    }
}

impl<'rt> Engine for SeqParEngine<'rt> {
    fn name(&self) -> &'static str {
        "sequence-parallel"
    }

    fn group_size(&self) -> usize {
        self.n
    }

    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        let out = seqpar_step(self.rt.backend(), &self.fabric, &self.shape, params, batch)?;
        Ok(StepOutput {
            loss: out.mlm + out.sop,
            mlm: out.mlm,
            sop: out.sop,
            grads: out.grads,
            hidden: out.hidden,
        })
    }
}
