//! Tensor parallelism — the Megatron-LM baseline (paper §2, Eq. 3).
//!
//! Attention heads and MLP columns are split across the group; every
//! device holds the FULL sequence.  Communication: one all-reduce after
//! each block's second GEMM in forward, and one at each block's input in
//! backward (the conjugate f/g operators).
//!
//! Schedule transcription of `python/compile/chain.py::
//! tensorpar_forward_backward` (validated against `jax.grad`).  Weight
//! shards are sliced host-side from the global parameter store; gradient
//! shards are scattered back into global layout, so the optimizer and the
//! convergence comparison (Fig. 6) see identical parameter state across
//! engines.
//!
//! Like the sequence engine, the per-rank step logic is written once
//! against the [`Collective`] rank-set view as per-stage segments
//! (`tp_embed_fwd` → `tp_layer_fwd`* → `tp_heads_fwd_bwd` →
//! `tp_layer_bwd`* → `tp_embed_bwd`) and executed two ways: the
//! sequential [`Fabric`] slot view ([`TensorParEngine`], all ranks on the
//! calling thread) and the threaded per-rank view (`exec::mesh`, one OS
//! thread per mesh coordinate, where the segments are additionally split
//! across GPipe pipeline stages).
//!
//! Replicated computations (embeddings, LayerNorms, heads) produce
//! identical values on every rank, so only the rank-0 copy of their
//! parameter gradients is accumulated — the per-rank gradient stores sum
//! exactly (shards are disjoint, replicated entries appear once) to the
//! global gradient, with no extra collective, matching Megatron.

use anyhow::{anyhow, bail, Result};

use crate::comm::{Collective, Fabric};
use crate::model::params::ParamStore;
use crate::obs::mem;
use crate::parallel::{call1_on, call_on};
use crate::runtime::{Executor, Manifest, Runtime};
use crate::tensor::{ops, Tensor};

use super::{Batch, Engine, StepOutput};

/// Run-shape constants for the tensor-parallel step, derived once from
/// the manifest and shared by every rank (sequential or threaded).
#[derive(Clone, Debug)]
pub(crate) struct TpShape {
    pub t: usize, // TP degree
    pub b: usize,
    pub l: usize,
    pub layers: usize,
    pub hidden: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub heads: usize,
    pub to_heads_step: String,
}

impl TpShape {
    /// `t == 1` is the serial engine (no splitting, no communication).
    pub(crate) fn from_manifest(m: &Manifest, t: usize) -> Result<TpShape> {
        if t == 0 {
            bail!("tensor parallelism needs t >= 1");
        }
        if m.heads % t != 0 {
            // This is exactly Megatron's scaling cap the paper exploits
            // (tensor parallel size <= number of attention heads).
            bail!(
                "tensor parallelism size {t} must divide the head count {} \
                 (Megatron's limit — paper §4.2)",
                m.heads
            );
        }
        if m.ffn % t != 0 {
            bail!("TP size {t} must divide FFN width {}", m.ffn);
        }
        if t != 1 && t != m.tp {
            bail!(
                "artifacts were lowered for tp={} (and serial tp=1); got {t}",
                m.tp
            );
        }
        Ok(TpShape {
            t,
            b: m.batch,
            l: m.seq_len,
            layers: m.layers,
            hidden: m.hidden,
            head_dim: m.head_dim,
            ffn: m.ffn,
            heads: m.heads,
            to_heads_step: format!("to_heads_b{}", m.batch),
        })
    }

    fn zp(&self) -> usize {
        self.heads / self.t
    }

    fn fp(&self) -> usize {
        self.ffn / self.t
    }

    /// Column range of rank `d` in the head-split projections.
    fn head_cols(&self, d: usize) -> (usize, usize) {
        let w = self.zp() * self.head_dim;
        (d * w, (d + 1) * w)
    }

    fn ffn_cols(&self, d: usize) -> (usize, usize) {
        (d * self.fp(), (d + 1) * self.fp())
    }
}

/// Per-layer forward activations for the backward pass.  Replicated
/// activations (identical on every rank) are stashed ONCE per view; the
/// per-rank vectors hold only the genuinely sharded tensors, one entry
/// per executed rank.
pub(crate) struct TpLayerStash {
    x_in: Tensor, // replicated layer input
    q: Vec<Tensor>, // per-rank head shards
    k: Vec<Tensor>,
    v: Vec<Tensor>,
    p: Vec<Tensor>,
    ctx: Vec<Tensor>,
    pre1: Tensor,
    xm: Tensor,
    h: Vec<Tensor>, // per-rank FFN shard activations
    pre2: Tensor,
    /// Per-rank residency charges (`obs::mem`): each device holds its own
    /// copy of the replicated tensors plus its head/FFN shards.
    _charges: Vec<mem::Charge>,
}

/// Embedding forward: replicated — every rank holds the same
/// full-sequence activation (pipeline stage 0), so it is represented
/// (and computed) ONCE per view: under the sequential slot view the
/// ranks' copies would be bit-identical anyway, and a threaded per-rank
/// view executes exactly one rank.
pub(crate) fn tp_embed_fwd(
    ex: &dyn Executor,
    tsh: &TpShape,
    params: &ParamStore,
    batch: &Batch,
) -> Result<Tensor> {
    let pos = ops::slice_dim0(params.get("pos_emb")?, 0, tsh.l)?;
    let tok = params.get("tok_emb")?;
    call1_on(ex, "embed_fwd", &[&batch.ids, tok, &pos])
}

/// One transformer layer forward for the executed ranks: each rank runs
/// its head/FFN shard, partial outputs are combined by the two ring
/// all-reduces of Megatron's g operator.
#[allow(clippy::needless_range_loop)] // loops index several rank-parallel vecs
pub(crate) fn tp_layer_fwd(
    ex: &dyn Executor,
    view: &dyn Collective,
    tsh: &TpShape,
    params: &ParamStore,
    layer: usize,
    x: Tensor,
) -> Result<(Tensor, TpLayerStash)> {
    let ranks = view.local_ranks();
    let ln = ranks.len();
    let p_of = |name: &str| params.get(name);
    let pf = |s: &str| format!("layer{layer}.{s}");
    let zero_h = Tensor::zeros(&[tsh.hidden]);

    let mut q = Vec::new();
    let mut k = Vec::new();
    let mut v = Vec::new();
    let mut p = Vec::new();
    let mut ctx = Vec::new();
    let mut partial = Vec::new();
    for li in 0..ln {
        let d = ranks[li];
        let (lo, hi) = tsh.head_cols(d);
        let wq = ops::slice_last(p_of(&pf("wq"))?, lo, hi)?;
        let bq = ops::slice_dim0(p_of(&pf("bq"))?, lo, hi)?;
        let wk = ops::slice_last(p_of(&pf("wk"))?, lo, hi)?;
        let bk = ops::slice_dim0(p_of(&pf("bk"))?, lo, hi)?;
        let wv = ops::slice_last(p_of(&pf("wv"))?, lo, hi)?;
        let bv = ops::slice_dim0(p_of(&pf("bv"))?, lo, hi)?;
        let qd = call1_on(ex, &tsh.to_heads_step, &[&call1_on(ex, "linear_fwd", &[&x, &wq, &bq])?])?;
        let kd = call1_on(ex, &tsh.to_heads_step, &[&call1_on(ex, "linear_fwd", &[&x, &wk, &bk])?])?;
        let vd = call1_on(ex, &tsh.to_heads_step, &[&call1_on(ex, "linear_fwd", &[&x, &wv, &bv])?])?;
        let s = call1_on(ex, "scores_step", &[&qd, &kd])?;
        let pd = call1_on(ex, "softmax_fwd", &[&s])?;
        let acc0 = Tensor::zeros(&qd.shape);
        let cd = call1_on(ex, "av_step", &[&pd, &vd, &acc0])?;
        let wo = ops::slice_dim0(p_of(&pf("wo"))?, lo, hi)?;
        let flat = call1_on(ex, "from_heads", &[&cd])?;
        partial.push(call1_on(ex, "linear_fwd", &[&flat, &wo, &zero_h])?);
        q.push(qd);
        k.push(kd);
        v.push(vd);
        p.push(pd);
        ctx.push(cd);
    }
    // all-reduce the row-split output projection partials (g op)
    view.all_reduce_sum(&mut partial)?;
    // replicated epilogue, computed once per view (see tp_embed_fwd)
    let attn = call1_on(ex, "bias_add", &[&partial[0], p_of(&pf("bo"))?])?;
    let pre1 = call1_on(ex, "add", &[&x, &attn])?;
    let xm = call1_on(ex, "ln_fwd", &[&pre1, p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?])?;
    let mut hs = Vec::new();
    let mut partial2 = Vec::new();
    for li in 0..ln {
        let d = ranks[li];
        let (lo, hi) = tsh.ffn_cols(d);
        let w1 = ops::slice_last(p_of(&pf("w1"))?, lo, hi)?;
        let b1 = ops::slice_dim0(p_of(&pf("b1"))?, lo, hi)?;
        let hd = call1_on(ex, "gelu_linear_fwd", &[&xm, &w1, &b1])?;
        let w2 = ops::slice_dim0(p_of(&pf("w2"))?, lo, hi)?;
        partial2.push(call1_on(ex, "linear_fwd", &[&hd, &w2, &zero_h])?);
        hs.push(hd);
    }
    view.all_reduce_sum(&mut partial2)?;
    let m2 = call1_on(ex, "bias_add", &[&partial2[0], p_of(&pf("b2"))?])?;
    let pre2 = call1_on(ex, "add", &[&xm, &m2])?;
    let x_next = call1_on(ex, "ln_fwd", &[&pre2, p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?])?;
    // Residency charges: the replicated stash tensors are computed once
    // per view but every real device keeps its own copy, so each executed
    // rank is charged the full replicated set plus its own shards.
    let mut charges = Vec::with_capacity(2 * ln);
    let repl = x.bytes() + pre1.bytes() + xm.bytes() + pre2.bytes();
    for li in 0..ln {
        let d = ranks[li];
        charges.push(mem::Charge::new(
            d,
            mem::Category::Activation,
            (repl + hs[li].bytes()) as u64,
        ));
        let shard =
            q[li].bytes() + k[li].bytes() + v[li].bytes() + p[li].bytes() + ctx[li].bytes();
        charges.push(mem::Charge::new(d, mem::Category::AttnStash, shard as u64));
    }
    Ok((x_next, TpLayerStash { x_in: x, q, k, v, p, ctx, pre1, xm, h: hs, pre2, _charges: charges }))
}

/// MLM + SOP heads (replicated, computed once per view — every rank
/// holds the same final hidden states, so no broadcast is needed); the
/// parameter gradients are accumulated on group rank 0 only.  Returns
/// `(mlm, sop, dx)` with the losses counted once (zero on views that do
/// not execute rank 0).
pub(crate) fn tp_heads_fwd_bwd(
    ex: &dyn Executor,
    tsh: &TpShape,
    params: &ParamStore,
    batch: &Batch,
    x: &Tensor,
    ranks: &[usize],
    grads: &mut [ParamStore],
) -> Result<(f32, f32, Tensor)> {
    let m = tsh.b * tsh.l;
    let p_of = |name: &str| params.get(name);
    let labels = batch.labels.clone().reshaped(&[m])?;
    let mask = batch.mask.clone().reshaped(&[m])?;
    // replicated full-vocab losses, computed once per view (the hottest
    // kernel of the step — see tp_embed_fwd for why once is enough)
    let out = call_on(ex, "mlm_loss", &[x, p_of("mlm_w")?, p_of("mlm_b")?, &labels, &mask])?;
    let [mlm_lo, mut dxd, dw, db]: [Tensor; 4] =
        out.try_into().map_err(|_| anyhow!("mlm_loss arity"))?;
    let out = call_on(ex, "sop_loss", &[x, p_of("sop_w")?, p_of("sop_b")?, &batch.sop_labels])?;
    let [sop_lo, dx0, dsw, dsb]: [Tensor; 4] =
        out.try_into().map_err(|_| anyhow!("sop_loss arity"))?;
    ops::add_assign(&mut dxd, &dx0)?;
    let mut mlm = 0.0f32;
    let mut sop = 0.0f32;
    if let Some(li0) = ranks.iter().position(|&d| d == 0) {
        mlm = mlm_lo.scalar_f32()?;
        sop = sop_lo.scalar_f32()?;
        ops::add_assign(grads[li0].get_mut("mlm_w")?, &dw)?;
        ops::add_assign(grads[li0].get_mut("mlm_b")?, &db)?;
        ops::add_assign(grads[li0].get_mut("sop_w")?, &dsw)?;
        ops::add_assign(grads[li0].get_mut("sop_b")?, &dsb)?;
    }
    Ok((mlm, sop, dxd))
}

/// One transformer layer backward for the executed ranks; shard gradients
/// land in each rank's store at their global offsets, replicated ones on
/// group rank 0 only.
#[allow(clippy::needless_range_loop)]
pub(crate) fn tp_layer_bwd(
    ex: &dyn Executor,
    view: &dyn Collective,
    tsh: &TpShape,
    params: &ParamStore,
    layer: usize,
    st: &TpLayerStash,
    dx: &Tensor,
    grads: &mut [ParamStore],
) -> Result<Tensor> {
    let ranks = view.local_ranks();
    let ln = ranks.len();
    let li0 = ranks.iter().position(|&d| d == 0);
    let p_of = |name: &str| params.get(name);
    let pf = |s: &str| format!("layer{layer}.{s}");
    let zero_h = Tensor::zeros(&[tsh.hidden]);

    // LN2 backward (replicated, once per view — see tp_embed_fwd)
    let out = call_on(ex, "ln_bwd", &[&st.pre2, p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?, dx])?;
    let [d_pre2, dg2, db2]: [Tensor; 3] =
        out.try_into().map_err(|_| anyhow!("ln_bwd arity"))?;
    if let Some(li0) = li0 {
        ops::add_assign(grads[li0].get_mut(&pf("ln2_g"))?, &dg2)?;
        ops::add_assign(grads[li0].get_mut(&pf("ln2_b"))?, &db2)?;
        ops::add_assign(grads[li0].get_mut(&pf("b2"))?, &ops::sum_rows(&d_pre2)?)?;
    }
    let mut dxm_partial = Vec::with_capacity(ln);
    for li in 0..ln {
        let d = ranks[li];
        let (lo, hi) = tsh.ffn_cols(d);
        let w2 = ops::slice_dim0(p_of(&pf("w2"))?, lo, hi)?;
        let out = call_on(ex, "linear_bwd", &[&st.h[li], &w2, &zero_h, &d_pre2])?;
        let [dh, dw2, _db2]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
        ops::add_into_dim0(grads[li].get_mut(&pf("w2"))?, &dw2, lo)?;
        let w1 = ops::slice_last(p_of(&pf("w1"))?, lo, hi)?;
        let b1 = ops::slice_dim0(p_of(&pf("b1"))?, lo, hi)?;
        let out = call_on(ex, "gelu_linear_bwd", &[&st.xm, &w1, &b1, &dh])?;
        let [dxd, dw1, db1]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow!("gelu_linear_bwd arity"))?;
        ops::add_into_last(grads[li].get_mut(&pf("w1"))?, &dw1, lo)?;
        ops::add_into_dim0(grads[li].get_mut(&pf("b1"))?, &db1, lo)?;
        dxm_partial.push(dxd);
    }
    // all-reduce dx at the block input (f op backward) + residual
    view.all_reduce_sum(&mut dxm_partial)?;
    let dxm = call1_on(ex, "add", &[&dxm_partial[0], &d_pre2])?;

    // LN1 backward (replicated)
    let out = call_on(ex, "ln_bwd", &[&st.pre1, p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?, &dxm])?;
    let [d_pre1, dg1, db1]: [Tensor; 3] =
        out.try_into().map_err(|_| anyhow!("ln_bwd arity"))?;
    if let Some(li0) = li0 {
        ops::add_assign(grads[li0].get_mut(&pf("ln1_g"))?, &dg1)?;
        ops::add_assign(grads[li0].get_mut(&pf("ln1_b"))?, &db1)?;
        ops::add_assign(grads[li0].get_mut(&pf("bo"))?, &ops::sum_rows(&d_pre1)?)?;
    }

    let mut dx_partial = Vec::with_capacity(ln);
    for li in 0..ln {
        let d = ranks[li];
        let (lo, hi) = tsh.head_cols(d);
        let wo = ops::slice_dim0(p_of(&pf("wo"))?, lo, hi)?;
        let flat = call1_on(ex, "from_heads", &[&st.ctx[li]])?;
        let out = call_on(ex, "linear_bwd", &[&flat, &wo, &zero_h, &d_pre1])?;
        let [dflat, dwo, _dbo]: [Tensor; 3] =
            out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
        ops::add_into_dim0(grads[li].get_mut(&pf("wo"))?, &dwo, lo)?;
        let d_ctx = call1_on(ex, &tsh.to_heads_step, &[&dflat])?;
        let dp = call1_on(ex, "attn_dp_step", &[&d_ctx, &st.v[li]])?;
        let ds = call1_on(ex, "softmax_bwd", &[&st.p[li], &dp])?;
        let z0 = Tensor::zeros(&st.q[li].shape);
        let dq = call1_on(ex, "attn_dq_step", &[&ds, &st.k[li], &z0])?;
        let dk = call1_on(ex, "attn_dk_step", &[&ds, &st.q[li], &z0])?;
        let dv = call1_on(ex, "attn_dv_step", &[&st.p[li], &d_ctx, &z0])?;
        let mut dx_d: Option<Tensor> = None;
        for (wname, bname, dt) in [("wq", "bq", &dq), ("wk", "bk", &dk), ("wv", "bv", &dv)] {
            let w = ops::slice_last(p_of(&pf(wname))?, lo, hi)?;
            let bb = ops::slice_dim0(p_of(&pf(bname))?, lo, hi)?;
            let flat = call1_on(ex, "from_heads", &[dt])?;
            let out = call_on(ex, "linear_bwd", &[&st.x_in, &w, &bb, &flat])?;
            let [dxp, dw, dbp]: [Tensor; 3] =
                out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
            ops::add_into_last(grads[li].get_mut(&pf(wname))?, &dw, lo)?;
            ops::add_into_dim0(grads[li].get_mut(&pf(bname))?, &dbp, lo)?;
            match &mut dx_d {
                None => dx_d = Some(dxp),
                Some(acc) => ops::add_assign(acc, &dxp)?,
            }
        }
        dx_partial.push(dx_d.unwrap());
    }
    view.all_reduce_sum(&mut dx_partial)?;
    call1_on(ex, "add", &[&dx_partial[0], &d_pre1])
}

/// Embedding backward (replicated — computed and accumulated only on the
/// view that executes group rank 0).
pub(crate) fn tp_embed_bwd(
    ex: &dyn Executor,
    tsh: &TpShape,
    params: &ParamStore,
    batch: &Batch,
    dx: &Tensor,
    ranks: &[usize],
    grads: &mut [ParamStore],
) -> Result<()> {
    let Some(li0) = ranks.iter().position(|&d| d == 0) else {
        return Ok(()); // replicated: identical on every rank, count once
    };
    let pos = ops::slice_dim0(params.get("pos_emb")?, 0, tsh.l)?;
    let tok = params.get("tok_emb")?;
    let out = call_on(ex, "embed_bwd", &[&batch.ids, tok, &pos, dx])?;
    let [dtok, dpos]: [Tensor; 2] =
        out.try_into().map_err(|_| anyhow!("embed_bwd arity"))?;
    ops::add_assign(grads[li0].get_mut("tok_emb")?, &dtok)?;
    ops::add_into_dim0(grads[li0].get_mut("pos_emb")?, &dpos, 0)?;
    Ok(())
}

/// One full tensor-parallel step over whatever ranks `view` executes:
/// embed → layers (2 forward + 2 backward activation all-reduces each) →
/// heads → backward — the step program both the engine and the static
/// analyzer (`crate::analysis`) interpret.  Returns `(mlm, sop, final
/// hidden, per-local-rank grads)`; the shard merge stays with the caller
/// because it is host-side (no collective) and view-dependent.
pub(crate) fn tp_step(
    ex: &dyn Executor,
    view: &dyn Collective,
    tsh: &TpShape,
    params: &ParamStore,
    batch: &Batch,
) -> Result<(f32, f32, Tensor, Vec<ParamStore>)> {
    let ranks = view.local_ranks();
    let ln = ranks.len();

    // This implementation keeps the full parameter store host-side on
    // every rank and slices shards on demand, so each rank is charged the
    // replicated total (identical to the sequence engine's Params charge —
    // the measured SP-vs-TP peak gap comes from activations, not params).
    let _param_charges: Vec<mem::Charge> = ranks
        .iter()
        .map(|&d| mem::Charge::new(d, mem::Category::Params, params.total_bytes() as u64))
        .collect();

    let sp = crate::obs::begin();
    let mut x = tp_embed_fwd(ex, tsh, params, batch)?;
    sp.end_phase("tp_embed_fwd");
    let mut stashes = Vec::with_capacity(tsh.layers);
    for layer in 0..tsh.layers {
        let sp = crate::obs::begin();
        let (x_next, st) = tp_layer_fwd(ex, view, tsh, params, layer, x)?;
        sp.end_phase_idx("tp_layer_fwd", layer);
        x = x_next;
        stashes.push(st);
    }

    let mut grads: Vec<ParamStore> = (0..ln).map(|_| params.zeros_like()).collect();
    let _grad_charges: Vec<mem::Charge> = ranks
        .iter()
        .enumerate()
        .map(|(li, &d)| mem::Charge::new(d, mem::Category::Grads, grads[li].total_bytes() as u64))
        .collect();
    let sp = crate::obs::begin();
    let (mlm, sop, mut dx) = tp_heads_fwd_bwd(ex, tsh, params, batch, &x, &ranks, &mut grads)?;
    sp.end_phase("tp_heads_fwd_bwd");

    for layer in (0..tsh.layers).rev() {
        let sp = crate::obs::begin();
        dx = tp_layer_bwd(ex, view, tsh, params, layer, &stashes[layer], &dx, &mut grads)?;
        sp.end_phase_idx("tp_layer_bwd", layer);
    }
    let sp = crate::obs::begin();
    tp_embed_bwd(ex, tsh, params, batch, &dx, &ranks, &mut grads)?;
    sp.end_phase("tp_embed_bwd");
    Ok((mlm, sop, x, grads))
}

pub struct TensorParEngine<'rt> {
    rt: &'rt Runtime,
    pub fabric: Fabric,
    pub t: usize, // TP degree
    shape: TpShape,
}

impl<'rt> TensorParEngine<'rt> {
    /// `t == 1` is the serial engine (no splitting, no communication).
    pub fn new(rt: &'rt Runtime, fabric: Fabric) -> Result<TensorParEngine<'rt>> {
        let t = fabric.n;
        let shape = TpShape::from_manifest(rt.manifest(), t)?;
        Ok(TensorParEngine { rt, fabric, t, shape })
    }
}

impl<'rt> Engine for TensorParEngine<'rt> {
    fn name(&self) -> &'static str {
        if self.t == 1 { "serial" } else { "tensor-parallel" }
    }

    fn group_size(&self) -> usize {
        self.t
    }

    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        let ex = self.rt.backend();
        let (mlm, sop, x, mut grads) = tp_step(ex, &self.fabric, &self.shape, params, batch)?;
        let hidden = vec![x];

        // Host-side shard merge (exact: shards land at disjoint offsets,
        // replicated entries appear only in rank 0's store) — no
        // collective, matching Megatron's grad layout.
        let mut g = grads.remove(0);
        for other in grads {
            for (name, t) in other.values {
                ops::add_assign(g.get_mut(&name)?, &t)?;
            }
        }
        Ok(StepOutput { loss: mlm + sop, mlm, sop, grads: g, hidden })
    }
}
