//! Tensor parallelism — the Megatron-LM baseline (paper §2, Eq. 3).
//!
//! Attention heads and MLP columns are split across the group; every
//! device holds the FULL sequence.  Communication: one all-reduce after
//! each block's second GEMM in forward, and one at each block's input in
//! backward (the conjugate f/g operators).
//!
//! Schedule transcription of `python/compile/chain.py::
//! tensorpar_forward_backward` (validated against `jax.grad`).  Weight
//! shards are sliced host-side from the global parameter store; gradient
//! shards are scattered back into global layout, so the optimizer and the
//! convergence comparison (Fig. 6) see identical parameter state across
//! engines.
//!
//! Replicated computations (embeddings, LayerNorms, heads — identical on
//! every rank since their inputs are replicated) are executed once in this
//! sequential simulation; the cluster simulator charges their memory and
//! time per-device, as Megatron does.

use anyhow::{anyhow, bail, Result};

use crate::comm::Fabric;
use crate::model::params::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::{ops, Tensor};

use super::{call, call1, Batch, Engine, StepOutput};

struct LayerStash {
    x_in: Tensor,
    q: Vec<Tensor>,
    k: Vec<Tensor>,
    v: Vec<Tensor>,
    p: Vec<Tensor>,
    ctx: Vec<Tensor>,
    pre1: Tensor,
    xm: Tensor,
    h: Vec<Tensor>,
    pre2: Tensor,
}

pub struct TensorParEngine<'rt> {
    rt: &'rt Runtime,
    pub fabric: Fabric,
    pub t: usize, // TP degree
    b: usize,
    l: usize,
    layers: usize,
    hidden: usize,
    heads: usize,
    head_dim: usize,
    ffn: usize,
    to_heads_step: String,
}

impl<'rt> TensorParEngine<'rt> {
    /// `t == 1` is the serial engine (no splitting, no communication).
    pub fn new(rt: &'rt Runtime, fabric: Fabric) -> Result<TensorParEngine<'rt>> {
        let m = rt.manifest();
        let t = fabric.n;
        if m.heads % t != 0 {
            // This is exactly Megatron's scaling cap the paper exploits
            // (tensor parallel size <= number of attention heads).
            bail!(
                "tensor parallelism size {t} must divide the head count {} \
                 (Megatron's limit — paper §4.2)",
                m.heads
            );
        }
        if m.ffn % t != 0 {
            bail!("TP size {t} must divide FFN width {}", m.ffn);
        }
        if t != 1 && t != m.tp {
            bail!(
                "artifacts were lowered for tp={} (and serial tp=1); got {t}",
                m.tp
            );
        }
        Ok(TensorParEngine {
            rt,
            fabric,
            t,
            b: m.batch,
            l: m.seq_len,
            layers: m.layers,
            hidden: m.hidden,
            heads: m.heads,
            head_dim: m.head_dim,
            ffn: m.ffn,
            to_heads_step: format!("to_heads_b{}", m.batch),
        })
    }

    fn zp(&self) -> usize {
        self.heads / self.t
    }

    fn fp(&self) -> usize {
        self.ffn / self.t
    }

    /// Column range of rank `d` in the head-split projections.
    fn head_cols(&self, d: usize) -> (usize, usize) {
        let w = self.zp() * self.head_dim;
        (d * w, (d + 1) * w)
    }

    fn ffn_cols(&self, d: usize) -> (usize, usize) {
        (d * self.fp(), (d + 1) * self.fp())
    }
}

impl<'rt> Engine for TensorParEngine<'rt> {
    fn name(&self) -> &'static str {
        if self.t == 1 { "serial" } else { "tensor-parallel" }
    }

    fn group_size(&self) -> usize {
        self.t
    }

    fn forward_backward(&self, params: &ParamStore, batch: &Batch) -> Result<StepOutput> {
        let rt = self.rt;
        let (t, b, l, h) = (self.t, self.b, self.l, self.hidden);
        let m = b * l;
        let p_of = |name: &str| params.get(name);
        let zero_h = Tensor::zeros(&[h]);

        let ids = &batch.ids;
        let labels = batch.labels.clone().reshaped(&[m])?;
        let mask = batch.mask.clone().reshaped(&[m])?;
        let pos = ops::slice_dim0(p_of("pos_emb")?, 0, l)?;
        let tok = p_of("tok_emb")?;

        // ---- forward (x replicated across the TP group) -------------------
        let mut x = call1(rt, "embed_fwd", &[ids, tok, &pos])?;
        let mut stashes = Vec::with_capacity(self.layers);
        for li in 0..self.layers {
            let pf = |s: &str| format!("layer{li}.{s}");
            let x_in = x.clone();
            let mut q = Vec::new();
            let mut k = Vec::new();
            let mut v = Vec::new();
            let mut ctx = Vec::new();
            let mut p = Vec::new();
            let mut partial = Vec::new();
            for d in 0..t {
                let (lo, hi) = self.head_cols(d);
                let wq = ops::slice_last(p_of(&pf("wq"))?, lo, hi)?;
                let bq = ops::slice_dim0(p_of(&pf("bq"))?, lo, hi)?;
                let wk = ops::slice_last(p_of(&pf("wk"))?, lo, hi)?;
                let bk = ops::slice_dim0(p_of(&pf("bk"))?, lo, hi)?;
                let wv = ops::slice_last(p_of(&pf("wv"))?, lo, hi)?;
                let bv = ops::slice_dim0(p_of(&pf("bv"))?, lo, hi)?;
                let qd = call1(rt, &self.to_heads_step, &[&call1(rt, "linear_fwd", &[&x, &wq, &bq])?])?;
                let kd = call1(rt, &self.to_heads_step, &[&call1(rt, "linear_fwd", &[&x, &wk, &bk])?])?;
                let vd = call1(rt, &self.to_heads_step, &[&call1(rt, "linear_fwd", &[&x, &wv, &bv])?])?;
                let s = call1(rt, "scores_step", &[&qd, &kd])?;
                let pd = call1(rt, "softmax_fwd", &[&s])?;
                let acc0 = Tensor::zeros(&qd.shape);
                let cd = call1(rt, "av_step", &[&pd, &vd, &acc0])?;
                let wo = ops::slice_dim0(p_of(&pf("wo"))?, lo, hi)?;
                let flat = call1(rt, "from_heads", &[&cd])?;
                partial.push(call1(rt, "linear_fwd", &[&flat, &wo, &zero_h])?);
                q.push(qd); k.push(kd); v.push(vd); p.push(pd); ctx.push(cd);
            }
            // all-reduce the row-split output projection partials (g op)
            self.fabric.all_reduce_sum(&mut partial)?;
            let attn = call1(rt, "bias_add", &[&partial[0], p_of(&pf("bo"))?])?;
            let pre1 = call1(rt, "add", &[&x, &attn])?;
            let xm = call1(rt, "ln_fwd", &[&pre1, p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?])?;
            let mut hs = Vec::new();
            let mut partial2 = Vec::new();
            for d in 0..t {
                let (lo, hi) = self.ffn_cols(d);
                let w1 = ops::slice_last(p_of(&pf("w1"))?, lo, hi)?;
                let b1 = ops::slice_dim0(p_of(&pf("b1"))?, lo, hi)?;
                let hd = call1(rt, "gelu_linear_fwd", &[&xm, &w1, &b1])?;
                let w2 = ops::slice_dim0(p_of(&pf("w2"))?, lo, hi)?;
                partial2.push(call1(rt, "linear_fwd", &[&hd, &w2, &zero_h])?);
                hs.push(hd);
            }
            self.fabric.all_reduce_sum(&mut partial2)?;
            let m2 = call1(rt, "bias_add", &[&partial2[0], p_of(&pf("b2"))?])?;
            let pre2 = call1(rt, "add", &[&xm, &m2])?;
            x = call1(rt, "ln_fwd", &[&pre2, p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?])?;
            stashes.push(LayerStash { x_in, q, k, v, p, ctx, pre1, xm, h: hs, pre2 });
        }

        // ---- heads (replicated) -------------------------------------------
        let mut grads = params.zeros_like();
        let out = call(rt, "mlm_loss", &[&x, p_of("mlm_w")?, p_of("mlm_b")?, &labels, &mask])?;
        let [mlm_lo, mut dx, dw, db]: [Tensor; 4] =
            out.try_into().map_err(|_| anyhow!("mlm_loss arity"))?;
        let mlm = mlm_lo.scalar_f32()?;
        ops::add_assign(grads.get_mut("mlm_w")?, &dw)?;
        ops::add_assign(grads.get_mut("mlm_b")?, &db)?;
        let out = call(rt, "sop_loss", &[&x, p_of("sop_w")?, p_of("sop_b")?, &batch.sop_labels])?;
        let [sop_lo, dx0, dsw, dsb]: [Tensor; 4] =
            out.try_into().map_err(|_| anyhow!("sop_loss arity"))?;
        let sop = sop_lo.scalar_f32()?;
        ops::add_assign(&mut dx, &dx0)?;
        ops::add_assign(grads.get_mut("sop_w")?, &dsw)?;
        ops::add_assign(grads.get_mut("sop_b")?, &dsb)?;

        let hidden = vec![x];

        // ---- backward -------------------------------------------------------
        for li in (0..self.layers).rev() {
            let pf = |s: &str| format!("layer{li}.{s}");
            let st = &stashes[li];
            let out = call(rt, "ln_bwd", &[&st.pre2, p_of(&pf("ln2_g"))?, p_of(&pf("ln2_b"))?, &dx])?;
            let [d_pre2, dg2, db2]: [Tensor; 3] =
                out.try_into().map_err(|_| anyhow!("ln_bwd arity"))?;
            ops::add_assign(grads.get_mut(&pf("ln2_g"))?, &dg2)?;
            ops::add_assign(grads.get_mut(&pf("ln2_b"))?, &db2)?;
            ops::add_assign(grads.get_mut(&pf("b2"))?, &ops::sum_rows(&d_pre2)?)?;
            let mut dxm_partial = Vec::with_capacity(t);
            for d in 0..t {
                let (lo, hi) = self.ffn_cols(d);
                let w2 = ops::slice_dim0(p_of(&pf("w2"))?, lo, hi)?;
                let out = call(rt, "linear_bwd", &[&st.h[d], &w2, &zero_h, &d_pre2])?;
                let [dh, dw2, _db2]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
                ops::add_into_dim0(grads.get_mut(&pf("w2"))?, &dw2, lo)?;
                let w1 = ops::slice_last(p_of(&pf("w1"))?, lo, hi)?;
                let b1 = ops::slice_dim0(p_of(&pf("b1"))?, lo, hi)?;
                let out = call(rt, "gelu_linear_bwd", &[&st.xm, &w1, &b1, &dh])?;
                let [dxd, dw1, db1]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow!("gelu_linear_bwd arity"))?;
                ops::add_into_last(grads.get_mut(&pf("w1"))?, &dw1, lo)?;
                ops::add_into_dim0(grads.get_mut(&pf("b1"))?, &db1, lo)?;
                dxm_partial.push(dxd);
            }
            // all-reduce dx at the block input (f op backward) + residual
            self.fabric.all_reduce_sum(&mut dxm_partial)?;
            let dxm = call1(rt, "add", &[&dxm_partial[0], &d_pre2])?;

            let out = call(rt, "ln_bwd", &[&st.pre1, p_of(&pf("ln1_g"))?, p_of(&pf("ln1_b"))?, &dxm])?;
            let [d_pre1, dg1, db1]: [Tensor; 3] =
                out.try_into().map_err(|_| anyhow!("ln_bwd arity"))?;
            ops::add_assign(grads.get_mut(&pf("ln1_g"))?, &dg1)?;
            ops::add_assign(grads.get_mut(&pf("ln1_b"))?, &db1)?;
            ops::add_assign(grads.get_mut(&pf("bo"))?, &ops::sum_rows(&d_pre1)?)?;

            let mut dx_partial = Vec::with_capacity(t);
            for d in 0..t {
                let (lo, hi) = self.head_cols(d);
                let wo = ops::slice_dim0(p_of(&pf("wo"))?, lo, hi)?;
                let flat = call1(rt, "from_heads", &[&st.ctx[d]])?;
                let out = call(rt, "linear_bwd", &[&flat, &wo, &zero_h, &d_pre1])?;
                let [dflat, dwo, _dbo]: [Tensor; 3] =
                    out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
                ops::add_into_dim0(grads.get_mut(&pf("wo"))?, &dwo, lo)?;
                let d_ctx = call1(rt, &self.to_heads_step, &[&dflat])?;
                let dp = call1(rt, "attn_dp_step", &[&d_ctx, &st.v[d]])?;
                let ds = call1(rt, "softmax_bwd", &[&st.p[d], &dp])?;
                let z0 = Tensor::zeros(&st.q[d].shape);
                let dq = call1(rt, "attn_dq_step", &[&ds, &st.k[d], &z0])?;
                let dk = call1(rt, "attn_dk_step", &[&ds, &st.q[d], &z0])?;
                let dv = call1(rt, "attn_dv_step", &[&st.p[d], &d_ctx, &z0])?;
                let mut dx_d: Option<Tensor> = None;
                for (wname, bname, dt) in [("wq", "bq", &dq), ("wk", "bk", &dk), ("wv", "bv", &dv)] {
                    let w = ops::slice_last(p_of(&pf(wname))?, lo, hi)?;
                    let bb = ops::slice_dim0(p_of(&pf(bname))?, lo, hi)?;
                    let flat = call1(rt, "from_heads", &[dt])?;
                    let out = call(rt, "linear_bwd", &[&st.x_in, &w, &bb, &flat])?;
                    let [dxp, dw, dbp]: [Tensor; 3] =
                        out.try_into().map_err(|_| anyhow!("linear_bwd arity"))?;
                    ops::add_into_last(grads.get_mut(&pf(wname))?, &dw, lo)?;
                    ops::add_into_dim0(grads.get_mut(&pf(bname))?, &dbp, lo)?;
                    match &mut dx_d {
                        None => dx_d = Some(dxp),
                        Some(acc) => ops::add_assign(acc, &dxp)?,
                    }
                }
                dx_partial.push(dx_d.unwrap());
            }
            self.fabric.all_reduce_sum(&mut dx_partial)?;
            dx = call1(rt, "add", &[&dx_partial[0], &d_pre1])?;
        }

        // embeddings (replicated: identical on every rank, computed once)
        let out = call(rt, "embed_bwd", &[ids, tok, &pos, &dx])?;
        let [dtok, dpos]: [Tensor; 2] =
            out.try_into().map_err(|_| anyhow!("embed_bwd arity"))?;
        ops::add_assign(grads.get_mut("tok_emb")?, &dtok)?;
        ops::add_into_dim0(grads.get_mut("pos_emb")?, &dpos, 0)?;

        Ok(StepOutput { loss: mlm + sop, mlm, sop, grads, hidden })
    }
}
