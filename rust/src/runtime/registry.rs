//! Artifact-name construction — the mirror of `aot.py::art_name`.
//!
//! `{step}__{sig}` where sig joins each input's dims with 'x' and inputs
//! with '_', prefixing i32 inputs with 'i'.  The engines build names from
//! the shapes they are about to feed, so a config/manifest mismatch is
//! caught by name lookup before any execution happens.

use crate::tensor::Tensor;

/// Shape signature for one input.
fn sig(dims: &[usize], int: bool) -> String {
    let body = dims
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join("x");
    if int {
        format!("i{body}")
    } else {
        body
    }
}

/// Build an artifact name from explicit (dims, is_i32) pairs.
pub fn art_name(step: &str, inputs: &[(&[usize], bool)]) -> String {
    let parts: Vec<String> = inputs.iter().map(|(d, i)| sig(d, *i)).collect();
    format!("{step}__{}", parts.join("_"))
}

/// Build an artifact name from actual tensors (the common path).
pub fn art_name_for(step: &str, inputs: &[&Tensor]) -> String {
    let parts: Vec<String> = inputs
        .iter()
        .map(|t| sig(&t.shape, matches!(t.dtype(), crate::tensor::DType::I32)))
        .collect();
    format!("{step}__{}", parts.join("_"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_aot_naming() {
        // aot.py: art_name("linear_fwd", [spec([32,128]), spec([128,512]), spec([512])])
        //   == "linear_fwd__32x128_128x512_512"
        assert_eq!(
            art_name("linear_fwd", &[(&[32, 128], false), (&[128, 512], false), (&[512], false)]),
            "linear_fwd__32x128_128x512_512"
        );
        // i32 input prefix
        assert_eq!(
            art_name("embed_fwd", &[(&[2, 16], true), (&[1024, 128], false), (&[16, 128], false)]),
            "embed_fwd__i2x16_1024x128_16x128"
        );
    }

    #[test]
    fn from_tensors() {
        let x = Tensor::zeros(&[4, 8]);
        let ids = Tensor::from_i32(&[4], vec![0; 4]).unwrap();
        assert_eq!(art_name_for("f", &[&ids, &x]), "f__i4_4x8");
    }
}
