//! Manifest parsing — the contract `aot.py` writes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::{self, Value};

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub dims: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub batch: usize,
    pub seq_len: usize,
    pub ring: usize,
    pub tp: usize,
    pub linformer_k: usize,
    /// Blockwise-causal band width in tokens (0 = no masked-softmax
    /// artifacts; optional in the JSON — aot.py predates it).
    pub block_w: usize,
    /// Whether the Ulysses head-shard attention kernels were lowered
    /// (`--sp ulysses`; optional in the JSON, defaults to false).
    pub ulysses: bool,
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seed: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: Vec<ParamSpec>,
    pub goldens: BTreeMap<String, String>,
}

fn io_spec(v: &Value) -> Result<IoSpec> {
    let dims = v
        .req("dims")?
        .as_arr()
        .ok_or_else(|| anyhow!("dims not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match v.req("dtype")?.as_str() {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => bail!("unknown dtype {other:?}"),
    };
    Ok(IoSpec { dims, dtype })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let num = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow!("manifest key {k} not a number"))
        };
        let mut artifacts = BTreeMap::new();
        for (name, spec) in v
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let inputs = spec
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs not an array"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs not an array"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let file = spec
                .req("file")?
                .as_str()
                .ok_or_else(|| anyhow!("file not a string"))?
                .to_string();
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }
        let mut params = Vec::new();
        for p in v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not an array"))?
        {
            params.push(ParamSpec {
                name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                dims: p
                    .req("dims")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("param dims"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?,
                file: p.req("file")?.as_str().unwrap_or_default().to_string(),
            });
        }
        let mut goldens = BTreeMap::new();
        if let Some(g) = v.get("goldens").and_then(|g| g.as_obj()) {
            for (k, val) in g {
                if let Some(s) = val.as_str() {
                    goldens.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(Manifest {
            model: v
                .req("model")?
                .as_str()
                .ok_or_else(|| anyhow!("model not a string"))?
                .to_string(),
            batch: num("batch")?,
            seq_len: num("seq_len")?,
            ring: num("ring")?,
            tp: num("tp")?,
            linformer_k: num("linformer_k")?,
            block_w: v.get("block_w").and_then(|x| x.as_usize()).unwrap_or(0),
            ulysses: v.get("ulysses").and_then(|x| x.as_bool()).unwrap_or(false),
            hidden: num("hidden")?,
            heads: num("heads")?,
            head_dim: num("head_dim")?,
            ffn: num("ffn")?,
            layers: num("layers")?,
            vocab: num("vocab")?,
            seed: num("seed")?,
            artifacts,
            params,
            goldens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "bert-tiny", "batch": 2, "seq_len": 64, "ring": 4, "tp": 2,
        "linformer_k": 0, "hidden": 128, "heads": 2, "head_dim": 64,
        "ffn": 512, "layers": 2, "vocab": 1024, "seed": 0,
        "artifacts": {
            "add__32x128_32x128": {
                "file": "add__32x128_32x128.hlo.txt",
                "inputs": [{"dims": [32, 128], "dtype": "f32"},
                           {"dims": [32, 128], "dtype": "f32"}],
                "outputs": [{"dims": [32, 128], "dtype": "f32"}]
            }
        },
        "params": [{"name": "tok_emb", "dims": [1024, 128],
                    "file": "params/tok_emb.tensor"}],
        "goldens": {"ids": "goldens/ids.tensor"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "bert-tiny");
        assert_eq!(m.ring, 4);
        // block_w / ulysses are optional (predate aot.py) with defaults
        assert_eq!(m.block_w, 0);
        assert!(!m.ulysses);
        let a = &m.artifacts["add__32x128_32x128"];
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![32, 128]);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.goldens["ids"], "goldens/ids.tensor");
    }

    #[test]
    fn missing_key_is_an_error() {
        assert!(Manifest::parse(r#"{"model": "x"}"#).is_err());
    }
}
