//! Manifest parsing — the contract `aot.py` writes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::{self, Value};

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub dims: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub batch: usize,
    pub seq_len: usize,
    pub ring: usize,
    pub tp: usize,
    pub linformer_k: usize,
    /// Blockwise-causal band width in tokens (0 = no masked-softmax
    /// artifacts; optional in the JSON — aot.py predates it).
    pub block_w: usize,
    /// Whether the Ulysses head-shard attention kernels were lowered
    /// (`--sp ulysses`; optional in the JSON, defaults to false).
    pub ulysses: bool,
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seed: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: Vec<ParamSpec>,
    pub goldens: BTreeMap<String, String>,
}

/// Typed field access that names the key AND the offending JSON type —
/// a malformed manifest should say what is wrong where, not panic later.
fn str_field(v: &Value, key: &str) -> Result<String> {
    let f = v.req(key)?;
    f.as_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow!("key {key:?}: expected a string, got {}", f.type_name()))
}

fn dims_field(v: &Value, key: &str) -> Result<Vec<usize>> {
    let f = v.req(key)?;
    f.as_arr()
        .ok_or_else(|| anyhow!("key {key:?}: expected an array, got {}", f.type_name()))?
        .iter()
        .enumerate()
        .map(|(i, d)| {
            d.as_usize().ok_or_else(|| {
                anyhow!("{key}[{i}]: expected a non-negative whole number, got {}", d.type_name())
            })
        })
        .collect()
}

fn io_spec(v: &Value) -> Result<IoSpec> {
    let dims = dims_field(v, "dims")?;
    let dtype = match v.req("dtype")?.as_str() {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => bail!("unknown dtype {other:?} (f32|i32)"),
    };
    Ok(IoSpec { dims, dtype })
}

fn io_list(spec: &Value, key: &str) -> Result<Vec<IoSpec>> {
    let f = spec.req(key)?;
    f.as_arr()
        .ok_or_else(|| anyhow!("{key}: expected an array, got {}", f.type_name()))?
        .iter()
        .enumerate()
        .map(|(i, io)| io_spec(io).with_context(|| format!("{key}[{i}]")))
        .collect()
}

fn artifact_spec(spec: &Value) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        file: str_field(spec, "file")?,
        inputs: io_list(spec, "inputs")?,
        outputs: io_list(spec, "outputs")?,
    })
}

fn param_spec(p: &Value) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: str_field(p, "name")?,
        dims: dims_field(p, "dims")?,
        file: str_field(p, "file")?,
    })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let num = |k: &str| -> Result<usize> {
            let f = v.req(k)?;
            f.as_usize().ok_or_else(|| {
                anyhow!(
                    "manifest key {k:?}: expected a non-negative whole number, got {}",
                    f.type_name()
                )
            })
        };
        let artifacts_v = v.req("artifacts")?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in artifacts_v
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts: expected an object, got {}", artifacts_v.type_name()))?
        {
            let built = artifact_spec(spec)
                .with_context(|| format!("manifest artifact {name:?}"))?;
            artifacts.insert(name.clone(), built);
        }
        let params_v = v.req("params")?;
        let mut params = Vec::new();
        for (i, p) in params_v
            .as_arr()
            .ok_or_else(|| anyhow!("params: expected an array, got {}", params_v.type_name()))?
            .iter()
            .enumerate()
        {
            params.push(param_spec(p).with_context(|| format!("manifest params[{i}]"))?);
        }
        let mut goldens = BTreeMap::new();
        if let Some(g) = v.get("goldens").and_then(|g| g.as_obj()) {
            for (k, val) in g {
                if let Some(s) = val.as_str() {
                    goldens.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(Manifest {
            model: v
                .req("model")?
                .as_str()
                .ok_or_else(|| anyhow!("model not a string"))?
                .to_string(),
            batch: num("batch")?,
            seq_len: num("seq_len")?,
            ring: num("ring")?,
            tp: num("tp")?,
            linformer_k: num("linformer_k")?,
            block_w: v.get("block_w").and_then(|x| x.as_usize()).unwrap_or(0),
            ulysses: v.get("ulysses").and_then(|x| x.as_bool()).unwrap_or(false),
            hidden: num("hidden")?,
            heads: num("heads")?,
            head_dim: num("head_dim")?,
            ffn: num("ffn")?,
            layers: num("layers")?,
            vocab: num("vocab")?,
            seed: num("seed")?,
            artifacts,
            params,
            goldens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "bert-tiny", "batch": 2, "seq_len": 64, "ring": 4, "tp": 2,
        "linformer_k": 0, "hidden": 128, "heads": 2, "head_dim": 64,
        "ffn": 512, "layers": 2, "vocab": 1024, "seed": 0,
        "artifacts": {
            "add__32x128_32x128": {
                "file": "add__32x128_32x128.hlo.txt",
                "inputs": [{"dims": [32, 128], "dtype": "f32"},
                           {"dims": [32, 128], "dtype": "f32"}],
                "outputs": [{"dims": [32, 128], "dtype": "f32"}]
            }
        },
        "params": [{"name": "tok_emb", "dims": [1024, 128],
                    "file": "params/tok_emb.tensor"}],
        "goldens": {"ids": "goldens/ids.tensor"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "bert-tiny");
        assert_eq!(m.ring, 4);
        // block_w / ulysses are optional (predate aot.py) with defaults
        assert_eq!(m.block_w, 0);
        assert!(!m.ulysses);
        let a = &m.artifacts["add__32x128_32x128"];
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![32, 128]);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.goldens["ids"], "goldens/ids.tensor");
    }

    #[test]
    fn missing_key_is_an_error() {
        assert!(Manifest::parse(r#"{"model": "x"}"#).is_err());
    }

    #[test]
    fn malformed_fields_error_with_context() {
        // a negative dim must be refused, naming artifact + field + index
        let neg_dim = SAMPLE.replacen("[32, 128]", "[-32, 128]", 1);
        let e = format!("{:#}", Manifest::parse(&neg_dim).unwrap_err());
        assert!(e.contains("add__32x128_32x128"), "{e}");
        assert!(e.contains("dims[0]"), "{e}");

        // a param whose name is not a string is an error, not ""
        let bad_name = SAMPLE.replace(r#""name": "tok_emb""#, r#""name": 7"#);
        let e = format!("{:#}", Manifest::parse(&bad_name).unwrap_err());
        assert!(e.contains("params[0]"), "{e}");
        assert!(e.contains("expected a string"), "{e}");

        // a fractional scalar must not silently truncate
        let frac = SAMPLE.replace(r#""ring": 4"#, r#""ring": 4.5"#);
        let e = format!("{:#}", Manifest::parse(&frac).unwrap_err());
        assert!(e.contains("ring"), "{e}");
    }
}
