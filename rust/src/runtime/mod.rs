//! The executor layer: manifest contract + backend dispatch.
//!
//! A *manifest* describes every step artifact's input/output shapes; an
//! [`Executor`] runs named artifacts against that contract.  Two backends
//! implement it (see [`crate::backend`]):
//!
//! * the **native** backend — pure-rust f32 kernels over a synthetic
//!   in-memory manifest; the default, needs no external files;
//! * the **XLA/PJRT** backend (feature `backend-xla`) — compiles the
//!   `artifacts/*.hlo.txt` lowered by `python/compile/aot.py`.
//!
//! [`Runtime`] is the enum the engines hold: one concrete type, either
//! backend inside.  Every call is validated against the manifest shapes —
//! a mismatch is an orchestration bug and fails loudly with the artifact
//! name ([`validate_inputs`]).

pub mod manifest;
pub mod registry;

use std::path::Path;

use anyhow::{bail, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest, ParamSpec};

use crate::backend::native::{NativeBackend, NativeConfig};
#[cfg(feature = "backend-xla")]
use crate::backend::xla_pjrt::XlaRuntime;
use crate::tensor::Tensor;

/// Execution statistics (perf pass + tests read these).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub calls: u64,
    pub compile_nanos: u64,
    pub exec_nanos: u64,
}

/// Per-kernel dispatch totals — the `trace` subcommand renders these as
/// the "top-k kernels by total time" table.  Backends that do not track
/// per-kernel time return an empty vec (the default).
#[derive(Clone, Debug)]
pub struct KernelStat {
    pub name: String,
    pub calls: u64,
    pub total_ns: u64,
}

/// An executor runs manifest-described step artifacts.
///
/// The contract every backend upholds: `call` validates inputs against the
/// manifest entry (arity, dims, dtype) before executing, and the returned
/// tensors match the entry's output specs exactly.
pub trait Executor {
    fn manifest(&self) -> &Manifest;

    fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Convenience: call an artifact that returns exactly one tensor.
    fn call1(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        let mut out = self.call(name, inputs)?;
        if out.len() != 1 {
            bail!("{name}: expected 1 output, got {}", out.len());
        }
        Ok(out.pop().unwrap())
    }

    fn stats(&self) -> RuntimeStats {
        RuntimeStats::default()
    }

    /// Distinct executables compiled / kernels dispatched so far.
    fn cached_executables(&self) -> usize {
        0
    }

    /// Per-kernel call/time breakdown, unsorted.  Backends without
    /// per-kernel accounting keep the empty default.
    fn kernel_stats(&self) -> Vec<KernelStat> {
        Vec::new()
    }
}

/// Shared manifest-shape validation: arity, dims, dtype — the error names
/// the artifact so orchestration bugs surface immediately.
pub fn validate_inputs(name: &str, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (i, (t, io)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape != io.dims || t.dtype() != io.dtype {
            bail!(
                "{name}: input {i} is {:?}/{:?}, manifest wants {:?}/{:?}",
                t.shape, t.dtype(), io.dims, io.dtype
            );
        }
    }
    Ok(())
}

/// The backend the engines drive: enum dispatch over the executors.
pub enum Runtime {
    Native(NativeBackend),
    #[cfg(feature = "backend-xla")]
    Xla(XlaRuntime),
}

impl Runtime {
    /// Build the artifact-free native backend for a run-shape config.
    pub fn native(cfg: NativeConfig) -> Result<Runtime> {
        Ok(Runtime::Native(NativeBackend::new(cfg)?))
    }

    /// Open an artifact directory on the PJRT backend.
    #[cfg(feature = "backend-xla")]
    pub fn open(dir: &Path) -> Result<Runtime> {
        Ok(Runtime::Xla(XlaRuntime::open(dir)?))
    }

    /// Without the `backend-xla` feature there is nothing that can execute
    /// HLO artifacts — fail with a pointer at the two ways out.
    #[cfg(not(feature = "backend-xla"))]
    pub fn open(_dir: &Path) -> Result<Runtime> {
        bail!(
            "this build has no XLA backend; rebuild with `--features backend-xla` \
             to load HLO artifacts, or use the native backend (Runtime::native)"
        )
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Runtime::Native(_) => "native",
            #[cfg(feature = "backend-xla")]
            Runtime::Xla(_) => "xla-pjrt",
        }
    }

    /// The active backend as a trait object — the single dispatch point;
    /// every inherent convenience method below routes through it.
    pub fn backend(&self) -> &dyn Executor {
        match self {
            Runtime::Native(b) => b,
            #[cfg(feature = "backend-xla")]
            Runtime::Xla(b) => b,
        }
    }

    /// The active backend as a *thread-shareable* executor, for engines
    /// that run one OS thread per rank (`exec::DistRunner`).  The native
    /// backend is `Send + Sync`; the PJRT backend's `Rc`-based client
    /// handles are thread-local by construction, so it refuses here (but
    /// stays fully usable on the sequential engines).
    pub fn sync_backend(&self) -> Result<&(dyn Executor + Sync)> {
        match self {
            Runtime::Native(b) => Ok(b),
            #[cfg(feature = "backend-xla")]
            Runtime::Xla(_) => bail!(
                "the xla-pjrt backend holds Rc-based PJRT handles and cannot \
                 cross threads; threaded execution needs the native backend \
                 (run with --backend native)"
            ),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend().manifest()
    }

    pub fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.backend().call(name, inputs)
    }

    pub fn call1(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.backend().call1(name, inputs)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend().stats()
    }

    pub fn cached_executables(&self) -> usize {
        self.backend().cached_executables()
    }

    pub fn kernel_stats(&self) -> Vec<KernelStat> {
        self.backend().kernel_stats()
    }
}

impl Executor for NativeBackend {
    fn manifest(&self) -> &Manifest {
        NativeBackend::manifest(self)
    }

    fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        NativeBackend::call(self, name, inputs)
    }

    fn stats(&self) -> RuntimeStats {
        NativeBackend::stats(self)
    }

    fn cached_executables(&self) -> usize {
        NativeBackend::cached_executables(self)
    }

    fn kernel_stats(&self) -> Vec<KernelStat> {
        NativeBackend::kernel_stats(self)
    }
}

#[cfg(feature = "backend-xla")]
impl Executor for XlaRuntime {
    fn manifest(&self) -> &Manifest {
        XlaRuntime::manifest(self)
    }

    fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        XlaRuntime::call(self, name, inputs)
    }

    fn stats(&self) -> RuntimeStats {
        XlaRuntime::stats(self)
    }

    fn cached_executables(&self) -> usize {
        XlaRuntime::cached_executables(self)
    }
}
