//! Threaded ring fabric: the same ring protocol as [`super::Fabric`],
//! executed by real OS threads over channels.
//!
//! The sequential [`super::Fabric`] is what the engines drive (the PJRT
//! client handles are `Rc`-based and cannot cross threads), but the wire
//! protocol must be provably deadlock-free and order-correct — this module
//! is that proof, exercised by unit tests and `rust/tests/fabric.rs`.
//!
//! Topology: a full mesh of mpsc channels; `rx[i][j]` receives at rank i
//! what rank j sent.  Ring ops only use the (i -> i+1 mod n) edges.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::tensor::{ops, Tensor};

use super::{CommKind, Meter};

/// Per-rank communicator handle; owned by that rank's thread.
pub struct RingComm {
    pub rank: usize,
    pub n: usize,
    meter: Arc<Meter>,
    tx: Vec<Sender<Tensor>>,     // tx[j]: send to rank j
    rx: Vec<Receiver<Tensor>>,   // rx[j]: receive from rank j
}

/// Build the full mesh for `n` ranks.
pub fn mesh(n: usize, meter: Arc<Meter>) -> Vec<RingComm> {
    // channels[i][j] carries i -> j
    let mut senders: Vec<Vec<Option<Sender<Tensor>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    let mut receivers: Vec<Vec<Option<Receiver<Tensor>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    for i in 0..n {
        for j in 0..n {
            let (tx, rx) = channel();
            senders[i][j] = Some(tx);
            receivers[j][i] = Some(rx); // at j, indexed by source i
        }
    }
    let mut comms = Vec::with_capacity(n);
    for (rank, (srow, rrow)) in senders.drain(..).zip(receivers.drain(..)).enumerate() {
        comms.push(RingComm {
            rank,
            n,
            meter: meter.clone(),
            tx: srow.into_iter().map(Option::unwrap).collect(),
            rx: rrow.into_iter().map(Option::unwrap).collect(),
        });
    }
    comms
}

impl RingComm {
    pub fn next_rank(&self) -> usize {
        (self.rank + 1) % self.n
    }

    pub fn prev_rank(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    /// One ring exchange: send `t` to rank+1, receive from rank-1.
    /// Send-before-receive is safe because channels are buffered — this is
    /// the same non-blocking-send assumption NCCL's ring makes.
    pub fn ring_exchange(&self, t: Tensor) -> Result<Tensor> {
        let bytes = t.bytes() as u64;
        self.tx[self.next_rank()]
            .send(t)
            .map_err(|_| anyhow!("rank {}: ring peer hung up", self.rank))?;
        let got = self.rx[self.prev_rank()]
            .recv()
            .map_err(|_| anyhow!("rank {}: ring recv failed", self.rank))?;
        self.meter.add(CommKind::RingP2p, bytes);
        Ok(got)
    }

    /// Ring all-reduce (sum), chunked reduce-scatter + all-gather.
    /// Operates on this rank's local tensor; returns the reduced tensor.
    pub fn all_reduce_sum(&self, mut local: Tensor) -> Result<Tensor> {
        if self.n == 1 {
            return Ok(local);
        }
        // Simple ring version over whole tensors (n-1 reduce + n-1 gather
        // steps).  Byte metering matches the chunked ideal 2(n-1)C/n per
        // device because we meter on the canonical formula, not the naive
        // payload (documented accounting choice, same as Fabric).
        let c = local.bytes() as u64;
        let mut acc = local.clone();
        let mut travelling = local.clone();
        for _ in 0..self.n - 1 {
            travelling = self.ring_exchange_unmetered(travelling)?;
            ops::add_assign(&mut acc, &travelling)?;
        }
        // now every rank has the full sum in acc (after n-1 steps each rank
        // saw every chunk exactly once)
        local = acc;
        self.meter.add(CommKind::AllReduce, 2 * (self.n as u64 - 1) * c / self.n as u64);
        Ok(local)
    }

    fn ring_exchange_unmetered(&self, t: Tensor) -> Result<Tensor> {
        self.tx[self.next_rank()]
            .send(t)
            .map_err(|_| anyhow!("rank {}: ring peer hung up", self.rank))?;
        self.rx[self.prev_rank()]
            .recv()
            .map_err(|_| anyhow!("rank {}: ring recv failed", self.rank))
    }

    /// Direct P2P (pipeline stages).
    pub fn send_to(&self, dst: usize, t: Tensor) -> Result<()> {
        self.meter.add(CommKind::Pipeline, t.bytes() as u64);
        self.tx[dst]
            .send(t)
            .map_err(|_| anyhow!("rank {}: send to {dst} failed", self.rank))
    }

    pub fn recv_from(&self, src: usize) -> Result<Tensor> {
        self.rx[src]
            .recv()
            .map_err(|_| anyhow!("rank {}: recv from {src} failed", self.rank))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// N threads run the full RSA ring-rotation pattern concurrently; the
    /// result must equal the sequential Fabric's rotation semantics.
    #[test]
    fn threaded_ring_rotation_matches_sequential() {
        let n = 4;
        let meter = Meter::new();
        let comms = mesh(n, meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut held =
                        Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap();
                    let mut seen = vec![comm.rank];
                    for _ in 0..comm.n - 1 {
                        held = comm.ring_exchange(held).unwrap();
                        seen.push(held.f32s().unwrap()[0] as usize);
                    }
                    (comm.rank, seen, held)
                })
            })
            .collect();
        for h in handles {
            let (rank, seen, final_held) = h.join().unwrap();
            // device d sees chunks d, d-1, d-2, ... (mod n): every chunk once
            let expect: Vec<usize> = (0..n).map(|t| (rank + n - t) % n).collect();
            assert_eq!(seen, expect, "rank {rank} saw wrong chunk order");
            // after n-1 exchanges we hold chunk (rank+1) mod n
            assert_eq!(final_held.f32s().unwrap()[0] as usize, (rank + 1) % n);
        }
        // bytes: (n-1) exchanges x n ranks x 8 bytes
        assert_eq!(meter.get(CommKind::RingP2p), ((n - 1) * n * 8) as u64);
    }

    #[test]
    fn threaded_all_reduce_sums() {
        let n = 3;
        let meter = Meter::new();
        let comms = mesh(n, meter);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let local =
                        Tensor::from_f32(&[4], vec![(comm.rank + 1) as f32; 4]).unwrap();
                    comm.all_reduce_sum(local).unwrap()
                })
            })
            .collect();
        for h in handles {
            let t = h.join().unwrap();
            assert_eq!(t.f32s().unwrap(), &[6.0, 6.0, 6.0, 6.0]);
        }
    }

    #[test]
    fn p2p_send_recv() {
        let meter = Meter::new();
        let mut comms = mesh(2, meter.clone());
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = Tensor::from_f32(&[3], vec![7.0, 8.0, 9.0]).unwrap();
        let h = std::thread::spawn(move || c1.recv_from(0).unwrap());
        c0.send_to(1, t.clone()).unwrap();
        assert_eq!(h.join().unwrap(), t);
        assert_eq!(meter.get(CommKind::Pipeline), 12);
    }
}
