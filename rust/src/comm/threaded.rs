//! Threaded ring fabric: the same ring protocol as [`super::Fabric`],
//! executed by real OS threads over channels.
//!
//! This is the communication layer of `exec::DistRunner`: every rank runs
//! on its own OS thread and drives its own [`RingComm`], so RSA's ring
//! exchanges are genuinely concurrent P2P messages.  (Only the `Rc`-based
//! PJRT backend behind the `backend-xla` feature still forces sequential
//! per-device simulation; the default native backend is `Sync` and runs
//! threaded.)  The unit tests here plus `rust/tests/fabric.rs` and
//! `rust/tests/dist_equivalence.rs` prove the protocol is deadlock-free,
//! order-correct, and byte-metered identically to the sequential
//! [`super::Fabric`].
//!
//! Topology: a full mesh of mpsc channels; `rx[i][j]` receives at rank i
//! what rank j sent.  Ring ops only use the (i -> i+1 mod n) edges; the
//! direct edges carry pipeline sends and broadcast.
//!
//! Metering convention: ring P2P is metered per send (summing to the
//! group total the [`super::Fabric`] slot rotation records in one add);
//! the formula-metered collectives (all-reduce, all-gather, broadcast)
//! are metered ONCE per group call — at rank 0 / the root — with the same
//! canonical group-total formulas `Fabric` uses, so sequential and
//! threaded meters agree byte-for-byte AND op-for-op.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::tensor::{ops, Tensor};

use super::{Collective, CommKind, Meter, ShiftHandle};

/// A posted nonblocking receive: redeem with [`RingComm::irecv_wait`].
/// The channel mesh buffers every message, so posting is free — the
/// handle just fixes which edge (and which op, for error context) the
/// wait will drain.
#[derive(Debug)]
pub struct RecvHandle {
    /// Source global rank.
    pub src: usize,
    /// Operation label used in disconnect errors.
    op: &'static str,
}

/// Per-rank communicator handle; owned by that rank's thread.
pub struct RingComm {
    pub rank: usize,
    pub n: usize,
    meter: Arc<Meter>,
    tx: Vec<Sender<Tensor>>,     // tx[j]: send to rank j
    rx: Vec<Receiver<Tensor>>,   // rx[j]: receive from rank j
}

/// Build the full mesh for `n` ranks.
pub fn mesh(n: usize, meter: Arc<Meter>) -> Vec<RingComm> {
    // channels[i][j] carries i -> j; both matrices are filled in strict
    // construction order, so the layout holds without placeholder Options:
    // tx[i][j] is pushed on iteration (i, j), and rx[j] gains its source-i
    // receiver on the same iteration — ascending i for every j.
    let mut senders: Vec<Vec<Sender<Tensor>>> = (0..n).map(|_| Vec::with_capacity(n)).collect();
    let mut receivers: Vec<Vec<Receiver<Tensor>>> =
        (0..n).map(|_| Vec::with_capacity(n)).collect();
    for i in 0..n {
        for j in 0..n {
            let (tx, rx) = channel();
            senders[i].push(tx);
            receivers[j].push(rx); // at j, indexed by source i
        }
    }
    senders
        .into_iter()
        .zip(receivers)
        .enumerate()
        .map(|(rank, (tx, rx))| RingComm { rank, n, meter: meter.clone(), tx, rx })
        .collect()
}

impl RingComm {
    pub fn next_rank(&self) -> usize {
        (self.rank + 1) % self.n
    }

    pub fn prev_rank(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    /// A peer's channel end disconnected — its rank thread dropped the
    /// `RingComm`, almost always because it panicked or erred mid-step.
    /// Naming the peer and the op here is what lets `DistRunner` /
    /// `MeshRunner` report WHICH rank died instead of a bare recv error.
    fn disconnect_err(&self, peer: usize, op: &str) -> anyhow::Error {
        anyhow!(
            "rank {}: {op} with rank {peer} failed — peer disconnected \
             (rank {peer}'s thread panicked or erred mid-step)",
            self.rank
        )
    }

    /// Nonblocking send of `t` to global rank `dst`.  Channels are
    /// buffered, so this never blocks — the same non-blocking-send
    /// assumption NCCL's ring makes.  Returns the posted payload bytes;
    /// metering is the CALLER's job (at completion of the surrounding
    /// op), so a posted send is metered exactly once however it is used.
    pub fn isend(&self, dst: usize, t: Tensor, op: &'static str) -> Result<u64> {
        let bytes = t.bytes() as u64;
        self.tx[dst].send(t).map_err(|_| self.disconnect_err(dst, op))?;
        Ok(bytes)
    }

    /// Post a receive from global rank `src`.  Posting is free on the
    /// buffered mesh; the returned handle fixes the edge the matching
    /// [`RingComm::irecv_wait`] will drain (and the op label its
    /// disconnect error carries).
    pub fn irecv(&self, src: usize, op: &'static str) -> RecvHandle {
        RecvHandle { src, op }
    }

    /// Complete a posted receive: block (under an `obs::Waiter`, so the
    /// time counts as wait, not work) until the message arrives.
    pub fn irecv_wait(&self, h: RecvHandle) -> Result<Tensor> {
        let w = crate::obs::wait_begin();
        let got = self.rx[h.src]
            .recv()
            .map_err(|_| self.disconnect_err(h.src, h.op));
        w.end();
        got
    }

    /// One ring exchange: send `t` to rank+1, receive from rank-1.
    /// Send-before-receive is safe because channels are buffered — this is
    /// the same non-blocking-send assumption NCCL's ring makes.
    pub fn ring_exchange(&self, t: Tensor) -> Result<Tensor> {
        let sp = crate::obs::begin();
        let bytes = self.isend(self.next_rank(), t, "ring shift")?;
        let got = self.irecv_wait(self.irecv(self.prev_rank(), "ring shift"))?;
        self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        Ok(got)
    }

    /// Ring all-reduce (sum), chunked reduce-scatter + all-gather.
    /// Operates on this rank's local tensor; returns the reduced tensor.
    pub fn all_reduce_sum(&self, local: Tensor) -> Result<Tensor> {
        if self.n == 1 {
            return Ok(local);
        }
        // Simple ring version over whole tensors (n-1 reduce + n-1 gather
        // steps).  Metered once (at rank 0) on the canonical group-total
        // formula 2(n-1)C — not the naive payload — exactly matching the
        // single add Fabric::all_reduce_sum records (documented accounting
        // choice; rust/tests/dist_equivalence.rs pins the parity).
        //
        // NOTE: rank r accumulates in arrival order r, r-1, ..., r+1, so
        // the per-rank sums agree up to f32 reduction-order rounding, not
        // bit-for-bit (each rank's own result IS bit-deterministic).
        let sp = crate::obs::begin();
        let c = local.bytes() as u64;
        let mut travelling = local.clone();
        let mut acc = local;
        for _ in 0..self.n - 1 {
            travelling = self.ring_exchange_unmetered(travelling)?;
            ops::add_assign(&mut acc, &travelling)?;
        }
        // now every rank has the full sum in acc (after n-1 steps each rank
        // saw every chunk exactly once)
        if self.rank == 0 {
            self.meter.add_traced(CommKind::AllReduce, 2 * (self.n as u64 - 1) * c, sp);
        }
        Ok(acc)
    }

    /// Ring all-gather: returns the rank-order concatenation (dim `dim`)
    /// of every rank's `local`.  Metered at rank 0 as (n-1) * total chunk
    /// bytes — the Fabric::all_gather group-total formula.
    pub fn all_gather(&self, local: Tensor, dim: usize) -> Result<Tensor> {
        if self.n == 1 {
            return Ok(local);
        }
        let sp = crate::obs::begin();
        let mut parts: Vec<Option<Tensor>> = (0..self.n).map(|_| None).collect();
        let mut held = local.clone();
        parts[self.rank] = Some(local);
        for t in 1..self.n {
            held = self.ring_exchange_unmetered(held)?;
            // after t shifts we hold the chunk originally at (rank - t) mod n
            let origin = (self.rank + self.n - t) % self.n;
            if parts[origin].is_some() {
                bail!("rank {}: all_gather saw chunk {origin} twice", self.rank);
            }
            parts[origin] = Some(held.clone());
        }
        let owned: Vec<Tensor> = parts
            .into_iter()
            .map(|o| o.ok_or_else(|| anyhow!("rank {}: all_gather missed a chunk", self.rank)))
            .collect::<Result<_>>()?;
        if self.rank == 0 {
            let total: u64 = owned.iter().map(|t| t.bytes() as u64).sum();
            self.meter.add_traced(CommKind::AllGather, (self.n as u64 - 1) * total, sp);
        }
        let refs: Vec<&Tensor> = owned.iter().collect();
        ops::concat_dim(&refs, dim)
    }

    /// Broadcast from `root`: the root's tensor replaces every rank's
    /// `local`.  Uses the direct mesh edges (root sends n-1 copies) and is
    /// metered at the root as (n-1)*C under [`CommKind::Broadcast`] —
    /// matching Fabric::broadcast's accounting.
    pub fn broadcast(&self, local: Tensor, root: usize) -> Result<Tensor> {
        if root >= self.n {
            bail!("broadcast root {root} out of {}", self.n);
        }
        if self.n == 1 {
            return Ok(local);
        }
        if self.rank == root {
            let sp = crate::obs::begin();
            let c = local.bytes() as u64;
            for dst in 0..self.n {
                if dst != root {
                    self.tx[dst]
                        .send(local.clone())
                        .map_err(|_| anyhow!("rank {}: broadcast peer {dst} hung up", self.rank))?;
                }
            }
            self.meter.add_traced(CommKind::Broadcast, (self.n as u64 - 1) * c, sp);
            Ok(local)
        } else {
            let got = self.irecv_wait(self.irecv(root, "broadcast"))?;
            Ok(got)
        }
    }

    /// All-to-all transpose (see [`Collective::all_to_all`]): split the
    /// local tensor into `n` pieces along `split_dim`, fire piece `j` at
    /// rank `j` over the direct mesh edges (buffered, so the symmetric
    /// send pattern cannot deadlock), then concatenate the received
    /// pieces in global rank order along `concat_dim`.  Metered once (at
    /// rank 0) as `(n-1) * C` — the Fabric group-total formula.
    pub fn all_to_all(&self, local: Tensor, split_dim: usize, concat_dim: usize) -> Result<Tensor> {
        if self.n == 1 {
            return Ok(local);
        }
        let sp = crate::obs::begin();
        let c = local.bytes() as u64;
        let mut pieces: Vec<Option<Tensor>> =
            ops::chunk_dim(&local, split_dim, self.n)?.into_iter().map(Some).collect();
        for dst in 0..self.n {
            if dst == self.rank {
                continue;
            }
            let t = pieces[dst]
                .take()
                .ok_or_else(|| anyhow!("rank {}: all_to_all split lost piece {dst}", self.rank))?;
            self.tx[dst]
                .send(t)
                .map_err(|_| anyhow!("rank {}: all_to_all peer {dst} hung up", self.rank))?;
        }
        let parts: Vec<Tensor> = (0..self.n)
            .map(|src| {
                if src == self.rank {
                    pieces[src].take().ok_or_else(|| {
                        anyhow!("rank {}: own all_to_all piece missing", self.rank)
                    })
                } else {
                    self.irecv_wait(self.irecv(src, "all_to_all"))
                }
            })
            .collect::<Result<_>>()?;
        if self.rank == 0 {
            self.meter.add_traced(CommKind::AllToAll, (self.n as u64 - 1) * c, sp);
        }
        let refs: Vec<&Tensor> = parts.iter().collect();
        ops::concat_dim(&refs, concat_dim)
    }

    fn ring_exchange_unmetered(&self, t: Tensor) -> Result<Tensor> {
        self.isend(self.next_rank(), t, "ring exchange")?;
        self.irecv_wait(self.irecv(self.prev_rank(), "ring exchange"))
    }

    /// Direct P2P (pipeline stages).  The send itself is nonblocking
    /// (`isend` on the buffered mesh), so a stage boundary send already
    /// overlaps with whatever the sender computes next; it is metered at
    /// post time because delivery is guaranteed once enqueued.
    pub fn send_to(&self, dst: usize, t: Tensor) -> Result<()> {
        let sp = crate::obs::begin();
        let bytes = self.isend(dst, t, "pipeline send")?;
        self.meter.add_traced(CommKind::Pipeline, bytes, sp);
        Ok(())
    }

    pub fn recv_from(&self, src: usize) -> Result<Tensor> {
        self.irecv_wait(self.irecv(src, "pipeline recv"))
    }
}

/// Take the single local slot, leaving a cheap placeholder.
fn take_slot(comm: &RingComm, slots: &mut [Tensor]) -> Result<Tensor> {
    if slots.len() != 1 {
        bail!(
            "rank {}: per-rank view holds exactly 1 slot, got {}",
            comm.rank,
            slots.len()
        );
    }
    Ok(std::mem::replace(&mut slots[0], Tensor::zeros(&[])))
}

/// The per-rank threaded view: this communicator executes exactly one
/// global rank; every collective is real traffic against the peer rank
/// threads (which must be inside the same collective call).
impl Collective for RingComm {
    fn world(&self) -> usize {
        self.n
    }

    fn local_ranks(&self) -> Vec<usize> {
        vec![self.rank]
    }

    fn ring_shift(&self, slots: &mut [Tensor]) -> Result<()> {
        if self.n == 1 {
            // nothing moves, no bytes — mirrors Fabric::ring_shift so the
            // n=1 meters agree (the inherent collectives already no-op)
            if slots.len() != 1 {
                bail!("rank 0: per-rank view holds exactly 1 slot, got {}", slots.len());
            }
            return Ok(());
        }
        let t = take_slot(self, slots)?;
        slots[0] = self.ring_exchange(t)?;
        Ok(())
    }

    /// The real nonblocking half: clone the held chunk, `isend` it to the
    /// next rank and open the comm span — then the caller computes on the
    /// held chunk while the message is in flight.  The hop is metered at
    /// `ring_shift_wait`, exactly once and with the same bytes as the
    /// blocking [`RingComm::ring_exchange`], so meters and traces stay
    /// byte- and op-identical with overlap on.
    fn ring_shift_post(&self, slots: &[Tensor]) -> Result<ShiftHandle> {
        if slots.len() != 1 {
            bail!(
                "rank {}: per-rank view holds exactly 1 slot, got {}",
                self.rank,
                slots.len()
            );
        }
        if self.n == 1 {
            return Ok(ShiftHandle::ready(slots.to_vec()));
        }
        let sp = crate::obs::begin();
        let bytes = self.isend(self.next_rank(), slots[0].clone(), "ring shift")?;
        Ok(ShiftHandle::pending(bytes, sp))
    }

    /// Complete the posted shift: `irecv` the predecessor's chunk (the
    /// message usually arrived long ago — the wait split under `obs::`
    /// is what the overlap-efficiency metric reads), then meter/trace the
    /// hop with the bytes recorded at post time.
    fn ring_shift_wait(&self, handle: ShiftHandle) -> Result<Vec<Tensor>> {
        let (ready, bytes, sp) = handle.into_parts();
        if let Some(slots) = ready {
            return Ok(slots); // n == 1: nothing was in flight
        }
        let sp = sp.ok_or_else(|| {
            anyhow!("rank {}: ring_shift_wait on a handle with no open span", self.rank)
        })?;
        let got = self.irecv_wait(self.irecv(self.prev_rank(), "ring shift"))?;
        self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        Ok(vec![got])
    }

    fn all_reduce_sum(&self, slots: &mut [Tensor]) -> Result<()> {
        let t = take_slot(self, slots)?;
        slots[0] = RingComm::all_reduce_sum(self, t)?;
        Ok(())
    }

    fn all_gather(&self, slots: &mut [Tensor], dim: usize) -> Result<()> {
        let t = take_slot(self, slots)?;
        slots[0] = RingComm::all_gather(self, t, dim)?;
        Ok(())
    }

    fn broadcast(&self, slots: &mut [Tensor], root: usize) -> Result<()> {
        let t = take_slot(self, slots)?;
        slots[0] = RingComm::broadcast(self, t, root)?;
        Ok(())
    }

    fn all_to_all(
        &self,
        slots: &mut [Tensor],
        split_dim: usize,
        concat_dim: usize,
    ) -> Result<()> {
        let t = take_slot(self, slots)?;
        slots[0] = RingComm::all_to_all(self, t, split_dim, concat_dim)?;
        Ok(())
    }

    /// Skip-aware ring step: the static plan tells every rank both
    /// whether it sends (its own chunk is live) and whether it will
    /// receive (its predecessor's chunk is live) — no control message is
    /// needed for a skipped hop, which is the whole point.
    fn ring_shift_sparse(&self, slots: &mut [Tensor], live: &[bool]) -> Result<()> {
        if live.len() != self.n {
            bail!("rank {}: {} live flags for {} ranks", self.rank, live.len(), self.n);
        }
        let t = take_slot(self, slots)?;
        if self.n == 1 {
            slots[0] = t;
            return Ok(());
        }
        if live[self.rank] {
            let sp = crate::obs::begin();
            let bytes = self.isend(self.next_rank(), t, "sparse ring shift")?;
            self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        }
        slots[0] = if live[self.prev_rank()] {
            self.irecv_wait(self.irecv(self.prev_rank(), "sparse ring shift"))?
        } else {
            Tensor::zeros(&[]) // dead hop: placeholder, never read
        };
        Ok(())
    }

    /// Sparse gradient homing: fire every off-home contribution at its
    /// owner over the direct mesh edges (buffered, so no ordering
    /// deadlock), then collect this rank's own chunk in ascending
    /// consumer order — the SAME summation order the sequential Fabric
    /// uses, so the two executions stay bit-comparable per rank.
    fn reduce_chunks_home(
        &self,
        mut parts: Vec<Vec<Option<Tensor>>>,
        consumers: &[Vec<usize>],
    ) -> Result<Vec<Tensor>> {
        if parts.len() != 1 {
            bail!("rank {}: per-rank view holds 1 part row, got {}", self.rank, parts.len());
        }
        if consumers.len() != self.n {
            bail!("rank {}: {} consumer lists for {} ranks", self.rank, consumers.len(), self.n);
        }
        let mut mine = parts
            .pop()
            .ok_or_else(|| anyhow!("rank {}: reduce_chunks_home lost its part row", self.rank))?;
        if mine.len() != self.n {
            bail!("rank {}: {} chunk parts for {} ranks", self.rank, mine.len(), self.n);
        }
        for (src, part) in mine.iter().enumerate() {
            if part.is_some() != consumers[src].contains(&self.rank) {
                bail!("rank {}: contribution set disagrees with the consumer plan for chunk {src}", self.rank);
            }
        }
        // send phase: off-home contributions, ascending destination
        for src in 0..self.n {
            if src == self.rank {
                continue;
            }
            if let Some(t) = mine[src].take() {
                let sp = crate::obs::begin();
                let bytes = t.bytes() as u64;
                self.tx[src]
                    .send(t)
                    .map_err(|_| anyhow!("rank {}: grad delivery to {src} failed", self.rank))?;
                self.meter.add_traced(CommKind::RingP2p, bytes, sp);
            }
        }
        // collect phase: my own chunk, ascending consumer order
        let mut acc: Option<Tensor> = None;
        for &dst in &consumers[self.rank] {
            let t = if dst == self.rank {
                mine[self.rank]
                    .take()
                    .ok_or_else(|| anyhow!("rank {}: missing own contribution", self.rank))?
            } else {
                self.irecv_wait(self.irecv(dst, "grad delivery"))?
            };
            match &mut acc {
                None => acc = Some(t),
                Some(a) => ops::add_assign(a, &t)?,
            }
        }
        let home = acc.ok_or_else(|| {
            anyhow!("rank {}: chunk {} has no consumers", self.rank, self.rank)
        })?;
        Ok(vec![home])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// N threads run the full RSA ring-rotation pattern concurrently; the
    /// result must equal the sequential Fabric's rotation semantics.
    #[test]
    fn threaded_ring_rotation_matches_sequential() {
        let n = 4;
        let meter = Meter::new();
        let comms = mesh(n, meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut held =
                        Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap();
                    let mut seen = vec![comm.rank];
                    for _ in 0..comm.n - 1 {
                        held = comm.ring_exchange(held).unwrap();
                        seen.push(held.f32s().unwrap()[0] as usize);
                    }
                    (comm.rank, seen, held)
                })
            })
            .collect();
        for h in handles {
            let (rank, seen, final_held) = h.join().unwrap();
            // device d sees chunks d, d-1, d-2, ... (mod n): every chunk once
            let expect: Vec<usize> = (0..n).map(|t| (rank + n - t) % n).collect();
            assert_eq!(seen, expect, "rank {rank} saw wrong chunk order");
            // after n-1 exchanges we hold chunk (rank+1) mod n
            assert_eq!(final_held.f32s().unwrap()[0] as usize, (rank + 1) % n);
        }
        // bytes: (n-1) exchanges x n ranks x 8 bytes
        assert_eq!(meter.get(CommKind::RingP2p), ((n - 1) * n * 8) as u64);
    }

    #[test]
    fn threaded_all_reduce_sums() {
        let n = 3;
        let meter = Meter::new();
        let comms = mesh(n, meter);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let local =
                        Tensor::from_f32(&[4], vec![(comm.rank + 1) as f32; 4]).unwrap();
                    comm.all_reduce_sum(local).unwrap()
                })
            })
            .collect();
        for h in handles {
            let t = h.join().unwrap();
            assert_eq!(t.f32s().unwrap(), &[6.0, 6.0, 6.0, 6.0]);
        }
    }

    #[test]
    fn threaded_all_gather_concatenates_in_rank_order() {
        let n = 4;
        let meter = Meter::new();
        let comms = mesh(n, meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let local =
                        Tensor::from_f32(&[1, 2], vec![comm.rank as f32; 2]).unwrap();
                    comm.all_gather(local, 0).unwrap()
                })
            })
            .collect();
        for h in handles {
            let t = h.join().unwrap();
            assert_eq!(t.shape, vec![4, 2]);
            assert_eq!(
                t.f32s().unwrap(),
                &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]
            );
        }
        // metered once (rank 0), group total: (n-1) * sum of chunk bytes
        assert_eq!(meter.get(CommKind::AllGather), 3 * 4 * 8);
    }

    #[test]
    fn threaded_broadcast_replicates_root() {
        let n = 3;
        let meter = Meter::new();
        let comms = mesh(n, meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let local =
                        Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap();
                    comm.broadcast(local, 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().f32s().unwrap(), &[1.0, 1.0]);
        }
        // metered once (the root), under its own counter: (n-1) * C bytes
        assert_eq!(meter.get(CommKind::Broadcast), 2 * 2 * 4);
        assert_eq!(meter.get(CommKind::AllGather), 0);
    }

    /// The formula-metered collectives must land the SAME counters as the
    /// sequential Fabric — byte-for-byte and op-for-op.
    #[test]
    fn collective_metering_matches_fabric() {
        let n = 4;
        let len = 6;
        let mk = |d: usize| Tensor::from_f32(&[len], vec![d as f32; len]).unwrap();

        let fab_meter = Meter::new();
        let fabric = crate::comm::Fabric::new(n, fab_meter.clone());
        let mut slots: Vec<Tensor> = (0..n).map(mk).collect();
        fabric.all_reduce_sum(&mut slots).unwrap();
        let mut slots: Vec<Tensor> = (0..n).map(mk).collect();
        fabric.all_gather(&mut slots, 0).unwrap();
        let mut slots: Vec<Tensor> = (0..n).map(mk).collect();
        fabric.broadcast(&mut slots, 2).unwrap();

        let thr_meter = Meter::new();
        let comms = mesh(n, thr_meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let d = comm.rank;
                    let t = Tensor::from_f32(&[6], vec![d as f32; 6]).unwrap();
                    comm.all_reduce_sum(t.clone()).unwrap();
                    comm.all_gather(t.clone(), 0).unwrap();
                    comm.broadcast(t, 2).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(fab_meter.snapshot(), thr_meter.snapshot());
    }

    /// Threaded all-to-all: same transpose result and the same metered
    /// bytes (and op count) as the sequential Fabric.
    #[test]
    fn all_to_all_matches_fabric() {
        let n = 4;
        let mk = |d: usize| {
            Tensor::from_f32(&[2, 4, 8], (0..64).map(|i| (d * 100 + i) as f32).collect())
                .unwrap()
        };

        let fab_meter = Meter::new();
        let fabric = crate::comm::Fabric::new(n, fab_meter.clone());
        let mut want: Vec<Tensor> = (0..n).map(mk).collect();
        fabric.all_to_all(&mut want, 1, 2).unwrap();

        let thr_meter = Meter::new();
        let comms = mesh(n, thr_meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let local = mk(comm.rank);
                    (comm.rank, comm.all_to_all(local, 1, 2).unwrap())
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            assert_eq!(got, want[rank], "rank {rank} diverged from Fabric");
        }
        assert_eq!(fab_meter.snapshot(), thr_meter.snapshot());
        assert_eq!(thr_meter.get(CommKind::AllToAll), 3 * 2 * 4 * 8 * 4);
    }

    /// Two threaded all-to-alls with the dims swapped restore the
    /// original tensor on every rank (the backward-undoes-forward
    /// property the Ulysses schedule relies on).
    #[test]
    fn all_to_all_round_trip_is_identity_threaded() {
        let n = 2;
        let comms = mesh(n, Meter::new());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let orig = Tensor::from_f32(
                        &[2, 2, 4],
                        (0..16).map(|i| (comm.rank * 50 + i) as f32).collect(),
                    )
                    .unwrap();
                    let once = comm.all_to_all(orig.clone(), 1, 2).unwrap();
                    let back = comm.all_to_all(once, 2, 1).unwrap();
                    assert_eq!(back, orig, "rank {}: round trip diverged", comm.rank);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Threaded sparse ring shift: same chunk movement and the same
    /// metered bytes as the sequential Fabric for the same live pattern.
    #[test]
    fn sparse_ring_shift_matches_fabric() {
        let n = 4;
        let live = [true, false, true, false];

        let fab_meter = Meter::new();
        let fabric = crate::comm::Fabric::new(n, fab_meter.clone());
        let mut slots: Vec<Tensor> = (0..n)
            .map(|d| Tensor::from_f32(&[2], vec![d as f32; 2]).unwrap())
            .collect();
        fabric.ring_shift_sparse(&mut slots, &live).unwrap();

        let thr_meter = Meter::new();
        let comms = mesh(n, thr_meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut s =
                        vec![Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap()];
                    Collective::ring_shift_sparse(&comm, &mut s, &live).unwrap();
                    (comm.rank, s.pop().unwrap())
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            assert_eq!(got, slots[rank], "rank {rank} diverged from Fabric");
        }
        assert_eq!(fab_meter.get(CommKind::RingP2p), 2 * 2 * 4);
        assert_eq!(thr_meter.get(CommKind::RingP2p), fab_meter.get(CommKind::RingP2p));
    }

    /// Threaded gradient homing: same sums (ascending consumer order) and
    /// the same metered bytes as the sequential Fabric.
    #[test]
    fn reduce_chunks_home_matches_fabric() {
        let n = 3;
        // chunk 0 consumed by {0,1}; chunk 1 by {1,2}; chunk 2 by {2}
        let consumers = vec![vec![0usize, 1], vec![1, 2], vec![2]];
        let part_of = |dst: usize, src: usize| {
            Tensor::from_f32(&[2], vec![(10 * dst + src) as f32; 2]).unwrap()
        };
        let parts_for = |dst: usize| -> Vec<Option<Tensor>> {
            (0..n)
                .map(|src| consumers[src].contains(&dst).then(|| part_of(dst, src)))
                .collect()
        };

        let fab_meter = Meter::new();
        let fabric = crate::comm::Fabric::new(n, fab_meter.clone());
        let want = fabric
            .reduce_chunks_home((0..n).map(parts_for).collect(), &consumers)
            .unwrap();

        let thr_meter = Meter::new();
        let comms = mesh(n, thr_meter.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let consumers = consumers.clone();
                let parts = vec![parts_for(comm.rank)];
                std::thread::spawn(move || {
                    let out =
                        Collective::reduce_chunks_home(&comm, parts, &consumers).unwrap();
                    (comm.rank, out.into_iter().next().unwrap())
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            assert_eq!(got, want[rank], "rank {rank} home grad diverged");
        }
        assert_eq!(thr_meter.get(CommKind::RingP2p), fab_meter.get(CommKind::RingP2p));
    }

    /// Double-buffered rotation via post/wait: every rank posts the send
    /// of its held chunk, "computes" on it, then waits — the final chunk
    /// placement and the metered bytes must equal the blocking rotation.
    #[test]
    fn posted_ring_rotation_matches_blocking() {
        let n = 4;
        let blocking = Meter::new();
        {
            let comms = mesh(n, blocking.clone());
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    std::thread::spawn(move || {
                        let mut s =
                            vec![Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap()];
                        for _ in 0..comm.n - 1 {
                            Collective::ring_shift(&comm, &mut s).unwrap();
                        }
                        (comm.rank, s.pop().unwrap())
                    })
                })
                .collect();
            for h in handles {
                let (rank, held) = h.join().unwrap();
                assert_eq!(held.f32s().unwrap()[0] as usize, (rank + 1) % n);
            }
        }
        let posted = Meter::new();
        let comms = mesh(n, posted.clone());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut held =
                        vec![Tensor::from_f32(&[2], vec![comm.rank as f32; 2]).unwrap()];
                    for _ in 0..comm.n - 1 {
                        let h = Collective::ring_shift_post(&comm, &held).unwrap();
                        // compute on `held` happens here, overlapped
                        held = Collective::ring_shift_wait(&comm, h).unwrap();
                    }
                    (comm.rank, held.pop().unwrap())
                })
            })
            .collect();
        for h in handles {
            let (rank, held) = h.join().unwrap();
            assert_eq!(held.f32s().unwrap()[0] as usize, (rank + 1) % n);
        }
        assert_eq!(posted.snapshot(), blocking.snapshot(), "overlap must not change metering");
    }

    /// n=1 post/wait degenerates to a free identity, like the blocking
    /// shift.
    #[test]
    fn posted_shift_single_rank_is_free() {
        let meter = Meter::new();
        let mut comms = mesh(1, meter.clone());
        let comm = comms.pop().unwrap();
        let s = vec![Tensor::from_f32(&[2], vec![5.0; 2]).unwrap()];
        let h = Collective::ring_shift_post(&comm, &s).unwrap();
        let got = Collective::ring_shift_wait(&comm, h).unwrap();
        assert_eq!(got, s);
        assert_eq!(meter.snapshot().total(), 0);
    }

    /// A dead peer surfaces as a contextful error naming the peer rank
    /// and the op — not a hang, not a bare "recv failed".
    #[test]
    fn disconnect_error_names_peer_and_op() {
        let meter = Meter::new();
        let mut comms = mesh(2, meter);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c1); // rank 1 "dies": all its channel ends disconnect
        let t = Tensor::from_f32(&[2], vec![1.0; 2]).unwrap();
        let err = c0.ring_exchange(t).unwrap_err().to_string();
        assert!(err.contains("rank 0"), "missing own rank: {err}");
        assert!(err.contains("rank 1"), "missing peer rank: {err}");
        assert!(err.contains("ring shift"), "missing op: {err}");
        assert!(err.contains("disconnected"), "missing cause: {err}");
    }

    #[test]
    fn p2p_send_recv() {
        let meter = Meter::new();
        let mut comms = mesh(2, meter.clone());
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = Tensor::from_f32(&[3], vec![7.0, 8.0, 9.0]).unwrap();
        let h = std::thread::spawn(move || c1.recv_from(0).unwrap());
        c0.send_to(1, t.clone()).unwrap();
        assert_eq!(h.join().unwrap(), t);
        assert_eq!(meter.get(CommKind::Pipeline), 12);
    }
}
