//! The collective communication fabric.
//!
//! This is the substrate the paper assumes (NCCL/Gloo rings over the Piz
//! Daint interconnect) rebuilt in-process: ring point-to-point rotation,
//! ring all-reduce (reduce-scatter + all-gather), all-gather, all-to-all
//! (the Ulysses head-shard transpose), broadcast — every byte metered per
//! collective kind so the §3.2.2 communication-cost analysis can be
//! checked against measured traffic (rust/tests/comm_volume.rs; the full
//! closed-form table lives in docs/ARCHITECTURE.md).
//!
//! Two implementations share the semantics behind the [`Collective`]
//! trait:
//!
//! * [`Fabric`] — deterministic, runs collectives over the per-device slot
//!   vector; one call executes the whole group.  This is what the
//!   sequential engines and the simulator drive.
//! * [`threaded::RingComm`] — real per-rank communicators over channels
//!   executing the same ring protocol message-by-message; one OS thread
//!   per rank (`exec::DistRunner`).  The tests prove it is deadlock-free
//!   and byte-identical to [`Fabric`].

pub mod threaded;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::{ops, Tensor};

/// What kind of collective moved the bytes — the unit of the paper's
/// communication accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommKind {
    /// Ring point-to-point chunk rotation (RSA stages).
    RingP2p,
    /// Ring all-reduce (gradient reduction; TP partial sums).
    AllReduce,
    /// All-gather (pipeline boundary in Megatron's scheme).
    AllGather,
    /// All-to-all (Ulysses-style head-shard transpose: each rank sends a
    /// distinct 1/n piece of its tensor to every peer).
    AllToAll,
    /// Root-to-all replication (parameter init / checkpoint restore).
    Broadcast,
    /// Scatter/split (pipeline boundary split before transmit).
    Scatter,
    /// Pipeline stage-to-stage activation send.
    Pipeline,
}

/// Byte + op counters, shared by all fabrics of a run.
#[derive(Default, Debug)]
pub struct Meter {
    pub ring_p2p_bytes: AtomicU64,
    pub all_reduce_bytes: AtomicU64,
    pub all_gather_bytes: AtomicU64,
    pub all_to_all_bytes: AtomicU64,
    pub broadcast_bytes: AtomicU64,
    pub scatter_bytes: AtomicU64,
    pub pipeline_bytes: AtomicU64,
    pub ops: AtomicU64,
    // Per-kind op counts (one increment per `add` call — the anchor of
    // the runtime trace invariant: `crate::obs` emits exactly one comm
    // event per metered op, so trace event counts equal these).
    pub ring_p2p_ops: AtomicU64,
    pub all_reduce_ops: AtomicU64,
    pub all_gather_ops: AtomicU64,
    pub all_to_all_ops: AtomicU64,
    pub broadcast_ops: AtomicU64,
    pub scatter_ops: AtomicU64,
    pub pipeline_ops: AtomicU64,
}

impl Meter {
    pub fn new() -> Arc<Meter> {
        Arc::new(Meter::default())
    }

    pub fn add(&self, kind: CommKind, bytes: u64) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.ops_counter(kind).fetch_add(1, Ordering::Relaxed);
        self.counter(kind).fetch_add(bytes, Ordering::Relaxed);
    }

    /// Meter the op AND close `sp` as the matching [`crate::obs`] comm
    /// event.  The runtime fabrics route every metered collective
    /// through this, which is what makes per-kind trace event counts ==
    /// per-kind op counts hold by construction (asserted by
    /// [`crate::obs::cross_check`]); `sp` must have been begun when the
    /// collective started so the event's duration covers it.
    pub fn add_traced(&self, kind: CommKind, bytes: u64, sp: crate::obs::Span) {
        self.add(kind, bytes);
        sp.end_comm(kind, bytes);
    }

    fn counter(&self, kind: CommKind) -> &AtomicU64 {
        match kind {
            CommKind::RingP2p => &self.ring_p2p_bytes,
            CommKind::AllReduce => &self.all_reduce_bytes,
            CommKind::AllGather => &self.all_gather_bytes,
            CommKind::AllToAll => &self.all_to_all_bytes,
            CommKind::Broadcast => &self.broadcast_bytes,
            CommKind::Scatter => &self.scatter_bytes,
            CommKind::Pipeline => &self.pipeline_bytes,
        }
    }

    fn ops_counter(&self, kind: CommKind) -> &AtomicU64 {
        match kind {
            CommKind::RingP2p => &self.ring_p2p_ops,
            CommKind::AllReduce => &self.all_reduce_ops,
            CommKind::AllGather => &self.all_gather_ops,
            CommKind::AllToAll => &self.all_to_all_ops,
            CommKind::Broadcast => &self.broadcast_ops,
            CommKind::Scatter => &self.scatter_ops,
            CommKind::Pipeline => &self.pipeline_ops,
        }
    }

    pub fn get(&self, kind: CommKind) -> u64 {
        self.counter(kind).load(Ordering::Relaxed)
    }

    /// Op count for one kind (number of `add` calls, NOT bytes).
    pub fn get_ops(&self, kind: CommKind) -> u64 {
        self.ops_counter(kind).load(Ordering::Relaxed)
    }

    /// Per-kind op counts in the fixed kind order.  Note the counts are
    /// convention-dependent (the sequential `Fabric` meters one
    /// group-total add per collective; the threaded `RingComm` meters
    /// ring sends per rank but formula collectives once at rank 0/root),
    /// so compare them against traces from the SAME fabric only.
    pub fn kind_ops(&self) -> [(CommKind, u64); 7] {
        [
            (CommKind::RingP2p, self.get_ops(CommKind::RingP2p)),
            (CommKind::AllReduce, self.get_ops(CommKind::AllReduce)),
            (CommKind::AllGather, self.get_ops(CommKind::AllGather)),
            (CommKind::AllToAll, self.get_ops(CommKind::AllToAll)),
            (CommKind::Broadcast, self.get_ops(CommKind::Broadcast)),
            (CommKind::Scatter, self.get_ops(CommKind::Scatter)),
            (CommKind::Pipeline, self.get_ops(CommKind::Pipeline)),
        ]
    }

    pub fn total_bytes(&self) -> u64 {
        self.get(CommKind::RingP2p)
            + self.get(CommKind::AllReduce)
            + self.get(CommKind::AllGather)
            + self.get(CommKind::AllToAll)
            + self.get(CommKind::Broadcast)
            + self.get(CommKind::Scatter)
            + self.get(CommKind::Pipeline)
    }

    pub fn reset(&self) {
        self.ring_p2p_bytes.store(0, Ordering::Relaxed);
        self.all_reduce_bytes.store(0, Ordering::Relaxed);
        self.all_gather_bytes.store(0, Ordering::Relaxed);
        self.all_to_all_bytes.store(0, Ordering::Relaxed);
        self.broadcast_bytes.store(0, Ordering::Relaxed);
        self.scatter_bytes.store(0, Ordering::Relaxed);
        self.pipeline_bytes.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
        self.ring_p2p_ops.store(0, Ordering::Relaxed);
        self.all_reduce_ops.store(0, Ordering::Relaxed);
        self.all_gather_ops.store(0, Ordering::Relaxed);
        self.all_to_all_ops.store(0, Ordering::Relaxed);
        self.broadcast_ops.store(0, Ordering::Relaxed);
        self.scatter_ops.store(0, Ordering::Relaxed);
        self.pipeline_ops.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MeterSnapshot {
        MeterSnapshot {
            ring_p2p: self.get(CommKind::RingP2p),
            all_reduce: self.get(CommKind::AllReduce),
            all_gather: self.get(CommKind::AllGather),
            all_to_all: self.get(CommKind::AllToAll),
            broadcast: self.get(CommKind::Broadcast),
            scatter: self.get(CommKind::Scatter),
            pipeline: self.get(CommKind::Pipeline),
            ops: self.ops.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MeterSnapshot {
    pub ring_p2p: u64,
    pub all_reduce: u64,
    pub all_gather: u64,
    pub all_to_all: u64,
    pub broadcast: u64,
    pub scatter: u64,
    pub pipeline: u64,
    pub ops: u64,
}

impl MeterSnapshot {
    pub fn total(&self) -> u64 {
        self.ring_p2p
            + self.all_reduce
            + self.all_gather
            + self.all_to_all
            + self.broadcast
            + self.scatter
            + self.pipeline
    }

    /// Per-kind byte totals in a fixed order, for rendering and for
    /// byte-level comparison.  `ops` is deliberately excluded: the
    /// sequential `Fabric` meters one group-total add where the threaded
    /// `RingComm` meters per-rank adds, so op COUNTS differ between the
    /// fabrics even though every byte total agrees.
    pub fn kind_bytes(&self) -> [(CommKind, u64); 7] {
        [
            (CommKind::RingP2p, self.ring_p2p),
            (CommKind::AllReduce, self.all_reduce),
            (CommKind::AllGather, self.all_gather),
            (CommKind::AllToAll, self.all_to_all),
            (CommKind::Broadcast, self.broadcast),
            (CommKind::Scatter, self.scatter),
            (CommKind::Pipeline, self.pipeline),
        ]
    }

    /// Byte-exact equality per collective kind, ignoring op counts.
    pub fn same_bytes(&self, other: &MeterSnapshot) -> bool {
        self.kind_bytes() == other.kind_bytes()
    }
}

/// A pending nonblocking ring shift, returned by
/// [`Collective::ring_shift_post`] and redeemed by
/// [`Collective::ring_shift_wait`].
///
/// The handle is plain data so the `Collective` trait stays object-safe.
/// On the sequential [`Fabric`] (and every other view that keeps the
/// default eager implementation) the shift completes inside `post` and
/// `ready` already holds the rotated slots; on the threaded
/// [`threaded::RingComm`] `post` only enqueues the send (`ready` is
/// `None`) and `wait` performs the blocking receive, closes the open
/// comm span and meters the bytes — so metering and trace events are
/// byte- and op-identical to the blocking [`Collective::ring_shift`]
/// under BOTH fabrics, they just land at `wait` time.
#[derive(Debug)]
pub struct ShiftHandle {
    /// Rotated slots, already present when the shift completed eagerly.
    ready: Option<Vec<Tensor>>,
    /// Payload bytes of the posted send (0 on the eager path — the
    /// blocking shift already metered them).
    bytes: u64,
    /// The comm span opened at post time (threaded path only); closed by
    /// `ring_shift_wait` so its duration covers post → completion.
    sp: Option<crate::obs::Span>,
}

impl ShiftHandle {
    /// An already-completed shift (the eager/default path).
    pub fn ready(slots: Vec<Tensor>) -> ShiftHandle {
        ShiftHandle { ready: Some(slots), bytes: 0, sp: None }
    }

    /// An in-flight shift: `bytes` posted, span open until the wait.
    pub fn pending(bytes: u64, sp: crate::obs::Span) -> ShiftHandle {
        ShiftHandle { ready: None, bytes, sp: Some(sp) }
    }

    /// Destructure for the waiting side.
    pub fn into_parts(self) -> (Option<Vec<Tensor>>, u64, Option<crate::obs::Span>) {
        (self.ready, self.bytes, self.sp)
    }
}

/// A rank-set view of the collective fabric — the abstraction the
/// per-rank step logic in `parallel::sequence` is written against, so the
/// SAME code runs either sequentially simulated or genuinely threaded.
///
/// A view *executes* some set of global ranks and holds one tensor slot
/// per executed rank ([`Collective::local_ranks`], in slot order):
///
/// * [`Fabric`] executes ALL `n` ranks on the calling thread — `slots`
///   has length `n` and collectives are plain slot-vector permutations;
/// * [`threaded::RingComm`] executes exactly ONE rank — `slots` has
///   length 1 and every collective is real P2P traffic against the peer
///   rank threads, which must be calling the same collective.
///
/// Semantics agree by construction (`rust/tests/fabric.rs` and
/// `rust/tests/dist_equivalence.rs` prove it): after `t` ring shifts the
/// slot of global rank `d` holds the chunk originally owned by
/// `(d - t) mod n`, gathers concatenate in global rank order, and byte
/// metering agrees byte-for-byte between the two implementations.  One
/// caveat: the threaded ring all-reduce accumulates in each rank's
/// arrival order, so reduced values match the sequential ones (and each
/// other) up to f32 reduction-order rounding — any single rank's result
/// is still bit-deterministic across runs.
pub trait Collective {
    /// Global ring size.
    fn world(&self) -> usize;

    /// Global ranks this view executes, in slot order.
    fn local_ranks(&self) -> Vec<usize>;

    /// One ring step: every rank's slot moves to rank+1 (mod n); the slot
    /// of rank-1 arrives.
    fn ring_shift(&self, slots: &mut [Tensor]) -> Result<()>;

    /// Post (but do not complete) one ring step of `slots` — the
    /// nonblocking half of a double-buffered schedule: post the shift of
    /// chunk t, compute on chunk t, then [`Collective::ring_shift_wait`]
    /// for chunk t+1 to arrive.  The default implementation is EAGER and
    /// semantically identical to [`Collective::ring_shift`] (clone, shift,
    /// hand the rotated slots back through the handle), so the sequential
    /// [`Fabric`] and the static `analysis::TraceCollective` meter the
    /// same bytes and emit the same trace events with or without overlap
    /// — which is why every pinned comm closed form is unchanged.  The
    /// threaded `RingComm` overrides both halves with a real
    /// `isend`/`irecv` pair.
    fn ring_shift_post(&self, slots: &[Tensor]) -> Result<ShiftHandle> {
        let mut moved = slots.to_vec();
        self.ring_shift(&mut moved)?;
        Ok(ShiftHandle::ready(moved))
    }

    /// Complete a posted ring shift, returning the rotated slots.  On the
    /// threaded fabric this is where the blocking receive happens and
    /// where the op is metered/traced (exactly once per hop, same bytes
    /// as the blocking shift).
    fn ring_shift_wait(&self, handle: ShiftHandle) -> Result<Vec<Tensor>> {
        let (ready, _, _) = handle.into_parts();
        ready.ok_or_else(|| {
            anyhow::anyhow!(
                "ring_shift_wait: pending handle on an eager fabric (posted elsewhere?)"
            )
        })
    }

    /// Every slot replaced by the elementwise sum over all global ranks.
    fn all_reduce_sum(&self, slots: &mut [Tensor]) -> Result<()>;

    /// Every slot replaced by the rank-order concatenation (dim `dim`) of
    /// all global ranks' slots.
    fn all_gather(&self, slots: &mut [Tensor], dim: usize) -> Result<()>;

    /// Every slot replaced by global rank `root`'s slot.
    fn broadcast(&self, slots: &mut [Tensor], root: usize) -> Result<()>;

    /// All-to-all transpose: every rank splits its slot into `world()`
    /// equal pieces along `split_dim`, sends piece `j` to global rank
    /// `j`, and replaces its slot with the rank-order concatenation of
    /// the received pieces along `concat_dim`.  Applying it twice with
    /// the dims swapped is the identity (the piece routing is symmetric),
    /// which is exactly how the Ulysses attention backward undoes the
    /// forward head-shard exchange.
    ///
    /// Metered once per group call under [`CommKind::AllToAll`] on the
    /// group-total convention: each rank keeps its own piece and sends
    /// `n-1`, so a C-byte slot costs `(n-1) * C` across the group —
    /// byte- and op-identical between [`Fabric`] and the threaded
    /// `RingComm` (all slots must be the same size, as with every
    /// collective here).
    fn all_to_all(&self, slots: &mut [Tensor], split_dim: usize, concat_dim: usize)
        -> Result<()>;

    /// Skip-aware ring step for blockwise-sparse attention.  `live[d]`
    /// (indexed by GLOBAL rank, derived from the static block plan so
    /// every rank agrees) says whether the chunk currently held by rank
    /// `d` is still needed downstream: live chunks move to rank d+1 and
    /// are metered; dead chunks are dropped — the hop carries NO message
    /// and the receiving slot becomes an empty placeholder that the plan
    /// guarantees is never read.
    fn ring_shift_sparse(&self, slots: &mut [Tensor], live: &[bool]) -> Result<()>;

    /// Sparse gradient homing: `parts[li][src]` is executed rank li's
    /// contribution to origin chunk `src`'s gradient (`Some` exactly
    /// where the mask made li a consumer of src).  `consumers[src]`
    /// lists the consuming global ranks ascending — identical on every
    /// rank.  Each off-home contribution is delivered straight to the
    /// owner (one metered ring-P2P chunk-send) and summed there in
    /// ascending consumer order; returns each executed rank's summed
    /// gradient for its OWN chunk.  This replaces dense RSA's
    /// accumulator-rides-the-whole-ring schedule for masked patterns.
    fn reduce_chunks_home(
        &self,
        parts: Vec<Vec<Option<Tensor>>>,
        consumers: &[Vec<usize>],
    ) -> Result<Vec<Tensor>>;
}

/// Deterministic collective fabric over per-device slot vectors.
///
/// `slots[d]` is the tensor device `d` currently holds.  All byte counts
/// follow the standard accounting: total bytes SENT across the group (so
/// a ring rotation of a C-byte chunk over N devices costs N*C — each
/// device sends once; a ring all-reduce of C bytes costs 2*(N-1)*C total).
pub struct Fabric {
    pub n: usize,
    pub meter: Arc<Meter>,
}

impl Fabric {
    pub fn new(n: usize, meter: Arc<Meter>) -> Fabric {
        Fabric { n, meter }
    }

    /// One ring step: every device sends its slot to rank+1 (mod n).
    /// After `t` calls, device `d` holds the chunk originally at
    /// `(d - t) mod n` — the convention chain.py documents.
    pub fn ring_shift(&self, slots: &mut [Tensor]) -> Result<()> {
        if slots.len() != self.n {
            bail!("ring_shift: {} slots for {} devices", slots.len(), self.n);
        }
        if self.n == 1 {
            return Ok(()); // nothing moves, no bytes
        }
        let sp = crate::obs::begin();
        let bytes: u64 = slots.iter().map(|t| t.bytes() as u64).sum();
        slots.rotate_right(1);
        self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        Ok(())
    }

    /// Ring all-reduce (sum): every device ends with the elementwise sum.
    /// Metered as reduce-scatter + all-gather, group total: 2*(n-1)*C
    /// (i.e. 2*(n-1)/n * C sent per device).
    pub fn all_reduce_sum(&self, slots: &mut [Tensor]) -> Result<()> {
        if slots.len() != self.n {
            bail!("all_reduce: {} slots for {} devices", slots.len(), self.n);
        }
        if self.n == 1 {
            return Ok(());
        }
        let sp = crate::obs::begin();
        let c = slots[0].bytes() as u64;
        let (first, rest) = slots.split_at_mut(1);
        for s in rest.iter() {
            ops::add_assign(&mut first[0], s)?;
        }
        for s in rest.iter_mut() {
            *s = first[0].clone();
        }
        let n = self.n as u64;
        self.meter.add_traced(CommKind::AllReduce, 2 * (n - 1) * c, sp);
        Ok(())
    }

    /// All-gather: every device ends with the concatenation (dim `dim`) of
    /// all slots.  Each device sends its chunk to n-1 peers (ring pass):
    /// (n-1) * C total per device chunk.
    pub fn all_gather(&self, slots: &mut [Tensor], dim: usize) -> Result<()> {
        if slots.len() != self.n {
            bail!("all_gather: {} slots for {} devices", slots.len(), self.n);
        }
        if self.n == 1 {
            return Ok(());
        }
        let sp = crate::obs::begin();
        let bytes: u64 = slots.iter().map(|t| t.bytes() as u64).sum();
        let refs: Vec<&Tensor> = slots.iter().collect();
        let full = ops::concat_dim(&refs, dim)?;
        for s in slots.iter_mut() {
            *s = full.clone();
        }
        // ring all-gather: every device forwards n-1 chunks => (n-1) * sum(C)
        self.meter.add_traced(CommKind::AllGather, (self.n as u64 - 1) * bytes, sp);
        Ok(())
    }

    /// Broadcast from `root` to all (metered as (n-1)*C under its own
    /// [`CommKind::Broadcast`] counter so collective accounting never
    /// conflates it with all-gather traffic).
    pub fn broadcast(&self, slots: &mut [Tensor], root: usize) -> Result<()> {
        if slots.len() != self.n {
            bail!("broadcast: {} slots for {} devices", slots.len(), self.n);
        }
        if root >= self.n {
            bail!("broadcast root {root} out of {}", self.n);
        }
        if self.n == 1 {
            return Ok(());
        }
        let sp = crate::obs::begin();
        let c = slots[root].bytes() as u64;
        let src = slots[root].clone();
        for (i, s) in slots.iter_mut().enumerate() {
            if i != root {
                *s = src.clone();
            }
        }
        self.meter.add_traced(CommKind::Broadcast, (self.n as u64 - 1) * c, sp);
        Ok(())
    }

    /// All-to-all transpose (see [`Collective::all_to_all`]): slot `d`
    /// becomes the rank-order concatenation of every rank's `d`-th piece.
    /// Group-total metering: n ranks each send n-1 of their n pieces,
    /// i.e. `(n-1) * C` for C-byte slots.
    pub fn all_to_all(
        &self,
        slots: &mut [Tensor],
        split_dim: usize,
        concat_dim: usize,
    ) -> Result<()> {
        if slots.len() != self.n {
            bail!("all_to_all: {} slots for {} devices", slots.len(), self.n);
        }
        if self.n == 1 {
            return Ok(());
        }
        let sp = crate::obs::begin();
        let c = slots[0].bytes() as u64;
        if slots.iter().any(|s| s.bytes() as u64 != c) {
            bail!("all_to_all: slots must be the same size on every rank");
        }
        let pieces: Vec<Vec<Tensor>> = slots
            .iter()
            .map(|s| ops::chunk_dim(s, split_dim, self.n))
            .collect::<Result<_>>()?;
        for (d, slot) in slots.iter_mut().enumerate() {
            let refs: Vec<&Tensor> = pieces.iter().map(|row| &row[d]).collect();
            *slot = ops::concat_dim(&refs, concat_dim)?;
        }
        self.meter.add_traced(CommKind::AllToAll, (self.n as u64 - 1) * c, sp);
        Ok(())
    }

    /// Skip-aware ring step (see [`Collective::ring_shift_sparse`]): only
    /// live slots rotate and are metered; a rank whose predecessor's
    /// chunk died receives an empty placeholder.
    pub fn ring_shift_sparse(&self, slots: &mut [Tensor], live: &[bool]) -> Result<()> {
        if slots.len() != self.n || live.len() != self.n {
            bail!(
                "ring_shift_sparse: {} slots / {} live flags for {} devices",
                slots.len(),
                live.len(),
                self.n
            );
        }
        if self.n == 1 {
            return Ok(());
        }
        let sp = crate::obs::begin();
        let bytes: u64 = slots
            .iter()
            .zip(live)
            .filter(|(_, &l)| l)
            .map(|(t, _)| t.bytes() as u64)
            .sum();
        let old: Vec<Tensor> = slots
            .iter_mut()
            .map(|s| std::mem::replace(s, Tensor::zeros(&[])))
            .collect();
        for (d, t) in old.into_iter().enumerate() {
            if live[d] {
                slots[(d + 1) % self.n] = t;
            }
        }
        if bytes > 0 {
            self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        }
        Ok(())
    }

    /// Sparse gradient homing (see [`Collective::reduce_chunks_home`]):
    /// sums each chunk's contributions in ascending consumer order,
    /// metering one chunk-send per off-home contribution.
    pub fn reduce_chunks_home(
        &self,
        mut parts: Vec<Vec<Option<Tensor>>>,
        consumers: &[Vec<usize>],
    ) -> Result<Vec<Tensor>> {
        if parts.len() != self.n || consumers.len() != self.n {
            bail!(
                "reduce_chunks_home: {} part rows / {} consumer lists for {} devices",
                parts.len(),
                consumers.len(),
                self.n
            );
        }
        let sp = crate::obs::begin();
        let mut bytes = 0u64;
        let mut out = Vec::with_capacity(self.n);
        for src in 0..self.n {
            let mut acc: Option<Tensor> = None;
            for dst in 0..self.n {
                // own the contribution — `parts` was passed by value
                let part = parts[dst][src].take();
                if part.is_some() != consumers[src].contains(&dst) {
                    bail!("reduce_chunks_home: rank {dst} disagrees with the consumer plan for chunk {src}");
                }
                let Some(t) = part else { continue };
                if dst != src {
                    bytes += t.bytes() as u64;
                }
                match &mut acc {
                    None => acc = Some(t),
                    Some(a) => ops::add_assign(a, &t)?,
                }
            }
            out.push(acc.ok_or_else(|| {
                anyhow::anyhow!("reduce_chunks_home: chunk {src} has no consumers")
            })?);
        }
        if bytes > 0 {
            self.meter.add_traced(CommKind::RingP2p, bytes, sp);
        }
        Ok(out)
    }

    /// Point-to-point send between pipeline stages (metered separately so
    /// the Fig. 4 pipeline-communication comparison can read it off).
    pub fn pipeline_send(&self, t: &Tensor) {
        let sp = crate::obs::begin();
        self.meter.add_traced(CommKind::Pipeline, t.bytes() as u64, sp);
    }

    /// Megatron's pipeline boundary under tensor parallelism: scatter the
    /// activation (split along sequence), send, then all-gather on the
    /// receiving stage (paper §3.2.2 last paragraph).  Sequence
    /// parallelism skips both the scatter and the gather.  This is the
    /// one-call analytic form of the executable boundary in `exec::mesh`;
    /// the all-gather is metered on the same group-total convention as
    /// [`Fabric::all_gather`] — (n-1) * C for chunks summing to C — so the
    /// two agree byte-for-byte.
    pub fn pipeline_boundary_megatron(&self, act: &Tensor) {
        let c = act.bytes() as u64;
        if self.n == 1 {
            // degenerate group: a plain send, no split and no gather
            self.meter.add_traced(CommKind::Pipeline, c, crate::obs::begin());
            return;
        }
        // scatter: the activation is split across the TP group before send
        self.meter.add_traced(CommKind::Scatter, c, crate::obs::begin());
        // each TP rank sends its 1/n slice to the next stage
        self.meter.add_traced(CommKind::Pipeline, c, crate::obs::begin());
        // ring all-gather on the receiving side: group total (n-1) * C
        self.meter.add_traced(CommKind::AllGather, (self.n as u64 - 1) * c, crate::obs::begin());
    }
}

/// The sequential slot view: one `Fabric` call executes all `n` ranks.
impl Collective for Fabric {
    fn world(&self) -> usize {
        self.n
    }

    fn local_ranks(&self) -> Vec<usize> {
        (0..self.n).collect()
    }

    fn ring_shift(&self, slots: &mut [Tensor]) -> Result<()> {
        Fabric::ring_shift(self, slots)
    }

    fn all_reduce_sum(&self, slots: &mut [Tensor]) -> Result<()> {
        Fabric::all_reduce_sum(self, slots)
    }

    fn all_gather(&self, slots: &mut [Tensor], dim: usize) -> Result<()> {
        Fabric::all_gather(self, slots, dim)
    }

    fn broadcast(&self, slots: &mut [Tensor], root: usize) -> Result<()> {
        Fabric::broadcast(self, slots, root)
    }

    fn all_to_all(
        &self,
        slots: &mut [Tensor],
        split_dim: usize,
        concat_dim: usize,
    ) -> Result<()> {
        Fabric::all_to_all(self, slots, split_dim, concat_dim)
    }

    fn ring_shift_sparse(&self, slots: &mut [Tensor], live: &[bool]) -> Result<()> {
        Fabric::ring_shift_sparse(self, slots, live)
    }

    fn reduce_chunks_home(
        &self,
        parts: Vec<Vec<Option<Tensor>>>,
        consumers: &[Vec<usize>],
    ) -> Result<Vec<Tensor>> {
        Fabric::reduce_chunks_home(self, parts, consumers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(n: usize, len: usize) -> Vec<Tensor> {
        (0..n)
            .map(|d| Tensor::from_f32(&[len], vec![d as f32 + 1.0; len]).unwrap())
            .collect()
    }

    #[test]
    fn ring_shift_rotates_and_meters() {
        let m = Meter::new();
        let f = Fabric::new(4, m.clone());
        let mut s = slots(4, 8);
        f.ring_shift(&mut s).unwrap();
        // device d now holds chunk (d-1) mod 4
        assert_eq!(s[1].f32s().unwrap()[0], 1.0);
        assert_eq!(s[0].f32s().unwrap()[0], 4.0);
        assert_eq!(m.get(CommKind::RingP2p), 4 * 8 * 4); // 4 devices x 8 f32
        // full cycle returns home
        for _ in 0..3 {
            f.ring_shift(&mut s).unwrap();
        }
        assert_eq!(s[0].f32s().unwrap()[0], 1.0);
    }

    #[test]
    fn all_reduce_sums_everywhere() {
        let m = Meter::new();
        let f = Fabric::new(3, m.clone());
        let mut s = slots(3, 4);
        f.all_reduce_sum(&mut s).unwrap();
        for d in &s {
            assert_eq!(d.f32s().unwrap(), &[6.0, 6.0, 6.0, 6.0]);
        }
        // 2*(n-1)*C bytes
        assert_eq!(m.get(CommKind::AllReduce), 2 * 2 * 16);
    }

    #[test]
    fn all_gather_concatenates() {
        let m = Meter::new();
        let f = Fabric::new(2, m.clone());
        let mut s = vec![
            Tensor::from_f32(&[1, 2], vec![1.0, 2.0]).unwrap(),
            Tensor::from_f32(&[1, 2], vec![3.0, 4.0]).unwrap(),
        ];
        f.all_gather(&mut s, 0).unwrap();
        for d in &s {
            assert_eq!(d.shape, vec![2, 2]);
            assert_eq!(d.f32s().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        }
    }

    #[test]
    fn broadcast_replicates_root() {
        let m = Meter::new();
        let f = Fabric::new(3, m.clone());
        let mut s = slots(3, 2);
        f.broadcast(&mut s, 2).unwrap();
        for d in &s {
            assert_eq!(d.f32s().unwrap(), &[3.0, 3.0]);
        }
        // metered under its own counter: (n-1) * C bytes, no all-gather
        assert_eq!(m.get(CommKind::Broadcast), 2 * 2 * 4);
        assert_eq!(m.get(CommKind::AllGather), 0);
        assert_eq!(m.snapshot().broadcast, 2 * 2 * 4);
    }

    #[test]
    fn all_to_all_transposes_pieces_in_rank_order() {
        let m = Meter::new();
        let f = Fabric::new(2, m.clone());
        // rank d holds [[10d, 10d+1], [10d+2, 10d+3]]: split dim 0, concat dim 1
        let mut s = vec![
            Tensor::from_f32(&[2, 2], vec![0., 1., 2., 3.]).unwrap(),
            Tensor::from_f32(&[2, 2], vec![10., 11., 12., 13.]).unwrap(),
        ];
        f.all_to_all(&mut s, 0, 1).unwrap();
        // rank 0 gets row 0 of every rank, concatenated along dim 1
        assert_eq!(s[0].shape, vec![1, 4]);
        assert_eq!(s[0].f32s().unwrap(), &[0., 1., 10., 11.]);
        assert_eq!(s[1].f32s().unwrap(), &[2., 3., 12., 13.]);
        // group total: each rank keeps 1 piece and sends 1 => (n-1)*C
        assert_eq!(m.get(CommKind::AllToAll), 16);
        assert_eq!(m.snapshot().ops, 1);
    }

    #[test]
    fn all_to_all_twice_is_identity() {
        let m = Meter::new();
        let f = Fabric::new(4, m.clone());
        let mk = |d: usize| {
            Tensor::from_f32(&[2, 4, 8], (0..64).map(|i| (d * 100 + i) as f32).collect())
                .unwrap()
        };
        let orig: Vec<Tensor> = (0..4).map(mk).collect();
        let mut s = orig.clone();
        f.all_to_all(&mut s, 1, 2).unwrap(); // [2,4,8] -> [2,1,32]
        assert_eq!(s[0].shape, vec![2, 1, 32]);
        f.all_to_all(&mut s, 2, 1).unwrap(); // back to [2,4,8]
        assert_eq!(s, orig, "all_to_all ∘ all_to_all (dims swapped) must be identity");
        // each of the two calls moves (n-1)*C bytes
        let c = orig[0].bytes() as u64;
        assert_eq!(m.get(CommKind::AllToAll), 2 * 3 * c);
    }

    #[test]
    fn all_to_all_rejects_bad_shapes() {
        let f = Fabric::new(3, Meter::new());
        // dim not divisible by n
        let mut s: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(&[2, 4])).collect();
        assert!(f.all_to_all(&mut s, 1, 0).is_err());
        // wrong slot count
        let mut s: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(&[3, 3])).collect();
        assert!(f.all_to_all(&mut s, 0, 1).is_err());
    }

    #[test]
    fn single_device_is_free() {
        let m = Meter::new();
        let f = Fabric::new(1, m.clone());
        let mut s = slots(1, 8);
        f.ring_shift(&mut s).unwrap();
        f.all_reduce_sum(&mut s).unwrap();
        assert_eq!(m.snapshot().total(), 0);
    }

    #[test]
    fn sparse_ring_shift_moves_only_live_chunks() {
        let m = Meter::new();
        let f = Fabric::new(4, m.clone());
        let mut s = slots(4, 8);
        // chunks at ranks 0 and 2 are live; 1 and 3 die on this hop
        f.ring_shift_sparse(&mut s, &[true, false, true, false]).unwrap();
        assert_eq!(s[1].f32s().unwrap()[0], 1.0); // received 0's chunk
        assert_eq!(s[3].f32s().unwrap()[0], 3.0); // received 2's chunk
        assert_eq!(s[0].numel(), 1); // dead placeholder (3's chunk dropped)
        assert_eq!(s[2].numel(), 1);
        // only the two live sends are metered
        assert_eq!(m.get(CommKind::RingP2p), 2 * 8 * 4);
    }

    #[test]
    fn sparse_ring_shift_all_dead_is_free() {
        let m = Meter::new();
        let f = Fabric::new(3, m.clone());
        let mut s = slots(3, 4);
        f.ring_shift_sparse(&mut s, &[false, false, false]).unwrap();
        assert_eq!(m.snapshot().total(), 0);
        assert_eq!(m.snapshot().ops, 0);
    }

    #[test]
    fn reduce_chunks_home_sums_and_meters_off_home_sends() {
        let m = Meter::new();
        let f = Fabric::new(3, m.clone());
        let t = |v: f32| Tensor::from_f32(&[2], vec![v; 2]).unwrap();
        // chunk 0 consumed by {0, 1}; chunk 1 by {1, 2}; chunk 2 by {2}
        let parts = vec![
            vec![Some(t(1.0)), None, None],
            vec![Some(t(2.0)), Some(t(3.0)), None],
            vec![None, Some(t(4.0)), Some(t(5.0))],
        ];
        let consumers = vec![vec![0, 1], vec![1, 2], vec![2]];
        let out = f.reduce_chunks_home(parts, &consumers).unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[3.0, 3.0]);
        assert_eq!(out[1].f32s().unwrap(), &[7.0, 7.0]);
        assert_eq!(out[2].f32s().unwrap(), &[5.0, 5.0]);
        // two off-home contributions of 8 bytes each
        assert_eq!(m.get(CommKind::RingP2p), 2 * 8);
    }

    #[test]
    fn reduce_chunks_home_rejects_plan_mismatch() {
        let f = Fabric::new(2, Meter::new());
        let t = Tensor::from_f32(&[1], vec![1.0]).unwrap();
        let parts = vec![vec![Some(t.clone()), None], vec![None, Some(t)]];
        // plan claims rank 1 consumes chunk 0, but rank 1 sent nothing
        let consumers = vec![vec![0, 1], vec![1]];
        assert!(f.reduce_chunks_home(parts, &consumers).is_err());
    }

    #[test]
    fn megatron_boundary_accounting_matches_the_executable_convention() {
        // The one-call analytic boundary must meter exactly what the
        // executable mesh boundary (exec::mesh) meters: scatter C +
        // pipeline C + ring all-gather group total (n-1)*C.
        let m = Meter::new();
        let f = Fabric::new(4, m.clone());
        let act = Tensor::zeros(&[8, 16]); // 512 bytes
        f.pipeline_boundary_megatron(&act);
        assert_eq!(m.get(CommKind::Scatter), 512);
        assert_eq!(m.get(CommKind::Pipeline), 512);
        assert_eq!(m.get(CommKind::AllGather), 3 * 512);
        // degenerate group: a plain send, no split and no gather
        let m1 = Meter::new();
        Fabric::new(1, m1.clone()).pipeline_boundary_megatron(&act);
        assert_eq!(m1.get(CommKind::Pipeline), 512);
        assert_eq!(m1.total_bytes(), 512);
    }

    #[test]
    fn posted_shift_is_eager_and_byte_identical_on_the_fabric() {
        // the default post/wait pair must be indistinguishable from the
        // blocking shift: same rotation, same metered bytes, same op count
        let m = Meter::new();
        let f = Fabric::new(4, m.clone());
        let s = slots(4, 8);
        let h = Collective::ring_shift_post(&f, &s).unwrap();
        let rotated = Collective::ring_shift_wait(&f, h).unwrap();
        assert_eq!(rotated[1].f32s().unwrap()[0], 1.0);
        assert_eq!(rotated[0].f32s().unwrap()[0], 4.0);
        let m2 = Meter::new();
        let f2 = Fabric::new(4, m2.clone());
        let mut s2 = slots(4, 8);
        f2.ring_shift(&mut s2).unwrap();
        assert_eq!(rotated, s2);
        assert_eq!(m.snapshot(), m2.snapshot(), "post/wait must meter like the blocking shift");
    }

    #[test]
    fn wait_rejects_foreign_pending_handle() {
        // a pending handle can only be redeemed by the fabric that posted
        // it; the eager fabric never produces one, so receiving one is an
        // error, not a hang
        let f = Fabric::new(2, Meter::new());
        let h = ShiftHandle::pending(64, crate::obs::begin());
        assert!(Collective::ring_shift_wait(&f, h).is_err());
    }

    #[test]
    fn meter_reset() {
        let m = Meter::new();
        m.add(CommKind::Pipeline, 100);
        assert_eq!(m.total_bytes(), 100);
        m.reset();
        assert_eq!(m.total_bytes(), 0);
    }

    #[test]
    fn per_kind_op_counts_track_adds() {
        let m = Meter::new();
        m.add(CommKind::RingP2p, 10);
        m.add(CommKind::RingP2p, 10);
        m.add(CommKind::AllToAll, 5);
        assert_eq!(m.get_ops(CommKind::RingP2p), 2);
        assert_eq!(m.get_ops(CommKind::AllToAll), 1);
        assert_eq!(m.get_ops(CommKind::Broadcast), 0);
        // the aggregate op counter is the sum of the per-kind ones
        assert_eq!(m.kind_ops().iter().map(|(_, o)| o).sum::<u64>(), m.snapshot().ops);
        m.reset();
        assert_eq!(m.kind_ops().iter().map(|(_, o)| o).sum::<u64>(), 0);
    }
}
