//! Host-side tensor ops used by the coordinator.
//!
//! Heavy math lives in the HLO artifacts; what remains host-side is the
//! glue the ring schedule needs — slicing score rows per chunk, assembling
//! full rows from ring parts, and elementwise accumulation for gradient
//! reduction.  Everything here is O(bytes) copies or adds, no GEMMs.

use anyhow::{bail, Result};

use super::Tensor;

/// Slice the LAST dimension: rows keep their order, columns `[lo, hi)`.
/// Used to cut `P[..., i*Lc..(i+1)*Lc]` for the Ring-AV stage.
pub fn slice_last(t: &Tensor, lo: usize, hi: usize) -> Result<Tensor> {
    let last = *t.shape.last().ok_or_else(|| anyhow::anyhow!("scalar has no last dim"))?;
    if lo >= hi || hi > last {
        bail!("slice [{lo}, {hi}) out of last dim {last}");
    }
    let rows: usize = t.shape[..t.shape.len() - 1].iter().product();
    let width = hi - lo;
    let src = t.f32s()?;
    let mut out = Vec::with_capacity(rows * width);
    for r in 0..rows {
        let base = r * last;
        out.extend_from_slice(&src[base + lo..base + hi]);
    }
    let mut shape = t.shape.clone();
    *shape.last_mut().unwrap() = width;
    Tensor::from_f32(&shape, out)
}

/// Concatenate along the LAST dimension.  Used to assemble the full score
/// rows `S^n in R^{Lc x L}` from the N ring parts.
pub fn concat_last(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        bail!("concat of zero tensors");
    }
    let lead = &parts[0].shape[..parts[0].shape.len() - 1];
    for p in parts {
        if &p.shape[..p.shape.len() - 1] != lead {
            bail!(
                "concat_last: leading dims differ: {:?} vs {:?}",
                parts[0].shape, p.shape
            );
        }
    }
    let rows: usize = lead.iter().product();
    let widths: Vec<usize> = parts.iter().map(|p| *p.shape.last().unwrap()).collect();
    let total: usize = widths.iter().sum();
    let mut out = Vec::with_capacity(rows * total);
    let srcs: Vec<&[f32]> = parts
        .iter()
        .map(|p| p.f32s())
        .collect::<Result<_>>()?;
    for r in 0..rows {
        for (src, w) in srcs.iter().zip(&widths) {
            out.extend_from_slice(&src[r * w..(r + 1) * w]);
        }
    }
    let mut shape = lead.to_vec();
    shape.push(total);
    Tensor::from_f32(&shape, out)
}

/// Concatenate along dimension `dim` (used to reassemble hidden states
/// `[B, Lc, H]` chunks into `[B, L, H]` for verification).
pub fn concat_dim(parts: &[&Tensor], dim: usize) -> Result<Tensor> {
    if parts.is_empty() {
        bail!("concat of zero tensors");
    }
    let nd = parts[0].shape.len();
    if dim >= nd {
        bail!("concat dim {dim} out of rank {nd}");
    }
    // treat as [outer, dim, inner]
    let outer: usize = parts[0].shape[..dim].iter().product();
    let inner: usize = parts[0].shape[dim + 1..].iter().product();
    for p in parts {
        if p.shape.len() != nd
            || p.shape[..dim] != parts[0].shape[..dim]
            || p.shape[dim + 1..] != parts[0].shape[dim + 1..]
        {
            bail!("concat_dim: incompatible shapes {:?} vs {:?}", parts[0].shape, p.shape);
        }
    }
    let dims: Vec<usize> = parts.iter().map(|p| p.shape[dim]).collect();
    let total: usize = dims.iter().sum();
    let srcs: Vec<&[f32]> = parts.iter().map(|p| p.f32s()).collect::<Result<_>>()?;
    let mut out = Vec::with_capacity(outer * total * inner);
    for o in 0..outer {
        for (src, &d) in srcs.iter().zip(&dims) {
            let base = o * d * inner;
            out.extend_from_slice(&src[base..base + d * inner]);
        }
    }
    let mut shape = parts[0].shape.clone();
    shape[dim] = total;
    Tensor::from_f32(&shape, out)
}

/// Slice the FIRST dimension: rows `[lo, hi)` (contiguous copy).
/// Used to cut per-device position-embedding slices and Megatron row-split
/// weight shards.
pub fn slice_dim0(t: &Tensor, lo: usize, hi: usize) -> Result<Tensor> {
    let first = *t.shape.first().ok_or_else(|| anyhow::anyhow!("scalar has no dims"))?;
    if lo >= hi || hi > first {
        bail!("slice_dim0 [{lo}, {hi}) out of first dim {first}");
    }
    let inner: usize = t.shape[1..].iter().product();
    let mut shape = t.shape.clone();
    shape[0] = hi - lo;
    match &t.data {
        super::TData::F32(src) => {
            Tensor::from_f32(&shape, src[lo * inner..hi * inner].to_vec())
        }
        super::TData::I32(src) => {
            Tensor::from_i32(&shape, src[lo * inner..hi * inner].to_vec())
        }
    }
}

/// `dst[lo..hi, ...] += src` over the first dimension (gradient scatter
/// for row-split weight shards and pos-emb slices).
pub fn add_into_dim0(dst: &mut Tensor, src: &Tensor, lo: usize) -> Result<()> {
    let inner: usize = dst.shape[1..].iter().product();
    if src.shape[1..] != dst.shape[1..] {
        bail!("add_into_dim0 inner mismatch: {:?} vs {:?}", src.shape, dst.shape);
    }
    let rows = src.shape[0];
    if lo + rows > dst.shape[0] {
        bail!("add_into_dim0 rows [{lo}, {}) out of {}", lo + rows, dst.shape[0]);
    }
    let s = src.f32s()?.to_vec();
    let d = dst.f32s_mut()?;
    for (i, v) in s.iter().enumerate() {
        d[lo * inner + i] += v;
    }
    Ok(())
}

/// `dst[..., lo..hi] += src` over the last dimension (gradient scatter for
/// column-split weight shards).
pub fn add_into_last(dst: &mut Tensor, src: &Tensor, lo: usize) -> Result<()> {
    let dlast = *dst.shape.last().unwrap();
    let slast = *src.shape.last().unwrap();
    if dst.shape[..dst.shape.len() - 1] != src.shape[..src.shape.len() - 1] {
        bail!("add_into_last lead mismatch: {:?} vs {:?}", src.shape, dst.shape);
    }
    if lo + slast > dlast {
        bail!("add_into_last cols [{lo}, {}) out of {dlast}", lo + slast);
    }
    let rows: usize = dst.shape[..dst.shape.len() - 1].iter().product();
    let s = src.f32s()?.to_vec();
    let d = dst.f32s_mut()?;
    for r in 0..rows {
        for c in 0..slast {
            d[r * dlast + lo + c] += s[r * slast + c];
        }
    }
    Ok(())
}

/// `dst += src` elementwise (gradient accumulation; all-reduce reduction).
pub fn add_assign(dst: &mut Tensor, src: &Tensor) -> Result<()> {
    if dst.shape != src.shape {
        bail!("add_assign shape mismatch: {:?} vs {:?}", dst.shape, src.shape);
    }
    let s = src.f32s()?.to_vec(); // split borrows
    for (d, s) in dst.f32s_mut()?.iter_mut().zip(s) {
        *d += s;
    }
    Ok(())
}

/// `dst *= c` elementwise (gradient averaging).
pub fn scale_assign(dst: &mut Tensor, c: f32) -> Result<()> {
    for d in dst.f32s_mut()? {
        *d *= c;
    }
    Ok(())
}

/// Column-wise sum of a `[M, N]` tensor -> `[N]` (bias gradients).
pub fn sum_rows(t: &Tensor) -> Result<Tensor> {
    if t.shape.len() != 2 {
        bail!("sum_rows needs rank 2, got {:?}", t.shape);
    }
    let (m, n) = (t.shape[0], t.shape[1]);
    let src = t.f32s()?;
    let mut out = vec![0.0f32; n];
    for r in 0..m {
        for c in 0..n {
            out[c] += src[r * n + c];
        }
    }
    Tensor::from_f32(&[n], out)
}

/// Max |a - b| — the verification metric for golden comparisons.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> Result<f32> {
    if a.shape != b.shape {
        bail!("max_abs_diff shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    }
    Ok(a.f32s()?
        .iter()
        .zip(b.f32s()?)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max))
}

/// Split an f32 tensor into `n` equal chunks along dimension `dim` —
/// the inverse of [`concat_dim`].  This is the transpose step of the
/// all-to-all collective: each rank cuts its tensor into per-peer pieces
/// before the exchange ([`crate::comm::Collective::all_to_all`]).
pub fn chunk_dim(t: &Tensor, dim: usize, n: usize) -> Result<Vec<Tensor>> {
    let nd = t.shape.len();
    if dim >= nd {
        bail!("chunk_dim {dim} out of rank {nd}");
    }
    let d = t.shape[dim];
    if n == 0 || d % n != 0 {
        bail!("dim {dim} size {d} not divisible into {n} chunks");
    }
    let dc = d / n;
    let outer: usize = t.shape[..dim].iter().product();
    let inner: usize = t.shape[dim + 1..].iter().product();
    let src = t.f32s()?;
    let mut shape = t.shape.clone();
    shape[dim] = dc;
    let mut chunks = Vec::with_capacity(n);
    for c in 0..n {
        let mut out = Vec::with_capacity(outer * dc * inner);
        for o in 0..outer {
            let base = (o * d + c * dc) * inner;
            out.extend_from_slice(&src[base..base + dc * inner]);
        }
        chunks.push(Tensor::from_f32(&shape, out)?);
    }
    Ok(chunks)
}

/// Split a `[B, L, ...]`-shaped tensor into `n` chunks along dim 1.
/// This is the input router: how the coordinator shards a batch of
/// sequences across the ring devices.
pub fn chunk_dim1(t: &Tensor, n: usize) -> Result<Vec<Tensor>> {
    if t.shape.len() < 2 {
        bail!("chunk_dim1 needs rank >= 2, got {:?}", t.shape);
    }
    let l = t.shape[1];
    if l % n != 0 {
        bail!("dim1 {l} not divisible by {n} devices");
    }
    let lc = l / n;
    let b = t.shape[0];
    let inner: usize = t.shape[2..].iter().product();
    let mut chunks = Vec::with_capacity(n);
    match &t.data {
        super::TData::F32(src) => {
            for c in 0..n {
                let mut out = Vec::with_capacity(b * lc * inner);
                for bi in 0..b {
                    let base = (bi * l + c * lc) * inner;
                    out.extend_from_slice(&src[base..base + lc * inner]);
                }
                let mut shape = t.shape.clone();
                shape[1] = lc;
                chunks.push(Tensor::from_f32(&shape, out)?);
            }
        }
        super::TData::I32(src) => {
            for c in 0..n {
                let mut out = Vec::with_capacity(b * lc * inner);
                for bi in 0..b {
                    let base = (bi * l + c * lc) * inner;
                    out.extend_from_slice(&src[base..base + lc * inner]);
                }
                let mut shape = t.shape.clone();
                shape[1] = lc;
                chunks.push(Tensor::from_i32(&shape, out)?);
            }
        }
    }
    Ok(chunks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2x4() -> Tensor {
        Tensor::from_f32(&[2, 4], (0..8).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn slice_last_cuts_columns() {
        let s = slice_last(&t2x4(), 1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.f32s().unwrap(), &[1.0, 2.0, 5.0, 6.0]);
        assert!(slice_last(&t2x4(), 3, 3).is_err());
        assert!(slice_last(&t2x4(), 2, 5).is_err());
    }

    #[test]
    fn concat_last_inverts_slicing() {
        let t = t2x4();
        let a = slice_last(&t, 0, 2).unwrap();
        let b = slice_last(&t, 2, 4).unwrap();
        assert_eq!(concat_last(&[&a, &b]).unwrap(), t);
    }

    #[test]
    fn concat_dim_middle() {
        // [1,2,2] ++ [1,1,2] along dim 1
        let a = Tensor::from_f32(&[1, 2, 2], vec![0., 1., 2., 3.]).unwrap();
        let b = Tensor::from_f32(&[1, 1, 2], vec![9., 8.]).unwrap();
        let c = concat_dim(&[&a, &b], 1).unwrap();
        assert_eq!(c.shape, vec![1, 3, 2]);
        assert_eq!(c.f32s().unwrap(), &[0., 1., 2., 3., 9., 8.]);
    }

    #[test]
    fn chunk_dim1_shards_sequences() {
        // [2 batch, 4 seq] i32 ids
        let t = Tensor::from_i32(&[2, 4], (0..8).collect()).unwrap();
        let c = chunk_dim1(&t, 2).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].i32s().unwrap(), &[0, 1, 4, 5]);
        assert_eq!(c[1].i32s().unwrap(), &[2, 3, 6, 7]);
        assert!(chunk_dim1(&t, 3).is_err());
    }

    #[test]
    fn chunk_dim_splits_any_axis_and_inverts_concat() {
        let t = Tensor::from_f32(&[2, 4, 3], (0..24).map(|i| i as f32).collect()).unwrap();
        for dim in 0..3 {
            let n = t.shape[dim];
            let chunks = chunk_dim(&t, dim, n).unwrap();
            assert_eq!(chunks.len(), n);
            let refs: Vec<&Tensor> = chunks.iter().collect();
            assert_eq!(concat_dim(&refs, dim).unwrap(), t, "dim {dim}");
        }
        // middle-axis values land in the right chunk
        let c = chunk_dim(&t, 1, 2).unwrap();
        assert_eq!(c[0].shape, vec![2, 2, 3]);
        assert_eq!(c[1].f32s().unwrap()[0], 6.0); // t[0, 2, 0]
        assert!(chunk_dim(&t, 3, 2).is_err());
        assert!(chunk_dim(&t, 1, 3).is_err());
        assert!(chunk_dim(&t, 1, 0).is_err());
    }

    #[test]
    fn chunk_then_concat_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 6, 3], (0..36).map(|i| i as f32).collect()).unwrap();
        let chunks = chunk_dim1(&t, 3).unwrap();
        let refs: Vec<&Tensor> = chunks.iter().collect();
        assert_eq!(concat_dim(&refs, 1).unwrap(), t);
    }

    #[test]
    fn slice_dim0_and_scatter_roundtrip() {
        let t = Tensor::from_f32(&[4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = slice_dim0(&t, 1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.f32s().unwrap(), &[2.0, 3.0, 4.0, 5.0]);
        let mut z = Tensor::zeros(&[4, 2]);
        add_into_dim0(&mut z, &s, 1).unwrap();
        assert_eq!(z.f32s().unwrap(), &[0., 0., 2., 3., 4., 5., 0., 0.]);
        // i32 slicing too (ids)
        let i = Tensor::from_i32(&[3], vec![7, 8, 9]).unwrap();
        assert_eq!(slice_dim0(&i, 2, 3).unwrap().i32s().unwrap(), &[9]);
    }

    #[test]
    fn add_into_last_scatters_columns() {
        let t = t2x4();
        let s = slice_last(&t, 1, 3).unwrap();
        let mut z = Tensor::zeros(&[2, 4]);
        add_into_last(&mut z, &s, 1).unwrap();
        assert_eq!(z.f32s().unwrap(), &[0., 1., 2., 0., 0., 5., 6., 0.]);
        assert!(add_into_last(&mut z, &s, 3).is_err());
    }

    #[test]
    fn add_scale_maxdiff() {
        let mut a = t2x4();
        let b = t2x4();
        add_assign(&mut a, &b).unwrap();
        scale_assign(&mut a, 0.5).unwrap();
        assert_eq!(max_abs_diff(&a, &b).unwrap(), 0.0);
        let c = Tensor::zeros(&[2, 4]);
        assert_eq!(max_abs_diff(&a, &c).unwrap(), 7.0);
    }
}
