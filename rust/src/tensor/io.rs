//! SPT1 binary tensor interchange (mirror of python/compile/tensorio.py).
//!
//! Layout (little-endian):
//!   magic  b"SPT1" | dtype u8 (0=f32, 1=i32) | ndim u8 | dims u64*ndim | data

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{TData, Tensor};

const MAGIC: &[u8; 4] = b"SPT1";

pub fn save(path: &Path, t: &Tensor) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    let code: u8 = match t.data {
        TData::F32(_) => 0,
        TData::I32(_) => 1,
    };
    f.write_all(&[code, t.shape.len() as u8])?;
    for &d in &t.shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    match &t.data {
        TData::F32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        TData::I32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Tensor> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {magic:?}", path.display());
    }
    let mut hdr = [0u8; 2];
    f.read_exact(&mut hdr)?;
    let (code, ndim) = (hdr[0], hdr[1] as usize);
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut b = [0u8; 8];
        f.read_exact(&mut b)?;
        shape.push(u64::from_le_bytes(b) as usize);
    }
    let numel: usize = shape.iter().product();
    let mut raw = vec![0u8; numel * 4];
    f.read_exact(&mut raw)?;
    let data = match code {
        0 => TData::F32(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        1 => TData::I32(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        _ => bail!("{}: unknown dtype code {code}", path.display()),
    };
    Ok(Tensor { shape, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32_and_i32() {
        let dir = std::env::temp_dir();
        let t = Tensor::from_f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -0.5]).unwrap();
        let p = dir.join("spt1_test_f32.tensor");
        save(&p, &t).unwrap();
        assert_eq!(load(&p).unwrap(), t);

        let i = Tensor::from_i32(&[4], vec![-7, 0, 1, i32::MAX]).unwrap();
        let p2 = dir.join("spt1_test_i32.tensor");
        save(&p2, &i).unwrap();
        assert_eq!(load(&p2).unwrap(), i);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir();
        let p = dir.join("spt1_bad.tensor");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let dir = std::env::temp_dir();
        let t = Tensor::scalar(42.5);
        let p = dir.join("spt1_scalar.tensor");
        save(&p, &t).unwrap();
        let r = load(&p).unwrap();
        assert_eq!(r.shape, Vec::<usize>::new());
        assert_eq!(r.scalar_f32().unwrap(), 42.5);
    }
}
