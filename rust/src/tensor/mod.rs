//! Host tensors.
//!
//! The coordinator moves data between artifacts, the ring fabric, and the
//! optimizer as plain host buffers (the PJRT CPU client shares the host
//! address space, so "device" buffers are host memory anyway).  Two dtypes
//! are enough for the whole system: `f32` activations/params and `i32`
//! ids/labels — mirroring the SPT1 interchange format.

pub mod io;
pub mod ops;

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TData,
}

impl Tensor {
    // ---------------------------------------------------------- constructors
    //
    // Every materializing constructor notes its bytes with
    // `obs::mem::note_alloc` — the allocation-CHURN counter (total bytes
    // ever produced; kernel outputs funnel through these too).  `scalar`
    // and `reshaped` are exempt: one is noise, the other zero-copy.
    // Live/peak RESIDENCY is tracked separately by `obs::mem::Charge`s
    // at the stash/param choke points.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        crate::obs::mem::note_alloc(n * 4);
        Tensor { shape: shape.to_vec(), data: TData::F32(vec![0.0; n]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        crate::obs::mem::note_alloc(n * 4);
        Ok(Tensor { shape: shape.to_vec(), data: TData::F32(data) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        crate::obs::mem::note_alloc(n * 4);
        Ok(Tensor { shape: shape.to_vec(), data: TData::I32(data) })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: TData::F32(vec![v]) }
    }

    /// N(0, std) init from the deterministic PRNG.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        crate::obs::mem::note_alloc(n * 4);
        Tensor { shape: shape.to_vec(), data: TData::F32(data) }
    }

    // --------------------------------------------------------------- access
    pub fn dtype(&self) -> DType {
        match self.data {
            TData::F32(_) => DType::F32,
            TData::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes this tensor occupies (both dtypes are 4-byte).
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            TData::F32(v) => Ok(v),
            TData::I32(_) => bail!("expected f32 tensor, got i32 (shape {:?})", self.shape),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TData::F32(v) => Ok(v),
            TData::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            TData::I32(v) => Ok(v),
            TData::F32(_) => bail!("expected i32 tensor, got f32 (shape {:?})", self.shape),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.f32s()?;
        if d.len() != 1 {
            bail!("expected scalar, shape is {:?}", self.shape);
        }
        Ok(d[0])
    }

    /// Reinterpret with a new shape of equal element count (zero-copy).
    pub fn reshaped(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            bail!("cannot reshape {:?} -> {shape:?}", self.shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn constructors_validate_shape() {
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::from_i32(&[2], vec![1, 2]).is_ok());
    }

    #[test]
    fn dtype_accessors_guard() {
        let f = Tensor::zeros(&[2]);
        assert!(f.f32s().is_ok());
        assert!(f.i32s().is_err());
        let i = Tensor::from_i32(&[1], vec![3]).unwrap();
        assert!(i.i32s().is_ok());
        assert!(i.f32s().is_err());
    }

    #[test]
    fn randn_is_deterministic_and_scaled() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Tensor::randn(&[64, 64], 0.02, &mut r1);
        let b = Tensor::randn(&[64, 64], 0.02, &mut r2);
        assert_eq!(a, b);
        let std = {
            let v = a.f32s().unwrap();
            let m: f32 = v.iter().sum::<f32>() / v.len() as f32;
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / v.len() as f32).sqrt()
        };
        assert!((std - 0.02).abs() < 0.002, "std {std}");
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_f32(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.clone().reshaped(&[3, 2]).unwrap();
        assert_eq!(r.f32s().unwrap(), t.f32s().unwrap());
        assert!(t.reshaped(&[4, 2]).is_err());
    }
}
