//! Runtime observability: per-rank span timelines, Chrome-trace export
//! and measured comm/compute/bubble attribution.
//!
//! The static [`crate::analysis`] layer proves what bytes *must* move;
//! this module measures where a step's wall-clock actually *goes*.  It is
//! a span recorder with three design constraints:
//!
//! * **Zero heap work when disabled.**  [`begin`] is a single relaxed
//!   atomic load when recording is off; every `end_*` on a dead
//!   [`Span`] is a no-op.  The overhead contract is asserted by
//!   `benches/obs_overhead.rs` (spans-per-step × disabled-span cost must
//!   stay under step-time noise).
//! * **One clock discipline.**  All timestamps are nanoseconds since a
//!   process-wide monotonic epoch ([`now_ns`]); the [`Stopwatch`] used by
//!   the trainer, the bench harness and the native backend reads the
//!   same clock, so every reported duration is comparable.
//! * **Trace events are metering-anchored.**  Every comm event is
//!   emitted exactly where the [`crate::comm::Meter`] records the op
//!   ([`crate::comm::Meter::add_traced`]), so per-[`CommKind`] event
//!   counts and byte totals equal the meter's per-kind op/byte counters
//!   *by construction*, under both the sequential `Fabric` and the
//!   threaded `RingComm` conventions.  [`cross_check`] asserts it.
//!
//! # Thread model
//!
//! Recording is scoped by a [`Recorder`] session (a global lock — one
//! session at a time; tests serialize through it).  Events are buffered
//! thread-locally — no locking on the hot path — and merged into a
//! global sink at rank join: rank threads spawned by `exec::DistRunner`
//! / `exec::MeshRunner` inherit the session through a [`ForkHandle`]
//! captured on the spawning thread ([`fork`]), tag themselves with their
//! global rank ([`adopt`]), and [`flush`] their buffer before the scope
//! joins.  Threads that never adopted the live session record nothing,
//! so concurrent un-instrumented work cannot contaminate a trace.
//!
//! Blocking channel receives on the threaded path wrap themselves in a
//! [`Waiter`], which accumulates *wait* nanoseconds into the thread's
//! counter; a comm span reports `dur − wait` as transfer/compute and
//! `wait` as time spent blocked on a peer.
//!
//! # Exports
//!
//! [`chrome_trace`] renders events in Chrome trace format (one pid per
//! rank, `ph:"X"` complete events, args carrying bytes/kind) for
//! Perfetto / `chrome://tracing`; [`validate_chrome_trace`] schema-checks
//! a parsed file.  [`MetricsReport`] aggregates a trace into step wall
//! time, per-kind comm busy/wait totals, top-k kernels and the measured
//! GPipe bubble fraction ([`bubble_fraction`]), which converges on the
//! closed form `(s-1)/(m+s-1)` from [`crate::parallel::pipeline`].

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::comm::{CommKind, Meter};
use crate::util::json::{encode, Value};

pub mod mem;

// ---------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Current live session id (0 = none).  Monotonic: never reused.
static SESSION_ID: AtomicU64 = AtomicU64::new(0);
static SESSION_CTR: AtomicU64 = AtomicU64::new(0);
/// One recording session at a time (tests serialize through this).
static SESSION_LOCK: Mutex<()> = Mutex::new(());
/// Rank buffers merged here at flush.
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

#[derive(Default)]
struct Tls {
    session: u64,
    rank: usize,
    wait_ns: u64,
    events: Vec<Event>,
}

thread_local! {
    static TLS: RefCell<Tls> = RefCell::new(Tls::default());
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Nanoseconds since the process-wide monotonic epoch (pinned on first
/// use).  Every duration in the crate — spans, trainer step times, bench
/// iterations, backend kernel stats — derives from this one clock.
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Is a recording session live?  (Cheap: one relaxed atomic load.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------

/// What a span measured.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// One `Executor::call`: artifact name + total input/output bytes.
    Kernel { name: String, bytes: u64 },
    /// One metered collective op: kind, payload bytes (the meter's own
    /// accounting convention), and nanoseconds spent blocked on a
    /// channel recv inside the op (0 on the sequential fabric).
    Comm { kind: CommKind, bytes: u64, wait_ns: u64 },
    /// A named algorithm phase (`sp_embed_fwd`, `ring_hop`, `optimizer`,
    /// `step`, …); `index` disambiguates repeats (hop t, layer l).
    Phase { name: &'static str, index: Option<usize> },
    /// One GPipe cell (stage, microbatch, direction); `wait_ns` is recv
    /// blocking inside the cell so `dur − wait` is true busy time.
    Cell { stage: usize, micro: usize, forward: bool, wait_ns: u64 },
}

/// One recorded span: `[t0_ns, t0_ns + dur_ns]` on rank `rank`'s
/// timeline (ranks map to Chrome-trace pids).
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub rank: usize,
    pub t0_ns: u64,
    pub dur_ns: u64,
    pub kind: EventKind,
}

impl Event {
    /// Display name (also the Chrome-trace event name).
    pub fn name(&self) -> String {
        match &self.kind {
            EventKind::Kernel { name, .. } => name.clone(),
            EventKind::Comm { kind, .. } => format!("{kind:?}"),
            EventKind::Phase { name, index: None } => (*name).to_string(),
            EventKind::Phase { name, index: Some(i) } => format!("{name}:{i}"),
            EventKind::Cell { stage, micro, forward, .. } => {
                format!("cell s{stage} m{micro} {}", if *forward { "fwd" } else { "bwd" })
            }
        }
    }

    fn cat(&self) -> &'static str {
        match self.kind {
            EventKind::Kernel { .. } => "kernel",
            EventKind::Comm { .. } => "comm",
            EventKind::Phase { .. } => "phase",
            EventKind::Cell { .. } => "cell",
        }
    }
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// An open span.  Obtain with [`begin`]; close with exactly one `end_*`.
/// A span begun outside a live session (or on a thread that did not
/// [`adopt`] it) is dead: ending it does nothing, dropping it is free.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    live: bool,
    t0: u64,
    wait0: u64,
}

/// Open a span.  When recording is disabled this is one atomic load and
/// no heap work; the returned span is dead.
pub fn begin() -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { live: false, t0: 0, wait0: 0 };
    }
    let sid = SESSION_ID.load(Ordering::Relaxed);
    TLS.with(|t| {
        let t = t.borrow();
        if sid == 0 || t.session != sid {
            return Span { live: false, t0: 0, wait0: 0 };
        }
        Span { live: true, t0: now_ns(), wait0: t.wait_ns }
    })
}

impl Span {
    fn push(self, kind_of: impl FnOnce(u64) -> EventKind) {
        if !self.live {
            return;
        }
        let now = now_ns();
        TLS.with(|t| {
            let mut t = t.borrow_mut();
            let wait = t.wait_ns.saturating_sub(self.wait0);
            let ev = Event {
                rank: t.rank,
                t0_ns: self.t0,
                dur_ns: now.saturating_sub(self.t0),
                kind: kind_of(wait),
            };
            t.events.push(ev);
        });
    }

    /// Close as a kernel-call event.
    pub fn end_kernel(self, name: &str, bytes: u64) {
        if !self.live {
            return;
        }
        let name = name.to_string();
        self.push(|_| EventKind::Kernel { name, bytes });
    }

    /// Close as a collective event; the wait split is the growth of the
    /// thread's [`Waiter`] counter while the span was open.
    pub fn end_comm(self, kind: CommKind, bytes: u64) {
        self.push(|wait_ns| EventKind::Comm { kind, bytes, wait_ns });
    }

    /// Close as an algorithm phase.
    pub fn end_phase(self, name: &'static str) {
        self.push(|_| EventKind::Phase { name, index: None });
    }

    /// Close as an indexed phase (ring hop t, layer l, …).
    pub fn end_phase_idx(self, name: &'static str, index: usize) {
        self.push(|_| EventKind::Phase { name, index: Some(index) });
    }

    /// Close as a GPipe cell (stage, microbatch, direction).
    pub fn end_cell(self, stage: usize, micro: usize, forward: bool) {
        self.push(|wait_ns| EventKind::Cell { stage, micro, forward, wait_ns });
    }
}

/// Accumulates time spent blocked on a channel recv into the thread's
/// wait counter, so enclosing comm/cell spans can report a wait-vs-work
/// split.  Dead (one atomic load) outside a live session.
#[derive(Clone, Copy, Debug)]
pub struct Waiter {
    live: bool,
    t0: u64,
}

/// Start timing a blocking wait.
pub fn wait_begin() -> Waiter {
    if !ENABLED.load(Ordering::Relaxed) {
        return Waiter { live: false, t0: 0 };
    }
    let sid = SESSION_ID.load(Ordering::Relaxed);
    let live = sid != 0 && TLS.with(|t| t.borrow().session == sid);
    Waiter { live, t0: if live { now_ns() } else { 0 } }
}

impl Waiter {
    /// The wait is over; credit it to the thread's wait counter.
    pub fn end(self) {
        if !self.live {
            return;
        }
        let dt = now_ns().saturating_sub(self.t0);
        TLS.with(|t| t.borrow_mut().wait_ns += dt);
    }
}

// ---------------------------------------------------------------------
// Stopwatch — the one timer (trainer, bench harness, backend stats)
// ---------------------------------------------------------------------

/// A plain stopwatch over the [`now_ns`] clock.  Always runs (it does
/// not record events and needs no session) — this is the unified
/// replacement for the ad-hoc `Instant::now()` timers.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: u64,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: now_ns() }
    }

    pub fn elapsed_ns(&self) -> u64 {
        now_ns().saturating_sub(self.t0)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed_ns() as f64 / 1e9
    }
}

// ---------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------

/// A live recording session.  Holds the global session lock (so
/// concurrent tests serialize), enables recording on construction and
/// disables it on [`Recorder::finish`] / drop.  The calling thread is
/// rank 0; spawned rank threads join via [`fork`] / [`adopt`] /
/// [`flush`].
pub struct Recorder {
    _lock: MutexGuard<'static, ()>,
}

impl Recorder {
    /// Begin recording.  Blocks until any other session has finished.
    pub fn start() -> Recorder {
        let guard = lock(&SESSION_LOCK);
        let id = SESSION_CTR.fetch_add(1, Ordering::Relaxed) + 1;
        lock(&SINK).clear();
        TLS.with(|t| {
            let mut t = t.borrow_mut();
            t.session = id;
            t.rank = 0;
            t.wait_ns = 0;
            t.events.clear();
        });
        SESSION_ID.store(id, Ordering::SeqCst);
        ENABLED.store(true, Ordering::SeqCst);
        Recorder { _lock: guard }
    }

    /// Stop recording and return every event, merged across ranks and
    /// sorted by `(rank, t0)`.
    pub fn finish(self) -> Vec<Event> {
        flush();
        ENABLED.store(false, Ordering::SeqCst);
        SESSION_ID.store(0, Ordering::SeqCst);
        let mut events = std::mem::take(&mut *lock(&SINK));
        events.sort_by(|a, b| (a.rank, a.t0_ns).cmp(&(b.rank, b.t0_ns)));
        events
    }
}

impl Drop for Recorder {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        SESSION_ID.store(0, Ordering::SeqCst);
    }
}

/// A capability to record into the current session from another thread.
/// Capture on the session thread with [`fork`]; pass into the spawned
/// closure; redeem with [`adopt`].
#[derive(Clone, Copy, Debug)]
pub struct ForkHandle {
    session: u64,
}

/// Capture the calling thread's session (dead handle if none live).
pub fn fork() -> ForkHandle {
    if !ENABLED.load(Ordering::Relaxed) {
        return ForkHandle { session: 0 };
    }
    let sid = SESSION_ID.load(Ordering::Relaxed);
    let mine = TLS.with(|t| t.borrow().session);
    ForkHandle { session: if sid != 0 && mine == sid { sid } else { 0 } }
}

/// Join the handle's session as global rank `rank` (one pid per rank in
/// the exported trace).  A dead or stale handle leaves the thread
/// un-adopted: it records nothing.
pub fn adopt(h: ForkHandle, rank: usize) {
    if h.session == 0 || h.session != SESSION_ID.load(Ordering::Relaxed) {
        return;
    }
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        t.session = h.session;
        t.rank = rank;
        t.wait_ns = 0;
        t.events.clear();
    });
}

/// Merge this thread's buffered events into the session sink.  Rank
/// closures call this right before their scope joins; [`Recorder::finish`]
/// calls it for the session thread.
pub fn flush() {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        if t.session != 0
            && t.session == SESSION_ID.load(Ordering::Relaxed)
            && !t.events.is_empty()
        {
            lock(&SINK).append(&mut t.events);
        } else {
            t.events.clear();
        }
    });
}

// ---------------------------------------------------------------------
// Chrome-trace export
// ---------------------------------------------------------------------

fn num(v: f64) -> Value {
    Value::Num(v)
}

fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Render events in Chrome trace format (the object form with a
/// `traceEvents` array): one pid per rank with a `process_name`
/// metadata record, `ph:"X"` complete events with microsecond
/// timestamps, and args carrying bytes / kind / wait so Perfetto can
/// render the ring pipeline.
pub fn chrome_trace(events: &[Event]) -> Value {
    let mut out: Vec<Value> = Vec::with_capacity(events.len() + 8);
    let mut ranks: Vec<usize> = events.iter().map(|e| e.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in &ranks {
        out.push(obj(vec![
            ("name", s("process_name")),
            ("ph", s("M")),
            ("pid", num(*r as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", s(format!("rank {r}")))])),
        ]));
    }
    for e in events {
        let args = match &e.kind {
            EventKind::Kernel { bytes, .. } => obj(vec![("bytes", num(*bytes as f64))]),
            EventKind::Comm { kind, bytes, wait_ns } => obj(vec![
                ("kind", s(format!("{kind:?}"))),
                ("bytes", num(*bytes as f64)),
                ("wait_us", num(*wait_ns as f64 / 1e3)),
            ]),
            EventKind::Phase { .. } => obj(vec![]),
            EventKind::Cell { stage, micro, forward, wait_ns } => obj(vec![
                ("stage", num(*stage as f64)),
                ("micro", num(*micro as f64)),
                ("forward", Value::Bool(*forward)),
                ("wait_us", num(*wait_ns as f64 / 1e3)),
            ]),
        };
        out.push(obj(vec![
            ("name", s(e.name())),
            ("cat", s(e.cat())),
            ("ph", s("X")),
            ("ts", num(e.t0_ns as f64 / 1e3)),
            ("dur", num(e.dur_ns as f64 / 1e3)),
            ("pid", num(e.rank as f64)),
            ("tid", num(0.0)),
            ("args", args),
        ]));
    }
    obj(vec![("traceEvents", Value::Arr(out)), ("displayTimeUnit", s("ms"))])
}

/// Serialize a Chrome trace for `events` to `path`.
pub fn write_chrome_trace(path: &Path, events: &[Event]) -> Result<()> {
    let json = encode(&chrome_trace(events));
    std::fs::write(path, json)
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(())
}

/// [`chrome_trace`] plus the memory-counter track: appends one
/// `"ph":"C"` record per [`mem::MemReport`] sample (name `"memory"`,
/// pid = lane, args = per-category live bytes) so the trace viewer
/// shows a stacked memory counter under each rank's span timeline.
pub fn chrome_trace_with_counters(events: &[Event], mem: Option<&mem::MemReport>) -> Value {
    let mut doc = chrome_trace(events);
    if let Some(report) = mem {
        if let Value::Obj(map) = &mut doc {
            if let Some(Value::Arr(records)) = map.get_mut("traceEvents") {
                records.extend(mem::counter_records(report));
            }
        }
    }
    doc
}

/// Serialize a Chrome trace with memory counters to `path`.
pub fn write_chrome_trace_with_counters(
    path: &Path,
    events: &[Event],
    mem: Option<&mem::MemReport>,
) -> Result<()> {
    let json = encode(&chrome_trace_with_counters(events, mem));
    std::fs::write(path, json)
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(())
}

/// Summary of a validated Chrome-trace file.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TraceCheck {
    /// Total records in `traceEvents`.
    pub events: usize,
    /// `ph:"X"` complete events.
    pub complete: usize,
    /// `ph:"M"` metadata records.
    pub meta: usize,
    /// `ph:"C"` counter records (the memory track).
    pub counters: usize,
    /// Distinct pids (ranks), ascending.
    pub pids: Vec<usize>,
    /// Complete-event count per `cat`.
    pub cats: BTreeMap<String, usize>,
}

/// Schema-check a parsed Chrome-trace document: a `traceEvents` array
/// whose records each carry a string `name`/`ph`, numeric `pid`, numeric
/// `ts` and, for `ph:"X"`, a non-negative numeric `dur`; `ph:"C"`
/// counter records (the memory track) must carry numeric `ts` and an
/// object `args` whose values are all numeric series points.
pub fn validate_chrome_trace(doc: &Value) -> Result<TraceCheck> {
    let events = doc
        .req("traceEvents")
        .context("chrome trace: root must be an object with a traceEvents key")?
        .as_arr()
        .context("chrome trace: traceEvents must be an array")?;
    let mut check = TraceCheck::default();
    for (i, e) in events.iter().enumerate() {
        let at = || format!("traceEvents[{i}]");
        if e.as_obj().is_none() {
            bail!("{}: must be an object, got {}", at(), e.type_name());
        }
        let name = e.req("name").with_context(at)?;
        if name.as_str().is_none() {
            bail!("{}: name must be a string", at());
        }
        let ph = e
            .req("ph")
            .with_context(at)?
            .as_str()
            .with_context(|| format!("{}: ph must be a string", at()))?
            .to_string();
        let pid = e
            .req("pid")
            .with_context(at)?
            .as_usize()
            .with_context(|| format!("{}: pid must be a non-negative integer", at()))?;
        check.events += 1;
        match ph.as_str() {
            "X" => {
                e.req("ts")
                    .with_context(at)?
                    .as_f64()
                    .with_context(|| format!("{}: ts must be numeric", at()))?;
                let dur = e
                    .req("dur")
                    .with_context(at)?
                    .as_f64()
                    .with_context(|| format!("{}: dur must be numeric", at()))?;
                if dur < 0.0 {
                    bail!("{}: dur must be non-negative, got {dur}", at());
                }
                check.complete += 1;
                if let Some(cat) = e.get("cat").and_then(|c| c.as_str()) {
                    *check.cats.entry(cat.to_string()).or_insert(0) += 1;
                }
                if !check.pids.contains(&pid) {
                    check.pids.push(pid);
                }
            }
            "M" => check.meta += 1,
            "C" => {
                e.req("ts")
                    .with_context(at)?
                    .as_f64()
                    .with_context(|| format!("{}: ts must be numeric", at()))?;
                let args = e
                    .req("args")
                    .with_context(at)?
                    .as_obj()
                    .with_context(|| format!("{}: counter args must be an object", at()))?;
                for (k, v) in args {
                    if v.as_f64().is_none() {
                        bail!("{}: counter series {k:?} must be numeric", at());
                    }
                }
                check.counters += 1;
                if !check.pids.contains(&pid) {
                    check.pids.push(pid);
                }
            }
            other => bail!("{}: unsupported ph {other:?} (expected X, M or C)", at()),
        }
    }
    check.pids.sort_unstable();
    Ok(check)
}

// ---------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------

/// Per-[`CommKind`] aggregate over a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommAgg {
    pub kind: CommKind,
    /// Trace event count == the meter's per-kind op count.
    pub events: u64,
    /// Payload bytes == the meter's per-kind byte counter.
    pub bytes: u64,
    /// Total span time (includes wait).
    pub busy_ns: u64,
    /// Time blocked on channel recvs inside the spans.
    pub wait_ns: u64,
}

impl CommAgg {
    /// Span time NOT spent blocked on a channel recv — communication the
    /// schedule hid behind compute (plus local copy/protocol work).
    pub fn hidden_ns(&self) -> u64 {
        self.busy_ns.saturating_sub(self.wait_ns)
    }
}

/// Per-kernel aggregate over a trace's kernel events.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelAgg {
    pub name: String,
    pub calls: u64,
    pub total_ns: u64,
}

/// A trace distilled: wall time, throughput, comm attribution, top-k
/// kernels and measured pipeline bubble.
#[derive(Clone, Debug, Default)]
pub struct MetricsReport {
    /// Steps the trace covers.
    pub steps: usize,
    /// Wall time: the sum of `step` phase spans when present, else the
    /// whole event window.
    pub wall_ns: u64,
    /// `tokens / wall` (0 when either is unknown).
    pub tokens_per_sec: f64,
    /// Per-kind comm totals, fixed meter order, kinds with events only.
    pub comm: Vec<CommAgg>,
    /// Kernel totals, descending total time, truncated to top-k.
    pub kernels: Vec<KernelAgg>,
    /// Total kernel time across ALL kernels (not just top-k).
    pub kernel_ns: u64,
    /// Measured GPipe bubble fraction, when the trace has cell events.
    pub bubble: Option<f64>,
    /// Elastic recoveries the trace covers (one `recovery` phase span is
    /// recorded per re-carve by `exec::recovery`).
    pub recoveries: u64,
}

/// Measured pipeline bubble fraction from GPipe cell events:
/// `1 − Σ busy / (lanes × window)` where busy excludes recv wait, the
/// window spans first cell start to last cell end, and a lane is one
/// RANK that recorded cells (on the threaded mesh every pp×mp×dp
/// coordinate runs its stage's schedule, so lanes are ranks, not
/// stages — keying by stage would double-count busy whenever mp or dp
/// exceeds 1).  With uniform forward cells and uniform backward cells
/// this converges on `(s−1)/(m+s−1)` — the closed form pinned by
/// `crate::parallel::pipeline::Schedule::bubble_fraction` — independent
/// of the backward/forward cost ratio.  Compute it from single-step
/// traces; a multi-step window includes optimizer time between waves.
pub fn bubble_fraction(events: &[Event]) -> Option<f64> {
    let mut busy: BTreeMap<usize, u64> = BTreeMap::new();
    let mut t_min = u64::MAX;
    let mut t_max = 0u64;
    for e in events {
        if let EventKind::Cell { wait_ns, .. } = e.kind {
            *busy.entry(e.rank).or_insert(0) += e.dur_ns.saturating_sub(wait_ns);
            t_min = t_min.min(e.t0_ns);
            t_max = t_max.max(e.t0_ns + e.dur_ns);
        }
    }
    if busy.is_empty() || t_max <= t_min {
        return None;
    }
    let window = (t_max - t_min) as f64;
    let lanes = busy.len() as f64;
    let total: u64 = busy.values().sum();
    Some((1.0 - total as f64 / (lanes * window)).clamp(0.0, 1.0))
}

impl MetricsReport {
    /// Aggregate `events` into a report.  `tokens` is the total token
    /// count processed over `steps` (for throughput); `top_k` bounds the
    /// kernel table.
    pub fn build(events: &[Event], steps: usize, tokens: u64, top_k: usize) -> MetricsReport {
        let mut comm: BTreeMap<usize, CommAgg> = BTreeMap::new();
        let mut kernels: BTreeMap<String, KernelAgg> = BTreeMap::new();
        let mut step_ns = 0u64;
        let mut have_steps = false;
        let mut t_min = u64::MAX;
        let mut t_max = 0u64;
        let mut kernel_ns = 0u64;
        let mut recoveries = 0u64;
        for e in events {
            t_min = t_min.min(e.t0_ns);
            t_max = t_max.max(e.t0_ns + e.dur_ns);
            match &e.kind {
                EventKind::Comm { kind, bytes, wait_ns } => {
                    let a = comm.entry(kind_index(*kind)).or_insert(CommAgg {
                        kind: *kind,
                        events: 0,
                        bytes: 0,
                        busy_ns: 0,
                        wait_ns: 0,
                    });
                    a.events += 1;
                    a.bytes += bytes;
                    a.busy_ns += e.dur_ns;
                    a.wait_ns += wait_ns;
                }
                EventKind::Kernel { name, .. } => {
                    let a = kernels.entry(name.clone()).or_insert(KernelAgg {
                        name: name.clone(),
                        calls: 0,
                        total_ns: 0,
                    });
                    a.calls += 1;
                    a.total_ns += e.dur_ns;
                    kernel_ns += e.dur_ns;
                }
                EventKind::Phase { name, .. } if *name == "step" => {
                    have_steps = true;
                    step_ns += e.dur_ns;
                }
                EventKind::Phase { name, .. } if *name == "recovery" => {
                    recoveries += 1;
                }
                _ => {}
            }
        }
        let wall_ns = if have_steps {
            step_ns
        } else if t_max > t_min {
            t_max - t_min
        } else {
            0
        };
        let mut kernels: Vec<KernelAgg> = kernels.into_values().collect();
        kernels.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.name.cmp(&b.name)));
        kernels.truncate(top_k);
        MetricsReport {
            steps,
            wall_ns,
            tokens_per_sec: if wall_ns > 0 {
                tokens as f64 / (wall_ns as f64 / 1e9)
            } else {
                0.0
            },
            comm: comm.into_values().collect(),
            kernels,
            kernel_ns,
            bubble: bubble_fraction(events),
            recoveries,
        }
    }

    /// Overlap efficiency: the fraction of total comm span time the
    /// schedule hid from the critical path, `Σ(busy − wait) / Σ busy`
    /// over every comm kind.  A posted (nonblocking) shift whose payload
    /// arrived during compute waits ~0ns, so its span counts as hidden;
    /// a blocking shift's span is dominated by recv wait.  `None` when
    /// the trace has no comm span time to attribute.
    pub fn overlap_efficiency(&self) -> Option<f64> {
        let busy: u64 = self.comm.iter().map(|a| a.busy_ns).sum();
        if busy == 0 {
            return None;
        }
        let hidden: u64 = self.comm.iter().map(|a| a.hidden_ns()).sum();
        Some(hidden as f64 / busy as f64)
    }

    /// Render the report as a JSON tree (the `BENCH_obs.json` payload).
    pub fn to_json(&self) -> Value {
        let comm = self
            .comm
            .iter()
            .map(|a| {
                (
                    format!("{:?}", a.kind),
                    obj(vec![
                        ("events", num(a.events as f64)),
                        ("bytes", num(a.bytes as f64)),
                        ("busy_ns", num(a.busy_ns as f64)),
                        ("wait_ns", num(a.wait_ns as f64)),
                    ]),
                )
            })
            .collect();
        let kernels = self
            .kernels
            .iter()
            .map(|k| {
                obj(vec![
                    ("name", s(k.name.clone())),
                    ("calls", num(k.calls as f64)),
                    ("total_ns", num(k.total_ns as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("steps", num(self.steps as f64)),
            ("wall_ns", num(self.wall_ns as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec)),
            ("kernel_ns", num(self.kernel_ns as f64)),
            ("recoveries", num(self.recoveries as f64)),
            ("comm", Value::Obj(comm)),
            ("kernels_top", Value::Arr(kernels)),
            (
                "bubble",
                self.bubble.map(Value::Num).unwrap_or(Value::Null),
            ),
            (
                "overlap_efficiency",
                self.overlap_efficiency()
                    .map(Value::Num)
                    .unwrap_or(Value::Null),
            ),
        ])
    }
}

impl std::fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "steps: {}   wall: {:.3} ms   tokens/sec: {:.0}",
            self.steps,
            self.wall_ns as f64 / 1e6,
            self.tokens_per_sec
        )?;
        writeln!(f, "kernel time (all ranks): {:.3} ms", self.kernel_ns as f64 / 1e6)?;
        if let Some(b) = self.bubble {
            writeln!(f, "measured pipeline bubble: {b:.4}")?;
        }
        if self.recoveries > 0 {
            writeln!(f, "elastic recoveries: {}", self.recoveries)?;
        }
        if let Some(eff) = self.overlap_efficiency() {
            writeln!(f, "comm overlap efficiency: {eff:.4}")?;
        }
        if !self.comm.is_empty() {
            writeln!(
                f,
                "{:<10} {:>8} {:>14} {:>12} {:>12}",
                "comm", "events", "bytes", "busy ms", "wait ms"
            )?;
            for a in &self.comm {
                writeln!(
                    f,
                    "{:<10} {:>8} {:>14} {:>12.3} {:>12.3}",
                    format!("{:?}", a.kind),
                    a.events,
                    a.bytes,
                    a.busy_ns as f64 / 1e6,
                    a.wait_ns as f64 / 1e6
                )?;
            }
        }
        if !self.kernels.is_empty() {
            writeln!(f, "{:<26} {:>8} {:>12} {:>8}", "kernel (top-k)", "calls", "total ms", "share")?;
            for k in &self.kernels {
                writeln!(
                    f,
                    "{:<26} {:>8} {:>12.3} {:>7.1}%",
                    k.name,
                    k.calls,
                    k.total_ns as f64 / 1e6,
                    if self.kernel_ns > 0 {
                        100.0 * k.total_ns as f64 / self.kernel_ns as f64
                    } else {
                        0.0
                    }
                )?;
            }
        }
        Ok(())
    }
}

fn kind_index(kind: CommKind) -> usize {
    match kind {
        CommKind::RingP2p => 0,
        CommKind::AllReduce => 1,
        CommKind::AllGather => 2,
        CommKind::AllToAll => 3,
        CommKind::Broadcast => 4,
        CommKind::Scatter => 5,
        CommKind::Pipeline => 6,
    }
}

// ---------------------------------------------------------------------
// Trace/meter cross-check — the measured-vs-metered invariant
// ---------------------------------------------------------------------

/// One row of the trace/meter comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommCheckRow {
    pub kind: CommKind,
    pub trace_events: u64,
    pub trace_bytes: u64,
    pub meter_ops: u64,
    pub meter_bytes: u64,
}

/// Compare a trace's per-[`CommKind`] event counts and byte totals
/// against a [`Meter`]'s per-kind op and byte counters.  They must be
/// EQUAL: every comm event is emitted at the op's metering point
/// ([`Meter::add_traced`]), so any divergence means an instrumentation
/// bug.  Returns the comparison table; errors on the first mismatch.
pub fn cross_check(events: &[Event], meter: &Meter) -> Result<Vec<CommCheckRow>> {
    let mut trace: BTreeMap<usize, (u64, u64)> = BTreeMap::new();
    for e in events {
        if let EventKind::Comm { kind, bytes, .. } = e.kind {
            let t = trace.entry(kind_index(kind)).or_insert((0, 0));
            t.0 += 1;
            t.1 += bytes;
        }
    }
    let mut rows = Vec::new();
    for (kind, meter_ops) in meter.kind_ops() {
        let (trace_events, trace_bytes) =
            trace.get(&kind_index(kind)).copied().unwrap_or((0, 0));
        let meter_bytes = meter.get(kind);
        let row = CommCheckRow { kind, trace_events, trace_bytes, meter_ops, meter_bytes };
        if trace_events != meter_ops {
            bail!(
                "trace/meter mismatch for {kind:?}: {trace_events} trace events vs {meter_ops} metered ops"
            );
        }
        if trace_bytes != meter_bytes {
            bail!(
                "trace/meter mismatch for {kind:?}: {trace_bytes} trace bytes vs {meter_bytes} metered bytes"
            );
        }
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        // no session: spans are dead, waiters free
        let sp = begin();
        assert!(!sp.live);
        sp.end_phase("nothing");
        let w = wait_begin();
        w.end();
        flush();
        assert!(!enabled());
    }

    #[test]
    fn session_records_merges_and_sorts() {
        let rec = Recorder::start();
        assert!(enabled());
        let sp = begin();
        sp.end_phase("step");
        // rank thread joins via fork/adopt/flush
        let h = fork();
        std::thread::scope(|scope| {
            scope.spawn(move || {
                adopt(h, 3);
                let sp = begin();
                sp.end_kernel("matmul", 128);
                flush();
            });
        });
        let events = rec.finish();
        assert!(!enabled());
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].rank, 0);
        assert_eq!(events[1].rank, 3);
        assert_eq!(
            events[1].kind,
            EventKind::Kernel { name: "matmul".into(), bytes: 128 }
        );
        // a fresh session starts clean
        let rec2 = Recorder::start();
        assert!(rec2.finish().is_empty());
    }

    #[test]
    fn unadopted_threads_do_not_contaminate() {
        let rec = Recorder::start();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                // never adopted: everything it does is invisible
                let sp = begin();
                sp.end_phase("ghost");
                flush();
            });
        });
        assert!(rec.finish().is_empty());
    }

    #[test]
    fn waiter_splits_comm_time() {
        let rec = Recorder::start();
        let sp = begin();
        let w = wait_begin();
        std::thread::sleep(std::time::Duration::from_millis(2));
        w.end();
        sp.end_comm(CommKind::RingP2p, 64);
        let events = rec.finish();
        assert_eq!(events.len(), 1);
        match events[0].kind {
            EventKind::Comm { kind, bytes, wait_ns } => {
                assert_eq!(kind, CommKind::RingP2p);
                assert_eq!(bytes, 64);
                assert!(wait_ns >= 1_000_000, "wait {wait_ns}ns should cover the sleep");
                assert!(events[0].dur_ns >= wait_ns);
            }
            ref other => panic!("expected comm event, got {other:?}"),
        }
    }

    #[test]
    fn chrome_trace_roundtrips_and_validates() {
        let events = vec![
            Event {
                rank: 0,
                t0_ns: 1_000,
                dur_ns: 2_000,
                kind: EventKind::Phase { name: "sp_embed_fwd", index: None },
            },
            Event {
                rank: 1,
                t0_ns: 1_500,
                dur_ns: 500,
                kind: EventKind::Comm { kind: CommKind::AllToAll, bytes: 256, wait_ns: 100 },
            },
            Event {
                rank: 1,
                t0_ns: 2_500,
                dur_ns: 700,
                kind: EventKind::Cell { stage: 1, micro: 0, forward: true, wait_ns: 0 },
            },
        ];
        let doc = chrome_trace(&events);
        let parsed = crate::util::json::parse(&encode(&doc)).unwrap();
        let check = validate_chrome_trace(&parsed).unwrap();
        assert_eq!(check.complete, 3);
        assert_eq!(check.meta, 2); // one process_name per rank
        assert_eq!(check.pids, vec![0, 1]);
        assert_eq!(check.cats.get("comm"), Some(&1));
        // malformed: ph X without dur
        let bad = obj(vec![(
            "traceEvents",
            Value::Arr(vec![obj(vec![
                ("name", s("x")),
                ("ph", s("X")),
                ("ts", num(0.0)),
                ("pid", num(0.0)),
            ])]),
        )]);
        assert!(validate_chrome_trace(&bad).is_err());
    }

    #[test]
    fn counter_track_roundtrips_and_validates() {
        let report = mem::MemReport {
            lanes: vec![],
            churn_bytes: 0,
            churn_tensors: 0,
            samples: vec![mem::Sample { ts_ns: 2_000, lane: 1, live: [0, 0, 0, 128, 64, 0, 0] }],
        };
        let events = vec![Event {
            rank: 1,
            t0_ns: 1_000,
            dur_ns: 500,
            kind: EventKind::Phase { name: "step", index: None },
        }];
        let doc = chrome_trace_with_counters(&events, Some(&report));
        let parsed = crate::util::json::parse(&encode(&doc)).unwrap();
        let check = validate_chrome_trace(&parsed).unwrap();
        assert_eq!(check.complete, 1);
        assert_eq!(check.counters, 1, "the memory sample becomes a ph:C record");
        // a counter with a non-numeric series point must be rejected
        let bad = obj(vec![(
            "traceEvents",
            Value::Arr(vec![obj(vec![
                ("name", s("memory")),
                ("ph", s("C")),
                ("ts", num(0.0)),
                ("pid", num(0.0)),
                ("args", obj(vec![("params", s("lots"))])),
            ])]),
        )]);
        assert!(validate_chrome_trace(&bad).is_err());
        // without a report the document is unchanged plain chrome_trace
        assert_eq!(chrome_trace_with_counters(&events, None), chrome_trace(&events));
    }

    #[test]
    fn report_aggregates_comm_kernels_and_bubble() {
        let mk_cell = |stage: usize, micro: usize, t0: u64, dur: u64| Event {
            rank: stage,
            t0_ns: t0,
            dur_ns: dur,
            kind: EventKind::Cell { stage, micro, forward: true, wait_ns: 0 },
        };
        // 2 stages, 2 micros, unit cells in the GPipe dataflow layout:
        // stage 0 busy [0,2), stage 1 busy [1,3) => window 3, busy 4,
        // bubble = 1 - 4/6 = (s-1)/(m+s-1) = 1/3.
        let events = vec![
            mk_cell(0, 0, 0, 1),
            mk_cell(0, 1, 1, 1),
            mk_cell(1, 0, 1, 1),
            mk_cell(1, 1, 2, 1),
            Event {
                rank: 0,
                t0_ns: 0,
                dur_ns: 3,
                kind: EventKind::Phase { name: "step", index: None },
            },
            Event {
                rank: 0,
                t0_ns: 0,
                dur_ns: 2,
                kind: EventKind::Kernel { name: "matmul".into(), bytes: 64 },
            },
            Event {
                rank: 0,
                t0_ns: 2,
                dur_ns: 1,
                kind: EventKind::Kernel { name: "softmax_fwd".into(), bytes: 32 },
            },
            Event {
                rank: 1,
                t0_ns: 0,
                dur_ns: 2,
                kind: EventKind::Comm { kind: CommKind::Pipeline, bytes: 128, wait_ns: 1 },
            },
        ];
        let r = MetricsReport::build(&events, 1, 0, 1);
        assert_eq!(r.wall_ns, 3);
        let b = r.bubble.unwrap();
        assert!((b - 1.0 / 3.0).abs() < 1e-9, "bubble {b}");
        assert_eq!(r.kernel_ns, 3);
        assert_eq!(r.kernels.len(), 1, "top-k truncates");
        assert_eq!(r.kernels[0].name, "matmul");
        assert_eq!(r.comm.len(), 1);
        assert_eq!(r.comm[0].events, 1);
        assert_eq!(r.comm[0].bytes, 128);
        assert_eq!(r.comm[0].wait_ns, 1);
        // one comm span of 2ns, 1ns blocked => half the comm time hidden
        let eff = r.overlap_efficiency().unwrap();
        assert!((eff - 0.5).abs() < 1e-9, "overlap efficiency {eff}");
        // json tree renders without panicking and keeps the keys
        let j = r.to_json();
        assert!(j.req("comm").is_ok());
        assert_eq!(j.req("steps").unwrap().as_usize(), Some(1));
        assert!(j.req("overlap_efficiency").is_ok());
        // a comm-free report has nothing to attribute
        assert!(MetricsReport::build(&[], 1, 0, 1).overlap_efficiency().is_none());
    }

    #[test]
    fn cross_check_catches_divergence() {
        let meter = Meter::new();
        meter.add(CommKind::RingP2p, 100);
        let good = vec![Event {
            rank: 0,
            t0_ns: 0,
            dur_ns: 1,
            kind: EventKind::Comm { kind: CommKind::RingP2p, bytes: 100, wait_ns: 0 },
        }];
        let rows = cross_check(&good, &meter).unwrap();
        let ring = rows.iter().find(|r| r.kind == CommKind::RingP2p).unwrap();
        assert_eq!(ring.trace_events, 1);
        assert_eq!(ring.meter_ops, 1);
        assert_eq!(ring.trace_bytes, 100);
        // missing event: count mismatch
        assert!(cross_check(&[], &meter).is_err());
        // byte mismatch
        let bad = vec![Event {
            rank: 0,
            t0_ns: 0,
            dur_ns: 1,
            kind: EventKind::Comm { kind: CommKind::RingP2p, bytes: 99, wait_ns: 0 },
        }];
        assert!(cross_check(&bad, &meter).is_err());
    }

    #[test]
    fn stopwatch_is_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
        assert!(sw.elapsed_secs() >= 0.0);
    }
}
