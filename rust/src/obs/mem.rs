//! Per-rank tensor-lifetime memory accounting.
//!
//! The simulator's ledger ([`crate::simulator::memory`]) *predicts* what
//! each device must hold; this module *measures* it on the real engines.
//! Accounting is by RAII [`Charge`]s planted at the allocation choke
//! points — parameter/gradient stores, the per-layer activation stashes
//! (`parallel::sequence::LayerStash`, `parallel::tensorp::TpLayerStash`),
//! the ring k/v slot buffers (`attn::dense`, `attn::block`), GPipe's
//! held activations (`exec::mesh`) and the Adam state — each tagged with
//! a lane (global rank) and a [`Category`].  A charge adds to the lane's
//! live ledger on construction and releases on drop, so the per-category
//! high-water mark is measured, not modeled.  The contract
//! `tests/mem_validation.rs` asserts: measured per-rank category peaks
//! EQUAL `simulator::memory::sp_expect`'s closed forms, element-exactly.
//!
//! Design constraints mirror the span recorder in [`crate::obs`]:
//!
//! * **Zero heap work when disabled.**  [`Charge::new`] and
//!   [`note_alloc`] are one relaxed atomic load when no session is live
//!   (`benches/obs_overhead.rs` asserts the dead path stays inside the
//!   timer's noise band).
//! * **Session-scoped, thread-adopted.**  A [`MemSession`] holds a
//!   global lock (one at a time; tests serialize through it).  Rank
//!   threads join via [`fork`] / [`adopt`], tagging themselves with a
//!   lane BASE so a rank-local index maps to a global lane; threads that
//!   never adopted the live session account nothing, and a charge whose
//!   session ended before it dropped releases nothing (no underflow
//!   across sessions).
//! * **Peaks are per (lane, category).**  The reported `peak_total` is
//!   the SUM of category peaks — an upper bound that coincides with the
//!   true simultaneous peak here because every validated category is at
//!   its maximum while the last backward layer runs.
//!
//! Surfaces: [`MemReport::to_json`] (the `BENCH_mem.json` rows),
//! [`counter_records`] (Chrome-trace `"ph":"C"` memory tracks, one per
//! lane pid, merged by [`crate::obs::chrome_trace_with_counters`]) and
//! [`validate_bench_mem`] (the `trace --validate` schema check).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

// ---------------------------------------------------------------------
// Categories
// ---------------------------------------------------------------------

/// Number of accounting categories (== `Category::ALL.len()`).
pub const NCAT: usize = 7;
/// Highest lane count a session can track (global ranks; 4D-mesh shapes
/// in this repo are ≤ 16 ranks, 64 leaves headroom).
pub const MAX_LANES: usize = 64;

/// What a tracked allocation is FOR.  One ledger column per category,
/// so the measured peak decomposes the same way the simulator's
/// breakdown does.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    /// Replicated model parameters (`ParamStore`).
    Params,
    /// Gradient accumulators (`ParamStore::zeros_like`).
    Grads,
    /// Adam m + v state.
    Optimizer,
    /// Residual-stream stash: x_in / pre1 / xm / pre2 per layer.
    Activation,
    /// Attention stash: q/k/v/ctx plus the pattern's score stash.
    AttnStash,
    /// In-flight ring k/v + gradient slot chunks.
    RingBuf,
    /// GPipe held activations awaiting a backward microbatch.
    PipeStash,
}

impl Category {
    pub const ALL: [Category; NCAT] = [
        Category::Params,
        Category::Grads,
        Category::Optimizer,
        Category::Activation,
        Category::AttnStash,
        Category::RingBuf,
        Category::PipeStash,
    ];

    /// Stable snake_case name (JSON keys, trace counter args).
    pub fn label(self) -> &'static str {
        match self {
            Category::Params => "params",
            Category::Grads => "grads",
            Category::Optimizer => "optimizer",
            Category::Activation => "activation",
            Category::AttnStash => "attn_stash",
            Category::RingBuf => "ring_buf",
            Category::PipeStash => "pipe_stash",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

// ---------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Current live session id (0 = none).  Monotonic: never reused, so a
/// charge created in session k can never release into session k+1.
static SESSION_ID: AtomicU64 = AtomicU64::new(0);
static SESSION_CTR: AtomicU64 = AtomicU64::new(0);
/// One accounting session at a time (tests serialize through this).
static MEM_LOCK: Mutex<()> = Mutex::new(());
static SAMPLES: Mutex<Vec<Sample>> = Mutex::new(Vec::new());
static CHURN_BYTES: AtomicU64 = AtomicU64::new(0);
static CHURN_TENSORS: AtomicU64 = AtomicU64::new(0);

/// Keep the counter timeline bounded on long runs; the live/peak
/// ledgers are exact regardless (only the sampled TIMELINE truncates).
const SAMPLE_CAP: usize = 1 << 16;

struct Ledger {
    live: Vec<[AtomicU64; NCAT]>,
    peak: Vec<[AtomicU64; NCAT]>,
}

fn ledger() -> &'static Ledger {
    static LEDGER: OnceLock<Ledger> = OnceLock::new();
    LEDGER.get_or_init(|| Ledger {
        live: (0..MAX_LANES).map(|_| std::array::from_fn(|_| AtomicU64::new(0))).collect(),
        peak: (0..MAX_LANES).map(|_| std::array::from_fn(|_| AtomicU64::new(0))).collect(),
    })
}

thread_local! {
    /// (adopted session id, lane base): `Charge::new(rank, ..)` charges
    /// lane `base + rank`.
    static MEM_TLS: Cell<(u64, usize)> = const { Cell::new((0, 0)) };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Is an accounting session live?  (One relaxed atomic load.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Did the calling thread adopt the LIVE session?
fn adopted() -> Option<u64> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let sid = SESSION_ID.load(Ordering::Relaxed);
    let (mine, _) = MEM_TLS.with(|t| t.get());
    if sid != 0 && mine == sid {
        Some(sid)
    } else {
        None
    }
}

/// Record one tensor materialization (allocation CHURN — total bytes
/// ever produced, as opposed to the live/peak residency the charges
/// track).  Called from the `Tensor` constructors; reported, never
/// validated against closed forms.
pub fn note_alloc(bytes: usize) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if adopted().is_none() {
        return;
    }
    CHURN_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    CHURN_TENSORS.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Charges
// ---------------------------------------------------------------------

/// One live allocation on one lane's ledger: adds `bytes` to the lane's
/// `(category)` live count on construction, releases on drop, and bumps
/// the per-(lane, category) high-water mark.  Dead (a few atomic loads,
/// no ledger traffic) outside a live adopted session.  Hold it exactly
/// as long as the tensors it covers are reachable — typically as a
/// field of the stash it accounts or an `_`-prefixed local binding.
#[derive(Debug)]
pub struct Charge {
    /// Session the charge counted into (0 = dead).
    session: u64,
    lane: usize,
    cat: Category,
    bytes: u64,
}

impl Charge {
    /// Charge `bytes` to `base + rank` (the thread's adopted lane base
    /// plus a rank-local index) under `cat`.
    pub fn new(rank: usize, cat: Category, bytes: u64) -> Charge {
        let dead = Charge { session: 0, lane: 0, cat, bytes: 0 };
        let Some(sid) = adopted() else { return dead };
        let (_, base) = MEM_TLS.with(|t| t.get());
        let lane = base + rank;
        if lane >= MAX_LANES || bytes == 0 {
            return dead;
        }
        let lg = ledger();
        let now = lg.live[lane][cat.idx()].fetch_add(bytes, Ordering::AcqRel) + bytes;
        lg.peak[lane][cat.idx()].fetch_max(now, Ordering::AcqRel);
        push_sample(lane);
        Charge { session: sid, lane, cat, bytes }
    }
}

impl Drop for Charge {
    fn drop(&mut self) {
        if self.session == 0 || self.session != SESSION_ID.load(Ordering::Relaxed) {
            // dead, or the session it counted into already finished —
            // its ledger was snapshot/reset, nothing to release
            return;
        }
        ledger().live[self.lane][self.cat.idx()].fetch_sub(self.bytes, Ordering::AcqRel);
        push_sample(self.lane);
    }
}

fn push_sample(lane: usize) {
    let lg = ledger();
    let mut live = [0u64; NCAT];
    for (c, slot) in lg.live[lane].iter().enumerate() {
        live[c] = slot.load(Ordering::Relaxed);
    }
    let mut samples = lock(&SAMPLES);
    if samples.len() < SAMPLE_CAP {
        samples.push(Sample { ts_ns: super::now_ns(), lane, live });
    }
}

// ---------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------

/// One point of a lane's live-bytes timeline (drives the Chrome-trace
/// `"ph":"C"` counter track).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sample {
    pub ts_ns: u64,
    pub lane: usize,
    /// Live bytes per category (``Category::ALL`` order) at `ts_ns`.
    pub live: [u64; NCAT],
}

/// A live accounting session.  Holds the global session lock, resets
/// and enables the ledgers on construction, disables on
/// [`MemSession::finish`] / drop.  The calling thread adopts lane base
/// 0; spawned rank threads join via [`fork`] / [`adopt`].
pub struct MemSession {
    _lock: MutexGuard<'static, ()>,
}

impl MemSession {
    /// Begin accounting.  Blocks until any other session has finished.
    pub fn start() -> MemSession {
        let guard = lock(&MEM_LOCK);
        let id = SESSION_CTR.fetch_add(1, Ordering::Relaxed) + 1;
        let lg = ledger();
        for lane in lg.live.iter().chain(lg.peak.iter()) {
            for slot in lane {
                slot.store(0, Ordering::Relaxed);
            }
        }
        CHURN_BYTES.store(0, Ordering::Relaxed);
        CHURN_TENSORS.store(0, Ordering::Relaxed);
        lock(&SAMPLES).clear();
        MEM_TLS.with(|t| t.set((id, 0)));
        SESSION_ID.store(id, Ordering::SeqCst);
        ENABLED.store(true, Ordering::SeqCst);
        MemSession { _lock: guard }
    }

    /// Stop accounting and snapshot every lane that charged anything.
    pub fn finish(self) -> MemReport {
        ENABLED.store(false, Ordering::SeqCst);
        SESSION_ID.store(0, Ordering::SeqCst);
        let lg = ledger();
        let mut lanes = Vec::new();
        for lane in 0..MAX_LANES {
            let mut peak = [0u64; NCAT];
            let mut live = [0u64; NCAT];
            let mut any = false;
            for c in 0..NCAT {
                peak[c] = lg.peak[lane][c].load(Ordering::Relaxed);
                live[c] = lg.live[lane][c].load(Ordering::Relaxed);
                any |= peak[c] > 0;
            }
            if any {
                lanes.push(LaneMem { lane, live, peak });
            }
        }
        let mut samples = std::mem::take(&mut *lock(&SAMPLES));
        samples.sort_by(|a, b| (a.lane, a.ts_ns).cmp(&(b.lane, b.ts_ns)));
        MemReport {
            lanes,
            churn_bytes: CHURN_BYTES.load(Ordering::Relaxed),
            churn_tensors: CHURN_TENSORS.load(Ordering::Relaxed),
            samples,
        }
    }
}

impl Drop for MemSession {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        SESSION_ID.store(0, Ordering::SeqCst);
    }
}

/// A capability to account into the current session from another
/// thread.  Capture on the session thread with [`fork`]; redeem on the
/// spawned thread with [`adopt`].
#[derive(Clone, Copy, Debug)]
pub struct MemFork {
    session: u64,
}

/// Capture the calling thread's session (dead handle if none live).
pub fn fork() -> MemFork {
    MemFork { session: adopted().unwrap_or(0) }
}

/// Join the handle's session with lane base `lane_base`: this thread's
/// `Charge::new(rank, ..)` lands on lane `lane_base + rank`.  A dead or
/// stale handle leaves the thread un-adopted (it accounts nothing).
pub fn adopt(h: MemFork, lane_base: usize) {
    if h.session == 0 || h.session != SESSION_ID.load(Ordering::Relaxed) {
        return;
    }
    MEM_TLS.with(|t| t.set((h.session, lane_base)));
}

/// Move the calling thread's lane base (sequential engines that emulate
/// several coordinates on one thread re-aim their charges with this;
/// the adopted session is untouched).
pub fn set_lane_base(base: usize) {
    MEM_TLS.with(|t| {
        let (sid, _) = t.get();
        t.set((sid, base));
    });
}

// ---------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------

/// One lane's ledger snapshot at session end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneMem {
    /// Global rank (pid in the exported trace).
    pub lane: usize,
    /// Live bytes per category at `finish` — non-zero means something
    /// out-lived the session (a leak, or a deliberately held charge).
    pub live: [u64; NCAT],
    /// High-water mark per category over the session.
    pub peak: [u64; NCAT],
}

impl LaneMem {
    /// Peak bytes of one category.
    pub fn peak(&self, cat: Category) -> u64 {
        self.peak[cat.idx()]
    }

    /// Sum of category peaks — the per-lane peak the SP<TP comparison
    /// and `BENCH_mem.json` report.
    pub fn peak_total(&self) -> u64 {
        self.peak.iter().sum()
    }
}

/// A finished session: per-lane peaks plus allocation churn and the
/// sampled live-bytes timeline.
#[derive(Clone, Debug, Default)]
pub struct MemReport {
    /// Lanes that charged anything, ascending.
    pub lanes: Vec<LaneMem>,
    /// Total bytes ever materialized by `Tensor` constructors while the
    /// session was live (churn, not residency).
    pub churn_bytes: u64,
    /// Tensor constructions counted into `churn_bytes`.
    pub churn_tensors: u64,
    /// Live-bytes timeline, sorted by (lane, ts).
    pub samples: Vec<Sample>,
}

impl MemReport {
    /// The snapshot for one lane, if it charged anything.
    pub fn lane(&self, lane: usize) -> Option<&LaneMem> {
        self.lanes.iter().find(|l| l.lane == lane)
    }

    /// Largest per-lane peak total (the worst device — what the paper's
    /// Tables 1–2 bound).
    pub fn max_peak_total(&self) -> u64 {
        self.lanes.iter().map(|l| l.peak_total()).max().unwrap_or(0)
    }

    /// JSON tree: per-lane category peaks + totals + churn (the shape
    /// embedded in `BENCH_mem.json` rows and `trace --out`).
    pub fn to_json(&self) -> Value {
        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                let peaks = Category::ALL
                    .iter()
                    .map(|&c| (c.label().to_string(), Value::Num(l.peak(c) as f64)))
                    .collect();
                Value::Obj(
                    [
                        ("lane".to_string(), Value::Num(l.lane as f64)),
                        ("peak".to_string(), Value::Obj(peaks)),
                        ("peak_total".to_string(), Value::Num(l.peak_total() as f64)),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        Value::Obj(
            [
                ("lanes".to_string(), Value::Arr(lanes)),
                ("churn_bytes".to_string(), Value::Num(self.churn_bytes as f64)),
                ("churn_tensors".to_string(), Value::Num(self.churn_tensors as f64)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

impl std::fmt::Display for MemReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "{:<6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10} {:>12}",
            "lane",
            "params",
            "grads",
            "optimizer",
            "activation",
            "attn_stash",
            "ring_buf",
            "pipe_stash",
            "peak_total"
        )?;
        for l in &self.lanes {
            writeln!(
                f,
                "{:<6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10} {:>12}",
                l.lane,
                l.peak(Category::Params),
                l.peak(Category::Grads),
                l.peak(Category::Optimizer),
                l.peak(Category::Activation),
                l.peak(Category::AttnStash),
                l.peak(Category::RingBuf),
                l.peak(Category::PipeStash),
                l.peak_total()
            )?;
        }
        writeln!(
            f,
            "alloc churn: {} bytes over {} tensors",
            self.churn_bytes, self.churn_tensors
        )
    }
}

/// Chrome-trace counter records (`"ph":"C"`, name `"memory"`, one track
/// per lane pid) for the report's sampled timeline; args carry the
/// per-category live-byte series so the trace viewer stacks them.
pub fn counter_records(report: &MemReport) -> Vec<Value> {
    report
        .samples
        .iter()
        .map(|sp| {
            let args = Category::ALL
                .iter()
                .map(|&c| (c.label().to_string(), Value::Num(sp.live[c.idx()] as f64)))
                .collect();
            Value::Obj(
                [
                    ("name".to_string(), Value::Str("memory".to_string())),
                    ("cat".to_string(), Value::Str("mem".to_string())),
                    ("ph".to_string(), Value::Str("C".to_string())),
                    ("ts".to_string(), Value::Num(sp.ts_ns as f64 / 1e3)),
                    ("pid".to_string(), Value::Num(sp.lane as f64)),
                    ("tid".to_string(), Value::Num(0.0)),
                    ("args".to_string(), Value::Obj(args)),
                ]
                .into_iter()
                .collect(),
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// BENCH_mem.json schema validation (trace --validate)
// ---------------------------------------------------------------------

/// Schema-check a parsed `BENCH_mem.json` document (dispatched by the
/// `trace --validate` CLI when the file carries a `mem_rows` key).
/// Each row must name a strategy/pattern, carry `n ≥ 1`, a
/// `peak_per_rank` array of that many non-negative numbers whose max
/// equals `peak_max`, and per-category peaks under known labels; every
/// recorded in-bench assert must have held.  Returns a one-line summary.
pub fn validate_bench_mem(doc: &Value) -> Result<String> {
    let rows = doc
        .req("mem_rows")
        .context("BENCH_mem: root must carry a mem_rows array")?
        .as_arr()
        .context("BENCH_mem: mem_rows must be an array")?;
    if rows.is_empty() {
        bail!("BENCH_mem: mem_rows is empty");
    }
    for (i, row) in rows.iter().enumerate() {
        let at = || format!("mem_rows[{i}]");
        for key in ["strategy", "pattern"] {
            row.req(key)
                .with_context(at)?
                .as_str()
                .with_context(|| format!("{}: {key} must be a string", at()))?;
        }
        let n = row
            .req("n")
            .with_context(at)?
            .as_usize()
            .with_context(|| format!("{}: n must be a non-negative integer", at()))?;
        if n == 0 {
            bail!("{}: n must be >= 1", at());
        }
        let peaks = row
            .req("peak_per_rank")
            .with_context(at)?
            .as_arr()
            .with_context(|| format!("{}: peak_per_rank must be an array", at()))?;
        if peaks.len() != n {
            bail!("{}: peak_per_rank has {} entries, expected n={n}", at(), peaks.len());
        }
        let mut max = 0f64;
        for (j, p) in peaks.iter().enumerate() {
            let v = p
                .as_f64()
                .with_context(|| format!("{}: peak_per_rank[{j}] must be numeric", at()))?;
            if v < 0.0 {
                bail!("{}: peak_per_rank[{j}] must be non-negative", at());
            }
            max = max.max(v);
        }
        let peak_max = row
            .req("peak_max")
            .with_context(at)?
            .as_f64()
            .with_context(|| format!("{}: peak_max must be numeric", at()))?;
        if peak_max != max {
            bail!("{}: peak_max {peak_max} != max(peak_per_rank) {max}", at());
        }
        if let Some(cats) = row.get("categories") {
            let cats = cats
                .as_obj()
                .with_context(|| format!("{}: categories must be an object", at()))?;
            let known: Vec<&str> = Category::ALL.iter().map(|c| c.label()).collect();
            for (k, v) in cats {
                if !known.contains(&k.as_str()) {
                    bail!("{}: unknown category {k:?}", at());
                }
                v.as_f64()
                    .with_context(|| format!("{}: categories.{k} must be numeric", at()))?;
            }
        }
    }
    let mut asserts_ok = 0usize;
    if let Some(asserts) = doc.get("asserts") {
        let asserts = asserts.as_obj().context("BENCH_mem: asserts must be an object")?;
        for (k, v) in asserts {
            match v.as_bool() {
                Some(true) => asserts_ok += 1,
                Some(false) => bail!("BENCH_mem: recorded assert {k:?} FAILED"),
                None => bail!("BENCH_mem: asserts.{k} must be a bool"),
            }
        }
    }
    Ok(format!("{} mem rows, {} recorded asserts", rows.len(), asserts_ok))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_charges_are_dead() {
        // no session: charges and churn notes touch no ledger
        let c = Charge::new(0, Category::Params, 4096);
        assert_eq!(c.session, 0);
        drop(c);
        note_alloc(128);
        assert!(!enabled());
    }

    #[test]
    fn session_tracks_live_and_peak() {
        let ses = MemSession::start();
        assert!(enabled());
        {
            let _a = Charge::new(0, Category::Activation, 100);
            {
                let _b = Charge::new(0, Category::Activation, 50);
                // both live: peak sees 150
            }
            let _c = Charge::new(0, Category::AttnStash, 30);
        }
        note_alloc(64);
        note_alloc(64);
        let report = ses.finish();
        assert!(!enabled());
        assert_eq!(report.lanes.len(), 1);
        let lane = report.lane(0).unwrap();
        assert_eq!(lane.peak(Category::Activation), 150);
        assert_eq!(lane.peak(Category::AttnStash), 30);
        assert_eq!(lane.peak_total(), 180);
        assert_eq!(lane.live, [0u64; NCAT], "all charges dropped");
        assert_eq!(report.churn_bytes, 128);
        assert_eq!(report.churn_tensors, 2);
        assert!(report.samples.len() >= 3, "each charge/release samples");
        assert_eq!(report.max_peak_total(), 180);
    }

    #[test]
    fn fork_adopt_maps_lanes_and_blocks_strangers() {
        let ses = MemSession::start();
        let h = fork();
        std::thread::scope(|scope| {
            scope.spawn(move || {
                adopt(h, 2);
                let _c = Charge::new(1, Category::RingBuf, 77); // lane 3
            });
            scope.spawn(|| {
                // never adopted: invisible
                let c = Charge::new(0, Category::Params, 999);
                assert_eq!(c.session, 0);
            });
        });
        let report = ses.finish();
        assert_eq!(report.lanes.len(), 1);
        assert_eq!(report.lanes[0].lane, 3);
        assert_eq!(report.lanes[0].peak(Category::RingBuf), 77);
    }

    #[test]
    fn lane_base_moves_sequential_charges() {
        let ses = MemSession::start();
        let _p = Charge::new(0, Category::Params, 10); // lane 0
        set_lane_base(5);
        let _q = Charge::new(1, Category::Params, 20); // lane 6
        set_lane_base(0);
        let report = ses.finish();
        let lanes: Vec<usize> = report.lanes.iter().map(|l| l.lane).collect();
        assert_eq!(lanes, vec![0, 6]);
    }

    #[test]
    fn cross_session_drop_does_not_underflow() {
        let ses = MemSession::start();
        let held = Charge::new(0, Category::Grads, 40);
        let report = ses.finish();
        assert_eq!(report.lanes[0].peak(Category::Grads), 40);
        // a fresh session must not see the stale release
        let ses2 = MemSession::start();
        drop(held);
        let report2 = ses2.finish();
        assert!(report2.lanes.is_empty(), "stale drop leaked into a new session");
    }

    #[test]
    fn counter_records_carry_category_series() {
        let ses = MemSession::start();
        {
            let _c = Charge::new(0, Category::PipeStash, 123);
        }
        let report = ses.finish();
        let recs = counter_records(&report);
        assert!(recs.len() >= 2);
        let first = &recs[0];
        assert_eq!(first.req("ph").unwrap().as_str(), Some("C"));
        assert_eq!(first.req("name").unwrap().as_str(), Some("memory"));
        assert_eq!(
            first.req("args").unwrap().req("pipe_stash").unwrap().as_f64(),
            Some(123.0)
        );
    }

    #[test]
    fn bench_mem_schema_validates() {
        let doc = crate::util::json::parse(
            r#"{
              "mem_rows": [
                {"strategy": "ring", "pattern": "dense", "n": 2,
                 "peak_per_rank": [100, 90], "peak_max": 100,
                 "categories": {"params": 40, "attn_stash": 60}}
              ],
              "asserts": {"sp_peak_below_tp": true}
            }"#,
        )
        .unwrap();
        let summary = validate_bench_mem(&doc).unwrap();
        assert!(summary.contains("1 mem rows"), "{summary}");
        // peak_max must equal the rank max
        let bad = crate::util::json::parse(
            r#"{"mem_rows": [{"strategy": "ring", "pattern": "dense", "n": 1,
                "peak_per_rank": [5], "peak_max": 6}]}"#,
        )
        .unwrap();
        assert!(validate_bench_mem(&bad).is_err());
        // failed recorded asserts are an error
        let failed = crate::util::json::parse(
            r#"{"mem_rows": [{"strategy": "ring", "pattern": "dense", "n": 1,
                "peak_per_rank": [5], "peak_max": 5}],
                "asserts": {"sp_peak_below_tp": false}}"#,
        )
        .unwrap();
        assert!(validate_bench_mem(&failed).is_err());
    }
}
