//! Bench: the REAL-COMPUTE hot path — one training step of each engine
//! through the native backend, plus the per-stage RSA breakdown.  This is
//! the instrument for the EXPERIMENTS.md §Perf iteration log.
//!
//!     cargo bench --bench rsa_hotpath
//!
//! No artifacts needed: the native backend synthesizes its manifest.  (To
//! profile the PJRT path instead, build with `--features backend-xla` and
//! run `seqpar verify --backend xla`.)

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::model::params::ParamStore;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::tensor::Tensor;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // a meatier shape than the test default so the kernels dominate
    let cfg = NativeConfig { seq_len: 64, ..NativeConfig::tiny() };
    let rt = Runtime::native(cfg)?;
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3);
    let batch = corpus.next_batch()?;
    let tokens = (m.batch * m.seq_len) as f64;

    println!(
        "hot path @ {} [{} backend] (B={} L={} ring={} tp={})",
        m.model,
        rt.backend_name(),
        m.batch,
        m.seq_len,
        m.ring,
        m.tp
    );

    // ---- end-to-end steps -------------------------------------------------
    let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new()))?;
    let s = bench(2, 12, || {
        std::hint::black_box(seq.forward_backward(&params, &batch).unwrap());
    });
    s.report("seq-par fwd+bwd step (real compute)");
    println!("  -> {:.0} tokens/s real", tokens / (s.mean_ns / 1e9));

    let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new()))?;
    let st = bench(2, 12, || {
        std::hint::black_box(serial.forward_backward(&params, &batch).unwrap());
    });
    st.report("serial fwd+bwd step (real compute)");
    println!("  -> {:.0} tokens/s real", tokens / (st.mean_ns / 1e9));

    let tp = TensorParEngine::new(&rt, Fabric::new(m.tp, Meter::new()))?;
    let tt = bench(2, 12, || {
        std::hint::black_box(tp.forward_backward(&params, &batch).unwrap());
    });
    tt.report(&format!("tensor-par({}) fwd+bwd step (real compute)", m.tp));

    // ---- RSA stage breakdown ----------------------------------------------
    let (b, z, a) = (m.batch, m.heads, m.head_dim);
    let lc = m.seq_len / m.ring;
    let mut rng = Rng::new(5);
    let chunks = |rng: &mut Rng| -> Vec<Tensor> {
        (0..m.ring).map(|_| Tensor::randn(&[b, z, lc, a], 1.0, rng)).collect()
    };
    let q = chunks(&mut rng);
    let k = chunks(&mut rng);
    let v = chunks(&mut rng);
    let rsa = bench(2, 16, || {
        std::hint::black_box(seq.rsa_attention(&q, &k, &v).unwrap());
    });
    rsa.report("RSA attention only (ring QK^T + softmax + ring AV)");

    // ---- orchestration overhead: fabric + host glue vs kernel time --------
    let stats0 = rt.stats();
    let _ = seq.forward_backward(&params, &batch)?;
    let stats1 = rt.stats();
    let exec_ns = (stats1.exec_nanos - stats0.exec_nanos) as f64;
    let calls = stats1.calls - stats0.calls;
    println!(
        "one seq-par step: {calls} kernel calls, {} inside kernels, {} total -> orchestration overhead {:.1}%",
        fmt_ns(exec_ns),
        fmt_ns(s.mean_ns),
        100.0 * (s.mean_ns - exec_ns).max(0.0) / s.mean_ns
    );
    println!(
        "distinct kernels dispatched: {} over {} calls",
        rt.cached_executables(),
        stats1.calls,
    );
    Ok(())
}
