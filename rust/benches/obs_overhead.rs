//! Bench guard: disabled observability must be free.
//!
//!     cargo bench --bench obs_overhead
//!
//! Every `Executor::call`, collective, and phase boundary opens an
//! `obs::Span`.  When no `obs::Recorder` session is live (the default,
//! i.e. every run without `--trace`), `obs::begin()` is one relaxed
//! atomic load and the span is dead — no clock read, no TLS write, no
//! heap.  This bench measures that claim two ways and ASSERTS the dead
//! path stays within the timer's own noise band, so a regression that
//! puts real work on the disabled path fails `cargo bench` in CI.

use seqpar::backend::native::NativeConfig;
use seqpar::comm::Meter;
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::exec::DistRunner;
use seqpar::model::params::ParamStore;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};

const SPANS: usize = 10_000;

fn main() -> anyhow::Result<()> {
    assert!(!seqpar::obs::enabled(), "no Recorder session may be live in this bench");

    // ---- microcost: a dead begin/end pair, amortized over 10k spans -------
    let bare = bench(10, 200, || {
        for i in 0..SPANS {
            std::hint::black_box(i);
        }
    });
    let dead = bench(10, 200, || {
        for i in 0..SPANS {
            std::hint::black_box(i);
            let sp = seqpar::obs::begin();
            sp.end_phase("bench");
        }
    });
    bare.report(&format!("empty loop ({SPANS} iters)"));
    dead.report(&format!("disabled-span loop ({SPANS} iters)"));
    let delta = (dead.p50_ns - bare.p50_ns).max(0.0);
    println!("  -> disabled span costs {} each", fmt_ns(delta / SPANS as f64));

    // budget: run-to-run jitter of the bare loop plus 5ns per span (a
    // relaxed load + branch is well under that on any host CI runs on)
    let noise = (bare.p95_ns - bare.p50_ns).max(bare.p50_ns * 0.10);
    assert!(
        delta <= noise + SPANS as f64 * 5.0,
        "disabled spans are not free: loop p50 {} vs bare {} (noise budget {})",
        fmt_ns(dead.p50_ns),
        fmt_ns(bare.p50_ns),
        fmt_ns(noise)
    );

    // ---- microcost: a dead memory charge + churn note, session off --------
    // (`obs::mem` plants a `Charge` at every stash/param choke point and
    // a `note_alloc` in every `Tensor` constructor; with no `MemSession`
    // live both must collapse to a relaxed load, same budget as spans)
    assert!(!seqpar::obs::mem::enabled(), "no MemSession may be live in this bench");
    let dead_mem = bench(10, 200, || {
        for i in 0..SPANS {
            std::hint::black_box(i);
            let c = seqpar::obs::mem::Charge::new(0, seqpar::obs::mem::Category::Activation, 4096);
            std::hint::black_box(&c);
            seqpar::obs::mem::note_alloc(4096);
        }
    });
    dead_mem.report(&format!("disabled-charge loop ({SPANS} iters)"));
    let mem_delta = (dead_mem.p50_ns - bare.p50_ns).max(0.0);
    println!("  -> disabled charge costs {} each", fmt_ns(mem_delta / SPANS as f64));
    assert!(
        mem_delta <= noise + SPANS as f64 * 5.0,
        "disabled memory charges are not free: loop p50 {} vs bare {} (noise budget {})",
        fmt_ns(dead_mem.p50_ns),
        fmt_ns(bare.p50_ns),
        fmt_ns(noise)
    );

    // ---- end-to-end: a fully instrumented threaded step, recording off ----
    // (every kernel call, ring message and phase boundary crosses the
    // dead path; this is the number `train` without --trace pays)
    let rt = Runtime::native(NativeConfig::tiny())?;
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3).next_batch()?;
    let dist = DistRunner::new(&rt, Meter::new())?;
    let step = bench(2, 12, || {
        std::hint::black_box(dist.forward_backward(&params, &batch).unwrap());
    });
    step.report("threaded step, recording disabled");

    println!("OBS OVERHEAD GUARD OK");
    Ok(())
}
