//! Bench: measured per-rank memory peaks — `BENCH_mem.json`.
//!
//!     cargo bench --bench mem_profile
//!     cargo bench --bench mem_profile -- --out BENCH_mem.json
//!
//! One accounted training step (forward + backward + Adam under an
//! `obs::mem::MemSession`) per (strategy × pattern × n) cell:
//!
//! * `--sp ring`  × dense / linformer:8 / block:8 at n ∈ {1, 2, 4};
//! * `--sp ulysses` × dense at n ∈ {1, 2, 4} (bert-tiny-z4);
//! * tensor parallelism × dense at n ∈ {1, 2} (bert-tiny has 2 heads —
//!   exactly the paper's §4.2 head-count scaling limit).
//!
//! Every SP row's per-rank category peaks are pinned EXACTLY to
//! `simulator::memory::sp_expect` (the closed forms `tests/
//! mem_validation.rs` also asserts).  Two measured headline properties
//! land in the `asserts` block of `BENCH_mem.json`:
//!
//! * `sp_peak_below_tp` — at equal group size the SP peak is below the
//!   TP peak (this run shape is past the activation break-even: SP
//!   stashes 1/n of the residual stream, TP stashes all of it plus the
//!   sharded MLP hidden);
//! * `linformer_peak_flat` / `dense_peak_quadratic` — doubling L leaves
//!   Linformer's per-token attention stash flat (it shrinks: the K-wide
//!   rows are L-free) while dense grows linearly per token (the BZL²/N
//!   score stash).
//!
//! Flags: --out PATH (default BENCH_mem.json)

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::obs::mem::{self, Category, MemReport, MemSession};
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::simulator::memory::sp_expect;
use seqpar::simulator::{RunShape, Strategy};
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::train::trainer::{TrainConfig, Trainer};
use seqpar::util::cli::Args;
use seqpar::util::json::{encode, Value};

/// One full training step (fwd + bwd + Adam) under a fresh accounting
/// session, so every category — params through optimizer — peaks.
fn accounted_step<E: Engine>(rt: &Runtime, engine: &E, seed: u64) -> Result<MemReport> {
    let m = rt.manifest().clone();
    let mut params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    let ses = MemSession::start();
    let mut tr = Trainer::new(
        engine,
        &params,
        TrainConfig { steps: 1, warmup: 0, peak_lr: 1e-3, log_every: 1 },
    );
    tr.run(&mut params, || corpus.next_batch(), true)?;
    Ok(ses.finish())
}

/// Per-rank SP peaks must EQUAL the simulator's closed forms.
fn pin_sp_row(
    tag: &str,
    report: &MemReport,
    shape: &RunShape,
    strategy: Strategy,
    pattern: AttnPattern,
) -> Result<()> {
    let n = strategy.n();
    ensure!(report.lanes.len() == n, "{tag}: {} lanes charged, expected {n}", report.lanes.len());
    for d in 0..n {
        let exp = sp_expect(shape, strategy, pattern, d);
        let lane = report.lane(d).ok_or_else(|| anyhow::anyhow!("{tag}: rank {d} uncharged"))?;
        for (cat, want) in [
            (Category::Params, exp.params),
            (Category::Grads, exp.grads),
            (Category::Optimizer, exp.optimizer),
            (Category::Activation, exp.activation),
            (Category::AttnStash, exp.attn_stash),
        ] {
            ensure!(
                lane.peak(cat) == want,
                "{tag}: rank {d} {} measured {} != closed form {want}",
                cat.label(),
                lane.peak(cat)
            );
        }
        if let Some(rb) = exp.ring_buf {
            ensure!(
                lane.peak(Category::RingBuf) == rb,
                "{tag}: rank {d} ring_buf measured {} != closed form {rb}",
                lane.peak(Category::RingBuf)
            );
        }
    }
    Ok(())
}

/// One `mem_rows` entry: per-rank peak totals + worst-rank category
/// peaks (the shape `trace --validate` checks).
fn row(
    strategy: &str,
    pattern: &str,
    n: usize,
    model: &str,
    seq_len: usize,
    report: &MemReport,
) -> Value {
    let peaks: Vec<Value> = (0..n)
        .map(|d| Value::Num(report.lane(d).map_or(0, |l| l.peak_total()) as f64))
        .collect();
    let mut cats = BTreeMap::new();
    for &c in Category::ALL.iter() {
        let worst = report.lanes.iter().map(|l| l.peak(c)).max().unwrap_or(0);
        cats.insert(c.label().to_string(), Value::Num(worst as f64));
    }
    let mut r = BTreeMap::new();
    r.insert("strategy".to_string(), Value::Str(strategy.to_string()));
    r.insert("pattern".to_string(), Value::Str(pattern.to_string()));
    r.insert("n".to_string(), Value::Num(n as f64));
    r.insert("model".to_string(), Value::Str(model.to_string()));
    r.insert("seq_len".to_string(), Value::Num(seq_len as f64));
    r.insert("peak_per_rank".to_string(), Value::Arr(peaks));
    r.insert("peak_max".to_string(), Value::Num(report.max_peak_total() as f64));
    r.insert("categories".to_string(), Value::Obj(cats));
    r.insert("churn_bytes".to_string(), Value::Num(report.churn_bytes as f64));
    Value::Obj(r)
}

/// Worst-rank attention-stash peak of a report.
fn attn_stash_peak(report: &MemReport) -> u64 {
    report.lanes.iter().map(|l| l.peak(Category::AttnStash)).max().unwrap_or(0)
}

fn sp_report(cfg: NativeConfig, pattern: AttnPattern, sp: SpStrategy) -> Result<(MemReport, RunShape)> {
    let n = cfg.ring;
    let rt = Runtime::native(cfg)?;
    let m = rt.manifest().clone();
    let engine = SeqParEngine::with_strategy(&rt, Fabric::new(n, Meter::new()), pattern, sp)?;
    let report = accounted_step(&rt, &engine, 7)?;
    let shape = RunShape::new(seqpar::model::by_name(&m.model)?, m.batch, m.seq_len);
    Ok((report, shape))
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let out_path = args.str_or("out", "BENCH_mem.json").to_string();

    let mut rows: Vec<Value> = Vec::new();
    let mut asserts: BTreeMap<String, Value> = BTreeMap::new();
    println!(
        "{:>8} {:>12} {:>3} {:>14} {:>12} {:>12} {:>10}",
        "strategy", "pattern", "n", "peak_max", "activation", "attn_stash", "ring_buf"
    );
    let print_row = |strategy: &str, pattern: &str, n: usize, rep: &MemReport| {
        let cat = |c: Category| rep.lanes.iter().map(|l| l.peak(c)).max().unwrap_or(0);
        println!(
            "{strategy:>8} {pattern:>12} {n:>3} {:>14} {:>12} {:>12} {:>10}",
            rep.max_peak_total(),
            cat(Category::Activation),
            cat(Category::AttnStash),
            cat(Category::RingBuf)
        );
    };

    // ---- SP ring × pattern × n, pinned to the closed forms -------------
    let mut ring_dense_n2_peak = 0u64;
    for (plabel, pattern) in [
        ("dense", AttnPattern::Dense),
        ("linformer:8", AttnPattern::Linformer { k: 8 }),
        ("block:8", AttnPattern::Block { w: 8 }),
    ] {
        let (linformer_k, block_w) = pattern.native_knobs();
        for n in [1usize, 2, 4] {
            let cfg = NativeConfig { ring: n, linformer_k, block_w, ..NativeConfig::tiny() };
            let (report, shape) = sp_report(cfg, pattern, SpStrategy::Ring)?;
            pin_sp_row(
                &format!("ring {plabel} n={n}"),
                &report,
                &shape,
                Strategy::Sequence { n },
                pattern,
            )?;
            if plabel == "dense" && n == 2 {
                ring_dense_n2_peak = report.max_peak_total();
            }
            print_row("ring", plabel, n, &report);
            rows.push(row("ring", plabel, n, shape.model.name, shape.seq_len, &report));
        }
    }
    asserts.insert("sp_measured_equals_closed_forms".to_string(), Value::Bool(true));

    // ---- SP ulysses × dense × n (4-head tiny variant) ------------------
    for n in [1usize, 2, 4] {
        let cfg =
            NativeConfig { model: BERT_TINY_Z4, ring: n, ulysses: true, ..NativeConfig::tiny() };
        let (report, shape) = sp_report(cfg, AttnPattern::Dense, SpStrategy::Ulysses)?;
        pin_sp_row(
            &format!("ulysses dense n={n}"),
            &report,
            &shape,
            Strategy::Ulysses { n },
            AttnPattern::Dense,
        )?;
        print_row("ulysses", "dense", n, &report);
        rows.push(row("ulysses", "dense", n, shape.model.name, shape.seq_len, &report));
    }

    // ---- TP × dense × n (enters only through the SP < TP inequality) ---
    let mut tp_dense_n2_peak = 0u64;
    for n in [1usize, 2] {
        let rt = Runtime::native(NativeConfig::tiny())?;
        let m = rt.manifest().clone();
        let engine = TensorParEngine::new(&rt, Fabric::new(n, Meter::new()))?;
        let report = accounted_step(&rt, &engine, 7)?;
        ensure!(report.lanes.len() == n, "tp n={n}: {} lanes charged", report.lanes.len());
        if n == 2 {
            tp_dense_n2_peak = report.max_peak_total();
        }
        print_row("tp", "dense", n, &report);
        rows.push(row("tp", "dense", n, &m.model, m.seq_len, &report));
    }

    // the paper's Table-2 trade, measured: past the activation
    // break-even the SP rank peaks below the TP rank at equal group size
    ensure!(
        ring_dense_n2_peak > 0 && ring_dense_n2_peak < tp_dense_n2_peak,
        "SP peak {ring_dense_n2_peak} not below TP peak {tp_dense_n2_peak} at n=2"
    );
    println!(
        "SP vs TP at n=2: ring {ring_dense_n2_peak} < tp {tp_dense_n2_peak} ({:.2}x)",
        tp_dense_n2_peak as f64 / ring_dense_n2_peak as f64
    );
    asserts.insert("sp_peak_below_tp".to_string(), Value::Bool(true));

    // ---- L-scaling: Linformer's stash is flat per token, dense is not --
    let stash_at = |seq_len: usize, pattern: AttnPattern| -> Result<u64> {
        let (linformer_k, block_w) = pattern.native_knobs();
        let cfg =
            NativeConfig { ring: 2, seq_len, linformer_k, block_w, ..NativeConfig::tiny() };
        let (report, _) = sp_report(cfg, pattern, SpStrategy::Ring)?;
        Ok(attn_stash_peak(&report))
    };
    let (l0, l1) = (32usize, 64usize);
    let dense0 = stash_at(l0, AttnPattern::Dense)?;
    let dense1 = stash_at(l1, AttnPattern::Dense)?;
    let lin0 = stash_at(l0, AttnPattern::Linformer { k: 8 })?;
    let lin1 = stash_at(l1, AttnPattern::Linformer { k: 8 })?;
    // per-token stash: dense carries L-wide score rows (grows with L),
    // Linformer carries K-wide rows (flat — strictly shrinking, since
    // the projected K̃/Ṽ pair amortizes over more tokens)
    let per_tok = |bytes: u64, l: usize| bytes as f64 / l as f64;
    ensure!(
        per_tok(lin1, l1) <= per_tok(lin0, l0),
        "linformer per-token stash grew with L: {}@L{l0} -> {}@L{l1}",
        per_tok(lin0, l0),
        per_tok(lin1, l1)
    );
    ensure!(
        per_tok(dense1, l1) > per_tok(dense0, l0),
        "dense per-token stash did not grow with L: {} -> {}",
        per_tok(dense0, l0),
        per_tok(dense1, l1)
    );
    println!(
        "per-token attn stash, L{l0}->L{l1}: dense {:.1}B -> {:.1}B, linformer {:.1}B -> {:.1}B",
        per_tok(dense0, l0),
        per_tok(dense1, l1),
        per_tok(lin0, l0),
        per_tok(lin1, l1)
    );
    asserts.insert("linformer_peak_flat".to_string(), Value::Bool(true));
    asserts.insert("dense_peak_quadratic".to_string(), Value::Bool(true));

    // ---- emit + self-validate ------------------------------------------
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Value::Str("mem_profile".to_string()));
    top.insert("mem_rows".to_string(), Value::Arr(rows));
    top.insert("asserts".to_string(), Value::Obj(asserts));
    let doc = Value::Obj(top);
    let summary = mem::validate_bench_mem(&doc)?;
    std::fs::write(&out_path, encode(&doc))?;
    println!("wrote {out_path} ({summary})");
    println!("MEM PROFILE GUARD OK");
    Ok(())
}
