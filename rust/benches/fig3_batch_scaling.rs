//! Bench: Fig. 3a/3b (BERT-Base) and Fig. 7a/7b (BERT-Large) —
//! max batch size and throughput scaling along the tensor/sequence
//! parallel size.  Prints the same series the paper plots, then times the
//! generator itself.
//!
//!     cargo bench --bench fig3_batch_scaling [-- --model bert-large]

use seqpar::eval::bench::bench;
use seqpar::eval::figures;
use seqpar::model::{BERT_BASE, BERT_LARGE};
use seqpar::simulator::Cluster;

fn main() {
    let large = std::env::args().any(|a| a.contains("bert-large"));
    let model = if large { BERT_LARGE } else { BERT_BASE };
    let cluster = Cluster::default();

    println!("=== Fig. {}a — {} max batch vs parallel size (L=512) ===",
             if large { 7 } else { 3 }, model.name);
    println!("{:>4} {:>12} {:>12} | {:>12} {:>12}", "n", "TP maxB", "SP maxB", "TP tok/s", "SP tok/s");
    let rows = figures::fig3(&cluster, model);
    for r in &rows {
        println!(
            "{:>4} {:>12} {:>12} | {:>12} {:>12}",
            r.n,
            r.tp_max_batch.map(|v| v.to_string()).unwrap_or("—".into()),
            if r.sp_max_batch == 0 { "—".into() } else { r.sp_max_batch.to_string() },
            r.tp_tokens_per_sec.map(|v| format!("{v:.0}")).unwrap_or("—".into()),
            if r.sp_max_batch == 0 { "—".into() } else { format!("{:.0}", r.sp_tokens_per_sec) },
        );
    }
    let tp_best = rows.iter().filter_map(|r| r.tp_max_batch).max().unwrap_or(1);
    let sp64 = rows.iter().find(|r| r.n == 64).map(|r| r.sp_max_batch).unwrap_or(0);
    println!(
        "headline: SP@64 / best TP = {:.1}x   (paper: {} on 64 P100s)",
        sp64 as f64 / tp_best.max(1) as f64,
        if large { "10.2x" } else { "13.7x" }
    );

    bench(1, 10, || {
        std::hint::black_box(figures::fig3(&cluster, model));
    })
    .report("fig3 sweep (13 strategy points, OOM search)");
}
