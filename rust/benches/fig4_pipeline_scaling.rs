//! Bench: Fig. 4a/4b (Base) and Fig. 8a/8b (Large) — scaling along the
//! pipeline-parallel size with the model-parallel size fixed at 4.
//!
//!     cargo bench --bench fig4_pipeline_scaling [-- --model bert-large]

use seqpar::eval::bench::bench;
use seqpar::eval::figures;
use seqpar::model::{BERT_BASE, BERT_LARGE};
use seqpar::parallel::pipeline::Schedule;
use seqpar::simulator::Cluster;

fn main() {
    let large = std::env::args().any(|a| a.contains("bert-large"));
    let model = if large { BERT_LARGE } else { BERT_BASE };
    let cluster = Cluster::default();

    println!("=== Fig. {}a/b — {} scaling along pipeline size (MP=4, micros=8) ===",
             if large { 8 } else { 4 }, model.name);
    println!("{:>6} {:>12} {:>12} | {:>12} {:>12}", "stages", "TP maxB", "SP maxB", "TP tok/s", "SP tok/s");
    for r in figures::fig4(&cluster, model) {
        println!(
            "{:>6} {:>12} {:>12} | {:>12} {:>12}",
            r.n,
            r.tp_max_batch.map(|v| v.to_string()).unwrap_or("—".into()),
            r.sp_max_batch,
            r.tp_tokens_per_sec.map(|v| format!("{v:.0}")).unwrap_or("—".into()),
            format!("{:.0}", r.sp_tokens_per_sec),
        );
    }
    println!("(SP wins both: no split+all-gather at pipeline boundaries — §3.2.2)");

    // the schedule itself, at the sizes the paper uses
    for (stages, micros) in [(2usize, 8usize), (4, 8), (8, 8)] {
        let s = Schedule::gpipe(stages, micros);
        println!(
            "gpipe {stages}x{micros}: bubble fraction {:.3}, makespan {} ticks",
            s.bubble_fraction(),
            s.makespan(2)
        );
    }

    bench(1, 20, || {
        std::hint::black_box(figures::fig4(&cluster, model));
    })
    .report("fig4 sweep (4 pipeline depths x 2 strategies)");
}
