//! Bench: Fig. 5a (max sequence length, batch 64), Fig. 5b (sparse
//! upper bound, batch 4), Fig. 9 (BERT-Large max length, batch 16).
//!
//!     cargo bench --bench fig5_seqlen [-- --model bert-large]

use seqpar::eval::bench::bench;
use seqpar::eval::figures;
use seqpar::model::{BERT_BASE, BERT_LARGE};
use seqpar::simulator::Cluster;

fn main() {
    let large = std::env::args().any(|a| a.contains("bert-large"));
    let model = if large { BERT_LARGE } else { BERT_BASE };
    let batch = if large { 16 } else { 64 };
    let cluster = Cluster::default();

    println!("=== Fig. {} — {} max sequence length vs devices (batch {batch}) ===",
             if large { "9" } else { "5a" }, model.name);
    println!("{:>4} {:>12} {:>12}", "n", "TP maxL", "SP maxL");
    let rows = figures::fig5a(&cluster, model, batch);
    for r in &rows {
        println!(
            "{:>4} {:>12} {:>12}",
            r.n,
            r.tp_max_len.map(|v| v.to_string()).unwrap_or("—".into()),
            r.sp_max_len
        );
    }
    let tp_best = rows.iter().filter_map(|r| r.tp_max_len).max().unwrap_or(1);
    let sp64 = rows.iter().find(|r| r.n == 64).map(|r| r.sp_max_len).unwrap_or(0);
    println!(
        "headline: SP@64 / best TP = {:.1}x   (paper: {})",
        sp64 as f64 / tp_best.max(1) as f64,
        if large { "~2x" } else { "~3x, 1.4x at equal 16 GPUs" }
    );

    if !large {
        println!("\n=== Fig. 5b — sparse-attention length upper bound (batch 4, K=256) ===");
        println!("{:>4} {:>12} {:>12} {:>10}", "n", "dense", "sparse", "ideal");
        let rows = figures::fig5b(&cluster, model);
        let base = rows.first().map(|r| r.sparse_max_len).unwrap_or(0);
        for r in &rows {
            println!("{:>4} {:>12} {:>12} {:>10}", r.n, r.dense_max_len, r.sparse_max_len, base * r.n);
        }
        println!("(paper: >114K tokens @32 P100s — 27x beyond single-device sparse works)");
    }

    bench(1, 10, || {
        std::hint::black_box(figures::fig5a(&cluster, model, batch));
    })
    .report("fig5a sweep (length OOM search per size)");
}
