//! Bench: ring RSA vs Ulysses all-to-all — the SP-strategy crossover.
//!
//! The two sequence-parallel schedules move the SAME attention
//! mathematics with very different wire profiles: the ring rotates K/V
//! chunks every layer (`(2(n−1) + (4n−2))·n` chunk-sends per layer,
//! growing linearly with the ring size), while Ulysses pays 8 all-to-alls
//! per layer (`8(n−1)` chunk-sends in total, flat in n).  Two sections
//! land in `BENCH_ulysses.json`:
//!
//! * `analytic` — the closed-form group-total curves at a BERT-Base-like
//!   shape: ring bytes grow with n, all-to-all bytes stay ~flat, so the
//!   ring/ulysses ratio widens monotonically (asserted in-bench);
//! * `executable` — real training steps on a 4-head bert-tiny variant at
//!   n ∈ {1, 2, 4} for both `--sp` strategies: wall-clock per step plus
//!   the measured `ring_p2p` / `all_to_all` bytes, each pinned EXACTLY to
//!   its closed form, with the two strategies' losses agreeing within
//!   1e-4 (they compute the same step).  Each row also carries the
//!   `obs::` overlap-efficiency metric (hidden comm time / total comm
//!   time) from one traced step — on the eager sequential fabric no
//!   collective ever blocks, so the metric pins to 1.0 wherever the
//!   strategy communicates at all (null where it records no comm span
//!   at all, e.g. the ring at n = 1).
//!
//!     cargo bench --bench ulysses_vs_ring
//!     cargo bench --bench ulysses_vs_ring -- --iters 2 --warmup 1   # CI smoke
//!
//! Flags: --iters N --warmup N --out PATH

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::obs;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::cli::Args;
use seqpar::util::json::{encode, Value};

fn num(v: f64) -> Value {
    Value::Num(v)
}

/// Dense ring RSA group total, chunk-send units per layer.
fn ring_sends(n: u64) -> u64 {
    (2 * (n - 1) + (4 * n - 2)) * n
}

/// Ulysses group total, chunk-send units per layer (8 all-to-alls of the
/// local chunk, each `(n-1)/n` of the chunk per rank → `8(n-1)` chunks).
fn ulysses_sends(n: u64) -> u64 {
    8 * (n - 1)
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let iters = args.usize_or("iters", 5)?;
    let warmup = args.usize_or("warmup", 1)?;
    let out_path = args.str_or("out", "BENCH_ulysses.json").to_string();

    // ---- section 1: analytic closed-form curves (BERT-Base shape) ------
    let (b, z, a, l) = (4u64, 12u64, 64u64, 4096u64);
    println!("analytic (BERT-Base shape, B={b} Z={z} A={a} L={l}, per layer, group totals):");
    println!("{:>4} {:>16} {:>16} {:>8}", "n", "ring bytes", "ulysses bytes", "ratio");
    let mut analytic: Vec<Value> = Vec::new();
    let mut last_ratio = 0.0f64;
    for n in [2u64, 4, 8, 16, 32, 64] {
        let chunk = b * z * (l / n) * a * 4;
        let ring = ring_sends(n) * chunk;
        let uly = ulysses_sends(n) * chunk;
        let ratio = ring as f64 / uly as f64;
        println!("{n:>4} {ring:>16} {uly:>16} {ratio:>7.2}x");
        // the headline property: all-to-all beats the ring everywhere
        // (n >= 2) and its advantage widens monotonically with n — the
        // ring total grows ~linearly while the all-to-all total is flat
        ensure!(uly < ring, "n={n}: ulysses {uly} not below ring {ring}");
        ensure!(
            ratio > last_ratio,
            "n={n}: ring/ulysses ratio {ratio:.2} not monotonically widening (prev {last_ratio:.2})"
        );
        last_ratio = ratio;
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), num(n as f64));
        row.insert("ring_bytes".to_string(), num(ring as f64));
        row.insert("ulysses_bytes".to_string(), num(uly as f64));
        analytic.push(Value::Obj(row));
    }

    // ---- section 2: executable steps (bert-tiny-z4, both strategies) ---
    println!("\nexecutable (bert-tiny-z4, L=32):");
    println!(
        "{:>4} {:>8} {:>12} {:>14} {:>14} {:>10} {:>8}",
        "n", "sp", "step", "ring_p2p", "all_to_all", "loss", "ov-eff"
    );
    let mut exec_rows: Vec<Value> = Vec::new();
    let mut loss_by: BTreeMap<(usize, &str), f32> = BTreeMap::new();
    for n in [1usize, 2, 4] {
        for sp in [SpStrategy::Ring, SpStrategy::Ulysses] {
            let cfg = NativeConfig {
                model: BERT_TINY_Z4,
                ring: n,
                ulysses: !sp.is_ring(),
                ..NativeConfig::tiny()
            };
            let rt = Runtime::native(cfg)?;
            let m = rt.manifest().clone();
            let params = ParamStore::synthetic(&m);
            let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 13)
                .next_batch()?;
            let meter = Meter::new();
            let engine = SeqParEngine::with_strategy(
                &rt,
                Fabric::new(n, meter.clone()),
                AttnPattern::Dense,
                sp,
            )?;
            let loss = engine.forward_backward(&params, &batch)?.loss;

            // one traced step feeds the obs:: hidden-vs-wait attribution
            let rec = obs::Recorder::start();
            engine.forward_backward(&params, &batch)?;
            let overlap_eff =
                obs::MetricsReport::build(&rec.finish(), 1, 0, 0).overlap_efficiency();

            meter.reset();
            let stat = bench(warmup, iters, || {
                std::hint::black_box(engine.forward_backward(&params, &batch).unwrap());
            });
            let steps = (warmup + iters) as u64;
            let ring_p2p = meter.get(CommKind::RingP2p) / steps;
            let a2a = meter.get(CommKind::AllToAll) / steps;

            // pin the measured per-step bytes to the closed forms exactly
            let nn = n as u64;
            let chunk = (m.batch * m.heads * (m.seq_len / n) * m.head_dim * 4) as u64;
            let layers = m.layers as u64;
            if sp.is_ring() {
                let want = if n == 1 { 0 } else { ring_sends(nn) * chunk * layers };
                ensure!(
                    ring_p2p == want,
                    "n={n} ring: measured {ring_p2p}B != closed form {want}B"
                );
                ensure!(a2a == 0, "n={n} ring: unexpected all-to-all bytes {a2a}");
            } else {
                let want = ulysses_sends(nn) * chunk * layers;
                ensure!(
                    a2a == want,
                    "n={n} ulysses: measured {a2a}B != closed form {want}B"
                );
                ensure!(ring_p2p == 0, "n={n} ulysses: unexpected ring bytes {ring_p2p}");
            }
            loss_by.insert((n, sp.label()), loss);

            let eff_str =
                overlap_eff.map(|e| format!("{e:.4}")).unwrap_or_else(|| "-".to_string());
            println!(
                "{n:>4} {:>8} {:>12} {ring_p2p:>13}B {a2a:>13}B {loss:>10.4} {eff_str:>8}",
                sp.label(),
                fmt_ns(stat.mean_ns),
            );
            let mut row = BTreeMap::new();
            row.insert("n".to_string(), num(n as f64));
            row.insert("sp".to_string(), Value::Str(sp.label().to_string()));
            row.insert("step_mean_ns".to_string(), num(stat.mean_ns));
            row.insert("ring_p2p_bytes".to_string(), num(ring_p2p as f64));
            row.insert("all_to_all_bytes".to_string(), num(a2a as f64));
            row.insert("loss".to_string(), num(loss as f64));
            row.insert(
                "overlap_efficiency".to_string(),
                overlap_eff.map(num).unwrap_or(Value::Null),
            );
            exec_rows.push(Value::Obj(row));
        }
        // the two strategies execute the same training step
        let lr = loss_by[&(n, "ring")];
        let lu = loss_by[&(n, "ulysses")];
        ensure!(
            (lr - lu).abs() < 1e-4,
            "n={n}: ring loss {lr} vs ulysses loss {lu} diverged"
        );
    }

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Value::Str("ulysses_vs_ring".to_string()));
    top.insert("analytic_shape".to_string(), Value::Str(format!("B{b}_Z{z}_A{a}_L{l}")));
    top.insert("analytic".to_string(), Value::Arr(analytic));
    top.insert("executable_model".to_string(), Value::Str("bert-tiny-z4".to_string()));
    top.insert("executable".to_string(), Value::Arr(exec_rows));
    std::fs::write(&out_path, encode(&Value::Obj(top)))?;
    println!("wrote {out_path}");
    Ok(())
}
