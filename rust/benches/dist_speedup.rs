//! Bench: wall-clock win of REAL threads over the sequential simulation.
//!
//! For n ∈ {1, 2, 4, 8} ring ranks, times one forward+backward step of:
//!
//! * `serial`   — the single-device engine (no ring, the lower bound on
//!                work);
//! * `seq-sim`  — `SeqParEngine`, all n ranks simulated on one thread
//!                over the `Fabric` slot view;
//! * `threaded` — `exec::DistRunner`, one OS thread per rank over real
//!                ring P2P;
//! * `overlap`  — the same runner with `--overlap` (double-buffered
//!                ring: isend the next chunk, compute on the current
//!                one, wait at the last moment).
//!
//! seq-sim and threaded run the SAME per-rank step code and the same
//! total compute; the ratio between them is pure execution-layer win
//! (cores × overlap).  On top of the wall-clock rows, one traced run
//! per schedule splits ring-P2p span time into hidden vs blocked
//! (`obs::` wait attribution) and reports the overlap efficiency
//! `hidden / busy`; at n ≥ 4 the double-buffered ring must spend
//! strictly less time blocked on recv than the serialized ring.
//! Results land in `BENCH_dist.json` for the perf trajectory.
//!
//!     cargo bench --bench dist_speedup
//!     cargo bench --bench dist_speedup -- --iters 3 --warmup 1   # CI smoke
//!
//! Flags: --iters N --warmup N --sizes 1,2,4,8 --seq-len L --out PATH

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::exec::DistRunner;
use seqpar::model::params::ParamStore;
use seqpar::obs;
use seqpar::parallel::Batch;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::cli::Args;
use seqpar::util::json::{encode, Value};

fn num(v: f64) -> Value {
    Value::Num(v)
}

/// Run `iters` traced steps and split the recorded ring-P2p span time
/// into total span time (busy) and channel-blocked time (wait), summed
/// over all ranks and hops.  Runs outside the timed loops so the
/// recorder never skews the wall-clock rows.
fn ring_p2p_wait(
    runner: &DistRunner,
    params: &ParamStore,
    batch: &Batch,
    iters: usize,
) -> Result<(u64, u64)> {
    let rec = obs::Recorder::start();
    for _ in 0..iters {
        std::hint::black_box(runner.forward_backward(params, batch)?);
    }
    let (mut busy, mut wait) = (0u64, 0u64);
    for e in rec.finish() {
        if let obs::EventKind::Comm { kind: CommKind::RingP2p, wait_ns, .. } = e.kind {
            busy += e.dur_ns;
            wait += wait_ns;
        }
    }
    Ok((busy, wait))
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let iters = args.usize_or("iters", 8)?;
    let warmup = args.usize_or("warmup", 2)?;
    let sizes = args.usize_list_or("sizes", &[1, 2, 4, 8])?;
    let seq_len = args.usize_or("seq-len", 64)?;
    let out_path = args.str_or("out", "BENCH_dist.json").to_string();

    let batch = NativeConfig::tiny().batch;
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    println!(
        "dist_speedup @ bert-tiny (L={seq_len}, {cores} cores, {iters} iters + {warmup} warmup)"
    );
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14} {:>10} {:>8}",
        "n", "serial", "seq-sim", "threaded", "overlap", "speedup", "ov-eff"
    );

    let mut rows: Vec<Value> = Vec::new();
    for &n in &sizes {
        if seq_len % n != 0 {
            println!("{n:>4} skipped: seq_len {seq_len} not divisible by {n}");
            continue;
        }
        let cfg = NativeConfig { seq_len, ring: n, ..NativeConfig::tiny() };
        let rt = Runtime::native(cfg)?;
        let m = rt.manifest().clone();
        let params = ParamStore::synthetic(&m);
        let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3)
            .next_batch()?;

        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new()))?;
        let s = bench(warmup, iters, || {
            std::hint::black_box(serial.forward_backward(&params, &batch).unwrap());
        });

        let seq = SeqParEngine::new(&rt, Fabric::new(n, Meter::new()))?;
        let q = bench(warmup, iters, || {
            std::hint::black_box(seq.forward_backward(&params, &batch).unwrap());
        });

        let dist = DistRunner::new(&rt, Meter::new())?;
        let t = bench(warmup, iters, || {
            std::hint::black_box(dist.forward_backward(&params, &batch).unwrap());
        });

        let dist_ov = DistRunner::new(&rt, Meter::new())?.overlap(true);
        let v = bench(warmup, iters, || {
            std::hint::black_box(dist_ov.forward_backward(&params, &batch).unwrap());
        });

        // wait attribution: one traced run per ring schedule
        let (_, blk_wait) = ring_p2p_wait(&dist, &params, &batch, iters)?;
        let (ov_busy, ov_wait) = ring_p2p_wait(&dist_ov, &params, &batch, iters)?;
        let overlap_eff = if ov_busy > 0 {
            ov_busy.saturating_sub(ov_wait) as f64 / ov_busy as f64
        } else {
            0.0 // n = 1: no ring hops, nothing to hide
        };
        if n >= 2 {
            ensure!(
                overlap_eff > 0.0,
                "n={n}: double-buffered ring hid no comm time \
                 (busy {ov_busy}ns, blocked {ov_wait}ns)"
            );
        }
        if n >= 4 {
            ensure!(
                ov_wait < blk_wait,
                "n={n}: overlap ring blocked {ov_wait}ns on recv, \
                 not below the serialized ring's {blk_wait}ns"
            );
        }

        // seq-sim and threaded do identical work; this ratio is the
        // execution-layer speedup the threaded runner buys.
        let speedup = q.mean_ns / t.mean_ns;
        println!(
            "{n:>4} {:>14} {:>14} {:>14} {:>14} {speedup:>9.2}x {overlap_eff:>8.4}",
            fmt_ns(s.mean_ns),
            fmt_ns(q.mean_ns),
            fmt_ns(t.mean_ns),
            fmt_ns(v.mean_ns),
        );

        let mut row = BTreeMap::new();
        row.insert("n".to_string(), num(n as f64));
        row.insert("serial_mean_ns".to_string(), num(s.mean_ns));
        row.insert("seqsim_mean_ns".to_string(), num(q.mean_ns));
        row.insert("threaded_mean_ns".to_string(), num(t.mean_ns));
        row.insert("overlap_mean_ns".to_string(), num(v.mean_ns));
        row.insert("serial_min_ns".to_string(), num(s.min_ns));
        row.insert("seqsim_min_ns".to_string(), num(q.min_ns));
        row.insert("threaded_min_ns".to_string(), num(t.min_ns));
        row.insert("overlap_min_ns".to_string(), num(v.min_ns));
        row.insert("threaded_speedup_vs_seqsim".to_string(), num(speedup));
        row.insert(
            "blocking_ring_wait_ns".to_string(),
            num(blk_wait as f64 / iters as f64),
        );
        row.insert(
            "overlap_ring_wait_ns".to_string(),
            num(ov_wait as f64 / iters as f64),
        );
        row.insert(
            "overlap_efficiency".to_string(),
            if ov_busy > 0 { num(overlap_eff) } else { Value::Null },
        );
        rows.push(Value::Obj(row));
    }

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Value::Str("dist_speedup".to_string()));
    top.insert("model".to_string(), Value::Str("bert-tiny".to_string()));
    top.insert("batch".to_string(), num(batch as f64));
    top.insert("seq_len".to_string(), num(seq_len as f64));
    top.insert("cores".to_string(), num(cores as f64));
    top.insert("iters".to_string(), num(iters as f64));
    top.insert("rows".to_string(), Value::Arr(rows));
    std::fs::write(&out_path, encode(&Value::Obj(top)))?;
    println!("wrote {out_path}");
    Ok(())
}
