//! Bench: the Fig. 5b shape — max reachable sequence length vs device
//! count, dense vs sparse — plus an EXECUTABLE cross-check of the
//! analytic model.
//!
//! Two sections land in `BENCH_sparse.json`:
//!
//! * `fig5b` — the analytic curves on the paper's testbed (BERT-Base,
//!   16 GB devices): dense sequence parallelism saturates (the `[Lc, L]`
//!   score rows keep one L factor on-device) while Linformer + SP grows
//!   ~linearly with n ("train with infinite long sequence", §4.3);
//! * `executable` — real bert-tiny training steps through every `--attn`
//!   pattern at n ∈ {1, 2, 4}: proves the sparse paths run end-to-end;
//!   each row records wall-clock plus the measured `ring_p2p_bytes` /
//!   `all_reduce_bytes` (dense vs block vs linformer comm profiles side
//!   by side — the Table 3 regime), and the Linformer rows cross-check
//!   the executable per-device activation footprint against
//!   `simulator::sparse::peak_bytes_linformer`'s accounting.
//!
//!     cargo bench --bench sparse_seqlen
//!     cargo bench --bench sparse_seqlen -- --iters 2 --warmup 1   # CI smoke
//!
//! Flags: --iters N --warmup N --out PATH

use std::collections::BTreeMap;

use anyhow::Result;

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::model::params::ParamStore;
use seqpar::model::{BERT_BASE, BERT_TINY};
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::simulator::{search, sparse, Cluster, RunShape, Strategy};
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::cli::Args;
use seqpar::util::json::{encode, Value};

fn num(v: f64) -> Value {
    Value::Num(v)
}

/// Executable per-device activation bytes for one Linformer layer stash —
/// the exact tensors `parallel::sequence` holds for backward (the MLP
/// hidden is rematerialized, so it is absent here and present in the
/// simulator's ledger; the cross-check band accounts for that).
fn linformer_stash_bytes(b: usize, lc: usize, h: usize, z: usize, a: usize, kp: usize) -> u64 {
    let tok = (b * lc) as u64;
    let elems = tok * h as u64                      // x_in
        + 3 * (b * z * lc * a) as u64               // q, k, v
        + 2 * (b * z * kp * a) as u64               // projected K̃, Ṽ
        + (b * z * lc * kp) as u64                  // probs [Lc, k]
        + (b * z * lc * a) as u64                   // ctx
        + 3 * tok * h as u64;                       // pre1, xm, pre2
    elems * 4
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let iters = args.usize_or("iters", 5)?;
    let warmup = args.usize_or("warmup", 1)?;
    let out_path = args.str_or("out", "BENCH_sparse.json").to_string();

    // ---- section 1: analytic Fig. 5b curves (BERT-Base, paper cluster) --
    let cluster = Cluster::default();
    let kp = 256usize;
    println!("fig5b (analytic, BERT-Base, batch 4, 16 GB devices, k={kp}):");
    println!("{:>6} {:>14} {:>16} {:>8}", "n", "dense max L", "linformer max L", "ratio");
    let mut fig5b: Vec<Value> = Vec::new();
    let mut sparse_lens: Vec<(usize, usize)> = Vec::new();
    for n in [8usize, 16, 32, 64] {
        let dense = search::max_seq_len(&cluster, BERT_BASE, 4, 1, 1, Strategy::Sequence { n }, 256);
        let linf = sparse::max_seq_len_linformer(&cluster, BERT_BASE, 4, n, kp, 256);
        println!("{n:>6} {dense:>14} {linf:>16} {:>7.1}x", linf as f64 / dense.max(1) as f64);
        sparse_lens.push((n, linf));
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), num(n as f64));
        row.insert("dense_max_len".to_string(), num(dense as f64));
        row.insert("linformer_max_len".to_string(), num(linf as f64));
        fig5b.push(Value::Obj(row));
    }
    // the headline property the JSON must exhibit: Linformer's reachable
    // length grows ~linearly with n (8x devices => ~8x tokens)
    let (n0, l0) = sparse_lens[0];
    let (n3, l3) = sparse_lens[3];
    let scaling = (l3 as f64 / l0 as f64) / (n3 as f64 / n0 as f64);
    anyhow::ensure!(
        (0.4..=1.6).contains(&scaling),
        "linformer max-L scaling {scaling:.2} not ~linear in n ({n0}:{l0} -> {n3}:{l3})"
    );

    // ---- section 2: executable cross-check (bert-tiny, every pattern) ---
    let (b, l, z, a, h) = (2usize, 32usize, BERT_TINY.heads, BERT_TINY.head_dim, BERT_TINY.hidden);
    let tiny_k = 8usize;
    println!("\nexecutable (bert-tiny, L={l}, linformer:{tiny_k}):");
    println!(
        "{:>4} {:>12} {:>14} {:>14} {:>14} {:>8}",
        "n", "pattern", "step", "measured act", "sim peak", "ratio"
    );
    let mut exec_rows: Vec<Value> = Vec::new();
    let mut measured_by_n: Vec<(usize, u64)> = Vec::new();
    for n in [1usize, 2, 4] {
        for pattern in [
            AttnPattern::Dense,
            AttnPattern::Linformer { k: tiny_k },
            AttnPattern::Block { w: 8 },
        ] {
            let (linformer_k, block_w) = pattern.native_knobs();
            let cfg = NativeConfig {
                ring: n,
                seq_len: l,
                linformer_k,
                block_w,
                ..NativeConfig::tiny()
            };
            let rt = Runtime::native(cfg)?;
            let m = rt.manifest().clone();
            let params = ParamStore::synthetic(&m);
            let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 11)
                .next_batch()?;
            let meter = Meter::new();
            let engine = SeqParEngine::with_pattern(&rt, Fabric::new(n, meter.clone()), pattern)?;
            let stat = bench(warmup, iters, || {
                std::hint::black_box(engine.forward_backward(&params, &batch).unwrap());
            });

            let mut row = BTreeMap::new();
            row.insert("n".to_string(), num(n as f64));
            row.insert("attn".to_string(), Value::Str(pattern.label()));
            row.insert("step_mean_ns".to_string(), num(stat.mean_ns));
            row.insert("ring_p2p_bytes".to_string(), num(meter.get(CommKind::RingP2p) as f64));
            row.insert(
                "all_reduce_bytes".to_string(),
                num(meter.get(CommKind::AllReduce) as f64),
            );

            if let AttnPattern::Linformer { k } = pattern {
                // cross-check: executable per-device activation bytes vs
                // the simulator's Table 3 ledger for the same shape
                let lc = l / n;
                let measured =
                    linformer_stash_bytes(b, lc, h, z, a, k) * BERT_TINY.layers as u64;
                let sim_peak =
                    sparse::peak_bytes_linformer(&RunShape::new(BERT_TINY, b, l), n, k);
                let ratio = measured as f64 / sim_peak as f64;
                // the ledger also counts params+opt state and transients,
                // so measured activations must be a sane fraction of it
                anyhow::ensure!(
                    (0.01..=1.0).contains(&ratio),
                    "measured activations {measured}B vs simulated peak {sim_peak}B (ratio {ratio})"
                );
                measured_by_n.push((n, measured));
                println!(
                    "{n:>4} {:>12} {:>14} {measured:>13}B {sim_peak:>13}B {ratio:>7.3}",
                    pattern.label(),
                    fmt_ns(stat.mean_ns),
                );
                row.insert("measured_act_bytes".to_string(), num(measured as f64));
                row.insert("sim_peak_bytes".to_string(), num(sim_peak as f64));
            } else {
                println!(
                    "{n:>4} {:>12} {:>14} {:>14} {:>14} {:>8}",
                    pattern.label(),
                    fmt_ns(stat.mean_ns),
                    "-",
                    "-",
                    "-"
                );
            }
            exec_rows.push(Value::Obj(row));
        }
    }
    // per-device activations must shrink ~linearly with n (Table 3)
    let m1 = measured_by_n.iter().find(|(n, _)| *n == 1).unwrap().1;
    let m4 = measured_by_n.iter().find(|(n, _)| *n == 4).unwrap().1;
    let shrink = m1 as f64 / m4 as f64;
    anyhow::ensure!(
        (2.0..=5.0).contains(&shrink),
        "activation shrink n=1 -> n=4 is {shrink:.2}x, expected ~4x"
    );
    // the SLOPE in n must agree with the ledger: param/opt state is
    // n-invariant under SP, so peak(1) − peak(4) isolates the simulator's
    // L-scaled activation+transient bytes; the executable stash delta is
    // that minus the documented differences (MLP-hidden recompute, MLM
    // logit transients), which pins the two accountings to the same
    // scale — a lost `layers` factor or unit slip lands far outside.
    let sim_delta = sparse::peak_bytes_linformer(&RunShape::new(BERT_TINY, b, l), 1, tiny_k)
        - sparse::peak_bytes_linformer(&RunShape::new(BERT_TINY, b, l), 4, tiny_k);
    let meas_delta = m1 - m4;
    let slope = meas_delta as f64 / sim_delta as f64;
    anyhow::ensure!(
        (0.2..=1.0).contains(&slope),
        "executable stash delta {meas_delta}B vs ledger delta {sim_delta}B (slope {slope:.3})"
    );

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Value::Str("sparse_seqlen".to_string()));
    top.insert("fig5b_model".to_string(), Value::Str("bert-base".to_string()));
    top.insert("fig5b_k".to_string(), num(kp as f64));
    top.insert("fig5b".to_string(), Value::Arr(fig5b));
    top.insert("executable_model".to_string(), Value::Str("bert-tiny".to_string()));
    top.insert("executable".to_string(), Value::Arr(exec_rows));
    std::fs::write(&out_path, encode(&Value::Obj(top)))?;
    println!("wrote {out_path}");
    Ok(())
}
