//! Bench: Table 4 — weak scaling along batch and sequence dimensions
//! (pipeline 8), plus Tables 1/2/3 closed forms.
//!
//!     cargo bench --bench table4_weak_scaling

use seqpar::eval::bench::bench;
use seqpar::eval::figures;
use seqpar::model::BERT_BASE;
use seqpar::simulator::{memory, sparse, Cluster};

fn main() {
    let cluster = Cluster::default();
    println!("=== Table 4 — weak scaling, BERT-Base, pipeline=8 ===");
    println!(
        "{:>4} {:>6} {:>6} | {:>10} {:>10} | {:>10} {:>10}",
        "n", "batch", "L", "TP MB", "TP tok/s", "SP MB", "SP tok/s"
    );
    for r in figures::table4(&cluster, BERT_BASE) {
        println!(
            "{:>4} {:>6} {:>6} | {:>10} {:>10} | {:>10.1} {:>10.0}",
            r.n,
            r.batch,
            r.seq_len,
            r.tp_mem_mb.map(|m| format!("{m:.1}")).unwrap_or_else(|| "OOM".into()),
            r.tp_tokens_per_sec.map(|v| format!("{v:.0}")).unwrap_or("—".into()),
            r.sp_mem_mb,
            r.sp_tokens_per_sec,
        );
    }
    println!("(paper: SP memory flat at ~8.5GB while TP OOMs at n=8; SP less memory on the length sweep)");

    println!("\n=== Tables 1/2 closed forms (elements) at B=64 L=512 N=8 ===");
    for row in figures::tables12(BERT_BASE, 64, 512, 8) {
        println!(
            "{:<22} TP {:>14}  SP {:>14}   winner: {}",
            row.block, row.tp_elems, row.sp_elems,
            if row.sp_wins { "sequence" } else { "tensor" }
        );
    }
    println!(
        "break-evens: MLP BL>32H={}, Attn BL>16AZ={}",
        memory::mlp_breakeven_bl(768),
        memory::attn_breakeven_bl(64, 12)
    );
    println!("\n=== Table 3 — Linformer+SP block elements (B=4 L=65536 K=256) ===");
    for n in [8u64, 16, 32] {
        println!(
            "N={n:>3}: {} elements",
            sparse::paper_sparse_attn(4, 65536, 768, 64, 12, 256, n)
        );
    }

    bench(1, 20, || {
        std::hint::black_box(figures::table4(&cluster, BERT_BASE));
    })
    .report("table4 sweep");
}
