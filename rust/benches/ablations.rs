//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. Ring-chunk granularity — communication volume & simulated step time
//!    as the ring size grows at fixed work (the paper's "same comm as
//!    Megatron" §3.2.2 claim, swept).
//! 2. Pipeline boundary handling — Megatron's scatter+all-gather vs the
//!    sequence-parallel direct send, over stage counts (the mechanism
//!    behind Fig. 4b).
//! 3. Microbatch count — bubble fraction vs boundary traffic trade-off.
//!
//!     cargo bench --bench ablations

use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::eval::bench::bench;
use seqpar::model::BERT_BASE;
use seqpar::parallel::pipeline::{boundary_bytes_megatron, boundary_bytes_seqpar, Schedule};
use seqpar::simulator::{timing, Cluster, RunShape, Strategy};
use seqpar::tensor::Tensor;

fn main() {
    let cluster = Cluster::default();

    println!("=== ablation 1: ring size at fixed global work (B=64, L=512) ===");
    println!("{:>4} {:>14} {:>14} {:>12}", "n", "SP bytes/layer", "TP bytes/layer", "SP/TP time");
    for n in [2usize, 4, 8, 16, 32, 64] {
        let shape = RunShape::new(BERT_BASE, 64, 512);
        let sp = Strategy::Sequence { n };
        if !sp.feasible(&BERT_BASE, 512) {
            continue;
        }
        // paper closed form: both equal 8(N-1)·BZ(L/N)A elements
        let chunk = (64 * 12 * (512 / n) * 64 * 4) as u64;
        let sp_bytes = 8 * (n as u64 - 1) * chunk;
        let sp_t = timing::step_time(&cluster, &shape, sp).expect("n >= 2 is non-degenerate");
        let tp_feasible = BERT_BASE.heads % n == 0;
        let (tp_bytes, ratio) = if tp_feasible {
            let c = (64 * 512 * 768 * 4) as u64;
            let tp_bytes = 8 * (n as u64 - 1) * c / n as u64;
            let tp_t = timing::step_time(&cluster, &shape, Strategy::Tensor { n })
                .expect("n >= 2 is non-degenerate");
            (tp_bytes.to_string(), format!("{:.3}", sp_t / tp_t))
        } else {
            ("—".into(), "—".into())
        };
        println!("{n:>4} {sp_bytes:>14} {tp_bytes:>14} {ratio:>12}");
    }
    println!("(equal volumes at equal n — the §3.2.2 equivalence)");

    println!("\n=== ablation 2: pipeline boundary bytes per microbatch (MP=4) ===");
    println!(
        "{:>6} {:>24} {:>16} {:>8}",
        "B", "megatron scat+send+gath", "seqpar send", "saving"
    );
    for b in [8usize, 32, 128] {
        let meg = boundary_bytes_megatron(b, 512, 768, 4);
        let sp = boundary_bytes_seqpar(b, 512, 768, 4);
        // the executable boundary (exec::mesh) also meters the scatter,
        // which costs exactly the send volume — include it so this table
        // agrees with the measured BENCH_mesh.json boundary totals
        let m_total = meg.send + meg.send + meg.gather;
        let s_total = sp.send + sp.gather;
        println!(
            "{b:>6} {m_total:>24} {s_total:>16} {:>7.1}%",
            100.0 * (m_total - s_total) as f64 / m_total as f64
        );
    }

    println!("\n=== ablation 3: microbatches vs bubble (4 stages) ===");
    println!("{:>8} {:>10} {:>14}", "micros", "bubble", "sim tok/s (SP4)");
    for micros in [1usize, 2, 4, 8, 16, 32] {
        let s = Schedule::gpipe(4, micros);
        let shape = RunShape::new(BERT_BASE, 32, 512).with_pipeline(4, micros);
        let tps = timing::tokens_per_sec(&cluster, &shape, Strategy::Sequence { n: 4 })
            .expect("micros >= 1 is non-degenerate");
        println!("{micros:>8} {:>10.3} {tps:>14.0}", s.bubble_fraction());
    }

    // fabric micro-benchmarks (the in-process substrate itself)
    println!("\n=== fabric micro-benchmarks ===");
    let meter = Meter::new();
    let fabric = Fabric::new(8, meter);
    let mut slots: Vec<Tensor> = (0..8).map(|_| Tensor::zeros(&[256 * 1024])).collect();
    bench(3, 50, || {
        fabric.ring_shift(&mut slots).unwrap();
    })
    .report("ring_shift 8 x 1MB");
    bench(3, 20, || {
        fabric.all_reduce_sum(&mut slots).unwrap();
    })
    .report("all_reduce 8 x 1MB");
    let _ = fabric.meter.get(CommKind::RingP2p);
}
