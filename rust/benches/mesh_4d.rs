//! Bench: the executable 4D mesh — DP×PP×SP vs the DP×PP×TP baseline.
//!
//! For each mesh shape in the matrix, times one full mesh training step
//! (threaded `exec::MeshRunner`, one OS thread per coordinate) for both
//! model-parallel kinds and records the metered traffic, separating the
//! stage-boundary counters (Pipeline / AllGather / Scatter) where the
//! paper's §3.2.2 claim lives: SP sends its already-split chunk, TP pays
//! scatter + all-gather on top.  The bench asserts the claim on the
//! measured bytes — strictly fewer boundary bytes for SP at every
//! pipelined shape — and writes `BENCH_mesh.json` for the trajectory.
//!
//!     cargo bench --bench mesh_4d
//!     cargo bench --bench mesh_4d -- --iters 2 --warmup 1   # CI smoke
//!
//! Flags: --iters N --warmup N --micros M --seq-len L --out PATH

use std::collections::BTreeMap;

use anyhow::Result;

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Meter};
use seqpar::eval::bench::{bench, fmt_ns};
use seqpar::exec::{MeshRunner, MeshStep};
use seqpar::model::params::ParamStore;
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::parallel::Batch;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::cli::Args;
use seqpar::util::json::{encode, Value};

fn num(v: f64) -> Value {
    Value::Num(v)
}

const SHAPES: [(usize, usize, usize); 4] = [(1, 1, 4), (2, 1, 2), (1, 2, 2), (2, 2, 2)];

fn main() -> Result<()> {
    let args = Args::parse_env();
    let iters = args.usize_or("iters", 6)?;
    let warmup = args.usize_or("warmup", 1)?;
    let micros = args.usize_or("micros", 2)?;
    let seq_len = args.usize_or("seq-len", 32)?;
    let out_path = args.str_or("out", "BENCH_mesh.json").to_string();

    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    println!(
        "mesh_4d @ bert-tiny (L={seq_len}, micros={micros}, {cores} cores, {iters} iters + {warmup} warmup)"
    );
    println!(
        "{:>10} {:>6} {:>14} {:>12} {:>12} {:>12}",
        "mesh", "world", "step", "boundary", "ring+ar", "bubble"
    );

    let mut rows: Vec<Value> = Vec::new();
    // boundary totals per (dp,pp,mp) shape, to assert SP < TP at the end
    let mut boundary: BTreeMap<(usize, usize, usize, bool), u64> = BTreeMap::new();
    for (dp, pp, mp) in SHAPES {
        for kind in [MpKind::Sequence, MpKind::Tensor] {
            let mesh = Mesh::new(dp, pp, mp, kind)?;
            let cfg = NativeConfig { seq_len, ..NativeConfig::tiny() }.for_mesh(&mesh);
            if kind == MpKind::Tensor && cfg.model.heads % mp != 0 {
                println!(
                    "{:>10} {:>6} skipped: Megatron's cap (mp {mp} > {} heads)",
                    mesh.label(),
                    cfg.model.heads
                );
                continue;
            }
            let rt = Runtime::native(cfg)?;
            let m = rt.manifest().clone();
            let params = ParamStore::synthetic(&m);
            let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3);
            let batches: Vec<Vec<Batch>> = (0..dp)
                .map(|_| (0..micros).map(|_| corpus.next_batch()).collect::<Result<_>>())
                .collect::<Result<_>>()?;

            let meter = Meter::new();
            let runner = MeshRunner::new(&rt, mesh, micros, meter.clone())?;
            // one metered step for the traffic columns
            meter.reset();
            runner.step(&params, &batches)?;
            let snap = meter.snapshot();
            let bnd = snap.pipeline + snap.all_gather + snap.scatter;
            boundary.insert((dp, pp, mp, kind == MpKind::Sequence), bnd);

            let t = bench(warmup, iters, || {
                std::hint::black_box(runner.step(&params, &batches).unwrap());
            });
            let bubble = seqpar::parallel::pipeline::Schedule::gpipe(pp, micros).bubble_fraction();
            println!(
                "{:>10} {:>6} {:>14} {:>12} {:>12} {:>12.3}",
                mesh.label(),
                mesh.world_size(),
                fmt_ns(t.mean_ns),
                bnd,
                snap.ring_p2p + snap.all_reduce,
                bubble,
            );

            let mut row = BTreeMap::new();
            row.insert("mesh".to_string(), Value::Str(mesh.label()));
            row.insert("dp".to_string(), num(dp as f64));
            row.insert("pp".to_string(), num(pp as f64));
            row.insert("mp".to_string(), num(mp as f64));
            row.insert(
                "kind".to_string(),
                Value::Str(if kind == MpKind::Sequence { "sp" } else { "tp" }.to_string()),
            );
            row.insert("world".to_string(), num(mesh.world_size() as f64));
            row.insert("micros".to_string(), num(micros as f64));
            row.insert("mean_ns".to_string(), num(t.mean_ns));
            row.insert("min_ns".to_string(), num(t.min_ns));
            row.insert("bubble_fraction".to_string(), num(bubble));
            row.insert("ring_p2p_bytes".to_string(), num(snap.ring_p2p as f64));
            row.insert("all_reduce_bytes".to_string(), num(snap.all_reduce as f64));
            row.insert("boundary_pipeline_bytes".to_string(), num(snap.pipeline as f64));
            row.insert("boundary_all_gather_bytes".to_string(), num(snap.all_gather as f64));
            row.insert("boundary_scatter_bytes".to_string(), num(snap.scatter as f64));
            row.insert("boundary_total_bytes".to_string(), num(bnd as f64));
            rows.push(Value::Obj(row));
        }
    }

    // the §3.2.2 claim, on measured bytes: SP boundaries strictly cheaper
    // than TP at every pipelined shape
    for (dp, pp, mp) in SHAPES {
        let (Some(&sp), Some(&tp)) = (
            boundary.get(&(dp, pp, mp, true)),
            boundary.get(&(dp, pp, mp, false)),
        ) else {
            continue;
        };
        if pp > 1 && mp > 1 {
            assert!(
                sp < tp,
                "{dp}x{pp}x{mp}: SP boundary bytes {sp} must be strictly below TP {tp}"
            );
        }
    }
    println!("(SP < TP boundary bytes asserted at every pipelined shape)");

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Value::Str("mesh_4d".to_string()));
    top.insert("model".to_string(), Value::Str("bert-tiny".to_string()));
    top.insert("seq_len".to_string(), num(seq_len as f64));
    top.insert("micros".to_string(), num(micros as f64));
    top.insert("cores".to_string(), num(cores as f64));
    top.insert("iters".to_string(), num(iters as f64));
    top.insert("rows".to_string(), Value::Arr(rows));
    std::fs::write(&out_path, encode(&Value::Obj(top)))?;
    println!("wrote {out_path}");
    Ok(())
}
