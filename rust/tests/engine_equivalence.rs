//! Integration: the three engines are numerically interchangeable.
//!
//! The paper's correctness story (Fig. 6 / Appendix B) rests on sequence
//! parallelism computing THE SAME training step as the baselines.  These
//! tests drive all engines over random batches and assert losses, hidden
//! states, and every parameter gradient agree — not just trends.
//!
//! They run on the native backend by default (no artifacts needed; this is
//! what CI executes).  The artifact-backed variant of the same checks is
//! compiled behind the `backend-xla` feature at the bottom of the file.

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::{Batch, Engine};
use seqpar::runtime::Runtime;
use seqpar::tensor::ops;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::train::optim::{Adam, AdamConfig};

fn runtime() -> Runtime {
    Runtime::native(NativeConfig::tiny()).unwrap()
}

fn batch_for(rt: &Runtime, seed: u64) -> Batch {
    let m = rt.manifest();
    Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed)
        .next_batch()
        .unwrap()
}

const TOL: f32 = 2e-3;

#[test]
fn engines_agree_on_losses_and_grads() {
    let rt = runtime();
    let params = ParamStore::synthetic(rt.manifest());
    for seed in [10u64, 11, 12] {
        let batch = batch_for(&rt, seed);
        let m = rt.manifest().clone();
        let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new())).unwrap();
        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new())).unwrap();
        let tp = TensorParEngine::new(&rt, Fabric::new(m.tp, Meter::new())).unwrap();

        let a = seq.forward_backward(&params, &batch).unwrap();
        let b = serial.forward_backward(&params, &batch).unwrap();
        let c = tp.forward_backward(&params, &batch).unwrap();

        assert!((a.loss - b.loss).abs() < TOL, "seed {seed}: seq {} vs serial {}", a.loss, b.loss);
        assert!((c.loss - b.loss).abs() < TOL, "seed {seed}: tp {} vs serial {}", c.loss, b.loss);

        for (name, g) in &b.grads.values {
            let da = ops::max_abs_diff(&a.grads.values[name], g).unwrap();
            assert!(da < TOL, "seed {seed}: grad {name} seq vs serial Δ={da}");
            let dc = ops::max_abs_diff(&c.grads.values[name], g).unwrap();
            assert!(dc < TOL, "seed {seed}: grad {name} tp vs serial Δ={dc}");
        }

        // hidden states: seq chunks reassemble to the serial tensor
        let lc = m.seq_len / m.ring;
        let chunks3d: Vec<_> = a
            .hidden
            .iter()
            .map(|h| h.clone().reshaped(&[m.batch, lc, m.hidden]).unwrap())
            .collect();
        let refs: Vec<_> = chunks3d.iter().collect();
        let full = ops::concat_dim(&refs, 1)
            .unwrap()
            .reshaped(&[m.batch * m.seq_len, m.hidden])
            .unwrap();
        let dh = ops::max_abs_diff(&full, &b.hidden[0]).unwrap();
        assert!(dh < TOL, "seed {seed}: hidden Δ={dh}");
    }
}

#[test]
fn sgd_trajectories_stay_locked() {
    // Three Adam steps with each engine from the same init: parameters
    // must remain identical (the strong version of Fig. 6).
    let rt = runtime();
    let mut p_seq = ParamStore::synthetic(rt.manifest());
    let mut p_ser = ParamStore::synthetic(rt.manifest());
    let m = rt.manifest().clone();
    let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new())).unwrap();
    let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new())).unwrap();
    let mut adam_a = Adam::new(&p_seq, AdamConfig::default());
    let mut adam_b = Adam::new(&p_ser, AdamConfig::default());
    for step in 0..3u64 {
        let batch = batch_for(&rt, 100 + step);
        let oa = seq.forward_backward(&p_seq, &batch).unwrap();
        let ob = serial.forward_backward(&p_ser, &batch).unwrap();
        adam_a.step(&mut p_seq, &oa.grads, 1e-3).unwrap();
        adam_b.step(&mut p_ser, &ob.grads, 1e-3).unwrap();
    }
    let mut worst = (String::new(), 0.0f32);
    for (name, a) in &p_seq.values {
        let d = ops::max_abs_diff(a, &p_ser.values[name]).unwrap();
        if d > worst.1 {
            worst = (name.clone(), d);
        }
    }
    assert!(
        worst.1 < 5e-3,
        "after 3 Adam steps params diverged: {} Δ={}",
        worst.0,
        worst.1
    );
}

#[test]
fn data_parallel_composes_with_sequence_parallel() {
    // 4D story: DP(2) over SP(ring) — averaged grads equal the average of
    // two independent SP steps.
    let rt = runtime();
    let params = ParamStore::synthetic(rt.manifest());
    let m = rt.manifest().clone();
    let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new())).unwrap();
    let dp = seqpar::parallel::data::DataParallel::new(&seq, Fabric::new(2, Meter::new()));
    let b1 = batch_for(&rt, 31);
    let b2 = batch_for(&rt, 32);
    let out = dp.step(&params, &[b1.clone(), b2.clone()]).unwrap();

    let o1 = seq.forward_backward(&params, &b1).unwrap();
    let o2 = seq.forward_backward(&params, &b2).unwrap();
    let want_loss = (o1.loss + o2.loss) / 2.0;
    assert!((out.loss - want_loss).abs() < 1e-4);
    for (name, g) in &out.grads.values {
        let mut avg = o1.grads.values[name].clone();
        ops::add_assign(&mut avg, &o2.grads.values[name]).unwrap();
        ops::scale_assign(&mut avg, 0.5).unwrap();
        let d = ops::max_abs_diff(g, &avg).unwrap();
        assert!(d < 1e-5, "DP grad {name} Δ={d}");
    }
}

#[test]
fn engine_rejects_mismatched_group_size() {
    // the manifest pins ring/tp; an engine asking for a different group
    // must fail at construction, not mid-schedule
    let rt = runtime();
    let ring = rt.manifest().ring;
    assert!(SeqParEngine::new(&rt, Fabric::new(ring + 1, Meter::new())).is_err());
    let heads = rt.manifest().heads;
    assert!(TensorParEngine::new(&rt, Fabric::new(heads + 1, Meter::new())).is_err());
}

/// Artifact-backed variant: the same equivalence over the PJRT backend.
/// Skips (with a note) when `artifacts/manifest.json` is absent.
#[cfg(feature = "backend-xla")]
mod xla_artifacts {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engines_agree_on_artifacts() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Runtime::open(&dir).unwrap();
        let params = ParamStore::load(&dir, rt.manifest()).unwrap();
        let batch = batch_for(&rt, 10);
        let m = rt.manifest().clone();
        let seq = SeqParEngine::new(&rt, Fabric::new(m.ring, Meter::new())).unwrap();
        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new())).unwrap();
        let a = seq.forward_backward(&params, &batch).unwrap();
        let b = serial.forward_backward(&params, &batch).unwrap();
        assert!((a.loss - b.loss).abs() < TOL, "seq {} vs serial {}", a.loss, b.loss);
        for (name, g) in &b.grads.values {
            let d = ops::max_abs_diff(&a.grads.values[name], g).unwrap();
            assert!(d < TOL, "grad {name} Δ={d}");
        }
    }
}
