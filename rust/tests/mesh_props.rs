//! Property-based fuzz over the executable 4D mesh: random small run
//! shapes and random mesh factorizations (invalid ones must be REJECTED
//! by the constructors, valid ones must match the serial engine), plus
//! the boundary-bytes ledger: the measured stage-boundary traffic must
//! equal `pipeline::boundary_totals` EXACTLY, per collective kind —
//! including the SP-skips-all-gather delta of paper §3.2.2.

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::exec::{MeshEngine, MeshStep};
use seqpar::model::params::ParamStore;
use seqpar::parallel::pipeline::boundary_totals;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::parallel::{Batch, Engine};
use seqpar::runtime::Runtime;
use seqpar::tensor::ops;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::prop::{self, Prop};

const TOL: f32 = 1e-4;

fn batches_for(rt: &Runtime, dp: usize, micros: usize, seed: u64) -> Vec<Vec<Batch>> {
    let m = rt.manifest();
    let mut c = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    (0..dp)
        .map(|_| (0..micros).map(|_| c.next_batch().unwrap()).collect())
        .collect()
}

#[test]
fn random_meshes_match_serial_and_pin_boundary_bytes() {
    Prop::new(12, 0x4d_e511).check("mesh ~ serial + boundary ledger", |rng| {
        // ---- sample a run shape + factorization ----------------------
        let world = *prop::pick(rng, &[1usize, 2, 4]);
        let (dp, pp, mp) = prop::factor3(rng, world);
        let kind = if rng.below(2) == 0 { MpKind::Sequence } else { MpKind::Tensor };
        let micros = 1 + rng.below(2) as usize;
        let chunk = *prop::pick(rng, &[4usize, 8]);
        let seq_len = mp * chunk; // always divisible by the mp axis

        let mesh = Mesh::new(dp, pp, mp, kind).map_err(|e| e.to_string())?;
        let cfg = NativeConfig { seq_len, ..NativeConfig::tiny() }.for_mesh(&mesh);
        let rt = Runtime::native(cfg).map_err(|e| e.to_string())?;
        let m = rt.manifest().clone();

        // ---- invalid factorizations must be rejected -----------------
        let layers_ok = m.layers % pp == 0;
        let heads_ok = kind == MpKind::Sequence || m.heads % mp == 0;
        let built = MeshEngine::new(&rt, mesh, micros, Meter::new());
        if !layers_ok || !heads_ok {
            if built.is_ok() {
                return Err(format!(
                    "mesh {} (layers_ok={layers_ok} heads_ok={heads_ok}) should be rejected",
                    mesh.label()
                ));
            }
            return Ok(()); // rejection path exercised
        }
        let _ = built.map_err(|e| format!("valid mesh {} rejected: {e}", mesh.label()))?;

        // ---- grad parity vs the serial engine ------------------------
        let params = ParamStore::synthetic(&m);
        let batches = batches_for(&rt, dp, micros, 17 + world as u64);
        let meter = Meter::new();
        let eng = MeshEngine::new(&rt, mesh, micros, meter.clone()).map_err(|e| e.to_string())?;
        let out = eng.step(&params, &batches).map_err(|e| e.to_string())?;

        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new()))
            .map_err(|e| e.to_string())?;
        let mut ref_loss = 0.0f32;
        let mut ref_grads = params.zeros_like();
        for replica in &batches {
            for b in replica {
                let o = serial.forward_backward(&params, b).map_err(|e| e.to_string())?;
                ref_loss += o.loss;
                for (name, g) in &o.grads.values {
                    ops::add_assign(ref_grads.get_mut(name).unwrap(), g).unwrap();
                }
            }
        }
        for t in ref_grads.values.values_mut() {
            ops::scale_assign(t, 1.0 / dp as f32).unwrap();
        }
        ref_loss /= dp as f32;

        if (out.loss - ref_loss).abs() >= TOL {
            return Err(format!(
                "{} micros={micros}: mesh loss {} vs serial {ref_loss}",
                mesh.label(),
                out.loss
            ));
        }
        for (name, g) in &ref_grads.values {
            let d = ops::max_abs_diff(&out.grads.values[name], g).unwrap();
            if d >= TOL {
                return Err(format!(
                    "{} micros={micros}: grad {name} diverged, Δ={d}",
                    mesh.label()
                ));
            }
        }

        // ---- boundary-bytes ledger vs the closed form ----------------
        // The mesh meters Pipeline/AllGather/Scatter ONLY at stage
        // boundaries, so the counters must equal the closed form exactly.
        // `boundary_totals` is per pipeline; every dp replica runs its own.
        let per = boundary_totals(kind, m.batch, m.seq_len, m.hidden, mp, pp, micros);
        let (want_send, want_gather) = (per.send * dp as u64, per.gather * dp as u64);
        let got_send = meter.get(CommKind::Pipeline);
        let got_gather = meter.get(CommKind::AllGather);
        let got_scatter = meter.get(CommKind::Scatter);
        if got_send != want_send {
            return Err(format!(
                "{} micros={micros}: boundary send {got_send} != closed form {want_send}",
                mesh.label()
            ));
        }
        if got_gather != want_gather {
            return Err(format!(
                "{} micros={micros}: boundary gather {got_gather} != closed form {want_gather}",
                mesh.label()
            ));
        }
        // Megatron scatters exactly what it sends; SP never scatters.
        let want_scatter = match kind {
            MpKind::Tensor if mp > 1 => want_send,
            _ => 0,
        };
        if got_scatter != want_scatter {
            return Err(format!(
                "{} micros={micros}: boundary scatter {got_scatter} != {want_scatter}",
                mesh.label()
            ));
        }
        Ok(())
    });
}
