//! Integration: the executable 4D mesh (DP×PP×SP, and the DP×PP×TP
//! baseline) computes THE SAME training step as the serial engine — the
//! paper's "4D parallelism" compatibility claim, measured instead of
//! assumed.
//!
//! For every mesh in {1×1×4, 2×1×2, 1×2×2, 2×2×2} × {SP, TP} ×
//! micros ∈ {1, 2, 4} (TP shapes above Megatron's head-count cap are
//! asserted to be *rejected* — bert-tiny has 2 heads, which is exactly
//! the paper's §4.2 scaling-limit point):
//!
//! * threaded `MeshRunner` == sequential `MeshEngine` == a serial
//!   reference (the single-device engine looped over every
//!   replica × microbatch, grads summed over micros and averaged over
//!   dp) on loss and every parameter gradient, within 1e-4;
//! * sequential and threaded meters agree byte-for-byte per collective;
//! * the threaded run is bit-deterministic across runs;
//! * at dp=pp=1 the mesh IS pure sequence parallelism (matches
//!   `SeqParEngine`/`DistRunner`);
//! * at equal mesh shape the SP stage boundaries move strictly fewer
//!   bytes than the TP baseline (SP skips scatter + all-gather);
//! * a checkpoint written under one mesh resumes bitwise-identically on
//!   a different factorization of the same world size.

use std::sync::Arc;

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::exec::{DistRunner, MeshEngine, MeshOutput, MeshRunner, MeshStep};
use seqpar::model::params::ParamStore;
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::parallel::{Batch, Engine};
use seqpar::runtime::Runtime;
use seqpar::tensor::ops;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::train::checkpoint::{self, Checkpoint};
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::train::optim::{Adam, AdamConfig};
use seqpar::util::state_hash::train_state_hash;

const TOL: f32 = 1e-4;

/// The native manifest must be lowered for the mesh's model axis
/// (ring=mp for SP, tp=mp for TP) — `NativeConfig::for_mesh` is the one
/// shared lowering rule; over-the-head-cap TP shapes keep the base
/// lowering so the MESH constructor (not the backend) rejects them.
fn runtime_for(mesh: &Mesh) -> Runtime {
    Runtime::native(NativeConfig::tiny().for_mesh(mesh)).unwrap()
}

fn batches_for(rt: &Runtime, dp: usize, micros: usize, seed: u64) -> Vec<Vec<Batch>> {
    let m = rt.manifest();
    let mut c = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed);
    (0..dp)
        .map(|_| (0..micros).map(|_| c.next_batch().unwrap()).collect())
        .collect()
}

/// Serial reference: the single-device engine looped over every
/// replica's microbatches; grads summed over micros, averaged over dp —
/// the mesh's documented semantics.
fn serial_reference(rt: &Runtime, params: &ParamStore, batches: &[Vec<Batch>]) -> (f32, ParamStore) {
    let serial = TensorParEngine::new(rt, Fabric::new(1, Meter::new())).unwrap();
    let dp = batches.len();
    let mut loss = 0.0f32;
    let mut grads = params.zeros_like();
    for replica in batches {
        for b in replica {
            let o = serial.forward_backward(params, b).unwrap();
            loss += o.loss;
            for (name, g) in &o.grads.values {
                ops::add_assign(grads.get_mut(name).unwrap(), g).unwrap();
            }
        }
    }
    for t in grads.values.values_mut() {
        ops::scale_assign(t, 1.0 / dp as f32).unwrap();
    }
    (loss / dp as f32, grads)
}

fn assert_grads_close(tag: &str, got: &ParamStore, want: &ParamStore, tol: f32) {
    for (name, g) in &want.values {
        let d = ops::max_abs_diff(&got.values[name], g).unwrap();
        assert!(d < tol, "{tag}: grad {name} diverged, Δ={d}");
    }
}

const MESHES: [(usize, usize, usize); 4] = [(1, 1, 4), (2, 1, 2), (1, 2, 2), (2, 2, 2)];

#[test]
fn mesh_matrix_matches_serial_engine() {
    for (dp, pp, mp) in MESHES {
        for kind in [MpKind::Sequence, MpKind::Tensor] {
            let mesh = Mesh::new(dp, pp, mp, kind).unwrap();
            let rt = runtime_for(&mesh);
            if kind == MpKind::Tensor && rt.manifest().heads % mp != 0 {
                // Megatron's cap: TP size must divide the head count
                // (bert-tiny has 2) — the paper's §4.2 limit, enforced
                let err = match MeshRunner::new(&rt, mesh, 1, Meter::new()) {
                    Ok(_) => panic!("{}: TP above the head cap must be rejected", mesh.label()),
                    Err(e) => e,
                };
                assert!(
                    err.to_string().contains("head count"),
                    "{}: unexpected rejection: {err}",
                    mesh.label()
                );
                continue;
            }
            let params = ParamStore::synthetic(rt.manifest());
            for micros in [1usize, 2, 4] {
                let tag = format!("{} micros={micros}", mesh.label());
                let batches = batches_for(&rt, dp, micros, 71);
                let (ref_loss, ref_grads) = serial_reference(&rt, &params, &batches);

                let seq_meter = Meter::new();
                let eng = MeshEngine::new(&rt, mesh, micros, seq_meter.clone()).unwrap();
                let a = eng.step(&params, &batches).unwrap();

                let thr_meter = Meter::new();
                let run = MeshRunner::new(&rt, mesh, micros, thr_meter.clone()).unwrap();
                let b = run.step(&params, &batches).unwrap();

                // losses: threaded == sequential == serial reference
                assert!(
                    (b.loss - ref_loss).abs() < TOL,
                    "{tag}: threaded loss {} vs serial {ref_loss}",
                    b.loss
                );
                assert!(
                    (a.loss - ref_loss).abs() < TOL,
                    "{tag}: sequential loss {} vs serial {ref_loss}",
                    a.loss
                );
                assert_eq!(a.replica_loss.len(), dp);

                // every gradient, against the serial reference and each other
                assert_grads_close(&format!("{tag} threaded vs serial"), &b.grads, &ref_grads, TOL);
                assert_grads_close(&format!("{tag} sequential vs serial"), &a.grads, &ref_grads, TOL);
                assert_grads_close(&format!("{tag} threaded vs sequential"), &b.grads, &a.grads, TOL);

                // byte-for-byte meter parity, per collective kind
                for ck in [
                    CommKind::RingP2p,
                    CommKind::AllReduce,
                    CommKind::AllGather,
                    CommKind::Broadcast,
                    CommKind::Scatter,
                    CommKind::Pipeline,
                ] {
                    assert_eq!(
                        seq_meter.get(ck),
                        thr_meter.get(ck),
                        "{tag}: {ck:?} bytes differ (sequential {} vs threaded {})",
                        seq_meter.get(ck),
                        thr_meter.get(ck)
                    );
                }
            }
        }
    }
}

/// At dp=pp=1 the mesh degenerates to pure sequence parallelism: same
/// loss and gradients as `SeqParEngine` (sequential) and `DistRunner`
/// (threaded), to float-exact tolerance.
#[test]
fn unit_mesh_is_pure_sequence_parallelism() {
    let mesh = Mesh::new(1, 1, 4, MpKind::Sequence).unwrap();
    let rt = runtime_for(&mesh);
    let params = ParamStore::synthetic(rt.manifest());
    let batches = batches_for(&rt, 1, 1, 13);

    let eng = MeshEngine::new(&rt, mesh, 1, Meter::new()).unwrap();
    let a = eng.step(&params, &batches).unwrap();
    let seq = SeqParEngine::new(&rt, Fabric::new(4, Meter::new())).unwrap();
    let want = seq.forward_backward(&params, &batches[0][0]).unwrap();
    assert!(
        (a.loss - want.loss).abs() <= 1e-6,
        "sequential mesh {} vs pure SP {}",
        a.loss,
        want.loss
    );
    assert_grads_close("sequential mesh vs pure SP", &a.grads, &want.grads, 1e-6);

    let run = MeshRunner::new(&rt, mesh, 1, Meter::new()).unwrap();
    let b = run.step(&params, &batches).unwrap();
    let dist = DistRunner::new(&rt, Meter::new()).unwrap();
    let wantd = dist.forward_backward(&params, &batches[0][0]).unwrap();
    assert!(
        (b.loss - wantd.loss).abs() <= 1e-6,
        "threaded mesh {} vs DistRunner {}",
        b.loss,
        wantd.loss
    );
    assert_grads_close("threaded mesh vs DistRunner", &b.grads, &wantd.grads, 1e-6);
}

/// Same seed, two threaded mesh runs ⇒ identical bits, regardless of OS
/// thread scheduling (the dataflow decides every float).
#[test]
fn threaded_mesh_is_deterministic() {
    let mesh = Mesh::new(2, 2, 2, MpKind::Sequence).unwrap();
    let rt = runtime_for(&mesh);
    let params = ParamStore::synthetic(rt.manifest());
    let batches = batches_for(&rt, 2, 2, 29);
    let run = MeshRunner::new(&rt, mesh, 2, Meter::new()).unwrap();
    let a = run.step(&params, &batches).unwrap();
    let b = run.step(&params, &batches).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss not bit-stable");
    for (name, g) in &a.grads.values {
        assert_eq!(g, &b.grads.values[name], "grad {name} not bit-stable");
    }
}

/// Memory parity on the 4D mesh: the sequential `MeshEngine` aims its
/// charges at global lanes with `obs::mem::set_lane_base`, the threaded
/// `MeshRunner` with per-thread lane adoption — both must record the
/// SAME per-(lane, category) high-water marks, for every mesh shape and
/// micro count, because the held-activation and stash lifetimes are
/// fixed by the GPipe schedule, not by the execution style.
#[test]
fn mesh_threaded_and_sequential_memory_peaks_agree() {
    for (dp, pp, mp) in MESHES {
        let mesh = Mesh::new(dp, pp, mp, MpKind::Sequence).unwrap();
        let rt = runtime_for(&mesh);
        let params = ParamStore::synthetic(rt.manifest());
        for micros in [1usize, 2] {
            let tag = format!("{} micros={micros}", mesh.label());
            let batches = batches_for(&rt, dp, micros, 61);

            let eng = MeshEngine::new(&rt, mesh, micros, Meter::new()).unwrap();
            let ses = seqpar::obs::mem::MemSession::start();
            eng.step(&params, &batches).unwrap();
            let a = ses.finish();

            let run = MeshRunner::new(&rt, mesh, micros, Meter::new()).unwrap();
            let ses = seqpar::obs::mem::MemSession::start();
            run.step(&params, &batches).unwrap();
            let b = ses.finish();

            assert_eq!(
                a.lanes.len(),
                mesh.world_size(),
                "{tag}: sequential run charged the wrong lane count"
            );
            assert_eq!(
                b.lanes.len(),
                mesh.world_size(),
                "{tag}: threaded run charged the wrong lane count"
            );
            for (la, lb) in a.lanes.iter().zip(&b.lanes) {
                assert_eq!(la.lane, lb.lane, "{tag}: lane sets differ");
                assert_eq!(
                    la.peak, lb.peak,
                    "{tag}: lane {} per-category peaks differ (sequential vs threaded)",
                    la.lane
                );
            }
        }
    }
}

/// Comm/compute overlap on the full 4D mesh: for every SP mesh shape,
/// the overlapped threaded `MeshRunner` computes bit-identical results
/// to its blocking self, matches the overlapped sequential `MeshEngine`,
/// and both meter byte-identical traffic — the ring primitive composes
/// with GPipe stage boundaries without moving a float or a byte.
#[test]
fn overlap_mesh_matches_blocking_and_sequential() {
    for (dp, pp, mp) in MESHES {
        let mesh = Mesh::new(dp, pp, mp, MpKind::Sequence).unwrap();
        let rt = runtime_for(&mesh);
        let params = ParamStore::synthetic(rt.manifest());
        let micros = 2;
        let tag = format!("{} micros={micros} overlap", mesh.label());
        let batches = batches_for(&rt, dp, micros, 71);

        let blocking = MeshRunner::new(&rt, mesh, micros, Meter::new()).unwrap();
        let want = blocking.step(&params, &batches).unwrap();

        let thr_meter = Meter::new();
        let run = MeshRunner::new(&rt, mesh, micros, thr_meter.clone())
            .unwrap()
            .overlap(true);
        let b = run.step(&params, &batches).unwrap();
        assert_eq!(b.loss.to_bits(), want.loss.to_bits(), "{tag}: overlap moved the loss bits");
        for (name, g) in &b.grads.values {
            assert_eq!(g, &want.grads.values[name], "{tag}: overlap moved grad {name}");
        }

        let seq_meter = Meter::new();
        let eng = MeshEngine::new(&rt, mesh, micros, seq_meter.clone())
            .unwrap()
            .overlap(true);
        let a = eng.step(&params, &batches).unwrap();
        assert!(
            (a.loss - b.loss).abs() < TOL,
            "{tag}: sequential loss {} vs threaded {}",
            a.loss,
            b.loss
        );
        assert_grads_close(&format!("{tag} sequential vs threaded"), &a.grads, &b.grads, TOL);

        for ck in [
            CommKind::RingP2p,
            CommKind::AllReduce,
            CommKind::AllGather,
            CommKind::Broadcast,
            CommKind::Scatter,
            CommKind::Pipeline,
        ] {
            assert_eq!(
                seq_meter.get(ck),
                thr_meter.get(ck),
                "{tag}: {ck:?} bytes differ with overlap on (sequential {} vs threaded {})",
                seq_meter.get(ck),
                thr_meter.get(ck)
            );
        }
    }
}

/// A mesh-coordinate panic mid-step must not hang the world: peers on
/// the ring, pipeline and dp axes see broken channels as contextful
/// disconnect errors and unwind; the runner joins every thread and
/// names the panicked mesh rank as the root cause.
#[test]
fn mesh_rank_panic_is_reported_not_hung() {
    let mesh = Mesh::new(2, 1, 2, MpKind::Sequence).unwrap();
    let rt = runtime_for(&mesh);
    let params = ParamStore::synthetic(rt.manifest());
    let batches = batches_for(&rt, 2, 1, 99);
    let mut run = MeshRunner::new(&rt, mesh, 1, Meter::new()).unwrap();
    run.inject_fault(1);
    let err = run
        .step(&params, &batches)
        .err()
        .expect("a dead mesh rank must fail the step, not hang it");
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1"), "error must name the dead rank: {msg}");
    assert!(msg.contains("panicked"), "error must say the rank panicked: {msg}");
}

/// Same contract under the Ulysses SP strategy, overlap on and off: a
/// rank dying with all-to-alls mid-flight inside its mp group must
/// surface as the contextful disconnect report, not a hang — the a2a
/// exchange partners block on recvs the dead rank will never serve.
#[test]
fn mesh_ulysses_rank_panic_is_reported_not_hung() {
    for overlap in [false, true] {
        let mesh = Mesh::new(2, 1, 2, MpKind::Sequence).unwrap();
        // bert-tiny has 2 heads: mp=2 divides them, so the backend lowers
        // the head-shard a2a kernels on the sequence axis
        let rt = Runtime::native(NativeConfig {
            ulysses: true,
            ..NativeConfig::tiny().for_mesh(&mesh)
        })
        .unwrap();
        let params = ParamStore::synthetic(rt.manifest());
        let batches = batches_for(&rt, 2, 1, 103);
        let mut run = MeshRunner::with_strategy(&rt, mesh, 1, Meter::new(), SpStrategy::Ulysses)
            .unwrap()
            .overlap(overlap);
        run.inject_fault(1);
        let err = run
            .step(&params, &batches)
            .err()
            .expect("a dead mesh rank must fail the ulysses step, not hang it");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "overlap={overlap}: must name the dead rank: {msg}");
        assert!(msg.contains("panicked"), "overlap={overlap}: must say it panicked: {msg}");
    }
}

/// The §3.2.2 stage-boundary claim, measured: at equal mesh shape, SP
/// boundaries move strictly fewer bytes than the TP baseline — SP sends
/// its already-split chunk (Pipeline only), TP pays scatter + all-gather
/// on top of the same sends.
#[test]
fn sp_stage_boundaries_beat_tp_baseline() {
    for (dp, pp, mp) in [(1usize, 2usize, 2usize), (2, 2, 2)] {
        let micros = 2;
        let boundary = |kind: MpKind| -> (u64, u64, u64) {
            let mesh = Mesh::new(dp, pp, mp, kind).unwrap();
            let rt = runtime_for(&mesh);
            let params = ParamStore::synthetic(rt.manifest());
            let batches = batches_for(&rt, dp, micros, 5);
            let meter = Meter::new();
            let run = MeshRunner::new(&rt, mesh, micros, meter.clone()).unwrap();
            run.step(&params, &batches).unwrap();
            (
                meter.get(CommKind::Pipeline),
                meter.get(CommKind::AllGather),
                meter.get(CommKind::Scatter),
            )
        };
        let (sp_send, sp_gather, sp_scatter) = boundary(MpKind::Sequence);
        let (tp_send, tp_gather, tp_scatter) = boundary(MpKind::Tensor);
        // identical send volume; SP skips the scatter and the gather
        assert_eq!(sp_send, tp_send, "{dp}x{pp}x{mp}: boundary send volumes");
        assert_eq!(sp_gather, 0, "{dp}x{pp}x{mp}: SP must not all-gather at boundaries");
        assert_eq!(sp_scatter, 0, "{dp}x{pp}x{mp}: SP must not scatter at boundaries");
        assert!(tp_gather > 0 && tp_scatter > 0, "{dp}x{pp}x{mp}: TP pays the gather");
        let sp_total = sp_send + sp_gather + sp_scatter;
        let tp_total = tp_send + tp_gather + tp_scatter;
        assert!(
            sp_total < tp_total,
            "{dp}x{pp}x{mp}: SP boundary bytes {sp_total} not below TP {tp_total}"
        );
    }
}

/// Checkpoint round-trip across mesh factorizations: train k steps on
/// mesh A (2×1×2), checkpoint, then take one step on mesh B (1×2×2 — a
/// different factorization of the same world size).  The step computed
/// from the restored checkpoint must be bitwise identical to the step
/// computed from the uninterrupted in-memory state.
#[test]
fn checkpoint_roundtrip_across_mesh_factorizations() {
    let mesh_a = Mesh::new(2, 1, 2, MpKind::Sequence).unwrap();
    let mesh_b = Mesh::new(1, 2, 2, MpKind::Sequence).unwrap();
    assert_eq!(mesh_a.world_size(), mesh_b.world_size());
    let rt = runtime_for(&mesh_a); // ring=2 serves both factorizations
    let m = rt.manifest().clone();
    let micros = 2;
    let runner_a = MeshRunner::new(&rt, mesh_a, micros, Meter::new()).unwrap();
    let runner_b = MeshRunner::new(&rt, mesh_b, micros, Meter::new()).unwrap();

    // deterministic batch stream, generated once
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 301);
    let mut step_batches = |dp: usize| -> Vec<Vec<Batch>> {
        (0..dp)
            .map(|_| (0..micros).map(|_| corpus.next_batch().unwrap()).collect())
            .collect()
    };

    // k = 2 steps on mesh A
    let mut params = ParamStore::synthetic(&m);
    let mut adam = Adam::new(&params, AdamConfig::default());
    for _ in 0..2 {
        let out = runner_a.step(&params, &step_batches(mesh_a.dp)).unwrap();
        adam.step(&mut params, &out.grads, 1e-3).unwrap();
    }

    // checkpoint at step k
    let dir = std::env::temp_dir().join("seqpar_mesh_ckpt_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let (am, av, at) = adam.state();
    // the corpus fed 2 steps × dp(2) × micros(2) = 8 batches so far
    checkpoint::save(
        &dir,
        &Checkpoint {
            step: at,
            params: params.clone(),
            adam_m: am.clone(),
            adam_v: av.clone(),
            data_cursor: 8,
        },
    )
    .unwrap();
    // one number certifies params + both Adam moments + the cursor —
    // taken now, before either continuation advances the live state
    let live_hash = train_state_hash(&params, &adam, 8);

    // step k+1 on mesh B — shared batch for both continuations
    let b_batches = step_batches(mesh_b.dp);

    // path 1: uninterrupted in-memory continuation
    let mut params_mem = params.clone();
    let out = runner_b.step(&params_mem, &b_batches).unwrap();
    adam.step(&mut params_mem, &out.grads, 1e-3).unwrap();

    // path 2: restore from disk, then the same step
    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.step, 2);
    assert_eq!(ck.data_cursor, 8, "data-loader cursor lost in the round-trip");
    let mut params_disk = ck.params;
    for (name, t) in &params.values {
        assert_eq!(t, &params_disk.values[name], "restored param {name} differs");
    }
    let mut adam_disk = Adam::from_state(AdamConfig::default(), ck.adam_m, ck.adam_v, ck.step);
    // the restored training state is the save-time state, to the bit
    assert_eq!(
        live_hash,
        train_state_hash(&params_disk, &adam_disk, ck.data_cursor),
        "restored state hash differs from the live state at save time"
    );
    let out = runner_b.step(&params_disk, &b_batches).unwrap();
    adam_disk.step(&mut params_disk, &out.grads, 1e-3).unwrap();

    for (name, t) in &params_mem.values {
        assert_eq!(
            t, &params_disk.values[name],
            "param {name} not bitwise identical after the cross-mesh resume"
        );
    }
    // and the full post-step state agrees as one hash (mesh B consumed
    // dp(1) × micros(2) more batches: cursor 10 on both continuations)
    assert_eq!(
        train_state_hash(&params_mem, &adam, 10),
        train_state_hash(&params_disk, &adam_disk, 10),
        "post-resume state hash diverged between the two continuations"
    );
}

/// Loss bookkeeping sanity: the replica losses the mesh reports sum to
/// the step loss (mean over dp), and `MeshOutput` is plumbed through the
/// trait object surface the trainer uses.
#[test]
fn mesh_step_trait_object_reports_consistent_losses() {
    let mesh = Mesh::new(2, 1, 2, MpKind::Sequence).unwrap();
    let rt = runtime_for(&mesh);
    let params = ParamStore::synthetic(rt.manifest());
    let batches = batches_for(&rt, 2, 1, 99);
    let runner = MeshRunner::new(&rt, mesh, 1, Meter::new()).unwrap();
    let obj: &dyn MeshStep = &runner;
    assert_eq!(obj.mesh().world_size(), 4);
    assert_eq!(obj.micros(), 1);
    let out: MeshOutput = obj.step(&params, &batches).unwrap();
    let mean: f32 = out.replica_loss.iter().sum::<f32>() / out.replica_loss.len() as f32;
    assert!(
        (out.loss - mean).abs() < 1e-5,
        "loss {} != mean of replica losses {mean}",
        out.loss
    );
    let _: Arc<Meter> = runner.meter.clone(); // meter stays shareable
}
