//! Integration: the paper's quantitative claims, checked against the
//! figure generators (artifact-free — pure simulator).
//!
//! We do not expect to match the P100 testbed's absolute numbers; these
//! tests pin the SHAPE of each result: who wins, roughly by how much,
//! where the caps and crossovers fall (DESIGN.md §2, §5).

use seqpar::eval::figures;
use seqpar::model::{BERT_BASE, BERT_LARGE};
use seqpar::simulator::{memory, search, Cluster, RunShape, Strategy};
use seqpar::util::prop::Prop;

fn cluster() -> Cluster {
    Cluster::default()
}

// --------------------------------------------------------------- Fig. 3a
#[test]
fn fig3a_sp64_vs_tp12_batch_ratio_near_13_7() {
    let rows = figures::fig3(&cluster(), BERT_BASE);
    let tp_best = rows.iter().filter_map(|r| r.tp_max_batch).max().unwrap();
    let sp64 = rows.iter().find(|r| r.n == 64).unwrap().sp_max_batch;
    let ratio = sp64 as f64 / tp_best as f64;
    // paper: 13.7x — accept the right order of magnitude
    assert!((6.0..30.0).contains(&ratio), "batch ratio {ratio} (paper 13.7x)");
}

#[test]
fn fig3a_tp_stops_at_12_sp_reaches_64() {
    let rows = figures::fig3(&cluster(), BERT_BASE);
    assert!(rows.iter().any(|r| r.n == 12 && r.tp_max_batch.is_some()));
    assert!(rows
        .iter()
        .filter(|r| r.n > 12)
        .all(|r| r.tp_max_batch.is_none()));
    assert!(rows.iter().any(|r| r.n == 64 && r.sp_max_batch > 0));
}

// --------------------------------------------------------------- Fig. 3b
#[test]
fn fig3b_throughput_comparable_at_same_size() {
    let rows = figures::fig3(&cluster(), BERT_BASE);
    for r in rows.iter().filter(|r| r.tp_tokens_per_sec.is_some() && r.sp_max_batch > 0) {
        let ratio = r.sp_tokens_per_sec / r.tp_tokens_per_sec.unwrap();
        assert!(
            (0.5..2.0).contains(&ratio),
            "n={}: SP/TP throughput ratio {ratio}",
            r.n
        );
    }
}

// --------------------------------------------------------------- Fig. 4
#[test]
fn fig4_sp_wins_batch_and_throughput_across_pipeline_depths() {
    for model in [BERT_BASE, BERT_LARGE] {
        for r in figures::fig4(&cluster(), model) {
            assert!(
                r.sp_max_batch >= r.tp_max_batch.unwrap(),
                "{}: stage {} batch", model.name, r.n
            );
            assert!(
                r.sp_tokens_per_sec >= 0.95 * r.tp_tokens_per_sec.unwrap(),
                "{}: stage {} throughput", model.name, r.n
            );
        }
    }
}

// --------------------------------------------------------------- Fig. 5a
#[test]
fn fig5a_length_ratio_and_equal_16_gpu_point() {
    let rows = figures::fig5a(&cluster(), BERT_BASE, 64);
    let tp_best = rows.iter().filter_map(|r| r.tp_max_len).max().unwrap();
    let sp64 = rows.iter().find(|r| r.n == 64).unwrap().sp_max_len;
    let ratio = sp64 as f64 / tp_best as f64;
    assert!((2.0..12.0).contains(&ratio), "length ratio {ratio} (paper ~3x)");
    // paper: at the same 16 GPUs SP reaches 1.4x TP's length.  TP can't
    // use 16 on BERT-Base, so compare at the shared feasible size 12 vs
    // SP@16 — SP must be ahead.
    let sp16 = rows.iter().find(|r| r.n == 16).unwrap().sp_max_len;
    assert!(sp16 as f64 >= 1.2 * tp_best as f64, "SP@16 {sp16} vs TP@12 {tp_best}");
}

// --------------------------------------------------------------- Fig. 9
#[test]
fn fig9_bert_large_length_ratio_near_2x() {
    let rows = figures::fig5a(&cluster(), BERT_LARGE, 16);
    let tp_best = rows.iter().filter_map(|r| r.tp_max_len).max().unwrap();
    let sp64 = rows.iter().find(|r| r.n == 64).unwrap().sp_max_len;
    let ratio = sp64 as f64 / tp_best as f64;
    assert!((1.5..8.0).contains(&ratio), "Large length ratio {ratio} (paper ~2x)");
}

// --------------------------------------------------------------- Fig. 5b
#[test]
fn fig5b_sparse_reaches_100k_plus_at_32_devices() {
    let rows = figures::fig5b(&cluster(), BERT_BASE);
    let at32 = rows.iter().find(|r| r.n == 32).unwrap();
    assert!(
        at32.sparse_max_len >= 100_000,
        "sparse@32 = {} (paper: >114K)",
        at32.sparse_max_len
    );
    // near-ideal scaling: doubling devices ~doubles the bound (>=1.8x)
    for w in rows.windows(2) {
        let r = w[1].sparse_max_len as f64 / w[0].sparse_max_len as f64;
        assert!(r > 1.7, "sparse scaling step {:?} -> {:?} only {r}", w[0].n, w[1].n);
    }
}

#[test]
fn fig5b_27x_beyond_single_device_sparse() {
    let rows = figures::fig5b(&cluster(), BERT_BASE);
    let single = rows.iter().find(|r| r.n == 1).unwrap().sparse_max_len;
    let at32 = rows.iter().find(|r| r.n == 32).unwrap().sparse_max_len;
    assert!(
        at32 as f64 / single as f64 > 16.0,
        "sparse@32 {at32} vs single-device {single} (paper: 27x)"
    );
}

// --------------------------------------------------------------- Table 4
#[test]
fn table4_sp_constant_memory_tp_ooms() {
    let rows = figures::table4(&cluster(), BERT_BASE);
    let batch_sweep: Vec<_> = rows.iter().filter(|r| r.seq_len == 512 && r.batch >= 64).collect();
    // SP memory flat (paper: 8477 -> 8490 MB)
    let first = batch_sweep.first().unwrap().sp_mem_mb;
    for r in &batch_sweep {
        assert!(r.sp_mem_mb / first < 1.1, "SP memory should stay flat");
    }
    // TP eventually OOMs in the batch sweep (paper: at n=8)
    assert!(batch_sweep.iter().any(|r| r.tp_mem_mb.is_none()), "TP should OOM");
    // length sweep: SP uses less memory than TP wherever both fit
    for r in rows.iter().filter(|r| r.batch == 64 && r.seq_len > 256) {
        if let Some(tp) = r.tp_mem_mb {
            assert!(r.sp_mem_mb <= tp, "L={}: SP {} vs TP {tp}", r.seq_len, r.sp_mem_mb);
        }
    }
}

// ------------------------------------------------------------ Tables 1/2
#[test]
fn breakeven_properties_hold_across_shapes() {
    Prop::new(64, 33).check("table 1/2 break-evens", |rng| {
        let h = 64 * (1 + rng.below(16));
        let z = 1 + rng.below(16);
        let a = 64u64;
        // n >= 2: at N=1 both Table-1 forms reduce to 32H² + 5BLH (equal).
        let n = 2 + rng.below(15);
        let bl_small = rng.below(32 * h) + 1;
        let bl_big = 32 * h + 16 * a * z + rng.below(1 << 20) + 1;
        // Eq. 5 direction: big BL -> SP wins the MLP block
        if memory::paper_mlp_sequence(1, bl_big, h, n) >= memory::paper_mlp_tensor(1, bl_big, h, n)
        {
            return Err(format!("SP should win MLP at BL={bl_big} H={h} N={n}"));
        }
        // small BL and N>1 -> TP wins
        if n > 1
            && bl_small < 16 * h
            && memory::paper_mlp_sequence(1, bl_small, h, n)
                <= memory::paper_mlp_tensor(1, bl_small, h, n)
        {
            return Err(format!("TP should win MLP at BL={bl_small} H={h} N={n}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------- §4.2 cap claim
#[test]
fn megatron_cap_is_heads_seqpar_cap_is_length() {
    // "tensor parallelism size is a maximum of 12 for BERT Base" and
    // "only the sequence length is required to be divisible" (§4.2).
    assert!(!Strategy::Tensor { n: 24 }.feasible(&BERT_BASE, 512));
    assert!(Strategy::Tensor { n: 12 }.feasible(&BERT_BASE, 512));
    assert!(Strategy::Sequence { n: 64 }.feasible(&BERT_BASE, 512));
    assert!(!Strategy::Sequence { n: 3 }.feasible(&BERT_BASE, 512));
    assert!(Strategy::Tensor { n: 16 }.feasible(&BERT_LARGE, 512));
    assert!(!Strategy::Tensor { n: 32 }.feasible(&BERT_LARGE, 512));
}

// ----------------------------------------------------- search invariants
#[test]
fn oom_search_monotone_in_memory_budget() {
    Prop::new(24, 77).check("bigger GPU -> bigger batch", |rng| {
        let n = 1usize << rng.below(5);
        let mut small = cluster();
        small.gpu_mem = 8 * (1 << 30);
        let mut big = cluster();
        big.gpu_mem = 32 * (1 << 30);
        let strat = Strategy::Sequence { n };
        let bs = search::max_batch(&small, BERT_BASE, 512, 1, 1, strat);
        let bb = search::max_batch(&big, BERT_BASE, 512, 1, 1, strat);
        if bb >= bs {
            Ok(())
        } else {
            Err(format!("n={n}: 32GB batch {bb} < 8GB batch {bs}"))
        }
    });
}

#[test]
fn fig5a_gap_widens_with_32gb_gpus() {
    // paper §4.3: "the gap is expected to widen if we use 32GB GPUs"
    let c16 = cluster();
    let mut c32 = cluster();
    c32.gpu_mem = 32 * (1 << 30);
    let gap = |c: &Cluster| {
        let sp = search::max_seq_len(c, BERT_BASE, 64, 1, 1, Strategy::Sequence { n: 16 }, 64);
        let tp = search::max_seq_len(c, BERT_BASE, 64, 1, 1, Strategy::Tensor { n: 4 }, 64);
        sp as i64 - tp as i64
    };
    assert!(gap(&c32) > gap(&c16), "absolute length gap should widen at 32GB");
}

#[test]
fn ledger_vs_paper_quadratic_share() {
    // The score-matrix share of activation memory grows with L — the
    // motivation of the whole paper.  Check the ledger reproduces it.
    let short = RunShape::new(BERT_BASE, 8, 256);
    let long = RunShape::new(BERT_BASE, 8, 4096);
    let f = |s: &RunShape| {
        let total = memory::layer_stash_elems(s, Strategy::Sequence { n: 8 }) as f64;
        let quad = (8 * 12 * (s.seq_len / 8) * s.seq_len) as f64;
        quad / total
    };
    assert!(f(&long) > 2.0 * f(&short), "quadratic share must dominate at long L");
}
