//! Integration: measured communication volume vs the paper's §3.2.2
//! closed forms.
//!
//! The paper claims sequence parallelism's total attention communication
//! equals Megatron's: 8(N-1)·B·Z·(L/N)·A elements per layer.  Our engines
//! meter every byte through the fabric; this test derives the closed form
//! for OUR schedule and asserts the meters match it exactly, then checks
//! the paper-form equivalence.  Runs on the native backend — no artifacts
//! needed.

use seqpar::attn::{block::BlockPlan, AttnPattern};
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};

fn runtime() -> Runtime {
    Runtime::native(NativeConfig::tiny()).unwrap()
}

#[test]
fn ring_traffic_matches_closed_form() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 1);
    let batch = corpus.next_batch().unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
    engine.forward_backward(&params, &batch).unwrap();

    let n = m.ring as u64;
    let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
    // OUR schedule per layer (all devices combined, bytes):
    //   forward:  (n-1) k-shifts + (n-1) v-shifts           = 2(n-1) · n·chunk
    //   backward: (n-1) v-shifts + n dv-shifts
    //           + (n-1) k-shifts + n dk-shifts              = (4n-2) · n·chunk
    //   (only the gradient ACCUMULATORS take the final delivery shift —
    //    re-rotating the data chunks home would be pure waste)
    let per_layer = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes;
    let expect = per_layer * m.layers as u64;
    assert_eq!(
        meter.get(CommKind::RingP2p),
        expect,
        "ring bytes diverged from the schedule's closed form"
    );

    // Paper §3.2.2 equivalence: per-DEVICE attention traffic is
    // 8(N-1)·chunk for both SP and Megatron.  Our schedule's per-device
    // volume is 2(n-1) + (4n-2) = 6n-4 chunk-sends ≈ the paper's 8(n-1)
    // within a constant factor (the paper counts softmax-grad all-reduces
    // that we realize as the same accumulator rides) — check the ratio.
    let ours_per_device = (2 * (n - 1) + (4 * n - 2)) * chunk_bytes;
    let paper_per_device = 8 * (n - 1) * chunk_bytes;
    let ratio = ours_per_device as f64 / paper_per_device as f64;
    assert!(
        (0.5..=1.5).contains(&ratio),
        "schedule volume {ours_per_device} vs paper form {paper_per_device} (ratio {ratio})"
    );
}

/// Comm/compute overlap must not change a single metered byte: the
/// double-buffered schedule posts the same shifts the blocking schedule
/// issues (one per hop, metered at completion), so the pinned closed
/// form above holds verbatim with `--overlap` on.
#[test]
fn overlap_ring_traffic_matches_same_closed_form() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 1)
        .next_batch()
        .unwrap();

    let blocking = Meter::new();
    SeqParEngine::new(&rt, Fabric::new(m.ring, blocking.clone()))
        .unwrap()
        .forward_backward(&params, &batch)
        .unwrap();

    let overlapped = Meter::new();
    SeqParEngine::new(&rt, Fabric::new(m.ring, overlapped.clone()))
        .unwrap()
        .overlap(true)
        .forward_backward(&params, &batch)
        .unwrap();

    let n = m.ring as u64;
    let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
    let expect = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes * m.layers as u64;
    assert_eq!(
        overlapped.get(CommKind::RingP2p),
        expect,
        "overlap changed the ring closed form"
    );
    assert!(
        overlapped.snapshot().same_bytes(&blocking.snapshot()),
        "overlap changed a metered byte count somewhere"
    );
}

/// Blockwise-sparse attention: the measured ring volume matches the
/// skip-aware closed form `4·Σh(src) + 2·Σ(consumers(src)−1)` chunk-sends
/// per layer and is STRICTLY below dense RSA's `(2(n−1) + (4n−2))·n` —
/// the §4.3 claim that masking removes communication, made measurable.
#[test]
fn blockwise_ring_traffic_matches_skip_aware_closed_form() {
    let cfg = NativeConfig { block_w: 8, ..NativeConfig::tiny() }; // n=4, L=32, Lc=8
    let rt = Runtime::native(cfg).unwrap();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 4)
        .next_batch()
        .unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::with_pattern(
        &rt,
        Fabric::new(m.ring, meter.clone()),
        AttnPattern::Block { w: 8 },
    )
    .unwrap();
    engine.forward_backward(&params, &batch).unwrap();

    let n = m.ring as u64;
    let lc = m.seq_len / m.ring;
    let chunk_bytes = (m.batch * m.heads * lc * m.head_dim * 4) as u64;
    let plan = BlockPlan::new(m.ring, lc, 8);
    // W=8 over Lc=8 chunks reaches only the diagonal + first subdiagonal:
    // hops = [1,1,1,0] (H=3), consumer counts [2,2,2,1] → 4·3 + 2·3 = 18
    assert_eq!(plan.chunk_sends_per_layer(), 18);
    let expect = plan.chunk_sends_per_layer() * chunk_bytes * m.layers as u64;
    assert_eq!(
        meter.get(CommKind::RingP2p),
        expect,
        "blockwise ring bytes diverged from the skip-aware closed form"
    );

    // strictly below the dense schedule's volume at the same shape
    let dense = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes * m.layers as u64;
    assert!(
        expect < dense,
        "skip-aware volume {expect} not below dense closed form {dense}"
    );
}

/// Linformer: NO ring traffic at all — the attention communication is
/// 4 all-reduces of the projected [B, Z, k, A] per layer (2 forward for
/// K̃/Ṽ, 2 backward for their grads), independent of L, on top of the
/// usual parameter-gradient all-reduce (Table 3's communication regime).
#[test]
fn linformer_traffic_is_allreduce_only_and_l_independent() {
    let cfg = NativeConfig { linformer_k: 8, ..NativeConfig::tiny() };
    let rt = Runtime::native(cfg).unwrap();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 5)
        .next_batch()
        .unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::with_pattern(
        &rt,
        Fabric::new(m.ring, meter.clone()),
        AttnPattern::Linformer { k: 8 },
    )
    .unwrap();
    let out = engine.forward_backward(&params, &batch).unwrap();

    assert_eq!(meter.get(CommKind::RingP2p), 0, "linformer must not ring-rotate K/V");
    let n = m.ring as u64;
    let proj_bytes = (m.batch * m.heads * m.linformer_k * m.head_dim * 4) as u64;
    let param_bytes: u64 = out.grads.values.values().map(|t| t.bytes() as u64).sum();
    // 4 all-reduces of the projected tensors per layer + the grad reduce,
    // each metered on the canonical 2(n-1)·C group total
    let expect = 2 * (n - 1) * (4 * proj_bytes * m.layers as u64 + param_bytes);
    assert_eq!(meter.get(CommKind::AllReduce), expect, "linformer all-reduce accounting");
}

/// Ulysses all-to-all SP: NO ring traffic; the attention communication is
/// exactly 8 all-to-alls of the local `[B, Z, Lc, A]` chunk per layer
/// (q/k/v/ctx forward, their gradients backward), each metered on the
/// `(n-1)·C` group total — `8(n−1)` chunk-sends per layer in total, flat
/// in the per-hop ring length and strictly below the dense ring schedule.
#[test]
fn ulysses_traffic_matches_closed_form() {
    // bert-tiny has 2 heads; Ulysses at ring 4 needs 4 | Z, so use the
    // 4-head variant at the same hidden size
    let cfg = NativeConfig { model: BERT_TINY_Z4, ulysses: true, ..NativeConfig::tiny() }; // ring = 4
    let rt = Runtime::native(cfg).unwrap();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let batch = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 6)
        .next_batch()
        .unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::with_strategy(
        &rt,
        Fabric::new(m.ring, meter.clone()),
        AttnPattern::Dense,
        SpStrategy::Ulysses,
    )
    .unwrap();
    let out = engine.forward_backward(&params, &batch).unwrap();

    assert_eq!(meter.get(CommKind::RingP2p), 0, "ulysses must not ring-rotate K/V");
    let n = m.ring as u64;
    let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
    let expect = 8 * (n - 1) * chunk_bytes * m.layers as u64;
    assert_eq!(
        meter.get(CommKind::AllToAll),
        expect,
        "ulysses all-to-all bytes diverged from the 8(n-1)-chunk closed form"
    );
    // strictly below the dense ring schedule at the same shape
    let dense = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes * m.layers as u64;
    assert!(
        expect < dense,
        "ulysses volume {expect} not below the dense ring closed form {dense}"
    );
    // the parameter-gradient all-reduce is unchanged by the strategy
    let param_bytes: u64 = out.grads.values.values().map(|t| t.bytes() as u64).sum();
    assert_eq!(meter.get(CommKind::AllReduce), 2 * (n - 1) * param_bytes);
}

#[test]
fn gradient_allreduce_metered() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 2);
    let batch = corpus.next_batch().unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
    let out = engine.forward_backward(&params, &batch).unwrap();

    // ring all-reduce of every parameter-grad tensor, group-total
    // accounting (Fabric convention: 2(n-1)·C bytes sent across the group
    // per tensor — summing over tensors gives 2(n-1) · param_bytes).  The
    // threaded RingComm meters the identical totals, which is what makes
    // sequential and threaded runs comparable byte-for-byte.
    let n = m.ring as u64;
    let param_bytes: u64 = out.grads.values.values().map(|t| t.bytes() as u64).sum();
    assert_eq!(
        meter.get(CommKind::AllReduce),
        2 * (n - 1) * param_bytes,
        "gradient all-reduce accounting"
    );
}

#[test]
fn serial_moves_zero_bytes() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3);
    let batch = corpus.next_batch().unwrap();
    let meter = Meter::new();
    let engine =
        seqpar::parallel::tensorp::TensorParEngine::new(&rt, Fabric::new(1, meter.clone()))
            .unwrap();
    engine.forward_backward(&params, &batch).unwrap();
    assert_eq!(meter.snapshot().total(), 0, "serial engine must not communicate");
}

/// Artifact-backed variant of the closed-form check (PJRT backend).
#[cfg(feature = "backend-xla")]
mod xla_artifacts {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn ring_traffic_matches_closed_form_on_artifacts() {
        let dir = PathBuf::from("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(&dir).unwrap();
        let m = rt.manifest().clone();
        let params = ParamStore::load(&dir, &m).unwrap();
        let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 1);
        let batch = corpus.next_batch().unwrap();
        let meter = Meter::new();
        let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
        engine.forward_backward(&params, &batch).unwrap();
        let n = m.ring as u64;
        let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
        let per_layer = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes;
        assert_eq!(meter.get(CommKind::RingP2p), per_layer * m.layers as u64);
    }
}
