//! Integration: measured communication volume vs the paper's §3.2.2
//! closed forms.
//!
//! The paper claims sequence parallelism's total attention communication
//! equals Megatron's: 8(N-1)·B·Z·(L/N)·A elements per layer.  Our engines
//! meter every byte through the fabric; this test derives the closed form
//! for OUR schedule and asserts the meters match it exactly, then checks
//! the paper-form equivalence.  Runs on the native backend — no artifacts
//! needed.

use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::parallel::sequence::SeqParEngine;
use seqpar::parallel::Engine;
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};

fn runtime() -> Runtime {
    Runtime::native(NativeConfig::tiny()).unwrap()
}

#[test]
fn ring_traffic_matches_closed_form() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 1);
    let batch = corpus.next_batch().unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
    engine.forward_backward(&params, &batch).unwrap();

    let n = m.ring as u64;
    let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
    // OUR schedule per layer (all devices combined, bytes):
    //   forward:  (n-1) k-shifts + (n-1) v-shifts           = 2(n-1) · n·chunk
    //   backward: (n-1) v-shifts + n dv-shifts
    //           + (n-1) k-shifts + n dk-shifts              = (4n-2) · n·chunk
    //   (only the gradient ACCUMULATORS take the final delivery shift —
    //    re-rotating the data chunks home would be pure waste)
    let per_layer = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes;
    let expect = per_layer * m.layers as u64;
    assert_eq!(
        meter.get(CommKind::RingP2p),
        expect,
        "ring bytes diverged from the schedule's closed form"
    );

    // Paper §3.2.2 equivalence: per-DEVICE attention traffic is
    // 8(N-1)·chunk for both SP and Megatron.  Our schedule's per-device
    // volume is 2(n-1) + (4n-2) = 6n-4 chunk-sends ≈ the paper's 8(n-1)
    // within a constant factor (the paper counts softmax-grad all-reduces
    // that we realize as the same accumulator rides) — check the ratio.
    let ours_per_device = (2 * (n - 1) + (4 * n - 2)) * chunk_bytes;
    let paper_per_device = 8 * (n - 1) * chunk_bytes;
    let ratio = ours_per_device as f64 / paper_per_device as f64;
    assert!(
        (0.5..=1.5).contains(&ratio),
        "schedule volume {ours_per_device} vs paper form {paper_per_device} (ratio {ratio})"
    );
}

#[test]
fn gradient_allreduce_metered() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 2);
    let batch = corpus.next_batch().unwrap();

    let meter = Meter::new();
    let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
    let out = engine.forward_backward(&params, &batch).unwrap();

    // ring all-reduce of every parameter-grad tensor, group-total
    // accounting (Fabric convention: 2(n-1)·C bytes sent across the group
    // per tensor — summing over tensors gives 2(n-1) · param_bytes).  The
    // threaded RingComm meters the identical totals, which is what makes
    // sequential and threaded runs comparable byte-for-byte.
    let n = m.ring as u64;
    let param_bytes: u64 = out.grads.values.values().map(|t| t.bytes() as u64).sum();
    assert_eq!(
        meter.get(CommKind::AllReduce),
        2 * (n - 1) * param_bytes,
        "gradient all-reduce accounting"
    );
}

#[test]
fn serial_moves_zero_bytes() {
    let rt = runtime();
    let m = rt.manifest().clone();
    let params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 3);
    let batch = corpus.next_batch().unwrap();
    let meter = Meter::new();
    let engine =
        seqpar::parallel::tensorp::TensorParEngine::new(&rt, Fabric::new(1, meter.clone()))
            .unwrap();
    engine.forward_backward(&params, &batch).unwrap();
    assert_eq!(meter.snapshot().total(), 0, "serial engine must not communicate");
}

/// Artifact-backed variant of the closed-form check (PJRT backend).
#[cfg(feature = "backend-xla")]
mod xla_artifacts {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn ring_traffic_matches_closed_form_on_artifacts() {
        let dir = PathBuf::from("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(&dir).unwrap();
        let m = rt.manifest().clone();
        let params = ParamStore::load(&dir, &m).unwrap();
        let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 1);
        let batch = corpus.next_batch().unwrap();
        let meter = Meter::new();
        let engine = SeqParEngine::new(&rt, Fabric::new(m.ring, meter.clone())).unwrap();
        engine.forward_backward(&params, &batch).unwrap();
        let n = m.ring as u64;
        let chunk_bytes = (m.batch * m.heads * (m.seq_len / m.ring) * m.head_dim * 4) as u64;
        let per_layer = (2 * (n - 1) + (4 * n - 2)) * n * chunk_bytes;
        assert_eq!(meter.get(CommKind::RingP2p), per_layer * m.layers as u64);
    }
}
