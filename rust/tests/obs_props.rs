//! Integration properties of the runtime-observability layer (`obs::`).
//!
//! Two pinned invariants from the design:
//!
//! * trace ↔ meter: for every SP strategy × attention pattern × ring
//!   size, on BOTH fabrics, the recorded comm events agree with the
//!   `Meter` exactly — per-kind event count == op count, per-kind traced
//!   bytes == metered bytes (`obs::cross_check`);
//! * measured bubble: the GPipe bubble fraction computed from recorded
//!   cell timings on the threaded mesh converges on the closed form
//!   `(s−1)/(m+s−1)` pinned by `parallel::pipeline::Schedule`.
//!
//! Plus hygiene: engine traces export schema-valid Chrome JSON, and
//! spans opened outside a recording session leave nothing behind.

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::exec::{DistRunner, MeshRunner, MeshStep};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::obs;
use seqpar::parallel::pipeline::Schedule;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::parallel::{Batch, Engine};
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::json;

fn batch_for(rt: &Runtime, seed: u64) -> Batch {
    let m = rt.manifest();
    Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed)
        .next_batch()
        .unwrap()
}

/// Every runtime meter site emits its comm event through
/// `Meter::add_traced`, so the trace and the meter cannot drift — pin it
/// across the full strategy × pattern × ring-size × fabric matrix.
#[test]
fn trace_matches_meter_across_strategies_and_patterns() {
    let cases = [
        (SpStrategy::Ring, AttnPattern::Dense),
        (SpStrategy::Ring, AttnPattern::Linformer { k: 8 }),
        (SpStrategy::Ring, AttnPattern::Block { w: 8 }),
        (SpStrategy::Ulysses, AttnPattern::Dense),
    ];
    for (sp, pattern) in cases {
        let (linformer_k, block_w) = pattern.native_knobs();
        for n in [2usize, 4] {
            let tag = format!("sp={} attn={} n={n}", sp.label(), pattern.label());
            // ulysses shards whole heads: use the 4-head tiny model so
            // n=4 divides (same configs as dist_equivalence.rs)
            let rt = if sp.is_ring() {
                Runtime::native(NativeConfig {
                    ring: n,
                    linformer_k,
                    block_w,
                    ..NativeConfig::tiny()
                })
            } else {
                Runtime::native(NativeConfig {
                    model: BERT_TINY_Z4,
                    ring: n,
                    ulysses: true,
                    ..NativeConfig::tiny()
                })
            }
            .unwrap();
            let params = ParamStore::synthetic(rt.manifest());
            let batch = batch_for(&rt, 59);

            // sequential fabric: one group-total event per collective
            let meter = Meter::new();
            let eng =
                SeqParEngine::with_strategy(&rt, Fabric::new(n, meter.clone()), pattern, sp)
                    .unwrap();
            let rec = obs::Recorder::start();
            eng.forward_backward(&params, &batch).unwrap();
            let events = rec.finish();
            let rows = obs::cross_check(&events, &meter)
                .unwrap_or_else(|e| panic!("{tag} sequential: {e:#}"));
            assert!(
                rows.iter().any(|r| r.trace_events > 0),
                "{tag} sequential: no comm events traced"
            );

            // threaded fabric: per-message ring events, formula
            // collectives metered once at rank 0 / the root
            let meter = Meter::new();
            let dist = DistRunner::with_strategy(&rt, meter.clone(), pattern, sp).unwrap();
            let rec = obs::Recorder::start();
            dist.forward_backward(&params, &batch).unwrap();
            let events = rec.finish();
            let rows = obs::cross_check(&events, &meter)
                .unwrap_or_else(|e| panic!("{tag} threaded: {e:#}"));
            assert!(
                rows.iter().any(|r| r.trace_events > 0),
                "{tag} threaded: no comm events traced"
            );
        }
    }
}

/// The bubble measured from recorded cell spans on the threaded mesh
/// (busy = dur − recv-wait, per stage, over the cell window) lands on
/// the analytical GPipe fraction `(s−1)/(m+s−1)` — generously toleranced
/// because bert-tiny cells run in microseconds on a shared CI box.
#[test]
fn measured_bubble_matches_gpipe_closed_form() {
    let (pp, micros) = (2usize, 4usize);
    let mesh = Mesh::new(1, pp, 2, MpKind::Sequence).unwrap();
    let rt = Runtime::native(NativeConfig::tiny().for_mesh(&mesh)).unwrap();
    let params = ParamStore::synthetic(rt.manifest());
    let m = rt.manifest();
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 83);
    let batches: Vec<Vec<Batch>> = (0..mesh.dp)
        .map(|_| (0..micros).map(|_| corpus.next_batch().unwrap()).collect())
        .collect();

    let meter = Meter::new();
    let runner = MeshRunner::new(&rt, mesh, micros, meter.clone()).unwrap();
    let rec = obs::Recorder::start();
    runner.step(&params, &batches).unwrap();
    let events = rec.finish();

    // the mesh path holds the same trace↔meter invariant
    obs::cross_check(&events, &meter).unwrap();

    let measured = obs::bubble_fraction(&events)
        .expect("threaded mesh step must record cell events");
    let want = Schedule::gpipe(pp, micros).bubble_fraction();
    assert!(
        (measured - want).abs() < 0.2,
        "measured bubble {measured:.4} vs closed form {want:.4} (pp={pp} micros={micros})"
    );

    // the report surfaces the same number
    let report = obs::MetricsReport::build(&events, 1, 0, 5);
    assert_eq!(report.bubble, obs::bubble_fraction(&events));
}

/// A real engine trace round-trips through the Chrome-trace encoder and
/// the hand-rolled JSON parser, and passes the schema validator with one
/// pid per rank.
#[test]
fn engine_trace_exports_valid_chrome_json() {
    let n = 2;
    let rt = Runtime::native(NativeConfig { ring: n, ..NativeConfig::tiny() }).unwrap();
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 7);

    let dist = DistRunner::new(&rt, Meter::new()).unwrap();
    let rec = obs::Recorder::start();
    dist.forward_backward(&params, &batch).unwrap();
    let events = rec.finish();
    assert!(!events.is_empty());

    let doc = json::parse(&json::encode(&obs::chrome_trace(&events))).unwrap();
    let check = obs::validate_chrome_trace(&doc).unwrap();
    assert_eq!(check.complete, events.len(), "one X record per recorded event");
    assert_eq!(check.pids, (0..n).collect::<Vec<_>>(), "one pid per rank");
    assert_eq!(check.meta, n, "one process_name record per rank");
    assert!(check.cats.contains_key("kernel"), "cats: {:?}", check.cats);
    assert!(check.cats.contains_key("comm"), "cats: {:?}", check.cats);
    assert!(check.cats.contains_key("phase"), "cats: {:?}", check.cats);
}

/// Degenerate report inputs must degrade gracefully, not panic — the
/// `trace` subcommand reaches every one of these through its flags:
/// `--top-k 0`, a top-k larger than the kernel table, and `--validate`
/// against a trace with no events or no steps.
#[test]
fn metrics_report_handles_degenerate_inputs() {
    let n = 2;
    let rt = Runtime::native(NativeConfig { ring: n, ..NativeConfig::tiny() }).unwrap();
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 7);
    let dist = DistRunner::new(&rt, Meter::new()).unwrap();
    let rec = obs::Recorder::start();
    dist.forward_backward(&params, &batch).unwrap();
    let events = rec.finish();

    // --top-k 0: the kernel table empties, the totals survive
    let r0 = obs::MetricsReport::build(&events, 1, 64, 0);
    assert!(r0.kernels.is_empty(), "top-k 0 must truncate the whole table");
    assert!(r0.kernel_ns > 0, "kernel totals must not depend on top-k");
    let _ = format!("{r0}"); // Display renders without a kernel table
    assert!(r0.to_json().req("kernels_top").is_ok());

    // top-k far beyond the kernel count: everything, no padding, no panic
    let rbig = obs::MetricsReport::build(&events, 1, 64, 100_000);
    assert!(!rbig.kernels.is_empty());
    assert!(rbig.kernels.len() < 100_000);
    assert_eq!(rbig.kernel_ns, r0.kernel_ns);

    // an event-free trace: zeros and Nones, never NaN or panic
    let empty = obs::MetricsReport::build(&[], 0, 0, 10);
    assert_eq!(empty.wall_ns, 0);
    assert_eq!(empty.tokens_per_sec, 0.0);
    assert!(empty.bubble.is_none());
    assert!(empty.overlap_efficiency().is_none());
    let _ = format!("{empty}");
    let doc = empty.to_json();
    assert!(doc.req("overlap_efficiency").is_ok());

    // --validate on a zero-event Chrome trace: schema-valid, zero counts
    let doc = json::parse(&json::encode(&obs::chrome_trace(&[]))).unwrap();
    let chk = obs::validate_chrome_trace(&doc).unwrap();
    assert_eq!(chk.complete, 0);
    assert!(chk.pids.is_empty());
}

/// Overlap efficiency is wired end to end: a traced run aggregates
/// hidden-vs-wait comm time into `MetricsReport::overlap_efficiency`.
/// On the sequential fabric every collective resolves eagerly (no
/// channel waits), so the whole comm span time counts as hidden and the
/// metric pins to exactly 1.0; a threaded run reports some fraction in
/// [0, 1].
#[test]
fn overlap_efficiency_is_reported() {
    let n = 4;
    let rt = Runtime::native(NativeConfig { ring: n, ..NativeConfig::tiny() }).unwrap();
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 31);

    let eng = SeqParEngine::new(&rt, Fabric::new(n, Meter::new()))
        .unwrap()
        .overlap(true);
    let rec = obs::Recorder::start();
    eng.forward_backward(&params, &batch).unwrap();
    let report = obs::MetricsReport::build(&rec.finish(), 1, 64, 5);
    assert_eq!(
        report.overlap_efficiency(),
        Some(1.0),
        "the eager fabric never blocks on a channel"
    );

    let dist = DistRunner::new(&rt, Meter::new()).unwrap().overlap(true);
    let rec = obs::Recorder::start();
    dist.forward_backward(&params, &batch).unwrap();
    let report = obs::MetricsReport::build(&rec.finish(), 1, 64, 5);
    let eff = report
        .overlap_efficiency()
        .expect("threaded run records comm spans");
    assert!((0.0..=1.0).contains(&eff), "efficiency {eff} out of range");
}

/// Recording is strictly opt-in: a full threaded step executed with no
/// live session leaves zero events behind for the next session to see.
#[test]
fn steps_outside_a_session_record_nothing() {
    let rt = Runtime::native(NativeConfig { ring: 2, ..NativeConfig::tiny() }).unwrap();
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 3);
    let dist = DistRunner::new(&rt, Meter::new()).unwrap();

    // no Recorder: every span taken during this step is dead
    dist.forward_backward(&params, &batch).unwrap();

    let rec = obs::Recorder::start();
    let events = rec.finish();
    assert!(events.is_empty(), "stale events leaked into a fresh session: {events:?}");
}
