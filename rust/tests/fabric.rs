//! Integration: the threaded ring fabric is semantically identical to the
//! sequential fabric — same rotation order, same reductions, same metered
//! bytes — and deadlock-free under concurrency.
//!
//! (This suite proves the WIRE PROTOCOL itself is sound, message by
//! message — the foundation `exec::DistRunner` builds its per-rank
//! threads on.  Only the `backend-xla` feature still forces sequential
//! per-device simulation, its PJRT handles being thread-local; the
//! default native backend runs both ways, and
//! `rust/tests/dist_equivalence.rs` checks the full training step agrees
//! between them.)

use seqpar::comm::threaded::mesh;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::tensor::Tensor;
use seqpar::util::prop::Prop;
use seqpar::util::rng::Rng;

/// Run the full RSA forward rotation pattern both ways; compare the
/// sequence of chunks each device observes and the total ring bytes.
#[test]
fn threaded_and_sequential_fabrics_agree() {
    Prop::new(12, 41).check("fabric equivalence", |rng| {
        let n = 2 + rng.below(6) as usize;
        let len = 1 + rng.below(64) as usize;
        let chunks: Vec<Tensor> = (0..n)
            .map(|d| {
                let mut r = Rng::new(d as u64 * 97 + 5);
                Tensor::randn(&[len], 1.0, &mut r)
            })
            .collect();

        // sequential: rotate n-1 times, record what device 0 holds
        let seq_meter = Meter::new();
        let fabric = Fabric::new(n, seq_meter.clone());
        let mut slots = chunks.clone();
        let mut seq_seen = vec![slots[0].clone()];
        for _ in 0..n - 1 {
            fabric.ring_shift(&mut slots).map_err(|e| e.to_string())?;
            seq_seen.push(slots[0].clone());
        }

        // threaded: same pattern with real threads
        let thr_meter = Meter::new();
        let comms = mesh(n, thr_meter.clone());
        let mut handles = Vec::new();
        for (d, comm) in comms.into_iter().enumerate() {
            let mine = chunks[d].clone();
            handles.push(std::thread::spawn(move || {
                let mut held = mine;
                let mut seen = vec![held.clone()];
                for _ in 0..comm.n - 1 {
                    held = comm.ring_exchange(held).unwrap();
                    seen.push(held.clone());
                }
                (comm.rank, seen)
            }));
        }
        let mut thr_seen_dev0 = None;
        for h in handles {
            let (rank, seen) = h.join().unwrap();
            if rank == 0 {
                thr_seen_dev0 = Some(seen);
            }
        }
        let thr_seen = thr_seen_dev0.unwrap();
        if thr_seen.len() != seq_seen.len() {
            return Err("observation length mismatch".into());
        }
        for (i, (a, b)) in thr_seen.iter().zip(&seq_seen).enumerate() {
            if a != b {
                return Err(format!("device 0 step {i}: threaded != sequential"));
            }
        }
        // byte accounting identical
        if thr_meter.get(CommKind::RingP2p) != seq_meter.get(CommKind::RingP2p) {
            return Err(format!(
                "ring bytes differ: threaded {} vs sequential {}",
                thr_meter.get(CommKind::RingP2p),
                seq_meter.get(CommKind::RingP2p)
            ));
        }
        Ok(())
    });
}

#[test]
fn threaded_allreduce_matches_sequential() {
    Prop::new(8, 43).check("all-reduce equivalence", |rng| {
        let n = 2 + rng.below(5) as usize;
        let len = 1 + rng.below(32) as usize;
        let inputs: Vec<Tensor> = (0..n)
            .map(|d| {
                let mut r = Rng::new(d as u64 + 1000);
                Tensor::randn(&[len], 1.0, &mut r)
            })
            .collect();
        let fabric = Fabric::new(n, Meter::new());
        let mut slots = inputs.clone();
        fabric.all_reduce_sum(&mut slots).map_err(|e| e.to_string())?;

        let comms = mesh(n, Meter::new());
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(d, comm)| {
                let mine = inputs[d].clone();
                std::thread::spawn(move || comm.all_reduce_sum(mine).unwrap())
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            let want = &slots[0];
            let diff = seqpar::tensor::ops::max_abs_diff(&got, want).map_err(|e| e.to_string())?;
            if diff > 1e-5 {
                return Err(format!("threaded all-reduce diverged by {diff}"));
            }
        }
        Ok(())
    });
}

/// Stress: many concurrent full rotations with no ordering hints must not
/// deadlock (channels buffer sends — the NCCL-ring liveness argument).
#[test]
fn ring_protocol_is_deadlock_free_under_stress() {
    for trial in 0..4 {
        let n = 8;
        let comms = mesh(n, Meter::new());
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let mut held = Tensor::zeros(&[128]);
                    for _round in 0..20 {
                        held = comm.ring_exchange(held).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap_or_else(|_| panic!("trial {trial}: thread panicked"));
        }
    }
}
