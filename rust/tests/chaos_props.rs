//! Chaos property-fuzz suite for elastic recovery (`exec::recovery`).
//!
//! The contract under test: a run that loses a rank mid-step, re-carves
//! the surviving world and resumes MUST be equivalent to checkpointing
//! at the failed step and cleanly resuming on the re-carved topology —
//! same losses (1e-4), same gradients (1e-4), same optimizer state, the
//! same training-state fingerprint down to the bit
//! (`util::state_hash::train_state_hash`), and byte-for-byte comm-meter
//! parity on the post-recovery steps.
//!
//! Cases are fuzzed over (failure step × mesh factorization × SP
//! strategy × attention pattern × overlap) from a deterministic seed.
//! `CHAOS_CASES` / `CHAOS_SEED` env vars override the sweep size and
//! seed (the CI chaos job runs the fixed default plus a small
//! randomized sweep); failures print the case for replay.

use seqpar::attn::AttnPattern;
use seqpar::exec::{Elastic, ElasticConfig, ElasticOutcome, RankFailure, RecoverPolicy, Topo};
use seqpar::model::BERT_TINY_Z4;
use seqpar::parallel::sequence::SpStrategy;
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::tensor::ops;
use seqpar::train::checkpoint::{self, Checkpoint};
use seqpar::train::trainer::TrainConfig;
use seqpar::util::prop::{pick, Prop};
use seqpar::util::rng::Rng;
use seqpar::util::state_hash::train_state_hash;

const TOL: f32 = 1e-4;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One fuzzed chaos case — everything needed to reproduce it is in the
/// Debug print the assertions carry.
#[derive(Clone, Copy, Debug)]
struct Case {
    topo: Topo,
    pattern: AttnPattern,
    sp: SpStrategy,
    overlap: bool,
    steps: u64,
    fail_step: u64,
    fail_rank: usize,
}

impl Case {
    fn config(&self) -> ElasticConfig {
        ElasticConfig {
            // 4 heads: admits ulysses at every ring/mp size drawn below
            model: BERT_TINY_Z4,
            batch: 2,
            seq_len: 32,
            pattern: self.pattern,
            sp: self.sp,
            overlap: self.overlap,
            policy: RecoverPolicy::Reshard,
            data_seed: 7,
            init_seed: 0,
            train: TrainConfig {
                steps: self.steps,
                warmup: 1,
                peak_lr: 1e-3,
                log_every: 1, // log every step: the curves are compared
            },
            topo: self.topo,
            quiet: true,
        }
    }
}

fn draw_case(rng: &mut Rng) -> Case {
    // strategy first: ulysses pins the pattern to dense
    let sp = *pick(rng, &[SpStrategy::Ring, SpStrategy::Ulysses]);
    let on_mesh = rng.below(2) == 1;
    let pattern = if on_mesh || !sp.is_ring() {
        // the mesh runners and the ulysses schedule are dense-only
        AttnPattern::Dense
    } else {
        *pick(
            rng,
            &[AttnPattern::Dense, AttnPattern::Linformer { k: 8 }, AttnPattern::Block { w: 8 }],
        )
    };
    let topo = if on_mesh {
        let (dp, pp, mp) = *pick(rng, &[(2, 1, 2), (1, 2, 2), (1, 1, 4), (2, 2, 2)]);
        let micros = 1 + rng.below(2) as usize;
        Topo::Mesh { mesh: Mesh::new(dp, pp, mp, MpKind::Sequence).unwrap(), micros }
    } else {
        Topo::Flat { n: *pick(rng, &[2usize, 4]) }
    };
    let steps = 3 + rng.below(3); // 3..=5 optimizer steps
    Case {
        topo,
        pattern,
        sp,
        overlap: rng.below(2) == 1,
        steps,
        fail_step: rng.below(steps),
        fail_rank: rng.below(topo.world() as u64) as usize,
    }
}

/// Run the faulty leg, then the clean leg from the recovery checkpoint,
/// and hold the recovered==clean contract between them.
fn check_recovery(case: &Case) -> Result<(), String> {
    let tag = format!("{case:?}");
    let cfg = case.config();

    // faulty leg: inject the fault, recover, run to completion
    let faulty: ElasticOutcome = Elastic::new(cfg)
        .fault_at(case.fail_step, case.fail_rank)
        .run()
        .map_err(|e| format!("{tag}: faulty leg failed: {e:#}"))?;
    if faulty.recoveries.len() != 1 {
        return Err(format!("{tag}: expected 1 recovery, saw {}", faulty.recoveries.len()));
    }
    let event = &faulty.recoveries[0];
    if event.step != case.fail_step || event.failed_rank != case.fail_rank {
        return Err(format!("{tag}: recovery event mismatch: {event}"));
    }
    if event.old_world != case.topo.world() || event.new_world != faulty.final_topo.world() {
        return Err(format!("{tag}: recovery event worlds mismatch: {event}"));
    }
    if faulty.final_topo.world() >= case.topo.world() {
        return Err(format!(
            "{tag}: re-carve did not shrink the world ({} -> {})",
            case.topo.world(),
            faulty.final_topo.world()
        ));
    }
    let ckpt = faulty.checkpoints[0].clone();
    if ckpt.step != case.fail_step {
        return Err(format!("{tag}: checkpoint at step {}, not {}", ckpt.step, case.fail_step));
    }

    // clean leg: resume from the same checkpoint on the re-carved
    // topology — no faults, same total step budget
    let mut clean_cfg = cfg;
    clean_cfg.topo = faulty.final_topo;
    let clean: ElasticOutcome = Elastic::new(clean_cfg)
        .resume_from(ckpt)
        .run()
        .map_err(|e| format!("{tag}: clean leg failed: {e:#}"))?;
    if !clean.recoveries.is_empty() {
        return Err(format!("{tag}: clean leg recovered?"));
    }

    // losses: the faulty curve from the failed step on == the clean curve
    let suffix: Vec<_> = faulty.curve.iter().filter(|p| p.step >= case.fail_step).collect();
    if suffix.len() != clean.curve.len() {
        return Err(format!(
            "{tag}: curve suffix {} points vs clean {}",
            suffix.len(),
            clean.curve.len()
        ));
    }
    for (f, c) in suffix.iter().zip(&clean.curve) {
        if f.step != c.step {
            return Err(format!("{tag}: curve step {} vs {}", f.step, c.step));
        }
        for (name, a, b) in
            [("loss", f.loss, c.loss), ("mlm", f.mlm, c.mlm), ("sop", f.sop, c.sop)]
        {
            if (a - b).abs() > TOL {
                return Err(format!("{tag}: step {} {name} {a} vs clean {b}", f.step));
            }
        }
    }

    // gradients of the final step: every tensor within tolerance
    let (fg, cg) = match (&faulty.last_grads, &clean.last_grads) {
        (Some(f), Some(c)) => (f, c),
        _ => return Err(format!("{tag}: a leg finished without gradients")),
    };
    for (name, g) in &cg.values {
        let d = ops::max_abs_diff(&fg.values[name], g).map_err(|e| format!("{tag}: {e}"))?;
        if d > TOL {
            return Err(format!("{tag}: final grad {name} diverged, Δ={d}"));
        }
    }

    // params + optimizer moments within tolerance...
    for (store_tag, a, b) in [
        ("param", &faulty.params, &clean.params),
        ("adam_m", faulty.adam.state().0, clean.adam.state().0),
        ("adam_v", faulty.adam.state().1, clean.adam.state().1),
    ] {
        for (name, t) in &b.values {
            let d = ops::max_abs_diff(&a.values[name], t).map_err(|e| format!("{tag}: {e}"))?;
            if d > TOL {
                return Err(format!("{tag}: final {store_tag} {name} diverged, Δ={d}"));
            }
        }
    }
    // ...and in fact identical to the bit, data cursor included: both
    // legs executed the same dataflow from the same state
    if faulty.cursor != clean.cursor {
        return Err(format!("{tag}: cursor {} vs clean {}", faulty.cursor, clean.cursor));
    }
    let fh = train_state_hash(&faulty.params, &faulty.adam, faulty.cursor);
    let ch = train_state_hash(&clean.params, &clean.adam, clean.cursor);
    if fh != ch {
        return Err(format!("{tag}: state hash {fh:#x} vs clean {ch:#x}"));
    }

    // byte-for-byte meter parity on the post-recovery steps (the faulty
    // leg's meter restarts at the re-carve for exactly this comparison)
    if faulty.post_meter != clean.post_meter {
        return Err(format!(
            "{tag}: post-recovery meters differ: {:?} vs {:?}",
            faulty.post_meter, clean.post_meter
        ));
    }
    Ok(())
}

/// The fixed cornerstone cases, always run: one flat ring and one
/// pipelined mesh, fault mid-run.
#[test]
fn recovered_equals_clean_flat_ring_fixed_case() {
    check_recovery(&Case {
        topo: Topo::Flat { n: 4 },
        pattern: AttnPattern::Dense,
        sp: SpStrategy::Ring,
        overlap: false,
        steps: 4,
        fail_step: 1,
        fail_rank: 2,
    })
    .unwrap();
}

#[test]
fn recovered_equals_clean_mesh_fixed_case() {
    check_recovery(&Case {
        topo: Topo::Mesh { mesh: Mesh::new(2, 1, 2, MpKind::Sequence).unwrap(), micros: 2 },
        pattern: AttnPattern::Dense,
        sp: SpStrategy::Ring,
        overlap: true,
        steps: 4,
        fail_step: 2,
        fail_rank: 1,
    })
    .unwrap();
}

/// A failure at step 0 recovers from pristine state (the checkpoint is
/// the init itself); ulysses re-carves under its head cap.
#[test]
fn recovered_equals_clean_ulysses_failure_at_step_zero() {
    check_recovery(&Case {
        topo: Topo::Flat { n: 4 },
        pattern: AttnPattern::Dense,
        sp: SpStrategy::Ulysses,
        overlap: false,
        steps: 3,
        fail_step: 0,
        fail_rank: 0,
    })
    .unwrap();
}

/// The randomized sweep: (failure step × factorization × SP strategy ×
/// pattern × overlap) from a deterministic seed.  CHAOS_CASES /
/// CHAOS_SEED override; each failed case prints its full Case for
/// replay.
#[test]
fn recovered_equals_clean_fuzzed() {
    let cases = env_u64("CHAOS_CASES", 4) as usize;
    let seed = env_u64("CHAOS_SEED", 0xc4a0_5001);
    Prop::new(cases, seed).check("recovered == clean resume", |rng| {
        let case = draw_case(rng);
        check_recovery(&case)
    });
}

/// `--recover none` (the default policy): the injected failure must
/// surface as the typed, contextful PR-9 report — dead rank named, no
/// hang, downcastable `RankFailure` — and NOT trigger a re-carve.
#[test]
fn recover_none_propagates_the_contextful_failure() {
    for topo in [
        Topo::Flat { n: 4 },
        Topo::Mesh { mesh: Mesh::new(2, 1, 2, MpKind::Sequence).unwrap(), micros: 1 },
    ] {
        let case = Case {
            topo,
            pattern: AttnPattern::Dense,
            sp: SpStrategy::Ring,
            overlap: false,
            steps: 3,
            fail_step: 1,
            fail_rank: 1,
        };
        let mut cfg = case.config();
        cfg.policy = RecoverPolicy::None;
        let err = Elastic::new(cfg)
            .fault_at(case.fail_step, case.fail_rank)
            .run()
            .err()
            .expect("policy none must propagate the failure, not recover");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "must name the dead rank: {msg}");
        assert!(msg.contains("panicked"), "must say the rank panicked: {msg}");
        let failure = err
            .downcast_ref::<RankFailure>()
            .expect("the propagated error must stay downcastable");
        assert_eq!(failure.rank, 1);
        assert_eq!(failure.world, case.topo.world());
    }
}

/// A fault aimed past the end of the run (or at a rank outside the
/// world) never fires: the run completes cleanly with zero recoveries.
#[test]
fn unfired_faults_leave_the_run_untouched() {
    let case = Case {
        topo: Topo::Flat { n: 2 },
        pattern: AttnPattern::Dense,
        sp: SpStrategy::Ring,
        overlap: false,
        steps: 2,
        fail_step: 0,
        fail_rank: 0,
    };
    let out = Elastic::new(case.config())
        .fault_at(99, 0) // past the end
        .fault_at(0, 7) // rank outside the 2-rank world
        .run()
        .unwrap();
    assert!(out.recoveries.is_empty(), "no fault fired, nothing to recover");
    assert_eq!(out.curve.len(), 2);
}

/// Mid-epoch resume equivalence through the DISK checkpoint path: run A
/// trains 4 uninterrupted steps; run B trains 2 steps, saves a
/// checkpoint (data cursor included), reloads it, and trains the
/// remaining 2.  Final state hashes must agree — this is the regression
/// test for the data-loader cursor that checkpoints previously dropped
/// (a resumed run would silently replay the epoch from batch 0).
#[test]
fn mid_epoch_disk_resume_matches_uninterrupted_run() {
    let case = Case {
        topo: Topo::Flat { n: 4 },
        pattern: AttnPattern::Dense,
        sp: SpStrategy::Ring,
        overlap: false,
        steps: 4,
        fail_step: 0, // unused: no fault is injected below
        fail_rank: 0,
    };
    let cfg = case.config();

    // leg A: uninterrupted
    let a = Elastic::new(cfg).run().unwrap();

    // leg B: stop after 2 steps...
    let mut half = cfg;
    half.train.steps = 2;
    let b1 = Elastic::new(half).run().unwrap();
    assert_eq!(b1.cursor, 2, "flat topology draws one batch per step");

    // ...checkpoint to disk, cursor included...
    let dir = std::env::temp_dir().join("seqpar_chaos_mid_epoch_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let ck = Checkpoint::capture(2, &b1.params, &b1.adam, b1.cursor);
    checkpoint::save(&dir, &ck).unwrap();
    let loaded = checkpoint::load(&dir).unwrap();
    assert_eq!(loaded.data_cursor, 2, "cursor lost in the disk round-trip");

    // ...and resume to the full 4 steps
    let b2 = Elastic::new(cfg).resume_from(loaded).run().unwrap();

    assert_eq!(
        train_state_hash(&a.params, &a.adam, a.cursor),
        train_state_hash(&b2.params, &b2.adam, b2.cursor),
        "mid-epoch disk resume diverged from the uninterrupted run"
    );
    // the resumed curve is the uninterrupted curve's suffix
    let a_suffix: Vec<_> = a.curve.iter().filter(|p| p.step >= 2).collect();
    assert_eq!(a_suffix.len(), b2.curve.len());
    for (x, y) in a_suffix.iter().zip(&b2.curve) {
        assert_eq!(x.step, y.step);
        assert!((x.loss - y.loss).abs() <= TOL, "step {}: {} vs {}", x.step, x.loss, y.loss);
    }
}
