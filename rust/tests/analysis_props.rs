//! Property-based fuzz over the static analyzer (`seqpar::analysis`):
//! for every sampled configuration the trace-derived per-kind byte
//! totals must equal a REAL engine run's meter EXACTLY (the closed-form
//! leg is checked inside `Analysis::verify`), invalid combinations must
//! be rejected by the analyzer and the engine ALIKE (never a panic),
//! and a deliberately skewed schedule must produce the per-rank
//! first-divergence diff instead of the deadlock it models.

use seqpar::analysis::{self, TraceEvent};
use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::exec::{MeshEngine, MeshStep};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::topology::{Mesh, MpKind};
use seqpar::parallel::{Batch, Engine};
use seqpar::runtime::Runtime;
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::util::prop::{self, Prop};

/// bert-tiny-z4 (4 heads) keeps every ring/mp in {1,2,4} compatible
/// with both SP strategies and with TP head sharding.
fn runtime_for(
    ring: usize,
    seq_len: usize,
    pattern: AttnPattern,
    sp: SpStrategy,
) -> Result<Runtime, String> {
    let (linformer_k, block_w) = pattern.native_knobs();
    Runtime::native(NativeConfig {
        model: BERT_TINY_Z4,
        batch: 2,
        seq_len,
        ring,
        tp: 1,
        linformer_k,
        block_w,
        ulysses: !sp.is_ring(),
        seed: 0,
    })
    .map_err(|e| e.to_string())
}

fn batch_for(rt: &Runtime, seed: u64) -> Result<Batch, String> {
    let m = rt.manifest();
    Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed)
        .next_batch()
        .map_err(|e| e.to_string())
}

/// analyzer derived bytes == measured engine bytes, per collective kind,
/// over random (ring, sp strategy, attention pattern); invalid combos
/// (Ulysses re-shards whole heads, so it needs dense attention) must be
/// rejected statically by BOTH the analyzer and the engine constructor.
#[test]
fn sp_step_analyzer_bytes_equal_measured_bytes() {
    Prop::new(14, 0xa11a_515).check("sp analyzer ~ measured", |rng| {
        let ring = *prop::pick(rng, &[1usize, 2, 4]);
        let sp = *prop::pick(rng, &[SpStrategy::Ring, SpStrategy::Ulysses]);
        let pattern = *prop::pick(
            rng,
            &[AttnPattern::Dense, AttnPattern::Linformer { k: 8 }, AttnPattern::Block { w: 8 }],
        );
        let seq_len = ring * *prop::pick(rng, &[8usize, 16]);
        let invalid = !sp.is_ring() && pattern != AttnPattern::Dense;

        let rt = match runtime_for(ring, seq_len, pattern, sp) {
            Ok(rt) => rt,
            // some invalid combos may already fail at manifest build —
            // that is a static rejection too
            Err(_) if invalid => return Ok(()),
            Err(e) => return Err(format!("valid config rejected at build: {e}")),
        };
        let analyzed = analysis::analyze_sp_step(&rt, pattern, sp);
        let meter = Meter::new();
        let engine = SeqParEngine::with_strategy(&rt, Fabric::new(ring, meter.clone()), pattern, sp);

        if invalid {
            if analyzed.is_ok() {
                return Err(format!(
                    "ring={ring} sp={} attn={:?}: analyzer should reject",
                    sp.label(),
                    pattern
                ));
            }
            if engine.is_ok() {
                return Err(format!(
                    "ring={ring} sp={} attn={:?}: engine should reject",
                    sp.label(),
                    pattern
                ));
            }
            return Ok(()); // rejection path exercised, consistently
        }

        let a = analyzed.map_err(|e| format!("analyzer rejected a valid config: {e:#}"))?;
        a.verify().map_err(|e| format!("{e:#}"))?;

        let params = ParamStore::synthetic(rt.manifest());
        let batch = batch_for(&rt, 11 + ring as u64)?;
        engine
            .map_err(|e| e.to_string())?
            .forward_backward(&params, &batch)
            .map_err(|e| e.to_string())?;
        let measured = meter.snapshot();
        if !a.derived.same_bytes(&measured) {
            return Err(format!(
                "ring={ring}: derived bytes != measured bytes\n{}",
                a.report(Some(&measured))
            ));
        }
        Ok(())
    });
}

/// Same invariant over random mesh factorizations: the analyzer's
/// abstract interpretation of the full DP×PP×MP step must meter the
/// exact bytes the threaded `MeshEngine` moves, and both must agree on
/// which factorizations are valid.
#[test]
fn mesh_analyzer_bytes_equal_measured_bytes() {
    Prop::new(10, 0x5e_9a27).check("mesh analyzer ~ measured", |rng| {
        let world = *prop::pick(rng, &[1usize, 2, 4]);
        let (dp, pp, mp) = prop::factor3(rng, world);
        let kind = if rng.below(2) == 0 { MpKind::Sequence } else { MpKind::Tensor };
        let sp = *prop::pick(rng, &[SpStrategy::Ring, SpStrategy::Ulysses]);
        let pattern =
            *prop::pick(rng, &[AttnPattern::Dense, AttnPattern::Linformer { k: 8 }, AttnPattern::Block { w: 8 }]);
        let micros = 1 + rng.below(2) as usize;
        let seq_len = mp * *prop::pick(rng, &[8usize, 16]);

        let mesh = Mesh::new(dp, pp, mp, kind).map_err(|e| e.to_string())?;
        let (linformer_k, block_w) = pattern.native_knobs();
        let cfg = NativeConfig {
            model: BERT_TINY_Z4,
            batch: 2,
            seq_len,
            ring: 4,
            tp: 2,
            linformer_k,
            block_w,
            ulysses: !sp.is_ring(),
            seed: 0,
        }
        .for_mesh(&mesh);
        let rt = Runtime::native(cfg).map_err(|e| e.to_string())?;

        let analyzed = analysis::analyze_mesh(&rt, mesh, micros, sp);
        let meter = Meter::new();
        let engine = MeshEngine::with_strategy(&rt, mesh, micros, meter.clone(), sp);

        // the analyzer and the engine must agree on validity: both go
        // through the same spec, so a one-sided rejection is a bug
        match (&analyzed, &engine) {
            (Err(_), Err(_)) => return Ok(()), // e.g. linformer on a mesh, pp ∤ layers
            (Err(e), Ok(_)) => {
                return Err(format!(
                    "{} micros={micros}: analyzer rejected what the engine accepts: {e:#}",
                    mesh.label()
                ))
            }
            (Ok(_), Err(e)) => {
                return Err(format!(
                    "{} micros={micros}: engine rejected what the analyzer accepts: {e}",
                    mesh.label()
                ))
            }
            (Ok(_), Ok(_)) => {}
        }
        let a = analyzed.map_err(|e| format!("{e:#}"))?;
        a.verify().map_err(|e| format!("{e:#}"))?;

        let m = rt.manifest().clone();
        let params = ParamStore::synthetic(&m);
        let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 17 + world as u64);
        let batches: Vec<Vec<Batch>> = (0..dp)
            .map(|_| (0..micros).map(|_| corpus.next_batch().unwrap()).collect())
            .collect();
        engine
            .map_err(|e| e.to_string())?
            .step(&params, &batches)
            .map_err(|e| e.to_string())?;
        let measured = meter.snapshot();
        if !a.derived.same_bytes(&measured) {
            return Err(format!(
                "{} micros={micros} sp={}: derived bytes != measured bytes\n{}",
                mesh.label(),
                sp.label(),
                a.report(Some(&measured))
            ));
        }
        Ok(())
    });
}

/// Negative path: skew ONE rank's schedule by one extra collective and
/// the analyzer must localise the divergence — group, event index, what
/// each rank issues — instead of letting a real run hang.
#[test]
fn skewed_schedule_is_statically_rejected_with_a_rank_diff() {
    let rt = runtime_for(4, 32, AttnPattern::Dense, SpStrategy::Ring).unwrap();
    let mut a = analysis::analyze_sp_step(&rt, AttnPattern::Dense, SpStrategy::Ring).unwrap();
    a.verify().expect("the untouched schedule must pass");

    // rank 1 issues one all-reduce the other ranks never post
    a.groups[0].traces[1].events.push(TraceEvent::AllReduce { bytes: 4 });

    let d = a.check_matched().expect_err("the skew must be detected");
    let msg = d.to_string();
    assert!(msg.contains("rank 1: all_reduce[4B]"), "{msg}");
    assert!(msg.contains("rank 0: (end of schedule)"), "{msg}");
    assert!(msg.contains("deadlock"), "{msg}");
    assert!(a.verify().is_err(), "verify must fail on a skewed schedule");

    // and the rendered report carries the diff + a failing verdict
    let report = a.report(None);
    assert!(report.contains("MISMATCH"), "{report}");
    assert!(report.contains("FAIL"), "{report}");
    assert!(report.contains("deadlock"), "{report}");
}
